//! Load generator for the PAC-native serving pipeline (DESIGN.md §8).
//!
//! Drives the multi-worker batching coordinator with two traffic
//! patterns — open-loop Poisson arrivals (a fixed offered rate,
//! submissions never wait on replies, overload load-sheds) and
//! closed-loop clients (a fixed concurrency, each client waits for its
//! reply) — against three executors:
//!
//! - `mock`  — a no-compute executor isolating the batcher itself;
//! - `pac`   — [`pacim::runtime::PacExecutor`], the thin serving
//!   adapter over the `pacim::engine` front door running the hybrid
//!   digital/sparsity PACiM computation (the real serving path);
//! - `exact` — the fully digital 8b/8b baseline executor.
//!
//! Emits `BENCH_serve.json` (schema: `pacim::util::benchfmt`) with
//! throughput, latency percentiles, the batch-fill histogram, load-shed
//! counts, and the modeled PACiM cycles/energy per image — CI uploads it
//! next to `BENCH_hotpath.json` to track the serving perf trajectory.
//!
//! Run: `cargo run --release --example loadgen -- [options]`
//!
//! ```text
//! --executor mock|pac|exact|all   (default all)
//! --mode     open|closed|both     (default both)
//! --requests N   --clients N   --workers N   --batch N
//! --wait-ms T    --queue-cap N --rps R       --seed S
//! --out PATH     (default BENCH_serve.json)
//! ```
//!
//! Set `PACIM_BENCH_QUICK=1` for a seconds-long smoke run (CI).

use pacim::coordinator::{BatchExecutor, BatchPolicy, CostEstimate, InferenceServer, ServeError};
use pacim::nn::{Model, PacConfig};
use pacim::runtime::PacExecutor;
use pacim::util::benchfmt::{ServeReport, ServeScenario};
use pacim::util::rng::Rng;
use pacim::workload::{synthetic_serving_workload, Dataset};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// No-compute executor: isolates batcher/pool overhead. Logit j of lane
/// i is `sum(lane_i) + j` so clients can verify their own reply.
struct MockExec {
    batch: usize,
    in_elems: usize,
    out_elems: usize,
    delay: Duration,
}

impl BatchExecutor for MockExec {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn input_elems(&self) -> usize {
        self.in_elems
    }

    fn output_elems(&self) -> usize {
        self.out_elems
    }

    fn execute(&mut self, batch: &[f32], _occupancy: usize) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        let mut out = Vec::with_capacity(self.batch * self.out_elems);
        for i in 0..self.batch {
            let s: f32 = batch[i * self.in_elems..(i + 1) * self.in_elems].iter().sum();
            for j in 0..self.out_elems {
                out.push(s + j as f32);
            }
        }
        Ok(out)
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Exec {
    Mock,
    Pac,
    Exact,
}

impl Exec {
    fn name(self) -> &'static str {
        match self {
            Exec::Mock => "mock",
            Exec::Pac => "pac",
            Exec::Exact => "exact",
        }
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Open,
    Closed,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Open => "open",
            Mode::Closed => "closed",
        }
    }
}

struct Opts {
    requests: usize,
    clients: usize,
    workers: usize,
    batch: usize,
    wait: Duration,
    queue_cap: usize,
    rps: f64,
    seed: u64,
}

fn arg_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Parse a numeric flag: absent → default, present-but-invalid → error
/// (a typo must not silently benchmark a different scenario).
fn parse_num<T: std::str::FromStr>(
    args: &[String],
    flag: &str,
    default: T,
) -> anyhow::Result<T> {
    match arg_value(args, flag) {
        None => Ok(default),
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid value for {flag}: '{s}'")),
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = std::env::var("PACIM_BENCH_QUICK")
        .ok()
        .is_some_and(|v| v != "0" && !v.is_empty());
    let opts = Opts {
        requests: parse_num(&args, "--requests", if quick { 48 } else { 1024 })?,
        clients: parse_num(&args, "--clients", 8usize)?.max(1),
        workers: parse_num(&args, "--workers", 2usize)?.max(1),
        batch: parse_num(&args, "--batch", 8usize)?.max(1),
        wait: Duration::from_millis(parse_num(&args, "--wait-ms", 2u64)?),
        queue_cap: parse_num(&args, "--queue-cap", 256usize)?,
        rps: parse_num(&args, "--rps", if quick { 300.0 } else { 1500.0 })?,
        seed: parse_num(&args, "--seed", 2024u64)?,
    };
    anyhow::ensure!(
        opts.rps.is_finite() && opts.rps > 0.0,
        "--rps must be a positive offered rate (got {})",
        opts.rps
    );
    let out_path = arg_value(&args, "--out").unwrap_or_else(|| "BENCH_serve.json".into());

    let execs: Vec<Exec> = match arg_value(&args, "--executor").as_deref() {
        Some("mock") => vec![Exec::Mock],
        Some("pac") => vec![Exec::Pac],
        Some("exact") => vec![Exec::Exact],
        Some("all") | None => vec![Exec::Mock, Exec::Pac, Exec::Exact],
        Some(other) => anyhow::bail!("unknown --executor '{other}' (mock|pac|exact|all)"),
    };
    let modes: Vec<Mode> = match arg_value(&args, "--mode").as_deref() {
        Some("open") => vec![Mode::Open],
        Some("closed") => vec![Mode::Closed],
        Some("both") | None => vec![Mode::Closed, Mode::Open],
        Some(other) => anyhow::bail!("unknown --mode '{other}' (open|closed|both)"),
    };

    // One synthetic workload shared by the pac/exact scenarios (weights
    // random; the compute and therefore the measured pipeline are real).
    let (model, ds) = synthetic_serving_workload(opts.seed, 8, 16, 10, 64)?;

    println!(
        "loadgen: {} requests | {} workers | batch {} | queue cap {} | {}",
        opts.requests,
        opts.workers,
        opts.batch,
        opts.queue_cap,
        if quick { "quick mode" } else { "full mode" }
    );
    let mut scenarios = Vec::new();
    for &exec in &execs {
        for &mode in &modes {
            let sc = run_scenario(exec, mode, &opts, &model, &ds)?;
            println!(
                "  {:<12} {:>7.1} req/s | p50 {:>8.0} us | p95 {:>8.0} us | p99 {:>8.0} us | \
                 fill {:.2} | shed {}",
                sc.name, sc.throughput_rps, sc.p50_us, sc.p95_us, sc.p99_us,
                sc.mean_batch_occupancy, sc.rejected
            );
            scenarios.push(sc);
        }
    }

    let report = ServeReport {
        bench: "serve".into(),
        quick,
        scenarios,
    };
    let json = serde_json::to_string_pretty(&report)?;
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    Ok(())
}

fn run_scenario(
    exec: Exec,
    mode: Mode,
    opts: &Opts,
    model: &Model,
    ds: &Dataset,
) -> anyhow::Result<ServeScenario> {
    let policy = BatchPolicy {
        max_wait: opts.wait,
        workers: opts.workers,
        queue_cap: opts.queue_cap,
        ..BatchPolicy::default()
    };
    let server = match exec {
        Exec::Mock => {
            let (batch, in_elems) = (opts.batch, ds.image_elems());
            InferenceServer::start_pool(
                move |_| {
                    Ok(MockExec {
                        batch,
                        in_elems,
                        out_elems: 10,
                        delay: Duration::from_micros(300),
                    })
                },
                policy,
            )?
        }
        Exec::Pac => {
            let e = PacExecutor::new(model.clone(), PacConfig::serving(), opts.batch)?;
            InferenceServer::start_pool(move |_| Ok(e.clone()), policy)?
        }
        Exec::Exact => {
            let e = PacExecutor::exact(model.clone(), opts.batch)?;
            InferenceServer::start_pool(move |_| Ok(e.clone()), policy)?
        }
    };

    let input = |i: usize| -> Vec<f32> {
        let idx = i % ds.n;
        ds.image(idx).iter().map(|&q| ds.params.dequantize(q)).collect()
    };

    let completed = AtomicU64::new(0);
    let mut sample_cost: Option<CostEstimate> = None;
    let t0 = Instant::now();
    match mode {
        Mode::Closed => {
            let h = server.handle();
            let next = AtomicUsize::new(0);
            std::thread::scope(|s| {
                let mut joins = Vec::new();
                for _ in 0..opts.clients {
                    let h = h.clone();
                    let completed = &completed;
                    let next = &next;
                    let input = &input;
                    joins.push(s.spawn(move || {
                        let mut cost = None;
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= opts.requests {
                                break cost;
                            }
                            if let Ok(r) = h.infer(input(i)) {
                                completed.fetch_add(1, Ordering::Relaxed);
                                cost = cost.or(r.cost);
                            }
                        }
                    }));
                }
                for j in joins {
                    sample_cost = sample_cost.or(j.join().unwrap());
                }
            });
        }
        Mode::Open => {
            let h = server.handle();
            let mut rng = Rng::new(opts.seed ^ 0x0DE1);
            let mut pending = Vec::with_capacity(opts.requests);
            let mut next_at = Instant::now();
            for i in 0..opts.requests {
                // Exponential inter-arrival → Poisson process at `rps`.
                let dt = -(1.0 - rng.next_f64()).ln() / opts.rps;
                next_at += Duration::from_secs_f64(dt);
                if let Some(wait) = next_at.checked_duration_since(Instant::now()) {
                    std::thread::sleep(wait);
                }
                match h.submit(input(i)) {
                    Ok(p) => pending.push(p),
                    Err(ServeError::QueueFull { .. }) => {} // counted server-side
                    Err(e) => return Err(e.into()),
                }
            }
            for p in pending {
                if let Ok(r) = p.wait() {
                    completed.fetch_add(1, Ordering::Relaxed);
                    sample_cost = sample_cost.or(r.cost);
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.stop();
    let completed = completed.load(Ordering::Relaxed);
    Ok(ServeScenario {
        name: format!("{}-{}", exec.name(), mode.name()),
        executor: exec.name().into(),
        mode: mode.name().into(),
        workers: opts.workers,
        batch_size: opts.batch,
        queue_cap: opts.queue_cap,
        offered_rps: if mode == Mode::Open { opts.rps } else { 0.0 },
        requests: opts.requests as u64,
        completed,
        rejected: m.rejected,
        failed_batches: m.failed_batches,
        wall_s: wall,
        throughput_rps: if wall > 0.0 { completed as f64 / wall } else { 0.0 },
        p50_us: m.latency_percentile_us(50.0),
        p95_us: m.latency_percentile_us(95.0),
        p99_us: m.latency_percentile_us(99.0),
        mean_batch_occupancy: m.mean_batch_occupancy(),
        batch_fill: m.batch_fill.clone(),
        modeled_cycles_per_image: sample_cost.map_or(0, |c| c.cycles),
        modeled_energy_uj_per_image: sample_cost.map_or(0.0, |c| c.total_uj()),
        // Measured dataplane traffic, aggregated from every worker's
        // executor telemetry at pool drain — 0 for the mock executor,
        // which has no ledger.
        measured_traffic_bits: m.traffic_bits,
        traffic_baseline_bits: m.traffic_baseline_bits,
        bits_per_request: if completed > 0 {
            m.traffic_bits as f64 / completed as f64
        } else {
            0.0
        },
        escalated: m.escalated,
    })
}
