//! Load generator for the PAC-native serving pipeline (DESIGN.md §8).
//!
//! Drives the multi-worker batching coordinator with two traffic
//! patterns — open-loop Poisson arrivals (a fixed offered rate,
//! submissions never wait on replies, overload load-sheds) and
//! closed-loop clients (a fixed concurrency, each client waits for its
//! reply) — against three executors:
//!
//! - `mock`  — a no-compute executor isolating the batcher itself;
//! - `pac`   — [`pacim::runtime::PacExecutor`], the thin serving
//!   adapter over the `pacim::engine` front door running the hybrid
//!   digital/sparsity PACiM computation (the real serving path);
//! - `exact` — the fully digital 8b/8b baseline executor.
//!
//! Emits `BENCH_serve.json` (schema: `pacim::util::benchfmt`) with
//! throughput, latency percentiles, the batch-fill histogram, load-shed
//! counts, and the modeled PACiM cycles/energy per image — CI uploads it
//! next to `BENCH_hotpath.json` to track the serving perf trajectory.
//!
//! Run: `cargo run --release --example loadgen -- [options]`
//!
//! ```text
//! --executor mock|pac|exact|all   (default all)
//! --mode     open|closed|both     (default both)
//! --requests N   --clients N   --workers N   --batch N
//! --wait-ms T    --queue-cap N --rps R       --seed S
//! --mix "resnet18=0.8,tinyvgg=0.2"   (multi-model open-loop rows)
//! --out PATH     (default BENCH_serve.json)
//! ```
//!
//! With `--mix`, one additional open-loop run drives a multi-model
//! deployment (`PacExecutor::serve_registry` behind a single
//! `MultiModelHandle`): arrivals at the total `--rps` draw a tenant by
//! the given weights, and one `"mix-<model>-open"` row per tenant
//! lands in the report with per-model latency, throughput, shard/steal
//! counters, and bits-per-request. `PACIM_ENFORCE_SERVE_SLO=1` gates
//! these rows through `benchfmt::enforce_serve_slo`.
//!
//! Set `PACIM_BENCH_QUICK=1` for a seconds-long smoke run (CI).

use pacim::coordinator::{
    BatchExecutor, BatchPolicy, CostEstimate, InferenceServer, ModelRegistry, ModelSpec,
    ServeError,
};
use pacim::engine::EngineBuilder;
use pacim::nn::{Model, PacConfig};
use pacim::runtime::PacExecutor;
use pacim::util::benchfmt::{ServeReport, ServeScenario};
use pacim::util::rng::Rng;
use pacim::util::Parallelism;
use pacim::workload::{synthetic_serving_workload, synthetic_tenant_workload, Dataset};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// No-compute executor: isolates batcher/pool overhead. Logit j of lane
/// i is `sum(lane_i) + j` so clients can verify their own reply.
struct MockExec {
    batch: usize,
    in_elems: usize,
    out_elems: usize,
    delay: Duration,
}

impl BatchExecutor for MockExec {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn input_elems(&self) -> usize {
        self.in_elems
    }

    fn output_elems(&self) -> usize {
        self.out_elems
    }

    fn execute(&mut self, batch: &[f32], _occupancy: usize) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        let mut out = Vec::with_capacity(self.batch * self.out_elems);
        for i in 0..self.batch {
            let s: f32 = batch[i * self.in_elems..(i + 1) * self.in_elems].iter().sum();
            for j in 0..self.out_elems {
                out.push(s + j as f32);
            }
        }
        Ok(out)
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Exec {
    Mock,
    Pac,
    Exact,
}

impl Exec {
    fn name(self) -> &'static str {
        match self {
            Exec::Mock => "mock",
            Exec::Pac => "pac",
            Exec::Exact => "exact",
        }
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Open,
    Closed,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Open => "open",
            Mode::Closed => "closed",
        }
    }
}

struct Opts {
    requests: usize,
    clients: usize,
    workers: usize,
    batch: usize,
    wait: Duration,
    queue_cap: usize,
    rps: f64,
    seed: u64,
}

fn arg_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Parse `--mix "resnet18=0.8,tinyvgg=0.2"` into (tenant id, weight)
/// pairs; weights must be positive and are normalized by the caller.
fn parse_mix(spec: &str) -> anyhow::Result<Vec<(String, f64)>> {
    let mut mix = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (id, w) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--mix entry '{part}' is not '<model>=<weight>'"))?;
        let weight: f64 = w
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--mix entry '{part}': invalid weight '{w}'"))?;
        anyhow::ensure!(
            weight.is_finite() && weight > 0.0,
            "--mix entry '{part}': weight must be positive"
        );
        let id = id.trim().to_string();
        anyhow::ensure!(
            !mix.iter().any(|(m, _)| *m == id),
            "--mix lists model '{id}' twice"
        );
        mix.push((id, weight));
    }
    anyhow::ensure!(!mix.is_empty(), "--mix parsed no '<model>=<weight>' entries");
    Ok(mix)
}

/// Parse a numeric flag: absent → default, present-but-invalid → error
/// (a typo must not silently benchmark a different scenario).
fn parse_num<T: std::str::FromStr>(
    args: &[String],
    flag: &str,
    default: T,
) -> anyhow::Result<T> {
    match arg_value(args, flag) {
        None => Ok(default),
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid value for {flag}: '{s}'")),
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = std::env::var("PACIM_BENCH_QUICK")
        .ok()
        .is_some_and(|v| v != "0" && !v.is_empty());
    let opts = Opts {
        requests: parse_num(&args, "--requests", if quick { 48 } else { 1024 })?,
        clients: parse_num(&args, "--clients", 8usize)?.max(1),
        workers: parse_num(&args, "--workers", 2usize)?.max(1),
        batch: parse_num(&args, "--batch", 8usize)?.max(1),
        wait: Duration::from_millis(parse_num(&args, "--wait-ms", 2u64)?),
        queue_cap: parse_num(&args, "--queue-cap", 256usize)?,
        rps: parse_num(&args, "--rps", if quick { 300.0 } else { 1500.0 })?,
        seed: parse_num(&args, "--seed", 2024u64)?,
    };
    anyhow::ensure!(
        opts.rps.is_finite() && opts.rps > 0.0,
        "--rps must be a positive offered rate (got {})",
        opts.rps
    );
    let out_path = arg_value(&args, "--out").unwrap_or_else(|| "BENCH_serve.json".into());

    let execs: Vec<Exec> = match arg_value(&args, "--executor").as_deref() {
        Some("mock") => vec![Exec::Mock],
        Some("pac") => vec![Exec::Pac],
        Some("exact") => vec![Exec::Exact],
        Some("all") | None => vec![Exec::Mock, Exec::Pac, Exec::Exact],
        Some(other) => anyhow::bail!("unknown --executor '{other}' (mock|pac|exact|all)"),
    };
    let modes: Vec<Mode> = match arg_value(&args, "--mode").as_deref() {
        Some("open") => vec![Mode::Open],
        Some("closed") => vec![Mode::Closed],
        Some("both") | None => vec![Mode::Closed, Mode::Open],
        Some(other) => anyhow::bail!("unknown --mode '{other}' (open|closed|both)"),
    };

    // One synthetic workload shared by the pac/exact scenarios (weights
    // random; the compute and therefore the measured pipeline are real).
    let (model, ds) = synthetic_serving_workload(opts.seed, 8, 16, 10, 64)?;

    println!(
        "loadgen: {} requests | {} workers | batch {} | queue cap {} | {}",
        opts.requests,
        opts.workers,
        opts.batch,
        opts.queue_cap,
        if quick { "quick mode" } else { "full mode" }
    );
    let mut scenarios = Vec::new();
    for &exec in &execs {
        for &mode in &modes {
            let sc = run_scenario(exec, mode, &opts, &model, &ds)?;
            println!(
                "  {:<12} {:>7.1} req/s | p50 {:>8.0} us | p95 {:>8.0} us | p99 {:>8.0} us | \
                 fill {:.2} | shed {}",
                sc.name, sc.throughput_rps, sc.p50_us, sc.p95_us, sc.p99_us,
                sc.mean_batch_occupancy, sc.rejected
            );
            scenarios.push(sc);
        }
    }

    if let Some(spec) = arg_value(&args, "--mix") {
        let mix = parse_mix(&spec)?;
        for sc in run_mix(&mix, &opts)? {
            println!(
                "  {:<18} {:>7.1} req/s | p99 {:>8.0} us | steals {:>4} | bits/req {:.0}",
                sc.name, sc.throughput_rps, sc.p99_us, sc.steals, sc.bits_per_request
            );
            scenarios.push(sc);
        }
    }

    let report = ServeReport {
        bench: "serve".into(),
        quick,
        scenarios,
    };
    let json = serde_json::to_string_pretty(&report)?;
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    Ok(())
}

fn run_scenario(
    exec: Exec,
    mode: Mode,
    opts: &Opts,
    model: &Model,
    ds: &Dataset,
) -> anyhow::Result<ServeScenario> {
    let policy = BatchPolicy {
        max_wait: opts.wait,
        workers: opts.workers,
        queue_cap: opts.queue_cap,
        ..BatchPolicy::default()
    };
    let server = match exec {
        Exec::Mock => {
            let (batch, in_elems) = (opts.batch, ds.image_elems());
            InferenceServer::start_pool(
                move |_| {
                    Ok(MockExec {
                        batch,
                        in_elems,
                        out_elems: 10,
                        delay: Duration::from_micros(300),
                    })
                },
                policy,
            )?
        }
        Exec::Pac => {
            let e = PacExecutor::new(model.clone(), PacConfig::serving(), opts.batch)?;
            InferenceServer::start_pool(move |_| Ok(e.clone()), policy)?
        }
        Exec::Exact => {
            let e = PacExecutor::exact(model.clone(), opts.batch)?;
            InferenceServer::start_pool(move |_| Ok(e.clone()), policy)?
        }
    };

    let input = |i: usize| -> Vec<f32> {
        let idx = i % ds.n;
        ds.image(idx).iter().map(|&q| ds.params.dequantize(q)).collect()
    };

    let completed = AtomicU64::new(0);
    let mut sample_cost: Option<CostEstimate> = None;
    let t0 = Instant::now();
    match mode {
        Mode::Closed => {
            let h = server.handle();
            let next = AtomicUsize::new(0);
            std::thread::scope(|s| {
                let mut joins = Vec::new();
                for _ in 0..opts.clients {
                    let h = h.clone();
                    let completed = &completed;
                    let next = &next;
                    let input = &input;
                    joins.push(s.spawn(move || {
                        let mut cost = None;
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= opts.requests {
                                break cost;
                            }
                            if let Ok(r) = h.infer(input(i)) {
                                completed.fetch_add(1, Ordering::Relaxed);
                                cost = cost.or(r.cost);
                            }
                        }
                    }));
                }
                for j in joins {
                    sample_cost = sample_cost.or(j.join().unwrap());
                }
            });
        }
        Mode::Open => {
            let h = server.handle();
            let mut rng = Rng::new(opts.seed ^ 0x0DE1);
            let mut pending = Vec::with_capacity(opts.requests);
            let mut next_at = Instant::now();
            for i in 0..opts.requests {
                // Exponential inter-arrival → Poisson process at `rps`.
                let dt = -(1.0 - rng.next_f64()).ln() / opts.rps;
                next_at += Duration::from_secs_f64(dt);
                if let Some(wait) = next_at.checked_duration_since(Instant::now()) {
                    std::thread::sleep(wait);
                }
                match h.submit(input(i)) {
                    Ok(p) => pending.push(p),
                    Err(ServeError::QueueFull { .. }) => {} // counted server-side
                    Err(e) => return Err(e.into()),
                }
            }
            for p in pending {
                if let Ok(r) = p.wait() {
                    completed.fetch_add(1, Ordering::Relaxed);
                    sample_cost = sample_cost.or(r.cost);
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.stop();
    let completed = completed.load(Ordering::Relaxed);
    Ok(ServeScenario {
        name: format!("{}-{}", exec.name(), mode.name()),
        executor: exec.name().into(),
        model: model.name.clone(),
        mode: mode.name().into(),
        workers: opts.workers,
        batch_size: opts.batch,
        queue_cap: opts.queue_cap,
        shards: m.per_shard.len().max(1) as u64,
        steals: m.steals,
        offered_rps: if mode == Mode::Open { opts.rps } else { 0.0 },
        requests: opts.requests as u64,
        completed,
        rejected: m.rejected,
        failed_batches: m.failed_batches,
        wall_s: wall,
        throughput_rps: if wall > 0.0 { completed as f64 / wall } else { 0.0 },
        p50_us: m.latency_percentile_us(50.0),
        p95_us: m.latency_percentile_us(95.0),
        p99_us: m.latency_percentile_us(99.0),
        mean_batch_occupancy: m.mean_batch_occupancy(),
        batch_fill: m.batch_fill.clone(),
        modeled_cycles_per_image: sample_cost.map_or(0, |c| c.cycles),
        modeled_energy_uj_per_image: sample_cost.map_or(0.0, |c| c.total_uj()),
        // Measured dataplane traffic, aggregated from every worker's
        // executor telemetry at pool drain — 0 for the mock executor,
        // which has no ledger.
        measured_traffic_bits: m.traffic_bits,
        traffic_baseline_bits: m.traffic_baseline_bits,
        bits_per_request: if completed > 0 {
            m.traffic_bits as f64 / completed as f64
        } else {
            0.0
        },
        escalated: m.escalated,
    })
}

/// One multi-model open-loop run: Poisson arrivals at the total `--rps`
/// draw a tenant by weight and fan into a single
/// [`pacim::coordinator::MultiModelHandle`]; one `"mix-<model>-open"`
/// row per tenant comes back out.
fn run_mix(mix: &[(String, f64)], opts: &Opts) -> anyhow::Result<Vec<ServeScenario>> {
    let total_w: f64 = mix.iter().map(|(_, w)| w).sum();
    let policy = BatchPolicy {
        max_wait: opts.wait,
        workers: opts.workers,
        queue_cap: opts.queue_cap,
        ..BatchPolicy::default()
    };

    // Per-tenant workload + PAC engine, registered behind one front door.
    let mut registry = ModelRegistry::new();
    let mut datasets = Vec::with_capacity(mix.len());
    for (i, (id, _)) in mix.iter().enumerate() {
        let (model, ds) =
            synthetic_tenant_workload(id, opts.seed.wrapping_add(i as u64), 8, 16, 10, 64)?;
        let engine = EngineBuilder::new(model)
            .pac(PacConfig::serving())
            .parallelism(Parallelism::off())
            .build()?;
        registry = registry
            .register(ModelSpec::new(id.clone(), engine).batch(opts.batch).policy(policy))?;
        datasets.push(ds);
    }
    let server = PacExecutor::serve_registry(registry)?;
    let h = server.handle();

    let input = |tenant: usize, i: usize| -> Vec<f32> {
        let ds = &datasets[tenant];
        let idx = i % ds.n;
        ds.image(idx).iter().map(|&q| ds.params.dequantize(q)).collect()
    };

    let mut rng = Rng::new(opts.seed ^ 0x3316);
    let mut arrivals = vec![0u64; mix.len()];
    let mut completed = vec![0u64; mix.len()];
    let mut sample_cost: Vec<Option<CostEstimate>> = vec![None; mix.len()];
    let mut pending: Vec<(usize, pacim::coordinator::PendingReply)> =
        Vec::with_capacity(opts.requests);
    let mut next_at = Instant::now();
    let t0 = Instant::now();
    for i in 0..opts.requests {
        let dt = -(1.0 - rng.next_f64()).ln() / opts.rps;
        next_at += Duration::from_secs_f64(dt);
        if let Some(wait) = next_at.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        // Draw the tenant by cumulative weight.
        let draw = rng.next_f64() * total_w;
        let mut tenant = mix.len() - 1;
        let mut acc = 0.0;
        for (t, (_, w)) in mix.iter().enumerate() {
            acc += w;
            if draw < acc {
                tenant = t;
                break;
            }
        }
        arrivals[tenant] += 1;
        match h.submit(&mix[tenant].0, input(tenant, i)) {
            Ok(p) => pending.push((tenant, p)),
            Err(ServeError::QueueFull { .. }) => {} // counted server-side
            Err(e) => return Err(e.into()),
        }
    }
    for (tenant, p) in pending {
        if let Ok(r) = p.wait() {
            completed[tenant] += 1;
            sample_cost[tenant] = sample_cost[tenant].or(r.cost);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut rows = Vec::with_capacity(mix.len());
    for (tenant, (id, metrics)) in server.stop().into_iter().enumerate() {
        let (_, w) = &mix[tenant];
        let done = completed[tenant];
        rows.push(ServeScenario {
            name: format!("mix-{id}-open"),
            executor: "pac".into(),
            model: id,
            mode: "open".into(),
            workers: opts.workers,
            batch_size: opts.batch,
            queue_cap: opts.queue_cap,
            shards: metrics.per_shard.len().max(1) as u64,
            steals: metrics.steals,
            offered_rps: opts.rps * w / total_w,
            requests: arrivals[tenant],
            completed: done,
            rejected: metrics.rejected,
            failed_batches: metrics.failed_batches,
            wall_s: wall,
            throughput_rps: if wall > 0.0 { done as f64 / wall } else { 0.0 },
            p50_us: metrics.latency_percentile_us(50.0),
            p95_us: metrics.latency_percentile_us(95.0),
            p99_us: metrics.latency_percentile_us(99.0),
            mean_batch_occupancy: metrics.mean_batch_occupancy(),
            batch_fill: metrics.batch_fill.clone(),
            modeled_cycles_per_image: sample_cost[tenant].map_or(0, |c| c.cycles),
            modeled_energy_uj_per_image: sample_cost[tenant].map_or(0.0, |c| c.total_uj()),
            measured_traffic_bits: metrics.traffic_bits,
            traffic_baseline_bits: metrics.traffic_baseline_bits,
            bits_per_request: if done > 0 {
                metrics.traffic_bits as f64 / done as f64
            } else {
                0.0
            },
            escalated: metrics.escalated,
        });
    }
    Ok(rows)
}
