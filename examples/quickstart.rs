//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the AOT artifacts (trained quantized tiny_resnet).
//! 2. Classify a few images with the bit-true rust engine — once exactly,
//!    once through the PAC hybrid backend.
//! 3. Print the architecture-level cycle/energy/traffic estimate for the
//!    same inference.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use pacim::coordinator::{schedule_model, ScheduleConfig};
use pacim::energy::EnergyModel;
use pacim::nn::{exact_backend, pac_backend, run_model, tiny_resnet, PacConfig, WeightStore};
use pacim::runtime::Manifest;
use pacim::workload::shapes::LayerShape;
use pacim::workload::Dataset;

fn main() -> anyhow::Result<()> {
    // ---- artifacts --------------------------------------------------------
    let man = Manifest::load(pacim::runtime::manifest::artifacts_dir())?;
    let store = WeightStore::load(man.path("weights")?)?;
    let ds = Dataset::load(man.path("dataset")?)?;
    let model = tiny_resnet(&store, ds.h, ds.n_classes)?;
    println!("model {} | {} MACs/image | {} test images", model.name, model.macs(), ds.n);

    // ---- bit-true inference: exact vs PAC ---------------------------------
    let exact = exact_backend(&model);
    let pac = pac_backend(&model, PacConfig::default());
    let mut agree = 0;
    let n = 8;
    for i in 0..n {
        let (le, _) = run_model(&model, &exact, ds.image(i));
        let (lp, stats) = run_model(&model, &pac, ds.image(i));
        let pe = argmax(&le);
        let pp = argmax(&lp);
        agree += (pe == pp) as usize;
        println!(
            "image {i}: label {} | exact -> {pe} | PAC -> {pp} | digital cycles/MAC {:.1}",
            ds.label(i),
            stats.avg_cycles_per_mac()
        );
    }
    println!("exact/PAC argmax agreement: {agree}/{n}");

    // ---- architecture estimate for this model -----------------------------
    let shapes: Vec<LayerShape> = model
        .compute_layers()
        .iter()
        .map(|(name, g)| LayerShape {
            name: name.to_string(),
            kind: pacim::workload::LayerShapeKind::Conv,
            geom: *g,
        })
        .collect();
    let em = EnergyModel::default();
    let dig = schedule_model(&shapes, &ScheduleConfig::digital_baseline());
    let pacs = schedule_model(&shapes, &ScheduleConfig::pacim_default());
    println!("\narchitecture estimate (per image):");
    for (label, rep, is_pac) in [("digital 8b/8b", &dig, false), ("PACiM 4-bit", &pacs, true)] {
        println!(
            "  {label:<14} {:>12} bit-serial cycles | compute {:>8.2} uJ | memory {:>8.2} uJ",
            rep.total_macs_cycles(),
            rep.compute_energy_pj(&em) / 1e6,
            rep.memory_energy_pj(&em, is_pac) / 1e6,
        );
    }
    println!(
        "  -> cycle reduction {:.0}% | activation-traffic reduction {:.0}%",
        100.0 * (1.0 - pacs.total_macs_cycles() as f64 / dig.total_macs_cycles() as f64),
        pacs.act_traffic_reduction() * 100.0
    );
    Ok(())
}

fn argmax(v: &[f32]) -> usize {
    v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
}
