//! Quickstart: the typed engine front door in one page.
//!
//! 1. Load the trained artifacts when they exist, or fall back to the
//!    deterministic synthetic workload (so this runs on a bare checkout
//!    — CI exercises exactly that path).
//! 2. Build two engines through `pacim::engine` — the exact 8b/8b
//!    reference and the PAC hybrid backend — and classify a few images.
//! 3. Print the modeled per-image silicon cost that every engine
//!    carries (cycles + energy under the matching bank schedule).
//!
//! Run: `cargo run --release --example quickstart`
//! (ends with a `quickstart: OK …` sentinel line; CI greps for it).

use pacim::engine::EngineBuilder;
use pacim::nn::{tiny_resnet, PacConfig, WeightStore};
use pacim::runtime::Manifest;
use pacim::workload::Dataset;

/// Artifacts when built, synthetic workload otherwise.
fn workload() -> anyhow::Result<(pacim::nn::Model, Dataset, &'static str)> {
    let load = || -> anyhow::Result<(pacim::nn::Model, Dataset)> {
        let man = Manifest::load(pacim::runtime::manifest::artifacts_dir())?;
        let store = WeightStore::load(man.path("weights")?)?;
        let ds = Dataset::load(man.path("dataset")?)?;
        let model = tiny_resnet(&store, ds.h, ds.n_classes)?;
        Ok((model, ds))
    };
    match load() {
        Ok((model, ds)) => Ok((model, ds, "artifacts")),
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); using the synthetic workload");
            let (model, ds) = pacim::workload::synthetic_serving_workload(2024, 8, 16, 10, 64)?;
            Ok((model, ds, "synthetic"))
        }
    }
}

fn main() -> anyhow::Result<()> {
    let (model, ds, source) = workload()?;
    println!(
        "model {} ({source}) | {} MACs/image | {} test images",
        model.name,
        model.macs(),
        ds.n
    );

    // ---- one front door, two backends -------------------------------------
    let exact = EngineBuilder::new(model.clone()).exact().build()?;
    let pac = EngineBuilder::new(model).pac(PacConfig::default()).build()?;
    let mut exact_session = exact.session();
    let mut pac_session = pac.session();

    let n = 8.min(ds.n);
    let mut agree = 0;
    for i in 0..n {
        let e = exact_session.infer(ds.image(i))?;
        let p = pac_session.infer(ds.image(i))?;
        agree += (e.argmax() == p.argmax()) as usize;
        println!(
            "image {i}: label {} | exact -> {} | PAC -> {} | digital cycles/MAC {:.1}",
            ds.label(i),
            e.argmax(),
            p.argmax(),
            p.stats.avg_cycles_per_mac()
        );
    }
    println!("exact/PAC argmax agreement: {agree}/{n}");

    // ---- the modeled silicon cost every engine carries ---------------------
    println!("\narchitecture estimate (per image):");
    for (label, engine) in [("digital 8b/8b", &exact), ("PACiM 4-bit", &pac)] {
        let c = engine.cost_estimate();
        println!(
            "  {label:<14} {:>12} bit-serial cycles | compute {:>8.2} uJ | memory {:>8.2} uJ",
            c.cycles,
            c.compute_pj / 1e6,
            c.memory_pj / 1e6,
        );
    }
    let (ce, cp) = (exact.cost_estimate(), pac.cost_estimate());
    println!(
        "  -> cycle reduction {:.0}%",
        100.0 * (1.0 - cp.cycles as f64 / ce.cycles as f64)
    );

    // Sentinel for the CI quickstart-smoke job: the zero-artifact engine
    // path produced real logits through both backends.
    println!("quickstart: OK ({source}, agreement {agree}/{n})");
    Ok(())
}
