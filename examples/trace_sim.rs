//! Workload trace simulation: run the paper's benchmark networks
//! (ResNet-18/50, VGG16-BN at CIFAR/ImageNet resolutions) through the
//! bank scheduler and print the per-layer + whole-model cycle, energy,
//! and traffic report — the data behind Fig. 7 and Table 4 at full scale.
//!
//! Run: `cargo run --release --example trace_sim -- [model] [res]`

use pacim::coordinator::{schedule_model, ScheduleConfig};
use pacim::energy::EnergyModel;
use pacim::workload::{resnet18, resnet50, vgg16_bn, Resolution};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let res = match std::env::args().nth(2).as_deref() {
        Some("imagenet") => Resolution::ImageNet,
        _ => Resolution::Cifar,
    };
    let classes = if res == Resolution::ImageNet { 1000 } else { 10 };
    let shapes = match model.as_str() {
        "resnet18" => resnet18(res, classes),
        "resnet50" => resnet50(res, classes),
        "vgg16" => vgg16_bn(res, classes),
        other => anyhow::bail!("unknown model '{other}' (resnet18|resnet50|vgg16)"),
    };
    let em = EnergyModel::default();
    let cfg = ScheduleConfig::pacim_default();
    let rep = schedule_model(&shapes, &cfg);

    println!("{model} @ {res:?} — PACiM single-bank schedule (4-bit static map)\n");
    println!("{:<22} {:>6} {:>9} {:>14} {:>10} {:>9}",
             "layer", "tiles", "wloads", "cycles", "act red.", "w red.");
    for l in &rep.layers {
        println!(
            "{:<22} {:>2}x{:<3} {:>9} {:>14} {:>9.1}% {:>8.1}%",
            l.name,
            l.row_tiles,
            l.oc_tiles,
            l.weight_loads,
            l.bit_serial_cycles,
            l.act_reduction() * 100.0,
            (1.0 - l.weight_bits_pacim as f64 / l.weight_bits_baseline as f64) * 100.0,
        );
    }

    let dig = schedule_model(&shapes, &ScheduleConfig::digital_baseline());
    let dyn_ = schedule_model(&shapes, &ScheduleConfig::pacim_dynamic());
    println!("\nwhole model:");
    for (label, r, pac) in [
        ("digital 8b/8b", &dig, false),
        ("PACiM static", &rep, true),
        ("PACiM dynamic", &dyn_, true),
    ] {
        let e = (r.compute_energy_pj(&em) + r.memory_energy_pj(&em, pac)) / 1e6;
        println!(
            "  {label:<14} cycles {:>14}  energy {:>10.1} uJ  act-traffic red. {:>5.1}%",
            r.total_macs_cycles(),
            e,
            r.act_traffic_reduction() * 100.0
        );
    }
    println!(
        "\ncycle reduction: static {:.1}% | dynamic {:.1}% (paper: 75% / 81%)",
        100.0 * (1.0 - rep.total_macs_cycles() as f64 / dig.total_macs_cycles() as f64),
        100.0 * (1.0 - dyn_.total_macs_cycles() as f64 / dig.total_macs_cycles() as f64),
    );
    Ok(())
}
