//! Design-space exploration: the accuracy/energy Pareto of the PACiM
//! configuration space (operand width x dynamic thresholds) — the
//! DESIGN.md §11 ablation harness.
//!
//! Run: `cargo run --release --example design_space -- [images]`

use pacim::arch::ThresholdSet;
use pacim::energy::EnergyModel;
use pacim::engine::{Engine, EngineBuilder};
use pacim::nn::{tiny_resnet, PacConfig, WeightStore};
use pacim::pac::{ComputeMap, PcuRounding};
use pacim::runtime::Manifest;
use pacim::workload::Dataset;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let man = Manifest::load(pacim::runtime::manifest::artifacts_dir())?;
    let store = WeightStore::load(man.path("weights")?)?;
    let ds = Dataset::load(man.path("dataset")?)?;
    let model = tiny_resnet(&store, ds.h, ds.n_classes)?;
    let n = n.min(ds.n);
    let images: Vec<&[u8]> = (0..n).map(|i| ds.image(i)).collect();
    let labels: Vec<usize> = (0..n).map(|i| ds.label(i)).collect();
    let threads = std::thread::available_parallelism()?.get();
    let em = EnergyModel::default();

    let exact: Engine = EngineBuilder::new(model.clone()).exact().build()?;
    let acc8 = exact.evaluate(&images, &labels, threads)?.accuracy;
    println!("exact 8b/8b: {:.2}% | digital eff {:.2} TOPS/W (8b/8b)\n",
             acc8 * 100.0, em.digital_8b().tops_w_8b);
    println!(
        "{:<34} {:>8} {:>10} {:>12} {:>12}",
        "configuration", "acc %", "loss %", "avg cycles", "TOPS/W 8b"
    );

    let mut frontier: Vec<(f64, f64)> = Vec::new(); // (eff, acc)
    for bits in [3u32, 4, 5] {
        for (th, tag) in [
            (None, "static"),
            (Some(ThresholdSet::new(0.06, 0.12, 0.25)), "dyn-moderate"),
            (Some(ThresholdSet::new(0.10, 0.20, 0.35)), "dyn-aggressive"),
        ] {
            // Dynamic levels are defined for the 4x4 base; skip others.
            if th.is_some() && bits != 4 {
                continue;
            }
            let cfg = PacConfig {
                map: ComputeMap::operand_based(bits, bits),
                thresholds: th,
                rounding: PcuRounding::RoundNearest,
                ..PacConfig::default()
            };
            let pac = EngineBuilder::new(model.clone()).pac(cfg).build()?;
            let ev = pac.evaluate(&images, &labels, threads)?;
            let (acc, stats) = (ev.accuracy, ev.stats);
            let cycles = if stats.levels.total() > 0 {
                stats.levels.average_cycles()
            } else {
                (bits * bits) as f64
            };
            let eff = em.hybrid_efficiency(cycles, 64.0 - cycles).tops_w_8b;
            println!(
                "{:<34} {:>8.2} {:>10.2} {:>12.2} {:>12.2}",
                format!("PAC {bits}x{bits} {tag}"),
                acc * 100.0,
                (acc - acc8) * 100.0,
                cycles,
                eff
            );
            frontier.push((eff, acc));
        }
    }

    // PCU rounding ablation (DESIGN.md §11).
    println!("\nPCU rounding ablation (4x4 static):");
    for (r, name) in [(PcuRounding::RoundNearest, "round-nearest"), (PcuRounding::Floor, "floor")] {
        let cfg = PacConfig { rounding: r, ..PacConfig::default() };
        let pac = EngineBuilder::new(model.clone()).pac(cfg).build()?;
        let acc = pac.evaluate(&images, &labels, threads)?.accuracy;
        println!("  {name:<16} acc {:.2}%", acc * 100.0);
    }

    // Report the Pareto frontier.
    frontier.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    println!("\nPareto frontier (efficiency-ordered):");
    let mut best_acc = 0.0;
    for (eff, acc) in frontier {
        if acc > best_acc {
            println!("  {eff:8.2} TOPS/W -> {:.2}%", acc * 100.0);
            best_acc = acc;
        }
    }
    Ok(())
}
