//! End-to-end serving driver (the E2E validation of DESIGN.md §8).
//!
//! Loads the AOT-compiled PAC model through PJRT, starts the threaded
//! batch-serving coordinator (sharded work-stealing ingress underneath,
//! DESIGN.md §16), fires concurrent single-image requests from client
//! threads, and reports latency percentiles, throughput, accuracy on the
//! synthetic test split, and the per-request architecture-level energy
//! estimate.
//!
//! Run: `cargo run --release --example serve -- [requests] [clients]`
//!
//! This driver hosts a single PJRT model. For the multi-model tenancy
//! path (N engines behind one routing front door, per-model pools and
//! SLO metrics) use the zero-artifact CLI instead:
//! `pacim serve --models resnet18,tinyvgg`, or drive a traffic mix with
//! `cargo run --release --example loadgen -- --mix "resnet18=0.8,tinyvgg=0.2"`.

use pacim::coordinator::{
    estimate_image_cost, model_shapes, BatchPolicy, InferenceServer, ScheduleConfig,
};
use pacim::energy::EnergyModel;
use pacim::nn::{tiny_resnet, WeightStore};
use pacim::runtime::{Manifest, PjrtExecutor};
use pacim::workload::Dataset;
use std::sync::atomic::{AtomicUsize, Ordering};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(256);
    let clients: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);

    let man = Manifest::load(pacim::runtime::manifest::artifacts_dir())?;
    let ds = Dataset::load(man.path("dataset")?)?;
    let store = WeightStore::load(man.path("weights")?)?;
    let model = tiny_resnet(&store, ds.h, ds.n_classes)?;
    let (batch, in_elems, classes) = (man.batch()?, man.input_elems()?, man.classes()?);
    let requests = requests.min(ds.n);

    println!(
        "serving {} ({} classes) | batch {batch} | {clients} clients | {requests} requests",
        man.get("model")?,
        classes
    );

    let hlo = man.path("model_pac")?;
    let server = InferenceServer::start_with(
        move || PjrtExecutor::load(&hlo, batch, in_elems, classes),
        BatchPolicy {
            max_wait: std::time::Duration::from_millis(2),
            ..BatchPolicy::default()
        },
    )?;
    let handle = server.handle();

    let correct = AtomicUsize::new(0);
    let next = AtomicUsize::new(0);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            let h = handle.clone();
            let correct = &correct;
            let next = &next;
            let ds = &ds;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= requests {
                    break;
                }
                let img: Vec<f32> = ds.image(i).iter().map(|&q| ds.params.dequantize(q)).collect();
                let reply = h.infer(img).expect("infer");
                let pred = reply
                    .logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == ds.label(i) {
                    correct.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let m = server.stop();

    println!("\nresults:");
    println!(
        "  throughput : {:.1} img/s ({} requests in {:.1} ms)",
        requests as f64 / wall,
        requests,
        wall * 1e3
    );
    println!(
        "  latency    : p50 {:.0} us | p95 {:.0} us | p99 {:.0} us",
        m.latency_percentile_us(50.0),
        m.latency_percentile_us(95.0),
        m.latency_percentile_us(99.0)
    );
    println!("  batching   : {} batches, mean occupancy {:.1}, {} padded slots",
             m.batches, m.mean_batch_occupancy(), m.padded_slots);
    println!("  accuracy   : {:.2}% (PAC 4-bit model)",
             correct.load(Ordering::Relaxed) as f64 / requests as f64 * 100.0);

    // Architecture-level energy per request (what the silicon would burn).
    let shapes = model_shapes(&model);
    let em = EnergyModel::default();
    let pac = estimate_image_cost(&shapes, &ScheduleConfig::pacim_default(), &em);
    let dig = estimate_image_cost(&shapes, &ScheduleConfig::digital_baseline(), &em);
    println!("  arch energy: {:.2} uJ/image (65nm PACiM estimate; digital would be {:.2} uJ)",
             pac.total_uj(),
             dig.total_uj());
    Ok(())
}
