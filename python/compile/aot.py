"""AOT export: train -> quantize -> emit artifacts.

Run as ``python -m compile.aot --out-dir ../artifacts`` (wired into
``make artifacts``). Python's ONLY runtime role ends here; the rust
binary consumes the artifacts.

Interchange format is HLO TEXT, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
    dataset.bin        quantized synthetic test split (+ params)
    weights.bin        quantized model sidecar for the rust simulator
    model_pac.hlo.txt  PAC hybrid forward (Pallas kernels), batch B
    model_exact.hlo.txt exact bit-serial forward, batch B
    pac_matmul.hlo.txt standalone L1 kernel (runtime microbench)
    train_cache.npz    float training cache
    manifest.txt       key/value index of all of the above
"""

from __future__ import annotations

import argparse
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .datagen import INPUT_PARAMS, generate, write_dataset_bin
from .kernels.pac_matmul import pac_matmul
from .model import ADD_NAMES, CONV_NAMES, quantized_forward, quantize_model
from .quant_utils import QuantParams
from .train import train_cached


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the default printer elides big weight
    # literals as "{...}", which xla_extension 0.5.1's text parser accepts
    # silently and turns into GARBAGE values. Hard requirement.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "elided constant survived"
    return text


# ---------------------------------------------------------------------------
# weights.bin writer (format: rust/src/nn/weights.rs)
# ---------------------------------------------------------------------------

_DTYPE_TAGS = {np.uint8: 0, np.int32: 1, np.float32: 2}


def _write_entry(f, name: str, arr: np.ndarray, scale=1.0, zp=0):
    tag = _DTYPE_TAGS[arr.dtype.type]
    f.write(struct.pack("<H", len(name)))
    f.write(name.encode())
    f.write(struct.pack("<BB", tag, arr.ndim))
    for d in arr.shape:
        f.write(struct.pack("<I", d))
    f.write(struct.pack("<f", scale))
    f.write(struct.pack("<i", zp))
    f.write(arr.tobytes())


def write_weights_bin(path: str, q) -> None:
    entries = []
    qp = lambda p: np.asarray([p.scale, float(p.zero_point)], np.float32)
    entries.append(("input.oq", qp(q["input.oq"]), 1.0, 0))
    for name in CONV_NAMES:
        layer = q[name]
        entries.append((f"{name}.w", layer["wq"].astype(np.uint8),
                        layer["wp"].scale, layer["wp"].zero_point))
        entries.append((f"{name}.b", layer["b"].astype(np.float32), 1.0, 0))
        entries.append((f"{name}.oq", qp(layer["oq"]), 1.0, 0))
    for name in ADD_NAMES:
        entries.append((f"{name}.oq", qp(q[f"{name}.oq"]), 1.0, 0))
    entries.append(("fc.w", q["fc"]["wq"].astype(np.uint8),
                    q["fc"]["wp"].scale, q["fc"]["wp"].zero_point))
    entries.append(("fc.b", q["fc"]["b"].astype(np.float32), 1.0, 0))
    with open(path, "wb") as f:
        f.write(b"PACW")
        f.write(struct.pack("<I", 1))
        f.write(struct.pack("<I", len(entries)))
        for name, arr, scale, zp in entries:
            _write_entry(f, name, np.ascontiguousarray(arr), scale, zp)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--hw", type=int, default=32)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--n-test", type=int, default=1024)
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the jnp reference instead of the Pallas kernels")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    print("[aot] 1/5 training (cached) ...")
    params, losses, train_acc = train_cached(
        os.path.join(out, "train_cache.npz"),
        c=args.width, classes=args.classes, hw=args.hw, steps=args.steps)

    print("[aot] 2/5 dataset ...")
    # Test split uses a different seed than training (seed+1).
    x_test, y_test = generate(args.n_test, hw=args.hw,
                              n_classes=args.classes, seed=8)
    xq_test = INPUT_PARAMS.quantize(x_test)
    write_dataset_bin(os.path.join(out, "dataset.bin"),
                      xq_test, y_test, args.classes)

    print("[aot] 3/5 PTQ calibration ...")
    q = quantize_model(params, x_test[:256], INPUT_PARAMS)
    write_weights_bin(os.path.join(out, "weights.bin"), q)

    print("[aot] 4/5 lowering to HLO text ...")
    in_elems = 3 * args.hw * args.hw
    spec = jax.ShapeDtypeStruct((args.batch, in_elems), jnp.float32)
    use_pallas = not args.no_pallas

    def fwd_pac(x):
        return (quantized_forward(q, x, hw=args.hw, classes=args.classes,
                                  mode="pac", use_pallas=use_pallas),)

    def fwd_exact(x):
        return (quantized_forward(q, x, hw=args.hw, classes=args.classes,
                                  mode="exact", use_pallas=use_pallas),)

    for fname, fn in (("model_pac.hlo.txt", fwd_pac),
                      ("model_exact.hlo.txt", fwd_exact)):
        text = to_hlo_text(jax.jit(fn).lower(spec))
        with open(os.path.join(out, fname), "w") as f:
            f.write(text)
        print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB)")

    # Standalone kernel artifact for the runtime microbench.
    kspec_x = jax.ShapeDtypeStruct((128, 576), jnp.int32)
    kspec_w = jax.ShapeDtypeStruct((576, 64), jnp.int32)

    def kern(x, w):
        return (pac_matmul(x, w, zpx=7, zpw=128),)

    text = to_hlo_text(jax.jit(kern).lower(kspec_x, kspec_w))
    with open(os.path.join(out, "pac_matmul.hlo.txt"), "w") as f:
        f.write(text)

    print("[aot] 5/5 manifest ...")
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("# generated by python -m compile.aot\n")
        f.write(f"model       tiny_resnet_c{args.width}\n")
        f.write(f"batch       {args.batch}\n")
        f.write(f"in_c        3\n")
        f.write(f"in_hw       {args.hw}\n")
        f.write(f"classes     {args.classes}\n")
        f.write(f"train_acc   {train_acc:.4f}\n")
        f.write(f"model_pac   model_pac.hlo.txt\n")
        f.write(f"model_exact model_exact.hlo.txt\n")
        f.write(f"pac_kernel  pac_matmul.hlo.txt\n")
        f.write(f"weights     weights.bin\n")
        f.write(f"dataset     dataset.bin\n")
    print("[aot] done.")


if __name__ == "__main__":
    main()
