"""uint8 affine quantization — the numerical contract shared with rust.

Mirrors ``rust/src/quant/mod.rs`` bit-for-bit:

    q = clamp(round(x / scale) + zero_point, 0, 255)
    x = scale * (q - zero_point)

Weights use symmetric "shifted-uint8" (zero point pinned to 128) so every
weight bit-plane is well-defined for the CiM mapping; activations use
asymmetric min-max calibration widened to include 0.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class QuantParams:
    scale: float
    zero_point: int

    def __post_init__(self):
        assert self.scale > 0, "scale must be positive"
        assert 0 <= self.zero_point <= 255, "uint8 zero point"

    def quantize(self, x: np.ndarray) -> np.ndarray:
        q = np.round(np.asarray(x, np.float64) / self.scale) + self.zero_point
        return np.clip(q, 0, 255).astype(np.uint8)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return (np.asarray(q, np.float32) - self.zero_point) * np.float32(self.scale)


def calibrate_minmax(lo: float, hi: float) -> QuantParams:
    """Min-max calibration, widened to include zero (rust: calibrate_minmax)."""
    lo = min(lo, 0.0)
    hi = max(hi, 0.0)
    span = max(hi - lo, 1e-8)
    scale = span / 255.0
    zp = int(np.clip(round(-lo / scale), 0, 255))
    return QuantParams(scale, zp)


def calibrate_tensor(x: np.ndarray) -> QuantParams:
    return calibrate_minmax(float(np.min(x)), float(np.max(x)))


def calibrate_weights_symmetric(w: np.ndarray) -> QuantParams:
    """Symmetric shifted-uint8 (zp = 128), rust: calibrate_weights_symmetric."""
    max_abs = max(float(np.max(np.abs(w))), 1e-8)
    return QuantParams(max_abs / 127.0, 128)
