"""L1 Pallas kernel: the hybrid PAC GEMM (Eq. 4).

One kernel invocation computes a (block_m, N) tile of the output. The K
(dot-product) dimension lives entirely in one VMEM block, mirroring how a
PACiM CiM column holds the whole DP vector; the M dimension is the grid.

TPU hardware adaptation (DESIGN.md `Hardware-Adaptation`):
- the D-CiM "NOR array + 256-input adder tree" becomes 16 bit-plane
  matmuls feeding the MXU (int8-weight-friendly contraction);
- the PCU sparsity path is a VPU reduction (popcount-as-sum over K)
  followed by an outer product of sparsity vectors — negligible FLOPs;
- BlockSpec tiles (block_m, K) x (K, N): VMEM footprint =
  4*(block_m*K + K*N + block_m*N) bytes (int32), kept under the ~16 MB
  VMEM budget by choosing block_m (see python/tests/test_kernels.py
  ::test_vmem_budget).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against kernels/ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import digital_pairs

DEFAULT_BLOCK_M = 128


def _pac_kernel(x_ref, w_ref, o_ref, *, k: int, zpx: int, zpw: int,
                bx: int, bw: int):
    """Kernel body: x (bm, K) int32, w (K, N) int32 -> o (bm, N) int32."""
    x = x_ref[...]
    w = w_ref[...]
    dig = set(digital_pairs(bx, bw))

    xb = [(x >> p) & 1 for p in range(8)]
    wb = [(w >> q) & 1 for q in range(8)]
    # Bit-level sparsity: the on-die encoder counts (VPU reduction).
    sx = [jnp.sum(b, axis=1) for b in xb]   # (bm,)
    sw = [jnp.sum(b, axis=0) for b in wb]   # (N,)

    raw = jnp.zeros(o_ref.shape, jnp.int32)
    for p in range(8):
        for q in range(8):
            if (p, q) in dig:
                # Digital domain: exact plane contraction (MXU).
                dp = jnp.dot(xb[p], wb[q], preferred_element_type=jnp.int32)
            else:
                # Sparsity domain: PCU point estimate Sx*Sw/n,
                # round-nearest fixed point (Eq. 3).
                prod = sx[p][:, None] * sw[q][None, :]
                dp = (prod + k // 2) // k
            raw = raw + (dp << (p + q))

    # Zero-point correction; sum_x is reconstructed from the sparsity
    # counts (sum_p 2^p Sx[p]) exactly as the architecture does - the
    # LSB activation bits are never read as binary data.
    sum_x = jnp.zeros((x.shape[0],), jnp.int32)
    for p in range(8):
        sum_x = sum_x + (sx[p] << p)
    sum_w = jnp.sum(w, axis=0)
    o_ref[...] = (raw
                  - zpw * sum_x[:, None]
                  - zpx * sum_w[None, :]
                  + k * zpx * zpw)


@functools.partial(jax.jit, static_argnames=("zpx", "zpw", "bx", "bw", "block_m"))
def pac_matmul(xq, wq, *, zpx: int, zpw: int, bx: int = 4, bw: int = 4,
               block_m: int = DEFAULT_BLOCK_M):
    """Hybrid PAC GEMM: xq (M, K) x wq (K, N) uint8-valued int32 tensors.

    Returns int32 (M, N) zero-point-corrected accumulators, matching
    ref.pac_matmul_ref exactly.
    """
    x = jnp.asarray(xq, jnp.int32)
    w = jnp.asarray(wq, jnp.int32)
    m, k = x.shape
    n = w.shape[1]
    bm = min(block_m, m)
    m_pad = ((m + bm - 1) // bm) * bm
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    kern = functools.partial(_pac_kernel, k=k, zpx=zpx, zpw=zpw, bx=bx, bw=bw)
    out = pl.pallas_call(
        kern,
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.int32),
        interpret=True,
    )(x, w)
    return out[:m]


def vmem_bytes(block_m: int, k: int, n: int) -> int:
    """Static VMEM footprint estimate of one kernel instance (int32)."""
    return 4 * (block_m * k + k * n + block_m * n)
