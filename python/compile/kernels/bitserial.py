"""L1 Pallas kernel: exact bit-serial GEMM (the pure D-CiM baseline).

All 64 binary (p,q) cycles run exactly (Eq. 1) - this is the kernel the
digital-baseline model variant uses, and the reference point for the
kernel-level ablation of approximate operand width.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128


def _bitserial_kernel(x_ref, w_ref, o_ref, *, k: int, zpx: int, zpw: int):
    x = x_ref[...]
    w = w_ref[...]
    raw = jnp.zeros(o_ref.shape, jnp.int32)
    for p in range(8):
        xb = (x >> p) & 1
        for q in range(8):
            wb = (w >> q) & 1
            dp = jnp.dot(xb, wb, preferred_element_type=jnp.int32)
            raw = raw + (dp << (p + q))
    sum_x = jnp.sum(x, axis=1, keepdims=True)
    sum_w = jnp.sum(w, axis=0, keepdims=True)
    o_ref[...] = raw - zpw * sum_x - zpx * sum_w + k * zpx * zpw


@functools.partial(jax.jit, static_argnames=("zpx", "zpw", "block_m"))
def bitserial_matmul(xq, wq, *, zpx: int, zpw: int,
                     block_m: int = DEFAULT_BLOCK_M):
    """Exact bit-serial GEMM; equals the plain int32 GEMM (tested)."""
    x = jnp.asarray(xq, jnp.int32)
    w = jnp.asarray(wq, jnp.int32)
    m, k = x.shape
    n = w.shape[1]
    bm = min(block_m, m)
    m_pad = ((m + bm - 1) // bm) * bm
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    kern = functools.partial(_bitserial_kernel, k=k, zpx=zpx, zpw=zpw)
    out = pl.pallas_call(
        kern,
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.int32),
        interpret=True,
    )(x, w)
    return out[:m]
