"""Pure-jnp oracles for the L1 kernels.

These are the ground truth the Pallas kernels are tested against
(`python/tests/test_kernels.py`, hypothesis sweeps) and the numerical
contract shared with the rust engine (`rust/src/pac/mac.rs` implements
the same equations; `rust/tests/integration_nn.rs` cross-checks through
the exported artifacts).

Everything operates on *quantized uint8 values carried as int32*.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# The paper's default operand split: activation/weight MSB bits kept
# digital (4x4 -> 16 exact cycles, 48 approximated).
DEFAULT_BITS = 4


def digital_pairs(bx: int = DEFAULT_BITS, bw: int = DEFAULT_BITS):
    """The digital set D = {(p,q) : p >= 8-bx, q >= 8-bw} (Eq. 4)."""
    return [(p, q) for p in range(8 - bx, 8) for q in range(8 - bw, 8)]


def sparsity_pairs(bx: int = DEFAULT_BITS, bw: int = DEFAULT_BITS):
    dig = set(digital_pairs(bx, bw))
    return [(p, q) for p in range(8) for q in range(8) if (p, q) not in dig]


def exact_matmul_ref(xq, wq, zpx: int, zpw: int):
    """Exact zero-point-corrected integer GEMM.

    xq: (M, K) uint8-valued, wq: (K, N) uint8-valued; returns int32 (M, N)
    accumulators sum_k (x-zpx)(w-zpw).
    """
    x = jnp.asarray(xq, jnp.int32) - zpx
    w = jnp.asarray(wq, jnp.int32) - zpw
    return x @ w


def _zero_point_correct(raw, x, w, k, zpx, zpw):
    sum_x = jnp.sum(x, axis=1, keepdims=True)  # (M, 1)
    sum_w = jnp.sum(w, axis=0, keepdims=True)  # (1, N)
    return raw - zpw * sum_x - zpx * sum_w + k * zpx * zpw


def bitserial_matmul_ref(xq, wq, zpx: int, zpw: int):
    """The same GEMM computed the D-CiM way: 64 binary (p,q) plane
    matmuls with shift-accumulate (Eq. 1), then zero-point correction.
    Must equal ``exact_matmul_ref`` exactly (tested)."""
    x = jnp.asarray(xq, jnp.int32)
    w = jnp.asarray(wq, jnp.int32)
    k = x.shape[1]
    raw = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
    for p in range(8):
        xb = (x >> p) & 1
        for q in range(8):
            wb = (w >> q) & 1
            raw = raw + ((xb @ wb) << (p + q))
    return _zero_point_correct(raw, x, w, k, zpx, zpw)


def pac_matmul_ref(xq, wq, zpx: int, zpw: int, bx: int = DEFAULT_BITS,
                   bw: int = DEFAULT_BITS):
    """The hybrid PAC GEMM (Eq. 4): digital MSB cycles exact, the rest
    estimated from bit-level sparsity with PCU round-nearest fixed point
    (rust: pac::hybrid_mac + zero_point_correct).

    int32 is sufficient: raw <= K*255*255 < 2^31 for K <= 33000.
    """
    x = jnp.asarray(xq, jnp.int32)
    w = jnp.asarray(wq, jnp.int32)
    m, k = x.shape
    n = w.shape[1]
    dig = set(digital_pairs(bx, bw))

    xb = [(x >> p) & 1 for p in range(8)]
    wb = [(w >> q) & 1 for q in range(8)]
    sx = [jnp.sum(b, axis=1) for b in xb]  # (M,) per plane
    sw = [jnp.sum(b, axis=0) for b in wb]  # (N,) per plane

    raw = jnp.zeros((m, n), jnp.int32)
    for p in range(8):
        for q in range(8):
            if (p, q) in dig:
                dp = xb[p] @ wb[q]
            else:
                prod = sx[p][:, None] * sw[q][None, :]
                dp = (prod + k // 2) // k  # round-nearest divide by DP len
            raw = raw + (dp << (p + q))
    return _zero_point_correct(raw, x, w, k, zpx, zpw)


def pac_matmul_numpy(xq, wq, zpx, zpw, bx=DEFAULT_BITS, bw=DEFAULT_BITS):
    """Numpy twin of pac_matmul_ref (used by tests to avoid tracing)."""
    x = np.asarray(xq, np.int64)
    w = np.asarray(wq, np.int64)
    m, k = x.shape
    n = w.shape[1]
    dig = set(digital_pairs(bx, bw))
    raw = np.zeros((m, n), np.int64)
    for p in range(8):
        xb = (x >> p) & 1
        sxp = xb.sum(axis=1)
        for q in range(8):
            wb = (w >> q) & 1
            if (p, q) in dig:
                dp = xb @ wb
            else:
                swq = wb.sum(axis=0)
                dp = (sxp[:, None] * swq[None, :] + k // 2) // k
            raw += dp << (p + q)
    sum_x = x.sum(axis=1, keepdims=True)
    sum_w = w.sum(axis=0, keepdims=True)
    return (raw - zpw * sum_x - zpx * sum_w + k * zpx * zpw).astype(np.int32)
