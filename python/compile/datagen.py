"""Deterministic procedural image-classification dataset.

Our substitution for CIFAR-10/100 (no dataset downloads in this
environment — DESIGN.md §3): a texture taxonomy whose classes are
distinguishable by a small CNN but non-trivial (random phase, frequency
jitter, per-channel color modulation, additive noise). The 100-class
variant crosses the 10 base textures with 10 color palettes, mirroring
how CIFAR-100 is "CIFAR-10 but finer".

Everything derives from a single integer seed; the same seed always
produces the same dataset on any platform (numpy Philox).
"""

from __future__ import annotations

import numpy as np

from .quant_utils import QuantParams

N_TEXTURES = 10

# 10 fixed RGB palettes for the 100-class variant (base, accent).
_PALETTES = np.array(
    [
        [[1.0, 0.2, 0.2], [0.1, 0.1, 0.9]],
        [[0.2, 1.0, 0.2], [0.9, 0.1, 0.7]],
        [[0.2, 0.2, 1.0], [0.9, 0.9, 0.1]],
        [[0.9, 0.6, 0.1], [0.1, 0.7, 0.7]],
        [[0.8, 0.1, 0.8], [0.2, 0.9, 0.3]],
        [[0.9, 0.9, 0.9], [0.1, 0.1, 0.1]],
        [[0.6, 0.3, 0.1], [0.3, 0.6, 0.9]],
        [[0.1, 0.5, 0.3], [0.9, 0.4, 0.2]],
        [[0.5, 0.5, 0.9], [0.9, 0.5, 0.5]],
        [[0.3, 0.9, 0.8], [0.7, 0.2, 0.5]],
    ],
    dtype=np.float32,
)


def _texture(kind: int, hw: int, rng: np.random.Generator) -> np.ndarray:
    """One grayscale texture field in [0, 1], shape (hw, hw)."""
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32) / hw
    phase = rng.uniform(0, 2 * np.pi)
    freq = rng.uniform(2.5, 4.5)
    t = 2 * np.pi * freq
    if kind == 0:  # horizontal stripes
        g = np.sin(t * yy + phase)
    elif kind == 1:  # vertical stripes
        g = np.sin(t * xx + phase)
    elif kind == 2:  # diagonal stripes
        g = np.sin(t * (xx + yy) / np.sqrt(2) + phase)
    elif kind == 3:  # checkerboard
        g = np.sign(np.sin(t * xx + phase) * np.sin(t * yy + phase))
    elif kind == 4:  # concentric rings
        cx, cy = rng.uniform(0.35, 0.65, size=2)
        r = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
        g = np.sin(2.5 * t * r + phase)
    elif kind == 5:  # spot lattice
        g = np.sin(t * xx + phase) * np.sin(t * yy + phase)
        g = np.where(g > 0.3, 1.0, -1.0)
    elif kind == 6:  # radial gradient
        cx, cy = rng.uniform(0.3, 0.7, size=2)
        r = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
        g = 1.0 - 2.0 * np.clip(r / 0.7, 0, 1)
    elif kind == 7:  # linear gradient (random direction)
        ang = rng.uniform(0, 2 * np.pi)
        g = 2.0 * ((xx - 0.5) * np.cos(ang) + (yy - 0.5) * np.sin(ang))
    elif kind == 8:  # coarse block noise
        blocks = rng.uniform(-1, 1, size=(4, 4)).astype(np.float32)
        g = np.kron(blocks, np.ones((hw // 4, hw // 4), np.float32))
    elif kind == 9:  # cross grid
        g = np.maximum(np.sin(t * xx + phase), np.sin(t * yy + phase))
    else:
        raise ValueError(f"unknown texture {kind}")
    return (np.clip(g, -1, 1) + 1) / 2  # → [0, 1]


def generate(
    n: int, hw: int = 32, n_classes: int = 10, seed: int = 7, noise: float = 0.06
):
    """Generate `n` images (NCHW float32 in [0,1]) and labels.

    n_classes = 10 → textures with random palettes (palette is nuisance);
    n_classes = 100 → texture × palette grid (palette is class-defining).
    """
    assert n_classes in (10, 100), "10 or 100 classes"
    assert hw % 4 == 0
    rng = np.random.Generator(np.random.Philox(seed))
    images = np.zeros((n, 3, hw, hw), np.float32)
    labels = (np.arange(n) % n_classes).astype(np.uint8)
    # Shuffle label order deterministically so splits are balanced.
    rng.shuffle(labels)
    for i in range(n):
        label = int(labels[i])
        if n_classes == 10:
            kind = label
            palette = _PALETTES[rng.integers(0, len(_PALETTES))]
        else:
            kind = label % N_TEXTURES
            palette = _PALETTES[label // N_TEXTURES]
        g = _texture(kind, hw, rng)
        base, accent = palette
        img = g[None, :, :] * base[:, None, None] + (1 - g[None, :, :]) * accent[
            :, None, None
        ]
        img += rng.normal(0, noise, size=img.shape).astype(np.float32)
        images[i] = np.clip(img, 0, 1)
    return images, labels


# Input quantization contract: raw [0,1] pixels, scale 1/255, zp 0.
INPUT_PARAMS = QuantParams(1.0 / 255.0, 0)


def write_dataset_bin(path, images_q: np.ndarray, labels: np.ndarray, n_classes: int,
                      params: QuantParams = INPUT_PARAMS) -> None:
    """Write `dataset.bin` (format: rust/src/workload/dataset.rs)."""
    n, c, h, w = images_q.shape
    assert images_q.dtype == np.uint8 and labels.dtype == np.uint8
    with open(path, "wb") as f:
        f.write(b"PACD")
        for v in (1, n, c, h, w, n_classes):
            f.write(np.uint32(v).tobytes())
        f.write(np.float32(params.scale).tobytes())
        f.write(np.uint32(params.zero_point).tobytes())
        f.write(images_q.tobytes())
        f.write(labels.tobytes())
