"""L2: the model compute graph.

Two faces of the same ``tiny_resnet`` topology (kept in sync with
``rust/src/nn/layers.rs::tiny_resnet``):

1. ``float_forward``  - the float training model (build-time training).
2. ``quantized_forward`` - the PTQ inference graph whose every GEMM runs
   through an L1 kernel (PAC hybrid or exact bit-serial); this is what
   ``aot.py`` lowers to HLO text for the rust PJRT runtime.

Topology (width C, input 3xHWxHW):

    stem:   conv3x3(3->C)/1 + relu
    block1: save; conv3x3(C->C)+relu; conv3x3(C->C); add+relu
    down1:  conv3x3(C->2C)/2 + relu
    block2: residual block @2C
    down2:  conv3x3(2C->4C)/2 + relu
    block3: residual block @4C
    head:   global avgpool; linear(4C->classes) -> logits
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.bitserial import bitserial_matmul
from .kernels.pac_matmul import pac_matmul
from .kernels.ref import exact_matmul_ref, pac_matmul_ref
from .quant_utils import QuantParams, calibrate_minmax, calibrate_weights_symmetric

# Conv layer names in program order (shared with rust + weights.bin).
CONV_NAMES = [
    "stem",
    "block1.conv1", "block1.conv2",
    "down1",
    "block2.conv1", "block2.conv2",
    "down2",
    "block3.conv1", "block3.conv2",
]
# (in_mult, out_mult, stride, relu) per conv, mults x base width C.
CONV_SPECS = {
    "stem": (None, 1, 1, True),          # in_c = 3
    "block1.conv1": (1, 1, 1, True),
    "block1.conv2": (1, 1, 1, False),
    "down1": (1, 2, 2, True),
    "block2.conv1": (2, 2, 1, True),
    "block2.conv2": (2, 2, 1, False),
    "down2": (2, 4, 2, True),
    "block3.conv1": (4, 4, 1, True),
    "block3.conv2": (4, 4, 1, False),
}
ADD_NAMES = ["block1.add", "block2.add", "block3.add"]


def conv_channels(c: int):
    """(in_c, out_c) per conv name for base width c."""
    out = {}
    for name, (im, om, _, _) in CONV_SPECS.items():
        in_c = 3 if im is None else im * c
        out[name] = (in_c, om * c)
    return out


# --------------------------------------------------------------------------
# Float training model
# --------------------------------------------------------------------------

def init_params(key, c: int = 16, classes: int = 10) -> Dict[str, jnp.ndarray]:
    """He-init float parameters. Conv weights OIHW, fc (classes, 4C)."""
    params = {}
    chans = conv_channels(c)
    for name in CONV_NAMES:
        in_c, out_c = chans[name]
        key, sub = jax.random.split(key)
        fan_in = in_c * 9
        params[f"{name}.w"] = jax.random.normal(
            sub, (out_c, in_c, 3, 3), jnp.float32) * np.sqrt(2.0 / fan_in)
        params[f"{name}.b"] = jnp.zeros((out_c,), jnp.float32)
    key, sub = jax.random.split(key)
    params["fc.w"] = jax.random.normal(sub, (classes, 4 * c), jnp.float32) * 0.05
    params["fc.b"] = jnp.zeros((classes,), jnp.float32)
    return params


def _conv2d(x, w, b, stride):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + b[None, :, None, None]


def float_forward(params, x, capture: Callable[[str, jnp.ndarray], None] = None,
                  noise_key=None, noise_std=0.0):
    """Float forward; ``capture(name, act)`` observes post-activation
    tensors for PTQ calibration.

    ``noise_std`` > 0 injects Gaussian noise proportional to each conv
    output's std *before* the nonlinearity — the training-time proxy for
    the PAC approximation noise (paper §6.1: fine-tuning under
    progressively augmented Gaussian noise). The first conv is left
    clean, mirroring the architecture's exact first layer."""
    keys = {}
    if noise_key is not None:
        split = jax.random.split(noise_key, len(CONV_NAMES))
        keys = dict(zip(CONV_NAMES, split))

    def note(name, v):
        if capture is not None:
            capture(name, v)
        return v

    def conv(name, h):
        _, _, stride, relu = CONV_SPECS[name]
        y = _conv2d(h, params[f"{name}.w"], params[f"{name}.b"], stride)
        if name in keys and name != "stem":
            sigma = noise_std * jnp.std(y)
            y = y + sigma * jax.random.normal(keys[name], y.shape)
        if relu:
            y = jax.nn.relu(y)
        return note(name, y)

    h = conv("stem", x)
    for blk in ("block1", "block2", "block3"):
        skip = h
        h = conv(f"{blk}.conv1", h)
        h = conv(f"{blk}.conv2", h)
        h = note(f"{blk}.add", jax.nn.relu(h + skip))
        if blk == "block1":
            h = conv("down1", h)
        elif blk == "block2":
            h = conv("down2", h)
    gap = jnp.mean(h, axis=(2, 3))
    return gap @ params["fc.w"].T + params["fc.b"]


# --------------------------------------------------------------------------
# PTQ: calibrate + pack the quantized model description
# --------------------------------------------------------------------------

def quantize_model(params, calib_x: np.ndarray, input_params: QuantParams):
    """Post-training quantization. Returns a dict:
        {name: {"wq": (out_c, K) uint8, "wp": QuantParams, "b": f32 (out_c,),
                "oq": QuantParams}}  per conv,
        plus "<blk>.add.oq" entries, an "fc" entry, and "input.oq".
    """
    hi_ranges: Dict[str, float] = {}
    lo_ranges: Dict[str, float] = {}

    def capture(name, v):
        hi_ranges[name] = max(hi_ranges.get(name, 0.0), float(jnp.max(v)))
        lo_ranges[name] = min(lo_ranges.get(name, 0.0), float(jnp.min(v)))

    _ = float_forward(params, jnp.asarray(calib_x), capture)
    q = {"input.oq": input_params}
    for name in CONV_NAMES:
        w = np.asarray(params[f"{name}.w"])  # OIHW
        out_c = w.shape[0]
        wq_params = calibrate_weights_symmetric(w)
        wq = wq_params.quantize(w.reshape(out_c, -1))  # (out_c, K), (c,kh,kw)
        oq = calibrate_minmax(lo_ranges[name], hi_ranges[name])
        q[name] = {
            "wq": wq, "wp": wq_params,
            "b": np.asarray(params[f"{name}.b"]), "oq": oq,
        }
    for name in ADD_NAMES:
        q[f"{name}.oq"] = calibrate_minmax(0.0, hi_ranges[name])
    fcw = np.asarray(params["fc.w"])  # (classes, 4C)
    fwp = calibrate_weights_symmetric(fcw)
    q["fc"] = {"wq": fwp.quantize(fcw), "wp": fwp,
               "b": np.asarray(params["fc.b"])}
    return q


# --------------------------------------------------------------------------
# Quantized inference graph (lowered to HLO by aot.py)
# --------------------------------------------------------------------------

def _patches_nchw(xq, stride, pad_value):
    """im2col with zero-point padding: xq int32 (B,C,H,W) ->
    (B*OH*OW, C*9), (c, kh, kw) feature order (matches rust im2col)."""
    xpad = jnp.pad(xq, ((0, 0), (0, 0), (1, 1), (1, 1)),
                   constant_values=pad_value)
    cols = jax.lax.conv_general_dilated_patches(
        xpad.astype(jnp.float32),
        filter_shape=(3, 3), window_strides=(stride, stride),
        padding="VALID", dimension_numbers=("NCHW", "OIHW", "NCHW"))
    # (B, C*9, OH, OW), feature dim ordered (c, kh, kw).
    bb, k, oh, ow = cols.shape
    cols = jnp.transpose(cols, (0, 2, 3, 1)).reshape(bb * oh * ow, k)
    return cols.astype(jnp.int32), (oh, ow)


def quantized_forward(q, x_flat, *, hw: int, classes: int,
                      mode: str = "pac", bits: int = 4,
                      use_pallas: bool = True, block_m: int = 128,
                      min_dp: int = 512):
    """The AOT-exported graph: f32 (B, 3*hw*hw) pixels in [0,1] -> logits
    f32 (B, classes).

    mode: "pac" (hybrid Eq. 4) or "exact" (bit-serial baseline).
    use_pallas: route GEMMs through the L1 Pallas kernels (default) or
    the pure-jnp references (fallback / A-B testing).
    min_dp: layers with DP length below this run exactly. The paper's PAC
    operating range is DP >= 512 (Table 1 note d; every CONV/LINEAR layer
    of its benchmarks qualifies); our substitute model has shorter early
    layers, which stay digital — mirrored by the rust backend's
    ``PacConfig::min_dp_len`` (512).
    """
    b = x_flat.shape[0]
    inp: QuantParams = q["input.oq"]
    x = x_flat.reshape(b, 3, hw, hw)
    xq = jnp.clip(jnp.round(x / inp.scale) + inp.zero_point,
                  0, 255).astype(jnp.int32)

    first_done = [False]

    def gemm(xcols, layer, h_params):
        zpx_ = int(h_params.zero_point)
        zpw_ = int(layer["wp"].zero_point)
        wq = jnp.asarray(layer["wq"], jnp.int32).T  # (K, out_c)
        if not first_done[0]:
            # First layer always exact (standard D-CiM, paper 6.1).
            first_done[0] = True
            return exact_matmul_ref(xcols, wq, zpx_, zpw_)
        if wq.shape[0] < min_dp:
            # Below the PAC operating range: standard D-CiM.
            return exact_matmul_ref(xcols, wq, zpx_, zpw_)
        if mode == "pac":
            if use_pallas:
                return pac_matmul(xcols, wq, zpx=zpx_, zpw=zpw_,
                                  bx=bits, bw=bits, block_m=block_m)
            return pac_matmul_ref(xcols, wq, zpx_, zpw_, bx=bits, bw=bits)
        if use_pallas:
            return bitserial_matmul(xcols, wq, zpx=zpx_, zpw=zpw_,
                                    block_m=block_m)
        return exact_matmul_ref(xcols, wq, zpx_, zpw_)

    def conv(name, h, h_params):
        _, _, stride, relu = CONV_SPECS[name]
        layer = q[name]
        cols, (oh, ow) = _patches_nchw(h, stride, int(h_params.zero_point))
        acc = gemm(cols, layer, h_params)
        out_c = layer["wq"].shape[0]
        oq: QuantParams = layer["oq"]
        real = acc.astype(jnp.float32) * np.float32(h_params.scale * layer["wp"].scale) \
            + jnp.asarray(layer["b"])
        if relu:
            real = jnp.maximum(real, 0.0)
        y = jnp.clip(jnp.round(real / oq.scale) + oq.zero_point,
                     0, 255).astype(jnp.int32)
        y = y.reshape(b, oh, ow, out_c).transpose(0, 3, 1, 2)
        return y, oq

    h, hp = conv("stem", xq, inp)
    for blk in ("block1", "block2", "block3"):
        skip, skip_p = h, hp
        h, hp = conv(f"{blk}.conv1", h, hp)
        h, hp = conv(f"{blk}.conv2", h, hp)
        oq: QuantParams = q[f"{blk}.add.oq"]
        real = (h - hp.zero_point) * np.float32(hp.scale) \
            + (skip - skip_p.zero_point) * np.float32(skip_p.scale)
        real = jnp.maximum(real, 0.0)
        h = jnp.clip(jnp.round(real / oq.scale) + oq.zero_point,
                     0, 255).astype(jnp.int32)
        hp = oq
        if blk == "block1":
            h, hp = conv("down1", h, hp)
        elif blk == "block2":
            h, hp = conv("down2", h, hp)
    # Global average pool with round-nearest integer mean (rust exec.rs).
    px = h.shape[2] * h.shape[3]
    gap = (jnp.sum(h, axis=(2, 3)) + px // 2) // px  # (B, 4C) int32
    fc = q["fc"]
    wq = jnp.asarray(fc["wq"], jnp.int32).T
    acc = exact_matmul_ref(gap, wq, int(hp.zero_point), int(fc["wp"].zero_point))
    logits = acc.astype(jnp.float32) * np.float32(hp.scale * fc["wp"].scale) \
        + jnp.asarray(fc["b"])
    return logits
