"""Build-time training of the tiny models on the synthetic dataset.

Hand-rolled Adam (optax is not installed in this environment). Runs once
under ``make artifacts``; the resulting float weights are cached in
``artifacts/train_cache.npz`` keyed by the config hash so re-running the
build is a no-op.
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .datagen import generate
from .model import float_forward, init_params


def adam_init(params):
    return {
        "m": jax.tree_util.tree_map(jnp.zeros_like, params),
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def loss_fn(params, x, y, noise_key=None, noise_std=0.0):
    """Softmax CE with activation-noise injection — the paper's
    noise-aware fine-tuning (§6.1): Gaussian noise proportional to each
    conv output's scale emulates the PAC approximation error during
    training, so the deployed model tolerates it.
    ``noise_std`` may be a traced scalar (0 disables noise smoothly)."""
    logits = float_forward(params, x, noise_key=noise_key, noise_std=noise_std)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(params, x, y, batch=256):
    correct = 0
    for i in range(0, len(x), batch):
        logits = float_forward(params, jnp.asarray(x[i:i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == jnp.asarray(y[i:i + batch])))
    return correct / len(x)


def train(
    c: int = 16,
    classes: int = 10,
    hw: int = 32,
    n_train: int = 4096,
    steps: int = 1000,
    batch: int = 64,
    lr: float = 2e-3,
    seed: int = 7,
    noise_finetune_steps: int = 200,
    noise_std: float = 0.10,
    pac_ste_steps: int = 0,
    log_every: int = 100,
    log=print,
) -> Dict[str, np.ndarray]:
    """Train tiny_resnet; returns float params as numpy arrays.

    The last `noise_finetune_steps` apply progressively augmented Gaussian
    weight noise (the paper's fine-tuning recipe, §6.1) so the quantized/
    approximated model inherits noise tolerance.
    """
    x_train, y_train = generate(n_train, hw=hw, n_classes=classes, seed=seed)
    params = init_params(jax.random.PRNGKey(seed), c=c, classes=classes)
    state = adam_init(params)

    @jax.jit
    def step_fn(params, state, x, y, key, noise_std):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, key, noise_std)
        params, state = adam_update(params, grads, state, lr=lr)
        return params, state, loss

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    losses = []
    for s in range(steps):
        idx = rng.integers(0, n_train, batch)
        x = jnp.asarray(x_train[idx])
        y = jnp.asarray(y_train[idx].astype(np.int32))
        key, sub = jax.random.split(key)
        # Progressive noise ramp over the fine-tuning tail.
        ft = s - (steps - noise_finetune_steps)
        sigma = noise_std * max(0.0, ft / noise_finetune_steps) if ft > 0 else 0.0
        params, state, loss = step_fn(params, state, x, y, sub, sigma)
        losses.append(float(loss))
        if log_every and s % log_every == 0:
            log(f"  step {s:4d}  loss {float(loss):.4f}  sigma {sigma:.4f}")
    train_acc = accuracy(params, x_train[:1024], y_train[:1024].astype(np.int32))
    log(f"  train accuracy before PAC fine-tune: {train_acc * 100:.2f}%")
    if pac_ste_steps > 0:
        params = pac_finetune(params, classes=classes, hw=hw,
                              n_train=n_train, steps=pac_ste_steps,
                              seed=seed, log=log)
        train_acc = accuracy(params, x_train[:1024],
                             y_train[:1024].astype(np.int32))
        log(f"  final float train accuracy: {train_acc * 100:.2f}%")
    return {k: np.asarray(v) for k, v in params.items()}, losses, train_acc


def pac_finetune(
    params,
    classes: int,
    hw: int,
    n_train: int = 4096,
    steps: int = 200,
    batch: int = 16,
    lr: float = 5e-5,
    seed: int = 7,
    recalib_every: int = 50,
    log_every: int = 50,
    log=print,
):
    """PAC-aware fine-tuning via a straight-through estimator.

    The paper fine-tunes "under progressively augmented Gaussian noise";
    on our shallow substitute model plain Gaussian noise is not enough —
    the PAC error is *structured* (it removes the covariance between
    activation and weight LSB bit-planes), so we fine-tune against the
    actual deployed forward: the loss is evaluated on the PAC-quantized
    logits, with gradients flowing through the float model (STE):

        logits = float_logits + stop_grad(pac_logits - float_logits)

    The quantization calibration is refreshed every ``recalib_every``
    steps from the live parameters.

    EXPERIMENTAL (off by default): with stale calibration the STE offset
    grows between recalibrations and training can diverge; see
    EXPERIMENTS.md. The shipped configuration instead scopes PAC to the
    paper's DP operating range (>= 512; our substitute uses >= 256), where
    plain noise fine-tuning suffices.
    """
    from .datagen import INPUT_PARAMS
    from .model import quantize_model, quantized_forward

    x_train, y_train = generate(n_train, hw=hw, n_classes=classes, seed=seed)
    state = adam_init(params)
    rng = np.random.default_rng(seed + 2)

    q = None
    step_fn = None

    def make_step(q_frozen):
        def ste_loss(params, x, y):
            xf = x.reshape(x.shape[0], -1)
            pac_logits = quantized_forward(
                q_frozen, xf, hw=hw, classes=classes, mode="pac",
                use_pallas=False)
            float_logits = float_forward(params, x)
            logits = float_logits + jax.lax.stop_gradient(
                pac_logits - float_logits)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

        @jax.jit
        def step(params, state, x, y):
            loss, grads = jax.value_and_grad(ste_loss)(params, x, y)
            params, state = adam_update(params, grads, state, lr=lr)
            return params, state, loss

        return step

    for s_i in range(steps):
        if s_i % recalib_every == 0:
            q = quantize_model(params, x_train[:128], INPUT_PARAMS)
            step_fn = make_step(q)
        idx = rng.integers(0, n_train, batch)
        x = jnp.asarray(x_train[idx])
        y = jnp.asarray(y_train[idx].astype(np.int32))
        params, state, loss = step_fn(params, state, x, y)
        if log_every and s_i % log_every == 0:
            log(f"  [pac-ste] step {s_i:4d}  loss {float(loss):.4f}")
    return params


def config_hash(**kwargs) -> str:
    return hashlib.sha256(json.dumps(kwargs, sort_keys=True).encode()).hexdigest()[:16]


def train_cached(cache_path: str, log=print, **kwargs):
    """Train with an on-disk cache keyed by the config hash."""
    h = config_hash(**kwargs)
    if os.path.exists(cache_path):
        data = np.load(cache_path, allow_pickle=True)
        if str(data.get("config_hash")) == h:
            log(f"  using cached training run ({cache_path})")
            params = {k: data[k] for k in data.files
                      if k not in ("config_hash", "losses", "train_acc")}
            return params, list(data["losses"]), float(data["train_acc"])
    params, losses, train_acc = train(log=log, **kwargs)
    np.savez(cache_path, config_hash=h, losses=np.asarray(losses),
             train_acc=train_acc, **params)
    return params, losses, train_acc
