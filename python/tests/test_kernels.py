"""L1 kernel correctness: Pallas kernels vs the pure-jnp/numpy oracle.

Hypothesis sweeps shapes, zero points, operand widths and block sizes —
the CORE correctness signal for the compute path (task: kernel == ref
exactly; the PAC kernel is integer arithmetic, so equality is exact, not
allclose).
"""

import os
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels.bitserial import bitserial_matmul
from compile.kernels.pac_matmul import pac_matmul, vmem_bytes
from compile.kernels.ref import (
    bitserial_matmul_ref,
    digital_pairs,
    exact_matmul_ref,
    pac_matmul_numpy,
    sparsity_pairs,
)


def rand_mat(rng, m, k):
    return rng.integers(0, 256, (m, k)).astype(np.int32)


# ---------------------------------------------------------------------------
# Structure of the computing map
# ---------------------------------------------------------------------------

def test_digital_pairs_default_is_16():
    assert len(digital_pairs()) == 16
    assert len(sparsity_pairs()) == 48
    assert (7, 7) in digital_pairs()
    assert (3, 3) not in digital_pairs()


@pytest.mark.parametrize("b", [0, 1, 2, 4, 5, 8])
def test_digital_pairs_partition(b):
    assert len(digital_pairs(b, b)) == b * b
    assert len(digital_pairs(b, b)) + len(sparsity_pairs(b, b)) == 64


# ---------------------------------------------------------------------------
# Exactness of the bit-serial identity (Eq. 1)
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 24),
    k=st.integers(1, 96),
    n=st.integers(1, 16),
    zpx=st.integers(0, 255),
    zpw=st.integers(0, 255),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=40, deadline=None)
def test_bitserial_ref_equals_exact(m, k, n, zpx, zpw, seed):
    rng = np.random.default_rng(seed)
    x, w = rand_mat(rng, m, k), rand_mat(rng, k, n)
    got = np.asarray(bitserial_matmul_ref(x, w, zpx, zpw))
    want = np.asarray(exact_matmul_ref(x, w, zpx, zpw))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Pallas kernels vs oracles (the hypothesis sweep the task mandates)
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 40),
    k=st.integers(2, 128),
    n=st.integers(1, 24),
    zpx=st.integers(0, 255),
    zpw=st.sampled_from([0, 100, 128, 255]),
    bits=st.sampled_from([2, 3, 4, 5, 6]),
    block_m=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=30, deadline=None)
def test_pac_pallas_equals_numpy_oracle(m, k, n, zpx, zpw, bits, block_m, seed):
    rng = np.random.default_rng(seed)
    x, w = rand_mat(rng, m, k), rand_mat(rng, k, n)
    got = np.asarray(
        pac_matmul(x, w, zpx=zpx, zpw=zpw, bx=bits, bw=bits, block_m=block_m)
    )
    want = pac_matmul_numpy(x, w, zpx, zpw, bx=bits, bw=bits)
    np.testing.assert_array_equal(got, want)


@given(
    m=st.integers(1, 32),
    k=st.integers(2, 96),
    n=st.integers(1, 16),
    zpx=st.integers(0, 255),
    block_m=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=20, deadline=None)
def test_bitserial_pallas_is_exact(m, k, n, zpx, block_m, seed):
    rng = np.random.default_rng(seed)
    x, w = rand_mat(rng, m, k), rand_mat(rng, k, n)
    got = np.asarray(bitserial_matmul(x, w, zpx=zpx, zpw=128, block_m=block_m))
    want = np.asarray(exact_matmul_ref(x, w, zpx, 128))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Approximation quality (paper §3.2 at the kernel level)
# ---------------------------------------------------------------------------

def test_pac_relative_error_below_1pct_at_dp_1024():
    rng = np.random.default_rng(42)
    x, w = rand_mat(rng, 64, 1024), rand_mat(rng, 1024, 32)
    approx = np.asarray(pac_matmul(x, w, zpx=0, zpw=0)).astype(np.float64)
    exact = np.asarray(exact_matmul_ref(x, w, 0, 0)).astype(np.float64)
    rel = np.abs(approx - exact) / np.maximum(exact, 1)
    assert np.median(rel) < 0.01, float(np.median(rel))


def test_wider_operand_reduces_error():
    rng = np.random.default_rng(43)
    x, w = rand_mat(rng, 32, 512), rand_mat(rng, 512, 16)
    exact = np.asarray(exact_matmul_ref(x, w, 0, 0)).astype(np.float64)
    errs = []
    for bits in (2, 4, 6):
        approx = np.asarray(pac_matmul(x, w, zpx=0, zpw=0, bx=bits, bw=bits))
        errs.append(float(np.abs(approx - exact).mean()))
    assert errs[0] > errs[1] > errs[2], errs


def test_full_digital_operand_is_exact():
    rng = np.random.default_rng(44)
    x, w = rand_mat(rng, 16, 64), rand_mat(rng, 64, 8)
    approx = np.asarray(pac_matmul(x, w, zpx=9, zpw=128, bx=8, bw=8))
    exact = np.asarray(exact_matmul_ref(x, w, 9, 128))
    np.testing.assert_array_equal(approx, exact)


# ---------------------------------------------------------------------------
# VMEM budget (L1 perf contract, DESIGN.md §9)
# ---------------------------------------------------------------------------

def test_vmem_budget():
    # Largest layer in tiny_resnet at block_m=128: K=576, N=64.
    assert vmem_bytes(128, 576, 64) < 16 * 2**20
    # And the biggest ResNet-18 CIFAR layer (K=4608, N=512) still fits.
    assert vmem_bytes(128, 4608, 512) < 16 * 2**20
