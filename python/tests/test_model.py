"""L2 model tests: float model shapes/training signal, PTQ fidelity, the
pallas/ref A-B equality on the full forward, and operand-width ordering."""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.datagen import INPUT_PARAMS, generate
from compile.model import (
    float_forward,
    init_params,
    quantize_model,
    quantized_forward,
)

HW, C, CLASSES, B = 16, 8, 10, 4


@pytest.fixture(scope="module")
def setup():
    x, y = generate(32, hw=HW, n_classes=CLASSES, seed=11)
    params = init_params(jax.random.PRNGKey(1), c=C, classes=CLASSES)
    q = quantize_model(params, x[:16], INPUT_PARAMS)
    return params, q, x, y


def test_float_forward_shape(setup):
    params, _, x, _ = setup
    logits = float_forward(params, jnp.asarray(x[:B]))
    assert logits.shape == (B, CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_quantized_exact_tracks_float(setup):
    params, q, x, _ = setup
    xf = x[:B].reshape(B, -1)
    lq = np.asarray(quantized_forward(q, jnp.asarray(xf), hw=HW,
                                      classes=CLASSES, mode="exact"))
    lf = np.asarray(float_forward(params, jnp.asarray(x[:B])))
    corr = np.corrcoef(lq.ravel(), lf.ravel())[0, 1]
    assert corr > 0.9, corr


def test_pallas_equals_ref_full_forward(setup):
    _, q, x, _ = setup
    xf = jnp.asarray(x[:B].reshape(B, -1))
    for mode in ("pac", "exact"):
        a = np.asarray(quantized_forward(q, xf, hw=HW, classes=CLASSES,
                                         mode=mode, use_pallas=True))
        b = np.asarray(quantized_forward(q, xf, hw=HW, classes=CLASSES,
                                         mode=mode, use_pallas=False))
        np.testing.assert_array_equal(a, b)


def test_pac_forward_close_to_exact(setup):
    _, q, x, _ = setup
    xf = jnp.asarray(x[:B].reshape(B, -1))
    pac = np.asarray(quantized_forward(q, xf, hw=HW, classes=CLASSES, mode="pac"))
    exact = np.asarray(quantized_forward(q, xf, hw=HW, classes=CLASSES, mode="exact"))
    # Quantized-logit agreement: same argmax on most rows for an
    # untrained net is not guaranteed; assert bounded deviation instead.
    scale = np.abs(exact).max() + 1e-6
    assert np.abs(pac - exact).max() / scale < 0.6


def test_operand_width_monotone(setup):
    _, q, x, _ = setup
    xf = jnp.asarray(x[:B].reshape(B, -1))
    exact = np.asarray(quantized_forward(q, xf, hw=HW, classes=CLASSES, mode="exact"))
    errs = []
    for bits in (2, 4, 6):
        pac = np.asarray(quantized_forward(q, xf, hw=HW, classes=CLASSES,
                                           mode="pac", bits=bits))
        errs.append(float(np.abs(pac - exact).mean()))
    assert errs[0] >= errs[1] >= errs[2], errs


def test_training_reduces_loss():
    from compile.train import train
    params, losses, acc = train(c=8, classes=10, hw=16, n_train=256,
                                steps=60, batch=32, log_every=0,
                                noise_finetune_steps=10, log=lambda *_: None)
    assert np.mean(losses[:10]) > np.mean(losses[-10:]), "loss did not drop"
