"""Quantization contract tests — mirrors rust/src/quant tests so the two
implementations cannot drift apart silently."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.quant_utils import (
    QuantParams,
    calibrate_minmax,
    calibrate_tensor,
    calibrate_weights_symmetric,
)


def test_minmax_includes_zero():
    p = calibrate_minmax(0.5, 4.0)
    assert p.zero_point == 0
    assert abs(float(p.dequantize(p.quantize(np.array(0.0))))) < 1e-6


def test_symmetric_weights_zp128():
    w = np.array([-1.0, 0.5, 0.25, 1.0], np.float32)
    p = calibrate_weights_symmetric(w)
    assert p.zero_point == 128
    assert p.quantize(np.array(-1.0)) == 128 - 127


def test_saturation():
    p = QuantParams(0.1, 128)
    assert p.quantize(np.array(1e9)) == 255
    assert p.quantize(np.array(-1e9)) == 0


@given(
    lo=st.floats(-100, 0),
    hi=st.floats(0.01, 100),
    xs=st.lists(st.floats(-100, 100), min_size=1, max_size=64),
)
@settings(max_examples=100, deadline=None)
def test_roundtrip_within_half_ulp(lo, hi, xs):
    p = calibrate_minmax(lo, hi)
    x = np.clip(np.asarray(xs, np.float32), lo, hi)
    back = p.dequantize(p.quantize(x))
    assert np.all(np.abs(back - x) <= p.scale * 0.5 + 1e-4)


@given(st.lists(st.floats(-50, 50), min_size=2, max_size=128))
@settings(max_examples=100, deadline=None)
def test_calibrate_tensor_covers_range(xs):
    x = np.asarray(xs, np.float32)
    p = calibrate_tensor(x)
    back = p.dequantize(p.quantize(x))
    assert np.all(np.abs(back - x) <= p.scale * 0.5 + 1e-4)


def test_rust_equivalence_vectors():
    """Golden vectors checked on both sides (rust: quant::tests).

    Values exactly on the .5 rounding boundary are excluded: numpy rounds
    half-to-even while rust rounds half-away-from-zero, and float division
    can land on either side of the boundary (a ≤0.5-ulp difference that is
    irrelevant to the simulation but breaks exact golden tests).
    """
    p = QuantParams(0.1, 128)
    xs = np.array([-12.0, -0.04, 0.0, 0.049, 3.3, 12.69], np.float32)
    qs = p.quantize(xs)
    assert qs.tolist() == [8, 128, 128, 128, 161, 255]
