"""AOT export pipeline tests: HLO text generation, weights.bin format,
and (when artifacts exist) consistency of the exported files."""

import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.aot import to_hlo_text, write_weights_bin
from compile.datagen import INPUT_PARAMS, generate
from compile.model import init_params, quantize_model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    def fn(a, b):
        return (jnp.matmul(a, b) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_weights_bin_format(tmp_path):
    x, _ = generate(8, hw=16, seed=21)
    params = init_params(jax.random.PRNGKey(2), c=8, classes=10)
    q = quantize_model(params, x, INPUT_PARAMS)
    path = tmp_path / "weights.bin"
    write_weights_bin(str(path), q)
    raw = path.read_bytes()
    assert raw[:4] == b"PACW"
    version, n_entries = struct.unpack("<II", raw[4:12])
    assert version == 1
    # 1 input.oq + 9 convs x 3 + 3 add.oq + fc.w + fc.b = 33
    assert n_entries == 33
    # First entry name parses.
    name_len = struct.unpack("<H", raw[12:14])[0]
    name = raw[14:14 + name_len].decode()
    assert name  # BTreeMap-ordering on the rust side doesn't care


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifest_files_exist():
    man = {}
    with open(os.path.join(ARTIFACTS, "manifest.txt")) as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                k, v = line.split(None, 1)
                man[k] = v
    for key in ("model_pac", "model_exact", "weights", "dataset", "pac_kernel"):
        assert os.path.exists(os.path.join(ARTIFACTS, man[key])), key


@needs_artifacts
def test_exported_hlo_mentions_entry():
    with open(os.path.join(ARTIFACTS, "model_pac.hlo.txt")) as f:
        head = f.read(4000)
    assert "HloModule" in head


@needs_artifacts
def test_trained_model_beats_chance_via_hlo_semantics():
    """Re-run the quantized forward in python on the test split and check
    accuracy clears chance by a wide margin (full accuracy eval happens in
    the rust benches)."""
    from compile.model import quantized_forward
    from compile.train import train_cached

    cache = os.path.join(ARTIFACTS, "train_cache.npz")
    data = np.load(cache, allow_pickle=True)
    params = {k: jnp.asarray(data[k]) for k in data.files
              if k not in ("config_hash", "losses", "train_acc")}
    x, y = generate(128, hw=32, n_classes=10, seed=8)  # = aot test split seed
    q = quantize_model(params, x[:64], INPUT_PARAMS)
    logits = quantized_forward(q, jnp.asarray(x.reshape(128, -1)),
                               hw=32, classes=10, mode="pac")
    acc = float(np.mean(np.argmax(np.asarray(logits), axis=1) == y))
    assert acc > 0.5, f"PAC accuracy {acc} barely above chance"
