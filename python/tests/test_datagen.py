"""Synthetic dataset determinism + sanity."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.datagen import INPUT_PARAMS, generate, write_dataset_bin


def test_deterministic():
    a_x, a_y = generate(16, hw=16, seed=5)
    b_x, b_y = generate(16, hw=16, seed=5)
    np.testing.assert_array_equal(a_x, b_x)
    np.testing.assert_array_equal(a_y, b_y)


def test_different_seeds_differ():
    a_x, _ = generate(4, hw=16, seed=1)
    b_x, _ = generate(4, hw=16, seed=2)
    assert not np.array_equal(a_x, b_x)


def test_shapes_and_ranges():
    x, y = generate(20, hw=32, n_classes=10, seed=3)
    assert x.shape == (20, 3, 32, 32)
    assert x.dtype == np.float32
    assert float(x.min()) >= 0.0 and float(x.max()) <= 1.0
    assert y.dtype == np.uint8
    assert set(np.unique(y)) <= set(range(10))


def test_labels_balanced():
    _, y = generate(1000, hw=16, n_classes=10, seed=4)
    counts = np.bincount(y, minlength=10)
    assert counts.min() >= 80 and counts.max() <= 120


def test_100_class_variant():
    x, y = generate(400, hw=16, n_classes=100, seed=6)
    assert set(np.unique(y)) <= set(range(100))
    assert len(np.unique(y)) > 80


def test_classes_are_separable_by_mean_profile():
    # Crude separability check: per-class mean images must differ clearly
    # (a CNN will find much more).
    x, y = generate(400, hw=16, n_classes=10, seed=7)
    means = np.stack([x[y == k].mean(axis=0).ravel() for k in range(10)])
    d = np.linalg.norm(means[:, None, :] - means[None, :, :], axis=2)
    off_diag = d[~np.eye(10, dtype=bool)]
    assert off_diag.min() > 0.5, float(off_diag.min())


def test_dataset_bin_roundtrip(tmp_path):
    x, y = generate(8, hw=16, n_classes=10, seed=9)
    xq = INPUT_PARAMS.quantize(x)
    p = tmp_path / "ds.bin"
    write_dataset_bin(p, xq, y, 10)
    raw = p.read_bytes()
    assert raw[:4] == b"PACD"
    n, c, h, w, ncls = np.frombuffer(raw[8:28], np.uint32)
    assert (n, c, h, w, ncls) == (8, 3, 16, 16, 10)
    imgs = np.frombuffer(raw[36:36 + 8 * 3 * 16 * 16], np.uint8)
    np.testing.assert_array_equal(imgs, xq.ravel())
