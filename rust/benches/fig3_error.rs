//! Fig. 3 — Approximate error analysis.
//!
//! (a) per-bit-index weight/activation sparsity of the trained quantized
//!     model (paper: quantized ResNet-18 on CIFAR-100; ours: the trained
//!     tiny_resnet — substitution in DESIGN.md §3);
//! (b) distribution of actual MAC outputs vs the PAC expectation at
//!     DP 1024 (paper: RMSE ≈ 6 LSB, ~68% within 1 RMSE);
//! (c) RMSE(%) vs DP length 16→4096 with the n^-1/2 law and the ≈64
//!     crossover against the ~4% competitor error line.

#[path = "harness.rs"]
mod harness;

use harness::{banner, row, try_artifacts, Checks};
use pacim::nn::{run_model_with, ExactBackend, MacBackend, ModelScratch, Op, ProfilingBackend};
use pacim::pac::error_analysis::{
    mac_distribution, rmse_scaling_exponent, rmse_vs_dp_length, theoretical_rmse_lsb,
};
use pacim::util::Parallelism;

fn main() {
    banner("Fig. 3", "PAC approximate error analysis");
    let mut checks = Checks::new();

    // ---- (a) sparsity profile -------------------------------------------
    println!("  (a) bit-level sparsity by bit index (profiled through the engine:");
    println!("      every im2col DP vector of every layer, 16 test images)");
    if let Some((_, model, ds)) = try_artifacts() {
        // Profile the real intermediate activations as the CiM array sees
        // them, via the profiling backend wrapper.
        let mut prof = ProfilingBackend::new(ExactBackend::default());
        {
            let mut id = 0;
            for op in &model.ops {
                match op {
                    Op::Conv2d(c) => {
                        prof.prepare(id, &c.weight, c.wparams.zero_point);
                        id += 1;
                    }
                    Op::Linear(l) => {
                        prof.prepare(id, &l.weight, l.wparams.zero_point);
                        id += 1;
                    }
                    _ => {}
                }
            }
        }
        prof.name_layers(&model);
        let mut scratch = ModelScratch::default();
        for i in 0..16.min(ds.n) {
            run_model_with(&model, &prof, ds.image(i), &Parallelism::off(), &mut scratch)
                .expect("profiling pass executes");
        }
        let wr = prof.aggregate_w_rates();
        let xr = prof.aggregate_x_rates();
        println!("      bit:      7     6     5     4     3     2     1     0");
        print!("      weight: ");
        for p in (0..8).rev() {
            print!("{:5.2} ", wr[p]);
        }
        println!();
        print!("      activ.: ");
        for p in (0..8).rev() {
            print!("{:5.2} ", xr[p]);
        }
        println!();
        println!("\n      per-layer activation sparsity (mean over bits 0..6):");
        for lp in prof.profiles() {
            let r = lp.x_rates();
            let mean: f64 = r[..7].iter().sum::<f64>() / 7.0;
            println!("        {:<16} {:.3}", lp.name, mean);
        }
        // Paper: weight sparsity ~0.25-0.7 across bits; activation
        // sparsity 0-0.3 (ReLU features are mostly small/zero).
        let w_in_band = (0..8).filter(|&p| (0.2..=0.75).contains(&wr[p])).count();
        checks.claim(w_in_band >= 6, "weight bit-sparsity within the paper's 0.25-0.7 band");
        let x_low = (0..8).filter(|&p| xr[p] <= 0.45).count();
        checks.claim(x_low >= 7, "activation bit-sparsity low (paper band 0-0.3)");
    }

    // ---- (b) MAC distribution at DP 1024 --------------------------------
    println!("\n  (b) MAC distribution, DP=1024, Sw=0.5/Sx=0.3, 100K iters");
    let d = mac_distribution(1024, 0.5, 0.3, 100_000, 42);
    println!("      E[MAC] = {:.1}", d.expected);
    println!("      {}", d.histogram.sparkline(56));
    row("RMSE (LSB)", "~6", &format!("{:.2}", d.rmse_lsb));
    row("fraction within ±1 RMSE", ">68% (~0.6% dev)", &format!("{:.1}%", d.within_1_rmse * 100.0));
    let theory = theoretical_rmse_lsb(1024, 0.3, 0.5);
    row("hypergeometric theory (LSB)", "-", &format!("{theory:.2}"));
    checks.claim((4.5..8.0).contains(&d.rmse_lsb), "RMSE ≈ 6 LSB at DP 1024");
    checks.claim((0.6..0.76).contains(&d.within_1_rmse), "~68% of MACs within 1 RMSE");
    checks.claim((d.rmse_lsb - theory).abs() / theory < 0.1, "Monte-Carlo matches theory <10%");

    // ---- (c) RMSE vs DP length ------------------------------------------
    println!("\n  (c) RMSE(%) vs DP length (20K iters each)");
    let dps = [16usize, 32, 64, 128, 256, 512, 1024, 2048, 4096];
    let res = rmse_vs_dp_length(&dps, 0.5, 0.3, 20_000, 7);
    for r in &res {
        let bar = "#".repeat((r.rmse_pct * 8.0).min(60.0) as usize);
        println!("      DP {:>5}: {:6.3}%  {}", r.dp_len, r.rmse_pct, bar);
    }
    let slope = rmse_scaling_exponent(&res);
    row("scaling exponent (log-log fit)", "-0.5 (n^-1/2)", &format!("{slope:.3}"));
    let at64 = res.iter().find(|r| r.dp_len == 64).unwrap().rmse_pct;
    let at128 = res.iter().find(|r| r.dp_len == 128).unwrap().rmse_pct;
    row("crossover vs ~4% competitors", "DP ≈ 64", &format!("{at64:.2}% @64, {at128:.2}% @128"));
    checks.claim((-0.56..=-0.44).contains(&slope), "n^-1/2 scaling law");
    checks.claim(at64 < 4.6 && at128 < 4.0, "crossover at DP ≈ 64 vs 4% line");
    checks.claim(res.last().unwrap().rmse_pct < 0.4, "RMSE < 0.4% at DP 4096");
    checks.finish("Fig. 3");
}
