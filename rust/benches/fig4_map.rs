//! Fig. 4 — the digital-sparsity computing map, including the dynamic
//! workload levels (gray squares) and the LSB-column elimination that
//! distinguishes operand-based from shift-based hybrid splits.

#[path = "harness.rs"]
mod harness;

use harness::{banner, row, Checks};
use pacim::pac::compute_map::DynamicLevel;
use pacim::pac::ComputeMap;

fn main() {
    banner("Fig. 4", "Computing map of the PACiM architecture");
    let mut checks = Checks::new();

    let base = ComputeMap::operand_based(4, 4);
    println!("  operand-based 4x4 map (D = digital, s = sparsity):");
    for line in base.render().lines() {
        println!("    {line}");
    }
    row("digital cycles (static 4-bit)", "16/64", &format!("{}/64", base.digital_cycles()));
    row(
        "cycle reduction vs digital",
        "75%",
        &format!("{}%", 100 * (64 - base.digital_cycles()) / 64),
    );
    row(
        "weight memory columns kept",
        "4 MSB (LSB removed)",
        &format!("{:?}", base.required_weight_bits()),
    );

    println!("\n  dynamic workload levels (§5):");
    for lvl in DynamicLevel::all() {
        let m = lvl.map();
        println!(
            "    {:>2} digital cycles -> reduction {:4.1}%  map {:?}",
            m.digital_cycles(),
            100.0 * (1.0 - m.digital_cycles() as f64 / 64.0),
            m.digital_set().iter().map(|&(p, q)| 10 * p + q).collect::<Vec<_>>()
        );
    }

    let shift = ComputeMap::shift_based(10);
    println!("\n  traditional shift-order split (for contrast): keeps {} weight columns",
             shift.required_weight_bits().len());

    checks.claim(base.digital_cycles() == 16, "4x4 operand split = 16 digital cycles");
    checks.claim(
        base.required_weight_bits() == vec![4, 5, 6, 7],
        "4 LSB weight columns eliminated",
    );
    checks.claim(
        DynamicLevel::all().iter().all(|l| l.map().is_digital(7, 7)),
        "MSBxMSB cycle retained at every dynamic level",
    );
    checks.claim(shift.required_weight_bits().len() > 4,
        "shift-based split cannot remove LSB columns (operand-based advantage)");
    checks.finish("Fig. 4");
}
