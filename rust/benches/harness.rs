//! Shared bench harness (criterion is unavailable offline; `cargo bench`
//! runs these as `harness = false` binaries).
//!
//! Each bench regenerates one table/figure of the paper and prints the
//! paper-reported value next to the measured one. `timeit` provides
//! criterion-style micro-timing for the perf bench.

#![allow(dead_code)]

use std::time::Instant;

/// Print a bench banner.
pub fn banner(id: &str, title: &str) {
    println!("\n================================================================");
    println!("  {id} — {title}");
    println!("================================================================");
}

/// Print a paper-vs-measured row.
pub fn row(label: &str, paper: &str, measured: &str) {
    println!("  {label:<42} paper: {paper:>14}   ours: {measured:>14}");
}

/// Simple check reporting (benches should not panic mid-table; they
/// collect failures and exit non-zero at the end).
pub struct Checks {
    failures: Vec<String>,
}

impl Checks {
    pub fn new() -> Self {
        Self { failures: Vec::new() }
    }

    pub fn claim(&mut self, ok: bool, what: &str) {
        if ok {
            println!("  [ok]   {what}");
        } else {
            println!("  [FAIL] {what}");
            self.failures.push(what.to_string());
        }
    }

    pub fn finish(self, id: &str) {
        if self.failures.is_empty() {
            println!("  => {id}: all qualitative claims reproduced\n");
        } else {
            println!("  => {id}: {} claim(s) FAILED: {:?}\n", self.failures.len(), self.failures);
            std::process::exit(1);
        }
    }
}

/// Quick-mode flag for CI smoke runs (`PACIM_BENCH_QUICK=1` shrinks
/// image counts and repetitions to seconds) — shared by every bench
/// that offers a reduced sweep.
pub fn quick_mode() -> bool {
    std::env::var("PACIM_BENCH_QUICK")
        .ok()
        .is_some_and(|v| v != "0" && !v.is_empty())
}

/// Micro-timing: median of `reps` runs of `f`, returning (median_s, out).
pub fn timeit<T>(reps: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    assert!(reps >= 1);
    let mut times = Vec::with_capacity(reps);
    let mut out = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        out = Some(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (times[times.len() / 2], out.unwrap())
}

/// Throughput pretty-printer.
pub fn rate(n: f64, seconds: f64, unit: &str) -> String {
    let r = n / seconds;
    if r > 1e9 {
        format!("{:.2} G{unit}/s", r / 1e9)
    } else if r > 1e6 {
        format!("{:.2} M{unit}/s", r / 1e6)
    } else if r > 1e3 {
        format!("{:.2} k{unit}/s", r / 1e3)
    } else {
        format!("{r:.2} {unit}/s")
    }
}

/// Load artifacts if present (accuracy benches degrade gracefully).
pub fn try_artifacts() -> Option<(
    pacim::runtime::Manifest,
    pacim::nn::Model,
    pacim::workload::Dataset,
)> {
    let dir = pacim::runtime::manifest::artifacts_dir();
    let man = match pacim::runtime::Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            println!("  (artifacts not built: {e}; skipping measured rows)");
            return None;
        }
    };
    let store = pacim::nn::WeightStore::load(man.path("weights").ok()?).ok()?;
    let ds = pacim::workload::Dataset::load(man.path("dataset").ok()?).ok()?;
    let model = pacim::nn::tiny_resnet(&store, ds.h, ds.n_classes).ok()?;
    Some((man, model, ds))
}

/// Build an exact-backend engine for `model` (benches abort on the
/// typed error — a bench target has no caller to hand it to).
pub fn engine_exact(model: &pacim::nn::Model) -> pacim::engine::Engine {
    pacim::engine::EngineBuilder::new(model.clone())
        .exact()
        .build()
        .expect("bench model is valid")
}

/// Build a PAC-backend engine for `model` under `cfg`.
pub fn engine_pac(model: &pacim::nn::Model, cfg: pacim::nn::PacConfig) -> pacim::engine::Engine {
    pacim::engine::EngineBuilder::new(model.clone())
        .pac(cfg)
        .build()
        .expect("bench model/config is valid")
}

/// Evaluate accuracy over the first `n` dataset images through the
/// engine front door.
pub fn eval_accuracy(
    engine: &pacim::engine::Engine,
    ds: &pacim::workload::Dataset,
    n: usize,
) -> (f64, pacim::nn::RunStats) {
    let n = n.min(ds.n);
    let images: Vec<&[u8]> = (0..n).map(|i| ds.image(i)).collect();
    let labels: Vec<usize> = (0..n).map(|i| ds.label(i)).collect();
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let ev = engine
        .evaluate(&images, &labels, threads)
        .expect("bench inputs are pre-validated");
    (ev.accuracy, ev.stats)
}
