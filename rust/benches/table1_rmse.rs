//! Table 1 — "Error of State-of-the-Art Approximate Methods".
//!
//! Measures the binary-MAC-cycle RMSE (%) of each behavioral baseline and
//! of PAC under a common Monte-Carlo protocol (DP 1024, typical sparsity),
//! plus the PAC band over DP 512–4096 (the paper's note d).

#[path = "harness.rs"]
mod harness;

use harness::{banner, row, Checks};
use pacim::baselines::{
    measure_rmse_pct, AnalogLsb, ApproxAdderTree, ExactDigital, OsaHcim, PacMethod,
};
use pacim::pac::PcuRounding;

const N: usize = 1024;
const ITERS: u64 = 20_000;
const SX: f64 = 0.3;
const SW: f64 = 0.5;

fn main() {
    banner("Table 1", "RMSE of approximate methods (DP=1024, Sx=0.3, Sw=0.5)");
    let mut checks = Checks::new();

    let exact = measure_rmse_pct(&ExactDigital, N, SX, SW, 1000, 1);
    let adder = measure_rmse_pct(&ApproxAdderTree::calibrated(N, 0.04), N, SX, SW, ITERS, 2);
    let diana = measure_rmse_pct(&AnalogLsb::diana(N), N, SX, SW, ITERS, 3);
    let osa = measure_rmse_pct(&OsaHcim { dp_len: N }, N, SX, SW, ITERS, 4);
    let pac = measure_rmse_pct(
        &PacMethod { rounding: PcuRounding::RoundNearest },
        N, SX, SW, ITERS, 5,
    );

    row("D-CiM (exact reference)", "0", &format!("{exact:.3}%"));
    row("Approximate adder tree (ISSCC'22 [29])", "4.0/6.8%", &format!("{adder:.2}%"));
    row("Analog + ADC (ISSCC'22 [26], DIANA)", "3.5-4.8%", &format!("{diana:.2}%"));
    row("Hybrid CiM (ASP-DAC'24 [4], OSA-HCIM)", "8.5%", &format!("{osa:.2}%"));
    row("PAC / sparsity (this work)", "0.3-1.0%", &format!("{pac:.3}%"));

    println!("\n  PAC band over the paper's DP range (note d):");
    let mut band = Vec::new();
    for (i, &dp) in [512usize, 1024, 2048, 4096].iter().enumerate() {
        let r = measure_rmse_pct(
            &PacMethod { rounding: PcuRounding::RoundNearest },
            dp, SX, SW, ITERS, 10 + i as u64,
        );
        println!("    DP {dp:>5}: {r:.3}%");
        band.push(r);
    }

    checks.claim(exact == 0.0, "exact digital reference has zero error");
    checks.claim((0.2..1.0).contains(&pac), "PAC RMSE in the 0.3-1.0% band at DP 1024");
    checks.claim(band.iter().all(|&r| r < 1.05), "PAC < ~1% across DP 512-4096");
    checks.claim(band.windows(2).all(|w| w[1] < w[0]), "PAC RMSE decreases with DP length");
    checks.claim(adder / pac >= 4.0, "PAC >= 4x better than the approximate adder tree");
    checks.claim(diana / pac >= 4.0, "PAC >= 4x better than analog H-CiM");
    checks.claim(osa > diana && diana > pac, "error ordering OSA > DIANA > PAC holds");
    checks.finish("Table 1");
}
