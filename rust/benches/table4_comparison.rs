//! Table 4 — comparison with state-of-the-art CiM designs.
//!
//! Cited rows are reproduced verbatim (they are other groups' silicon);
//! the PACiM column is recomputed from our models: efficiency from the
//! energy composition, accuracy from the accuracy benches, memory-access
//! reduction from the traffic model — and the qualitative ranking claims
//! are asserted.

#[path = "harness.rs"]
mod harness;

use harness::{banner, eval_accuracy, Checks};
use pacim::coordinator::{schedule_model, ScheduleConfig};
use pacim::energy::EnergyModel;
use pacim::nn::PacConfig;
use pacim::workload::{resnet18, Resolution};

struct Row {
    name: &'static str,
    kind: &'static str,
    node: &'static str,
    peak_tops_w: f64,
    mem_red: Option<f64>,
}

fn main() {
    banner("Table 4", "Comparison with state-of-the-art CiM designs");
    let mut checks = Checks::new();
    let em = EnergyModel::default();

    let ours_peak = em.pacim_peak().tops_w_1b;
    let shapes = resnet18(Resolution::Cifar, 10);
    let rep = schedule_model(&shapes, &ScheduleConfig::pacim_default());
    let mem_red = rep.act_traffic_reduction();

    let rows = [
        Row {
            name: "ISSCC'21 [6]",
            kind: "Digital",
            node: "22nm",
            peak_tops_w: 163.13,
            mem_red: None,
        },
        Row {
            name: "ISSCC'22 [29]",
            kind: "Approximate",
            node: "28nm",
            peak_tops_w: 2219.0,
            mem_red: None,
        },
        Row {
            name: "ISSCC'22 [26]",
            kind: "Digital-Analog",
            node: "22nm",
            peak_tops_w: 74.88,
            mem_red: None,
        },
        Row {
            name: "ASP-DAC'24 [4]",
            kind: "Digital-Analog",
            node: "65nm",
            peak_tops_w: 370.56,
            mem_red: None,
        },
        Row {
            name: "ISSCC'24 [35]",
            kind: "Analog",
            node: "65nm",
            peak_tops_w: 4094.0,
            mem_red: None,
        },
        Row {
            name: "This work (PACiM)",
            kind: "Digital-Sparsity",
            node: "65nm",
            peak_tops_w: ours_peak,
            mem_red: Some(mem_red),
        },
    ];
    println!(
        "  {:<20} {:<16} {:<6} {:>14} {:>12}",
        "design", "type", "node", "peak TOPS/W*", "mem red."
    );
    for r in &rows {
        println!(
            "  {:<20} {:<16} {:<6} {:>14.2} {:>12}",
            r.name,
            r.kind,
            r.node,
            r.peak_tops_w,
            r.mem_red.map_or("NO".into(), |m| format!("{:.0}-50%", m * 100.0))
        );
    }
    println!("  (* 1b/1b-normalized, 65nm; cited rows are the papers' reported numbers)");

    // Accuracy rows (ours measured on the synthetic substitution).
    if let Some((_, model, ds)) = harness::try_artifacts() {
        let exact = harness::engine_exact(&model);
        let (acc8, _) = eval_accuracy(&exact, &ds, 256);
        let pac = harness::engine_pac(&model, PacConfig::default());
        let (acc4, _) = eval_accuracy(&pac, &ds, 256);
        println!(
            "\n  accuracy (synthetic-10 substitution): exact {:.2}%  PAC {:.2}%",
            acc8 * 100.0,
            acc4 * 100.0
        );
        println!(
            "  paper accuracy row: CIFAR-10 93.85 / CIFAR-100 72.36 / ImageNet 66.02 (ResNet-18)"
        );
        checks.claim(acc4 > 0.85, "PACiM accuracy stays high under approximation");
    }

    // Qualitative ranking claims from §6.2.
    let hcim_best = 370.56;
    checks.claim(ours_peak / hcim_best > 2.5, "≈4x over digital-analog H-CiM (ours/370 > 2.5x)");
    checks.claim(ours_peak > 163.13, "beats the all-digital macro");
    checks.claim(ours_peak < 4094.0, "analog macros remain ahead at low precision (as in paper)");
    checks.claim(
        rows[..5].iter().all(|r| r.mem_red.is_none()),
        "PACiM is the only design reducing memory access",
    );
    checks.claim((0.38..0.52).contains(&mem_red), "memory access reduction in the 40-50% band");
    checks.finish("Table 4");
}
