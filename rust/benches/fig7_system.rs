//! Fig. 7 — system analysis of PACiM.
//!
//! (a) bit-serial cycle reduction (64 → 16 static → ~12 dynamic);
//! (b) cache-access reduction vs channel length (40% @64ch → 50% deep);
//! (c) single-bank area/power breakdown (CnM ≈ 10% area / 30% power;
//!     buffer >50% of CnM area, ~70% of its power);
//! (d) **measured** activation traffic of the sparsity-encoded
//!     dataplane: run a ResNet-18-width network through the PAC engine
//!     and read `RunStats::traffic` — the workload-measured version of
//!     (b), now covering the residual save/add edges the fused
//!     dataplane encodes (DESIGN.md §12), cross-checked row by row
//!     against the analytic model and exported to `BENCH_traffic.json`
//!     (CI gates the ≥44% deep payload-edge floor behind
//!     `PACIM_ENFORCE_TRAFFIC_REDUCTION`);
//! (e) the traffic-priced multibank schedule (DESIGN.md §14): the λ
//!     knob trading buffer-spill bits for digital replay cycles on the
//!     same ResNet-18 shapes — the per-λ Pareto sweep lives in
//!     `pacim tune` / `BENCH_tune.json`.

#[path = "harness.rs"]
mod harness;

use harness::{banner, quick_mode, row, Checks};
use pacim::coordinator::{schedule_model, ScheduleConfig};
use pacim::energy::area::AreaModel;
use pacim::memory::traffic::{activation_traffic, reduction_vs_channels};
use pacim::util::benchfmt::{TrafficLayerBench, TrafficReport};
use pacim::workload::{resnet18, Resolution};

fn main() {
    banner("Fig. 7", "System analysis: cycles, memory access, area/power");
    let mut checks = Checks::new();
    let shapes = resnet18(Resolution::Cifar, 10);

    // ---- (a) bit-serial cycles -------------------------------------------
    println!("  (a) bit-serial cycles on ResNet-18 (CIFAR shapes)");
    let dig = schedule_model(&shapes, &ScheduleConfig::digital_baseline());
    let stat = schedule_model(&shapes, &ScheduleConfig::pacim_default());
    let dyn_ = schedule_model(&shapes, &ScheduleConfig::pacim_dynamic());
    let (cd, cs, cy) = (
        dig.total_macs_cycles(),
        stat.total_macs_cycles(),
        dyn_.total_macs_cycles(),
    );
    row("digital 8b/8b cycles", "1.00x", &format!("{cd}"));
    row(
        "PACiM static 4-bit",
        "-75%",
        &format!("{cs} ({:+.1}%)", 100.0 * (cs as f64 / cd as f64 - 1.0)),
    );
    row("PACiM dynamic", "-81%", &format!("{cy} ({:+.1}%)", 100.0 * (cy as f64 / cd as f64 - 1.0)));
    checks.claim((cs as f64 / cd as f64 - 0.25).abs() < 1e-9, "static map removes 75% of cycles");
    checks.claim(
        (cy as f64 / cd as f64 - 0.1875).abs() < 1e-9,
        "dynamic config removes 81% of cycles",
    );

    // ---- (b) memory access vs channel length -----------------------------
    println!("\n  (b) activation cache-access reduction vs channel length (4-bit MSB)");
    let rs = reduction_vs_channels(&[16, 32, 64, 128, 256, 512, 1024, 2048], 4);
    for (c, r) in &rs {
        let bar = "#".repeat((r * 80.0).max(0.0) as usize);
        println!("      C={c:<5} {:5.1}%  {bar}", r * 100.0);
    }
    let at64 = rs.iter().find(|(c, _)| *c == 64).unwrap().1;
    let deep = rs.last().unwrap().1;
    row("reduction @ 64 channels", "40%", &format!("{:.1}%", at64 * 100.0));
    row("reduction, deep layers", "up to 50%", &format!("{:.1}%", deep * 100.0));
    checks.claim((0.37..0.45).contains(&at64), "≈40% reduction at 64 channels");
    checks.claim(deep > 0.47, "approaches 50% in deep layers");
    let whole = schedule_model(&shapes, &ScheduleConfig::pacim_default());
    let net_red = whole.act_traffic_reduction();
    row("whole-net activation traffic (ResNet-18)", "40-50%", &format!("{:.1}%", net_red * 100.0));
    checks.claim((0.38..0.52).contains(&net_red), "whole-network reduction in the 40-50% band");

    // ---- (c) area / power breakdown ---------------------------------------
    println!("\n  (c) single-bank area/power breakdown (65nm calibration)");
    let am = AreaModel::default();
    let b = am.breakdown();
    let total_area: f64 = b.area_um2.iter().map(|(_, a)| a).sum();
    for ((name, a), (_, p)) in b.area_um2.iter().zip(&b.power_frac) {
        println!(
            "      {name:<14} area {:8.0} um2 ({:4.1}%)   power {:4.1}%",
            a,
            100.0 * a / total_area,
            p * 100.0
        );
    }
    let cnm_area: f64 = b
        .area_um2
        .iter()
        .filter(|(n, _)| n.starts_with("CnM"))
        .map(|(_, a)| a)
        .sum();
    let cnm_power: f64 = b
        .power_frac
        .iter()
        .filter(|(n, _)| n.starts_with("CnM"))
        .map(|(_, p)| p)
        .sum();
    row("CnM area share", "10%", &format!("{:.1}%", 100.0 * cnm_area / total_area));
    row("CnM power share", "30%", &format!("{:.1}%", cnm_power * 100.0));
    let buf_area = b.area_um2.iter().find(|(n, _)| *n == "CnM buffer").unwrap().1;
    let buf_power = b.power_frac.iter().find(|(n, _)| *n == "CnM buffer").unwrap().1;
    row("buffer share of CnM area", ">50%", &format!("{:.1}%", 100.0 * buf_area / cnm_area));
    row("buffer share of CnM power", "70%", &format!("{:.1}%", 100.0 * buf_power / cnm_power));
    checks.claim((100.0 * cnm_area / total_area - 10.0).abs() < 0.5, "CnM ≈ 10% of bank area");
    checks.claim((cnm_power - 0.30).abs() < 1e-9, "CnM ≈ 30% of bank power");
    checks.claim(buf_area / cnm_area > 0.5, "buffer > 50% of CnM area");
    checks.claim((buf_power / cnm_power - 0.70).abs() < 1e-9, "buffer ≈ 70% of CnM power");
    row("multi-bank CnM area (buffer removed)", "most of buffer gone",
        &format!("{:.0} um2 vs {:.0}", am.multibank_cnm_um2(), am.cnm_total_um2()));

    // ---- (d) measured dataplane traffic -----------------------------------
    measured_traffic_section(quick_mode(), &mut checks);

    // ---- (e) traffic-priced multibank scheduling (λ knob) -----------------
    println!("\n  (e) traffic-priced multibank schedule on ResNet-18 (DESIGN.md §14)");
    let cfg = pacim::arch::MultiBankConfig { banks: 4, rows: 256, mwcs: 64 };
    for lambda in [0.005, 0.02] {
        let c = pacim::arch::compare_lambda(&shapes, "resnet18-cifar", &cfg, lambda, 16.0);
        row(
            &format!("lambda = {lambda}"),
            "fewer bits, bounded cycles",
            &format!(
                "bits {:+.1}%  cycles {:+.1}%  ({} replayed)",
                100.0 * (c.bits_priced as f64 / c.bits_cycles_only as f64 - 1.0),
                100.0 * (c.cycles_priced as f64 / c.cycles_cycles_only as f64 - 1.0),
                c.replayed_layers
            ),
        );
        checks.claim(
            c.bits_priced < c.bits_cycles_only,
            "the priced schedule moves strictly fewer bits",
        );
        checks.claim(
            c.cycles_priced as f64
                <= c.cycles_cycles_only as f64 * pacim::util::benchfmt::TUNE_CYCLE_BOUND,
            "the cycle premium stays inside the tune gate's bound",
        );
    }
    checks.finish("Fig. 7");
}

/// Run a ResNet-18-width network (64→128→256 channels, the CIFAR
/// ResNet-18 ladder) through the PAC engine and report what the
/// sparsity-encoded dataplane *actually moved*, edge by edge, next to
/// the closed-form prediction for the same geometry. Since the fused
/// residual dataplane landed, the ledger also carries the skip-slot
/// save, add-in, and post-add edges of every residual block — the save
/// rows honestly cost bits (8 planes + counters vs an 8-bit copy), the
/// add-in rows are eliminated outright, and the triple nets out
/// strictly below the dense round-trip.
fn measured_traffic_section(quick: bool, checks: &mut Checks) {
    use pacim::engine::EngineBuilder;
    use pacim::nn::layers::synthetic::random_store;
    use pacim::nn::{tiny_resnet, PacConfig};
    use pacim::util::rng::Rng;

    println!("\n  (d) measured sparsity-encoded dataplane traffic (PAC engine run)");
    let mut rng = Rng::new(7077);
    let hw = if quick { 16 } else { 32 };
    let images = if quick { 1usize } else { 4 };
    let model = tiny_resnet(&random_store(&mut rng, 64, 10), hw, 10)
        .expect("synthetic model is valid");
    let model_name = model.name.clone();
    // Paper-default config: first layer digital, PAC above DP 512, the
    // encoded dataplane on — exactly what `pacim accuracy` runs.
    let engine = EngineBuilder::new(model)
        .pac(PacConfig {
            par: pacim::util::Parallelism::off(),
            ..PacConfig::default()
        })
        .build()
        .expect("synthetic model builds");
    let mut session = engine.session();
    let mut stats = pacim::nn::RunStats::default();
    for _ in 0..images {
        let img: Vec<u8> = (0..engine.input_elems()).map(|_| rng.below(256) as u8).collect();
        stats.merge(&session.infer(&img).expect("inference succeeds").stats);
    }
    let ledger = &stats.traffic;

    // Analytic cross-check per edge: groups from the layer geometry,
    // bits from the `memory::traffic` closed form for the encode
    // decision the executor actually took.
    let geoms = engine.model().compute_layers();
    let mut rows = Vec::new();
    let (mut res_bits, mut res_base) = (0u64, 0u64);
    for (name, e) in engine.traffic_rows(ledger) {
        let (_, g) = geoms[e.layer_id];
        let analytic_groups = g.out_pixels() as u64 * images as u64;
        let analytic_bits = if e.is_eliminated() {
            // Encoded residual_in edges never touch the buffer: the
            // epilogue reads the skip slot's planes in place.
            0
        } else if e.encoded {
            analytic_groups * activation_traffic(g.out_c, e.msb_bits).pacim
        } else {
            analytic_groups * g.out_c as u64 * 8
        };
        let kind = e.kind.as_str();
        if kind.starts_with("residual") {
            res_bits += e.bits;
            res_base += e.baseline_bits;
        }
        let deep = e.group_elems as usize >= pacim::util::benchfmt::TRAFFIC_DEEP_CHANNELS;
        println!(
            "      {name:<16} {kind:<13} {:>4} ch  {:>9} -> {:>9} bits  {}{:6.1}%",
            e.group_elems,
            e.baseline_bits,
            e.bits,
            if e.encoded { "encoded " } else { "dense   " },
            e.reduction() * 100.0
        );
        rows.push(TrafficLayerBench {
            layer: name.to_string(),
            kind: kind.to_string(),
            channels: e.group_elems as usize,
            groups: e.groups,
            baseline_bits: e.baseline_bits,
            measured_bits: e.bits,
            analytic_bits,
            reduction: e.reduction(),
            encoded: e.encoded,
            deep,
        });
    }
    let deep_min = rows
        .iter()
        .filter(|r| r.deep && r.encoded && pacim::util::benchfmt::traffic_payload_row(r))
        .map(|r| r.reduction)
        .fold(f64::INFINITY, f64::min);
    row(
        "deep encoded payload edges (>=128 ch)",
        "40-50%",
        &format!("min {:.1}%", deep_min * 100.0),
    );
    row(
        "residual save/add edges vs dense round-trip",
        "strictly fewer bits",
        &format!("{res_bits} vs {res_base}"),
    );
    row(
        "whole-net measured (all edges)",
        "<= analytic",
        &format!("{:.1}%", ledger.reduction() * 100.0),
    );
    checks.claim(
        rows.iter().all(|r| r.measured_bits == r.analytic_bits),
        "measured ledger matches the analytic traffic model on every edge",
    );
    checks.claim(
        deep_min.is_finite() && (0.40..0.52).contains(&deep_min),
        "deep encoded payload edges land in the paper's 40-50% band",
    );
    checks.claim(
        ledger.encoded_layer_count() == 14,
        "14 of 15 edges moved encoded (only the add->GAP handoff is dense)",
    );
    checks.claim(
        rows.iter()
            .filter(|r| r.kind == "residual_in")
            .all(|r| r.encoded && r.measured_bits == 0),
        "every fused add-in edge is eliminated outright",
    );
    checks.claim(
        res_base > 0 && res_bits < res_base,
        "the fused residual triple beats the dense save/add round-trip",
    );

    let report = TrafficReport {
        bench: "traffic".into(),
        quick,
        model: model_name,
        images,
        layers: rows,
        encoded_layers: ledger.encoded_layer_count(),
        deep_encoded_min_reduction: deep_min,
        network_reduction: ledger.reduction(),
    };
    match serde_json::to_string_pretty(&report)
        .map_err(anyhow::Error::from)
        .and_then(|s| std::fs::write("BENCH_traffic.json", s).map_err(anyhow::Error::from))
    {
        Ok(()) => println!("      wrote BENCH_traffic.json"),
        Err(e) => println!("      could not write BENCH_traffic.json: {e}"),
    }
}
