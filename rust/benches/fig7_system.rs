//! Fig. 7 — system analysis of PACiM.
//!
//! (a) bit-serial cycle reduction (64 → 16 static → ~12 dynamic);
//! (b) cache-access reduction vs channel length (40% @64ch → 50% deep);
//! (c) single-bank area/power breakdown (CnM ≈ 10% area / 30% power;
//!     buffer >50% of CnM area, ~70% of its power).

#[path = "harness.rs"]
mod harness;

use harness::{banner, row, Checks};
use pacim::coordinator::{schedule_model, ScheduleConfig};
use pacim::energy::area::AreaModel;
use pacim::memory::traffic::reduction_vs_channels;
use pacim::workload::{resnet18, Resolution};

fn main() {
    banner("Fig. 7", "System analysis: cycles, memory access, area/power");
    let mut checks = Checks::new();
    let shapes = resnet18(Resolution::Cifar, 10);

    // ---- (a) bit-serial cycles -------------------------------------------
    println!("  (a) bit-serial cycles on ResNet-18 (CIFAR shapes)");
    let dig = schedule_model(&shapes, &ScheduleConfig::digital_baseline());
    let stat = schedule_model(&shapes, &ScheduleConfig::pacim_default());
    let dyn_ = schedule_model(&shapes, &ScheduleConfig::pacim_dynamic());
    let (cd, cs, cy) = (
        dig.total_macs_cycles(),
        stat.total_macs_cycles(),
        dyn_.total_macs_cycles(),
    );
    row("digital 8b/8b cycles", "1.00x", &format!("{cd}"));
    row(
        "PACiM static 4-bit",
        "-75%",
        &format!("{cs} ({:+.1}%)", 100.0 * (cs as f64 / cd as f64 - 1.0)),
    );
    row("PACiM dynamic", "-81%", &format!("{cy} ({:+.1}%)", 100.0 * (cy as f64 / cd as f64 - 1.0)));
    checks.claim((cs as f64 / cd as f64 - 0.25).abs() < 1e-9, "static map removes 75% of cycles");
    checks.claim(
        (cy as f64 / cd as f64 - 0.1875).abs() < 1e-9,
        "dynamic config removes 81% of cycles",
    );

    // ---- (b) memory access vs channel length -----------------------------
    println!("\n  (b) activation cache-access reduction vs channel length (4-bit MSB)");
    let rs = reduction_vs_channels(&[16, 32, 64, 128, 256, 512, 1024, 2048], 4);
    for (c, r) in &rs {
        let bar = "#".repeat((r * 80.0).max(0.0) as usize);
        println!("      C={c:<5} {:5.1}%  {bar}", r * 100.0);
    }
    let at64 = rs.iter().find(|(c, _)| *c == 64).unwrap().1;
    let deep = rs.last().unwrap().1;
    row("reduction @ 64 channels", "40%", &format!("{:.1}%", at64 * 100.0));
    row("reduction, deep layers", "up to 50%", &format!("{:.1}%", deep * 100.0));
    checks.claim((0.37..0.45).contains(&at64), "≈40% reduction at 64 channels");
    checks.claim(deep > 0.47, "approaches 50% in deep layers");
    let whole = schedule_model(&shapes, &ScheduleConfig::pacim_default());
    let net_red = whole.act_traffic_reduction();
    row("whole-net activation traffic (ResNet-18)", "40-50%", &format!("{:.1}%", net_red * 100.0));
    checks.claim((0.38..0.52).contains(&net_red), "whole-network reduction in the 40-50% band");

    // ---- (c) area / power breakdown ---------------------------------------
    println!("\n  (c) single-bank area/power breakdown (65nm calibration)");
    let am = AreaModel::default();
    let b = am.breakdown();
    let total_area: f64 = b.area_um2.iter().map(|(_, a)| a).sum();
    for ((name, a), (_, p)) in b.area_um2.iter().zip(&b.power_frac) {
        println!(
            "      {name:<14} area {:8.0} um2 ({:4.1}%)   power {:4.1}%",
            a,
            100.0 * a / total_area,
            p * 100.0
        );
    }
    let cnm_area: f64 = b
        .area_um2
        .iter()
        .filter(|(n, _)| n.starts_with("CnM"))
        .map(|(_, a)| a)
        .sum();
    let cnm_power: f64 = b
        .power_frac
        .iter()
        .filter(|(n, _)| n.starts_with("CnM"))
        .map(|(_, p)| p)
        .sum();
    row("CnM area share", "10%", &format!("{:.1}%", 100.0 * cnm_area / total_area));
    row("CnM power share", "30%", &format!("{:.1}%", cnm_power * 100.0));
    let buf_area = b.area_um2.iter().find(|(n, _)| *n == "CnM buffer").unwrap().1;
    let buf_power = b.power_frac.iter().find(|(n, _)| *n == "CnM buffer").unwrap().1;
    row("buffer share of CnM area", ">50%", &format!("{:.1}%", 100.0 * buf_area / cnm_area));
    row("buffer share of CnM power", "70%", &format!("{:.1}%", 100.0 * buf_power / cnm_power));
    checks.claim((100.0 * cnm_area / total_area - 10.0).abs() < 0.5, "CnM ≈ 10% of bank area");
    checks.claim((cnm_power - 0.30).abs() < 1e-9, "CnM ≈ 30% of bank power");
    checks.claim(buf_area / cnm_area > 0.5, "buffer > 50% of CnM area");
    checks.claim((buf_power / cnm_power - 0.70).abs() < 1e-9, "buffer ≈ 70% of CnM power");
    row("multi-bank CnM area (buffer removed)", "most of buffer gone",
        &format!("{:.0} um2 vs {:.0}", am.multibank_cnm_um2(), am.cnm_total_um2()));
    checks.finish("Fig. 7");
}
