//! Fig. 6 — DNN inference accuracy.
//!
//! (a) PAC approximation of the 8-bit model vs native low-bit PTQ
//!     ("QAT" in the paper; we use PTQ-at-b-bits as the low-bit baseline —
//!     DESIGN.md §3) across approximate operand widths;
//! (b) dynamic workload configuration: average bit-serial cycles vs
//!     accuracy across threshold sets.
//!
//! Requires artifacts (skips gracefully otherwise).

#[path = "harness.rs"]
mod harness;

use harness::{banner, engine_exact, engine_pac, eval_accuracy, row, Checks};
use pacim::arch::ThresholdSet;
use pacim::nn::{Model, Op, PacConfig};
use pacim::pac::ComputeMap;

const EVAL_N: usize = 256;

/// Snap a trained uint8 model to b-bit weights+activations (PTQ-at-b):
/// the low-bit baseline of Fig. 6(a).
fn low_bit_model(model: &Model, bits: u32) -> Model {
    let mut m = model.clone();
    let snap = |q: u8, zp: i32| -> u8 {
        // Keep zp representable: quantize the offset from zp on a b-bit
        // grid spanning the uint8 range.
        let step = 1 << (8 - bits);
        let v = q as i32 - zp;
        let snapped = ((v + (step >> 1)) / step) * step;
        (zp + snapped).clamp(0, 255) as u8
    };
    for op in &mut m.ops {
        match op {
            Op::Conv2d(c) => {
                let zp = c.wparams.zero_point;
                for w in c.weight.data_mut() {
                    *w = snap(*w, zp);
                }
            }
            Op::Linear(l) => {
                let zp = l.wparams.zero_point;
                for w in l.weight.data_mut() {
                    *w = snap(*w, zp);
                }
            }
            _ => {}
        }
    }
    m
}

fn main() {
    banner("Fig. 6", "Inference accuracy: PAC vs low-bit baselines; dynamic config");
    let Some((_, model, ds)) = harness::try_artifacts() else {
        println!("  artifacts missing; run `make artifacts` first.");
        return;
    };
    let mut checks = Checks::new();

    let exact = engine_exact(&model);
    let (acc8, _) = eval_accuracy(&exact, &ds, EVAL_N);
    println!("  baseline exact 8b/8b accuracy: {:.2}%  ({} images)", acc8 * 100.0, EVAL_N);

    // ---- (a) operand-width sweep ----------------------------------------
    println!("\n  (a) PAC approximation vs low-bit PTQ (paper: ImageNet/ResNet-18)");
    println!("      paper reference points: PAC-4b 66.02% vs QAT-4b 59.71% (8b = 68.76%)");
    let mut pac_accs = Vec::new();
    let mut ptq_accs = Vec::new();
    for bits in [2u32, 3, 4, 5, 6] {
        let cfg = PacConfig {
            map: ComputeMap::operand_based(bits, bits),
            ..PacConfig::default()
        };
        let pac = engine_pac(&model, cfg);
        let (acc_pac, _) = eval_accuracy(&pac, &ds, EVAL_N);
        let low = low_bit_model(&model, bits);
        let lb = engine_exact(&low);
        let (acc_ptq, _) = eval_accuracy(&lb, &ds, EVAL_N);
        pac_accs.push(acc_pac);
        ptq_accs.push(acc_ptq);
        println!(
            "      {bits}-bit:  PAC {:6.2}%   PTQ-{bits}b {:6.2}%   (8b exact {:5.2}%)",
            acc_pac * 100.0,
            acc_ptq * 100.0,
            acc8 * 100.0
        );
    }
    // Paper's qualitative claims: PAC-4b beats native 4-bit by a wide
    // margin; PAC-5b ~ recovers the 8-bit baseline; PAC accuracy is
    // monotone-ish in operand width.
    let pac4 = pac_accs[2];
    let ptq4 = ptq_accs[2];
    let pac5 = pac_accs[3];
    checks.claim(pac4 > ptq4, "PAC-4b beats native 4-bit quantization");
    checks.claim(acc8 - pac5 < 0.02, "PAC-5b within 1-2% of the 8-bit baseline (paper: <1%)");
    checks.claim(pac_accs[4] >= pac_accs[1], "wider approximate operands do not hurt");

    // ---- (b) dynamic workload configuration ------------------------------
    println!("\n  (b) dynamic workload configuration (paper: avg 12 cycles at <=1% loss)");
    let cfg4 = PacConfig::default();
    let pac4b = engine_pac(&model, cfg4);
    let (acc_static, _) = eval_accuracy(&pac4b, &ds, EVAL_N);
    println!("      static 16-cycle:       acc {:6.2}%", acc_static * 100.0);
    let mut best: Option<(f64, f64)> = None;
    for (th, label) in [
        (ThresholdSet::new(0.03, 0.06, 0.12), "conservative"),
        (ThresholdSet::new(0.06, 0.12, 0.25), "moderate"),
        (ThresholdSet::new(0.10, 0.20, 0.35), "aggressive"),
        (ThresholdSet::new(0.20, 0.35, 0.55), "very aggressive"),
    ] {
        let cfg = PacConfig {
            thresholds: Some(th),
            ..PacConfig::default()
        };
        let pac = engine_pac(&model, cfg);
        let (acc, stats) = eval_accuracy(&pac, &ds, EVAL_N);
        let cycles = stats.levels.average_cycles();
        println!(
            "      {label:<16} acc {:6.2}%  avg digital cycles {:5.2}  (loss {:+.2}%)",
            acc * 100.0,
            cycles,
            (acc - acc_static) * 100.0
        );
        if acc_static - acc <= 0.011 {
            let better = match best {
                Some((c, _)) => cycles < c,
                None => true,
            };
            if better {
                best = Some((cycles, acc));
            }
        }
    }
    if let Some((cycles, acc)) = best {
        row(
            "best <=1%-loss configuration",
            "12 cycles",
            &format!("{cycles:.2} cycles @ {:.2}%", acc * 100.0),
        );
        checks.claim(cycles < 16.0, "dynamic config reduces cycles at <=1% accuracy loss");
        checks.claim(cycles <= 14.5, "reaches <=14.5 avg cycles (paper: 12)");
    } else {
        checks.claim(false, "some threshold set stays within 1% accuracy loss");
    }
    checks.finish("Fig. 6");
}
