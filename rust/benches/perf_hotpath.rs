//! §Perf — hot-path micro/macro benchmarks (EXPERIMENTS.md §Perf).
//!
//! L3 hot paths: BitPlanes decomposition, the full hybrid MAC, the
//! scalar-vs-rayon batched PAC MAC on real ResNet-18 layer shapes (the
//! headline comparison, exported to `BENCH_hotpath.json` for CI trend
//! tracking), the PAC conv backend on a realistic layer, and (with the
//! `pjrt` feature + artifacts) PJRT end-to-end batch latency + serving
//! throughput. Hand-rolled timing (criterion unavailable offline).
//!
//! Quick mode for CI smoke runs: set `PACIM_BENCH_QUICK=1` to shrink
//! batch sizes and repetition counts (~seconds instead of minutes).

#[path = "harness.rs"]
mod harness;

use harness::{banner, quick_mode, rate, timeit, Checks};
use pacim::nn::{GemmInput, MacBackend, PacConfig, RunStats};
use pacim::pac::{
    hybrid_mac, hybrid_mac_batch, par_hybrid_mac_batch, BitPlanes, ComputeMap, PcuRounding,
};
use pacim::tensor::{PackedPatches, Tensor};
use pacim::util::benchfmt::{BlockedBench, FusedBench, HotpathReport, LayerBench, SimdBench};
use pacim::util::rng::Rng;
use pacim::util::{KernelTier, Parallelism};
use pacim::workload::{resnet18, Resolution};

fn main() {
    banner("§Perf", "hot-path throughput");
    let quick = quick_mode();
    let mut rng = Rng::new(77);
    let mut checks = Checks::new();

    // --- BitPlanes decomposition -----------------------------------------
    let v: Vec<u8> = (0..4096).map(|_| rng.below(256) as u8).collect();
    let (t, _) = timeit(30, || BitPlanes::from_u8(&v));
    println!(
        "  BitPlanes::from_u8 (4096 elems):   {:>10.2} us  ({})",
        t * 1e6,
        rate(4096.0, t, "elem")
    );

    // --- hybrid MAC (Eq. 4) -----------------------------------------------
    let map = ComputeMap::operand_based(4, 4);
    for n in [256usize, 1024, 4096] {
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        let (t, _) = timeit(50, || hybrid_mac(&xp, &wp, &map, PcuRounding::RoundNearest));
        println!(
            "  hybrid_mac DP={n:<5}:              {:>10.2} us  ({} MAC-equiv)",
            t * 1e6,
            rate(n as f64, t, "")
        );
    }

    // --- batched PAC MAC: scalar vs rayon-parallel --------------------------
    // One DP vector pair per output activation, on real ResNet-18 (CIFAR)
    // conv layer shapes — the work distribution the multi-bank system
    // fans out across banks, here work-stolen across cores.
    let threads = rayon::current_num_threads();
    println!(
        "\n  batched PAC MAC, scalar vs parallel ({} rayon threads{}):",
        threads,
        if quick { ", quick mode" } else { "" }
    );
    let shapes = resnet18(Resolution::Cifar, 10);
    let wanted = ["layer1.0.conv1", "layer3.0.conv2", "layer4.1.conv2"];
    let pairs_n = if quick { 96 } else { 1024 };
    let reps = if quick { 3 } else { 7 };
    let mut layer_benches = Vec::new();
    for name in wanted {
        let shape = shapes
            .iter()
            .find(|s| s.name == name)
            .expect("ResNet-18 layer table changed");
        let k = shape.dp_len();
        let pairs: Vec<(BitPlanes, BitPlanes)> = (0..pairs_n)
            .map(|_| {
                let x: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
                let w: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
                (BitPlanes::from_u8(&x), BitPlanes::from_u8(&w))
            })
            .collect();
        let (t_seq, out_seq) =
            timeit(reps, || hybrid_mac_batch(&pairs, &map, PcuRounding::RoundNearest));
        let (t_par, out_par) =
            timeit(reps, || par_hybrid_mac_batch(&pairs, &map, PcuRounding::RoundNearest));
        let identical = out_seq == out_par;
        let macs = (pairs_n * k) as f64;
        let speedup = t_seq / t_par;
        println!(
            "    {name:<18} DP={k:<5} x{pairs_n}: scalar {:>9} par {:>9} speedup {speedup:.2}x",
            rate(macs, t_seq, "MAC"),
            rate(macs, t_par, "MAC"),
        );
        checks.claim(
            identical,
            &format!("{name}: parallel batch bit-identical to scalar"),
        );
        layer_benches.push(LayerBench {
            layer: name.to_string(),
            dp_len: k,
            pairs: pairs_n,
            scalar_macs_per_s: macs / t_seq,
            parallel_macs_per_s: macs / t_par,
            speedup,
            bit_identical: identical,
        });
    }
    let best = layer_benches
        .iter()
        .map(|l| l.speedup)
        .fold(0.0f64, f64::max);
    // Throughput is machine-load-dependent, so the >=2x target is
    // *reported* (here and in BENCH_hotpath.json) rather than asserted —
    // only the bit-identity claims above can fail this bench.
    println!("    best speedup {best:.2}x (target: >=2x at >=4 threads)");

    // --- blocked vs per-patch layer GEMM (the headline single-thread row) ---
    let blocked_benches = blocked_section(quick, &mut rng, &mut checks);

    // --- SIMD kernel tier vs forced scalar on the blocked GEMM ---
    let simd_benches = simd_section(quick, &mut rng, &mut checks);

    // --- fused dataplane vs dense round-trip (multi-layer, end to end) ---
    let fused_benches = fused_section(quick, &mut checks);

    // The report serializes through the shared schema
    // (`pacim::util::benchfmt`); tests/bench_schema.rs re-parses the
    // emitted file and fails on any drift, and CI's bench-smoke job
    // additionally gates `speedup_blocked >= 1.0` on every shape
    // (PACIM_ENFORCE_BLOCKED_SPEEDUP=1 → `benchfmt::enforce_blocked_floor`)
    // and `speedup_simd >= 1.0` on every simd row
    // (PACIM_ENFORCE_SIMD_SPEEDUP=1 → `benchfmt::enforce_simd_floor`).
    let report = HotpathReport {
        bench: "perf_hotpath".into(),
        threads,
        quick,
        layers: layer_benches,
        blocked: blocked_benches,
        simd: simd_benches,
        fused: fused_benches,
    };
    match serde_json::to_string_pretty(&report)
        .map_err(anyhow::Error::from)
        .and_then(|s| std::fs::write("BENCH_hotpath.json", s).map_err(anyhow::Error::from))
    {
        Ok(()) => println!("    wrote BENCH_hotpath.json"),
        Err(e) => println!("    could not write BENCH_hotpath.json: {e}"),
    }

    // --- PAC conv backend on a ResNet-ish layer ----------------------------
    // K=1152 (3x3x128), N=64 channels, 256 patches (16x16 output tile),
    // through the blocked layer-level GEMM with warm scratch.
    let k = 1152;
    let n_oc = 64;
    let patches = if quick { 32 } else { 256 };
    let wq: Vec<u8> = (0..n_oc * k).map(|_| rng.below(256) as u8).collect();
    let weight = Tensor::from_vec(&[n_oc, k], wq);
    let backend = pac_backend_for(&weight, Parallelism::auto());
    let cols: Vec<u8> = (0..patches * k).map(|_| rng.below(256) as u8).collect();
    let mut stats = RunStats::default();
    let mut planes = PackedPatches::default();
    let mut acc = Vec::new();
    let (t, _) = timeit(if quick { 2 } else { 5 }, || {
        backend.gemm_layer(
            0,
            GemmInput::Dense(&cols),
            patches,
            7,
            0,
            &Parallelism::off(),
            &mut planes,
            &mut acc,
            &mut stats,
        );
        std::hint::black_box(acc.last().copied())
    });
    let macs = (patches * n_oc * k) as f64;
    println!(
        "  PAC conv layer (K=1152,N=64,{patches}px): {:>9.2} ms  ({} hybrid-MAC)",
        t * 1e3,
        rate(macs, t, "")
    );

    // --- PAC-native serving pipeline (pool + dynamic batcher) ---------------
    serving_section(quick, &mut checks);

    // --- PJRT end-to-end (pjrt feature + artifacts required) ---------------
    pjrt_section();
    println!();
    checks.finish("§Perf");
}

/// Closed-loop throughput of the worker pool over the PAC executor on
/// the synthetic workload (no artifacts, no PJRT). The full open/closed
/// sweep with JSON export lives in `examples/loadgen.rs`; this row keeps
/// the serving path on the bench dashboard.
fn serving_section(quick: bool, checks: &mut Checks) {
    use pacim::coordinator::{BatchPolicy, InferenceServer};
    use pacim::runtime::PacExecutor;
    use pacim::workload::synthetic_serving_workload;
    use std::sync::atomic::{AtomicUsize, Ordering};

    let (model, ds) = synthetic_serving_workload(7701, 8, 16, 10, 32)
        .expect("synthetic workload");
    let requests = if quick { 24 } else { 128 };
    let workers = rayon::current_num_threads().clamp(1, 4);
    let exec =
        PacExecutor::new(model, PacConfig::serving(), 8).expect("valid serving engine");
    let server = InferenceServer::start_pool(
        move |_| Ok(exec.clone()),
        BatchPolicy {
            workers,
            ..BatchPolicy::default()
        },
    )
    .expect("pool start");
    let h = server.handle();
    let next = AtomicUsize::new(0);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for _ in 0..8 {
            let h = h.clone();
            let next = &next;
            let ds = &ds;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= requests {
                    break;
                }
                let img: Vec<f32> = ds
                    .image(i % ds.n)
                    .iter()
                    .map(|&q| ds.params.dequantize(q))
                    .collect();
                h.infer(img).expect("infer");
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let m = server.stop();
    println!(
        "\n  PAC serving ({workers} workers, batch 8): {:>9.2} ms  ({}, p50 {:.0} us, fill {:.2})",
        wall * 1e3,
        rate(requests as f64, wall, "img"),
        m.latency_percentile_us(50.0),
        m.mean_batch_occupancy()
    );
    checks.claim(
        m.requests == requests as u64 && m.failed_batches == 0,
        "serving pool answered every request",
    );
}

/// Blocked layer-level GEMM vs the frozen per-patch engine
/// (`gemm_per_patch_reference`), single-thread, on ResNet-18 (CIFAR)
/// layer shapes: the stem, a stride-1 3×3 mid layer, a deep stride-1
/// 3×3 layer, and the wide 1×1 downsample. Rows go into
/// `BENCH_hotpath.json`; CI gates `speedup_blocked >= 1.0` per shape.
fn blocked_section(quick: bool, rng: &mut Rng, checks: &mut Checks) -> Vec<BlockedBench> {
    println!("\n  blocked layer GEMM vs per-patch engine (single-thread):");
    let shapes = resnet18(Resolution::Cifar, 10);
    let wanted = ["stem", "layer1.0.conv1", "layer3.0.conv2", "layer4.0.downsample"];
    let pixel_cap = if quick { 48 } else { 192 };
    let reps = if quick { 3 } else { 7 };
    let mut rows = Vec::new();
    for name in wanted {
        let shape = shapes
            .iter()
            .find(|s| s.name == name)
            .expect("ResNet-18 layer table changed");
        let k = shape.dp_len();
        let out_c = shape.geom.out_c;
        let pixels = shape.out_pixels().min(pixel_cap);
        let wq: Vec<u8> = (0..out_c * k).map(|_| rng.below(256) as u8).collect();
        let weight = Tensor::from_vec(&[out_c, k], wq);
        // Both engines pinned scalar: this row isolates the kernel
        // restructuring from the rayon fan-out measured above.
        let backend = pac_backend_for(&weight, Parallelism::off());
        let cols: Vec<u8> = (0..pixels * k).map(|_| rng.below(256) as u8).collect();

        // Baseline: the pre-blocked engine — BitPlanes::from_u8 + one
        // accumulator Vec per patch, scalar columns.
        let (t_pp, reference) = timeit(reps, || {
            let mut stats = RunStats::default();
            let mut acc: Vec<i64> = Vec::new();
            for pix in 0..pixels {
                let accs = backend.gemm_per_patch_reference(
                    0,
                    &cols[pix * k..(pix + 1) * k],
                    7,
                    &mut stats,
                );
                acc.extend_from_slice(&accs);
            }
            acc
        });

        // Blocked: one layer-level call, warm scratch, scalar tiles.
        let mut planes = PackedPatches::default();
        let mut out: Vec<i64> = Vec::new();
        let (t_bl, _) = timeit(reps, || {
            let mut stats = RunStats::default();
            backend.gemm_layer(
                0,
                GemmInput::Dense(&cols),
                pixels,
                7,
                0,
                &Parallelism::off(),
                &mut planes,
                &mut out,
                &mut stats,
            );
        });
        let identical = out == reference;
        let macs = (pixels * out_c * k) as f64;
        let speedup = t_pp / t_bl;
        println!(
            "    {name:<20} DP={k:<5} OC={out_c:<4} {pixels}px: per-patch {:>9} blocked {:>9} \
             speedup {speedup:.2}x",
            rate(macs, t_pp, "MAC"),
            rate(macs, t_bl, "MAC"),
        );
        checks.claim(
            identical,
            &format!("{name}: blocked GEMM bit-identical to per-patch engine"),
        );
        rows.push(BlockedBench {
            shape: name.to_string(),
            dp_len: k,
            out_c,
            pixels,
            per_patch_macs_per_s: macs / t_pp,
            blocked_macs_per_s: macs / t_bl,
            speedup_blocked: speedup,
            bit_identical: identical,
        });
    }
    rows
}

/// SIMD kernel tier vs forced scalar on the blocked layer GEMM
/// (single-thread): the auto-detected tier (`PacConfig::kernel: None`,
/// honoring `PACIM_FORCE_KERNEL`) against the same GEMM pinned to the
/// scalar tier, same shape, same inputs, bit-identity asserted. Two
/// weight fills per shape: dense random (the density auto-off keeps
/// skipping disabled) and MSB-sparse in word-aligned stripes (the
/// zero-word bitmaps actually skip). Rows go into `BENCH_hotpath.json`;
/// CI gates `speedup_simd >= 1.0` per row on AVX2 runners
/// (`PACIM_ENFORCE_SIMD_SPEEDUP=1`). The stem is deliberately absent:
/// its DP length (27) packs into a single u64 word, so the vector path
/// degenerates to the scalar tail and the ratio would be pure noise.
fn simd_section(quick: bool, rng: &mut Rng, checks: &mut Checks) -> Vec<SimdBench> {
    println!("\n  SIMD kernel tier vs forced scalar (single-thread blocked GEMM):");
    let shapes = resnet18(Resolution::Cifar, 10);
    let wanted = ["layer1.0.conv1", "layer3.0.conv2", "layer4.0.downsample"];
    let pixel_cap = if quick { 48 } else { 192 };
    let reps = if quick { 3 } else { 7 };
    let mut rows = Vec::new();
    for name in wanted {
        let shape = shapes
            .iter()
            .find(|s| s.name == name)
            .expect("ResNet-18 layer table changed");
        let k = shape.dp_len();
        let out_c = shape.geom.out_c;
        let pixels = shape.out_pixels().min(pixel_cap);
        for sparse in [false, true] {
            let wq: Vec<u8> = if sparse {
                msb_sparse_fill(rng, out_c, k, 0.6)
            } else {
                (0..out_c * k).map(|_| rng.below(256) as u8).collect()
            };
            let weight = Tensor::from_vec(&[out_c, k], wq);
            let mk = |kernel| {
                let mut b = pacim::nn::PacBackend::new(PacConfig {
                    first_layer_exact: false,
                    min_dp_len: 0,
                    par: Parallelism::off(),
                    kernel,
                    ..PacConfig::default()
                });
                b.prepare(0, &weight, 128);
                b
            };
            let scalar = mk(Some(KernelTier::Scalar));
            let simd = mk(None);
            let tier = simd.kernel_caps().tier();
            let (live, total, skip_columns) = simd.weight_skip_profile(0);
            let live_word_fraction =
                if total == 0 { 1.0 } else { live as f64 / total as f64 };
            let cols: Vec<u8> = (0..pixels * k).map(|_| rng.below(256) as u8).collect();
            let time_gemm = |b: &pacim::nn::PacBackend| {
                let mut planes = PackedPatches::default();
                let mut out: Vec<i64> = Vec::new();
                let (t, _) = timeit(reps, || {
                    let mut stats = RunStats::default();
                    b.gemm_layer(
                        0,
                        GemmInput::Dense(&cols),
                        pixels,
                        7,
                        0,
                        &Parallelism::off(),
                        &mut planes,
                        &mut out,
                        &mut stats,
                    );
                });
                (t, out)
            };
            let (t_sc, out_sc) = time_gemm(&scalar);
            let (t_si, out_si) = time_gemm(&simd);
            let identical = out_sc == out_si;
            let macs = (pixels * out_c * k) as f64;
            let speedup = t_sc / t_si;
            let fill = if sparse { "msbsparse" } else { "dense" };
            println!(
                "    {name:<20} {fill:<9} DP={k:<5} [{:<6}]: scalar {:>9} simd {:>9} \
                 speedup {speedup:.2}x (live {live_word_fraction:.2}, skip {skip_columns} col)",
                tier.name(),
                rate(macs, t_sc, "MAC"),
                rate(macs, t_si, "MAC"),
            );
            checks.claim(
                identical,
                &format!("{name}-{fill}: {} kernel bit-identical to scalar", tier.name()),
            );
            rows.push(SimdBench {
                shape: format!("{name}-{fill}"),
                dp_len: k,
                out_c,
                pixels,
                tier: tier.name().into(),
                msb_sparse_weights: sparse,
                live_word_fraction,
                skip_columns,
                scalar_macs_per_s: macs / t_sc,
                simd_macs_per_s: macs / t_si,
                speedup_simd: speedup,
                bit_identical: identical,
            });
        }
    }
    rows
}

/// Word-aligned MSB-sparse weight fill: each 64-element block of a row
/// is either all-low (values < 16, so all four MSB planes of that word
/// are zero) or free-range — the distribution the zero-word bitmaps
/// were built for.
fn msb_sparse_fill(rng: &mut Rng, n_oc: usize, k: usize, p_low: f64) -> Vec<u8> {
    let mut wq = Vec::with_capacity(n_oc * k);
    for _ in 0..n_oc {
        for blk in 0..k.div_ceil(64) {
            let low = rng.bernoulli(p_low);
            for _ in blk * 64..(blk * 64 + 64).min(k) {
                wq.push(if low { rng.below(16) as u8 } else { rng.below(256) as u8 });
            }
        }
    }
    wq
}

/// Fused dataplane vs dense round-trip: the same multi-layer PAC
/// forward passes with producer-side encoding on (requantize→scatter→
/// pack straight into the consumer's slab) vs off (dense u8 activation
/// + consumer-side im2col + re-pack). Single-thread, warm scratch; the
/// logits must match bit for bit — the speedup is the deleted
/// dequant/requant/re-pack steady-state work.
fn fused_section(quick: bool, checks: &mut Checks) -> Vec<FusedBench> {
    use pacim::nn::layers::synthetic::random_store;
    use pacim::nn::{pac_backend, run_model_with, tiny_resnet, ModelScratch};

    println!("\n  fused dataplane vs dense round-trip (single-thread, multi-layer):");
    let mut rng = Rng::new(909);
    let (c, hw) = if quick { (16, 16) } else { (16, 32) };
    let model = tiny_resnet(&random_store(&mut rng, c, 10), hw, 10)
        .expect("synthetic model is valid");
    let images: Vec<Vec<u8>> = (0..if quick { 2 } else { 8 })
        .map(|_| (0..3 * hw * hw).map(|_| rng.below(256) as u8).collect())
        .collect();
    let cfg = |fuse| PacConfig {
        min_dp_len: 0,
        par: Parallelism::off(),
        fuse_dataplane: fuse,
        ..PacConfig::default()
    };
    let roundtrip = pac_backend(&model, cfg(false));
    let fused = pac_backend(&model, cfg(true));
    let reps = if quick { 3 } else { 7 };
    let par = Parallelism::off();
    let mut scratch = ModelScratch::default();
    let run_all = |backend: &pacim::nn::PacBackend, scratch: &mut ModelScratch| {
        let mut logits = Vec::new();
        let mut encoded = 0usize;
        for img in &images {
            let (lg, st) = run_model_with(&model, backend, img, &par, scratch)
                .expect("bench model executes");
            encoded = st.traffic.encoded_layer_count();
            logits.push(lg);
        }
        (logits, encoded)
    };
    let (t_rt, (ref_logits, rt_encoded)) = timeit(reps, || run_all(&roundtrip, &mut scratch));
    let (t_fu, (fu_logits, fu_encoded)) = timeit(reps, || run_all(&fused, &mut scratch));
    let identical = ref_logits == fu_logits;
    let speedup = t_rt / t_fu;
    let n = images.len() as f64;
    println!(
        "    {:<20} {} imgs: roundtrip {:>9} fused {:>9} speedup {speedup:.2}x \
         ({fu_encoded} encoded edges)",
        model.name,
        images.len(),
        rate(n, t_rt, "img"),
        rate(n, t_fu, "img"),
    );
    checks.claim(identical, "fused dataplane bit-identical to dense round-trip");
    checks.claim(rt_encoded == 0 && fu_encoded > 0, "fusion toggles the encoded edges");
    vec![FusedBench {
        model: model.name.clone(),
        images: images.len(),
        encoded_layers: fu_encoded,
        roundtrip_images_per_s: n / t_rt,
        fused_images_per_s: n / t_fu,
        speedup_fused: speedup,
        bit_identical: identical,
    }]
}

fn pac_backend_for(weight: &Tensor<u8>, par: Parallelism) -> pacim::nn::PacBackend {
    let mut b = pacim::nn::PacBackend::new(PacConfig {
        first_layer_exact: false,
        min_dp_len: 0,
        par,
        ..PacConfig::default()
    });
    b.prepare(0, weight, 128);
    b
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_section() {
    println!("  (pjrt feature disabled; skipping PJRT end-to-end rows)");
}

#[cfg(feature = "pjrt")]
fn pjrt_section() {
    if let Some((man, _, ds)) = harness::try_artifacts() {
        use pacim::runtime::PjrtExecutor;
        let batch = man.batch().unwrap();
        let in_elems = man.input_elems().unwrap();
        let classes = man.classes().unwrap();
        let exe = PjrtExecutor::load(man.path("model_pac").unwrap(), batch, in_elems, classes)
            .expect("compile");
        let mut flat = vec![0f32; batch * in_elems];
        for i in 0..batch {
            for (j, &q) in ds.image(i).iter().enumerate() {
                flat[i * in_elems + j] = ds.params.dequantize(q);
            }
        }
        exe.run(&flat).unwrap(); // warm-up
        let (t, _) = timeit(10, || exe.run(&flat).unwrap());
        println!(
            "  PJRT model_pac batch={batch}:          {:>9.2} ms  ({})",
            t * 1e3,
            rate(batch as f64, t, "img")
        );

        // Serving loop throughput (mock-free, real PJRT).
        use pacim::coordinator::{BatchPolicy, InferenceServer};
        let hlo = man.path("model_pac").unwrap();
        let server = InferenceServer::start_with(
            move || PjrtExecutor::load(&hlo, batch, in_elems, classes),
            BatchPolicy::default(),
        )
        .unwrap();
        let h = server.handle();
        let imgs: Vec<Vec<f32>> = (0..64.min(ds.n))
            .map(|i| ds.image(i).iter().map(|&q| ds.params.dequantize(q)).collect())
            .collect();
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for chunk in imgs.chunks(8) {
                let h = h.clone();
                let chunk = chunk.to_vec();
                s.spawn(move || {
                    for img in chunk {
                        h.infer(img).unwrap();
                    }
                });
            }
        });
        let serve_t = t0.elapsed().as_secs_f64();
        let m = server.stop();
        println!(
            "  serving {} reqs:                   {:>9.2} ms  ({}, p50 {:.0} us, batch occ {:.1})",
            imgs.len(),
            serve_t * 1e3,
            rate(imgs.len() as f64, serve_t, "img"),
            m.latency_percentile_us(50.0),
            m.mean_batch_occupancy()
        );
    }
}
