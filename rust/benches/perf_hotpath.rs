//! §Perf — hot-path micro/macro benchmarks (EXPERIMENTS.md §Perf).
//!
//! L3 hot paths: BitPlanes decomposition, the digital AND-popcount cycle,
//! the full hybrid MAC, the PAC conv backend on a realistic layer, and
//! (when artifacts exist) PJRT end-to-end batch latency + serving
//! throughput. Hand-rolled timing (criterion unavailable offline).

#[path = "harness.rs"]
mod harness;

use harness::{banner, rate, timeit};
use pacim::nn::{MacBackend, PacConfig, RunStats};
use pacim::pac::{hybrid_mac, BitPlanes, ComputeMap, PcuRounding};
use pacim::tensor::Tensor;
use pacim::util::rng::Rng;

fn main() {
    banner("§Perf", "hot-path throughput");
    let mut rng = Rng::new(77);

    // --- BitPlanes decomposition -----------------------------------------
    let v: Vec<u8> = (0..4096).map(|_| rng.below(256) as u8).collect();
    let (t, _) = timeit(30, || BitPlanes::from_u8(&v));
    println!("  BitPlanes::from_u8 (4096 elems):   {:>10.2} us  ({})",
             t * 1e6, rate(4096.0, t, "elem"));

    // --- hybrid MAC (Eq. 4) -----------------------------------------------
    let map = ComputeMap::operand_based(4, 4);
    for n in [256usize, 1024, 4096] {
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        let (t, _) = timeit(50, || hybrid_mac(&xp, &wp, &map, PcuRounding::RoundNearest));
        println!("  hybrid_mac DP={n:<5}:              {:>10.2} us  ({} MAC-equiv)",
                 t * 1e6, rate(n as f64, t, ""));
    }

    // --- PAC conv backend on a ResNet-ish layer ----------------------------
    // K=1152 (3x3x128), N=64 channels, 256 patches (16x16 output tile).
    let k = 1152;
    let n_oc = 64;
    let patches = 256;
    let wq: Vec<u8> = (0..n_oc * k).map(|_| rng.below(256) as u8).collect();
    let weight = Tensor::from_vec(&[n_oc, k], wq);
    let mut backend = pac_backend_for(&weight);
    let patch_data: Vec<Vec<u8>> = (0..patches)
        .map(|_| (0..k).map(|_| rng.below(256) as u8).collect())
        .collect();
    let mut stats = RunStats::default();
    let (t, _) = timeit(5, || {
        for p in &patch_data {
            std::hint::black_box(backend.gemm(0, p, 7, &mut stats));
        }
    });
    let macs = (patches * n_oc * k) as f64;
    println!("  PAC conv layer (K=1152,N=64,256px): {:>9.2} ms  ({} hybrid-MAC)",
             t * 1e3, rate(macs, t, ""));
    let _ = &mut backend;

    // --- PJRT end-to-end (artifacts required) ------------------------------
    if let Some((man, _, ds)) = harness::try_artifacts() {
        use pacim::runtime::PjrtExecutor;
        let batch = man.batch().unwrap();
        let in_elems = man.input_elems().unwrap();
        let classes = man.classes().unwrap();
        let exe = PjrtExecutor::load(man.path("model_pac").unwrap(), batch, in_elems, classes)
            .expect("compile");
        let mut flat = vec![0f32; batch * in_elems];
        for i in 0..batch {
            for (j, &q) in ds.image(i).iter().enumerate() {
                flat[i * in_elems + j] = ds.params.dequantize(q);
            }
        }
        exe.run(&flat).unwrap(); // warm-up
        let (t, _) = timeit(10, || exe.run(&flat).unwrap());
        println!("  PJRT model_pac batch={batch}:          {:>9.2} ms  ({})",
                 t * 1e3, rate(batch as f64, t, "img"));

        // Serving loop throughput (mock-free, real PJRT).
        use pacim::coordinator::{BatchPolicy, InferenceServer};
        let hlo = man.path("model_pac").unwrap();
        let server = InferenceServer::start_with(
            move || PjrtExecutor::load(&hlo, batch, in_elems, classes),
            BatchPolicy::default(),
        )
        .unwrap();
        let h = server.handle();
        let imgs: Vec<Vec<f32>> = (0..64.min(ds.n))
            .map(|i| ds.image(i).iter().map(|&q| ds.params.dequantize(q)).collect())
            .collect();
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for chunk in imgs.chunks(8) {
                let h = h.clone();
                let chunk = chunk.to_vec();
                s.spawn(move || {
                    for img in chunk {
                        h.infer(img).unwrap();
                    }
                });
            }
        });
        let serve_t = t0.elapsed().as_secs_f64();
        let mut m = server.stop();
        println!("  serving {} reqs:                   {:>9.2} ms  ({}, p50 {:.0} us, batch occ {:.1})",
                 imgs.len(), serve_t * 1e3, rate(imgs.len() as f64, serve_t, "img"),
                 m.latency_percentile_us(50.0), m.mean_batch_occupancy());
    }
    println!();
}

fn pac_backend_for(weight: &Tensor<u8>) -> pacim::nn::PacBackend {
    let mut b = pacim::nn::PacBackend::new(PacConfig {
        first_layer_exact: false,
        min_dp_len: 0,
        ..PacConfig::default()
    });
    b.prepare(0, weight, 128);
    b
}
