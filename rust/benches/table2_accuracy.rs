//! Table 2 — inference accuracy | loss under 4-bit PAC approximation.
//!
//! Paper grid: {ResNet-18, ResNet-50, VGG16-BN} × {CIFAR-10, CIFAR-100,
//! ImageNet}. Substitution (DESIGN.md §3): the trained tiny_resnet on the
//! synthetic 10-class dataset carries the accuracy measurements; the
//! paper's grid is reproduced as reference rows, and the qualitative
//! claims (loss < ~1% for the easy task; 5-bit mode recovers the loss;
//! dynamic config adds ~1%) are asserted on our measurements.

#[path = "harness.rs"]
mod harness;

use harness::{banner, engine_exact, engine_pac, eval_accuracy, row, Checks};
use pacim::arch::ThresholdSet;
use pacim::nn::PacConfig;
use pacim::pac::ComputeMap;

const EVAL_N: usize = 512;

fn main() {
    banner("Table 2", "Accuracy | loss under 4-bit PAC approximation");
    println!(
        "  paper (ResNet-18): CIFAR-10 93.85%|-0.62  CIFAR-100 72.36%|-0.62  ImageNet 66.02%|-2.74"
    );
    println!(
        "  paper (ResNet-50): CIFAR-10 93.21%|-1.02  CIFAR-100 72.65%|-1.04  ImageNet 75.98%|-3.38"
    );
    println!(
        "  paper (VGG16-BN) : CIFAR-10 94.29%|-0.66  CIFAR-100 75.39%|-0.69  ImageNet 71.59%|-1.31"
    );
    println!();

    let Some((_, model, ds)) = harness::try_artifacts() else {
        println!("  artifacts missing; run `make artifacts` first.");
        return;
    };
    let mut checks = Checks::new();

    let exact = engine_exact(&model);
    let (acc8, _) = eval_accuracy(&exact, &ds, EVAL_N);

    let pac4 = engine_pac(&model, PacConfig::default());
    let (acc4, _) = eval_accuracy(&pac4, &ds, EVAL_N);

    let cfg5 = PacConfig {
        map: ComputeMap::operand_based(5, 5),
        ..PacConfig::default()
    };
    let pac5 = engine_pac(&model, cfg5);
    let (acc5, _) = eval_accuracy(&pac5, &ds, EVAL_N);

    let cfg_dyn = PacConfig {
        thresholds: Some(ThresholdSet::default_cifar()),
        ..PacConfig::default()
    };
    let pacd = engine_pac(&model, cfg_dyn);
    let (accd, stats_d) = eval_accuracy(&pacd, &ds, EVAL_N);

    println!("  measured ({} {} images, synthetic-10):", EVAL_N, model.name);
    row("exact 8b/8b", "(baseline)", &format!("{:.2}%", acc8 * 100.0));
    row(
        "PAC 4-bit",
        "loss ≈ -0.6..-1%",
        &format!("{:.2}% ({:+.2}%)", acc4 * 100.0, (acc4 - acc8) * 100.0),
    );
    row(
        "PAC 5-bit",
        "loss < 1%",
        &format!("{:.2}% ({:+.2}%)", acc5 * 100.0, (acc5 - acc8) * 100.0),
    );
    row(
        "PAC 4-bit + dynamic",
        "additional ~1% loss",
        &format!(
            "{:.2}% ({:+.2}%), avg {:.1} cycles",
            accd * 100.0,
            (accd - acc8) * 100.0,
            stats_d.levels.average_cycles()
        ),
    );

    println!();
    println!("  note: our substitute model's PAC-eligible layers sit at the BOTTOM of");
    println!("  the paper's DP range (576 vs the paper's 576-4608 mix), so the 4-bit");
    println!("  static loss is larger than the paper's CIFAR numbers and closer to its");
    println!("  ImageNet row (-2.74..-3.38). The 5-bit escape hatch (paper 6.1) and the");
    println!("  dynamic configuration recover the loss exactly as the paper describes.");
    checks.claim(acc8 > 0.85, "trained baseline is strong (>85%)");
    checks.claim(acc8 - acc4 <= 0.10, "4-bit PAC usable at the DP-range floor (loss < 10%)");
    checks.claim(acc8 - acc5 <= 0.02, "5-bit PAC recovers to within ~1.5% (paper: <1%)");
    checks.claim(acc5 >= acc4 - 0.005, "5-bit no worse than 4-bit");
    checks.claim(acc8 - accd <= 0.035, "dynamic config within the paper's hard-task band (~3%)");
    checks.finish("Table 2");
}
