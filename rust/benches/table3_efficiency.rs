//! Table 3 — 1b/1b energy efficiency at 0.6/1.2 V, plus the §6.2 system
//! compositions (12x PCU vs D-CiM; ~5x system vs digital; 8b/8b peak).

#[path = "harness.rs"]
mod harness;

use harness::{banner, row, Checks};
use pacim::energy::{EnergyModel, Supply};

fn main() {
    banner("Table 3", "1b/1b energy efficiency (TOPS/W), supply 0.6/1.2V");
    let mut checks = Checks::new();
    let m06 = EnergyModel::default();
    let m12 = m06.at_supply(Supply::V12);

    row("D-CiM", "235.01 / 58.72",
        &format!("{:.2} / {:.2}", m06.dcim_tops_w(), m12.dcim_tops_w()));
    row("PCU + Acc.", "2945.92 / 736.48",
        &format!("{:.2} / {:.2}", m06.pcu_tops_w(), m12.pcu_tops_w()));
    row("PACiM (peak, dynamic 10-cycle)", "1170.28 / 292.57",
        &format!("{:.2} / {:.2}", m06.pacim_peak().tops_w_1b, m12.pacim_peak().tops_w_1b));
    row("PACiM (static 16/48 composition)", "-",
        &format!("{:.2} / {:.2}", m06.pacim_static().tops_w_1b, m12.pacim_static().tops_w_1b));

    println!("\n  §6.2 system-level compositions:");
    row("PCU / D-CiM efficiency ratio", "12x",
        &format!("{:.2}x", m06.pcu_tops_w() / m06.dcim_tops_w()));
    row("system / fully-digital ratio", "≈5x",
        &format!("{:.2}x", m06.pacim_peak().tops_w_1b / m06.digital_8b().tops_w_1b));
    row("8b/8b peak efficiency", "14.63 TOPS/W",
        &format!("{:.2} TOPS/W", m06.pacim_peak().tops_w_8b));
    row("8b/8b static efficiency", "-",
        &format!("{:.2} TOPS/W", m06.pacim_static().tops_w_8b));
    println!("\n  note: the D-CiM and PCU cells are calibration constants from the");
    println!("  paper's synthesis results; PACiM rows are *structural compositions*");
    println!("  over the cycle map (DESIGN.md §7). The static composition lands at");
    println!("  {:.0} TOPS/W; the paper's 1170.28 corresponds to the dynamic peak.",
             m06.pacim_static().tops_w_1b);

    checks.claim((m06.dcim_tops_w() - 235.01).abs() < 0.01, "D-CiM matches Table 3 @0.6V");
    checks.claim((m12.dcim_tops_w() - 58.72).abs() < 0.1, "D-CiM matches Table 3 @1.2V (V^2 law)");
    checks.claim((m06.pcu_tops_w() - 2945.92).abs() < 0.01, "PCU+Acc matches Table 3 @0.6V");
    checks.claim((m06.pcu_tops_w() / m06.dcim_tops_w() - 12.5).abs() < 0.1, "12x PCU/D-CiM ratio");
    let sys_ratio = m06.pacim_peak().tops_w_1b / m06.digital_8b().tops_w_1b;
    checks.claim((4.0..5.5).contains(&sys_ratio), "≈5x system vs fully digital");
    let peak8 = m06.pacim_peak().tops_w_8b;
    checks.claim((12.0..16.5).contains(&peak8), "8b/8b peak in the 14.63 TOPS/W band");
    checks.finish("Table 3");
}
