//! Integration: the NN engines against the trained artifacts — accuracy
//! bands, PAC-vs-exact relationships, and engine determinism under
//! threading. Skips gracefully without artifacts.

use pacim::arch::ThresholdSet;
use pacim::nn::{
    evaluate, exact_backend, pac_backend, run_model, tiny_resnet, PacConfig, WeightStore,
};
use pacim::pac::ComputeMap;
use pacim::runtime::Manifest;
use pacim::workload::Dataset;

fn load() -> Option<(pacim::nn::Model, Dataset)> {
    let man = Manifest::load(pacim::runtime::manifest::artifacts_dir()).ok()?;
    let store = WeightStore::load(man.path("weights").ok()?).ok()?;
    let ds = Dataset::load(man.path("dataset").ok()?).ok()?;
    let model = tiny_resnet(&store, ds.h, ds.n_classes).ok()?;
    Some((model, ds))
}

fn subset(ds: &Dataset, n: usize) -> (Vec<&[u8]>, Vec<usize>) {
    let n = n.min(ds.n);
    ((0..n).map(|i| ds.image(i)).collect(), (0..n).map(|i| ds.label(i)).collect())
}

#[test]
fn trained_model_beats_chance_by_wide_margin() {
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 128);
    let exact = exact_backend(&model);
    let (acc, stats) = evaluate(&model, &exact, &images, &labels, 8);
    assert!(acc > 0.8, "exact accuracy {acc}");
    assert_eq!(stats.macs, model.macs() * images.len() as u64);
}

#[test]
fn pac_accuracy_within_band_of_exact() {
    // The Table 2 claim at integration-test strength: 4-bit PAC loses
    // only a few points on the easy task.
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 128);
    let exact = exact_backend(&model);
    let (acc_e, _) = evaluate(&model, &exact, &images, &labels, 8);
    let pac = pac_backend(&model, PacConfig::default());
    let (acc_p, _) = evaluate(&model, &pac, &images, &labels, 8);
    assert!(
        acc_e - acc_p <= 0.12,
        "PAC loss too large: exact {acc_e} pac {acc_p}"
    );
}

#[test]
fn all_digital_map_reproduces_exact_engine_on_artifacts() {
    let Some((model, ds)) = load() else { return };
    let exact = exact_backend(&model);
    let cfg = PacConfig {
        map: ComputeMap::all_digital(),
        first_layer_exact: false,
        min_dp_len: 0,
        ..PacConfig::default()
    };
    let pac = pac_backend(&model, cfg);
    for i in 0..4.min(ds.n) {
        let (a, _) = run_model(&model, &exact, ds.image(i));
        let (b, _) = run_model(&model, &pac, ds.image(i));
        assert_eq!(a, b, "image {i}");
    }
}

#[test]
fn dynamic_config_trades_cycles_for_bounded_loss() {
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 96);
    let pac_s = pac_backend(&model, PacConfig::default());
    let (acc_s, _) = evaluate(&model, &pac_s, &images, &labels, 8);
    let cfg = PacConfig {
        thresholds: Some(ThresholdSet::default_cifar()),
        ..PacConfig::default()
    };
    let pac_d = pac_backend(&model, cfg);
    let (acc_d, stats) = evaluate(&model, &pac_d, &images, &labels, 8);
    assert!(stats.levels.total() > 0);
    assert!(stats.levels.average_cycles() < 16.0);
    // Dynamic is *better* than static on this model (see EXPERIMENTS.md).
    assert!(acc_d >= acc_s - 0.05, "dynamic loss too large: {acc_s} -> {acc_d}");
}

#[test]
fn evaluation_is_thread_count_invariant() {
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 32);
    let exact = exact_backend(&model);
    let (a1, _) = evaluate(&model, &exact, &images, &labels, 1);
    let (a8, _) = evaluate(&model, &exact, &images, &labels, 8);
    assert_eq!(a1, a8);
}

#[test]
fn five_bit_mode_recovers_loss() {
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 96);
    let exact = exact_backend(&model);
    let (acc_e, _) = evaluate(&model, &exact, &images, &labels, 8);
    let cfg5 = PacConfig {
        map: ComputeMap::operand_based(5, 5),
        ..PacConfig::default()
    };
    let pac5 = pac_backend(&model, cfg5);
    let (acc_5, _) = evaluate(&model, &pac5, &images, &labels, 8);
    assert!(acc_e - acc_5 <= 0.03, "5-bit loss: {acc_e} -> {acc_5}");
}
