//! Integration: the NN engines against the trained artifacts — accuracy
//! bands, PAC-vs-exact relationships, and engine determinism under
//! threading — all through the `pacim::engine` front door. Skips
//! gracefully without artifacts.

use pacim::arch::ThresholdSet;
use pacim::engine::{Engine, EngineBuilder};
use pacim::nn::{tiny_resnet, PacConfig, WeightStore};
use pacim::pac::ComputeMap;
use pacim::runtime::Manifest;
use pacim::workload::Dataset;

fn load() -> Option<(pacim::nn::Model, Dataset)> {
    let man = Manifest::load(pacim::runtime::manifest::artifacts_dir()).ok()?;
    let store = WeightStore::load(man.path("weights").ok()?).ok()?;
    let ds = Dataset::load(man.path("dataset").ok()?).ok()?;
    let model = tiny_resnet(&store, ds.h, ds.n_classes).ok()?;
    Some((model, ds))
}

fn subset(ds: &Dataset, n: usize) -> (Vec<&[u8]>, Vec<usize>) {
    let n = n.min(ds.n);
    ((0..n).map(|i| ds.image(i)).collect(), (0..n).map(|i| ds.label(i)).collect())
}

fn exact(model: &pacim::nn::Model) -> Engine {
    EngineBuilder::new(model.clone()).exact().build().unwrap()
}

fn pac(model: &pacim::nn::Model, cfg: PacConfig) -> Engine {
    EngineBuilder::new(model.clone()).pac(cfg).build().unwrap()
}

#[test]
fn trained_model_beats_chance_by_wide_margin() {
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 128);
    let ev = exact(&model).evaluate(&images, &labels, 8).unwrap();
    assert!(ev.accuracy > 0.8, "exact accuracy {}", ev.accuracy);
    assert_eq!(ev.stats.macs, model.macs() * images.len() as u64);
}

#[test]
fn pac_accuracy_within_band_of_exact() {
    // The Table 2 claim at integration-test strength: 4-bit PAC loses
    // only a few points on the easy task.
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 128);
    let acc_e = exact(&model).evaluate(&images, &labels, 8).unwrap().accuracy;
    let acc_p = pac(&model, PacConfig::default())
        .evaluate(&images, &labels, 8)
        .unwrap()
        .accuracy;
    assert!(
        acc_e - acc_p <= 0.12,
        "PAC loss too large: exact {acc_e} pac {acc_p}"
    );
}

#[test]
fn all_digital_map_reproduces_exact_engine_on_artifacts() {
    let Some((model, ds)) = load() else { return };
    let mut exact_session = exact(&model).session();
    let cfg = PacConfig {
        map: ComputeMap::all_digital(),
        first_layer_exact: false,
        min_dp_len: 0,
        ..PacConfig::default()
    };
    let mut pac_session = pac(&model, cfg).session();
    for i in 0..4.min(ds.n) {
        let a = exact_session.infer(ds.image(i)).unwrap();
        let b = pac_session.infer(ds.image(i)).unwrap();
        assert_eq!(a.logits, b.logits, "image {i}");
    }
}

#[test]
fn dynamic_config_trades_cycles_for_bounded_loss() {
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 96);
    let acc_s = pac(&model, PacConfig::default())
        .evaluate(&images, &labels, 8)
        .unwrap()
        .accuracy;
    let dynamic = EngineBuilder::new(model.clone())
        .pac(PacConfig::default())
        .dynamic(ThresholdSet::default_cifar())
        .build()
        .unwrap();
    let ev = dynamic.evaluate(&images, &labels, 8).unwrap();
    assert!(ev.stats.levels.total() > 0);
    assert!(ev.stats.levels.average_cycles() < 16.0);
    // Dynamic is *better* than static on this model (see EXPERIMENTS.md).
    assert!(
        ev.accuracy >= acc_s - 0.05,
        "dynamic loss too large: {acc_s} -> {}",
        ev.accuracy
    );
}

#[test]
fn evaluation_is_thread_count_invariant() {
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 32);
    let engine = exact(&model);
    let a1 = engine.evaluate(&images, &labels, 1).unwrap().accuracy;
    let a8 = engine.evaluate(&images, &labels, 8).unwrap().accuracy;
    assert_eq!(a1, a8);
}

#[test]
fn five_bit_mode_recovers_loss() {
    let Some((model, ds)) = load() else { return };
    let (images, labels) = subset(&ds, 96);
    let acc_e = exact(&model).evaluate(&images, &labels, 8).unwrap().accuracy;
    // approx_bits(5, 5) is the builder shorthand for the 5×5 operand map.
    let pac5 = EngineBuilder::new(model.clone())
        .pac(PacConfig::default())
        .approx_bits(5, 5)
        .build()
        .unwrap();
    let acc_5 = pac5.evaluate(&images, &labels, 8).unwrap().accuracy;
    assert!(acc_e - acc_5 <= 0.03, "5-bit loss: {acc_e} -> {acc_5}");
}
