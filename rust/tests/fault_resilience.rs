//! Fault-injection contracts (DESIGN.md §15): a `FaultConfig::off()`
//! engine is **bit-identical** to one built without the fault stage
//! (property-tested — logits and the full `RunStats`, fault and traffic
//! ledgers included), and a given `(seed, BER)` injects the **same**
//! per-layer counters no matter how the work is scheduled (tile
//! parallelism on/off, single-image vs batch lanes) — the
//! position-keyed RNG contract that makes fault sweeps reproducible.

use pacim::engine::{EngineBuilder, Fidelity, PacimError};
use pacim::fault::FaultConfig;
use pacim::nn::layers::synthetic::random_store;
use pacim::nn::{tiny_resnet, EscalationConfig, Model, PacConfig, RunStats};
use pacim::util::check::Checker;
use pacim::util::rng::Rng;
use pacim::util::Parallelism;

fn small_model(seed: u64, c: usize, classes: usize, hw: usize) -> Model {
    let mut rng = Rng::new(seed);
    tiny_resnet(&random_store(&mut rng, c, classes), hw, classes).unwrap()
}

fn image_for(model: &Model, rng: &mut Rng) -> Vec<u8> {
    (0..model.in_c * model.in_hw * model.in_hw)
        .map(|_| rng.below(256) as u8)
        .collect()
}

/// A PAC config whose layers all actually run approximate (the default
/// `min_dp_len: 512` keeps every layer of the 8×8 test model digital,
/// which would give the fault channels nothing to hit).
fn faultable_cfg(fuse: bool) -> PacConfig {
    PacConfig {
        first_layer_exact: false,
        min_dp_len: 0,
        fuse_dataplane: fuse,
        ..PacConfig::default()
    }
}

fn assert_all_stats_eq(a: &RunStats, b: &RunStats) {
    assert_eq!(a.macs, b.macs);
    assert_eq!(a.digital_cycles, b.digital_cycles);
    assert_eq!(a.pcu_ops, b.pcu_ops);
    assert_eq!(a.levels, b.levels);
    assert_eq!(a.traffic, b.traffic);
    assert_eq!(a.faults, b.faults);
    assert_eq!(a.escalations, b.escalations);
}

#[test]
fn prop_fault_off_is_bit_identical_to_no_fault_stage() {
    // `FaultConfig::off()` must be indistinguishable from never calling
    // `.fault(..)` at all: same logits, same statistics, empty ledger.
    // This is the "default off / zero cost" half of the §15 contract.
    Checker::new("fault_off_bit_identical", 16).run(|rng| {
        let model = small_model(rng.next_u64(), 4, 4, 8);
        let img = image_for(&model, rng);
        let cfg = faultable_cfg(rng.bernoulli(0.5));
        let par = if rng.bernoulli(0.5) {
            Parallelism::off()
        } else {
            Parallelism {
                enabled: true,
                min_items: 1,
            }
        };
        let plain = EngineBuilder::new(model.clone())
            .pac(cfg.clone())
            .parallelism(par)
            .build()
            .unwrap();
        let off = EngineBuilder::new(model)
            .pac(cfg)
            .fault(FaultConfig::off())
            .parallelism(par)
            .build()
            .unwrap();
        let a = plain.session().infer(&img).unwrap();
        let b = off.session().infer(&img).unwrap();
        assert_eq!(a.logits, b.logits, "fault-off engine logits diverged");
        assert_all_stats_eq(&a.stats, &b.stats);
        assert!(b.stats.faults.is_empty(), "fault-off run recorded injections");
    });
}

#[test]
fn prop_same_seed_same_ber_same_injections_across_schedules() {
    // The position-keyed RNG contract: injection sites depend only on
    // (seed, channel, position), never on tile order or thread count —
    // so the per-layer fault counters (and the faulted logits) agree
    // bit for bit between tile parallelism on and off, and between a
    // single-image run and a batch lane of the same image.
    Checker::new("fault_injection_schedule_invariant", 12).run(|rng| {
        let model = small_model(rng.next_u64(), 4, 4, 8);
        let img = image_for(&model, rng);
        let fc = FaultConfig::at_ber(rng.next_u64(), 1e-2);
        let build = |par: Parallelism| {
            EngineBuilder::new(model.clone())
                .pac(faultable_cfg(true))
                .fault(fc)
                .parallelism(par)
                .build()
                .unwrap()
        };
        let seq = build(Parallelism::off());
        let par = build(Parallelism {
            enabled: true,
            min_items: 1,
        });
        let a = seq.session().infer(&img).unwrap();
        let b = par.session().infer(&img).unwrap();
        assert_eq!(a.logits, b.logits, "faulted logits depend on schedule");
        assert_all_stats_eq(&a.stats, &b.stats);
        // At BER 1e-2 over thousands of weight-MSB bits the channels
        // cannot all stay silent — the sweep would otherwise "pass"
        // while injecting nothing.
        assert!(!a.stats.faults.is_empty(), "BER 1e-2 injected nothing");
        // Batch lanes reuse the same image nonce, so each lane carries
        // the identical ledger.
        let imgs = [img.as_slice(), img.as_slice()];
        for lane in par.session().infer_batch(&imgs).unwrap() {
            assert_eq!(lane.logits, a.logits);
            assert_eq!(lane.stats.faults, a.stats.faults);
        }
    });
}

#[test]
fn forced_escalation_recovers_exact_logits() {
    // With the monitor armed so aggressively that every sample trips it
    // (min_margin = +inf is rejected by validation, so use an absurdly
    // large finite margin), Fidelity::Auto must hand back the *exact*
    // backend's logits and count one escalation per image.
    let model = small_model(2025, 4, 4, 8);
    let mut rng = Rng::new(11);
    let img = image_for(&model, &mut rng);
    let exact = EngineBuilder::new(model.clone()).exact().build().unwrap();
    let want = exact.session().infer(&img).unwrap();
    let auto = EngineBuilder::new(model)
        .pac(faultable_cfg(false))
        .escalation(EscalationConfig {
            min_margin: 1e6,
            sigma: 0.0,
        })
        .build()
        .unwrap();
    let got = auto.session().infer_with(&img, Fidelity::Auto).unwrap();
    assert_eq!(got.logits, want.logits, "escalated logits must be exact");
    assert_eq!(got.stats.escalations, 1);
    // Fidelity::Fast on the same engine never escalates.
    let fast = auto.session().infer_with(&img, Fidelity::Fast).unwrap();
    assert_eq!(fast.stats.escalations, 0);
}

#[test]
fn fault_config_validation_and_backend_gating() {
    // Out-of-range BERs and non-finite noise are typed config errors...
    let model = small_model(7, 4, 4, 8);
    for bad in [
        FaultConfig {
            weight_msb_ber: 1.0,
            ..FaultConfig::off()
        },
        FaultConfig {
            edge_ber: -0.1,
            ..FaultConfig::off()
        },
        FaultConfig {
            pcu_noise: f64::NAN,
            ..FaultConfig::off()
        },
    ] {
        let err = EngineBuilder::new(model.clone()).pac(PacConfig::default()).fault(bad).build();
        assert!(
            matches!(err, Err(PacimError::InvalidConfig(_))),
            "invalid FaultConfig must be rejected at build()"
        );
    }
    // ...and the fault stage is PAC-only: the exact backend has no PAC
    // boundaries to corrupt.
    let err = EngineBuilder::new(model)
        .exact()
        .fault(FaultConfig::at_ber(1, 1e-3))
        .build();
    assert!(matches!(err, Err(PacimError::InvalidConfig(_))));
}
