//! Property-based tests over the crate's core invariants, using the
//! in-house `Checker` harness (proptest is unavailable offline).

use pacim::arch::ThresholdSet;
use pacim::nn::simd;
use pacim::nn::{
    pac_backend, run_model_with, ConvLayer, GemmInput, LinearLayer, MacBackend, Model,
    ModelScratch, Op, PacBackend, PacConfig, RunStats,
};
use pacim::pac::mac::{pac_cycle_f64, pcu_cycle, PcuRounding};
use pacim::pac::{
    exact_mac, exact_mac_bitserial, hybrid_mac, hybrid_mac_batch, par_hybrid_mac_batch,
    zero_point_correct, BitPlanes, ComputeMap, DynamicLevel,
};
use pacim::quant::{calibrate_minmax, calibrate_weights_symmetric, Requant};
use pacim::tensor::{im2col, Conv2dGeom, PackedPatches, QuantParams, Tensor};
use pacim::util::check::Checker;
use pacim::util::rng::Rng;
use pacim::util::{and_popcount, pack_bits_u64, KernelCaps, KernelTier, Parallelism};

#[test]
fn prop_bitserial_identity() {
    // Eq. 1 holds for every vector pair.
    Checker::new("bitserial_identity", 200).run(|rng| {
        let n = 1 + rng.below(600) as usize;
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        assert_eq!(exact_mac(&x, &w), exact_mac_bitserial(&xp, &wp));
    });
}

#[test]
fn prop_element_sum_equals_spec_score() {
    // Σv = Σ_p 2^p S[p] — the identity the zero-point correction and the
    // SPEC speculation both rely on.
    Checker::new("element_sum", 200).run(|rng| {
        let n = rng.below(1000) as usize;
        let v: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let bp = BitPlanes::from_u8(&v);
        let direct: u64 = v.iter().map(|&x| x as u64).sum();
        assert_eq!(bp.element_sum(), direct);
        assert_eq!(pacim::arch::spec_score(&bp.pop), direct);
    });
}

#[test]
fn prop_hybrid_interpolates_between_maps() {
    // All-digital == exact; estimate error decreases as digital cycles
    // grow (checked pairwise on the operand ladder for the same data).
    Checker::new("hybrid_ladder", 60).run(|rng| {
        let n = 64 + rng.below(400) as usize;
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        let exact = exact_mac(&x, &w) as i64;
        let all_dig = hybrid_mac(&xp, &wp, &ComputeMap::all_digital(), PcuRounding::RoundNearest);
        assert_eq!(all_dig.value, exact);
        let e8 = (hybrid_mac(&xp, &wp, &ComputeMap::operand_based(8, 8), PcuRounding::RoundNearest)
            .value - exact).abs();
        assert_eq!(e8, 0); // 8x8 operand == all digital
    });
}

#[test]
fn prop_pcu_cycle_bounds() {
    // 0 <= estimate <= n, and monotone in both sparsity counts.
    Checker::new("pcu_bounds", 300).run(|rng| {
        let n = 1 + rng.below(4096);
        let sx = rng.below(n + 1);
        let sw = rng.below(n + 1);
        let e = pcu_cycle(sx, sw, n, PcuRounding::RoundNearest);
        assert!(e <= n);
        if sx < n {
            assert!(pcu_cycle(sx + 1, sw, n, PcuRounding::RoundNearest) >= e);
        }
        // Floor <= RoundNearest <= Floor + 1.
        let f = pcu_cycle(sx, sw, n, PcuRounding::Floor);
        assert!(f <= e && e <= f + 1);
    });
}

#[test]
fn prop_pcu_cycle_tracks_f64_within_half_ulp() {
    // The PCU's fixed-point divide against the exact real value
    // `Sx·Sw/n` (pac_cycle_f64): RoundNearest lands within 0.5 of the
    // real quotient (an integer result cannot sit closer to a real than
    // half a unit), Floor within [0, 1) below it. The 1e-9 slack covers
    // the f64 division's own rounding (the operands are exact: Sx·Sw ≤
    // 2^26 and n ≤ 2^13 are both exactly representable).
    Checker::new("pcu_half_ulp", 400).run(|rng| {
        let n = 1 + rng.below(8192);
        let sx = rng.below(n + 1);
        let sw = rng.below(n + 1);
        let f = pac_cycle_f64(sx, sw, n);
        let nearest = pcu_cycle(sx, sw, n, PcuRounding::RoundNearest) as f64;
        assert!(
            (nearest - f).abs() <= 0.5 + 1e-9,
            "nearest: sx={sx} sw={sw} n={n} fixed={nearest} real={f}"
        );
        let floor = pcu_cycle(sx, sw, n, PcuRounding::Floor) as f64;
        assert!(
            floor <= f + 1e-9 && f - floor < 1.0 + 1e-9,
            "floor: sx={sx} sw={sw} n={n} fixed={floor} real={f}"
        );
    });
}

#[test]
fn prop_par_hybrid_mac_batch_bit_identical() {
    // The rayon-parallel batched kernel must reproduce the sequential
    // per-pair hybrid_mac exactly — every field of every HybridMac, over
    // random UINT8 DP vectors, lengths, and maps.
    Checker::new("par_batch_identity", 40).run(|rng| {
        let batch = 1 + rng.below(48) as usize;
        let n = 1 + rng.below(800) as usize;
        let bits = 1 + rng.below(8);
        let map = ComputeMap::operand_based(bits, bits);
        let rounding = if rng.bernoulli(0.5) {
            PcuRounding::RoundNearest
        } else {
            PcuRounding::Floor
        };
        let pairs: Vec<(BitPlanes, BitPlanes)> = (0..batch)
            .map(|_| {
                let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                (BitPlanes::from_u8(&x), BitPlanes::from_u8(&w))
            })
            .collect();
        let seq = hybrid_mac_batch(&pairs, &map, rounding);
        let par = par_hybrid_mac_batch(&pairs, &map, rounding);
        assert_eq!(seq, par);
        // And both agree with the scalar kernel element-wise.
        for (i, (xp, wp)) in pairs.iter().enumerate() {
            assert_eq!(par[i], hybrid_mac(xp, wp, &map, rounding), "pair {i}");
        }
    });
}

#[test]
fn prop_zero_point_correction_exact() {
    Checker::new("zp_correct", 200).run(|rng| {
        let n = 1 + rng.below(300) as usize;
        let zx = rng.below(256) as i32;
        let zw = rng.below(256) as i32;
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let raw = exact_mac(&x, &w) as i64;
        let sx: i64 = x.iter().map(|&v| v as i64).sum();
        let sw: i64 = w.iter().map(|&v| v as i64).sum();
        let got = zero_point_correct(raw, sx, sw, n as i64, zx, zw);
        let want: i64 = x.iter().zip(&w)
            .map(|(&a, &b)| (a as i64 - zx as i64) * (b as i64 - zw as i64))
            .sum();
        assert_eq!(got, want);
    });
}

#[test]
fn prop_quant_roundtrip() {
    Checker::new("quant_roundtrip", 300).run(|rng| {
        let lo = -(rng.next_f32() * 50.0);
        let hi = rng.next_f32() * 50.0 + 0.01;
        let p = calibrate_minmax(lo, hi);
        for _ in 0..16 {
            let x = lo + rng.next_f32() * (hi - lo);
            let err = (p.dequantize(p.quantize(x)) - x).abs();
            assert!(err <= p.scale * 0.5 + 1e-4, "x={x} err={err}");
        }
        // Zero must be exactly representable.
        assert!(p.dequantize(p.quantize(0.0)).abs() < 1e-6);
    });
}

#[test]
fn prop_symmetric_weights_sign_symmetry() {
    Checker::new("weight_symmetry", 100).run(|rng| {
        let n = 1 + rng.below(64) as usize;
        let w: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 4.0).collect();
        let t = Tensor::from_vec(&[n], w.clone());
        let p = calibrate_weights_symmetric(&t);
        assert_eq!(p.zero_point, 128);
        for &v in &w {
            let q = p.quantize(v) as i32 - 128;
            let qn = p.quantize(-v) as i32 - 128;
            assert_eq!(q, -qn, "v={v}");
        }
    });
}

#[test]
fn prop_requant_tracks_real_multiplier() {
    Checker::new("requant", 300).run(|rng| {
        let m = 1e-4 + rng.next_f64() * 0.9;
        let r = Requant::from_real(m);
        assert!((r.to_real() - m).abs() / m < 1e-8);
        let acc = rng.range_i64(-2_000_000, 2_000_000) as i32;
        let got = r.apply(acc) as f64;
        let want = acc as f64 * m;
        assert!((got - want).abs() <= 1.0, "acc={acc} m={m} got={got} want={want}");
    });
}

#[test]
fn prop_im2col_patch_contents() {
    // Every im2col entry is either a real input pixel or the pad value.
    Checker::new("im2col", 60).run(|rng| {
        let g = Conv2dGeom {
            in_c: 1 + rng.below(4) as usize,
            in_h: 4 + rng.below(8) as usize,
            in_w: 4 + rng.below(8) as usize,
            out_c: 1,
            kh: 3,
            kw: 3,
            stride: 1 + rng.below(2) as usize,
            pad: rng.below(2) as usize,
        };
        let input: Vec<u8> = (0..g.in_c * g.in_h * g.in_w)
            .map(|_| rng.below(255) as u8)
            .collect();
        let pad = 255u8; // distinct from data (data < 255)
        let cols = im2col(&input, &g, pad);
        let k = g.dp_len();
        assert_eq!(cols.len(), g.out_pixels() * k);
        // Count pad entries: must be 0 when pad=0.
        if g.pad == 0 {
            assert!(cols.iter().all(|&v| v != 255));
        }
    });
}

#[test]
fn prop_pack_bits_popcount() {
    Checker::new("pack_bits", 200).run(|rng| {
        let n = rng.below(500) as usize;
        let a: Vec<u8> = (0..n).map(|_| rng.bernoulli(0.5) as u8).collect();
        let b: Vec<u8> = (0..n).map(|_| rng.bernoulli(0.3) as u8).collect();
        let naive: u32 = a.iter().zip(&b).map(|(&x, &y)| (x & y) as u32).sum();
        assert_eq!(naive, and_popcount(&pack_bits_u64(&a), &pack_bits_u64(&b)));
    });
}

#[test]
fn prop_compute_map_partition() {
    Checker::new("map_partition", 100).run(|rng| {
        let bx = rng.below(9);
        let bw = rng.below(9);
        let m = ComputeMap::operand_based(bx, bw);
        assert_eq!(m.digital_cycles(), bx * bw);
        assert_eq!(m.digital_cycles() + m.sparsity_cycles(), 64);
        assert_eq!(m.required_weight_bits().len(), if bx == 0 { 0 } else { bw as usize });
    });
}

/// The pre-blocked per-patch engine as a [`MacBackend`]: drives
/// `PacBackend::gemm_per_patch_reference` one im2col patch at a time —
/// exactly the contract the engine had before the blocked GEMM refactor.
struct PerPatchEngine(PacBackend);

impl MacBackend for PerPatchEngine {
    fn prepare(&mut self, layer_id: usize, weight: &Tensor<u8>, zpw: i32) {
        self.0.prepare(layer_id, weight, zpw);
    }

    #[allow(clippy::too_many_arguments)]
    fn gemm_layer(
        &self,
        layer_id: usize,
        input: GemmInput<'_>,
        pixels: usize,
        zpx: i32,
        _nonce: u64,
        _par: &Parallelism,
        _planes: &mut PackedPatches,
        out: &mut Vec<i64>,
        stats: &mut RunStats,
    ) {
        // This engine never overrides `packed_input_bits`, so the
        // interpreter always hands it the dense matrix.
        let cols = match input {
            GemmInput::Dense(c) => c,
            GemmInput::Packed(_) => unreachable!("per-patch engine never requests packed input"),
        };
        out.clear();
        if pixels == 0 {
            return;
        }
        let k = cols.len() / pixels;
        for pix in 0..pixels {
            let accs = self.0.gemm_per_patch_reference(
                layer_id,
                &cols[pix * k..(pix + 1) * k],
                zpx,
                stats,
            );
            out.extend_from_slice(&accs);
        }
    }
}

/// One random single-conv model (conv → GAP → logits) over a random
/// geometry: kernel ∈ {1,3}, stride ∈ {1,2}, padding ∈ {0,1}.
fn random_conv_model(rng: &mut Rng) -> (Model, Vec<u8>) {
    let kernel = if rng.bernoulli(0.5) { 1 } else { 3 };
    let stride = 1 + rng.below(2) as usize;
    let pad = rng.below(2) as usize;
    let in_c = 1 + rng.below(4) as usize;
    let out_c = 1 + rng.below(12) as usize;
    let hw = 6 + rng.below(6) as usize;
    let geom = Conv2dGeom {
        in_c,
        in_h: hw,
        in_w: hw,
        out_c,
        kh: kernel,
        kw: kernel,
        stride,
        pad,
    };
    let k = geom.dp_len();
    let weight: Vec<u8> = (0..out_c * k).map(|_| rng.below(256) as u8).collect();
    let conv = ConvLayer {
        name: "c0".into(),
        geom,
        weight: Tensor::from_vec(&[out_c, k], weight),
        wparams: QuantParams::new(0.02, 128),
        bias: (0..out_c).map(|_| (rng.next_f32() - 0.5) * 0.1).collect(),
        out_params: QuantParams::new(0.05, 32),
        relu: true,
    };
    let fc_w: Vec<u8> = (0..3 * out_c).map(|_| rng.below(256) as u8).collect();
    let lin = LinearLayer {
        name: "fc".into(),
        in_f: out_c,
        out_f: 3,
        weight: Tensor::from_vec(&[3, out_c], fc_w),
        wparams: QuantParams::new(0.03, 128),
        bias: vec![0.0; 3],
        out_params: None,
        relu: false,
    };
    let model = Model {
        name: "prop_conv".into(),
        ops: vec![Op::Conv2d(conv), Op::GlobalAvgPool, Op::Linear(lin)],
        input_params: QuantParams::new(1.0 / 64.0, 128),
        in_c,
        in_hw: hw,
        num_classes: 3,
    };
    let img: Vec<u8> = (0..in_c * hw * hw).map(|_| rng.below(256) as u8).collect();
    (model, img)
}

#[test]
fn prop_blocked_engine_matches_per_patch_engine() {
    // The tentpole invariant: the blocked layer-level GEMM is bit-
    // identical (logits *and* statistics) to the sequential per-patch
    // engine it replaced, across random geometries, all four dynamic-
    // level maps, thresholds on/off, both roundings, the exact-fallback
    // path, and tile fan-out on/off.
    Checker::new("blocked_vs_per_patch", 48).run(|rng| {
        let (model, img) = random_conv_model(rng);
        let variant = rng.below(6);
        let (map, thresholds) = match variant {
            0..=3 => (DynamicLevel::all()[variant as usize].map(), None),
            4 => (ComputeMap::operand_based(4, 4), None),
            _ => (
                ComputeMap::operand_based(4, 4),
                Some(ThresholdSet::new(0.08, 0.16, 0.30)),
            ),
        };
        let cfg = PacConfig {
            map,
            thresholds,
            rounding: if rng.bernoulli(0.5) {
                PcuRounding::RoundNearest
            } else {
                PcuRounding::Floor
            },
            first_layer_exact: rng.bernoulli(0.25),
            min_dp_len: 0,
            par: Parallelism::off(),
            fuse_dataplane: rng.bernoulli(0.5),
            kernel: None,
            weight_skip: rng.bernoulli(0.5),
        };
        let blocked = pac_backend(&model, cfg.clone());
        let reference = PerPatchEngine(pac_backend(&model, cfg));
        let (b_ref, s_ref) = run_model_with(
            &model,
            &reference,
            &img,
            &Parallelism::off(),
            &mut ModelScratch::default(),
        )
        .expect("per-patch reference executes");
        for par in [
            Parallelism::off(),
            Parallelism {
                enabled: true,
                min_items: 1,
            },
        ] {
            let (b, s) =
                run_model_with(&model, &blocked, &img, &par, &mut ModelScratch::default())
                    .expect("blocked run executes");
            assert_eq!(b, b_ref, "logits diverged (variant {variant})");
            assert_eq!(s.macs, s_ref.macs);
            assert_eq!(s.digital_cycles, s_ref.digital_cycles);
            assert_eq!(s.pcu_ops, s_ref.pcu_ops);
            assert_eq!(s.levels, s_ref.levels);
        }
    });
}

/// One random packed plane: each word is empty, sparse, dense, or full,
/// so sweeps see zero words (skip fodder), ragged tails, and saturation.
fn random_plane(rng: &mut Rng, words: usize) -> Vec<u64> {
    (0..words)
        .map(|_| match rng.below(4) {
            0 => 0,
            1 => rng.next_u64() & rng.next_u64() & rng.next_u64(),
            2 => rng.next_u64(),
            _ => u64::MAX,
        })
        .collect()
}

#[test]
fn prop_simd_sweeps_bit_identical_across_tiers() {
    // Kernel-level pin for the SIMD tentpole: every tier the capability
    // probe can clamp a request to (asking for Avx512 on an AVX2-only
    // host yields Avx2, etc.) produces exactly the counts of the frozen
    // scalar sweep — with and without a weight zero-word skip bitmap —
    // over random word counts covering full vector blocks and ragged
    // scalar tails.
    Checker::new("simd_sweeps", 120).run(|rng| {
        let words = 1 + rng.below(130) as usize;
        let x0 = random_plane(rng, words);
        let x1 = random_plane(rng, words);
        let wmsb: Vec<u64> = (0..4).flat_map(|_| random_plane(rng, words)).collect();
        // Bit b of the skip bitmap is set iff word b of any MSB weight
        // plane is non-zero — exactly how `PacBackend::prepare` builds it.
        let mut skip = vec![0u64; words.div_ceil(64)];
        for b in 0..words {
            if (0..4).any(|q| wmsb[q * words + b] != 0) {
                skip[b / 64] |= 1 << (b % 64);
            }
        }
        let base0 = simd::sweep4_scalar(&x0, &wmsb);
        let base1 = simd::sweep4_scalar(&x1, &wmsb);
        for req in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512] {
            let caps = KernelCaps::select(Some(req));
            assert_eq!(simd::sweep4(caps, &x0, &wmsb, None), base0, "{req:?} no-skip");
            assert_eq!(simd::sweep4(caps, &x0, &wmsb, Some(&skip)), base0, "{req:?} skip");
            let pair = simd::sweep4_pair(caps, &x0, &x1, &wmsb, Some(&skip));
            assert_eq!(pair, [base0, base1], "{req:?} pair skip");
            let pair = simd::sweep4_pair(caps, &x0, &x1, &wmsb, None);
            assert_eq!(pair, [base0, base1], "{req:?} pair no-skip");
            assert_eq!(
                simd::and_popcount(caps, &x0, &wmsb[..words]),
                and_popcount(&x0, &wmsb[..words]),
                "{req:?} and_popcount"
            );
        }
    });
}

/// A wide random conv (dp_len ≥ 288, so the zero-word bitmap clears the
/// `SKIP_MIN_WORDS` floor) whose weight columns are MSB-sparse in whole
/// 64-lane blocks — the pattern the skip bitmap actually exploits.
fn random_wide_conv_model(rng: &mut Rng) -> (Model, Vec<u8>) {
    let in_c = 32 + rng.below(33) as usize;
    let out_c = 3 + rng.below(6) as usize;
    let hw = 4 + rng.below(3) as usize;
    let geom = Conv2dGeom {
        in_c,
        in_h: hw,
        in_w: hw,
        out_c,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
    };
    let k = geom.dp_len();
    let mut weight = vec![0u8; out_c * k];
    for col in weight.chunks_mut(k) {
        for block in col.chunks_mut(64) {
            // An all-< 16 block has zero MSB planes → a dead skip word.
            let msb_dead = rng.bernoulli(0.7);
            for v in block.iter_mut() {
                *v = if msb_dead { rng.below(16) as u8 } else { rng.below(256) as u8 };
            }
        }
    }
    let conv = ConvLayer {
        name: "wide".into(),
        geom,
        weight: Tensor::from_vec(&[out_c, k], weight),
        wparams: QuantParams::new(0.02, 128),
        bias: (0..out_c).map(|_| (rng.next_f32() - 0.5) * 0.1).collect(),
        out_params: QuantParams::new(0.05, 32),
        relu: true,
    };
    let fc_w: Vec<u8> = (0..3 * out_c).map(|_| rng.below(256) as u8).collect();
    let lin = LinearLayer {
        name: "fc".into(),
        in_f: out_c,
        out_f: 3,
        weight: Tensor::from_vec(&[3, out_c], fc_w),
        wparams: QuantParams::new(0.03, 128),
        bias: vec![0.0; 3],
        out_params: None,
        relu: false,
    };
    let model = Model {
        name: "prop_wide_conv".into(),
        ops: vec![Op::Conv2d(conv), Op::GlobalAvgPool, Op::Linear(lin)],
        input_params: QuantParams::new(1.0 / 64.0, 128),
        in_c,
        in_hw: hw,
        num_classes: 3,
    };
    let img: Vec<u8> = (0..in_c * hw * hw).map(|_| rng.below(256) as u8).collect();
    (model, img)
}

#[test]
fn prop_kernel_tiers_and_weight_skip_model_identical() {
    // End-to-end pin: logits AND modeled statistics are invariant under
    // every kernel-tier request (clamped by the probe) and under weight
    // zero-word skipping, on both the static 4×4 map and the dynamic
    // threshold ladder. Skipping is an exact transform (x & 0 = 0), so
    // any divergence — numeric or in the cycle ledger — is a bug.
    Checker::new("kernel_tiers_model", 20).run(|rng| {
        let (model, img) = random_wide_conv_model(rng);
        let base_cfg = PacConfig {
            map: ComputeMap::operand_based(4, 4),
            thresholds: if rng.bernoulli(0.5) {
                Some(ThresholdSet::new(0.08, 0.16, 0.30))
            } else {
                None
            },
            rounding: if rng.bernoulli(0.5) {
                PcuRounding::RoundNearest
            } else {
                PcuRounding::Floor
            },
            first_layer_exact: false,
            min_dp_len: 0,
            par: Parallelism::off(),
            fuse_dataplane: rng.bernoulli(0.5),
            kernel: Some(KernelTier::Scalar),
            weight_skip: false,
        };
        let base = pac_backend(&model, base_cfg.clone());
        let (b_ref, s_ref) = run_model_with(
            &model,
            &base,
            &img,
            &Parallelism::off(),
            &mut ModelScratch::default(),
        )
        .expect("scalar baseline executes");
        let tiers = [
            Some(KernelTier::Scalar),
            Some(KernelTier::Avx2),
            Some(KernelTier::Avx512),
            None,
        ];
        for kernel in tiers {
            for weight_skip in [false, true] {
                let cfg = PacConfig {
                    kernel,
                    weight_skip,
                    ..base_cfg.clone()
                };
                let eng = pac_backend(&model, cfg);
                if weight_skip {
                    // The sparse fill must actually engage the bitmap,
                    // or this test silently stops covering the skip path.
                    let (live, total, active) = eng.weight_skip_profile(0);
                    assert!(active > 0, "skip auto-off unexpectedly disabled all columns");
                    assert!(live < total, "no dead words despite MSB-sparse fill");
                }
                let (b, s) = run_model_with(
                    &model,
                    &eng,
                    &img,
                    &Parallelism::off(),
                    &mut ModelScratch::default(),
                )
                .expect("kernel-tier run executes");
                assert_eq!(b, b_ref, "logits diverged: kernel {kernel:?} skip {weight_skip}");
                assert_eq!(s.macs, s_ref.macs);
                assert_eq!(s.digital_cycles, s_ref.digital_cycles);
                assert_eq!(s.pcu_ops, s_ref.pcu_ops);
                assert_eq!(s.levels, s_ref.levels);
            }
        }
    });
}

#[test]
fn prop_priced_lambda0_is_the_cycles_only_schedule() {
    // The λ=0 contract (DESIGN.md §14): traffic-priced scheduling with a
    // zero traffic price reproduces the legacy cycles-only multibank
    // schedule bit-for-bit, for every random workload × bank config.
    use pacim::arch::{
        schedule_network_multibank, schedule_network_priced, MultiBankConfig, TrafficPrice,
    };
    use pacim::workload::LayerShape;
    Checker::new("priced_lambda0", 80).run(|rng| {
        let n_layers = 1 + rng.below(6) as usize;
        let shapes: Vec<LayerShape> = (0..n_layers)
            .map(|i| {
                let name = format!("l{i}");
                if rng.bernoulli(0.25) {
                    let in_f = 16 + rng.below(1024) as usize;
                    LayerShape::linear(&name, in_f, 1 + rng.below(1000) as usize)
                } else {
                    let k = if rng.bernoulli(0.5) { 1 } else { 3 };
                    LayerShape::conv(
                        &name,
                        1 + rng.below(512) as usize,
                        1 + rng.below(512) as usize,
                        2 + rng.below(32) as usize,
                        k,
                        1 + rng.below(2) as usize,
                    )
                }
            })
            .collect();
        let cfg = MultiBankConfig {
            banks: 1 + rng.below(8) as usize,
            rows: [64, 128, 256][rng.below(3) as usize],
            mwcs: [16, 64][rng.below(2) as usize],
        };
        let price = TrafficPrice::default(); // lambda = 0
        let priced = schedule_network_priced(&shapes, &cfg, &price);
        assert_eq!(priced.to_multibank(), schedule_network_multibank(&shapes, &cfg));
        // Every group at λ=0 keeps the legacy staging: spill policy, no
        // replayed layers.
        assert_eq!(priced.replayed_layers(), 0);
    });
}

#[test]
fn prop_pareto_front_is_sound_and_order_invariant() {
    // Front invariants over random point clouds: non-empty, mutually
    // non-dominating, covering (every off-front point is dominated),
    // deterministic, and invariant (as a set of point values) under
    // permutation of the candidate order.
    use pacim::arch::dse::{dominates, pareto_front, DsePoint};
    Checker::new("pareto_front", 120).run(|rng| {
        let n = 1 + rng.below(40) as usize;
        let mut points: Vec<DsePoint> = (0..n)
            .map(|_| DsePoint {
                banks: 1 + rng.below(8) as usize,
                rows: 64 << rng.below(3),
                thresholds: None,
                lambda: rng.below(4) as f64 * 0.005,
                accuracy: rng.below(5) as f64 * 0.25,
                avg_digital_cycles: 10.0 + rng.below(7) as f64,
                cycles: 1 + rng.below(8) as u64,
                bits: 1 + rng.below(8) as u64,
            })
            .collect();
        let front = pareto_front(&points);
        assert!(!front.is_empty(), "front of a non-empty cloud is non-empty");
        for &i in &front {
            for &j in &front {
                assert!(!dominates(&points[i], &points[j]), "front point dominates another");
            }
        }
        for i in 0..points.len() {
            if !front.contains(&i) {
                assert!(
                    points.iter().any(|p| dominates(p, &points[i])),
                    "off-front point {i} is not dominated by anything"
                );
            }
        }
        assert_eq!(front, pareto_front(&points), "front is deterministic");
        // Permute and compare the fronts as sorted multisets of values.
        let key = |p: &DsePoint| (p.accuracy.to_bits(), p.cycles, p.bits);
        let mut before: Vec<_> = front.iter().map(|&i| key(&points[i])).collect();
        for i in (1..points.len()).rev() {
            points.swap(i, rng.below(i as u32 + 1) as usize);
        }
        let mut after: Vec<_> = pareto_front(&points).iter().map(|&i| key(&points[i])).collect();
        before.sort_unstable();
        after.sort_unstable();
        assert_eq!(before, after, "front changed under candidate reordering");
    });
}

#[test]
fn prop_encoder_matches_direct_counts() {
    use pacim::arch::encoder::{EncodingMode, SparsityEncoder};
    use pacim::pac::bit_sparsity_counts;
    Checker::new("encoder", 100).run(|rng| {
        let n = rng.below(400) as usize;
        let vals: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let mut enc = SparsityEncoder::new(EncodingMode::LayerWise);
        // Interrupt at a random point (intermediate buffer roundtrip).
        let cut = if n > 0 { rng.below(n as u32) as usize } else { 0 };
        enc.push_slice(&vals[..cut]);
        enc.save_to_buffer();
        enc.restore_from_buffer();
        enc.push_slice(&vals[cut..]);
        let g = enc.finalize_group();
        assert_eq!(g.counters, bit_sparsity_counts(&vals));
        assert_eq!(g.count as usize, n);
    });
}
