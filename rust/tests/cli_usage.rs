//! CLI usage-text contract: an unknown subcommand must print the full
//! subcommand menu (every subcommand, one line each) so users can
//! discover `pacim tune` & friends without reading the source.

use std::process::Command;

/// Every subcommand the binary advertises. Keep in sync with
/// `SUBCOMMANDS` in `src/main.rs` — this test is the pin.
const EXPECTED: &[&str] =
    &["info", "map", "rmse", "simulate", "accuracy", "serve", "tune", "faultsweep"];

fn usage_stderr(arg: Option<&str>) -> String {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_pacim"));
    if let Some(a) = arg {
        cmd.arg(a);
    }
    let out = cmd.output().expect("spawn pacim");
    assert!(
        out.status.success(),
        "usage path must exit 0, got {:?}",
        out.status
    );
    String::from_utf8(out.stderr).expect("stderr utf8")
}

#[test]
fn unknown_subcommand_lists_every_subcommand() {
    let err = usage_stderr(Some("frobnicate"));
    assert!(err.contains("usage: pacim"), "missing usage header:\n{err}");
    assert!(err.contains("subcommands:"), "missing menu header:\n{err}");
    for name in EXPECTED {
        assert!(
            err.contains(&format!("pacim {name}")),
            "usage text does not mention subcommand '{name}':\n{err}"
        );
    }
    // Each menu row carries a one-line description, not just the name.
    let tune_row = err
        .lines()
        .find(|l| l.trim_start().starts_with("pacim tune"))
        .expect("tune row present");
    assert!(
        tune_row.contains("autotune"),
        "tune row lacks its description: {tune_row}"
    );
}

#[test]
fn bare_invocation_prints_the_same_menu() {
    let err = usage_stderr(None);
    assert!(err.contains("usage: pacim"), "missing usage header:\n{err}");
    for name in EXPECTED {
        assert!(
            err.contains(&format!("pacim {name}")),
            "usage text does not mention subcommand '{name}':\n{err}"
        );
    }
}
