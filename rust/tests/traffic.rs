//! Traffic accounting: the measured `TrafficLedger` (what the executor
//! actually moved between layers) against the analytic `memory::traffic`
//! / `coordinator::scheduler` model (what the closed form predicts from
//! geometry), the fused residual dataplane (DESIGN.md §12) against the
//! dense round-trip, plus the paper's deep-layer reduction band measured
//! on a ResNet-18-width network.

use pacim::coordinator::{schedule_layer, ScheduleConfig};
use pacim::engine::EngineBuilder;
use pacim::memory::{activation_traffic, residual_traffic, EdgeKind};
use pacim::nn::layers::synthetic::random_store;
use pacim::nn::{
    pac_backend, run_model_with, tiny_resnet, ConvLayer, LinearLayer, Model, ModelScratch, Op,
    PacConfig, RunStats,
};
use pacim::tensor::{Conv2dGeom, QuantParams, Tensor};
use pacim::util::check::Checker;
use pacim::util::rng::Rng;
use pacim::util::Parallelism;
use pacim::workload::{LayerShape, LayerShapeKind};

fn run_par(model: &Model, cfg: PacConfig, img: &[u8], par: Parallelism) -> (Vec<f32>, RunStats) {
    let backend = pac_backend(model, cfg);
    run_model_with(model, &backend, img, &par, &mut ModelScratch::default())
        .expect("synthetic model executes")
}

fn run(model: &Model, cfg: PacConfig, img: &[u8]) -> (Vec<f32>, RunStats) {
    run_par(model, cfg, img, Parallelism::off())
}

fn rand_conv(
    rng: &mut Rng,
    name: String,
    in_c: usize,
    out_c: usize,
    hw: usize,
    kernel: usize,
    stride: usize,
    relu: bool,
) -> (Op, usize) {
    let geom = Conv2dGeom {
        in_c,
        in_h: hw,
        in_w: hw,
        out_c,
        kh: kernel,
        kw: kernel,
        stride,
        pad: kernel / 2,
    };
    let k = geom.dp_len();
    let weight: Vec<u8> = (0..out_c * k).map(|_| rng.below(256) as u8).collect();
    let out_hw = geom.out_h();
    let op = Op::Conv2d(ConvLayer {
        name,
        geom,
        weight: Tensor::from_vec(&[out_c, k], weight),
        wparams: QuantParams::new(0.02, 128),
        bias: (0..out_c).map(|_| (rng.next_f32() - 0.5) * 0.1).collect(),
        out_params: QuantParams::new(0.05, 32),
        relu,
    });
    (op, out_hw)
}

fn finish_model(mut ops: Vec<Op>, in_c0: usize, hw0: usize, last_c: usize, rng: &mut Rng) -> Model {
    ops.push(Op::GlobalAvgPool);
    let fc_w: Vec<u8> = (0..3 * last_c).map(|_| rng.below(256) as u8).collect();
    ops.push(Op::Linear(LinearLayer {
        name: "fc".into(),
        in_f: last_c,
        out_f: 3,
        weight: Tensor::from_vec(&[3, last_c], fc_w),
        wparams: QuantParams::new(0.03, 128),
        bias: vec![0.0; 3],
        out_params: None,
        relu: false,
    }));
    Model {
        name: "traffic_stack".into(),
        ops,
        input_params: QuantParams::new(1.0 / 64.0, 128),
        in_c: in_c0,
        in_hw: hw0,
        num_classes: 3,
    }
}

/// A random stack of chained convolutions (kernel ∈ {1,3}, stride ∈
/// {1,2}, matching padding) followed by GAP + logits — every conv but
/// the last has a conv consumer, so under `min_dp_len = 0` every such
/// edge rides the encoded dataplane.
fn random_conv_stack(rng: &mut Rng) -> (Model, Vec<u8>) {
    let depth = 2 + rng.below(2) as usize;
    let mut in_c = 1 + rng.below(4) as usize;
    let mut hw = 8 + rng.below(5) as usize;
    let in_c0 = in_c;
    let hw0 = hw;
    let mut ops = Vec::new();
    for i in 0..depth {
        let kernel = if rng.bernoulli(0.5) { 1 } else { 3 };
        let stride = 1 + rng.below(2) as usize;
        let out_c = 1 + rng.below(12) as usize;
        let relu = rng.bernoulli(0.7);
        let (op, out_hw) = rand_conv(rng, format!("c{i}"), in_c, out_c, hw, kernel, stride, relu);
        ops.push(op);
        in_c = out_c;
        hw = out_hw;
    }
    let model = finish_model(ops, in_c0, hw0, in_c, rng);
    let img: Vec<u8> = (0..in_c0 * hw0 * hw0).map(|_| rng.below(256) as u8).collect();
    (model, img)
}

/// A random resnet-style stack: stem conv, then 2–3 residual blocks
/// (`SaveSkip; conv1; conv2; AddSkip`) joined by transition convs with
/// mixed strides and widths, then GAP + logits — the shape family the
/// fused residual dataplane must reproduce bit for bit against the
/// dense round-trip.
fn random_resnet_stack(rng: &mut Rng) -> (Model, Vec<u8>) {
    let blocks = 2 + rng.below(2) as usize;
    let in_c0 = 3;
    let hw0 = 12 + 4 * rng.below(2) as usize;
    let mut ch = 2 + rng.below(6) as usize;
    let mut hw = hw0;
    let mut ops = Vec::new();
    let (stem, out_hw) = rand_conv(rng, "stem".into(), in_c0, ch, hw, 3, 1, true);
    ops.push(stem);
    hw = out_hw;
    for b in 0..blocks {
        if b > 0 {
            let stride = 1 + rng.below(2) as usize;
            let out_c = 2 + rng.below(8) as usize;
            let (t, t_hw) =
                rand_conv(rng, format!("trans{b}"), ch, out_c, hw, 3, stride, true);
            ops.push(t);
            ch = out_c;
            hw = t_hw;
        }
        ops.push(Op::SaveSkip);
        for (i, relu) in [(1usize, true), (2, rng.bernoulli(0.5))] {
            let kernel = if rng.bernoulli(0.5) { 1 } else { 3 };
            let (c, _) =
                rand_conv(rng, format!("b{b}.conv{i}"), ch, ch, hw, kernel, 1, relu);
            ops.push(c);
        }
        ops.push(Op::AddSkip {
            out_params: QuantParams::new(0.06, 30),
            relu: rng.bernoulli(0.7),
        });
    }
    let model = finish_model(ops, in_c0, hw0, ch, rng);
    let img: Vec<u8> = (0..in_c0 * hw0 * hw0).map(|_| rng.below(256) as u8).collect();
    (model, img)
}

#[test]
fn prop_measured_ledger_matches_analytic_model() {
    // For random conv/linear geometries, every measured ledger entry
    // must equal the closed-form `memory::traffic` prediction for its
    // edge — bits, baseline, and the scheduler's per-layer accounting
    // (which counts write + read, i.e. exactly 2× the ledger's
    // one-direction bits).
    Checker::new("ledger_vs_analytic", 32).run(|rng| {
        let (model, img) = random_conv_stack(rng);
        let cfg = PacConfig {
            first_layer_exact: rng.bernoulli(0.3),
            min_dp_len: 0,
            par: Parallelism::off(),
            ..PacConfig::default()
        };
        let (_, stats) = run(&model, cfg, &img);
        let convs: Vec<&ConvLayer> = model
            .ops
            .iter()
            .filter_map(|op| match op {
                Op::Conv2d(c) => Some(c),
                _ => None,
            })
            .collect();
        let sched_cfg = ScheduleConfig::pacim_default();
        for (i, conv) in convs.iter().enumerate() {
            let e = stats.traffic.layer(i).unwrap_or_else(|| panic!("no entry for conv {i}"));
            let g = &conv.geom;
            let groups = g.out_pixels() as u64;
            assert_eq!(e.groups, groups, "conv {i} groups");
            assert_eq!(e.group_elems, g.out_c as u64, "conv {i} channels");
            // A skip-free stack only produces payload edges.
            assert!(matches!(e.kind, EdgeKind::Conv | EdgeKind::Pool), "conv {i} kind");
            // Every conv with a conv consumer rides the encoded
            // dataplane (min_dp_len = 0); the last conv feeds GAP and
            // stays dense.
            assert_eq!(e.encoded, i + 1 < convs.len(), "conv {i} encode decision");
            let t = activation_traffic(g.out_c, 4);
            let want_bits = if e.encoded { groups * t.pacim } else { groups * t.baseline };
            assert_eq!(e.bits, want_bits, "conv {i} measured bits");
            assert_eq!(e.baseline_bits, groups * t.baseline, "conv {i} baseline");
            // Cross-check against the scheduler's analytic accounting
            // (assumes every edge encoded, write + read).
            let shape = LayerShape {
                name: conv.name.clone(),
                kind: LayerShapeKind::Conv,
                geom: *g,
            };
            let rep = schedule_layer(&shape, &sched_cfg);
            assert_eq!(rep.act_bits_baseline, 2 * e.baseline_bits, "conv {i} sched baseline");
            if e.encoded {
                assert_eq!(rep.act_bits_pacim, 2 * e.bits, "conv {i} sched pacim");
            }
        }
        // The terminal logits layer is host output, never a cache edge.
        assert!(stats.traffic.layer(convs.len()).is_none());
    });
}

#[test]
fn prop_fused_and_roundtrip_ledgers_share_baselines() {
    // Fusion changes how bits move, never how many elements exist: the
    // dense round-trip and the fused run must agree on every edge's
    // baseline, and on logits + counters bit for bit.
    Checker::new("ledger_fused_vs_dense", 24).run(|rng| {
        let (model, img) = random_conv_stack(rng);
        let fle = rng.bernoulli(0.3);
        let mk = |fuse| PacConfig {
            first_layer_exact: fle,
            min_dp_len: 0,
            par: Parallelism::off(),
            fuse_dataplane: fuse,
            ..PacConfig::default()
        };
        let (a, sa) = run(&model, mk(false), &img);
        let (b, sb) = run(&model, mk(true), &img);
        assert_eq!(a, b, "logits diverged");
        assert_eq!(sa.macs, sb.macs);
        assert_eq!(sa.digital_cycles, sb.digital_cycles);
        assert_eq!(sa.pcu_ops, sb.pcu_ops);
        assert_eq!(sa.traffic.encoded_layer_count(), 0);
        assert_eq!(sa.traffic.total_baseline_bits(), sb.traffic.total_baseline_bits());
        for (ea, eb) in sa.traffic.layers().iter().zip(sb.traffic.layers()) {
            assert_eq!(ea.layer_id, eb.layer_id);
            assert_eq!(ea.kind, eb.kind);
            assert_eq!(ea.groups, eb.groups);
            assert_eq!(ea.baseline_bits, eb.baseline_bits);
        }
    });
}

#[test]
fn prop_fused_residual_dataplane_is_transparent() {
    // Random resnet-style nets (skip depth ≥ 2, mixed strides/widths,
    // parallelism on or off): `fuse_dataplane` must switch only the
    // *representation* of the residual edges. Logits and every compute
    // counter stay identical, the ledger carries the same row set with
    // the same baselines, the fused add-in edges are eliminated
    // outright, every fused row matches the `memory::traffic` closed
    // form, and the residual edges as a whole move strictly fewer bits
    // than their dense round-trip.
    Checker::new("residual_fused_vs_dense", 20).run(|rng| {
        let (model, img) = random_resnet_stack(rng);
        let blocks =
            model.ops.iter().filter(|op| matches!(op, Op::SaveSkip)).count() as u64;
        assert!(blocks >= 2, "generator must produce skip depth >= 2");
        let par = if rng.bernoulli(0.5) { Parallelism::auto() } else { Parallelism::off() };
        let fle = rng.bernoulli(0.3);
        let mk = |fuse| PacConfig {
            first_layer_exact: fle,
            min_dp_len: 0,
            par,
            fuse_dataplane: fuse,
            ..PacConfig::default()
        };
        let (a, sa) = run_par(&model, mk(false), &img, par);
        let (b, sb) = run_par(&model, mk(true), &img, par);
        assert_eq!(a, b, "logits diverged");
        assert_eq!(sa.macs, sb.macs);
        assert_eq!(sa.digital_cycles, sb.digital_cycles);
        assert_eq!(sa.pcu_ops, sb.pcu_ops);

        // Row sets are 1:1 — same (layer_id, kind) keys, same geometry,
        // same baselines; only the moved-bit column may differ.
        assert_eq!(sa.traffic.layers().len(), sb.traffic.layers().len());
        for (ea, eb) in sa.traffic.layers().iter().zip(sb.traffic.layers()) {
            assert_eq!((ea.layer_id, ea.kind), (eb.layer_id, eb.kind));
            assert_eq!(ea.groups, eb.groups);
            assert_eq!(ea.group_elems, eb.group_elems);
            assert_eq!(ea.baseline_bits, eb.baseline_bits);
        }
        // Dense round-trip: nothing encoded, every edge at baseline.
        assert_eq!(sa.traffic.encoded_layer_count(), 0);
        for e in sa.traffic.layers() {
            assert_eq!(e.bits, e.baseline_bits);
        }
        // Fused: each block contributes its save/in/add triple; the
        // add-in edges vanish, and every row matches the closed form.
        let kind_count = |k| sb.traffic.layers().iter().filter(|e| e.kind == k).count() as u64;
        assert_eq!(kind_count(EdgeKind::ResidualSave), blocks);
        assert_eq!(kind_count(EdgeKind::ResidualIn), blocks);
        assert_eq!(kind_count(EdgeKind::ResidualAdd), blocks);
        for e in sb.traffic.layers() {
            if e.kind == EdgeKind::ResidualIn {
                assert!(e.is_eliminated(), "fused add-in edge must be eliminated");
            }
            let want = if e.is_eliminated() {
                0
            } else if e.encoded {
                e.groups * activation_traffic(e.group_elems as usize, e.msb_bits).pacim
            } else {
                e.groups * e.group_elems * 8
            };
            assert_eq!(e.bits, want, "layer {} {:?}", e.layer_id, e.kind);
        }
        // The residual edges as a whole move strictly fewer bits than
        // their dense round-trip (`residual_traffic`'s C >= 2
        // strictness claim; the generator never draws C = 1). The
        // *network* total is deliberately not asserted: at the tiny
        // widths drawn here an encoded conv payload edge can honestly
        // cost more than dense (counter overhead — the crossover
        // `memory::traffic` exposes on purpose).
        let residual = [EdgeKind::ResidualSave, EdgeKind::ResidualIn, EdgeKind::ResidualAdd];
        let (mut res_fused, mut res_dense) = (0u64, 0u64);
        for e in sb.traffic.layers().iter().filter(|e| residual.contains(&e.kind)) {
            res_fused += e.bits;
            res_dense += e.baseline_bits;
        }
        assert!(res_fused < res_dense, "residual triples must beat the dense round-trip");
        for e in sb.traffic.layers().iter().filter(|e| e.kind == EdgeKind::ResidualSave) {
            let rt = residual_traffic(e.group_elems as usize, e.groups, 4);
            assert_eq!(e.bits, rt.save.pacim);
            assert!(rt.total().pacim < rt.total().baseline);
        }
    });
}

#[test]
fn deep_resnet18_width_edges_land_in_the_papers_band() {
    // End-to-end on a network with the CIFAR ResNet-18 channel ladder
    // (64 → 128 → 256): the measured reduction on deep encoded payload
    // edges must land in Fig. 7(b)'s 40–50% band, under the *default*
    // engine configuration (first layer digital, PAC above DP 512,
    // dataplane fused) — the same path `pacim accuracy` and serving
    // run. Since the fused residual dataplane landed, the ledger holds
    // 15 rows: 9 conv payload edges plus a save/in/add triple per
    // residual block, with only the block3 add→GAP handoff dense.
    let mut rng = Rng::new(1818);
    let model = tiny_resnet(&random_store(&mut rng, 64, 10), 16, 10).unwrap();
    let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();

    let engine = EngineBuilder::new(model.clone())
        .pac(PacConfig {
            par: Parallelism::off(),
            ..PacConfig::default()
        })
        .build()
        .unwrap();
    let out = engine.session().infer(&img).unwrap();
    let ledger = &out.stats.traffic;
    let rows = engine.traffic_rows(ledger);
    assert_eq!(rows.len(), 15, "9 conv edges + 3 residual triples (fc logits are host output)");

    let find = |name: &str, kind: EdgeKind| {
        rows.iter()
            .find(|(n, e)| *n == name && e.kind == kind)
            .unwrap_or_else(|| panic!("no ledger row for {name} {kind:?}"))
            .1
    };
    // Every conv payload edge rides the encoded dataplane at 4 MSB
    // planes — including the stem/down edges that used to round-trip
    // dense into the skip slot before the fused save landed.
    for (name, ch, band) in [
        ("stem", 64u64, 0.38..0.45),
        ("block1.conv1", 64, 0.38..0.45),
        ("down1", 128, 0.40..0.48),
        ("block2.conv1", 128, 0.40..0.48),
        ("down2", 256, 0.43..0.50),
        ("block3.conv1", 256, 0.43..0.50),
    ] {
        let e = find(name, EdgeKind::Conv);
        assert!(e.encoded, "{name} must be encoded");
        assert_eq!(e.group_elems, ch);
        assert_eq!(e.msb_bits, 4);
        let r = e.reduction();
        assert!(band.contains(&r), "{name}: reduction {r}");
    }
    // Skip-slot saves keep all 8 planes plus counters — honestly above
    // the dense baseline (negative reduction), paid back by the
    // eliminated add-in edge of the same block.
    for (name, ch) in [("stem", 64u64), ("down1", 128), ("down2", 256)] {
        let save = find(name, EdgeKind::ResidualSave);
        assert!(save.encoded && save.msb_bits == 8);
        assert_eq!(save.group_elems, ch);
        assert!(save.reduction() < 0.0, "{name} save must cost bits");
        assert_eq!(save.bits, save.groups * activation_traffic(ch as usize, 8).pacim);
    }
    for name in ["block1.conv2", "block2.conv2", "block3.conv2"] {
        let input = find(name, EdgeKind::ResidualIn);
        assert!(input.is_eliminated(), "{name} add-in must be eliminated");
        assert_eq!(input.bits, 0);
        assert_eq!(input.reduction(), 1.0);
    }
    // Post-add edges: encoded into the next conv for blocks 1–2, dense
    // into GAP for block 3 — measured accounting is honest about the
    // one edge the software dataplane still cannot encode.
    for (name, ch) in [("block1.conv2", 64u64), ("block2.conv2", 128)] {
        let add = find(name, EdgeKind::ResidualAdd);
        assert!(add.encoded && add.msb_bits == 4);
        assert_eq!(add.group_elems, ch);
    }
    let tail = find("block3.conv2", EdgeKind::ResidualAdd);
    assert!(!tail.encoded, "add→GAP stays dense");
    assert_eq!(tail.reduction(), 0.0);
    assert_eq!(ledger.encoded_layer_count(), 14);
    assert!(ledger.reduction() > 0.0);

    // Each block's save/in/add triple nets out strictly below the dense
    // round-trip, matching `memory::residual_traffic`.
    for (save_name, tail_name) in [
        ("stem", "block1.conv2"),
        ("down1", "block2.conv2"),
        ("down2", "block3.conv2"),
    ] {
        let save = find(save_name, EdgeKind::ResidualSave);
        let input = find(tail_name, EdgeKind::ResidualIn);
        let add = find(tail_name, EdgeKind::ResidualAdd);
        let moved = save.bits + input.bits + add.bits;
        let dense = save.baseline_bits + input.baseline_bits + add.baseline_bits;
        assert!(moved < dense, "{save_name} block: {moved} !< {dense}");
    }

    // The dense round-trip reproduces the fused run exactly, over the
    // same 15-row key set, with nothing encoded.
    let dense = EngineBuilder::new(model)
        .pac(PacConfig {
            par: Parallelism::off(),
            fuse_dataplane: false,
            ..PacConfig::default()
        })
        .build()
        .unwrap();
    let ref_out = dense.session().infer(&img).unwrap();
    assert_eq!(ref_out.logits, out.logits);
    assert_eq!(ref_out.stats.macs, out.stats.macs);
    assert_eq!(ref_out.stats.digital_cycles, out.stats.digital_cycles);
    let dt = &ref_out.stats.traffic;
    assert_eq!(dt.layers().len(), 15);
    assert_eq!(dt.encoded_layer_count(), 0);
    assert_eq!(dt.total_baseline_bits(), ledger.total_baseline_bits());
    assert!(ledger.total_bits() < dt.total_bits());
}

#[test]
fn hidden_linear_records_a_dense_edge_and_logits_record_none() {
    // A hidden FC (out_params = Some) writes its activations back to
    // cache as one layer-wise dense group; the terminal logits layer is
    // delivered to the host and never appears in the ledger.
    let hidden = LinearLayer {
        name: "fc1".into(),
        in_f: 4,
        out_f: 6,
        weight: Tensor::from_vec(&[6, 4], vec![1u8; 24]),
        wparams: QuantParams::new(0.02, 128),
        bias: vec![0.0; 6],
        out_params: Some(QuantParams::new(0.05, 32)),
        relu: true,
    };
    let logits = LinearLayer {
        name: "fc2".into(),
        in_f: 6,
        out_f: 3,
        weight: Tensor::from_vec(&[3, 6], vec![2u8; 18]),
        wparams: QuantParams::new(0.02, 128),
        bias: vec![0.0; 3],
        out_params: None,
        relu: false,
    };
    let model = Model {
        name: "mini_mlp".into(),
        ops: vec![Op::Linear(hidden), Op::Linear(logits)],
        input_params: QuantParams::new(1.0, 0),
        in_c: 1,
        in_hw: 2,
        num_classes: 3,
    };
    let engine = EngineBuilder::new(model).exact().build().unwrap();
    let out = engine.session().infer(&[10, 20, 30, 40]).unwrap();
    let t = &out.stats.traffic;
    let e = t.layer(0).expect("hidden FC edge recorded");
    assert!(!e.encoded);
    assert_eq!(e.kind, EdgeKind::Linear);
    assert_eq!((e.groups, e.group_elems, e.bits), (1, 6, 6 * 8));
    assert!(t.layer(1).is_none(), "logits layer must not record traffic");
    assert_eq!(t.layers().len(), 1);
}
