//! Traffic accounting: the measured `TrafficLedger` (what the executor
//! actually moved between layers) against the analytic `memory::traffic`
//! / `coordinator::scheduler` model (what the closed form predicts from
//! geometry), plus the paper's deep-layer reduction band measured on a
//! ResNet-18-width network.

use pacim::coordinator::{schedule_layer, ScheduleConfig};
use pacim::engine::EngineBuilder;
use pacim::memory::activation_traffic;
use pacim::nn::layers::synthetic::random_store;
use pacim::nn::{
    pac_backend, run_model_with, tiny_resnet, ConvLayer, LinearLayer, Model, ModelScratch, Op,
    PacConfig, RunStats,
};
use pacim::tensor::{Conv2dGeom, QuantParams, Tensor};
use pacim::util::check::Checker;
use pacim::util::rng::Rng;
use pacim::util::Parallelism;
use pacim::workload::{LayerShape, LayerShapeKind};

fn run(model: &Model, cfg: PacConfig, img: &[u8]) -> (Vec<f32>, RunStats) {
    let backend = pac_backend(model, cfg);
    run_model_with(model, &backend, img, &Parallelism::off(), &mut ModelScratch::default())
}

/// A random stack of chained convolutions (kernel ∈ {1,3}, stride ∈
/// {1,2}, matching padding) followed by GAP + logits — every conv but
/// the last has a conv consumer, so under `min_dp_len = 0` every such
/// edge rides the encoded dataplane.
fn random_conv_stack(rng: &mut Rng) -> (Model, Vec<u8>) {
    let depth = 2 + rng.below(2) as usize;
    let mut in_c = 1 + rng.below(4) as usize;
    let mut hw = 8 + rng.below(5) as usize;
    let in_c0 = in_c;
    let hw0 = hw;
    let mut ops = Vec::new();
    for i in 0..depth {
        let kernel = if rng.bernoulli(0.5) { 1 } else { 3 };
        let stride = 1 + rng.below(2) as usize;
        let out_c = 1 + rng.below(12) as usize;
        let geom = Conv2dGeom {
            in_c,
            in_h: hw,
            in_w: hw,
            out_c,
            kh: kernel,
            kw: kernel,
            stride,
            pad: kernel / 2,
        };
        let k = geom.dp_len();
        let weight: Vec<u8> = (0..out_c * k).map(|_| rng.below(256) as u8).collect();
        ops.push(Op::Conv2d(ConvLayer {
            name: format!("c{i}"),
            geom,
            weight: Tensor::from_vec(&[out_c, k], weight),
            wparams: QuantParams::new(0.02, 128),
            bias: (0..out_c).map(|_| (rng.next_f32() - 0.5) * 0.1).collect(),
            out_params: QuantParams::new(0.05, 32),
            relu: rng.bernoulli(0.7),
        }));
        in_c = out_c;
        hw = geom.out_h();
    }
    ops.push(Op::GlobalAvgPool);
    let fc_w: Vec<u8> = (0..3 * in_c).map(|_| rng.below(256) as u8).collect();
    ops.push(Op::Linear(LinearLayer {
        name: "fc".into(),
        in_f: in_c,
        out_f: 3,
        weight: Tensor::from_vec(&[3, in_c], fc_w),
        wparams: QuantParams::new(0.03, 128),
        bias: vec![0.0; 3],
        out_params: None,
        relu: false,
    }));
    let model = Model {
        name: "traffic_stack".into(),
        ops,
        input_params: QuantParams::new(1.0 / 64.0, 128),
        in_c: in_c0,
        in_hw: hw0,
        num_classes: 3,
    };
    let img: Vec<u8> = (0..in_c0 * hw0 * hw0).map(|_| rng.below(256) as u8).collect();
    (model, img)
}

#[test]
fn prop_measured_ledger_matches_analytic_model() {
    // For random conv/linear geometries, every measured ledger entry
    // must equal the closed-form `memory::traffic` prediction for its
    // edge — bits, baseline, and the scheduler's per-layer accounting
    // (which counts write + read, i.e. exactly 2× the ledger's
    // one-direction bits).
    Checker::new("ledger_vs_analytic", 32).run(|rng| {
        let (model, img) = random_conv_stack(rng);
        let cfg = PacConfig {
            first_layer_exact: rng.bernoulli(0.3),
            min_dp_len: 0,
            par: Parallelism::off(),
            ..PacConfig::default()
        };
        let (_, stats) = run(&model, cfg, &img);
        let convs: Vec<&ConvLayer> = model
            .ops
            .iter()
            .filter_map(|op| match op {
                Op::Conv2d(c) => Some(c),
                _ => None,
            })
            .collect();
        let sched_cfg = ScheduleConfig::pacim_default();
        for (i, conv) in convs.iter().enumerate() {
            let e = stats.traffic.layer(i).unwrap_or_else(|| panic!("no entry for conv {i}"));
            let g = &conv.geom;
            let groups = g.out_pixels() as u64;
            assert_eq!(e.groups, groups, "conv {i} groups");
            assert_eq!(e.group_elems, g.out_c as u64, "conv {i} channels");
            // Every conv with a conv consumer rides the encoded
            // dataplane (min_dp_len = 0); the last conv feeds GAP and
            // stays dense.
            assert_eq!(e.encoded, i + 1 < convs.len(), "conv {i} encode decision");
            let t = activation_traffic(g.out_c, 4);
            let want_bits = if e.encoded { groups * t.pacim } else { groups * t.baseline };
            assert_eq!(e.bits, want_bits, "conv {i} measured bits");
            assert_eq!(e.baseline_bits, groups * t.baseline, "conv {i} baseline");
            // Cross-check against the scheduler's analytic accounting
            // (assumes every edge encoded, write + read).
            let shape = LayerShape {
                name: conv.name.clone(),
                kind: LayerShapeKind::Conv,
                geom: *g,
            };
            let rep = schedule_layer(&shape, &sched_cfg);
            assert_eq!(rep.act_bits_baseline, 2 * e.baseline_bits, "conv {i} sched baseline");
            if e.encoded {
                assert_eq!(rep.act_bits_pacim, 2 * e.bits, "conv {i} sched pacim");
            }
        }
        // The terminal logits layer is host output, never a cache edge.
        assert!(stats.traffic.layer(convs.len()).is_none());
    });
}

#[test]
fn prop_fused_and_roundtrip_ledgers_share_baselines() {
    // Fusion changes how bits move, never how many elements exist: the
    // dense round-trip and the fused run must agree on every edge's
    // baseline, and on logits + counters bit for bit.
    Checker::new("ledger_fused_vs_dense", 24).run(|rng| {
        let (model, img) = random_conv_stack(rng);
        let mk = |fuse| PacConfig {
            first_layer_exact: false,
            min_dp_len: 0,
            par: Parallelism::off(),
            fuse_dataplane: fuse,
            ..PacConfig::default()
        };
        let (a, sa) = run(&model, mk(false), &img);
        let (b, sb) = run(&model, mk(true), &img);
        assert_eq!(a, b, "logits diverged");
        assert_eq!(sa.macs, sb.macs);
        assert_eq!(sa.digital_cycles, sb.digital_cycles);
        assert_eq!(sa.pcu_ops, sb.pcu_ops);
        assert_eq!(sa.traffic.encoded_layer_count(), 0);
        assert_eq!(sa.traffic.total_baseline_bits(), sb.traffic.total_baseline_bits());
        for (ea, eb) in sa.traffic.layers().iter().zip(sb.traffic.layers()) {
            assert_eq!(ea.layer_id, eb.layer_id);
            assert_eq!(ea.groups, eb.groups);
            assert_eq!(ea.baseline_bits, eb.baseline_bits);
        }
    });
}

#[test]
fn deep_resnet18_width_edges_land_in_the_papers_band() {
    // End-to-end on a network with the CIFAR ResNet-18 channel ladder
    // (64 → 128 → 256): the measured reduction on deep encoded edges
    // must land in Fig. 7(b)'s 40–50% band, under the *default* engine
    // configuration (first layer digital, PAC above DP 512, dataplane
    // fused) — the same path `pacim accuracy` and serving run.
    let mut rng = Rng::new(1818);
    let model = tiny_resnet(&random_store(&mut rng, 64, 10), 16, 10).unwrap();
    let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();

    let engine = EngineBuilder::new(model.clone())
        .pac(PacConfig {
            par: Parallelism::off(),
            ..PacConfig::default()
        })
        .build()
        .unwrap();
    let out = engine.session().infer(&img).unwrap();
    let ledger = &out.stats.traffic;
    let rows = engine.traffic_rows(ledger);
    assert_eq!(rows.len(), 9, "9 conv edges (fc logits are host output)");

    let find = |name: &str| {
        rows.iter()
            .find(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("no ledger row for {name}"))
            .1
    };
    // The three in-block conv1→conv2 edges ride the encoded dataplane.
    for (name, ch, band) in [
        ("block1.conv1", 64u64, 0.38..0.45),
        ("block2.conv1", 128, 0.40..0.48),
        ("block3.conv1", 256, 0.43..0.50),
    ] {
        let e = find(name);
        assert!(e.encoded, "{name} must be encoded");
        assert_eq!(e.group_elems, ch);
        assert_eq!(e.msb_bits, 4);
        let r = e.reduction();
        assert!(band.contains(&r), "{name}: reduction {r}");
    }
    // Edges into pools/skips stay dense — measured accounting is honest
    // about what the software dataplane does not encode.
    for name in ["stem", "down1", "down2", "block3.conv2"] {
        let e = find(name);
        assert!(!e.encoded, "{name} must be dense");
        assert_eq!(e.reduction(), 0.0);
    }
    assert_eq!(ledger.encoded_layer_count(), 3);
    assert!(ledger.reduction() > 0.0);

    // The dense round-trip reproduces the fused run exactly.
    let dense = EngineBuilder::new(model)
        .pac(PacConfig {
            par: Parallelism::off(),
            fuse_dataplane: false,
            ..PacConfig::default()
        })
        .build()
        .unwrap();
    let ref_out = dense.session().infer(&img).unwrap();
    assert_eq!(ref_out.logits, out.logits);
    assert_eq!(ref_out.stats.macs, out.stats.macs);
    assert_eq!(ref_out.stats.digital_cycles, out.stats.digital_cycles);
}

#[test]
fn hidden_linear_records_a_dense_edge_and_logits_record_none() {
    // A hidden FC (out_params = Some) writes its activations back to
    // cache as one layer-wise dense group; the terminal logits layer is
    // delivered to the host and never appears in the ledger.
    let hidden = LinearLayer {
        name: "fc1".into(),
        in_f: 4,
        out_f: 6,
        weight: Tensor::from_vec(&[6, 4], vec![1u8; 24]),
        wparams: QuantParams::new(0.02, 128),
        bias: vec![0.0; 6],
        out_params: Some(QuantParams::new(0.05, 32)),
        relu: true,
    };
    let logits = LinearLayer {
        name: "fc2".into(),
        in_f: 6,
        out_f: 3,
        weight: Tensor::from_vec(&[3, 6], vec![2u8; 18]),
        wparams: QuantParams::new(0.02, 128),
        bias: vec![0.0; 3],
        out_params: None,
        relu: false,
    };
    let model = Model {
        name: "mini_mlp".into(),
        ops: vec![Op::Linear(hidden), Op::Linear(logits)],
        input_params: QuantParams::new(1.0, 0),
        in_c: 1,
        in_hw: 2,
        num_classes: 3,
    };
    let engine = EngineBuilder::new(model).exact().build().unwrap();
    let out = engine.session().infer(&[10, 20, 30, 40]).unwrap();
    let t = &out.stats.traffic;
    let e = t.layer(0).expect("hidden FC edge recorded");
    assert!(!e.encoded);
    assert_eq!((e.groups, e.group_elems, e.bits), (1, 6, 6 * 8));
    assert!(t.layer(1).is_none(), "logits layer must not record traffic");
    assert_eq!(t.layers().len(), 1);
}
