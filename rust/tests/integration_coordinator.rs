//! Integration: serving coordinator under load, with failure injection,
//! the PAC-native executor pool end-to-end, and scheduler consistency
//! across workloads (no artifacts needed).

use pacim::coordinator::server::BatchExecutor;
use pacim::coordinator::{
    schedule_model, BatchPolicy, InferenceServer, ModelRegistry, ModelSpec, ScheduleConfig,
    ServeError,
};
use pacim::engine::EngineBuilder;
use pacim::nn::PacConfig;
use pacim::runtime::PacExecutor;
use pacim::workload::{
    resnet18, resnet50, synthetic_serving_workload, synthetic_tenant_workload, vgg16_bn,
    Resolution,
};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Deterministic mock: logit j = input[0] * (j+1).
struct Mock {
    batch: usize,
    calls: AtomicUsize,
    fail_on: Option<usize>,
}

impl BatchExecutor for Mock {
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn input_elems(&self) -> usize {
        4
    }
    fn output_elems(&self) -> usize {
        3
    }
    fn execute(&mut self, batch: &[f32], _occupancy: usize) -> anyhow::Result<Vec<f32>> {
        let c = self.calls.fetch_add(1, Ordering::Relaxed);
        if Some(c) == self.fail_on {
            anyhow::bail!("injected");
        }
        std::thread::sleep(Duration::from_micros(100));
        let mut out = Vec::new();
        for i in 0..self.batch {
            for j in 0..3 {
                out.push(batch[i * 4] * (j + 1) as f32);
            }
        }
        Ok(out)
    }
}

#[test]
fn sustained_load_many_clients() {
    let server = InferenceServer::start(
        Mock { batch: 8, calls: AtomicUsize::new(0), fail_on: None },
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        },
    );
    let h = server.handle();
    let total = 200;
    let done = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for t in 0..10 {
            let h = h.clone();
            let done = &done;
            s.spawn(move || {
                for i in 0..total / 10 {
                    let v = (t * 100 + i) as f32;
                    let r = h.infer(vec![v, 0.0, 0.0, 0.0]).unwrap();
                    assert_eq!(r.logits, vec![v, 2.0 * v, 3.0 * v]);
                    done.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    assert_eq!(done.load(Ordering::Relaxed), total);
    let m = server.stop();
    assert_eq!(m.requests, total as u64);
    assert!(m.mean_batch_occupancy() > 1.0, "batching never engaged");
}

#[test]
fn failure_injection_mid_stream_recovers() {
    let server = InferenceServer::start(
        Mock { batch: 1, calls: AtomicUsize::new(0), fail_on: Some(3) },
        BatchPolicy::default(),
    );
    let h = server.handle();
    let mut errors = 0;
    for i in 0..8 {
        match h.infer(vec![i as f32, 0.0, 0.0, 0.0]) {
            Ok(r) => assert_eq!(r.logits[0], i as f32),
            Err(_) => errors += 1,
        }
    }
    assert_eq!(errors, 1, "exactly the injected batch fails");
    let m = server.stop();
    assert_eq!(m.failed_batches, 1);
    assert_eq!(m.requests, 7);
}

#[test]
fn pac_pool_serves_bit_identical_to_offline_inference() {
    // The whole serving pipeline — f32 submission, re-quantization,
    // dynamic batching across a 2-worker pool, lane fan-out, padding —
    // must return exactly the logits offline inference produces. The
    // input scale is a power of two, so dequantize∘quantize is lossless
    // and the comparison can be bit-exact.
    let (model, ds) = synthetic_serving_workload(1234, 8, 16, 10, 16).unwrap();
    let offline_engine = EngineBuilder::new(model.clone())
        .pac(PacConfig::serving())
        .build()
        .unwrap();
    let mut offline_session = offline_engine.session();
    let offline: Vec<Vec<f32>> = (0..16)
        .map(|i| offline_session.infer(ds.image(i)).unwrap().logits)
        .collect();

    let exec = PacExecutor::new(model, PacConfig::serving(), 4).unwrap();
    let server = InferenceServer::start_pool(
        move |_| Ok(exec.clone()),
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            workers: 2,
            queue_cap: 64,
            ..BatchPolicy::default()
        },
    )
    .unwrap();
    let h = server.handle();
    std::thread::scope(|s| {
        for i in 0..16 {
            let h = h.clone();
            let ds = &ds;
            let want = &offline[i];
            s.spawn(move || {
                let img: Vec<f32> = ds
                    .image(i)
                    .iter()
                    .map(|&q| ds.params.dequantize(q))
                    .collect();
                let r = h.infer(img).unwrap();
                assert_eq!(&r.logits, want, "request {i}");
                let cost = r.cost.expect("PAC executor annotates cost");
                assert!(cost.cycles > 0);
                assert!(cost.total_uj() > 0.0);
            });
        }
    });
    let m = server.stop();
    assert_eq!(m.requests, 16);
    assert_eq!(m.failed_batches, 0);
    assert_eq!(m.per_worker.len(), 2);
}

#[test]
fn worker_panic_mid_batch_is_isolated_under_concurrent_load() {
    // Panic isolation end-to-end, under concurrency: a 2-worker pool
    // whose shared fuse makes exactly one executor call panic mid-batch.
    // Exactly the request riding that batch gets `WorkerLost`; every
    // other concurrent client gets *its own* reply (value-checked, so a
    // crossed or duplicated reply would be caught), the pool rebuilds
    // the poisoned worker from the factory, and no worker is abandoned.
    struct PanicOnce {
        fuse: Arc<AtomicBool>,
    }
    impl BatchExecutor for PanicOnce {
        fn batch_size(&self) -> usize {
            1
        }
        fn input_elems(&self) -> usize {
            4
        }
        fn output_elems(&self) -> usize {
            3
        }
        fn execute(&mut self, batch: &[f32], _occupancy: usize) -> anyhow::Result<Vec<f32>> {
            if self.fuse.swap(false, Ordering::SeqCst) {
                panic!("injected executor panic");
            }
            Ok((0..3).map(|j| batch[0] * (j + 1) as f32).collect())
        }
    }

    let fuse = Arc::new(AtomicBool::new(true));
    let builds = Arc::new(AtomicUsize::new(0));
    let server = {
        let (fuse, builds) = (fuse.clone(), builds.clone());
        InferenceServer::start_pool(
            move |_| {
                builds.fetch_add(1, Ordering::SeqCst);
                Ok(PanicOnce { fuse: fuse.clone() })
            },
            BatchPolicy {
                max_wait: Duration::from_micros(50),
                workers: 2,
                ..BatchPolicy::default()
            },
        )
        .unwrap()
    };
    let h = server.handle();
    let total = 12usize;
    let served = AtomicUsize::new(0);
    let lost = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for i in 0..total {
            let h = h.clone();
            let (served, lost) = (&served, &lost);
            s.spawn(move || {
                let v = (i + 1) as f32;
                match h.infer(vec![v, 0.0, 0.0, 0.0]) {
                    Ok(r) => {
                        assert_eq!(
                            r.logits,
                            vec![v, 2.0 * v, 3.0 * v],
                            "request {i} received someone else's reply"
                        );
                        served.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(ServeError::WorkerLost) => {
                        lost.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(other) => panic!("request {i}: unexpected error {other}"),
                }
            });
        }
    });
    // One fuse, batch size 1 ⇒ exactly one request rode the panic; every
    // reply is accounted for (no drops, no duplicates).
    assert_eq!(lost.load(Ordering::SeqCst), 1, "exactly one WorkerLost");
    assert_eq!(served.load(Ordering::SeqCst), total - 1);
    assert!(!fuse.load(Ordering::SeqCst), "the fuse fired");
    let m = server.stop();
    assert_eq!(m.worker_panics, 1);
    assert_eq!(m.failed_batches, 1);
    assert_eq!(m.workers_lost, 0, "the panicked worker was rebuilt, not abandoned");
    assert_eq!(m.requests, (total - 1) as u64);
    assert_eq!(m.per_worker.len(), 2);
    assert_eq!(
        builds.load(Ordering::SeqCst),
        3,
        "2 initial executors + 1 post-panic rebuild"
    );
}

#[test]
fn retired_worker_shard_is_not_stranded() {
    // The WorkerLost-then-retire path end-to-end: worker A panics, its
    // rebuild fails (single-use factory), and it retires cleanly. Its
    // ingress shard stays live — P2C keeps placing new submissions on
    // it — so the surviving worker must keep *stealing* that shard's
    // requests. No request may hang, and the retiree's telemetry must
    // survive into the final metrics.
    struct PanicOnce {
        fuse: Arc<AtomicBool>,
    }
    impl BatchExecutor for PanicOnce {
        fn batch_size(&self) -> usize {
            1
        }
        fn input_elems(&self) -> usize {
            4
        }
        fn output_elems(&self) -> usize {
            3
        }
        fn execute(&mut self, batch: &[f32], _occupancy: usize) -> anyhow::Result<Vec<f32>> {
            if self.fuse.swap(false, Ordering::SeqCst) {
                panic!("injected executor panic");
            }
            Ok((0..3).map(|j| batch[0] * (j + 1) as f32).collect())
        }
    }

    let fuse = Arc::new(AtomicBool::new(true));
    let builds = Arc::new(AtomicUsize::new(0));
    let server = {
        let (fuse, builds) = (fuse.clone(), builds.clone());
        InferenceServer::start_pool(
            move |_| {
                if builds.fetch_add(1, Ordering::SeqCst) >= 2 {
                    anyhow::bail!("no spare executor for the rebuild");
                }
                Ok(PanicOnce { fuse: fuse.clone() })
            },
            BatchPolicy {
                max_wait: Duration::from_micros(50),
                workers: 2,
                ..BatchPolicy::default()
            },
        )
        .unwrap()
    };
    let h = server.handle();
    // The first request rides the panicking batch: whichever worker
    // executes it trips the shared fuse and then fails to respawn.
    match h.infer(vec![1.0, 0.0, 0.0, 0.0]) {
        Err(ServeError::WorkerLost) => {}
        other => panic!("expected WorkerLost for the fused request, got {other:?}"),
    }
    // Post-retirement traffic: every request must still be answered,
    // including the roughly-half that P2C places on the dead shard.
    let total = 32usize;
    for i in 0..total {
        let v = (i + 2) as f32;
        let r = h.infer(vec![v, 0.0, 0.0, 0.0]).unwrap();
        assert_eq!(r.logits, vec![v, 2.0 * v, 3.0 * v], "request {i}");
    }
    let m = server.stop();
    assert_eq!(m.worker_panics, 1);
    assert_eq!(m.failed_batches, 1);
    assert_eq!(m.workers_lost, 0, "retirement is a clean join, not a loss");
    assert_eq!(m.requests, total as u64);
    assert_eq!(m.per_worker.len(), 2, "the retiree's telemetry survives");
    assert!(m.steals >= 1, "the survivor stole from the retired shard");
    assert_eq!(m.per_shard.len(), 2);
    let submitted: u64 = m.per_shard.iter().map(|s| s.submitted).sum();
    assert_eq!(submitted, (total + 1) as u64, "the fused request counts too");
    assert_eq!(
        builds.load(Ordering::SeqCst),
        3,
        "2 initial executors + 1 failed rebuild attempt"
    );
}

#[test]
fn multi_model_registry_routes_and_matches_offline() {
    // Two tenants with distinct topologies behind one front door: each
    // routed reply must be bit-identical to that tenant's own offline
    // session (so routing can never cross-wire models), an unknown id
    // gets the typed routing error, and stop() reports per-model
    // metrics in registration order.
    let mut registry = ModelRegistry::new();
    let mut offline = Vec::new();
    for (i, id) in ["resnet18", "tinyvgg"].into_iter().enumerate() {
        let (model, ds) = synthetic_tenant_workload(id, 90 + i as u64, 8, 16, 10, 6).unwrap();
        let engine = EngineBuilder::new(model)
            .pac(PacConfig::serving())
            .build()
            .unwrap();
        let mut session = engine.session();
        let logits: Vec<Vec<f32>> = (0..6)
            .map(|j| session.infer(ds.image(j)).unwrap().logits)
            .collect();
        registry = registry
            .register(ModelSpec::new(id, engine).batch(4).policy(BatchPolicy {
                max_wait: Duration::from_millis(1),
                workers: 2,
                ..BatchPolicy::default()
            }))
            .unwrap();
        offline.push((id, ds, logits));
    }

    let server = PacExecutor::serve_registry(registry).unwrap();
    assert_eq!(server.models(), vec!["resnet18", "tinyvgg"]);
    let h = server.handle();
    match h.infer("alexnet", vec![0.0; 3 * 16 * 16]) {
        Err(ServeError::UnknownModel { model }) => assert_eq!(model, "alexnet"),
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    std::thread::scope(|s| {
        for (id, ds, logits) in &offline {
            for j in 0..6 {
                let h = h.clone();
                s.spawn(move || {
                    let img: Vec<f32> = ds
                        .image(j)
                        .iter()
                        .map(|&q| ds.params.dequantize(q))
                        .collect();
                    let r = h.infer(id, img).unwrap();
                    assert_eq!(&r.logits, &logits[j], "{id} request {j}");
                    assert!(r.cost.is_some(), "{id}: cost annotation missing");
                });
            }
        }
    });
    let metrics = server.stop();
    assert_eq!(metrics.len(), 2);
    assert_eq!(metrics[0].0, "resnet18");
    assert_eq!(metrics[1].0, "tinyvgg");
    for (tid, m) in &metrics {
        assert_eq!(m.requests, 6, "{tid}");
        assert_eq!(m.failed_batches, 0, "{tid}");
        assert_eq!(m.per_shard.len(), 2, "{tid}");
        assert!(m.traffic_bits > 0, "{tid}: traffic telemetry not wired");
    }
}

#[test]
fn exact_executor_serves_and_costs_more_than_pac() {
    // Same model, same image through both executors: each must produce
    // finite logits of the right arity, and the exact executor's cost
    // annotation (fully digital schedule) must exceed PAC's hybrid one.
    let (model, ds) = synthetic_serving_workload(555, 8, 16, 10, 4).unwrap();
    let img: Vec<f32> = ds
        .image(0)
        .iter()
        .map(|&q| ds.params.dequantize(q))
        .collect();
    let mut replies = Vec::new();
    for exec in [
        PacExecutor::new(model.clone(), PacConfig::serving(), 2).unwrap(),
        PacExecutor::exact(model, 2).unwrap(),
    ] {
        let server = InferenceServer::start_pool(
            move |_| Ok(exec.clone()),
            BatchPolicy::default(),
        )
        .unwrap();
        let r = server.handle().infer(img.clone()).unwrap();
        assert_eq!(r.logits.len(), 10);
        assert!(r.logits.iter().all(|l| l.is_finite()));
        replies.push(r);
        server.stop();
    }
    // The exact executor's modeled cost is the fully digital schedule —
    // strictly more cycles than PAC's hybrid schedule.
    let pac_cost = replies[0].cost.unwrap();
    let exact_cost = replies[1].cost.unwrap();
    assert!(pac_cost.cycles < exact_cost.cycles);
}

#[test]
fn scheduler_consistency_across_networks() {
    // The 75% static / 81.25% dynamic cycle reductions are properties of
    // the map, so they must hold for EVERY network exactly.
    for shapes in [
        resnet18(Resolution::Cifar, 10),
        resnet18(Resolution::ImageNet, 1000),
        resnet50(Resolution::ImageNet, 1000),
        vgg16_bn(Resolution::Cifar, 10),
        vgg16_bn(Resolution::ImageNet, 1000),
    ] {
        let dig = schedule_model(&shapes, &ScheduleConfig::digital_baseline());
        let stat = schedule_model(&shapes, &ScheduleConfig::pacim_default());
        let dyn_ = schedule_model(&shapes, &ScheduleConfig::pacim_dynamic());
        let rs = stat.total_macs_cycles() as f64 / dig.total_macs_cycles() as f64;
        let rd = dyn_.total_macs_cycles() as f64 / dig.total_macs_cycles() as f64;
        assert!((rs - 0.25).abs() < 1e-9);
        assert!((rd - 0.1875).abs() < 1e-9);
        // Activation traffic reduction lands in the paper's 40-50% band
        // for every benchmark network.
        let red = stat.act_traffic_reduction();
        assert!((0.35..0.52).contains(&red), "{red}");
    }
}

#[test]
fn weight_traffic_scales_with_model_size() {
    let r18 = schedule_model(
        &resnet18(Resolution::ImageNet, 1000),
        &ScheduleConfig::pacim_default(),
    );
    let r50 = schedule_model(
        &resnet50(Resolution::ImageNet, 1000),
        &ScheduleConfig::pacim_default(),
    );
    let w18: u64 = r18.layers.iter().map(|l| l.weight_bits_pacim).sum();
    let w50: u64 = r50.layers.iter().map(|l| l.weight_bits_pacim).sum();
    assert!(w50 > w18, "ResNet-50 moves more weight bits than ResNet-18");
}
