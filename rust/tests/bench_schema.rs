//! Schema gate for the CI bench artifacts.
//!
//! `BENCH_hotpath.json` (benches/perf_hotpath.rs), `BENCH_serve.json`
//! (examples/loadgen.rs), `BENCH_traffic.json`
//! (benches/fig7_system.rs), and `BENCH_tune.json` (`pacim tune`) are
//! uploaded by CI to track the perf trajectory; future regression
//! gating parses them, so they must stay machine-readable. These tests
//! validate golden samples against the shared schema
//! (`pacim::util::benchfmt`, `deny_unknown_fields`) and — when the real
//! files exist (CI runs this after the bench/loadgen/tune jobs,
//! pointing `PACIM_BENCH_HOTPATH_JSON` / `PACIM_BENCH_SERVE_JSON` /
//! `PACIM_BENCH_TRAFFIC_JSON` / `PACIM_BENCH_TUNE_JSON` at the
//! produced artifacts) — re-parse the actual emitted JSON.

use pacim::util::benchfmt::{
    enforce_blocked_floor, enforce_resilience, enforce_serve_slo, enforce_simd_floor,
    enforce_traffic_floor, enforce_tune_front, validate_hotpath, validate_resilience,
    validate_serve, validate_traffic, validate_tune,
};
use std::path::PathBuf;

const HOTPATH_GOLDEN: &str = r#"{
  "bench": "perf_hotpath",
  "threads": 4,
  "quick": true,
  "layers": [
    {
      "layer": "layer1.0.conv1",
      "dp_len": 576,
      "pairs": 96,
      "scalar_macs_per_s": 120000000.0,
      "parallel_macs_per_s": 360000000.0,
      "speedup": 3.0,
      "bit_identical": true
    }
  ],
  "blocked": [
    {
      "shape": "layer1.0.conv1",
      "dp_len": 576,
      "out_c": 64,
      "pixels": 192,
      "per_patch_macs_per_s": 120000000.0,
      "blocked_macs_per_s": 250000000.0,
      "speedup_blocked": 2.08,
      "bit_identical": true
    }
  ],
  "simd": [
    {
      "shape": "layer1.0.conv1-dense",
      "dp_len": 576,
      "out_c": 64,
      "pixels": 192,
      "tier": "avx2",
      "msb_sparse_weights": false,
      "live_word_fraction": 1.0,
      "skip_columns": 0,
      "scalar_macs_per_s": 120000000.0,
      "simd_macs_per_s": 220000000.0,
      "speedup_simd": 1.83,
      "bit_identical": true
    },
    {
      "shape": "layer1.0.conv1-msbsparse",
      "dp_len": 576,
      "out_c": 64,
      "pixels": 192,
      "tier": "avx2",
      "msb_sparse_weights": true,
      "live_word_fraction": 0.41,
      "skip_columns": 64,
      "scalar_macs_per_s": 120000000.0,
      "simd_macs_per_s": 320000000.0,
      "speedup_simd": 2.67,
      "bit_identical": true
    }
  ],
  "fused": [
    {
      "model": "tiny_resnet_c16",
      "images": 8,
      "encoded_layers": 14,
      "roundtrip_images_per_s": 52.0,
      "fused_images_per_s": 57.0,
      "speedup_fused": 1.09,
      "bit_identical": true
    }
  ]
}"#;

const TRAFFIC_GOLDEN: &str = r#"{
  "bench": "traffic",
  "quick": true,
  "model": "tiny_resnet_c64",
  "images": 1,
  "layers": [
    {
      "layer": "block3.conv1",
      "kind": "conv",
      "channels": 256,
      "groups": 16,
      "baseline_bits": 32768,
      "measured_bits": 17408,
      "analytic_bits": 17408,
      "reduction": 0.46875,
      "encoded": true,
      "deep": true
    },
    {
      "layer": "down2",
      "kind": "conv",
      "channels": 256,
      "groups": 16,
      "baseline_bits": 32768,
      "measured_bits": 32768,
      "analytic_bits": 32768,
      "reduction": 0.0,
      "encoded": false,
      "deep": true
    },
    {
      "layer": "down2",
      "kind": "residual_save",
      "channels": 256,
      "groups": 16,
      "baseline_bits": 32768,
      "measured_bits": 33792,
      "analytic_bits": 33792,
      "reduction": -0.03125,
      "encoded": true,
      "deep": true
    },
    {
      "layer": "block3.conv2",
      "kind": "residual_in",
      "channels": 256,
      "groups": 16,
      "baseline_bits": 32768,
      "measured_bits": 0,
      "analytic_bits": 0,
      "reduction": 1.0,
      "encoded": true,
      "deep": true
    }
  ],
  "encoded_layers": 3,
  "deep_encoded_min_reduction": 0.46875,
  "network_reduction": 0.359375
}"#;

const SERVE_GOLDEN: &str = r#"{
  "bench": "serve",
  "quick": true,
  "scenarios": [
    {
      "name": "pac-open",
      "executor": "pac",
      "model": "tiny_resnet_c8",
      "mode": "open",
      "workers": 2,
      "batch_size": 8,
      "queue_cap": 256,
      "shards": 2,
      "steals": 5,
      "offered_rps": 300.0,
      "requests": 48,
      "completed": 46,
      "rejected": 2,
      "failed_batches": 0,
      "wall_s": 0.21,
      "throughput_rps": 219.0,
      "p50_us": 2100.0,
      "p95_us": 5400.0,
      "p99_us": 7600.0,
      "mean_batch_occupancy": 6.57,
      "batch_fill": [0, 0, 1, 0, 1, 1, 0, 4],
      "modeled_cycles_per_image": 934912,
      "modeled_energy_uj_per_image": 11.8,
      "measured_traffic_bits": 4600000,
      "traffic_baseline_bits": 9200000,
      "bits_per_request": 100000.0,
      "escalated": 0
    }
  ]
}"#;

const TUNE_GOLDEN: &str = r#"{
  "bench": "tune",
  "quick": true,
  "model": "tiny_resnet-synthetic",
  "workload": "resnet18-cifar",
  "images": 48,
  "points": [
    {
      "banks": 4,
      "rows": 256,
      "thresholds": null,
      "lambda": 0.0,
      "accuracy": 0.91,
      "avg_digital_cycles": 16.0,
      "cycles": 1000000,
      "bits": 5000000,
      "on_front": true
    },
    {
      "banks": 4,
      "rows": 256,
      "thresholds": null,
      "lambda": 0.005,
      "accuracy": 0.91,
      "avg_digital_cycles": 16.0,
      "cycles": 1010000,
      "bits": 4800000,
      "on_front": true
    },
    {
      "banks": 4,
      "rows": 256,
      "thresholds": [0.08, 0.16, 0.3],
      "lambda": 0.02,
      "accuracy": 0.905,
      "avg_digital_cycles": 13.4,
      "cycles": 800000,
      "bits": 4600000,
      "on_front": true
    },
    {
      "banks": 2,
      "rows": 256,
      "thresholds": null,
      "lambda": 0.0,
      "accuracy": 0.9,
      "avg_digital_cycles": 16.0,
      "cycles": 1020000,
      "bits": 5100000,
      "on_front": false
    }
  ],
  "schedules": [
    {
      "workload": "resnet18-cifar",
      "banks": 4,
      "rows": 256,
      "lambda": 0.02,
      "cycles_cycles_only": 1000000,
      "bits_cycles_only": 5000000,
      "cycles_priced": 1030000,
      "bits_priced": 4600000,
      "replayed_layers": 3
    }
  ],
  "measured_bits": 1417216,
  "analytic_bits": 1417216,
  "residual_bits_encoded": 101376,
  "residual_bits_dense": 180224
}"#;

const RESILIENCE_GOLDEN: &str = r#"{
  "bench": "resilience",
  "quick": true,
  "model": "tiny_resnet-synthetic",
  "images": 48,
  "min_margin": 1.5,
  "fault_off_bit_identical": true,
  "rows": [
    {
      "ber": 0.0,
      "acc_exact": 1.0,
      "acc_plain": 0.9375,
      "acc_escalated": 1.0,
      "escalation_rate": 0.85,
      "weight_bits_flipped": 0,
      "edge_bits_flipped": 0,
      "pcu_noise_events": 0,
      "recovered": 1.0
    },
    {
      "ber": 0.001,
      "acc_exact": 1.0,
      "acc_plain": 0.75,
      "acc_escalated": 0.9375,
      "escalation_rate": 0.875,
      "weight_bits_flipped": 412,
      "edge_bits_flipped": 96,
      "pcu_noise_events": 147456,
      "recovered": 0.75
    }
  ]
}"#;

#[test]
fn hotpath_golden_passes() {
    let r = validate_hotpath(HOTPATH_GOLDEN).unwrap();
    assert_eq!(r.layers.len(), 1);
}

#[test]
fn serve_golden_passes() {
    let r = validate_serve(SERVE_GOLDEN).unwrap();
    assert_eq!(r.scenarios[0].executor, "pac");
    assert_eq!(r.scenarios[0].model, "tiny_resnet_c8");
    assert_eq!(r.scenarios[0].shards, 2);
    // The golden is schema-valid but hosts only one model, so the
    // multi-model SLO gate must refuse it rather than vacuously pass.
    assert!(enforce_serve_slo(&r).is_err());
}

#[test]
fn serve_single_shard_steals_are_schema_drift() {
    // A single-shard row has nobody to steal from; nonzero steal
    // counters there mean the writer's accounting is broken.
    let drifted = SERVE_GOLDEN.replace("\"shards\": 2", "\"shards\": 1");
    assert!(validate_serve(&drifted).unwrap_err().contains("steal"));
}

#[test]
fn traffic_golden_passes_and_holds_the_floor() {
    let r = validate_traffic(TRAFFIC_GOLDEN).unwrap();
    assert_eq!(r.layers.len(), 4);
    assert_eq!(r.encoded_layers, 3);
    // The residual_save row costs bits and the residual_in row reduces
    // by 1.0; neither may leak into the payload floor gate.
    enforce_traffic_floor(&r, 0.44).unwrap();
}

#[test]
fn traffic_schema_drift_and_drifted_measurement_rejected() {
    // Renamed field: unknown new name / missing old name both fail.
    let drifted = TRAFFIC_GOLDEN.replace("\"measured_bits\"", "\"bits_measured\"");
    assert!(validate_traffic(&drifted).is_err());
    // Measured bits disagreeing with the analytic model is a hard error
    // (the cross-check the acceptance criterion gates on).
    let skewed = TRAFFIC_GOLDEN.replace("\"analytic_bits\": 17408", "\"analytic_bits\": 17400");
    assert!(validate_traffic(&skewed).unwrap_err().contains("analytic"));
    // A below-floor deep encoded edge fails the enforcement gate.
    let low = TRAFFIC_GOLDEN
        .replace("\"measured_bits\": 17408", "\"measured_bits\": 22938")
        .replace("\"analytic_bits\": 17408", "\"analytic_bits\": 22938")
        .replace("\"reduction\": 0.46875", "\"reduction\": 0.29998779296875")
        .replace("\"deep_encoded_min_reduction\": 0.46875",
                 "\"deep_encoded_min_reduction\": 0.29998779296875")
        .replace("\"network_reduction\": 0.359375",
                 "\"network_reduction\": 0.31718444824218750");
    let r = validate_traffic(&low).unwrap();
    assert!(enforce_traffic_floor(&r, 0.44).unwrap_err().contains("floor"));
    // An unknown edge kind is schema drift, not free text.
    let aliased = TRAFFIC_GOLDEN.replace("\"kind\": \"residual_save\"", "\"kind\": \"skip_save\"");
    assert!(validate_traffic(&aliased).unwrap_err().contains("unknown edge kind"));
    // An encoded residual_in row reporting moved bits means the fused
    // epilogue leaked a dense gather — schema-invalid.
    let leaked = TRAFFIC_GOLDEN.replacen("\"measured_bits\": 0", "\"measured_bits\": 64", 1);
    assert!(validate_traffic(&leaked).unwrap_err().contains("eliminated by definition"));
    // A deep encoded row mislabeled shallow cannot dodge the gate: the
    // validator recomputes the flag from the channel count.
    let dodged = TRAFFIC_GOLDEN.replace(
        "\"reduction\": 0.46875,\n      \"encoded\": true,\n      \"deep\": true",
        "\"reduction\": 0.46875,\n      \"encoded\": true,\n      \"deep\": false",
    );
    assert!(validate_traffic(&dodged).unwrap_err().contains("deep flag"));
}

#[test]
fn tune_golden_passes_and_holds_the_front_gate() {
    let r = validate_tune(TUNE_GOLDEN).unwrap();
    assert_eq!(r.points.len(), 4);
    assert_eq!(r.points.iter().filter(|p| p.on_front).count(), 3);
    enforce_tune_front(&r).unwrap();
}

#[test]
fn tune_schema_drift_and_cooked_front_rejected() {
    // Renamed field → drift in both directions.
    let drifted = TUNE_GOLDEN.replace("\"bits_priced\"", "\"priced_bits\"");
    assert!(validate_tune(&drifted).is_err());
    // A writer cannot promote the dominated point onto the front…
    let cooked = TUNE_GOLDEN.replacen("\"on_front\": false", "\"on_front\": true", 1);
    assert!(validate_tune(&cooked).unwrap_err().contains("on_front"));
    // …nor hide a genuine front point.
    let cooked = TUNE_GOLDEN.replacen("\"on_front\": true", "\"on_front\": false", 1);
    assert!(validate_tune(&cooked).unwrap_err().contains("on_front"));
    // The measured/analytic traffic cross-check is load-bearing.
    let skewed = TUNE_GOLDEN.replace("\"measured_bits\": 1417216", "\"measured_bits\": 1417208");
    assert!(validate_tune(&skewed).unwrap_err().contains("analytic"));
    // No bit savings within the cycle bound → the enforcement gate fails.
    let flat = TUNE_GOLDEN.replace("\"bits_priced\": 4600000", "\"bits_priced\": 5000000");
    let r = validate_tune(&flat).unwrap();
    assert!(enforce_tune_front(&r).unwrap_err().contains("fewer bits"));
    // Savings bought with an unbounded cycle premium fail too.
    let slow = TUNE_GOLDEN.replace("\"cycles_priced\": 1030000", "\"cycles_priced\": 2000000");
    let r = validate_tune(&slow).unwrap();
    assert!(enforce_tune_front(&r).is_err());
    // Fused residual edges not strictly below their dense round-trip
    // fail the enforcement gate.
    let flat = TUNE_GOLDEN
        .replace("\"residual_bits_encoded\": 101376", "\"residual_bits_encoded\": 180224");
    let r = validate_tune(&flat).unwrap();
    assert!(enforce_tune_front(&r).unwrap_err().contains("not strictly below"));
    // …and a probe that never ran a residual block has nothing to gate.
    let hollow = TUNE_GOLDEN
        .replace("\"residual_bits_encoded\": 101376", "\"residual_bits_encoded\": 0")
        .replace("\"residual_bits_dense\": 180224", "\"residual_bits_dense\": 0");
    let r = validate_tune(&hollow).unwrap();
    assert!(enforce_tune_front(&r).unwrap_err().contains("no residual edges"));
}

#[test]
fn renamed_field_is_schema_drift() {
    // A writer renaming `speedup` → `speed_up` must fail the gate in
    // both directions: unknown new name, missing old name.
    let drifted = HOTPATH_GOLDEN.replace("\"speedup\"", "\"speed_up\"");
    assert!(validate_hotpath(&drifted).is_err());
    // Same for the blocked-GEMM rows.
    let drifted = HOTPATH_GOLDEN.replace("\"speedup_blocked\"", "\"blocked_speedup\"");
    assert!(validate_hotpath(&drifted).is_err());
    // Dropping the blocked section entirely is drift, not a pass.
    let drifted = HOTPATH_GOLDEN.replace("\"blocked\":", "\"blocked_rows\":");
    assert!(validate_hotpath(&drifted).is_err());
    // Same for the SIMD kernel rows: renamed speedup field and dropped
    // section are both drift.
    let drifted = HOTPATH_GOLDEN.replace("\"speedup_simd\"", "\"simd_speedup\"");
    assert!(validate_hotpath(&drifted).is_err());
    let drifted = HOTPATH_GOLDEN.replace("\"simd\":", "\"simd_rows\":");
    assert!(validate_hotpath(&drifted).is_err());
    // An unknown kernel tier name is a validation error, not free text.
    let drifted = HOTPATH_GOLDEN.replace("\"tier\": \"avx2\"", "\"tier\": \"neon\"");
    assert!(validate_hotpath(&drifted).unwrap_err().contains("tier"));
}

#[test]
fn blocked_regression_gate_catches_slowdown() {
    let r = validate_hotpath(HOTPATH_GOLDEN).unwrap();
    enforce_blocked_floor(&r).unwrap();
    let slowed = HOTPATH_GOLDEN.replace("\"speedup_blocked\": 2.08", "\"speedup_blocked\": 0.97");
    let r = validate_hotpath(&slowed).unwrap();
    assert!(enforce_blocked_floor(&r).unwrap_err().contains("regressed"));
}

#[test]
fn simd_regression_gate_catches_slowdown_and_scalar_dodge() {
    let r = validate_hotpath(HOTPATH_GOLDEN).unwrap();
    enforce_simd_floor(&r).unwrap();
    // A sub-1.0x SIMD row fails the floor.
    let slowed = HOTPATH_GOLDEN.replace("\"speedup_simd\": 1.83", "\"speedup_simd\": 0.97");
    let r = validate_hotpath(&slowed).unwrap();
    assert!(enforce_simd_floor(&r).unwrap_err().contains("regressed"));
    // A report whose rows all ran the scalar tier cannot vacuously pass
    // the SIMD gate: that means capability detection (or the runner)
    // silently downgraded, and the gate refuses.
    let dodged = HOTPATH_GOLDEN.replace("\"tier\": \"avx2\"", "\"tier\": \"scalar\"");
    let r = validate_hotpath(&dodged).unwrap();
    assert!(enforce_simd_floor(&r).unwrap_err().contains("refusing"));
    // An empty simd section under enforcement is an error, not a pass
    // (an empty array is the only in-schema way for the rows to vanish;
    // dropping the key entirely is already schema drift, tested above).
    let emptied = {
        let start = HOTPATH_GOLDEN.find("\"simd\": [").unwrap();
        let end = start + HOTPATH_GOLDEN[start..].find("],").unwrap();
        format!("{}\"simd\": [{}", &HOTPATH_GOLDEN[..start], &HOTPATH_GOLDEN[end..])
    };
    let r = validate_hotpath(&emptied).unwrap();
    assert!(enforce_simd_floor(&r).is_err());
}

#[test]
fn extra_field_is_schema_drift() {
    let drifted = SERVE_GOLDEN.replace("\"quick\": true,", "\"quick\": true, \"v\": 2,");
    assert!(validate_serve(&drifted).is_err());
}

#[test]
fn serve_traffic_fields_are_recomputed_not_trusted() {
    // 4600000 bits over 46 completed requests must report exactly
    // 100000 bits/request; a cooked value is schema drift.
    let cooked =
        SERVE_GOLDEN.replace("\"bits_per_request\": 100000.0", "\"bits_per_request\": 1.0");
    assert!(validate_serve(&cooked).unwrap_err().contains("bits_per_request"));
    // Measured traffic above the dense baseline is physically impossible
    // for this dataplane and is rejected.
    let inflated = SERVE_GOLDEN
        .replace("\"measured_traffic_bits\": 4600000", "\"measured_traffic_bits\": 9660000")
        .replace("\"bits_per_request\": 100000.0", "\"bits_per_request\": 210000.0");
    assert!(validate_serve(&inflated).unwrap_err().contains("baseline"));
}

#[test]
fn resilience_golden_passes_and_holds_the_gate() {
    let r = validate_resilience(RESILIENCE_GOLDEN).unwrap();
    assert_eq!(r.rows.len(), 2);
    assert!(r.fault_off_bit_identical);
    enforce_resilience(&r).unwrap();
}

#[test]
fn resilience_schema_drift_and_cooked_recovery_rejected() {
    // Renamed field → drift in both directions.
    let drifted = RESILIENCE_GOLDEN.replace("\"recovered\"", "\"recovery\"");
    assert!(validate_resilience(&drifted).is_err());
    // The gated number is recomputed from the accuracies: a writer
    // cannot claim more recovery than the rows show.
    let cooked = RESILIENCE_GOLDEN.replacen("\"recovered\": 0.75", "\"recovered\": 0.99", 1);
    assert!(validate_resilience(&cooked).unwrap_err().contains("recovered"));
    // A ber = 0 row reporting injections means the disabled channels
    // leak — schema-invalid, not a gate nuance.
    let leaky = RESILIENCE_GOLDEN.replacen("\"pcu_noise_events\": 0", "\"pcu_noise_events\": 7", 1);
    assert!(validate_resilience(&leaky).unwrap_err().contains("leak"));
}

#[test]
fn resilience_gate_catches_weak_recovery_and_divergence() {
    // Escalation recovering less than half the loss fails the gate
    // (0.75 → 0.80 recovers 0.05 of the 0.25 lost).
    let weak = RESILIENCE_GOLDEN
        .replace("\"acc_escalated\": 0.9375", "\"acc_escalated\": 0.8")
        .replacen("\"recovered\": 0.75", "\"recovered\": 0.2", 1);
    let r = validate_resilience(&weak).unwrap();
    assert!(enforce_resilience(&r).unwrap_err().contains("floor"));
    // A fault-off divergence is fatal regardless of the accuracies.
    let diverged = RESILIENCE_GOLDEN
        .replace("\"fault_off_bit_identical\": true", "\"fault_off_bit_identical\": false");
    let r = validate_resilience(&diverged).unwrap();
    assert!(enforce_resilience(&r).unwrap_err().contains("diverged"));
    // A gate row that never injected cannot vacuously pass.
    let hollow = RESILIENCE_GOLDEN
        .replace("\"weight_bits_flipped\": 412", "\"weight_bits_flipped\": 0")
        .replace("\"edge_bits_flipped\": 96", "\"edge_bits_flipped\": 0")
        .replace("\"pcu_noise_events\": 147456", "\"pcu_noise_events\": 0");
    let r = validate_resilience(&hollow).unwrap();
    assert!(enforce_resilience(&r).unwrap_err().contains("injected nothing"));
}

#[test]
fn inconsistent_batch_fill_rejected() {
    // 46 completed but the histogram only accounts for 4 requests.
    let drifted = SERVE_GOLDEN.replace(
        "\"batch_fill\": [0, 0, 1, 0, 1, 1, 0, 4]",
        "\"batch_fill\": [4, 0, 0, 0, 0, 0, 0, 0]",
    );
    assert!(validate_serve(&drifted).is_err());
}

/// Resolve a real artifact path: explicit env var wins; otherwise try
/// the default filename in CWD (bench binaries run with CWD = rust/).
fn artifact(env: &str, default_name: &str) -> Option<PathBuf> {
    if let Ok(p) = std::env::var(env) {
        return Some(PathBuf::from(p));
    }
    let p = PathBuf::from(default_name);
    p.exists().then_some(p)
}

#[test]
fn real_hotpath_artifact_if_present() {
    // CI's bench-smoke job sets these env vars after running the bench:
    // the blocked kernel must beat (or tie) the per-patch baseline on
    // every measured shape, and — on runners where the probe selects a
    // vector tier — the SIMD sweep must beat (or tie) the forced-scalar
    // sweep on every measured shape, or the job fails.
    let enforce = std::env::var("PACIM_ENFORCE_BLOCKED_SPEEDUP")
        .is_ok_and(|v| v != "0" && !v.is_empty());
    let enforce_simd = std::env::var("PACIM_ENFORCE_SIMD_SPEEDUP")
        .is_ok_and(|v| v != "0" && !v.is_empty());
    match artifact("PACIM_BENCH_HOTPATH_JSON", "BENCH_hotpath.json") {
        Some(p) => {
            let json = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let r = validate_hotpath(&json)
                .unwrap_or_else(|e| panic!("{} schema drift: {e}", p.display()));
            println!(
                "validated {} ({} layers, {} blocked rows, {} simd rows)",
                p.display(),
                r.layers.len(),
                r.blocked.len(),
                r.simd.len()
            );
            if enforce {
                enforce_blocked_floor(&r)
                    .unwrap_or_else(|e| panic!("{} blocked-GEMM regression: {e}", p.display()));
                println!("blocked-GEMM floor enforced: all shapes >= 1.0x");
            }
            if enforce_simd {
                enforce_simd_floor(&r)
                    .unwrap_or_else(|e| panic!("{} SIMD kernel regression: {e}", p.display()));
                println!("SIMD kernel floor enforced: all shapes >= 1.0x on a vector tier");
            }
        }
        // Enforcement with no artifact must be a hard failure — a green
        // gate that never parsed a report is worse than a red one.
        None if enforce || enforce_simd => panic!(
            "PACIM_ENFORCE_BLOCKED_SPEEDUP / PACIM_ENFORCE_SIMD_SPEEDUP is set but no \
             BENCH_hotpath.json was found (checked PACIM_BENCH_HOTPATH_JSON and the \
             default CWD path)"
        ),
        None => println!("no BENCH_hotpath.json present; golden-sample checks only"),
    }
}

#[test]
fn real_traffic_artifact_if_present() {
    // CI's bench-smoke job sets PACIM_ENFORCE_TRAFFIC_REDUCTION=1 after
    // running fig7_system: every deep (≥128-channel) encoded *payload*
    // edge must hit a ≥44% reduction floor (residual save/in rows are
    // accounted but not floor-gated), and the measured ledger must
    // equal the analytic model row for row (validate_traffic), or the
    // job fails. Mirrors PACIM_ENFORCE_BLOCKED_SPEEDUP.
    let enforce = std::env::var("PACIM_ENFORCE_TRAFFIC_REDUCTION")
        .is_ok_and(|v| v != "0" && !v.is_empty());
    match artifact("PACIM_BENCH_TRAFFIC_JSON", "BENCH_traffic.json") {
        Some(p) => {
            let json = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let r = validate_traffic(&json)
                .unwrap_or_else(|e| panic!("{} schema drift: {e}", p.display()));
            println!(
                "validated {} ({} rows, {} encoded, deep min {:.3})",
                p.display(),
                r.layers.len(),
                r.encoded_layers,
                r.deep_encoded_min_reduction
            );
            if enforce {
                enforce_traffic_floor(&r, 0.44)
                    .unwrap_or_else(|e| panic!("{} traffic regression: {e}", p.display()));
                println!("traffic floor enforced: deep encoded payload edges >= 44%");
            }
        }
        None if enforce => panic!(
            "PACIM_ENFORCE_TRAFFIC_REDUCTION is set but no BENCH_traffic.json was found \
             (checked PACIM_BENCH_TRAFFIC_JSON and the default CWD path)"
        ),
        None => println!("no BENCH_traffic.json present; golden-sample checks only"),
    }
}

#[test]
fn real_tune_artifact_if_present() {
    // CI's bench-smoke job runs `pacim tune --quick` and then sets
    // PACIM_ENFORCE_TUNE_FRONT=1: the emitted report must hold a ≥ 3
    // point Pareto front and show at least one deep workload where the
    // traffic-priced schedule moves strictly fewer bits within the
    // cycle bound, or the job fails.
    let enforce =
        std::env::var("PACIM_ENFORCE_TUNE_FRONT").is_ok_and(|v| v != "0" && !v.is_empty());
    match artifact("PACIM_BENCH_TUNE_JSON", "BENCH_tune.json") {
        Some(p) => {
            let json = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let r = validate_tune(&json)
                .unwrap_or_else(|e| panic!("{} schema drift: {e}", p.display()));
            println!(
                "validated {} ({} points, {} on front, {} schedule rows)",
                p.display(),
                r.points.len(),
                r.points.iter().filter(|q| q.on_front).count(),
                r.schedules.len()
            );
            if enforce {
                enforce_tune_front(&r)
                    .unwrap_or_else(|e| panic!("{} tune-front regression: {e}", p.display()));
                println!("tune front enforced: ≥ 3 points, priced schedule saves bits");
            }
        }
        None if enforce => panic!(
            "PACIM_ENFORCE_TUNE_FRONT is set but no BENCH_tune.json was found \
             (checked PACIM_BENCH_TUNE_JSON and the default CWD path)"
        ),
        None => println!("no BENCH_tune.json present; golden-sample checks only"),
    }
}

#[test]
fn real_serve_artifact_if_present() {
    // CI's serve-smoke job runs the multi-model loadgen mix and then
    // sets PACIM_ENFORCE_SERVE_SLO=1: the report must hold ≥ 2 models
    // on sharded open-loop rows, every gated row under the p99 floor
    // with per-model traffic attribution, a nonzero steal count, and
    // aggregate throughput at a sane fraction of the offered rate — or
    // the job fails. An empty or single-shard report cannot pass.
    let enforce =
        std::env::var("PACIM_ENFORCE_SERVE_SLO").is_ok_and(|v| v != "0" && !v.is_empty());
    match artifact("PACIM_BENCH_SERVE_JSON", "BENCH_serve.json") {
        Some(p) => {
            let json = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let r = validate_serve(&json)
                .unwrap_or_else(|e| panic!("{} schema drift: {e}", p.display()));
            println!("validated {} ({} scenarios)", p.display(), r.scenarios.len());
            if enforce {
                enforce_serve_slo(&r)
                    .unwrap_or_else(|e| panic!("{} serve SLO regression: {e}", p.display()));
                println!("serve SLO enforced: multi-model p99/steals/traffic all held");
            }
        }
        None if enforce => panic!(
            "PACIM_ENFORCE_SERVE_SLO is set but no BENCH_serve.json was found \
             (checked PACIM_BENCH_SERVE_JSON and the default CWD path)"
        ),
        None => println!("no BENCH_serve.json present; golden-sample checks only"),
    }
}

#[test]
fn real_resilience_artifact_if_present() {
    // CI's bench-smoke job runs `pacim faultsweep --quick` and then sets
    // PACIM_ENFORCE_RESILIENCE=1: fault-off runs must have been
    // bit-identical to the fault-free engine, and at BER 1e-3 the
    // escalating engine must recover at least half the accuracy the
    // non-escalating one loses, or the job fails.
    let enforce =
        std::env::var("PACIM_ENFORCE_RESILIENCE").is_ok_and(|v| v != "0" && !v.is_empty());
    match artifact("PACIM_BENCH_RESILIENCE_JSON", "BENCH_resilience.json") {
        Some(p) => {
            let json = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let r = validate_resilience(&json)
                .unwrap_or_else(|e| panic!("{} schema drift: {e}", p.display()));
            println!(
                "validated {} ({} rows, fault-off bit-identical: {})",
                p.display(),
                r.rows.len(),
                r.fault_off_bit_identical
            );
            if enforce {
                enforce_resilience(&r)
                    .unwrap_or_else(|e| panic!("{} resilience regression: {e}", p.display()));
                println!("resilience gate enforced: recovery >= 50% at BER 1e-3");
            }
        }
        None if enforce => panic!(
            "PACIM_ENFORCE_RESILIENCE is set but no BENCH_resilience.json was found \
             (checked PACIM_BENCH_RESILIENCE_JSON and the default CWD path)"
        ),
        None => println!("no BENCH_resilience.json present; golden-sample checks only"),
    }
}
