//! Schema gate for the CI bench artifacts.
//!
//! `BENCH_hotpath.json` (benches/perf_hotpath.rs) and `BENCH_serve.json`
//! (examples/loadgen.rs) are uploaded by CI to track the perf trajectory;
//! future regression gating parses them, so they must stay
//! machine-readable. These tests validate golden samples against the
//! shared schema (`pacim::util::benchfmt`, `deny_unknown_fields`) and —
//! when the real files exist (CI runs this after the bench/loadgen jobs,
//! pointing `PACIM_BENCH_HOTPATH_JSON` / `PACIM_BENCH_SERVE_JSON` at the
//! produced artifacts) — re-parse the actual emitted JSON.

use pacim::util::benchfmt::{validate_hotpath, validate_serve};
use std::path::PathBuf;

const HOTPATH_GOLDEN: &str = r#"{
  "bench": "perf_hotpath",
  "threads": 4,
  "quick": true,
  "layers": [
    {
      "layer": "layer1.0.conv1",
      "dp_len": 576,
      "pairs": 96,
      "scalar_macs_per_s": 120000000.0,
      "parallel_macs_per_s": 360000000.0,
      "speedup": 3.0,
      "bit_identical": true
    }
  ]
}"#;

const SERVE_GOLDEN: &str = r#"{
  "bench": "serve",
  "quick": true,
  "scenarios": [
    {
      "name": "pac-open",
      "executor": "pac",
      "mode": "open",
      "workers": 2,
      "batch_size": 8,
      "queue_cap": 256,
      "offered_rps": 300.0,
      "requests": 48,
      "completed": 46,
      "rejected": 2,
      "failed_batches": 0,
      "wall_s": 0.21,
      "throughput_rps": 219.0,
      "p50_us": 2100.0,
      "p95_us": 5400.0,
      "p99_us": 7600.0,
      "mean_batch_occupancy": 6.57,
      "batch_fill": [0, 0, 1, 0, 1, 1, 0, 4],
      "modeled_cycles_per_image": 934912,
      "modeled_energy_uj_per_image": 11.8
    }
  ]
}"#;

#[test]
fn hotpath_golden_passes() {
    let r = validate_hotpath(HOTPATH_GOLDEN).unwrap();
    assert_eq!(r.layers.len(), 1);
}

#[test]
fn serve_golden_passes() {
    let r = validate_serve(SERVE_GOLDEN).unwrap();
    assert_eq!(r.scenarios[0].executor, "pac");
}

#[test]
fn renamed_field_is_schema_drift() {
    // A writer renaming `speedup` → `speed_up` must fail the gate in
    // both directions: unknown new name, missing old name.
    let drifted = HOTPATH_GOLDEN.replace("\"speedup\"", "\"speed_up\"");
    assert!(validate_hotpath(&drifted).is_err());
}

#[test]
fn extra_field_is_schema_drift() {
    let drifted = SERVE_GOLDEN.replace("\"quick\": true,", "\"quick\": true, \"v\": 2,");
    assert!(validate_serve(&drifted).is_err());
}

#[test]
fn inconsistent_batch_fill_rejected() {
    // 46 completed but the histogram only accounts for 4 requests.
    let drifted = SERVE_GOLDEN.replace(
        "\"batch_fill\": [0, 0, 1, 0, 1, 1, 0, 4]",
        "\"batch_fill\": [4, 0, 0, 0, 0, 0, 0, 0]",
    );
    assert!(validate_serve(&drifted).is_err());
}

/// Resolve a real artifact path: explicit env var wins; otherwise try
/// the default filename in CWD (bench binaries run with CWD = rust/).
fn artifact(env: &str, default_name: &str) -> Option<PathBuf> {
    if let Ok(p) = std::env::var(env) {
        return Some(PathBuf::from(p));
    }
    let p = PathBuf::from(default_name);
    p.exists().then_some(p)
}

#[test]
fn real_hotpath_artifact_if_present() {
    match artifact("PACIM_BENCH_HOTPATH_JSON", "BENCH_hotpath.json") {
        Some(p) => {
            let json = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let r = validate_hotpath(&json)
                .unwrap_or_else(|e| panic!("{} schema drift: {e}", p.display()));
            println!("validated {} ({} layers)", p.display(), r.layers.len());
        }
        None => println!("no BENCH_hotpath.json present; golden-sample checks only"),
    }
}

#[test]
fn real_serve_artifact_if_present() {
    match artifact("PACIM_BENCH_SERVE_JSON", "BENCH_serve.json") {
        Some(p) => {
            let json = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let r = validate_serve(&json)
                .unwrap_or_else(|e| panic!("{} schema drift: {e}", p.display()));
            println!("validated {} ({} scenarios)", p.display(), r.scenarios.len());
        }
        None => println!("no BENCH_serve.json present; golden-sample checks only"),
    }
}
