//! Schema gate for the CI bench artifacts.
//!
//! `BENCH_hotpath.json` (benches/perf_hotpath.rs) and `BENCH_serve.json`
//! (examples/loadgen.rs) are uploaded by CI to track the perf trajectory;
//! future regression gating parses them, so they must stay
//! machine-readable. These tests validate golden samples against the
//! shared schema (`pacim::util::benchfmt`, `deny_unknown_fields`) and —
//! when the real files exist (CI runs this after the bench/loadgen jobs,
//! pointing `PACIM_BENCH_HOTPATH_JSON` / `PACIM_BENCH_SERVE_JSON` at the
//! produced artifacts) — re-parse the actual emitted JSON.

use pacim::util::benchfmt::{enforce_blocked_floor, validate_hotpath, validate_serve};
use std::path::PathBuf;

const HOTPATH_GOLDEN: &str = r#"{
  "bench": "perf_hotpath",
  "threads": 4,
  "quick": true,
  "layers": [
    {
      "layer": "layer1.0.conv1",
      "dp_len": 576,
      "pairs": 96,
      "scalar_macs_per_s": 120000000.0,
      "parallel_macs_per_s": 360000000.0,
      "speedup": 3.0,
      "bit_identical": true
    }
  ],
  "blocked": [
    {
      "shape": "layer1.0.conv1",
      "dp_len": 576,
      "out_c": 64,
      "pixels": 192,
      "per_patch_macs_per_s": 120000000.0,
      "blocked_macs_per_s": 250000000.0,
      "speedup_blocked": 2.08,
      "bit_identical": true
    }
  ]
}"#;

const SERVE_GOLDEN: &str = r#"{
  "bench": "serve",
  "quick": true,
  "scenarios": [
    {
      "name": "pac-open",
      "executor": "pac",
      "mode": "open",
      "workers": 2,
      "batch_size": 8,
      "queue_cap": 256,
      "offered_rps": 300.0,
      "requests": 48,
      "completed": 46,
      "rejected": 2,
      "failed_batches": 0,
      "wall_s": 0.21,
      "throughput_rps": 219.0,
      "p50_us": 2100.0,
      "p95_us": 5400.0,
      "p99_us": 7600.0,
      "mean_batch_occupancy": 6.57,
      "batch_fill": [0, 0, 1, 0, 1, 1, 0, 4],
      "modeled_cycles_per_image": 934912,
      "modeled_energy_uj_per_image": 11.8
    }
  ]
}"#;

#[test]
fn hotpath_golden_passes() {
    let r = validate_hotpath(HOTPATH_GOLDEN).unwrap();
    assert_eq!(r.layers.len(), 1);
}

#[test]
fn serve_golden_passes() {
    let r = validate_serve(SERVE_GOLDEN).unwrap();
    assert_eq!(r.scenarios[0].executor, "pac");
}

#[test]
fn renamed_field_is_schema_drift() {
    // A writer renaming `speedup` → `speed_up` must fail the gate in
    // both directions: unknown new name, missing old name.
    let drifted = HOTPATH_GOLDEN.replace("\"speedup\"", "\"speed_up\"");
    assert!(validate_hotpath(&drifted).is_err());
    // Same for the blocked-GEMM rows.
    let drifted = HOTPATH_GOLDEN.replace("\"speedup_blocked\"", "\"blocked_speedup\"");
    assert!(validate_hotpath(&drifted).is_err());
    // Dropping the blocked section entirely is drift, not a pass.
    let drifted = HOTPATH_GOLDEN.replace("\"blocked\":", "\"blocked_rows\":");
    assert!(validate_hotpath(&drifted).is_err());
}

#[test]
fn blocked_regression_gate_catches_slowdown() {
    let r = validate_hotpath(HOTPATH_GOLDEN).unwrap();
    enforce_blocked_floor(&r).unwrap();
    let slowed = HOTPATH_GOLDEN.replace("\"speedup_blocked\": 2.08", "\"speedup_blocked\": 0.97");
    let r = validate_hotpath(&slowed).unwrap();
    assert!(enforce_blocked_floor(&r).unwrap_err().contains("regressed"));
}

#[test]
fn extra_field_is_schema_drift() {
    let drifted = SERVE_GOLDEN.replace("\"quick\": true,", "\"quick\": true, \"v\": 2,");
    assert!(validate_serve(&drifted).is_err());
}

#[test]
fn inconsistent_batch_fill_rejected() {
    // 46 completed but the histogram only accounts for 4 requests.
    let drifted = SERVE_GOLDEN.replace(
        "\"batch_fill\": [0, 0, 1, 0, 1, 1, 0, 4]",
        "\"batch_fill\": [4, 0, 0, 0, 0, 0, 0, 0]",
    );
    assert!(validate_serve(&drifted).is_err());
}

/// Resolve a real artifact path: explicit env var wins; otherwise try
/// the default filename in CWD (bench binaries run with CWD = rust/).
fn artifact(env: &str, default_name: &str) -> Option<PathBuf> {
    if let Ok(p) = std::env::var(env) {
        return Some(PathBuf::from(p));
    }
    let p = PathBuf::from(default_name);
    p.exists().then_some(p)
}

#[test]
fn real_hotpath_artifact_if_present() {
    // CI's bench-smoke job sets this env var after running the bench:
    // the blocked kernel must beat (or tie) the per-patch baseline on
    // every measured shape, or the job fails.
    let enforce = std::env::var("PACIM_ENFORCE_BLOCKED_SPEEDUP")
        .is_ok_and(|v| v != "0" && !v.is_empty());
    match artifact("PACIM_BENCH_HOTPATH_JSON", "BENCH_hotpath.json") {
        Some(p) => {
            let json = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let r = validate_hotpath(&json)
                .unwrap_or_else(|e| panic!("{} schema drift: {e}", p.display()));
            println!(
                "validated {} ({} layers, {} blocked rows)",
                p.display(),
                r.layers.len(),
                r.blocked.len()
            );
            if enforce {
                enforce_blocked_floor(&r)
                    .unwrap_or_else(|e| panic!("{} blocked-GEMM regression: {e}", p.display()));
                println!("blocked-GEMM floor enforced: all shapes >= 1.0x");
            }
        }
        // Enforcement with no artifact must be a hard failure — a green
        // gate that never parsed a report is worse than a red one.
        None if enforce => panic!(
            "PACIM_ENFORCE_BLOCKED_SPEEDUP is set but no BENCH_hotpath.json was found \
             (checked PACIM_BENCH_HOTPATH_JSON and the default CWD path)"
        ),
        None => println!("no BENCH_hotpath.json present; golden-sample checks only"),
    }
}

#[test]
fn real_serve_artifact_if_present() {
    match artifact("PACIM_BENCH_SERVE_JSON", "BENCH_serve.json") {
        Some(p) => {
            let json = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let r = validate_serve(&json)
                .unwrap_or_else(|e| panic!("{} schema drift: {e}", p.display()));
            println!("validated {} ({} scenarios)", p.display(), r.scenarios.len());
        }
        None => println!("no BENCH_serve.json present; golden-sample checks only"),
    }
}
