//! The `pacim::engine` front door: typed error paths (unit tests) and
//! the facade invariant (property tests) — `Engine`/`Session` output is
//! **bit-identical** (logits *and* `RunStats`) to the retained low-level
//! reference path (`nn::run_model_with` over an explicitly constructed
//! backend), for both backends, with parallelism on and off.

use pacim::arch::ThresholdSet;
use pacim::coordinator::{BatchPolicy, InferenceServer, ServeError};
use pacim::engine::{EngineBuilder, PacimError};
use pacim::nn::layers::synthetic::random_store;
use pacim::nn::{
    exact_backend, pac_backend, run_model_with, tiny_resnet, ConvLayer, LinearLayer, Model,
    ModelScratch, Op, PacConfig, RunStats,
};
use pacim::pac::{ComputeMap, PcuRounding};
use pacim::runtime::PacExecutor;
use pacim::tensor::{Conv2dGeom, QuantParams, Tensor};
use pacim::util::check::Checker;
use pacim::util::rng::Rng;
use pacim::util::Parallelism;

fn small_model(seed: u64, c: usize, classes: usize, hw: usize) -> Model {
    let mut rng = Rng::new(seed);
    tiny_resnet(&random_store(&mut rng, c, classes), hw, classes).unwrap()
}

fn image_for(model: &Model, rng: &mut Rng) -> Vec<u8> {
    (0..model.in_c * model.in_hw * model.in_hw)
        .map(|_| rng.below(256) as u8)
        .collect()
}

fn assert_stats_eq(a: &RunStats, b: &RunStats) {
    assert_eq!(a.macs, b.macs);
    assert_eq!(a.digital_cycles, b.digital_cycles);
    assert_eq!(a.pcu_ops, b.pcu_ops);
    assert_eq!(a.levels, b.levels);
    // Same backend config ⇒ same dataplane decisions ⇒ the measured
    // traffic ledgers must agree edge for edge too.
    assert_eq!(a.traffic, b.traffic);
}

// ---------------------------------------------------------------------------
// Facade invariant: Engine ≡ legacy reference path, bit for bit.
// ---------------------------------------------------------------------------

#[test]
fn prop_engine_bit_identical_to_legacy_reference() {
    // The acceptance invariant of the API redesign: for random models,
    // images, backend modes, configurations, and parallelism policies,
    // the engine façade reproduces the reference path exactly — logits
    // and statistics. A pure refactor: zero numeric drift.
    Checker::new("engine_vs_reference", 24).run(|rng| {
        let classes = 2 + rng.below(6) as usize;
        let model = small_model(rng.next_u64(), 4, classes, 8);
        let img = image_for(&model, rng);
        let par = if rng.bernoulli(0.5) {
            Parallelism::off()
        } else {
            Parallelism {
                enabled: true,
                min_items: 1,
            }
        };
        let exact_mode = rng.bernoulli(0.4);
        let cfg = PacConfig {
            map: if rng.bernoulli(0.5) {
                ComputeMap::operand_based(4, 4)
            } else {
                ComputeMap::operand_based(5, 3)
            },
            thresholds: None,
            rounding: if rng.bernoulli(0.5) {
                PcuRounding::RoundNearest
            } else {
                PcuRounding::Floor
            },
            first_layer_exact: rng.bernoulli(0.5),
            min_dp_len: if rng.bernoulli(0.5) { 0 } else { 512 },
            par: Parallelism::off(),
            fuse_dataplane: rng.bernoulli(0.5),
            ..PacConfig::default()
        };

        // Reference: explicit backend + the low-level interpreter entry.
        let (ref_logits, ref_stats) = if exact_mode {
            let b = exact_backend(&model);
            run_model_with(&model, &b, &img, &par, &mut ModelScratch::default()).unwrap()
        } else {
            let b = pac_backend(&model, cfg.clone());
            run_model_with(&model, &b, &img, &par, &mut ModelScratch::default()).unwrap()
        };

        // Façade: the same computation through the one front door.
        let builder = EngineBuilder::new(model).parallelism(par);
        let engine = if exact_mode {
            builder.exact().build().unwrap()
        } else {
            builder.pac(cfg).build().unwrap()
        };
        let mut session = engine.session();
        let out = session.infer(&img).unwrap();
        assert_eq!(out.logits, ref_logits, "engine logits diverged");
        assert_stats_eq(&out.stats, &ref_stats);

        // Warm-scratch repeat: same result out of reused arenas.
        let again = session.infer(&img).unwrap();
        assert_eq!(again.logits, ref_logits);
        assert_stats_eq(&again.stats, &ref_stats);
    });
}

#[test]
fn prop_engine_dynamic_thresholds_match_reference() {
    // Same invariant on the dynamic-workload path (per-pixel level
    // classification), including the level histogram.
    Checker::new("engine_dynamic_vs_reference", 12).run(|rng| {
        let model = small_model(rng.next_u64(), 4, 4, 8);
        let img = image_for(&model, rng);
        let th = ThresholdSet::new(0.08, 0.16, 0.30);
        let cfg = PacConfig {
            thresholds: Some(th),
            ..PacConfig::default()
        };
        let b = pac_backend(&model, cfg);
        let (ref_logits, ref_stats) =
            run_model_with(&model, &b, &img, &Parallelism::off(), &mut ModelScratch::default())
                .unwrap();
        let engine = EngineBuilder::new(model)
            .pac(PacConfig::default())
            .dynamic(th)
            .parallelism(Parallelism::off())
            .build()
            .unwrap();
        let out = engine.session().infer(&img).unwrap();
        assert_eq!(out.logits, ref_logits);
        assert_stats_eq(&out.stats, &ref_stats);
        assert!(out.stats.levels.total() > 0, "dynamic path must classify");
    });
}

#[test]
fn prop_fused_dataplane_invariant_through_engine() {
    // The sparsity-encoded dataplane is numerically inert: an engine
    // with producer-side encoding on must reproduce the dense
    // round-trip engine bit for bit — logits and cycle/op counters —
    // while the measured traffic ledgers differ exactly in the encoded
    // edges. Covers single-image, warm-scratch repeat, and batch.
    Checker::new("engine_fused_vs_roundtrip", 12).run(|rng| {
        let model = small_model(rng.next_u64(), 4, 4, 8);
        let img = image_for(&model, rng);
        let base = PacConfig {
            first_layer_exact: rng.bernoulli(0.5),
            min_dp_len: 0,
            par: Parallelism::off(),
            fuse_dataplane: false,
            ..PacConfig::default()
        };
        let fused_cfg = PacConfig {
            fuse_dataplane: true,
            ..base.clone()
        };
        let dense = EngineBuilder::new(model.clone()).pac(base).build().unwrap();
        let fused = EngineBuilder::new(model).pac(fused_cfg).build().unwrap();
        let (mut sd, mut sf) = (dense.session(), fused.session());
        let a = sd.infer(&img).unwrap();
        let b = sf.infer(&img).unwrap();
        assert_eq!(a.logits, b.logits, "fused engine logits diverged");
        assert_eq!(a.stats.macs, b.stats.macs);
        assert_eq!(a.stats.digital_cycles, b.stats.digital_cycles);
        assert_eq!(a.stats.pcu_ops, b.stats.pcu_ops);
        assert_eq!(a.stats.levels, b.stats.levels);
        // tiny_resnet's fused dataplane encodes 14 of 15 ledger rows:
        // 9 conv/save payload edges, 3 eliminated add-in edges, and 2
        // encoded post-add edges — only the add→GAP handoff stays dense.
        assert_eq!(a.stats.traffic.encoded_layer_count(), 0);
        assert_eq!(b.stats.traffic.encoded_layer_count(), 14);
        assert_eq!(
            a.stats.traffic.total_baseline_bits(),
            b.stats.traffic.total_baseline_bits()
        );
        assert!(b.stats.traffic.total_bits() <= a.stats.traffic.total_bits());
        // Warm-scratch repeat through the same sessions.
        let b2 = sf.infer(&img).unwrap();
        assert_eq!(b2.logits, a.logits);
        // Batch lanes reproduce the single-image path.
        let imgs = [img.as_slice(), img.as_slice()];
        for lane in sf.infer_batch(&imgs).unwrap() {
            assert_eq!(lane.logits, a.logits);
            assert_eq!(lane.stats.traffic, b.stats.traffic);
        }
    });
}

#[test]
fn prop_infer_batch_matches_sequential_infer() {
    Checker::new("engine_batch_vs_sequential", 12).run(|rng| {
        let model = small_model(rng.next_u64(), 4, 4, 8);
        let engine = EngineBuilder::new(model.clone())
            .pac(PacConfig::default())
            .build()
            .unwrap();
        let lanes = 1 + rng.below(5) as usize;
        let imgs: Vec<Vec<u8>> = (0..lanes).map(|_| image_for(&model, rng)).collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|v| v.as_slice()).collect();
        let mut session = engine.session();
        let seq: Vec<_> = refs.iter().map(|i| session.infer(i).unwrap()).collect();
        for lane_par in [Parallelism::off(), Parallelism::coarse()] {
            let mut batch_session = engine.session();
            batch_session.set_lane_parallelism(lane_par);
            let batch = batch_session.infer_batch(&refs).unwrap();
            assert_eq!(batch.len(), seq.len());
            for (a, b) in batch.iter().zip(&seq) {
                assert_eq!(a.logits, b.logits);
                assert_stats_eq(&a.stats, &b.stats);
            }
        }
    });
}

#[test]
fn engine_evaluate_matches_sequential_reference() {
    // Same accuracy, same aggregate statistics, including last-wins
    // argmax tie-breaking, vs an explicit sequential reference sweep
    // over the retained low-level entry point — at 1 and 4 threads.
    let model = small_model(4242, 8, 10, 16);
    let mut rng = Rng::new(77);
    let imgs: Vec<Vec<u8>> = (0..12).map(|_| image_for(&model, &mut rng)).collect();
    let images: Vec<&[u8]> = imgs.iter().map(|v| v.as_slice()).collect();
    let labels: Vec<usize> = (0..12).map(|_| rng.below(10) as usize).collect();

    // Reference: one image at a time through `run_model_with`, with the
    // engine's argmax semantics (ties go to the *last* maximal index).
    let backend = pac_backend(&model, PacConfig::default());
    let mut correct = 0usize;
    let mut ref_stats = RunStats::default();
    let mut scratch = ModelScratch::default();
    for (img, &label) in images.iter().zip(&labels) {
        let (logits, stats) =
            run_model_with(&model, &backend, img, &Parallelism::off(), &mut scratch).unwrap();
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &x) in logits.iter().enumerate() {
            if x >= best_v {
                best_v = x;
                best = i;
            }
        }
        if best == label {
            correct += 1;
        }
        ref_stats.merge(&stats);
    }
    let ref_acc = correct as f64 / images.len() as f64;

    for threads in [1usize, 4] {
        let engine = EngineBuilder::new(model.clone())
            .pac(PacConfig::default())
            .build()
            .unwrap();
        let ev = engine.evaluate(&images, &labels, threads).unwrap();
        assert_eq!(ev.accuracy, ref_acc, "threads={threads}");
        assert_stats_eq(&ev.stats, &ref_stats);
        assert_eq!(ev.images, 12);
    }
}

#[test]
fn cost_estimates_follow_backend_mode() {
    let model = small_model(5555, 4, 4, 8);
    let exact = EngineBuilder::new(model.clone()).exact().build().unwrap();
    let pac = EngineBuilder::new(model.clone())
        .pac(PacConfig::default())
        .build()
        .unwrap();
    let dynamic = EngineBuilder::new(model)
        .pac(PacConfig::default())
        .dynamic(ThresholdSet::default_cifar())
        .build()
        .unwrap();
    let (ce, cp, cd) = (
        exact.cost_estimate(),
        pac.cost_estimate(),
        dynamic.cost_estimate(),
    );
    assert!(cp.cycles < ce.cycles, "PAC must model fewer cycles");
    assert!(cd.cycles < cp.cycles, "dynamic must model fewer still");
    // Sessions expose the same annotation.
    assert_eq!(pac.session().cost_estimate().cycles, cp.cycles);
}

// ---------------------------------------------------------------------------
// Typed error paths: shapes.
// ---------------------------------------------------------------------------

#[test]
fn prop_wrong_input_length_is_typed_never_fatal() {
    Checker::new("engine_bad_input_lengths", 48).run(|rng| {
        let model = small_model(rng.next_u64(), 4, 4, 8);
        let engine = EngineBuilder::new(model).exact().build().unwrap();
        let want = engine.input_elems();
        let mut got = rng.below(2 * want as u32 + 7) as usize;
        if got == want {
            got += 1;
        }
        let mut session = engine.session();
        match session.infer(&vec![0u8; got]) {
            Err(PacimError::ShapeMismatch { got: g, want: w, .. }) => {
                assert_eq!((g, w), (got, want));
            }
            other => panic!("wanted ShapeMismatch, got {other:?}"),
        }
        match session.infer_f32(&vec![0.0f32; got]) {
            Err(PacimError::ShapeMismatch { got: g, .. }) => assert_eq!(g, got),
            other => panic!("wanted ShapeMismatch, got {other:?}"),
        }
        let good = vec![0u8; want];
        let bad = vec![0u8; got];
        match session.infer_batch(&[good.as_slice(), bad.as_slice()]) {
            Err(PacimError::ShapeMismatch { context, .. }) => {
                assert!(context.contains("lane 1"), "{context}");
            }
            other => panic!("wanted ShapeMismatch, got {other:?}"),
        }
        // The session stays usable after every rejection.
        assert!(session.infer(&good).is_ok());
    });
}

#[test]
fn evaluate_label_arity_mismatch_is_typed() {
    let model = small_model(99, 4, 4, 8);
    let engine = EngineBuilder::new(model).exact().build().unwrap();
    let img = vec![0u8; engine.input_elems()];
    let err = engine.evaluate(&[img.as_slice()], &[0, 1], 2).unwrap_err();
    assert!(matches!(err, PacimError::ShapeMismatch { .. }), "{err}");
}

// ---------------------------------------------------------------------------
// Typed error paths: configuration.
// ---------------------------------------------------------------------------

#[test]
fn invalid_cycle_split_rejected() {
    let model = small_model(100, 4, 4, 8);
    for (bx, bw) in [(9u32, 4u32), (4, 9), (200, 200)] {
        let err = EngineBuilder::new(model.clone())
            .approx_bits(bx, bw)
            .build()
            .unwrap_err();
        assert!(matches!(err, PacimError::InvalidConfig(_)), "{bx}x{bw}: {err}");
    }
    // In-range splits build fine, including the degenerate all-sparsity 0×0.
    for (bx, bw) in [(0u32, 0u32), (8, 8), (4, 4)] {
        assert!(EngineBuilder::new(model.clone()).approx_bits(bx, bw).build().is_ok());
    }
}

#[test]
fn dynamic_thresholds_require_4x4_base_map() {
    let model = small_model(101, 4, 4, 8);
    let cfg = PacConfig {
        map: ComputeMap::operand_based(5, 5),
        thresholds: Some(ThresholdSet::default_cifar()),
        ..PacConfig::default()
    };
    let err = EngineBuilder::new(model.clone()).pac(cfg).build().unwrap_err();
    match err {
        PacimError::InvalidConfig(msg) => {
            assert!(msg.contains("4×4"), "{msg}");
            assert!(msg.contains("16 digital"), "{msg}");
        }
        other => panic!("wanted InvalidConfig, got {other:?}"),
    }
    // On the 4×4 base the same thresholds are accepted.
    let ok = PacConfig {
        thresholds: Some(ThresholdSet::default_cifar()),
        ..PacConfig::default()
    };
    assert!(EngineBuilder::new(model).pac(ok).build().is_ok());
}

#[test]
fn exact_backend_rejects_pac_only_options() {
    let model = small_model(102, 4, 4, 8);
    let e1 = EngineBuilder::new(model.clone())
        .exact()
        .dynamic(ThresholdSet::default_cifar())
        .build()
        .unwrap_err();
    assert!(matches!(e1, PacimError::InvalidConfig(_)), "{e1}");
    let e2 = EngineBuilder::new(model)
        .exact()
        .approx_bits(4, 4)
        .build()
        .unwrap_err();
    assert!(matches!(e2, PacimError::InvalidConfig(_)), "{e2}");
}

// ---------------------------------------------------------------------------
// Typed error paths: model validation.
// ---------------------------------------------------------------------------

fn logits_linear(in_f: usize, out_f: usize) -> LinearLayer {
    LinearLayer {
        name: "fc".into(),
        in_f,
        out_f,
        weight: Tensor::from_vec(&[out_f, in_f], vec![1u8; out_f * in_f]),
        wparams: QuantParams::new(1.0, 0),
        bias: vec![0.0; out_f],
        out_params: None,
        relu: false,
    }
}

fn mini_model(ops: Vec<Op>, in_c: usize, in_hw: usize) -> Model {
    Model {
        name: "mini".into(),
        ops,
        input_params: QuantParams::new(1.0, 0),
        in_c,
        in_hw,
        num_classes: 2,
    }
}

#[test]
fn empty_model_is_a_typed_error() {
    let err = EngineBuilder::new(mini_model(vec![], 1, 4)).exact().build().unwrap_err();
    match err {
        PacimError::Model(msg) => assert!(msg.contains("no compute layers"), "{msg}"),
        other => panic!("wanted Model error, got {other:?}"),
    }
}

#[test]
fn model_without_logits_layer_is_a_typed_error() {
    // A pooling-only program never produces logits.
    let err = EngineBuilder::new(mini_model(vec![Op::MaxPool2, Op::GlobalAvgPool], 1, 4))
        .exact()
        .build()
        .unwrap_err();
    assert!(matches!(err, PacimError::Model(_)), "{err}");
}

#[test]
fn unbalanced_skip_stack_is_a_typed_error() {
    let ops = vec![
        Op::AddSkip {
            out_params: QuantParams::new(1.0, 0),
            relu: false,
        },
        Op::Linear(logits_linear(16, 2)),
    ];
    let err = EngineBuilder::new(mini_model(ops, 1, 4)).exact().build().unwrap_err();
    match err {
        PacimError::Model(msg) => assert!(msg.contains("SaveSkip"), "{msg}"),
        other => panic!("wanted Model error, got {other:?}"),
    }
}

#[test]
fn leftover_save_skip_is_a_typed_error() {
    // The other direction of skip-stack balance: a pushed activation
    // that no AddSkip ever consumes (a silently dropped residual).
    let ops = vec![Op::SaveSkip, Op::GlobalAvgPool, Op::Linear(logits_linear(1, 2))];
    let err = EngineBuilder::new(mini_model(ops, 1, 4)).exact().build().unwrap_err();
    match err {
        PacimError::Model(msg) => assert!(msg.contains("unconsumed"), "{msg}"),
        other => panic!("wanted Model error, got {other:?}"),
    }
}

#[test]
fn unreachable_ops_after_logits_are_a_typed_error() {
    let ops = vec![
        Op::GlobalAvgPool,
        Op::Linear(logits_linear(1, 2)),
        Op::MaxPool2, // dead: the logits layer ended the program
    ];
    let err = EngineBuilder::new(mini_model(ops, 1, 4)).exact().build().unwrap_err();
    match err {
        PacimError::Model(msg) => assert!(msg.contains("unreachable"), "{msg}"),
        other => panic!("wanted Model error, got {other:?}"),
    }
}

#[test]
fn conv_geometry_mismatch_is_a_typed_error() {
    // Conv declares 3 input channels; the program hands it 1.
    let geom = Conv2dGeom {
        in_c: 3,
        in_h: 4,
        in_w: 4,
        out_c: 2,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
    };
    let conv = ConvLayer {
        name: "bad".into(),
        geom,
        weight: Tensor::from_vec(&[2, geom.dp_len()], vec![0u8; 2 * geom.dp_len()]),
        wparams: QuantParams::new(1.0, 0),
        bias: vec![0.0; 2],
        out_params: QuantParams::new(1.0, 0),
        relu: true,
    };
    let ops = vec![Op::Conv2d(conv), Op::GlobalAvgPool, Op::Linear(logits_linear(2, 2))];
    let err = EngineBuilder::new(mini_model(ops, 1, 4)).exact().build().unwrap_err();
    assert!(matches!(err, PacimError::Model(_)), "{err}");
}

#[test]
fn linear_arity_mismatch_is_a_typed_error() {
    // 1×4×4 input flattens to 16 features; the linear declares 8.
    let ops = vec![Op::Linear(logits_linear(8, 2))];
    let err = EngineBuilder::new(mini_model(ops, 1, 4)).exact().build().unwrap_err();
    assert!(matches!(err, PacimError::Model(_)), "{err}");
}

// ---------------------------------------------------------------------------
// Typed error paths: serving passthrough.
// ---------------------------------------------------------------------------

#[test]
fn serve_bad_input_converts_to_shape_mismatch() {
    let model = small_model(103, 4, 4, 8);
    let exec = PacExecutor::new(model, PacConfig::serving(), 2).unwrap();
    let want = exec.engine().input_elems();
    let server = InferenceServer::start_pool(
        move |_| Ok(exec.clone()),
        BatchPolicy::default(),
    )
    .unwrap();
    let h = server.handle();
    let serve_err = match h.submit(vec![0.0; 3]) {
        Err(e) => e,
        Ok(_) => panic!("a 3-element submission must be rejected"),
    };
    assert!(matches!(serve_err, ServeError::BadInput { got: 3, .. }));
    // Queue-full and shape errors pass through the typed taxonomy.
    let typed: PacimError = serve_err.into();
    match typed {
        PacimError::ShapeMismatch { got, want: w, .. } => {
            assert_eq!(got, 3);
            assert_eq!(w, want);
        }
        other => panic!("wanted ShapeMismatch, got {other:?}"),
    }
    server.stop();
}

#[test]
fn queue_full_and_lifecycle_errors_pass_through_typed() {
    let full: PacimError = ServeError::QueueFull { capacity: 7 }.into();
    assert!(matches!(full, PacimError::QueueFull { capacity: 7 }), "{full}");
    let stopped: PacimError = ServeError::Stopped.into();
    assert!(matches!(stopped, PacimError::ServerStopped));
    let dropped: PacimError = ServeError::Dropped.into();
    assert!(matches!(dropped, PacimError::RequestDropped));
    let lost: PacimError = ServeError::WorkerLost.into();
    assert!(matches!(lost, PacimError::WorkerLost));
    let late: PacimError = ServeError::DeadlineExceeded.into();
    assert!(matches!(late, PacimError::DeadlineExceeded));
}

#[test]
fn crate_error_converts_losslessly() {
    let e: PacimError = pacim::Error::Shape("weights.bin stem.w".into()).into();
    assert!(matches!(e, PacimError::Model(_)), "{e}");
    let c: PacimError = pacim::Error::Config("bad".into()).into();
    assert!(matches!(c, PacimError::InvalidConfig(_)), "{c}");
}

#[test]
fn empty_batch_is_ok_and_empty() {
    let model = small_model(104, 4, 4, 8);
    let engine = EngineBuilder::new(model).exact().build().unwrap();
    assert!(engine.session().infer_batch(&[]).unwrap().is_empty());
}
