//! Integration: PJRT runtime <-> AOT artifacts.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! trivially with a notice) when artifacts are absent so `cargo test`
//! stays green on a fresh checkout. The whole file additionally requires
//! the `pjrt` cargo feature (and its vendored xla-rs toolchain); without
//! it the file compiles to an empty test binary.

#![cfg(feature = "pjrt")]

use pacim::engine::EngineBuilder;
use pacim::nn::{tiny_resnet, WeightStore};
use pacim::runtime::{Manifest, PjrtExecutor};
use pacim::workload::Dataset;

fn artifacts() -> Option<Manifest> {
    let dir = pacim::runtime::manifest::artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn pjrt_loads_and_runs_model_pac() {
    let Some(man) = artifacts() else { return };
    let batch = man.batch().unwrap();
    let in_elems = man.input_elems().unwrap();
    let classes = man.classes().unwrap();
    let exe = PjrtExecutor::load(man.path("model_pac").unwrap(), batch, in_elems, classes)
        .expect("compile model_pac");
    let ds = Dataset::load(man.path("dataset").unwrap()).unwrap();
    let mut flat = vec![0f32; batch * in_elems];
    for i in 0..batch {
        for (j, &q) in ds.image(i).iter().enumerate() {
            flat[i * in_elems + j] = ds.params.dequantize(q);
        }
    }
    let out = exe.run(&flat).expect("execute");
    assert_eq!(out.len(), batch * classes);
    assert!(out.iter().all(|v| v.is_finite()));
    // Logits must discriminate: not all equal.
    let first = &out[..classes];
    assert!(first.iter().any(|&v| (v - first[0]).abs() > 1e-6));
}

#[test]
fn pjrt_model_exact_matches_rust_engine_predictions() {
    // The exported exact model and the rust bit-true engine implement the
    // same quantized network; their predictions must agree on real data
    // (logits may differ in float round-off, argmax almost never).
    let Some(man) = artifacts() else { return };
    let batch = man.batch().unwrap();
    let in_elems = man.input_elems().unwrap();
    let classes = man.classes().unwrap();
    let exe =
        PjrtExecutor::load(man.path("model_exact").unwrap(), batch, in_elems, classes)
            .expect("compile model_exact");
    let ds = Dataset::load(man.path("dataset").unwrap()).unwrap();
    let store = WeightStore::load(man.path("weights").unwrap()).unwrap();
    let model = tiny_resnet(&store, ds.h, ds.n_classes).unwrap();
    let engine = EngineBuilder::new(model).exact().build().unwrap();
    let mut session = engine.session();

    let mut flat = vec![0f32; batch * in_elems];
    for i in 0..batch {
        for (j, &q) in ds.image(i).iter().enumerate() {
            flat[i * in_elems + j] = ds.params.dequantize(q);
        }
    }
    let out = exe.run(&flat).expect("execute");
    let mut agree = 0;
    for i in 0..batch {
        let hlo_pred = argmax(&out[i * classes..(i + 1) * classes]);
        let rust_pred = session.infer(ds.image(i)).unwrap().argmax();
        if hlo_pred == rust_pred {
            agree += 1;
        }
    }
    assert!(
        agree * 10 >= batch * 9,
        "only {agree}/{batch} argmax agreements between HLO and rust engine"
    );
}

#[test]
fn pjrt_pac_kernel_artifact_runs() {
    let Some(man) = artifacts() else { return };
    let Ok(path) = man.path("pac_kernel") else { return };
    // Kernel artifact: int32 (128, 576) x (576, 64). PjrtExecutor is
    // f32-shaped, so drive the xla API directly here.
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).unwrap();
    let x: Vec<i32> = (0..128 * 576).map(|i| ((i * 37 + 11) % 256) as i32).collect();
    let w: Vec<i32> = (0..576 * 64).map(|i| ((i * 53 + 7) % 256) as i32).collect();
    let xl = xla::Literal::vec1(&x).reshape(&[128, 576]).unwrap();
    let wl = xla::Literal::vec1(&w).reshape(&[576, 64]).unwrap();
    let result = exe.execute::<xla::Literal>(&[xl, wl]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let out = result.to_tuple1().unwrap();
    let vals = out.to_vec::<i32>().unwrap();
    assert_eq!(vals.len(), 128 * 64);

    // Cross-check a handful of outputs against the rust PAC reference.
    use pacim::pac::{hybrid_mac, BitPlanes, ComputeMap, PcuRounding};
    let map = ComputeMap::operand_based(4, 4);
    for m in [0usize, 17, 127] {
        let xrow: Vec<u8> = (0..576).map(|k| x[m * 576 + k] as u8).collect();
        for n in [0usize, 33, 63] {
            let wcol: Vec<u8> = (0..576).map(|k| w[k * 64 + n] as u8).collect();
            let xp = BitPlanes::from_u8(&xrow);
            let wp = BitPlanes::from_u8(&wcol);
            let h = hybrid_mac(&xp, &wp, &map, PcuRounding::RoundNearest);
            let sum_x: i64 = xrow.iter().map(|&v| v as i64).sum();
            let sum_w: i64 = wcol.iter().map(|&v| v as i64).sum();
            let want =
                pacim::pac::zero_point_correct(h.value, sum_x, sum_w, 576, 7, 128);
            assert_eq!(
                vals[m * 64 + n] as i64, want,
                "mismatch at ({m},{n}): python kernel vs rust hybrid_mac"
            );
        }
    }
}

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}
