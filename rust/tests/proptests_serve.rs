//! Property-based tests over the serving batcher's invariants, using the
//! in-house `Checker` harness (proptest is unavailable offline).
//!
//! The invariants under test, across random batch sizes, worker counts,
//! queue capacities, and traffic shapes:
//!
//! 1. **No request lost or duplicated** across deadline flushes: every
//!    admitted request gets exactly one reply, and the reply echoes that
//!    request's own payload (a lane misalignment or a padded lane
//!    leaking into a reply would break the echo).
//! 2. **Conservation**: batch-fill histogram × occupancy = requests, and
//!    `padded_slots` completes every batch to the compiled size.
//! 3. **Shutdown drains**: requests admitted before `stop()` are all
//!    answered; requests after are rejected with `Stopped`.
//! 4. **Load-shed fires exactly at capacity**: with the single worker
//!    parked inside `execute()`, exactly `queue_cap` submissions are
//!    admitted and the next one fails with `QueueFull`.

use pacim::coordinator::{BatchExecutor, BatchPolicy, InferenceServer, ServeError};
use pacim::util::check::Checker;
use std::sync::mpsc;
use std::time::Duration;

/// Echo executor: logit 0 of lane i = input[0] of lane i, logit 1 =
/// input[1]. Padded lanes echo zeros, so any lane/reply misalignment is
/// visible to the client.
struct EchoExec {
    batch: usize,
    in_elems: usize,
    delay: Duration,
}

impl BatchExecutor for EchoExec {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn input_elems(&self) -> usize {
        self.in_elems
    }

    fn output_elems(&self) -> usize {
        2
    }

    fn execute(&mut self, batch: &[f32], _occupancy: usize) -> anyhow::Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut out = Vec::with_capacity(self.batch * 2);
        for i in 0..self.batch {
            out.push(batch[i * self.in_elems]);
            out.push(batch[i * self.in_elems + 1]);
        }
        Ok(out)
    }
}

#[test]
fn prop_no_request_lost_or_duplicated() {
    Checker::new("serve_no_loss_no_dup", 25).run(|rng| {
        let batch = 1 + rng.below(6) as usize;
        let workers = 1 + rng.below(3) as usize;
        let n = 1 + rng.below(40) as usize;
        let in_elems = 3;
        let server = InferenceServer::start_pool(
            move |_| {
                Ok(EchoExec {
                    batch,
                    in_elems,
                    delay: Duration::from_micros(100),
                })
            },
            BatchPolicy {
                max_wait: Duration::from_micros(500),
                workers,
                queue_cap: 4 * n,
                ..BatchPolicy::default()
            },
        )
        .unwrap();
        let h = server.handle();
        // Submit all n open-loop, then harvest: replies must echo each
        // request's unique id (payload [i, 1000+i, 0]).
        let pending: Vec<_> = (0..n)
            .map(|i| {
                h.submit(vec![i as f32, 1000.0 + i as f32, 0.0]).unwrap()
            })
            .collect();
        for (i, p) in pending.into_iter().enumerate() {
            let r = p.wait().unwrap();
            assert_eq!(
                r.logits,
                vec![i as f32, 1000.0 + i as f32],
                "reply for request {i} does not echo its own payload"
            );
            assert!(r.occupancy >= 1 && r.occupancy <= batch);
        }
        let m = server.stop();
        assert_eq!(m.requests, n as u64, "requests lost or duplicated");
        assert_eq!(m.rejected, 0);
        // Conservation: the fill histogram re-derives requests and pads.
        let filled: u64 = m
            .batch_fill
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as u64 + 1) * c)
            .sum();
        assert_eq!(filled, m.requests);
        assert_eq!(m.padded_slots, m.batches * batch as u64 - m.requests);
    });
}

#[test]
fn prop_shutdown_drains_every_admitted_request() {
    Checker::new("serve_drain", 25).run(|rng| {
        let batch = 1 + rng.below(4) as usize;
        let workers = 1 + rng.below(2) as usize;
        let n = 1 + rng.below(20) as usize;
        let server = InferenceServer::start_pool(
            move |_| {
                Ok(EchoExec {
                    batch,
                    in_elems: 2,
                    delay: Duration::from_millis(1),
                })
            },
            BatchPolicy {
                max_wait: Duration::from_micros(200),
                workers,
                queue_cap: 4 * n,
                ..BatchPolicy::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let pending: Vec<_> = (0..n)
            .map(|i| h.submit(vec![i as f32, 0.0]).unwrap())
            .collect();
        // Stop concurrently with the drain: every admitted request must
        // still be answered.
        let stopper = std::thread::spawn(move || server.stop());
        for (i, p) in pending.into_iter().enumerate() {
            let r = p.wait().unwrap();
            assert_eq!(r.logits[0], i as f32);
        }
        let m = stopper.join().unwrap();
        assert_eq!(m.requests, n as u64);
        // The queue is closed: new submissions are rejected.
        assert!(matches!(
            h.infer(vec![0.0, 0.0]),
            Err(ServeError::Stopped)
        ));
    });
}

/// Executor that parks inside `execute` until released, signalling entry
/// — lets the test pin the worker and fill the queue deterministically.
struct GatedExec {
    entered: mpsc::Sender<()>,
    gate: mpsc::Receiver<()>,
}

impl BatchExecutor for GatedExec {
    fn batch_size(&self) -> usize {
        1
    }

    fn input_elems(&self) -> usize {
        1
    }

    fn output_elems(&self) -> usize {
        1
    }

    fn execute(&mut self, batch: &[f32], _occupancy: usize) -> anyhow::Result<Vec<f32>> {
        let _ = self.entered.send(());
        let _ = self.gate.recv();
        Ok(vec![batch[0]])
    }
}

#[test]
fn prop_load_shed_fires_exactly_at_capacity() {
    Checker::new("serve_load_shed", 20).run(|rng| {
        let cap = 1 + rng.below(8) as usize;
        let (entered_tx, entered_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let cell = std::sync::Mutex::new(Some(GatedExec {
            entered: entered_tx,
            gate: gate_rx,
        }));
        let server = InferenceServer::start_pool(
            move |_| {
                Ok(cell
                    .lock()
                    .unwrap()
                    .take()
                    .expect("single worker, single executor"))
            },
            BatchPolicy {
                max_wait: Duration::from_micros(1),
                workers: 1,
                queue_cap: cap,
                ..BatchPolicy::default()
            },
        )
        .unwrap();
        let h = server.handle();
        // Park the worker: first request is popped and blocks in
        // execute(); wait for the entry signal so the queue is empty.
        let parked = h.submit(vec![0.5]).unwrap();
        entered_rx.recv().unwrap();
        // Now exactly `cap` submissions are admitted...
        let pending: Vec<_> = (0..cap)
            .map(|i| h.submit(vec![i as f32]).unwrap())
            .collect();
        // ...and the next one sheds with the typed error.
        match h.submit(vec![99.0]) {
            Err(ServeError::QueueFull { capacity }) => assert_eq!(capacity, cap),
            Err(e) => panic!("expected QueueFull, got {e:?}"),
            Ok(_) => panic!("expected QueueFull, got an admitted request"),
        }
        // Release the worker (one token per pending execute call).
        for _ in 0..cap + 1 {
            gate_tx.send(()).unwrap();
        }
        assert_eq!(parked.wait().unwrap().logits, vec![0.5]);
        for (i, p) in pending.into_iter().enumerate() {
            assert_eq!(p.wait().unwrap().logits, vec![i as f32]);
        }
        let m = server.stop();
        assert_eq!(m.requests, cap as u64 + 1);
        assert_eq!(m.rejected, 1, "exactly one submission load-shed");
    });
}
