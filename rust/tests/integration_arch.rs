//! Integration: the structural bank model against the flat NN backend,
//! the encoder against the engine's activations, and the scheduler
//! against hand-counted tilings.

use pacim::arch::{
    encoder::{encode_conv_output, EncodingMode, SparsityEncoder},
    BankConfig, PacimBank, ThresholdSet,
};
use pacim::coordinator::{schedule_layer, ScheduleConfig};
use pacim::pac::sparsity::bit_sparsity_counts;
use pacim::util::rng::Rng;
use pacim::workload::shapes::LayerShape;

#[test]
fn bank_tiles_match_scheduler_accounting() {
    // Run a real (small) layer through the functional bank and check the
    // analytic scheduler's cycle count formula agrees.
    let mut rng = Rng::new(2000);
    let shape = LayerShape::conv("t", 8, 16, 8, 3, 1); // k=72, 64 pixels
    let k = shape.dp_len();
    let weights: Vec<Vec<u8>> = (0..shape.geom.out_c)
        .map(|_| (0..k).map(|_| rng.below(256) as u8).collect())
        .collect();
    let mut bank = PacimBank::new(BankConfig::default());
    bank.load_weights(&weights);
    let pixels = shape.out_pixels();
    for _ in 0..pixels {
        let x: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
        bank.compute(&x);
    }
    // Functional: 16 broadcasts per pixel (single tile: k<=256, oc<=64).
    assert_eq!(bank.stats.dcim.bit_serial_cycles, 16 * pixels as u64);
    let cfg = ScheduleConfig::pacim_default();
    let rep = schedule_layer(&shape, &cfg);
    assert_eq!(rep.row_tiles, 1);
    assert_eq!(rep.oc_tiles, 1);
    assert_eq!(rep.bit_serial_cycles, 16 * pixels as u64);
}

#[test]
fn encoder_output_feeds_bank_speculation_consistently() {
    // The sparsity the encoder emits for a pixel group must equal what
    // the bank computes internally for the same data — the architecture's
    // cache round-trip is lossless for sparsity.
    let mut rng = Rng::new(2001);
    let channels = 32;
    let pixels = 9;
    let chw: Vec<u8> = (0..channels * pixels)
        .map(|_| rng.below(256) as u8)
        .collect();
    let mut enc = SparsityEncoder::new(EncodingMode::PixelWise);
    let groups = encode_conv_output(&chw, channels, pixels, &mut enc);
    for (pix, g) in groups.iter().enumerate() {
        let col: Vec<u8> = (0..channels).map(|c| chw[c * pixels + pix]).collect();
        assert_eq!(g.counters, bit_sparsity_counts(&col), "pixel {pix}");
    }
}

#[test]
fn dynamic_bank_cycle_savings_show_up_in_stats() {
    let mut rng = Rng::new(2002);
    let n = 128;
    let ws: Vec<Vec<u8>> = (0..8)
        .map(|_| (0..n).map(|_| rng.below(256) as u8).collect())
        .collect();
    let cfg = BankConfig {
        thresholds: Some(ThresholdSet::new(0.2, 0.35, 0.5)),
        ..BankConfig::default()
    };
    let mut bank = PacimBank::new(cfg);
    bank.load_weights(&ws);
    // Mix of sparse and dense inputs.
    for i in 0..40 {
        let density = (i % 4) as f64 * 0.3;
        let x: Vec<u8> = (0..n)
            .map(|_| if rng.bernoulli(density) { rng.below(256) as u8 } else { 0 })
            .collect();
        bank.compute(&x);
    }
    let h = bank.stats.levels;
    assert_eq!(h.total(), 40);
    assert!(h.c10 > 0, "no low-saliency decisions: {h:?}");
    assert!(h.average_cycles() < 16.0);
    assert!(h.average_cycles() >= 10.0);
}

#[test]
fn priced_assignment_prices_still_dense_edges_at_the_baseline() {
    // DESIGN.md §12: edges the dataplane does not sparsity-encode (the
    // classifier input after GAP, tiny layers below the encode floor)
    // move 8-bit dense activations. The traffic-priced scheduler must
    // price exactly those edges at the dense baseline and the encoded
    // ones at the MSB+counter rate — per layer, not as a global switch.
    use pacim::arch::{schedule_network_priced_with, MultiBankConfig, TrafficPrice};
    use pacim::memory::traffic::activation_traffic;
    use pacim::util::Parallelism;

    let shapes = vec![
        LayerShape::conv("stem", 16, 64, 8, 3, 1), // encoded, 64 pixels
        LayerShape::conv("mid", 64, 128, 4, 3, 1), // encoded, 16 pixels
        LayerShape::linear("fc", 128, 10),         // still dense (§12)
    ];
    let encoded = [true, true, false];
    let cfg = MultiBankConfig { banks: 4, rows: 256, mwcs: 64 };
    let price = TrafficPrice::default();
    let rep = schedule_network_priced_with(&shapes, &encoded, &cfg, &price, &Parallelism::off());

    // Encoded conv edges: write + read of MSB planes + sparsity counters
    // per output pixel group.
    let stem = &rep.schedules[0];
    let t = activation_traffic(64, price.msb_bits);
    assert_eq!(stem.act_bits, 2 * 64 * t.pacim);
    let mid = &rep.schedules[1];
    let t = activation_traffic(128, price.msb_bits);
    assert_eq!(mid.act_bits, 2 * 16 * t.pacim);
    // The dense classifier edge: one group of out_f plain 8-bit values.
    let fc = &rep.schedules[2];
    assert_eq!(fc.act_bits, 2 * 8 * 10);
    assert_eq!(fc.act_bits, 2 * activation_traffic(10, price.msb_bits).baseline);
}

#[test]
fn priced_assignment_replays_a_deep_dense_edge_under_lambda() {
    // A still-dense edge on a deep layer (row tiles > banks) spills
    // *dense* groups, so its checkpoint traffic is priced at the 8-bit
    // baseline — making the Replay flip cheaper to justify than on an
    // encoded edge. Under a moderate λ the priced schedule must replay
    // the layer (zero spill bits) while λ=0 keeps the spill staging.
    use pacim::arch::{schedule_network_priced_with, MultiBankConfig, SpillPolicy, TrafficPrice};
    use pacim::util::Parallelism;

    let shapes = vec![LayerShape::conv("deep", 512, 512, 4, 3, 1)]; // 18 row tiles
    let cfg = MultiBankConfig { banks: 4, rows: 256, mwcs: 64 };

    let base = schedule_network_priced_with(
        &shapes,
        &[false],
        &cfg,
        &TrafficPrice::default(),
        &Parallelism::off(),
    );
    assert_eq!(base.schedules[0].policy, SpillPolicy::Spill);
    assert!(base.schedules[0].spill_bits > 0, "deep layer must spill at lambda = 0");

    let price = TrafficPrice { lambda: 0.02, ..TrafficPrice::default() };
    let priced = schedule_network_priced_with(&shapes, &[false], &cfg, &price, &Parallelism::off());
    let s = &priced.schedules[0];
    assert_eq!(s.policy, SpillPolicy::Replay, "lambda must buy the replay");
    assert_eq!(s.spill_bits, 0);
    assert!(s.total_bits() < base.schedules[0].total_bits());
    assert!(s.cycles >= base.schedules[0].cycles, "replay re-runs encoding cycles");
}

#[test]
fn weight_bits_affect_row_writes() {
    use pacim::arch::{DCimBank, DCimConfig};
    let mut full = DCimBank::new(DCimConfig { rows: 64, mwcs: 4, weight_bits: 8 });
    let mut pac = DCimBank::new(DCimConfig { rows: 64, mwcs: 4, weight_bits: 4 });
    let ws: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8 * 17; 64]).collect();
    full.load_weights(&ws);
    pac.load_weights(&ws);
    // LSB elimination halves weight-update writes (the 50% DRAM claim's
    // on-array counterpart).
    assert_eq!(pac.stats.weight_row_writes * 2, full.stats.weight_row_writes);
}
