//! Integration: the structural bank model against the flat NN backend,
//! the encoder against the engine's activations, and the scheduler
//! against hand-counted tilings.

use pacim::arch::{
    encoder::{encode_conv_output, EncodingMode, SparsityEncoder},
    BankConfig, PacimBank, ThresholdSet,
};
use pacim::coordinator::{schedule_layer, ScheduleConfig};
use pacim::pac::sparsity::bit_sparsity_counts;
use pacim::util::rng::Rng;
use pacim::workload::shapes::LayerShape;

#[test]
fn bank_tiles_match_scheduler_accounting() {
    // Run a real (small) layer through the functional bank and check the
    // analytic scheduler's cycle count formula agrees.
    let mut rng = Rng::new(2000);
    let shape = LayerShape::conv("t", 8, 16, 8, 3, 1); // k=72, 64 pixels
    let k = shape.dp_len();
    let weights: Vec<Vec<u8>> = (0..shape.geom.out_c)
        .map(|_| (0..k).map(|_| rng.below(256) as u8).collect())
        .collect();
    let mut bank = PacimBank::new(BankConfig::default());
    bank.load_weights(&weights);
    let pixels = shape.out_pixels();
    for _ in 0..pixels {
        let x: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
        bank.compute(&x);
    }
    // Functional: 16 broadcasts per pixel (single tile: k<=256, oc<=64).
    assert_eq!(bank.stats.dcim.bit_serial_cycles, 16 * pixels as u64);
    let cfg = ScheduleConfig::pacim_default();
    let rep = schedule_layer(&shape, &cfg);
    assert_eq!(rep.row_tiles, 1);
    assert_eq!(rep.oc_tiles, 1);
    assert_eq!(rep.bit_serial_cycles, 16 * pixels as u64);
}

#[test]
fn encoder_output_feeds_bank_speculation_consistently() {
    // The sparsity the encoder emits for a pixel group must equal what
    // the bank computes internally for the same data — the architecture's
    // cache round-trip is lossless for sparsity.
    let mut rng = Rng::new(2001);
    let channels = 32;
    let pixels = 9;
    let chw: Vec<u8> = (0..channels * pixels)
        .map(|_| rng.below(256) as u8)
        .collect();
    let mut enc = SparsityEncoder::new(EncodingMode::PixelWise);
    let groups = encode_conv_output(&chw, channels, pixels, &mut enc);
    for (pix, g) in groups.iter().enumerate() {
        let col: Vec<u8> = (0..channels).map(|c| chw[c * pixels + pix]).collect();
        assert_eq!(g.counters, bit_sparsity_counts(&col), "pixel {pix}");
    }
}

#[test]
fn dynamic_bank_cycle_savings_show_up_in_stats() {
    let mut rng = Rng::new(2002);
    let n = 128;
    let ws: Vec<Vec<u8>> = (0..8)
        .map(|_| (0..n).map(|_| rng.below(256) as u8).collect())
        .collect();
    let cfg = BankConfig {
        thresholds: Some(ThresholdSet::new(0.2, 0.35, 0.5)),
        ..BankConfig::default()
    };
    let mut bank = PacimBank::new(cfg);
    bank.load_weights(&ws);
    // Mix of sparse and dense inputs.
    for i in 0..40 {
        let density = (i % 4) as f64 * 0.3;
        let x: Vec<u8> = (0..n)
            .map(|_| if rng.bernoulli(density) { rng.below(256) as u8 } else { 0 })
            .collect();
        bank.compute(&x);
    }
    let h = bank.stats.levels;
    assert_eq!(h.total(), 40);
    assert!(h.c10 > 0, "no low-saliency decisions: {h:?}");
    assert!(h.average_cycles() < 16.0);
    assert!(h.average_cycles() >= 10.0);
}

#[test]
fn weight_bits_affect_row_writes() {
    use pacim::arch::{DCimBank, DCimConfig};
    let mut full = DCimBank::new(DCimConfig { rows: 64, mwcs: 4, weight_bits: 8 });
    let mut pac = DCimBank::new(DCimConfig { rows: 64, mwcs: 4, weight_bits: 4 });
    let ws: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8 * 17; 64]).collect();
    full.load_weights(&ws);
    pac.load_weights(&ws);
    // LSB elimination halves weight-update writes (the 50% DRAM claim's
    // on-array counterpart).
    assert_eq!(pac.stats.weight_row_writes * 2, full.stats.weight_row_writes);
}
