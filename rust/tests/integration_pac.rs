//! Integration: PAC math across modules — compute maps x MAC kernels x
//! error analysis working together (no artifacts required).

use pacim::pac::error_analysis::{pac_rmse, rmse_vs_dp_length, BitModel};
use pacim::pac::{
    exact_mac, exact_mac_bitserial, hybrid_mac, BitPlanes, ComputeMap, DynamicLevel,
    PcuRounding,
};
use pacim::util::rng::Rng;

#[test]
fn hybrid_error_shrinks_with_dp_length() {
    // End-to-end check of the paper's central scaling claim at the full
    // 8b/8b MAC level (not just single cycles): relative error of the
    // 4x4 hybrid MAC shrinks roughly as 1/sqrt(n).
    let map = ComputeMap::operand_based(4, 4);
    let mut rng = Rng::new(1000);
    let mut rel_errs = Vec::new();
    for &n in &[64usize, 256, 1024] {
        let mut err_acc = 0.0f64;
        let trials = 200;
        for _ in 0..trials {
            let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let xp = BitPlanes::from_u8(&x);
            let wp = BitPlanes::from_u8(&w);
            let h = hybrid_mac(&xp, &wp, &map, PcuRounding::RoundNearest);
            let exact = exact_mac(&x, &w) as f64;
            err_acc += ((h.value as f64 - exact) / exact).abs();
        }
        rel_errs.push(err_acc / trials as f64);
    }
    assert!(
        rel_errs[0] > rel_errs[1] && rel_errs[1] > rel_errs[2],
        "{rel_errs:?}"
    );
    assert!(rel_errs[2] < 0.005, "rel err at DP 1024: {}", rel_errs[2]);
}

#[test]
fn dynamic_levels_order_error_monotonically() {
    // Fewer digital cycles -> no smaller error, on average.
    let mut rng = Rng::new(1001);
    let n = 512;
    let mut errs = Vec::new();
    for lvl in DynamicLevel::all() {
        let map = lvl.map();
        let mut acc = 0.0f64;
        let trials = 300;
        let mut rng2 = rng.clone();
        for _ in 0..trials {
            let x: Vec<u8> = (0..n).map(|_| rng2.below(256) as u8).collect();
            let w: Vec<u8> = (0..n).map(|_| rng2.below(256) as u8).collect();
            let xp = BitPlanes::from_u8(&x);
            let wp = BitPlanes::from_u8(&w);
            let h = hybrid_mac(&xp, &wp, &map, PcuRounding::RoundNearest);
            let exact = exact_mac(&x, &w) as f64;
            acc += (h.value as f64 - exact).abs();
        }
        errs.push(acc / trials as f64);
        let _ = &mut rng;
    }
    // 10-cycle error >= 16-cycle error (strict at the ends).
    assert!(errs[0] > errs[3], "{errs:?}");
}

#[test]
fn rounding_mode_bias() {
    // Floor rounding biases the estimate low; round-nearest is unbiased.
    // (The DESIGN.md §11 PCU-rounding ablation, as a regression test.)
    let nearest = pac_rmse(512, 0.5, 0.3, 3000, 77, BitModel::Iid);
    assert!(nearest.bias_lsb.abs() < 0.5, "bias={}", nearest.bias_lsb);
}

#[test]
fn rmse_sweep_matches_paper_band() {
    // Fig 3(c) end-to-end: RMSE at DP 512..4096 within 0.3-1.0%.
    let res = rmse_vs_dp_length(&[512, 1024, 2048, 4096], 0.5, 0.3, 3000, 99);
    for r in &res {
        assert!(
            (0.1..=1.1).contains(&r.rmse_pct),
            "DP {}: {}%",
            r.dp_len,
            r.rmse_pct
        );
    }
    // Table 1 band bound: "0.3-1.0% with DP length from 512 to 4096".
    assert!(res[0].rmse_pct < 1.05);
    assert!(res[3].rmse_pct < 0.45);
}

#[test]
fn bitserial_identity_large_random_sweep() {
    let mut rng = Rng::new(1002);
    for _ in 0..50 {
        let n = 1 + rng.below(700) as usize;
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        assert_eq!(exact_mac(&x, &w), exact_mac_bitserial(&xp, &wp));
    }
}
