//! Property-based tests over the sharded work-stealing ingress
//! (`pacim::coordinator::ingress`), using the in-house `Checker` harness
//! (proptest is unavailable offline).
//!
//! The invariants under test, across random shard counts, capacities,
//! popper counts, and item counts:
//!
//! 1. **No item lost or duplicated across shards**: with K concurrent
//!    poppers draining (own shard first, stealing on empty), the union
//!    of everything popped is exactly the submitted multiset, and the
//!    stolen flags agree with the per-shard steal counters.
//! 2. **Close-then-drain accounts for every residual item**: items not
//!    popped before `close()` all come back out of `drain_residual`,
//!    exactly once.
//! 3. **Per-request SLO deadlines survive stealing** (server-level):
//!    under a pool whose workers steal, every request either completes
//!    or is reaped with the typed deadline error — never lost — and the
//!    two outcomes partition the admitted set.

use pacim::coordinator::{
    BatchExecutor, BatchPolicy, InferenceServer, Ingress, ServeError, SloClass,
};
use pacim::engine::Fidelity;
use pacim::util::check::Checker;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn prop_no_item_lost_or_duplicated_across_shards() {
    Checker::new("ingress_no_loss_no_dup", 20).run(|rng| {
        let shards = 1 + rng.below(6) as usize;
        let poppers = 1 + rng.below(4) as usize;
        let n = 1 + rng.below(200) as usize;
        let ingress: Arc<Ingress<u64>> = Arc::new(Ingress::new(shards, 4 * n));

        // Poppers first, so submission and draining race for real.
        let mut joins = Vec::new();
        for w in 0..poppers {
            let ing = Arc::clone(&ingress);
            joins.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                let mut stolen = 0u64;
                while let Some(p) = ing.pop_blocking(w % ing.shard_count()) {
                    if p.stolen {
                        stolen += 1;
                    }
                    got.push(p.item);
                }
                (got, stolen)
            }));
        }
        for i in 0..n {
            ingress.submit(i as u64).unwrap();
        }
        ingress.close();

        let mut all = Vec::new();
        let mut stolen_seen = 0u64;
        for j in joins {
            let (got, stolen) = j.join().unwrap();
            all.extend(got);
            stolen_seen += stolen;
        }
        all.sort_unstable();
        let want: Vec<u64> = (0..n as u64).collect();
        assert_eq!(all, want, "items lost or duplicated across shards");

        // Accounting closes: admissions partition over shards, steal
        // flags match the victims' counters, nothing was rejected.
        let summaries = ingress.shard_summaries();
        assert_eq!(summaries.len(), shards);
        let submitted: u64 = summaries.iter().map(|s| s.submitted).sum();
        assert_eq!(submitted, n as u64);
        let stolen_counted: u64 = summaries.iter().map(|s| s.stolen).sum();
        assert_eq!(stolen_seen, stolen_counted, "steal flags vs shard counters");
        assert_eq!(ingress.rejected(), 0);
        assert_eq!(ingress.queued(), 0, "drained ingress holds nothing");
    });
}

#[test]
fn prop_close_then_drain_accounts_for_every_residual_item() {
    Checker::new("ingress_drain_residual", 30).run(|rng| {
        let shards = 1 + rng.below(5) as usize;
        let n = 1 + rng.below(60) as usize;
        let take = rng.below(n as u32 + 1) as usize;
        let ingress: Ingress<u64> = Ingress::new(shards, n);
        for i in 0..n {
            ingress.submit(i as u64).unwrap();
        }
        // Pop a prefix single-threaded (stealing across shards as the
        // popper's own shard empties), then close with the rest queued.
        let mut popped = Vec::with_capacity(take);
        for _ in 0..take {
            popped.push(ingress.try_pop(0).expect("queued items remain").item);
        }
        ingress.close();
        let mut residual = Vec::new();
        let shed = ingress.drain_residual(|v| residual.push(v));
        assert_eq!(shed as usize, n - take, "drain count");
        assert_eq!(residual.len(), n - take);
        let mut all: Vec<u64> = popped.iter().chain(&residual).copied().collect();
        all.sort_unstable();
        let want: Vec<u64> = (0..n as u64).collect();
        assert_eq!(all, want, "popped ∪ drained must be the admitted set");
        // The drained set and popped set are disjoint by construction.
        let seen: HashSet<u64> = popped.into_iter().collect();
        assert!(residual.iter().all(|v| !seen.contains(v)));
        // A second drain finds nothing.
        assert_eq!(ingress.drain_residual(|_| ()), 0);
    });
}

/// Echo executor with a fixed per-batch delay, slow enough that queued
/// requests outlive tight SLO deadlines.
struct SlowEcho {
    in_elems: usize,
    delay: Duration,
}

impl BatchExecutor for SlowEcho {
    fn batch_size(&self) -> usize {
        1
    }

    fn input_elems(&self) -> usize {
        self.in_elems
    }

    fn output_elems(&self) -> usize {
        1
    }

    fn execute(&mut self, batch: &[f32], _occupancy: usize) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        Ok(vec![batch[0]])
    }
}

#[test]
fn prop_slo_deadlines_partition_requests_under_stealing() {
    Checker::new("ingress_slo_partition", 10).run(|rng| {
        let workers = 2 + rng.below(2) as usize;
        let n = 8 + rng.below(24) as usize;
        let server = InferenceServer::start_pool(
            move |_| {
                Ok(SlowEcho {
                    in_elems: 2,
                    delay: Duration::from_millis(2),
                })
            },
            BatchPolicy {
                max_wait: Duration::from_micros(100),
                workers,
                queue_cap: 4 * n,
                ..BatchPolicy::default()
            },
        )
        .unwrap();
        let h = server.handle();
        // A tight per-request deadline: under 2ms batches some requests
        // will be served in time, the rest must be reaped — none lost,
        // none answered with anything but the typed deadline error, and
        // a reply that does arrive echoes its own payload (a stolen
        // request must not be cross-wired to another shard's reply).
        let slo = SloClass::latency(Duration::from_millis(5));
        let pending: Vec<_> = (0..n)
            .map(|i| h.submit_slo(vec![i as f32, 0.0], Fidelity::Fast, slo).unwrap())
            .collect();
        let mut served = 0u64;
        let mut reaped = 0u64;
        for (i, p) in pending.into_iter().enumerate() {
            match p.wait() {
                Ok(r) => {
                    assert_eq!(r.logits, vec![i as f32], "reply cross-wired");
                    served += 1;
                }
                Err(ServeError::DeadlineExceeded) => reaped += 1,
                Err(e) => panic!("request {i}: unexpected error {e:?}"),
            }
        }
        let m = server.stop();
        assert_eq!(served + reaped, n as u64, "an admitted request vanished");
        assert_eq!(m.requests, served, "served count disagrees");
        assert_eq!(m.deadline_expired, reaped, "reap count disagrees");
        assert_eq!(m.per_shard.len(), workers);
        let submitted: u64 = m.per_shard.iter().map(|s| s.submitted).sum();
        assert_eq!(submitted, n as u64);
    });
}
