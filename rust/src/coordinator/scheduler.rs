//! The bank scheduler: maps DNN layers onto PACiM banks and produces the
//! cycle / energy / traffic accounting behind Fig. 7 and Tables 3–4.
//!
//! Mapping rules (§4.3, §6.2):
//! - a CONV layer lowers to a GEMM of `out_pixels × dp_len × out_c`;
//! - output channels tile onto MWCs (64 per bank);
//! - the DP dimension tiles onto rows (256 per column pass) — a DP longer
//!   than the array is split into `row_tiles` passes whose partial sums
//!   accumulate in the output buffer;
//! - each weight tile is loaded once (weight-stationary) and serves every
//!   output pixel before the next update — the schedule that lets the
//!   sparsity encoder run uninterrupted in multi-bank systems (§4.5).

use crate::energy::EnergyModel;
use crate::memory::traffic::{activation_traffic, weight_traffic};
use crate::nn::layers::{Model, Op};
use crate::workload::shapes::{LayerShape, LayerShapeKind};

/// Scheduling/accounting configuration.
#[derive(Debug, Clone)]
pub struct ScheduleConfig {
    /// Rows per bank (DP segment per pass).
    pub rows: usize,
    /// MWCs per bank (output channels resident at once).
    pub mwcs: usize,
    /// Number of banks tiled in the system.
    pub banks: usize,
    /// Average digital cycles per 8b/8b output MAC (16 static 4-bit map;
    /// ≈12 with dynamic workload configuration).
    pub avg_digital_cycles: f64,
    /// Sparsity-domain cycles per output MAC (64 − digital for the static
    /// map; the dynamic transfer moves digital cycles here).
    pub avg_sparsity_cycles: f64,
    /// Binary activation bits transmitted (4-bit MSB default).
    pub msb_bits: u32,
}

impl ScheduleConfig {
    /// The paper's default single-bank 4-bit-approximation system.
    pub fn pacim_default() -> Self {
        Self {
            rows: 256,
            mwcs: 64,
            banks: 1,
            avg_digital_cycles: 16.0,
            avg_sparsity_cycles: 48.0,
            msb_bits: 4,
        }
    }

    /// Dynamic workload configuration at the paper's CIFAR operating
    /// point (average 12 digital cycles, Fig. 6(b)).
    pub fn pacim_dynamic() -> Self {
        Self {
            avg_digital_cycles: 12.0,
            avg_sparsity_cycles: 52.0,
            ..Self::pacim_default()
        }
    }

    /// Fully digital baseline (no PAC): 64 digital cycles, all 8 bits
    /// transmitted, all 8 weight bits stored.
    pub fn digital_baseline() -> Self {
        Self {
            rows: 256,
            mwcs: 64,
            banks: 1,
            avg_digital_cycles: 64.0,
            avg_sparsity_cycles: 0.0,
            msb_bits: 8,
        }
    }

    /// Bank geometry view of this schedule, for the §4.5 multi-bank and
    /// traffic-priced schedulers in [`crate::arch::multibank`].
    pub fn multibank(&self) -> crate::arch::MultiBankConfig {
        crate::arch::MultiBankConfig {
            banks: self.banks,
            rows: self.rows,
            mwcs: self.mwcs,
        }
    }

    /// Traffic-pricing view of this schedule: the λ knob plus this
    /// config's MSB width and measured digital cycle average, ready for
    /// [`crate::arch::schedule_network_priced`]. With `lambda = 0.0` the
    /// priced schedule reproduces the cycles-only §4.5 staging, and its
    /// per-layer `act_bits` sum to [`CostEstimate::act_bits`] (both are
    /// the same `activation_traffic` closed form).
    pub fn traffic_price(&self, lambda: f64) -> crate::arch::TrafficPrice {
        crate::arch::TrafficPrice {
            lambda,
            msb_bits: self.msb_bits,
            avg_digital_cycles: self.avg_digital_cycles,
        }
    }
}

/// Per-layer schedule report.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    /// Column-pass tiles along the DP dimension.
    pub row_tiles: usize,
    /// MWC tiles along the output-channel dimension.
    pub oc_tiles: usize,
    /// Total weight-tile loads (row_tiles × oc_tiles).
    pub weight_loads: usize,
    /// D-CiM bit-serial broadcast cycles for the whole layer.
    pub bit_serial_cycles: u64,
    /// Equivalent binary ops in each domain (for energy composition).
    pub dcim_ops: f64,
    pub pcu_ops: f64,
    /// Activation bits moved to/from cache (write + next-layer read).
    pub act_bits_baseline: u64,
    pub act_bits_pacim: u64,
    /// Weight bits loaded from DRAM.
    pub weight_bits_baseline: u64,
    pub weight_bits_pacim: u64,
}

impl LayerReport {
    pub fn act_reduction(&self) -> f64 {
        1.0 - self.act_bits_pacim as f64 / self.act_bits_baseline.max(1) as f64
    }
}

/// Whole-model schedule report.
#[derive(Debug, Clone, Default)]
pub struct ModelReport {
    pub layers: Vec<LayerReport>,
}

impl ModelReport {
    pub fn total_macs_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.bit_serial_cycles).sum()
    }

    pub fn total_dcim_ops(&self) -> f64 {
        self.layers.iter().map(|l| l.dcim_ops).sum()
    }

    pub fn total_pcu_ops(&self) -> f64 {
        self.layers.iter().map(|l| l.pcu_ops).sum()
    }

    /// Aggregate activation-traffic reduction (Fig. 7(b) headline).
    pub fn act_traffic_reduction(&self) -> f64 {
        let base: u64 = self.layers.iter().map(|l| l.act_bits_baseline).sum();
        let ours: u64 = self.layers.iter().map(|l| l.act_bits_pacim).sum();
        1.0 - ours as f64 / base.max(1) as f64
    }

    pub fn weight_traffic_reduction(&self) -> f64 {
        let base: u64 = self.layers.iter().map(|l| l.weight_bits_baseline).sum();
        let ours: u64 = self.layers.iter().map(|l| l.weight_bits_pacim).sum();
        1.0 - ours as f64 / base.max(1) as f64
    }

    /// Compute energy (pJ) under the energy model (compute only; memory
    /// energy is reported separately by `memory_energy_pj`).
    pub fn compute_energy_pj(&self, m: &EnergyModel) -> f64 {
        self.total_dcim_ops() * m.dcim_pj_per_op + self.total_pcu_ops() * m.pcu_pj_per_op
    }

    /// Memory energy (pJ): activation SRAM traffic + weight DRAM traffic.
    pub fn memory_energy_pj(&self, m: &EnergyModel, pacim: bool) -> f64 {
        let (act, wgt): (u64, u64) = self
            .layers
            .iter()
            .map(|l| {
                if pacim {
                    (l.act_bits_pacim, l.weight_bits_pacim)
                } else {
                    (l.act_bits_baseline, l.weight_bits_baseline)
                }
            })
            .fold((0, 0), |(a, w), (la, lw)| (a + la, w + lw));
        act as f64 / 16.0 * m.sram_pj_per_16b + wgt as f64 / 64.0 * m.dram_pj_per_access
    }
}

/// Schedule one layer.
pub fn schedule_layer(shape: &LayerShape, cfg: &ScheduleConfig) -> LayerReport {
    let g = &shape.geom;
    let k = g.dp_len();
    let row_tiles = (k + cfg.rows - 1) / cfg.rows;
    let oc_tiles = (g.out_c + cfg.mwcs - 1) / cfg.mwcs;
    let pixels = g.out_pixels() as u64;

    // Bit-serial broadcast cycles: each (pixel, row-tile, oc-tile) runs
    // `avg_digital_cycles` broadcasts (all resident MWCs compute in
    // parallel during one broadcast).
    let bit_serial_cycles =
        (pixels * row_tiles as u64 * oc_tiles as u64) as f64 * cfg.avg_digital_cycles;

    // Equivalent binary ops: each 8b/8b output MAC comprises 64 binary
    // (p,q) cycles split between domains; the per-domain equivalent op
    // count is the MAC total × the domain's cycle share.
    let total_macs = g.macs() as f64; // out_c × pixels × k
    let dcim_ops = total_macs * (cfg.avg_digital_cycles / 64.0);
    let pcu_ops = total_macs * (cfg.avg_sparsity_cycles / 64.0);

    // Activation traffic: output written once, read once by the next
    // layer. Encoding group = channels per pixel (CONV) or the layer
    // (LINEAR).
    let groups = match shape.kind {
        LayerShapeKind::Conv => pixels,
        LayerShapeKind::Linear => 1,
    };
    let group_elems = match shape.kind {
        LayerShapeKind::Conv => g.out_c,
        LayerShapeKind::Linear => g.out_c,
    };
    let t = activation_traffic(group_elems, cfg.msb_bits);
    let act_bits_baseline = 2 * groups * t.baseline; // write + read
    let act_bits_pacim = 2 * groups * t.pacim;

    // Weight traffic from DRAM: each weight element loaded once per
    // occupancy (weight-stationary single pass).
    let wt = weight_traffic(k, cfg.msb_bits);
    let weight_bits_baseline = g.out_c as u64 * wt.baseline;
    let weight_bits_pacim = g.out_c as u64 * wt.pacim;

    LayerReport {
        name: shape.name.clone(),
        row_tiles,
        oc_tiles,
        weight_loads: row_tiles * oc_tiles,
        bit_serial_cycles: bit_serial_cycles as u64,
        dcim_ops,
        pcu_ops,
        act_bits_baseline,
        act_bits_pacim,
        weight_bits_baseline,
        weight_bits_pacim,
    }
}

/// Schedule a whole model.
pub fn schedule_model(shapes: &[LayerShape], cfg: &ScheduleConfig) -> ModelReport {
    ModelReport {
        layers: shapes.iter().map(|s| schedule_layer(s, cfg)).collect(),
    }
}

/// Modeled per-image silicon cost of one inference, derived from the
/// bank schedule. The serving path attaches this to every reply
/// ([`crate::coordinator::server::Reply::cost`]) so a load test doubles
/// as an architecture-exploration scenario: latency percentiles from the
/// software pipeline, cycles/energy from the PACiM model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostEstimate {
    /// D-CiM bit-serial broadcast cycles per image.
    pub cycles: u64,
    /// Compute energy per image (pJ, 65 nm @ 0.6 V calibration).
    pub compute_pj: f64,
    /// Memory energy per image (pJ): activation SRAM + weight DRAM.
    pub memory_pj: f64,
    /// Modeled activation cache bits moved per image (write + read)
    /// under this schedule's encoding. The *measured* counterpart per
    /// run is `RunStats::traffic` (see `memory::TrafficLedger`).
    pub act_bits: u64,
    /// The same traffic at the 8-bit dense baseline.
    pub act_bits_baseline: u64,
}

impl CostEstimate {
    /// Total modeled energy per image in µJ.
    pub fn total_uj(&self) -> f64 {
        (self.compute_pj + self.memory_pj) / 1e6
    }

    /// Modeled activation-traffic reduction vs the 8-bit dense baseline
    /// (0 for fully digital schedules).
    pub fn act_traffic_reduction(&self) -> f64 {
        1.0 - self.act_bits as f64 / self.act_bits_baseline.max(1) as f64
    }
}

/// Extract the schedulable layer shapes of a compiled model (CONV layers
/// verbatim, LINEAR layers as 1×1 GEMMs), for cost estimation of the
/// actually-served network rather than a paper benchmark table.
pub fn model_shapes(model: &Model) -> Vec<LayerShape> {
    model
        .ops
        .iter()
        .filter_map(|op| match op {
            Op::Conv2d(c) => Some(LayerShape {
                name: c.name.clone(),
                kind: LayerShapeKind::Conv,
                geom: c.geom,
            }),
            Op::Linear(l) => Some(LayerShape::linear(&l.name, l.in_f, l.out_f)),
            _ => None,
        })
        .collect()
}

/// Per-image cost estimate for serving a workload under `cfg`.
pub fn estimate_image_cost(
    shapes: &[LayerShape],
    cfg: &ScheduleConfig,
    em: &EnergyModel,
) -> CostEstimate {
    let rep = schedule_model(shapes, cfg);
    let pacim = cfg.msb_bits < 8;
    let act_bits = rep
        .layers
        .iter()
        .map(|l| if pacim { l.act_bits_pacim } else { l.act_bits_baseline })
        .sum();
    CostEstimate {
        cycles: rep.total_macs_cycles(),
        compute_pj: rep.compute_energy_pj(em),
        memory_pj: rep.memory_energy_pj(em, pacim),
        act_bits,
        act_bits_baseline: rep.layers.iter().map(|l| l.act_bits_baseline).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::shapes::{resnet18, Resolution};

    #[test]
    fn tiling_counts() {
        let l = LayerShape::conv("c", 128, 256, 16, 3, 1);
        let cfg = ScheduleConfig::pacim_default();
        let r = schedule_layer(&l, &cfg);
        // k = 1152 → 5 row tiles of 256; 256 oc → 4 MWC tiles.
        assert_eq!(r.row_tiles, 5);
        assert_eq!(r.oc_tiles, 4);
        assert_eq!(r.weight_loads, 20);
    }

    #[test]
    fn cycle_reduction_75pct_static() {
        // Fig. 7(a): static 4-bit map reduces bit-serial cycles by 75%.
        let shapes = resnet18(Resolution::Cifar, 10);
        let pac = schedule_model(&shapes, &ScheduleConfig::pacim_default());
        let dig = schedule_model(&shapes, &ScheduleConfig::digital_baseline());
        let red = 1.0 - pac.total_macs_cycles() as f64 / dig.total_macs_cycles() as f64;
        assert!((red - 0.75).abs() < 1e-9, "reduction={red}");
    }

    #[test]
    fn cycle_reduction_81pct_dynamic() {
        // Fig. 7(a)/abstract: dynamic configuration reaches 81%.
        let shapes = resnet18(Resolution::Cifar, 10);
        let pac = schedule_model(&shapes, &ScheduleConfig::pacim_dynamic());
        let dig = schedule_model(&shapes, &ScheduleConfig::digital_baseline());
        let red = 1.0 - pac.total_macs_cycles() as f64 / dig.total_macs_cycles() as f64;
        assert!((red - 0.8125).abs() < 1e-9, "reduction={red}");
    }

    #[test]
    fn traffic_reduction_band() {
        // Fig. 7(b): 40–50% activation traffic reduction on ResNet-18.
        let shapes = resnet18(Resolution::Cifar, 10);
        let rep = schedule_model(&shapes, &ScheduleConfig::pacim_default());
        let red = rep.act_traffic_reduction();
        assert!((0.38..0.52).contains(&red), "act reduction={red}");
        let wred = rep.weight_traffic_reduction();
        assert!((0.42..0.52).contains(&wred), "weight reduction={wred}");
    }

    #[test]
    fn ops_partition_preserves_total() {
        let shapes = resnet18(Resolution::Cifar, 10);
        let rep = schedule_model(&shapes, &ScheduleConfig::pacim_default());
        let total: f64 = shapes.iter().map(|s| s.macs() as f64).sum();
        assert!(
            ((rep.total_dcim_ops() + rep.total_pcu_ops()) - total).abs() / total < 1e-12
        );
    }

    #[test]
    fn model_shapes_cover_every_compute_layer() {
        use crate::nn::layers::{synthetic, tiny_resnet};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let shapes = model_shapes(&model);
        // 9 convs + 1 linear head.
        assert_eq!(shapes.len(), 10);
        assert_eq!(shapes.last().unwrap().kind, LayerShapeKind::Linear);
        let macs: u64 = shapes.iter().map(|s| s.macs()).sum();
        assert_eq!(macs, model.macs());
    }

    #[test]
    fn image_cost_estimate_orders_configs() {
        use crate::nn::layers::{synthetic, tiny_resnet};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(78);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let shapes = model_shapes(&model);
        let em = EnergyModel::default();
        let pac = estimate_image_cost(&shapes, &ScheduleConfig::pacim_default(), &em);
        let dig = estimate_image_cost(&shapes, &ScheduleConfig::digital_baseline(), &em);
        assert!(pac.cycles > 0 && pac.total_uj() > 0.0);
        assert!(pac.cycles < dig.cycles, "PAC must cut bit-serial cycles");
        assert!(pac.total_uj() < dig.total_uj());
        // Modeled activation traffic: digital moves the full 8 bits
        // (zero reduction); PACiM saves on every edge but the tiny
        // synthetic widths (8–32 channels) sit well below the paper's
        // deep-layer band — the counter overhead is honest.
        assert_eq!(dig.act_bits, dig.act_bits_baseline);
        assert_eq!(dig.act_traffic_reduction(), 0.0);
        assert_eq!(pac.act_bits_baseline, dig.act_bits_baseline);
        assert!(pac.act_bits < pac.act_bits_baseline);
        assert!((0.10..0.40).contains(&pac.act_traffic_reduction()));
    }

    #[test]
    fn pacim_energy_beats_digital() {
        let shapes = resnet18(Resolution::Cifar, 10);
        let m = EnergyModel::default();
        let pac = schedule_model(&shapes, &ScheduleConfig::pacim_dynamic());
        let dig = schedule_model(&shapes, &ScheduleConfig::digital_baseline());
        let e_pac = pac.compute_energy_pj(&m) + pac.memory_energy_pj(&m, true);
        let e_dig = dig.compute_energy_pj(&m) + dig.memory_energy_pj(&m, false);
        assert!(e_pac < e_dig, "pacim {e_pac} pJ vs digital {e_dig} pJ");
    }

    #[test]
    fn traffic_price_bridge_reproduces_act_bits() {
        // The ScheduleConfig → TrafficPrice bridge must keep the two
        // traffic models in lock-step: the priced multibank schedule's
        // activation bits equal the analytic CostEstimate's, and λ=0
        // keeps the cycles-only staging.
        use crate::arch::{schedule_network_multibank, schedule_network_priced};
        let shapes = resnet18(Resolution::Cifar, 10);
        let cfg = ScheduleConfig { banks: 4, ..ScheduleConfig::pacim_default() };
        let est = estimate_image_cost(&shapes, &cfg, &EnergyModel::default());
        let rep = schedule_network_priced(&shapes, &cfg.multibank(), &cfg.traffic_price(0.0));
        assert_eq!(rep.total_act_bits(), est.act_bits);
        assert_eq!(rep.to_multibank(), schedule_network_multibank(&shapes, &cfg.multibank()));
    }
}
