//! The serving coordinator: a multi-worker pool behind a sharded
//! work-stealing ingress with admission control.
//!
//! This is the L3 runtime path: clients submit single images through the
//! sharded [`Ingress`] (one bounded queue per worker, power-of-two-
//! choices placement, no global lock on the submit path — see
//! [`super::ingress`]); N workers (each owning its own [`BatchExecutor`])
//! drain their own shard first and steal from siblings on empty, pop up
//! to `batch_size` requests or wait out a deadline, pad partial batches,
//! execute, and distribute per-request results. When the global capacity
//! bound is hit the submission is load-shed with a typed error
//! ([`ServeError::QueueFull`]) instead of queueing unbounded latency —
//! the backpressure policy of DESIGN.md §8.
//!
//! The executor is a trait so unit tests run against a mock, the
//! PAC-native path against [`crate::runtime::PacExecutor`] (pure rust, no
//! PJRT), and the AOT path against `crate::runtime::PjrtExecutor` (behind
//! the `pjrt` cargo feature). Executors may annotate every reply with the
//! modeled silicon cost ([`CostEstimate`]) so serving doubles as an
//! architecture-exploration scenario.
//!
//! Shutdown is a graceful drain: [`InferenceServer::stop`] closes the
//! ingress to new submissions, workers keep flushing batches (stealing
//! the residue of retired siblings' shards) until every shard is empty,
//! and the per-worker metrics are merged into the aggregate
//! [`ServerMetrics`] returned to the caller. The drain is *bounded*
//! ([`BatchPolicy::drain_timeout`]): if a worker wedges, the residual
//! queues are load-shed with a typed error instead of hanging the caller
//! forever.
//!
//! The pool is hardened against its own executors (DESIGN.md §15): a
//! panic inside `execute` is caught, the in-flight requests get a typed
//! [`ServeError::WorkerLost`], the poisoned executor is rebuilt from the
//! worker's factory, and the pool keeps draining. Requests may carry a
//! per-request SLO class ([`SloClass`]): a latency deadline (overriding
//! the pool-wide [`BatchPolicy::deadline`]) reaped at batch-gather time
//! with [`ServeError::DeadlineExceeded`], and/or a traffic budget in
//! measured activation bits, enforced against the executor's modeled
//! floor before execution ([`ServeError::TrafficBudgetExceeded`]) and
//! flagged on the reply when the measured share overruns it.

use super::ingress::{Ingress, IngressError, ShardSummary, SloClass};
use super::scheduler::CostEstimate;
use crate::engine::Fidelity;
use crate::util::stats::percentile_sorted;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Something that can run a fixed-batch forward pass.
/// Inputs are flattened f32 images (C·H·W each), batched contiguously.
///
/// Implementations need not be `Send`: the server constructs its executor
/// *inside* the worker thread (PJRT handles hold non-Send `Rc`s).
pub trait BatchExecutor {
    /// Compiled batch size.
    fn batch_size(&self) -> usize;
    /// Elements per input (C·H·W).
    fn input_elems(&self) -> usize;
    /// Elements per output (num classes).
    fn output_elems(&self) -> usize;
    /// Execute on exactly `batch_size()` inputs; returns
    /// `batch_size() × output_elems()` outputs. The first `occupancy`
    /// lanes are real requests; the rest are zero padding. Executors
    /// with a fixed compiled batch (PJRT) ignore the hint; pure-rust
    /// executors may skip the padded lanes — only the first
    /// `occupancy × output_elems()` outputs ever reach replies.
    fn execute(&mut self, batch: &[f32], occupancy: usize) -> anyhow::Result<Vec<f32>>;
    /// Fidelity-aware variant: `fidelities[i]` is the class of occupied
    /// lane `i` (`fidelities.len() == occupancy`). The default ignores
    /// the classes and runs [`BatchExecutor::execute`] — executors
    /// without an escalation path treat every class as the plain path.
    fn execute_with(
        &mut self,
        batch: &[f32],
        occupancy: usize,
        fidelities: &[Fidelity],
    ) -> anyhow::Result<Vec<f32>> {
        let _ = fidelities;
        self.execute(batch, occupancy)
    }
    /// Modeled per-image silicon cost, attached to every reply this
    /// executor produces. Also the floor for SLO traffic budgets: a
    /// request whose [`SloClass::max_bits`] is below `act_bits` cannot
    /// possibly be served within budget and is reaped before execution.
    /// Default: no cost model.
    fn cost_estimate(&self) -> Option<CostEstimate> {
        None
    }
    /// Cumulative engine telemetry since this executor was constructed
    /// (measured activation traffic, escalation reruns). The worker loop
    /// folds it into [`ServerMetrics`] when the executor retires — at
    /// drain or before a post-panic rebuild — and differences it around
    /// every batch to attribute measured bits to replies. Default: no
    /// telemetry.
    fn telemetry(&self) -> ExecTelemetry {
        ExecTelemetry::default()
    }
}

/// Cumulative measured-engine counters an executor can expose to the
/// serving metrics (see [`BatchExecutor::telemetry`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecTelemetry {
    /// Measured inter-layer activation bits moved (producer writes, one
    /// direction — `RunStats::traffic` totals).
    pub traffic_bits: u64,
    /// 8-bit dense-equivalent bits of the same edges.
    pub traffic_baseline_bits: u64,
    /// Samples the confidence monitor re-ran through the exact backend.
    pub escalated: u64,
}

/// Typed submission/serving error (the load-shed and lifecycle states a
/// client must distinguish).
#[derive(Debug, thiserror::Error)]
pub enum ServeError {
    #[error("input has {got} elems, expected {want}")]
    BadInput { got: usize, want: usize },
    /// Admission control fired: the sharded ingress already holds
    /// `capacity` pending requests. Clients should back off and retry.
    #[error("admission queue full ({capacity} pending requests); load shed")]
    QueueFull { capacity: usize },
    #[error("server stopped")]
    Stopped,
    #[error("request dropped (batch execution failed)")]
    Dropped,
    /// The executor serving this request's batch panicked. The pool
    /// rebuilt the worker's executor and kept serving; only the
    /// in-flight batch is lost.
    #[error("worker lost (executor panicked mid-batch); retry")]
    WorkerLost,
    /// The request's deadline ([`SloClass::deadline`] or the pool-wide
    /// [`BatchPolicy::deadline`]) expired while it was still queued; it
    /// was reaped without occupying a lane.
    #[error("request deadline exceeded while queued")]
    DeadlineExceeded,
    /// The request's traffic budget ([`SloClass::max_bits`]) is below
    /// the executor's modeled per-image floor
    /// ([`CostEstimate::act_bits`]); it cannot possibly be served within
    /// budget and was reaped before occupying a lane.
    #[error("traffic budget {budget_bits} bits below the modeled floor of {floor_bits} bits")]
    TrafficBudgetExceeded { budget_bits: u64, floor_bits: u64 },
    /// The multi-model router has no tenant registered under this id.
    #[error("unknown model '{model}'")]
    UnknownModel { model: String },
}

/// One inference request.
struct Request {
    input: Vec<f32>,
    fidelity: Fidelity,
    slo: SloClass,
    enqueued: Instant,
    reply: mpsc::Sender<Result<Reply, ServeError>>,
}

/// Per-request response.
#[derive(Debug, Clone)]
pub struct Reply {
    pub logits: Vec<f32>,
    /// Queue + batch + execute latency.
    pub latency: Duration,
    /// Compiled batch size of the executor this request rode through.
    pub batch_size: usize,
    /// Real (non-padded) requests in the batch this request rode in.
    pub occupancy: usize,
    /// Modeled per-image PACiM cycles/energy, when the executor carries a
    /// cost model (see [`BatchExecutor::cost_estimate`]).
    pub cost: Option<CostEstimate>,
    /// Measured activation bits attributed to this request: the batch's
    /// telemetry delta split evenly over its occupied lanes (0 when the
    /// executor exposes no telemetry).
    pub traffic_bits: u64,
    /// True when `traffic_bits` overran the request's SLO budget
    /// ([`SloClass::max_bits`]). The reply is still delivered — the
    /// overrun is a flag, not a failure — and counted in
    /// [`ServerMetrics::budget_violations`].
    pub budget_exceeded: bool,
}

/// Per-worker slice of the aggregate metrics (one entry per pool worker
/// in [`ServerMetrics::per_worker`]).
#[derive(Debug, Clone)]
pub struct WorkerSummary {
    pub worker: usize,
    pub requests: u64,
    pub batches: u64,
    pub failed_batches: u64,
    pub exec_time: Duration,
    pub p50_us: f64,
    pub p99_us: f64,
    /// Measured activation bits this worker's executor moved
    /// ([`BatchExecutor::telemetry`]).
    pub traffic_bits: u64,
    /// Escalation reruns this worker's executor performed.
    pub escalated: u64,
    /// Executor panics this worker caught and recovered from.
    pub worker_panics: u64,
    /// Requests this worker stole from sibling shards.
    pub steals: u64,
    /// This worker's own batch-fill histogram (`batch_fill[i]` = batches
    /// that carried exactly `i + 1` real requests), so shard-level fill
    /// is visible next to the pool aggregate.
    pub batch_fill: Vec<u64>,
}

/// Per-worker bound on retained latency samples: beyond this, samples
/// are reservoir-sampled (Algorithm R) so a long-running server keeps
/// O(1) memory while the percentiles stay statistically faithful.
const LATENCY_RESERVOIR: usize = 65_536;

/// Server-side aggregate metrics.
#[derive(Debug, Clone, Default)]
pub struct ServerMetrics {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub failed_batches: u64,
    /// Submissions load-shed by admission control (queue full).
    pub rejected: u64,
    /// Measured inter-layer activation bits the pool's executors moved
    /// (one direction; see [`ExecTelemetry`]).
    pub traffic_bits: u64,
    /// 8-bit dense-equivalent bits of the same edges.
    pub traffic_baseline_bits: u64,
    /// Samples the confidence monitor re-ran through the exact backend.
    pub escalated: u64,
    /// Requests reaped at gather time because their deadline expired.
    pub deadline_expired: u64,
    /// Traffic-budget SLO violations: requests reaped because their
    /// budget sat below the modeled floor, plus served requests whose
    /// measured share overran their budget (flagged on the reply).
    pub budget_violations: u64,
    /// Requests workers popped from shards they do not own (the
    /// work-stealing engagement counter; per-victim counts are in
    /// [`ServerMetrics::per_shard`]).
    pub steals: u64,
    /// Executor panics caught by workers (each rebuilt its executor and
    /// kept serving; the in-flight batch got [`ServeError::WorkerLost`]).
    pub worker_panics: u64,
    /// Residual queued requests load-shed when the drain timeout fired.
    pub drain_shed: u64,
    /// Workers that could not be recovered (executor rebuild failed, the
    /// thread itself panicked, or it was still wedged past the drain
    /// timeout); their local metrics are lost.
    pub workers_lost: u64,
    pub exec_time: Duration,
    /// Batch-fill histogram: `batch_fill[i]` = batches that carried
    /// exactly `i + 1` real requests.
    pub batch_fill: Vec<u64>,
    /// Per-worker breakdown (empty until `stop()` merges the pool).
    pub per_worker: Vec<WorkerSummary>,
    /// Per-shard ingress counters (empty until `stop()` snapshots the
    /// ingress): submissions, steals suffered, peak depth.
    pub per_shard: Vec<ShardSummary>,
    /// Bounded latency reservoir (≤ [`LATENCY_RESERVOIR`] per worker).
    /// Finalized (sorted ascending) exactly once, in
    /// [`InferenceServer::stop`], so percentile queries are `&self`.
    latencies_us: Vec<f64>,
    latency_samples_seen: u64,
}

impl ServerMetrics {
    /// Latency percentile in µs over the finalized reservoir. Metrics
    /// handed out by [`InferenceServer::stop`] are finalized (sorted);
    /// queries are read-only and O(1).
    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        percentile_sorted(&self.latencies_us, p)
    }

    /// Sort the merged reservoir once so every subsequent percentile
    /// query is a read-only rank lookup.
    fn finalize(&mut self) {
        self.latencies_us.sort_by(|a, b| a.total_cmp(b));
    }

    fn record_latency(&mut self, us: f64, rng: &mut crate::util::rng::Rng) {
        self.latency_samples_seen += 1;
        if self.latencies_us.len() < LATENCY_RESERVOIR {
            self.latencies_us.push(us);
        } else {
            // Algorithm R: keep each of the `seen` samples with equal
            // probability.
            let j = (rng.next_u64() % self.latency_samples_seen) as usize;
            if j < LATENCY_RESERVOIR {
                self.latencies_us[j] = us;
            }
        }
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / self.batches as f64
    }

    /// Measured activation bits moved per served request (0 when the
    /// executors expose no telemetry or nothing was served).
    pub fn bits_per_request(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.traffic_bits as f64 / self.requests as f64
    }

    /// Fraction of served requests that were stolen from a sibling
    /// shard (0 when nothing was served).
    pub fn steal_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.steals as f64 / self.requests as f64
    }

    /// Fold one worker's local metrics into the aggregate (sorting the
    /// worker's reservoir first, so its summary percentiles read from
    /// finalized data; the aggregate is re-finalized after the last
    /// absorb, since appending breaks sortedness).
    fn absorb(&mut self, worker: usize, mut m: ServerMetrics) {
        m.finalize();
        let p50 = m.latency_percentile_us(50.0);
        let p99 = m.latency_percentile_us(99.0);
        self.per_worker.push(WorkerSummary {
            worker,
            requests: m.requests,
            batches: m.batches,
            failed_batches: m.failed_batches,
            exec_time: m.exec_time,
            p50_us: p50,
            p99_us: p99,
            traffic_bits: m.traffic_bits,
            escalated: m.escalated,
            worker_panics: m.worker_panics,
            steals: m.steals,
            batch_fill: m.batch_fill.clone(),
        });
        self.requests += m.requests;
        self.batches += m.batches;
        self.padded_slots += m.padded_slots;
        self.failed_batches += m.failed_batches;
        self.traffic_bits += m.traffic_bits;
        self.traffic_baseline_bits += m.traffic_baseline_bits;
        self.escalated += m.escalated;
        self.deadline_expired += m.deadline_expired;
        self.budget_violations += m.budget_violations;
        self.steals += m.steals;
        self.worker_panics += m.worker_panics;
        self.exec_time += m.exec_time;
        if self.batch_fill.len() < m.batch_fill.len() {
            self.batch_fill.resize(m.batch_fill.len(), 0);
        }
        for (i, c) in m.batch_fill.iter().enumerate() {
            self.batch_fill[i] += c;
        }
        self.latencies_us.append(&mut m.latencies_us);
        self.latency_samples_seen += m.latency_samples_seen;
    }
}

/// Batching/pool policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max time the first request of a batch waits for company.
    pub max_wait: Duration,
    /// Worker threads in the pool (each owns one executor and one
    /// ingress shard).
    pub workers: usize,
    /// Admission-control bound across all shards: pending requests
    /// beyond this are load-shed with [`ServeError::QueueFull`].
    pub queue_cap: usize,
    /// Pool-wide per-request deadline, measured from submission:
    /// requests still queued past it are reaped at batch-gather time
    /// with [`ServeError::DeadlineExceeded`] and never occupy a lane.
    /// A request's own [`SloClass::deadline`] takes precedence.
    /// `None` (default) keeps requests queued indefinitely.
    pub deadline: Option<Duration>,
    /// Bound on the [`InferenceServer::stop`] drain: past it, the
    /// residual queues are load-shed with [`ServeError::Stopped`] and
    /// any still-wedged worker is abandoned (counted in
    /// [`ServerMetrics::workers_lost`]) instead of hanging the caller.
    pub drain_timeout: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            workers: 1,
            queue_cap: 1024,
            deadline: None,
            drain_timeout: Duration::from_secs(10),
        }
    }
}

/// A reply that has been submitted but not yet waited on (open-loop
/// clients submit many, then harvest).
pub struct PendingReply {
    rx: mpsc::Receiver<Result<Reply, ServeError>>,
}

impl PendingReply {
    /// Block until the reply arrives. Errors are typed: batch execution
    /// failure ([`ServeError::Dropped`]), an executor panic
    /// ([`ServeError::WorkerLost`]), a reaped deadline
    /// ([`ServeError::DeadlineExceeded`]), an unservable traffic budget
    /// ([`ServeError::TrafficBudgetExceeded`]), or a shutdown load-shed
    /// ([`ServeError::Stopped`]). A dropped channel (worker thread died
    /// without answering) degrades to [`ServeError::Dropped`].
    pub fn wait(self) -> Result<Reply, ServeError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::Dropped),
        }
    }
}

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct ServerHandle {
    ingress: Arc<Ingress<Request>>,
    input_elems: usize,
}

impl ServerHandle {
    /// Enqueue one image without blocking on the result (open-loop
    /// traffic). Load-sheds with [`ServeError::QueueFull`] when the
    /// ingress is at capacity. Runs at [`Fidelity::Fast`], best-effort
    /// SLO.
    pub fn submit(&self, input: Vec<f32>) -> Result<PendingReply, ServeError> {
        self.submit_slo(input, Fidelity::Fast, SloClass::default())
    }

    /// [`ServerHandle::submit`] with an explicit per-request fidelity
    /// class (honored by fidelity-aware executors; others run their
    /// plain path for every class).
    pub fn submit_with(
        &self,
        input: Vec<f32>,
        fidelity: Fidelity,
    ) -> Result<PendingReply, ServeError> {
        self.submit_slo(input, fidelity, SloClass::default())
    }

    /// Fully classed submission: explicit fidelity *and* SLO class
    /// (latency deadline, traffic budget).
    pub fn submit_slo(
        &self,
        input: Vec<f32>,
        fidelity: Fidelity,
        slo: SloClass,
    ) -> Result<PendingReply, ServeError> {
        if input.len() != self.input_elems {
            return Err(ServeError::BadInput {
                got: input.len(),
                want: self.input_elems,
            });
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.ingress
            .submit(Request {
                input,
                fidelity,
                slo,
                enqueued: Instant::now(),
                reply: reply_tx,
            })
            .map_err(|e| match e {
                IngressError::Closed => ServeError::Stopped,
                IngressError::Full { capacity } => ServeError::QueueFull { capacity },
            })?;
        Ok(PendingReply { rx: reply_rx })
    }

    /// Submit one image; blocks until the reply arrives (closed-loop
    /// traffic).
    pub fn infer(&self, input: Vec<f32>) -> Result<Reply, ServeError> {
        self.submit(input)?.wait()
    }

    /// Closed-loop submission at an explicit fidelity class.
    pub fn infer_with(&self, input: Vec<f32>, fidelity: Fidelity) -> Result<Reply, ServeError> {
        self.submit_with(input, fidelity)?.wait()
    }

    /// Closed-loop submission with explicit fidelity and SLO classes.
    pub fn infer_slo(
        &self,
        input: Vec<f32>,
        fidelity: Fidelity,
        slo: SloClass,
    ) -> Result<Reply, ServeError> {
        self.submit_slo(input, fidelity, slo)?.wait()
    }
}

/// The inference server: a pool of workers, each owning an executor and
/// one shard of the work-stealing ingress.
pub struct InferenceServer {
    ingress: Arc<Ingress<Request>>,
    handle: ServerHandle,
    workers: Vec<std::thread::JoinHandle<ServerMetrics>>,
    drain_timeout: Duration,
}

impl InferenceServer {
    /// Start a pool of `policy.workers` workers. `factory(i)` builds
    /// worker `i`'s executor *on that worker's thread* (PJRT executables
    /// are not `Send`; pure-rust executors are usually a cheap `clone`).
    /// Each worker owns one ingress shard (shards == workers).
    /// Fails if any factory fails or workers disagree on input size.
    pub fn start_pool<E, F>(factory: F, policy: BatchPolicy) -> anyhow::Result<Self>
    where
        E: BatchExecutor + 'static,
        F: Fn(usize) -> anyhow::Result<E> + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let n = policy.workers.max(1);
        let ingress = Arc::new(Ingress::new(n, policy.queue_cap));
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<usize>>();
        let mut workers = Vec::with_capacity(n);
        for w in 0..n {
            let factory = Arc::clone(&factory);
            let ingress = Arc::clone(&ingress);
            let ready_tx = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                let executor = match factory(w) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(e.input_elems()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return ServerMetrics::default();
                    }
                };
                // Release the ready channel before serving: if a sibling
                // worker's factory panics (sender dropped without a
                // message), the startup loop below must see the channel
                // disconnect rather than block on this worker's clone
                // for its entire serving lifetime.
                drop(ready_tx);
                // The factory stays available to the loop so a poisoned
                // executor (caught panic) can be rebuilt in place.
                worker_loop(w, executor, &ingress, policy, &|| factory(w))
            }));
        }
        drop(ready_tx);

        let mut input_elems: Option<usize> = None;
        let mut startup_err: Option<anyhow::Error> = None;
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(ie)) => match input_elems {
                    None => input_elems = Some(ie),
                    Some(prev) if prev != ie => {
                        startup_err = Some(anyhow::anyhow!(
                            "pool executors disagree on input size ({prev} vs {ie})"
                        ));
                    }
                    Some(_) => {}
                },
                Ok(Err(e)) => startup_err = Some(e),
                Err(_) => {
                    startup_err =
                        Some(anyhow::anyhow!("server worker died during startup"))
                }
            }
        }
        if let Some(e) = startup_err {
            ingress.close();
            for w in workers {
                let _ = w.join();
            }
            return Err(e);
        }
        let input_elems = input_elems.expect("at least one worker");
        let handle = ServerHandle {
            ingress: Arc::clone(&ingress),
            input_elems,
        };
        Ok(Self {
            ingress,
            handle,
            workers,
            drain_timeout: policy.drain_timeout,
        })
    }

    /// Start a single worker whose executor is built on the worker thread
    /// by `factory` (PJRT executables are not `Send`). Fails if the
    /// factory fails. `policy.workers` is ignored (forced to 1); use
    /// [`Self::start_pool`] for multi-worker serving.
    pub fn start_with<E, F>(factory: F, policy: BatchPolicy) -> anyhow::Result<Self>
    where
        E: BatchExecutor + 'static,
        F: FnOnce() -> anyhow::Result<E> + Send + 'static,
    {
        let cell = Mutex::new(Some(factory));
        Self::start_pool(
            move |_| match cell.lock().unwrap().take() {
                Some(f) => f(),
                // A second call is a post-panic rebuild attempt: a
                // single-use factory cannot respawn, so the worker
                // retires (counted in `ServerMetrics::workers_lost`).
                None => Err(anyhow::anyhow!(
                    "single-use executor factory already consumed; cannot rebuild"
                )),
            },
            BatchPolicy {
                workers: 1,
                ..policy
            },
        )
    }

    /// Convenience for executors that are already constructed and `Send`
    /// (mocks, pure-rust executors). Single worker; use
    /// [`Self::start_pool`] with a cloning factory for a pool.
    pub fn start<E: BatchExecutor + Send + 'static>(
        executor: E,
        policy: BatchPolicy,
    ) -> Self {
        Self::start_with(move || Ok(executor), policy)
            .expect("infallible factory cannot fail")
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Stop the server: close the ingress to new submissions, drain
    /// every pending request, join the pool, and return the merged
    /// metrics (including the per-shard ingress counters).
    ///
    /// The drain is bounded by [`BatchPolicy::drain_timeout`]: if the
    /// pool has not finished by then (a wedged executor), the residual
    /// queues are load-shed with [`ServeError::Stopped`]
    /// (`metrics.drain_shed`), workers get one more timeout window to
    /// finish their in-flight batch, and any still unfinished are
    /// abandoned (`metrics.workers_lost`) so the caller never hangs.
    pub fn stop(mut self) -> ServerMetrics {
        self.ingress.close();
        let mut total = ServerMetrics::default();
        let deadline = Instant::now() + self.drain_timeout;
        while Instant::now() < deadline && !self.workers.iter().all(|w| w.is_finished()) {
            std::thread::sleep(Duration::from_millis(1));
        }
        if !self.workers.iter().all(|w| w.is_finished()) {
            // Timed out: unblock every still-queued client with a typed
            // error, then give workers one more window for the batch
            // they are already executing.
            total.drain_shed = self.ingress.drain_residual(|r| {
                let _ = r.reply.send(Err(ServeError::Stopped));
            });
            let grace = Instant::now() + self.drain_timeout;
            while Instant::now() < grace && !self.workers.iter().all(|w| w.is_finished()) {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        for (i, w) in self.workers.drain(..).enumerate() {
            if w.is_finished() {
                match w.join() {
                    Ok(m) => total.absorb(i, m),
                    // The worker thread itself panicked (outside the
                    // executor guard); its metrics are lost.
                    Err(_) => total.workers_lost += 1,
                }
            } else {
                // Still wedged past both windows: abandon the thread
                // (it holds only its own executor and an ingress handle).
                total.workers_lost += 1;
            }
        }
        total.rejected = self.ingress.rejected();
        total.per_shard = self.ingress.shard_summaries();
        total.finalize();
        total
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // `stop()` drains `workers`, so this only fires on an abandoned
        // server (e.g. a panicking test): release the pool so threads
        // drain and exit instead of blocking forever.
        self.ingress.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Workers that died early can leave requests queued; unblock
        // their clients with the typed shutdown error.
        self.ingress.drain_residual(|r| {
            let _ = r.reply.send(Err(ServeError::Stopped));
        });
    }
}

/// One pool worker: pop a batch from the sharded ingress (own shard
/// first, stealing from siblings on empty; first request blocking,
/// companions until the deadline), reap requests whose SLO can no longer
/// be met, pad, execute under a panic guard, reply.
///
/// `rebuild` re-runs the worker's executor factory after a caught panic
/// (the poisoned executor's internal state is unknowable). If the
/// rebuild fails, the worker retires early; its metrics survive, and
/// sibling workers steal the residue of its shard.
fn worker_loop<E: BatchExecutor>(
    worker_id: usize,
    mut executor: E,
    ingress: &Ingress<Request>,
    policy: BatchPolicy,
    rebuild: &dyn Fn() -> anyhow::Result<E>,
) -> ServerMetrics {
    let bs = executor.batch_size().max(1);
    let in_elems = executor.input_elems();
    let out_elems = executor.output_elems();
    let cost = executor.cost_estimate();
    let mut metrics = ServerMetrics {
        batch_fill: vec![0; bs],
        ..ServerMetrics::default()
    };
    // Fold an executor's cumulative telemetry into the worker metrics —
    // at drain, and before a post-panic rebuild resets the counters.
    let fold_telemetry = |metrics: &mut ServerMetrics, t: ExecTelemetry| {
        metrics.traffic_bits += t.traffic_bits;
        metrics.traffic_baseline_bits += t.traffic_baseline_bits;
        metrics.escalated += t.escalated;
    };
    // Deterministic per-worker stream for the latency reservoir.
    let mut rng = crate::util::rng::Rng::new(0xC0FF_EE00 ^ worker_id as u64);
    while let Some(first) = ingress.pop_blocking(worker_id) {
        if first.stolen {
            metrics.steals += 1;
        }
        let gather_deadline = Instant::now() + policy.max_wait;
        let mut batch = vec![first.item];
        while batch.len() < bs {
            match ingress.pop_until(worker_id, gather_deadline) {
                Some(p) => {
                    if p.stolen {
                        metrics.steals += 1;
                    }
                    batch.push(p.item);
                }
                None => break,
            }
        }
        // Reap requests whose SLO can no longer be met: an expired
        // deadline (per-request class first, pool-wide fallback), or a
        // traffic budget below the executor's modeled per-image floor.
        // Typed error, no lane occupied, no latency sample.
        let now = Instant::now();
        batch.retain(|r| {
            if let Some(dl) = r.slo.deadline.or(policy.deadline) {
                if now.duration_since(r.enqueued) > dl {
                    metrics.deadline_expired += 1;
                    let _ = r.reply.send(Err(ServeError::DeadlineExceeded));
                    return false;
                }
            }
            if let (Some(budget), Some(c)) = (r.slo.max_bits, cost) {
                if c.act_bits > budget {
                    metrics.budget_violations += 1;
                    let _ = r.reply.send(Err(ServeError::TrafficBudgetExceeded {
                        budget_bits: budget,
                        floor_bits: c.act_bits,
                    }));
                    return false;
                }
            }
            true
        });
        if batch.is_empty() {
            continue;
        }
        // Assemble (pad partial batches with zeros).
        let mut flat = vec![0f32; bs * in_elems];
        for (i, r) in batch.iter().enumerate() {
            flat[i * in_elems..(i + 1) * in_elems].copy_from_slice(&r.input);
        }
        let fidelities: Vec<Fidelity> = batch.iter().map(|r| r.fidelity).collect();
        let telem_before = executor.telemetry();
        let t0 = Instant::now();
        // The executor is arbitrary user code; a panic inside it must
        // not take down the worker (the batch is lost, the pool is not).
        let result = catch_unwind(AssertUnwindSafe(|| {
            executor.execute_with(&flat, batch.len(), &fidelities)
        }));
        match result {
            Ok(Ok(out)) => {
                metrics.exec_time += t0.elapsed();
                metrics.batches += 1;
                metrics.batch_fill[batch.len() - 1] += 1;
                // Counted on success only, so the conservation identity
                // `padded_slots == batches·batch_size − requests` holds
                // even after failed batches.
                metrics.padded_slots += (bs - batch.len()) as u64;
                let occupancy = batch.len();
                // Attribute the batch's measured traffic evenly over its
                // occupied lanes (0 for telemetry-less executors).
                let delta_bits = executor
                    .telemetry()
                    .traffic_bits
                    .saturating_sub(telem_before.traffic_bits);
                let share = delta_bits / occupancy as u64;
                for (i, r) in batch.into_iter().enumerate() {
                    let latency = r.enqueued.elapsed();
                    metrics.requests += 1;
                    metrics.record_latency(latency.as_secs_f64() * 1e6, &mut rng);
                    let budget_exceeded = r.slo.max_bits.is_some_and(|b| share > b);
                    if budget_exceeded {
                        metrics.budget_violations += 1;
                    }
                    let _ = r.reply.send(Ok(Reply {
                        logits: out[i * out_elems..(i + 1) * out_elems].to_vec(),
                        latency,
                        batch_size: bs,
                        occupancy,
                        cost,
                        traffic_bits: share,
                        budget_exceeded,
                    }));
                }
            }
            Ok(Err(e)) => {
                // Typed executor failure: fail this batch but keep
                // serving with the same executor.
                eprintln!("pacim-server[{worker_id}]: executor error: {e}");
                metrics.failed_batches += 1;
                for r in batch {
                    let _ = r.reply.send(Err(ServeError::Dropped));
                }
            }
            Err(_panic) => {
                // Executor panicked: the lane is poisoned. Answer the
                // in-flight requests, salvage the telemetry the old
                // executor accumulated, and rebuild from the factory.
                eprintln!("pacim-server[{worker_id}]: executor panicked; rebuilding");
                metrics.worker_panics += 1;
                metrics.failed_batches += 1;
                for r in batch {
                    let _ = r.reply.send(Err(ServeError::WorkerLost));
                }
                fold_telemetry(&mut metrics, executor.telemetry());
                match rebuild() {
                    Ok(e) => executor = e,
                    Err(e) => {
                        // No replacement: retire this worker. Sibling
                        // workers (if any) steal its shard's residue.
                        eprintln!(
                            "pacim-server[{worker_id}]: executor rebuild failed ({e}); \
                             worker retiring"
                        );
                        return metrics;
                    }
                }
            }
        }
    }
    fold_telemetry(&mut metrics, executor.telemetry());
    metrics
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Mock executor: logit j of input i = sum(input_i) + j.
    pub struct MockExecutor {
        pub batch: usize,
        pub in_elems: usize,
        pub out_elems: usize,
        pub delay: Duration,
        pub fail_every: Option<u64>,
        pub panic_every: Option<u64>,
        pub calls: u64,
    }

    impl BatchExecutor for MockExecutor {
        fn batch_size(&self) -> usize {
            self.batch
        }

        fn input_elems(&self) -> usize {
            self.in_elems
        }

        fn output_elems(&self) -> usize {
            self.out_elems
        }

        fn execute(&mut self, batch: &[f32], _occupancy: usize) -> anyhow::Result<Vec<f32>> {
            self.calls += 1;
            if let Some(k) = self.panic_every {
                if self.calls % k == 0 {
                    panic!("injected executor panic");
                }
            }
            if let Some(k) = self.fail_every {
                if self.calls % k == 0 {
                    anyhow::bail!("injected failure");
                }
            }
            std::thread::sleep(self.delay);
            let mut out = Vec::with_capacity(self.batch * self.out_elems);
            for i in 0..self.batch {
                let s: f32 = batch[i * self.in_elems..(i + 1) * self.in_elems]
                    .iter()
                    .sum();
                for j in 0..self.out_elems {
                    out.push(s + j as f32);
                }
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::MockExecutor;
    use super::*;

    fn mock(batch: usize) -> MockExecutor {
        MockExecutor {
            batch,
            in_elems: 4,
            out_elems: 3,
            delay: Duration::from_micros(200),
            fail_every: None,
            panic_every: None,
            calls: 0,
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let server = InferenceServer::start(mock(4), BatchPolicy::default());
        let h = server.handle();
        let reply = h.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(reply.logits, vec![10.0, 11.0, 12.0]);
        assert_eq!(reply.batch_size, 4);
        assert_eq!(reply.occupancy, 1);
        assert!(reply.cost.is_none(), "mock has no cost model");
        assert_eq!(reply.traffic_bits, 0, "mock exposes no telemetry");
        assert!(!reply.budget_exceeded, "best-effort SLO never flags");
        let metrics = server.stop();
        assert_eq!(metrics.requests, 1);
        assert_eq!(metrics.batches, 1);
        assert_eq!(metrics.padded_slots, 3);
        assert_eq!(metrics.batch_fill, vec![1, 0, 0, 0]);
        assert_eq!(metrics.per_shard.len(), 1);
        assert_eq!(metrics.per_shard[0].submitted, 1);
        assert_eq!(metrics.steals, 0, "one shard: nothing to steal");
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let server = InferenceServer::start(
            mock(8),
            BatchPolicy {
                max_wait: Duration::from_millis(50),
                ..BatchPolicy::default()
            },
        );
        let h = server.handle();
        let mut joins = Vec::new();
        for i in 0..8 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                h.infer(vec![i as f32; 4]).unwrap()
            }));
        }
        let replies: Vec<Reply> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        for (i, r) in replies.iter().enumerate() {
            assert_eq!(r.logits[0], 4.0 * i as f32);
        }
        let metrics = server.stop();
        assert_eq!(metrics.requests, 8);
        // With a generous wait window they should have shared few batches.
        assert!(metrics.batches <= 4, "batches={}", metrics.batches);
        assert!(metrics.mean_batch_occupancy() >= 2.0);
    }

    #[test]
    fn wrong_input_size_rejected() {
        let server = InferenceServer::start(mock(2), BatchPolicy::default());
        let h = server.handle();
        let err = h.infer(vec![1.0; 3]).unwrap_err();
        assert!(matches!(err, ServeError::BadInput { got: 3, want: 4 }));
        server.stop();
    }

    #[test]
    fn executor_failure_drops_batch_but_server_survives() {
        let server = InferenceServer::start(
            MockExecutor {
                fail_every: Some(1), // every call fails... except none succeed
                ..mock(1)
            },
            BatchPolicy::default(),
        );
        let h = server.handle();
        let r1 = h.infer(vec![0.0; 4]);
        assert!(matches!(r1, Err(ServeError::Dropped)));
        // Server thread is still alive and accepts further requests
        // (they also fail here since every call fails, but don't hang).
        let r2 = h.infer(vec![1.0; 4]);
        assert!(r2.is_err());
        let m = server.stop();
        assert_eq!(m.requests, 0);
        assert_eq!(m.failed_batches, 2);
    }

    #[test]
    fn intermittent_failure_recovers() {
        let server = InferenceServer::start(
            MockExecutor {
                fail_every: Some(2), // calls 2, 4, … fail
                ..mock(1)
            },
            BatchPolicy::default(),
        );
        let h = server.handle();
        assert!(h.infer(vec![1.0; 4]).is_ok()); // call 1
        assert!(h.infer(vec![1.0; 4]).is_err()); // call 2 fails
        assert!(h.infer(vec![1.0; 4]).is_ok()); // call 3
        let m = server.stop();
        assert_eq!(m.requests, 2);
        assert_eq!(m.failed_batches, 1);
    }

    #[test]
    fn latency_percentiles_reported() {
        let server = InferenceServer::start(mock(1), BatchPolicy::default());
        let h = server.handle();
        for _ in 0..20 {
            h.infer(vec![0.0; 4]).unwrap();
        }
        let m = server.stop();
        // Queries are `&self`: the reservoir was finalized at stop().
        let p50 = m.latency_percentile_us(50.0);
        let p99 = m.latency_percentile_us(99.0);
        assert!(p50 > 0.0);
        assert!(p99 >= p50);
        assert_eq!(m.latency_percentile_us(50.0), p50, "read-only and stable");
    }

    #[test]
    fn pool_roundtrip_and_per_worker_merge() {
        let server = InferenceServer::start_pool(
            |_| Ok(mock(2)),
            BatchPolicy {
                max_wait: Duration::from_millis(1),
                workers: 3,
                queue_cap: 64,
                ..BatchPolicy::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let mut joins = Vec::new();
        for i in 0..24 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                h.infer(vec![i as f32; 4]).unwrap()
            }));
        }
        for (i, j) in joins.into_iter().enumerate() {
            let r = j.join().unwrap();
            assert_eq!(r.logits[0], 4.0 * i as f32);
        }
        let m = server.stop();
        assert_eq!(m.requests, 24);
        assert_eq!(m.per_worker.len(), 3);
        let worker_reqs: u64 = m.per_worker.iter().map(|w| w.requests).sum();
        assert_eq!(worker_reqs, m.requests);
        let worker_batches: u64 = m.per_worker.iter().map(|w| w.batches).sum();
        assert_eq!(worker_batches, m.batches);
        // Conservation: fills weighted by occupancy recover the requests,
        // and the padded slots complete every batch to the compiled size.
        let filled: u64 = m
            .batch_fill
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as u64 + 1) * c)
            .sum();
        assert_eq!(filled, m.requests);
        assert_eq!(m.padded_slots, m.batches * 2 - m.requests);
        // Per-shard ingress accounting covers every admission, and the
        // per-worker fill histograms partition the aggregate exactly.
        assert_eq!(m.per_shard.len(), 3);
        let shard_submitted: u64 = m.per_shard.iter().map(|s| s.submitted).sum();
        assert_eq!(shard_submitted, 24);
        for i in 0..m.batch_fill.len() {
            let per_worker_sum: u64 = m
                .per_worker
                .iter()
                .map(|w| w.batch_fill.get(i).copied().unwrap_or(0))
                .sum();
            assert_eq!(per_worker_sum, m.batch_fill[i], "fill bucket {i}");
        }
    }

    #[test]
    fn queue_full_load_sheds_with_typed_error() {
        // One worker stuck in a slow batch; capacity 2. Fill the queue,
        // then the next submission must shed.
        let server = InferenceServer::start(
            MockExecutor {
                delay: Duration::from_millis(200),
                ..mock(1)
            },
            BatchPolicy {
                max_wait: Duration::from_micros(1),
                workers: 1,
                queue_cap: 2,
                ..BatchPolicy::default()
            },
        );
        let h = server.handle();
        // First request occupies the worker (popped quickly); give it
        // time to enter execute().
        let busy = h.submit(vec![0.0; 4]).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let p1 = h.submit(vec![1.0; 4]).unwrap();
        let p2 = h.submit(vec![2.0; 4]).unwrap();
        let shed = h.submit(vec![3.0; 4]);
        assert!(matches!(shed, Err(ServeError::QueueFull { capacity: 2 })));
        assert!(busy.wait().is_ok());
        assert!(p1.wait().is_ok());
        assert!(p2.wait().is_ok());
        let m = server.stop();
        assert_eq!(m.requests, 3);
        assert_eq!(m.rejected, 1);
    }

    #[test]
    fn stop_drains_pending_requests() {
        let server = InferenceServer::start(
            MockExecutor {
                delay: Duration::from_millis(5),
                ..mock(2)
            },
            BatchPolicy {
                max_wait: Duration::from_millis(1),
                workers: 1,
                queue_cap: 64,
                ..BatchPolicy::default()
            },
        );
        let h = server.handle();
        let pending: Vec<PendingReply> =
            (0..10).map(|i| h.submit(vec![i as f32; 4]).unwrap()).collect();
        // Stop immediately: every already-admitted request must still be
        // answered (graceful drain), and later submissions must fail.
        let stopper = std::thread::spawn(move || server.stop());
        for (i, p) in pending.into_iter().enumerate() {
            let r = p.wait().unwrap();
            assert_eq!(r.logits[0], 4.0 * i as f32);
        }
        let m = stopper.join().unwrap();
        assert_eq!(m.requests, 10);
        assert!(matches!(h.infer(vec![0.0; 4]), Err(ServeError::Stopped)));
    }

    #[test]
    fn executor_panic_is_isolated_and_worker_recovers() {
        // Call 2 panics. The pool must answer that request with the
        // typed WorkerLost error, rebuild the executor from the factory,
        // and keep serving calls 3+ (the rebuilt executor's counter
        // restarts, so no later call hits the panic trigger again until
        // its own call 2 — exercise past it).
        let server = InferenceServer::start_pool(
            |_| {
                Ok(MockExecutor {
                    panic_every: Some(2),
                    ..mock(1)
                })
            },
            BatchPolicy {
                max_wait: Duration::from_micros(1),
                ..BatchPolicy::default()
            },
        )
        .unwrap();
        let h = server.handle();
        assert!(h.infer(vec![1.0; 4]).is_ok()); // call 1
        let lost = h.infer(vec![1.0; 4]); // call 2 panics
        assert!(matches!(lost, Err(ServeError::WorkerLost)), "{lost:?}");
        assert!(h.infer(vec![1.0; 4]).is_ok()); // rebuilt executor, call 1
        let m = server.stop();
        assert_eq!(m.requests, 2);
        assert_eq!(m.worker_panics, 1);
        assert_eq!(m.failed_batches, 1);
        assert_eq!(m.workers_lost, 0);
    }

    #[test]
    fn single_use_factory_cannot_respawn_and_pool_retires() {
        // `start` wraps a FnOnce factory: after a panic the rebuild must
        // fail gracefully (worker retires, stop() does not hang).
        let server = InferenceServer::start(
            MockExecutor {
                panic_every: Some(1),
                ..mock(1)
            },
            BatchPolicy {
                max_wait: Duration::from_micros(1),
                drain_timeout: Duration::from_millis(200),
                ..BatchPolicy::default()
            },
        );
        let h = server.handle();
        assert!(matches!(h.infer(vec![0.0; 4]), Err(ServeError::WorkerLost)));
        let m = server.stop();
        assert_eq!(m.worker_panics, 1);
        // The retired worker still returned its metrics.
        assert_eq!(m.per_worker.len(), 1);
    }

    #[test]
    fn expired_requests_are_reaped_with_typed_error() {
        // Worker busy for 100ms; deadline 20ms. The queued victim must
        // come back DeadlineExceeded without occupying a lane.
        let server = InferenceServer::start(
            MockExecutor {
                delay: Duration::from_millis(100),
                ..mock(1)
            },
            BatchPolicy {
                max_wait: Duration::from_micros(1),
                deadline: Some(Duration::from_millis(20)),
                ..BatchPolicy::default()
            },
        );
        let h = server.handle();
        let busy = h.submit(vec![0.0; 4]).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let victim = h.submit(vec![1.0; 4]).unwrap();
        assert!(busy.wait().is_ok());
        let got = victim.wait();
        assert!(matches!(got, Err(ServeError::DeadlineExceeded)), "{got:?}");
        let m = server.stop();
        assert_eq!(m.requests, 1);
        assert_eq!(m.deadline_expired, 1);
    }

    #[test]
    fn slo_deadline_overrides_pool_policy() {
        // Pool-wide deadline is None, but the victim carries its own
        // 20ms SLO deadline — it must be reaped while the best-effort
        // sibling queued behind the same slow batch is served.
        let server = InferenceServer::start(
            MockExecutor {
                delay: Duration::from_millis(100),
                ..mock(1)
            },
            BatchPolicy {
                max_wait: Duration::from_micros(1),
                ..BatchPolicy::default()
            },
        );
        let h = server.handle();
        let busy = h.submit(vec![0.0; 4]).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let victim = h
            .submit_slo(
                vec![1.0; 4],
                Fidelity::Fast,
                SloClass::latency(Duration::from_millis(20)),
            )
            .unwrap();
        let patient = h.submit(vec![2.0; 4]).unwrap();
        assert!(busy.wait().is_ok());
        let got = victim.wait();
        assert!(matches!(got, Err(ServeError::DeadlineExceeded)), "{got:?}");
        assert!(patient.wait().is_ok(), "best-effort request survives");
        let m = server.stop();
        assert_eq!(m.requests, 2);
        assert_eq!(m.deadline_expired, 1);
    }

    #[test]
    fn traffic_budget_below_modeled_floor_is_reaped() {
        // The executor models 1000 act bits per image; a 10-bit budget
        // can never be met, so the request is reaped pre-execution with
        // the typed error, while a generous budget rides through.
        struct Costed(MockExecutor);
        impl BatchExecutor for Costed {
            fn batch_size(&self) -> usize {
                self.0.batch_size()
            }
            fn input_elems(&self) -> usize {
                self.0.input_elems()
            }
            fn output_elems(&self) -> usize {
                self.0.output_elems()
            }
            fn execute(&mut self, batch: &[f32], occupancy: usize) -> anyhow::Result<Vec<f32>> {
                self.0.execute(batch, occupancy)
            }
            fn cost_estimate(&self) -> Option<CostEstimate> {
                Some(CostEstimate {
                    cycles: 1,
                    compute_pj: 0.0,
                    memory_pj: 0.0,
                    act_bits: 1000,
                    act_bits_baseline: 8000,
                })
            }
        }
        let server = InferenceServer::start(Costed(mock(1)), BatchPolicy::default());
        let h = server.handle();
        let got = h.infer_slo(vec![0.0; 4], Fidelity::Fast, SloClass::traffic_budget(10));
        assert!(
            matches!(
                got,
                Err(ServeError::TrafficBudgetExceeded {
                    budget_bits: 10,
                    floor_bits: 1000,
                })
            ),
            "{got:?}"
        );
        let ok = h.infer_slo(
            vec![0.0; 4],
            Fidelity::Fast,
            SloClass::traffic_budget(1_000_000),
        );
        assert!(ok.is_ok());
        let m = server.stop();
        assert_eq!(m.requests, 1, "the reaped request never occupied a lane");
        assert_eq!(m.budget_violations, 1);
    }

    #[test]
    fn measured_share_overrun_flags_the_reply() {
        // Telemetry grows 100 bits per call; with batch 1 every request
        // is attributed 100 measured bits. A 50-bit budget is overrun
        // (flagged, still served); a 1000-bit budget is within SLO.
        struct Telem(MockExecutor);
        impl BatchExecutor for Telem {
            fn batch_size(&self) -> usize {
                self.0.batch_size()
            }
            fn input_elems(&self) -> usize {
                self.0.input_elems()
            }
            fn output_elems(&self) -> usize {
                self.0.output_elems()
            }
            fn execute(&mut self, batch: &[f32], occupancy: usize) -> anyhow::Result<Vec<f32>> {
                self.0.execute(batch, occupancy)
            }
            fn telemetry(&self) -> ExecTelemetry {
                ExecTelemetry {
                    traffic_bits: 100 * self.0.calls,
                    traffic_baseline_bits: 200 * self.0.calls,
                    escalated: 0,
                }
            }
        }
        let server = InferenceServer::start(Telem(mock(1)), BatchPolicy::default());
        let h = server.handle();
        let flagged = h
            .infer_slo(vec![0.0; 4], Fidelity::Fast, SloClass::traffic_budget(50))
            .unwrap();
        assert_eq!(flagged.traffic_bits, 100);
        assert!(flagged.budget_exceeded, "100 measured bits > 50 budget");
        let within = h
            .infer_slo(vec![0.0; 4], Fidelity::Fast, SloClass::traffic_budget(1000))
            .unwrap();
        assert_eq!(within.traffic_bits, 100);
        assert!(!within.budget_exceeded);
        let m = server.stop();
        assert_eq!(m.requests, 2);
        assert_eq!(m.budget_violations, 1);
    }

    #[test]
    fn fidelity_reaches_the_executor_and_defaults_to_fast() {
        use std::sync::atomic::{AtomicU64, Ordering};
        struct Spy {
            inner: MockExecutor,
            accurate_seen: Arc<AtomicU64>,
        }
        impl BatchExecutor for Spy {
            fn batch_size(&self) -> usize {
                self.inner.batch_size()
            }
            fn input_elems(&self) -> usize {
                self.inner.input_elems()
            }
            fn output_elems(&self) -> usize {
                self.inner.output_elems()
            }
            fn execute(&mut self, batch: &[f32], occupancy: usize) -> anyhow::Result<Vec<f32>> {
                self.inner.execute(batch, occupancy)
            }
            fn execute_with(
                &mut self,
                batch: &[f32],
                occupancy: usize,
                fidelities: &[Fidelity],
            ) -> anyhow::Result<Vec<f32>> {
                assert_eq!(fidelities.len(), occupancy);
                let n = fidelities
                    .iter()
                    .filter(|&&f| f == Fidelity::Accurate)
                    .count() as u64;
                self.accurate_seen.fetch_add(n, Ordering::Relaxed);
                self.inner.execute(batch, occupancy)
            }
        }
        let seen = Arc::new(AtomicU64::new(0));
        let seen2 = Arc::clone(&seen);
        let server = InferenceServer::start(
            Spy {
                inner: mock(2),
                accurate_seen: seen2,
            },
            BatchPolicy::default(),
        );
        let h = server.handle();
        assert!(h.infer(vec![0.0; 4]).is_ok());
        assert!(h.infer_with(vec![0.0; 4], Fidelity::Accurate).is_ok());
        server.stop();
        assert_eq!(seen.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn telemetry_flows_into_metrics() {
        struct Telem(MockExecutor);
        impl BatchExecutor for Telem {
            fn batch_size(&self) -> usize {
                self.0.batch_size()
            }
            fn input_elems(&self) -> usize {
                self.0.input_elems()
            }
            fn output_elems(&self) -> usize {
                self.0.output_elems()
            }
            fn execute(&mut self, batch: &[f32], occupancy: usize) -> anyhow::Result<Vec<f32>> {
                self.0.execute(batch, occupancy)
            }
            fn telemetry(&self) -> ExecTelemetry {
                ExecTelemetry {
                    traffic_bits: 100 * self.0.calls,
                    traffic_baseline_bits: 200 * self.0.calls,
                    escalated: self.0.calls,
                }
            }
        }
        let server = InferenceServer::start(Telem(mock(1)), BatchPolicy::default());
        let h = server.handle();
        for _ in 0..4 {
            let r = h.infer(vec![0.0; 4]).unwrap();
            assert_eq!(r.traffic_bits, 100, "per-reply measured attribution");
        }
        let m = server.stop();
        assert_eq!(m.traffic_bits, 400);
        assert_eq!(m.traffic_baseline_bits, 800);
        assert_eq!(m.escalated, 4);
        assert_eq!(m.per_worker[0].traffic_bits, 400);
        assert!((m.bits_per_request() - 100.0).abs() < 1e-9);
    }
}
