//! The serving coordinator: a threaded request loop with dynamic
//! batching in front of a (PJRT-compiled) model executable.
//!
//! This is the L3 runtime path: clients submit single images; the
//! batcher groups them up to the executable's compiled batch size or a
//! deadline, pads partial batches, executes, and distributes per-request
//! results. Python never appears here — the executable was AOT-compiled
//! at build time.
//!
//! The executor is a trait so unit tests run against a mock and the
//! examples against `crate::runtime::PjrtExecutor` (behind the `pjrt`
//! cargo feature).

use crate::util::stats::percentile;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Something that can run a fixed-batch forward pass.
/// Inputs are flattened f32 images (C·H·W each), batched contiguously.
///
/// Implementations need not be `Send`: the server constructs its executor
/// *inside* the worker thread (PJRT handles hold non-Send `Rc`s).
pub trait BatchExecutor {
    /// Compiled batch size.
    fn batch_size(&self) -> usize;
    /// Elements per input (C·H·W).
    fn input_elems(&self) -> usize;
    /// Elements per output (num classes).
    fn output_elems(&self) -> usize;
    /// Execute on exactly `batch_size()` inputs; returns
    /// `batch_size() × output_elems()` outputs.
    fn execute(&mut self, batch: &[f32]) -> anyhow::Result<Vec<f32>>;
}

/// One inference request.
struct Request {
    input: Vec<f32>,
    enqueued: Instant,
    reply: mpsc::Sender<Reply>,
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// Per-request response.
#[derive(Debug, Clone)]
pub struct Reply {
    pub logits: Vec<f32>,
    /// Queue + batch + execute latency.
    pub latency: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

/// Server-side aggregate metrics.
#[derive(Debug, Clone, Default)]
pub struct ServerMetrics {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub failed_batches: u64,
    pub exec_time: Duration,
    latencies_us: Vec<f64>,
}

impl ServerMetrics {
    pub fn latency_percentile_us(&mut self, p: f64) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        percentile(&mut self.latencies_us, p)
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / self.batches as f64
    }
}

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    input_elems: usize,
}

impl ServerHandle {
    /// Submit one image; blocks until the reply arrives.
    pub fn infer(&self, input: Vec<f32>) -> anyhow::Result<Reply> {
        anyhow::ensure!(
            input.len() == self.input_elems,
            "input has {} elems, expected {}",
            input.len(),
            self.input_elems
        );
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Req(Request {
                input,
                enqueued: Instant::now(),
                reply: reply_tx,
            }))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("request dropped (batch failed or server stopped)"))
    }
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max time the first request of a batch waits for company.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
        }
    }
}

/// The inference server: owns the executor on a dedicated thread.
pub struct InferenceServer {
    handle: ServerHandle,
    worker: Option<std::thread::JoinHandle<ServerMetrics>>,
}

impl InferenceServer {
    /// Start a server whose executor is built on the worker thread by
    /// `factory` (PJRT executables are not `Send`). Fails if the factory
    /// fails.
    pub fn start_with<E, F>(factory: F, policy: BatchPolicy) -> anyhow::Result<Self>
    where
        E: BatchExecutor + 'static,
        F: FnOnce() -> anyhow::Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<usize>>();
        let worker = std::thread::spawn(move || {
            let mut executor = match factory() {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(e.input_elems()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return ServerMetrics::default();
                }
            };
            let mut metrics = ServerMetrics::default();
            let bs = executor.batch_size();
            let out_elems = executor.output_elems();
            let in_elems = executor.input_elems();
            'serve: loop {
                // Block for the first request of a batch.
                let first = match rx.recv() {
                    Ok(Msg::Req(r)) => r,
                    Ok(Msg::Shutdown) | Err(_) => break,
                };
                let deadline = Instant::now() + policy.max_wait;
                let mut batch = vec![first];
                let mut shutdown_after = false;
                while batch.len() < bs {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(Msg::Req(r)) => batch.push(r),
                        Ok(Msg::Shutdown) => {
                            shutdown_after = true;
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            shutdown_after = true;
                            break;
                        }
                    }
                }
                // Assemble (pad partial batches with zeros).
                let mut flat = vec![0f32; bs * in_elems];
                for (i, r) in batch.iter().enumerate() {
                    flat[i * in_elems..(i + 1) * in_elems].copy_from_slice(&r.input);
                }
                metrics.padded_slots += (bs - batch.len()) as u64;
                let t0 = Instant::now();
                match executor.execute(&flat) {
                    Ok(out) => {
                        metrics.exec_time += t0.elapsed();
                        metrics.batches += 1;
                        for (i, r) in batch.into_iter().enumerate() {
                            let latency = r.enqueued.elapsed();
                            metrics.requests += 1;
                            metrics.latencies_us.push(latency.as_secs_f64() * 1e6);
                            let _ = r.reply.send(Reply {
                                logits: out[i * out_elems..(i + 1) * out_elems].to_vec(),
                                latency,
                                batch_size: bs,
                            });
                        }
                    }
                    Err(e) => {
                        // Fail this batch (reply senders drop → clients
                        // see an error) but keep serving.
                        eprintln!("pacim-server: executor error: {e}");
                        metrics.failed_batches += 1;
                    }
                }
                if shutdown_after {
                    break 'serve;
                }
            }
            metrics
        });
        let input_elems = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker died during startup"))??;
        Ok(Self {
            handle: ServerHandle { tx, input_elems },
            worker: Some(worker),
        })
    }

    /// Convenience for executors that are already constructed and `Send`
    /// (mocks, pure-rust executors).
    pub fn start<E: BatchExecutor + Send + 'static>(
        executor: E,
        policy: BatchPolicy,
    ) -> Self {
        Self::start_with(move || Ok(executor), policy)
            .expect("infallible factory cannot fail")
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Stop the server (after in-flight work) and collect metrics.
    pub fn stop(mut self) -> ServerMetrics {
        let _ = self.handle.tx.send(Msg::Shutdown);
        self.worker
            .take()
            .expect("server already stopped")
            .join()
            .expect("server thread panicked")
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Mock executor: logit j of input i = sum(input_i) + j.
    pub struct MockExecutor {
        pub batch: usize,
        pub in_elems: usize,
        pub out_elems: usize,
        pub delay: Duration,
        pub fail_every: Option<u64>,
        pub calls: u64,
    }

    impl BatchExecutor for MockExecutor {
        fn batch_size(&self) -> usize {
            self.batch
        }

        fn input_elems(&self) -> usize {
            self.in_elems
        }

        fn output_elems(&self) -> usize {
            self.out_elems
        }

        fn execute(&mut self, batch: &[f32]) -> anyhow::Result<Vec<f32>> {
            self.calls += 1;
            if let Some(k) = self.fail_every {
                if self.calls % k == 0 {
                    anyhow::bail!("injected failure");
                }
            }
            std::thread::sleep(self.delay);
            let mut out = Vec::with_capacity(self.batch * self.out_elems);
            for i in 0..self.batch {
                let s: f32 = batch[i * self.in_elems..(i + 1) * self.in_elems]
                    .iter()
                    .sum();
                for j in 0..self.out_elems {
                    out.push(s + j as f32);
                }
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::MockExecutor;
    use super::*;

    fn mock(batch: usize) -> MockExecutor {
        MockExecutor {
            batch,
            in_elems: 4,
            out_elems: 3,
            delay: Duration::from_micros(200),
            fail_every: None,
            calls: 0,
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let server = InferenceServer::start(mock(4), BatchPolicy::default());
        let h = server.handle();
        let reply = h.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(reply.logits, vec![10.0, 11.0, 12.0]);
        let metrics = server.stop();
        assert_eq!(metrics.requests, 1);
        assert_eq!(metrics.batches, 1);
        assert_eq!(metrics.padded_slots, 3);
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let server = InferenceServer::start(
            mock(8),
            BatchPolicy {
                max_wait: Duration::from_millis(50),
            },
        );
        let h = server.handle();
        let mut joins = Vec::new();
        for i in 0..8 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                h.infer(vec![i as f32; 4]).unwrap()
            }));
        }
        let replies: Vec<Reply> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        for (i, r) in replies.iter().enumerate() {
            assert_eq!(r.logits[0], 4.0 * i as f32);
        }
        let metrics = server.stop();
        assert_eq!(metrics.requests, 8);
        // With a generous wait window they should have shared few batches.
        assert!(metrics.batches <= 4, "batches={}", metrics.batches);
        assert!(metrics.mean_batch_occupancy() >= 2.0);
    }

    #[test]
    fn wrong_input_size_rejected() {
        let server = InferenceServer::start(mock(2), BatchPolicy::default());
        let h = server.handle();
        assert!(h.infer(vec![1.0; 3]).is_err());
        server.stop();
    }

    #[test]
    fn executor_failure_drops_batch_but_server_survives() {
        let server = InferenceServer::start(
            MockExecutor {
                fail_every: Some(1), // every call fails... except none succeed
                ..mock(1)
            },
            BatchPolicy::default(),
        );
        let h = server.handle();
        let r1 = h.infer(vec![0.0; 4]);
        assert!(r1.is_err());
        // Server thread is still alive and accepts further requests
        // (they also fail here since every call fails, but don't hang).
        let r2 = h.infer(vec![1.0; 4]);
        assert!(r2.is_err());
        let m = server.stop();
        assert_eq!(m.requests, 0);
        assert_eq!(m.failed_batches, 2);
    }

    #[test]
    fn intermittent_failure_recovers() {
        let server = InferenceServer::start(
            MockExecutor {
                fail_every: Some(2), // calls 2, 4, … fail
                ..mock(1)
            },
            BatchPolicy::default(),
        );
        let h = server.handle();
        assert!(h.infer(vec![1.0; 4]).is_ok()); // call 1
        assert!(h.infer(vec![1.0; 4]).is_err()); // call 2 fails
        assert!(h.infer(vec![1.0; 4]).is_ok()); // call 3
        let m = server.stop();
        assert_eq!(m.requests, 2);
        assert_eq!(m.failed_batches, 1);
    }

    #[test]
    fn latency_percentiles_reported() {
        let server = InferenceServer::start(mock(1), BatchPolicy::default());
        let h = server.handle();
        for _ in 0..20 {
            h.infer(vec![0.0; 4]).unwrap();
        }
        let mut m = server.stop();
        let p50 = m.latency_percentile_us(50.0);
        let p99 = m.latency_percentile_us(99.0);
        assert!(p50 > 0.0);
        assert!(p99 >= p50);
    }
}
