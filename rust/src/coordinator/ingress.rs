//! The sharded serving ingress and the multi-model tenancy layer.
//!
//! PACiM's system-level throughput comes from many banks chewing on
//! independent slices of traffic in parallel (paper §IV). The serving
//! front door mirrors that topology: instead of one global
//! `Mutex + Condvar` batcher every request funnels through, each pool
//! worker owns a *shard* — its own bounded FIFO — and the submit path
//! never takes a global lock:
//!
//! - **admission** is a CAS loop on one atomic slot counter, so the
//!   `queue_cap` bound stays *exact* (load-shed fires on submission
//!   `cap + 1`, never earlier, never later — the PR 8 property tests
//!   keep holding verbatim);
//! - **placement** is power-of-two-choices over per-shard atomic depth
//!   gauges: hash two shards, push to the shallower. P2C keeps the
//!   maximum queue imbalance O(log log n) without any coordination, and
//!   it makes spill *deterministic* in the way the steal tests rely on:
//!   once one shard is strictly deeper, the next submission must land
//!   on the other;
//! - the only lock a submission touches is the chosen shard's own
//!   mutex, for the `VecDeque` push.
//!
//! **Steal protocol.** A worker pops its own shard first (FIFO). On
//! empty it sweeps the sibling shards round-robin starting after its
//! own index and takes the head of the first non-empty queue — a
//! *steal*, counted on both the victim shard ([`ShardSummary::stolen`])
//! and the thief ([`super::server::WorkerSummary::steals`]). Idle waits
//! park on the worker's own condvar but time out every [`STEAL_POLL`]
//! so backlog on sibling shards is discovered even when the worker's
//! own condvar never fires (e.g. its owner retired after a panic — no
//! request parked on a shard is ever stranded).
//!
//! **Drain.** `close()` latches every shard shut under its own lock;
//! once a closed shard is observed empty it can never refill, so the
//! all-shards-closed-and-empty exit check is sound even though it is
//! evaluated one shard at a time. Workers drain their own shard, then
//! steal the residue of everyone else's, then exit.
//!
//! **Multi-model tenancy** (the PPAC framing: one deployed array hosts
//! many operation modes): a [`ModelRegistry`] maps model ids to
//! Arc-shared [`Engine`] replicas with per-model [`BatchPolicy`],
//! default [`Fidelity`], and default [`SloClass`]. A
//! [`MultiModelServer`] runs one sharded worker pool per model —
//! batches never mix models, since lanes share one compiled executor —
//! behind a single routing [`MultiModelHandle`]. Build one with
//! [`crate::runtime::PacExecutor::serve_registry`].

use super::server::{
    BatchPolicy, InferenceServer, PendingReply, Reply, ServeError, ServerHandle, ServerMetrics,
};
use crate::engine::{Engine, Fidelity, PacimError};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How long an idle worker parks on its own shard's condvar before
/// re-sweeping sibling shards for stealable work. Bounds the latency of
/// a steal when the victim's owner is wedged or retired; small enough
/// to be invisible next to `BatchPolicy::max_wait`.
const STEAL_POLL: Duration = Duration::from_micros(200);

/// Typed submission failure of the sharded ingress (the server maps
/// these onto [`ServeError::Stopped`] / [`ServeError::QueueFull`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IngressError {
    /// The ingress is closed to new submissions (drain in progress).
    Closed,
    /// Admission control fired: `capacity` items are already queued
    /// across all shards.
    Full {
        /// The exact global bound that was hit.
        capacity: usize,
    },
}

/// One successful pop, with provenance: which shard the item came from
/// and whether the popper stole it from a shard it does not own.
#[derive(Debug)]
pub struct Popped<T> {
    /// The dequeued item.
    pub item: T,
    /// Index of the shard the item was queued on.
    pub shard: usize,
    /// True when the popping worker is not the shard's owner.
    pub stolen: bool,
}

/// Snapshot of one shard's lifetime counters (read at `stop()` into
/// [`ServerMetrics::per_shard`]).
#[derive(Debug, Clone, Default)]
pub struct ShardSummary {
    /// Shard index (== owning worker index).
    pub shard: usize,
    /// Items admitted onto this shard.
    pub submitted: u64,
    /// Items popped off this shard by a non-owner (steal-rate numerator;
    /// `submitted` is the denominator).
    pub stolen: u64,
    /// Deepest this shard's queue ever got.
    pub max_depth: usize,
}

struct ShardQueue<T> {
    queue: VecDeque<T>,
    /// `false` once drain begins: no new items enter this shard. Set
    /// under the shard lock, so a successful push strictly precedes any
    /// observation of `!open && empty`.
    open: bool,
}

struct Shard<T> {
    state: Mutex<ShardQueue<T>>,
    notify: Condvar,
    /// Depth gauge for P2C placement, maintained under the shard lock
    /// (reads are relaxed: placement tolerates staleness, correctness
    /// never depends on it).
    depth: AtomicUsize,
    submitted: AtomicU64,
    stolen: AtomicU64,
    max_depth: AtomicUsize,
}

impl<T> Shard<T> {
    fn new() -> Self {
        Self {
            state: Mutex::new(ShardQueue {
                queue: VecDeque::new(),
                open: true,
            }),
            notify: Condvar::new(),
            depth: AtomicUsize::new(0),
            submitted: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            max_depth: AtomicUsize::new(0),
        }
    }
}

/// SplitMix64 finalizer: turns the monotone submission ticket into the
/// two P2C shard candidates.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Per-worker sharded queues with power-of-two-choices placement, work
/// stealing, an exact global capacity bound, and bounded graceful
/// drain. Generic over the item type so the queueing/stealing protocol
/// is property-testable with plain payloads (`tests/proptests_ingress`).
pub struct Ingress<T> {
    shards: Vec<Shard<T>>,
    capacity: usize,
    /// Items currently queued across all shards (the CAS admission
    /// token pool; never exceeds `capacity`).
    queued: AtomicUsize,
    rejected: AtomicU64,
    /// Fast-path close flag so a submission to a stopped ingress reports
    /// `Closed` even when the queue is still full of draining items
    /// (the per-shard `open` flags stay authoritative).
    closed: AtomicBool,
    ticket: AtomicU64,
}

impl<T> Ingress<T> {
    /// An ingress with `shards` queues (one per pool worker) and an
    /// exact global capacity of `capacity` queued items. Both floors at
    /// 1.
    pub fn new(shards: usize, capacity: usize) -> Self {
        Self {
            shards: (0..shards.max(1)).map(|_| Shard::new()).collect(),
            capacity: capacity.max(1),
            queued: AtomicUsize::new(0),
            rejected: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            ticket: AtomicU64::new(0),
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The exact global admission bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Items currently queued across all shards.
    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Submissions load-shed by admission control so far.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Current per-shard queue depths (relaxed gauges; for tests and
    /// observability, not for correctness decisions).
    pub fn depths(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| s.depth.load(Ordering::Relaxed))
            .collect()
    }

    /// Lifetime counters of every shard.
    pub fn shard_summaries(&self) -> Vec<ShardSummary> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| ShardSummary {
                shard: i,
                submitted: s.submitted.load(Ordering::Relaxed),
                stolen: s.stolen.load(Ordering::Relaxed),
                max_depth: s.max_depth.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Acquire one admission token, or fail when `capacity` items are
    /// already queued. A CAS loop (not fetch_add-then-undo) so rejected
    /// submissions never transiently overshoot the bound.
    fn try_acquire_slot(&self) -> bool {
        let mut n = self.queued.load(Ordering::SeqCst);
        loop {
            if n >= self.capacity {
                return false;
            }
            match self
                .queued
                .compare_exchange_weak(n, n + 1, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => return true,
                Err(cur) => n = cur,
            }
        }
    }

    /// Power-of-two-choices: hash the submission ticket into two shard
    /// candidates and pick the strictly shallower one (ties keep the
    /// first, so a lone deep shard always diverts traffic).
    fn pick_shard(&self) -> usize {
        let k = self.shards.len();
        if k == 1 {
            return 0;
        }
        let h = splitmix64(self.ticket.fetch_add(1, Ordering::Relaxed));
        let a = (h as u32 as usize) % k;
        let b = ((h >> 32) as usize) % k;
        let da = self.shards[a].depth.load(Ordering::Relaxed);
        let db = self.shards[b].depth.load(Ordering::Relaxed);
        if db < da {
            b
        } else {
            a
        }
    }

    /// Admit one item: acquire a capacity token, pick a shard (P2C),
    /// push under that shard's lock only. Returns the shard index.
    pub fn submit(&self, item: T) -> Result<usize, IngressError> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(IngressError::Closed);
        }
        if !self.try_acquire_slot() {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(IngressError::Full {
                capacity: self.capacity,
            });
        }
        let idx = self.pick_shard();
        let shard = &self.shards[idx];
        let mut st = shard.state.lock().unwrap();
        if !st.open {
            // Raced with close(): refund the token; the item was never
            // visible to any worker.
            drop(st);
            self.queued.fetch_sub(1, Ordering::SeqCst);
            return Err(IngressError::Closed);
        }
        st.queue.push_back(item);
        let depth = st.queue.len();
        shard.depth.store(depth, Ordering::Relaxed);
        drop(st);
        shard.submitted.fetch_add(1, Ordering::Relaxed);
        shard.max_depth.fetch_max(depth, Ordering::Relaxed);
        shard.notify.notify_one();
        Ok(idx)
    }

    fn pop_shard(&self, idx: usize) -> Option<T> {
        let shard = &self.shards[idx];
        let mut st = shard.state.lock().unwrap();
        let item = st.queue.pop_front()?;
        shard.depth.store(st.queue.len(), Ordering::Relaxed);
        drop(st);
        self.queued.fetch_sub(1, Ordering::SeqCst);
        Some(item)
    }

    /// One non-blocking pass of the pop protocol: `worker`'s own shard
    /// first, then one steal sweep round-robin from `worker + 1`.
    pub fn try_pop(&self, worker: usize) -> Option<Popped<T>> {
        let me = worker % self.shards.len();
        if let Some(item) = self.pop_shard(me) {
            return Some(Popped {
                item,
                shard: me,
                stolen: false,
            });
        }
        let k = self.shards.len();
        for off in 1..k {
            let v = (me + off) % k;
            if let Some(item) = self.pop_shard(v) {
                self.shards[v].stolen.fetch_add(1, Ordering::Relaxed);
                return Some(Popped {
                    item,
                    shard: v,
                    stolen: true,
                });
            }
        }
        None
    }

    /// Every shard closed *and* empty. A closed shard observed empty can
    /// never refill (pushes require `open`, set under the same lock), so
    /// the shard-at-a-time sweep is a sound exit condition.
    fn all_drained(&self) -> bool {
        self.shards.iter().all(|s| {
            let st = s.state.lock().unwrap();
            !st.open && st.queue.is_empty()
        })
    }

    /// Pop one item for `worker`, blocking until one is available
    /// anywhere. Returns `None` only when the ingress is closed and
    /// every shard is drained.
    pub fn pop_blocking(&self, worker: usize) -> Option<Popped<T>> {
        let me = worker % self.shards.len();
        loop {
            if let Some(p) = self.try_pop(me) {
                return Some(p);
            }
            let shard = &self.shards[me];
            let st = shard.state.lock().unwrap();
            if !st.queue.is_empty() {
                continue; // refilled while we were sweeping siblings
            }
            if !st.open {
                drop(st);
                if self.all_drained() {
                    return None;
                }
                // Own shard is done but a sibling still holds work:
                // loop back into the steal sweep (each iteration either
                // pops an item or observes the system one step closer
                // to fully drained, so this cannot spin unboundedly).
                std::thread::yield_now();
                continue;
            }
            let (st, _) = shard.notify.wait_timeout(st, STEAL_POLL).unwrap();
            drop(st);
        }
    }

    /// Pop one item for `worker`, waiting at most until `deadline` (the
    /// batch-gather companion wait). During drain an empty own shard
    /// falls through one steal sweep and then returns `None` so partial
    /// batches flush immediately.
    pub fn pop_until(&self, worker: usize, deadline: Instant) -> Option<Popped<T>> {
        let me = worker % self.shards.len();
        loop {
            if let Some(p) = self.try_pop(me) {
                return Some(p);
            }
            let shard = &self.shards[me];
            let st = shard.state.lock().unwrap();
            if !st.queue.is_empty() {
                continue;
            }
            if !st.open {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let wait = (deadline - now).min(STEAL_POLL);
            let (st, _) = shard.notify.wait_timeout(st, wait).unwrap();
            drop(st);
        }
    }

    /// Close every shard to new submissions and wake every waiter.
    /// Idempotent.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        for shard in &self.shards {
            let mut st = shard.state.lock().unwrap();
            st.open = false;
            drop(st);
            shard.notify.notify_all();
        }
    }

    /// Empty every shard, handing each residual item to `f` (the
    /// drain-timeout load-shed answers them with a typed error). Returns
    /// how many were shed.
    pub fn drain_residual(&self, mut f: impl FnMut(T)) -> u64 {
        let mut shed = 0u64;
        for shard in &self.shards {
            let mut st = shard.state.lock().unwrap();
            while let Some(item) = st.queue.pop_front() {
                self.queued.fetch_sub(1, Ordering::SeqCst);
                shed += 1;
                f(item);
            }
            shard.depth.store(0, Ordering::Relaxed);
        }
        shed
    }
}

// ---------------------------------------------------------------------------
// SLO classes
// ---------------------------------------------------------------------------

/// Per-request service-level objective: a latency deadline and/or a
/// traffic budget in measured activation bits.
///
/// - `deadline` overrides the pool-wide [`BatchPolicy::deadline`] for
///   this request: still queued past it, the request is reaped at
///   gather time with [`ServeError::DeadlineExceeded`] and never
///   occupies a lane.
/// - `max_bits` is enforced through the measured `ExecTelemetry`
///   plumbing: a request whose budget is below the executor's modeled
///   per-image floor (`CostEstimate::act_bits`) is reaped *before*
///   execution with [`ServeError::TrafficBudgetExceeded`]; a served
///   request whose measured per-lane share exceeds its budget is
///   flagged on the reply (`Reply::budget_exceeded`) and counted in
///   `ServerMetrics::budget_violations`.
///
/// The default (no deadline, no budget) is best-effort and leaves the
/// pool's behavior reply-for-reply identical to the un-SLO'd path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SloClass {
    /// Per-request latency deadline, measured from submission.
    pub deadline: Option<Duration>,
    /// Measured activation-traffic budget for this request, in bits.
    pub max_bits: Option<u64>,
}

impl SloClass {
    /// No deadline, no budget (the default).
    pub fn best_effort() -> Self {
        Self::default()
    }

    /// A latency-only SLO.
    pub fn latency(deadline: Duration) -> Self {
        Self {
            deadline: Some(deadline),
            max_bits: None,
        }
    }

    /// A traffic-budget-only SLO.
    pub fn traffic_budget(max_bits: u64) -> Self {
        Self {
            deadline: None,
            max_bits: Some(max_bits),
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-model tenancy
// ---------------------------------------------------------------------------

/// One tenant model: an Arc-shared [`Engine`] plus the per-model
/// serving defaults. Build with [`ModelSpec::new`] and the builder
/// methods, then [`ModelRegistry::register`].
#[derive(Clone)]
pub struct ModelSpec {
    /// Routing id (`MultiModelHandle::submit` key). Must be unique in a
    /// registry.
    pub id: String,
    /// The engine replicated (cheap Arc clone) across the pool workers.
    pub engine: Engine,
    /// Executor batch size for this model's pool.
    pub batch: usize,
    /// Per-model batching/pool policy.
    pub policy: BatchPolicy,
    /// Fidelity for requests routed without an explicit class.
    pub default_fidelity: Fidelity,
    /// SLO for requests routed without an explicit class.
    pub default_slo: SloClass,
}

impl ModelSpec {
    /// A spec with batch 8, the default [`BatchPolicy`], fast fidelity,
    /// and a best-effort SLO.
    pub fn new(id: impl Into<String>, engine: Engine) -> Self {
        Self {
            id: id.into(),
            engine,
            batch: 8,
            policy: BatchPolicy::default(),
            default_fidelity: Fidelity::Fast,
            default_slo: SloClass::default(),
        }
    }

    /// Set the executor batch size.
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Set the per-model pool policy.
    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Set the default fidelity class.
    pub fn fidelity(mut self, fidelity: Fidelity) -> Self {
        self.default_fidelity = fidelity;
        self
    }

    /// Set the default SLO class.
    pub fn slo(mut self, slo: SloClass) -> Self {
        self.default_slo = slo;
        self
    }
}

/// The model catalog one server deployment hosts: validated specs,
/// unique ids. Consumed by
/// [`crate::runtime::PacExecutor::serve_registry`].
#[derive(Default)]
pub struct ModelRegistry {
    specs: Vec<ModelSpec>,
}

impl ModelRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a model, validating the spec: unique id, nonzero batch and
    /// workers, and a default fidelity the engine can actually run
    /// (`Accurate` on a PAC engine needs the exact fallback armed).
    pub fn register(mut self, spec: ModelSpec) -> Result<Self, PacimError> {
        if spec.id.is_empty() {
            return Err(PacimError::InvalidConfig("empty model id".into()));
        }
        if self.specs.iter().any(|s| s.id == spec.id) {
            return Err(PacimError::InvalidConfig(format!(
                "duplicate model id '{}' in registry",
                spec.id
            )));
        }
        if spec.batch == 0 {
            return Err(PacimError::InvalidConfig(format!(
                "model '{}': batch must be >= 1",
                spec.id
            )));
        }
        if !spec.engine.supports_fidelity(spec.default_fidelity) {
            return Err(PacimError::InvalidConfig(format!(
                "model '{}': default fidelity {:?} unsupported by its engine \
                 (Accurate on a PAC engine requires the exact fallback)",
                spec.id, spec.default_fidelity
            )));
        }
        self.specs.push(spec);
        Ok(self)
    }

    /// The registered specs, in registration order.
    pub fn specs(&self) -> &[ModelSpec] {
        &self.specs
    }

    /// Consume the registry (the serve-startup path).
    pub fn into_specs(self) -> Vec<ModelSpec> {
        self.specs
    }

    /// Registered model ids, in registration order.
    pub fn ids(&self) -> Vec<&str> {
        self.specs.iter().map(|s| s.id.as_str()).collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }
}

/// One running tenant: a started per-model pool plus its routing
/// defaults (assembled by `PacExecutor::serve_registry`, or manually
/// for custom executors).
pub struct Tenant {
    /// Routing id.
    pub id: String,
    /// The model's running pool (sharded ingress inside).
    pub server: InferenceServer,
    /// Fidelity for requests routed without an explicit class.
    pub default_fidelity: Fidelity,
    /// SLO for requests routed without an explicit class.
    pub default_slo: SloClass,
}

struct Route {
    id: String,
    handle: ServerHandle,
    fidelity: Fidelity,
    slo: SloClass,
}

/// N models behind one routing front door: each tenant runs its own
/// sharded pool (batches never mix models), requests fan out by model
/// id through a shared [`MultiModelHandle`].
pub struct MultiModelServer {
    tenants: Vec<Tenant>,
    routes: Arc<Vec<Route>>,
}

impl MultiModelServer {
    /// Assemble a multi-model server from started tenants. Fails on an
    /// empty list or duplicate ids.
    pub fn from_tenants(tenants: Vec<Tenant>) -> Result<Self, PacimError> {
        if tenants.is_empty() {
            return Err(PacimError::InvalidConfig(
                "multi-model server needs at least one tenant".into(),
            ));
        }
        for (i, t) in tenants.iter().enumerate() {
            if tenants[..i].iter().any(|p| p.id == t.id) {
                return Err(PacimError::InvalidConfig(format!(
                    "duplicate tenant id '{}'",
                    t.id
                )));
            }
        }
        let routes = Arc::new(
            tenants
                .iter()
                .map(|t| Route {
                    id: t.id.clone(),
                    handle: t.server.handle(),
                    fidelity: t.default_fidelity,
                    slo: t.default_slo,
                })
                .collect::<Vec<_>>(),
        );
        Ok(Self { tenants, routes })
    }

    /// A cloneable routing handle over every tenant.
    pub fn handle(&self) -> MultiModelHandle {
        MultiModelHandle {
            routes: Arc::clone(&self.routes),
        }
    }

    /// Hosted model ids, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.tenants.iter().map(|t| t.id.as_str()).collect()
    }

    /// Stop every tenant pool (graceful bounded drain each) and return
    /// the per-model metrics, in registration order.
    pub fn stop(self) -> Vec<(String, ServerMetrics)> {
        self.tenants
            .into_iter()
            .map(|t| (t.id, t.server.stop()))
            .collect()
    }
}

/// Cloneable submission handle over a [`MultiModelServer`]: routes by
/// model id, applying the tenant's default fidelity/SLO unless the
/// caller overrides them.
#[derive(Clone)]
pub struct MultiModelHandle {
    routes: Arc<Vec<Route>>,
}

impl MultiModelHandle {
    fn route(&self, model: &str) -> Result<&Route, ServeError> {
        self.routes
            .iter()
            .find(|r| r.id == model)
            .ok_or_else(|| ServeError::UnknownModel {
                model: model.to_string(),
            })
    }

    /// Hosted model ids.
    pub fn models(&self) -> Vec<&str> {
        self.routes.iter().map(|r| r.id.as_str()).collect()
    }

    /// Open-loop submission to `model` under its default fidelity/SLO.
    pub fn submit(&self, model: &str, input: Vec<f32>) -> Result<PendingReply, ServeError> {
        let r = self.route(model)?;
        r.handle.submit_slo(input, r.fidelity, r.slo)
    }

    /// Open-loop submission with explicit per-request classes.
    pub fn submit_slo(
        &self,
        model: &str,
        input: Vec<f32>,
        fidelity: Fidelity,
        slo: SloClass,
    ) -> Result<PendingReply, ServeError> {
        self.route(model)?.handle.submit_slo(input, fidelity, slo)
    }

    /// Closed-loop inference on `model` under its defaults.
    pub fn infer(&self, model: &str, input: Vec<f32>) -> Result<Reply, ServeError> {
        self.submit(model, input)?.wait()
    }

    /// Closed-loop inference with explicit per-request classes.
    pub fn infer_slo(
        &self,
        model: &str,
        input: Vec<f32>,
        fidelity: Fidelity,
        slo: SloClass,
    ) -> Result<Reply, ServeError> {
        self.submit_slo(model, input, fidelity, slo)?.wait()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2c_spills_to_the_shallower_shard() {
        // With no poppers, depths only grow: once one shard is strictly
        // deeper, the next submission must land on the other. After two
        // submissions both shards hold exactly one item.
        let ing: Ingress<u32> = Ingress::new(2, 64);
        ing.submit(1).unwrap();
        ing.submit(2).unwrap();
        assert_eq!(ing.depths().iter().sum::<usize>(), 2);
        assert_eq!(ing.depths(), vec![1, 1]);
    }

    #[test]
    fn capacity_bound_is_exact_across_shards() {
        let ing: Ingress<u32> = Ingress::new(3, 4);
        for i in 0..4 {
            assert_eq!(ing.submit(i).is_ok(), true, "submission {i} admitted");
        }
        for i in 4..6 {
            assert_eq!(ing.submit(i), Err(IngressError::Full { capacity: 4 }));
        }
        assert_eq!(ing.rejected(), 2);
        assert_eq!(ing.queued(), 4);
        // Popping frees a slot for exactly one more admission.
        assert!(ing.pop_blocking(0).is_some());
        assert!(ing.submit(9).is_ok());
        assert_eq!(ing.submit(10), Err(IngressError::Full { capacity: 4 }));
    }

    #[test]
    fn closed_wins_over_full() {
        let ing: Ingress<u32> = Ingress::new(2, 1);
        ing.submit(1).unwrap();
        ing.close();
        // Stopped-while-full must report Closed, not Full.
        assert_eq!(ing.submit(2), Err(IngressError::Closed));
    }

    #[test]
    fn single_popper_drains_and_steals_every_shard() {
        let ing: Ingress<u64> = Ingress::new(4, 1024);
        let n = 64u64;
        for i in 0..n {
            ing.submit(i).unwrap();
        }
        ing.close();
        let mut got = Vec::new();
        let mut stolen_seen = 0u64;
        while let Some(p) = ing.pop_blocking(0) {
            assert_eq!(p.stolen, p.shard != 0, "provenance is consistent");
            if p.stolen {
                stolen_seen += 1;
            }
            got.push(p.item);
        }
        got.sort_unstable();
        assert_eq!(got, (0..n).collect::<Vec<_>>(), "no loss, no dup");
        let sums = ing.shard_summaries();
        assert_eq!(sums.iter().map(|s| s.submitted).sum::<u64>(), n);
        // Everything on shards 1..3 was, by definition, stolen by worker 0.
        let foreign: u64 = sums.iter().skip(1).map(|s| s.submitted).sum();
        assert_eq!(stolen_seen, foreign);
        assert_eq!(sums.iter().map(|s| s.stolen).sum::<u64>(), foreign);
        assert!(foreign > 0, "P2C spread 64 items over 4 shards");
    }

    #[test]
    fn pop_until_deadline_returns_none_when_empty() {
        let ing: Ingress<u32> = Ingress::new(2, 8);
        let t0 = Instant::now();
        assert!(ing
            .pop_until(0, Instant::now() + Duration::from_millis(5))
            .is_none());
        assert!(t0.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn drain_residual_counts_and_delivers() {
        let ing: Ingress<u32> = Ingress::new(3, 64);
        for i in 0..10 {
            ing.submit(i).unwrap();
        }
        ing.close();
        let mut got = Vec::new();
        let shed = ing.drain_residual(|x| got.push(x));
        assert_eq!(shed, 10);
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert_eq!(ing.queued(), 0);
        assert!(ing.pop_blocking(0).is_none(), "closed and drained");
    }
}
