//! L3 coordination: the bank scheduler (analytic cycle/energy/traffic
//! accounting) and the multi-worker batch-serving pool.
//!
//! - [`scheduler`] — maps DNN layer shapes onto PACiM banks; powers the
//!   Fig. 7 / Table 3-4 system analyses, `examples/trace_sim.rs`, and the
//!   per-reply [`CostEstimate`] serving annotation.
//! - [`server`] — the worker pool + shared dynamic batcher with admission
//!   control; powers `pacim serve`, `examples/loadgen.rs`, and (with the
//!   `pjrt` feature) `examples/serve.rs`.

pub mod scheduler;
pub mod server;

pub use scheduler::{
    estimate_image_cost, model_shapes, schedule_layer, schedule_model, CostEstimate,
    LayerReport, ModelReport, ScheduleConfig,
};
pub use server::{
    BatchExecutor, BatchPolicy, ExecTelemetry, InferenceServer, PendingReply, Reply,
    ServeError, ServerHandle, ServerMetrics, WorkerSummary,
};
