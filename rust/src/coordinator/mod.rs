//! L3 coordination: the bank scheduler (analytic cycle/energy/traffic
//! accounting) and the threaded batch-serving loop.
//!
//! - [`scheduler`] — maps DNN layer shapes onto PACiM banks; powers the
//!   Fig. 7 / Table 3-4 system analyses and `examples/trace_sim.rs`.
//! - [`server`] — the request loop + dynamic batcher in front of a
//!   PJRT executable; powers `examples/serve.rs`.

pub mod scheduler;
pub mod server;

pub use scheduler::{
    schedule_layer, schedule_model, LayerReport, ModelReport, ScheduleConfig,
};
pub use server::{BatchExecutor, BatchPolicy, InferenceServer, Reply, ServerHandle, ServerMetrics};
