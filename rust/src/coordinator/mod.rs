//! L3 coordination: the bank scheduler (analytic cycle/energy/traffic
//! accounting), the sharded work-stealing ingress, and the multi-worker
//! batch-serving pool.
//!
//! - [`scheduler`] — maps DNN layer shapes onto PACiM banks; powers the
//!   Fig. 7 / Table 3-4 system analyses, `examples/trace_sim.rs`, and the
//!   per-reply [`CostEstimate`] serving annotation.
//! - [`ingress`] — per-worker sharded request queues with
//!   power-of-two-choices placement and work stealing (no global lock on
//!   the submit path), per-request [`SloClass`]es, and the multi-model
//!   tenancy layer ([`ModelRegistry`], [`MultiModelServer`]).
//! - [`server`] — the worker pool on top of the sharded ingress; powers
//!   `pacim serve`, `examples/loadgen.rs`, and (with the `pjrt` feature)
//!   `examples/serve.rs`.

pub mod ingress;
pub mod scheduler;
pub mod server;

pub use ingress::{
    Ingress, IngressError, ModelRegistry, ModelSpec, MultiModelHandle, MultiModelServer,
    Popped, ShardSummary, SloClass, Tenant,
};
pub use scheduler::{
    estimate_image_cost, model_shapes, schedule_layer, schedule_model, CostEstimate,
    LayerReport, ModelReport, ScheduleConfig,
};
pub use server::{
    BatchExecutor, BatchPolicy, ExecTelemetry, InferenceServer, PendingReply, Reply,
    ServeError, ServerHandle, ServerMetrics, WorkerSummary,
};
