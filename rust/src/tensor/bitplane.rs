//! Layer-level bit-plane packing: the im2col patch matrix transposed
//! into contiguous per-pixel planes, **once per layer**.
//!
//! The pre-blocked engine decomposed every im2col patch independently
//! (`pac::sparsity::BitPlanes::from_u8` per output pixel), paying eight
//! heap allocations and a scattered plane layout per pixel. Packing the
//! whole `[pixels][k]` matrix into one `[pixel][p][word]` slab fuses the
//! lowering with the bit-plane transposition: one pass over the layer's
//! activations produces every plane word, every per-pixel sparsity count
//! `S_x[p]`, and (via the `Σv = Σ_p 2^p·S[p]` identity) every element
//! sum the zero-point correction needs — no LSB re-reads, no per-pixel
//! allocation. The slab is reusable scratch: steady-state inference
//! packs every layer of every image into the same buffers.

use crate::util::{words_for, Parallelism};
use rayon::prelude::*;

/// Pixels per packing tile when the fan-out is parallel (disjoint slab
/// ranges per tile, so the parallel pack is bit-identical to scalar).
const PACK_TILE: usize = 32;

/// A layer's activation matrix as packed bit-planes plus per-pixel
/// sparsity metadata. Reusable: [`PackedPatches::pack`] grows the
/// buffers on first use and overwrites them thereafter.
///
/// # Slab layout
///
/// One contiguous `Vec<u64>` holds every plane of every pixel, pixel-
/// major then plane-major (`words = ⌈k/64⌉` u64s per plane):
///
/// ```text
/// planes: [ pixel 0: p0[w0..w] p1[w0..w] … p7[w0..w] | pixel 1: … ]
///           └──────────────── 8·words ─────────────┘
/// word w of plane p of pixel pix  =  planes[(pix*8 + p)*words + w]
/// ```
///
/// Bit order matches `pac::sparsity::BitPlanes::from_u8`: patch element
/// `i` lands in bit `i % 64` of word `i / 64`, so an AND-popcount of an
/// activation plane word against the equally-packed weight plane word
/// is one digital bank cycle over 64 DP lanes. The trailing bits of the
/// last word (past `k`) are always zero — kernels may popcount whole
/// words without masking. This is the word layout `nn::simd` sweeps and
/// the unit the weight zero-word skip bitmaps (DESIGN.md §13) index.
///
/// # Sparsity metadata (the S_x side of Eq. 3)
///
/// Packing fuses the counter extraction with the transposition: `pop`
/// holds each pixel's per-plane set-bit counts `S_x[0..8]` (what the PCU
/// consumes), and `sums` the raw element sums reconstructed via the
/// `Σv = Σ_p 2^p·S_x[p]` identity (what the zero-point correction
/// consumes) — so the MACs' sparsity half never re-reads LSB planes.
#[derive(Debug, Clone, Default)]
pub struct PackedPatches {
    pixels: usize,
    /// Elements per patch (the DP length the planes were packed from).
    k: usize,
    /// `u64` words per plane: `⌈k/64⌉` (`util::words_for`).
    words: usize,
    /// `[pixel][p][word]` plane slab, `8 * words` words per pixel.
    planes: Vec<u64>,
    /// `pop[pix][p]` = S_x[p] of pixel `pix`'s patch.
    pop: Vec<[u32; 8]>,
    /// Per-pixel raw element sums (`Σ_p 2^p·S[p]`, Eq. 5 / zero-point).
    sums: Vec<i64>,
}

/// Pack one patch into `planes` (exactly `8 * words` words, all written)
/// and return its per-plane popcounts. Same block decomposition as
/// `BitPlanes::from_u8`, minus the allocations.
fn pack_patch(patch: &[u8], words: usize, planes: &mut [u64]) -> [u32; 8] {
    debug_assert_eq!(planes.len(), 8 * words);
    let mut pop = [0u32; 8];
    for (w, chunk) in patch.chunks(64).enumerate() {
        let mut acc = [0u64; 8];
        for (b, &x) in chunk.iter().enumerate() {
            let x = x as u64;
            acc[0] |= (x & 1) << b;
            acc[1] |= ((x >> 1) & 1) << b;
            acc[2] |= ((x >> 2) & 1) << b;
            acc[3] |= ((x >> 3) & 1) << b;
            acc[4] |= ((x >> 4) & 1) << b;
            acc[5] |= ((x >> 5) & 1) << b;
            acc[6] |= ((x >> 6) & 1) << b;
            acc[7] |= ((x >> 7) & 1) << b;
        }
        for p in 0..8 {
            planes[p * words + w] = acc[p];
            pop[p] += acc[p].count_ones();
        }
    }
    pop
}

impl PackedPatches {
    /// Pack the `[pixels][k]` matrix `cols`. Tiles of `PACK_TILE`
    /// pixels fan out over rayon when `par` allows (each tile writes a
    /// disjoint slab range — deterministic for any schedule).
    pub fn pack(&mut self, cols: &[u8], k: usize, pixels: usize, par: &Parallelism) {
        assert_eq!(cols.len(), pixels * k, "im2col matrix shape mismatch");
        let words = words_for(k);
        self.pixels = pixels;
        self.k = k;
        self.words = words;
        // Every slab word is overwritten below, so stale contents from a
        // previous (larger) layer are harmless; resize only zero-fills
        // growth.
        self.planes.resize(pixels * 8 * words, 0);
        self.pop.resize(pixels, [0; 8]);
        self.sums.resize(pixels, 0);
        if pixels == 0 {
            return;
        }
        if words == 0 {
            // k = 0 (empty DP): no planes; counts and sums are all zero.
            self.pop.fill([0; 8]);
            self.sums.fill(0);
            return;
        }
        let pstride = 8 * words;
        let pack_tile = |t: usize, planes: &mut [u64], pop: &mut [[u32; 8]], sums: &mut [i64]| {
            let base = t * PACK_TILE;
            for (j, pl) in planes.chunks_exact_mut(pstride).enumerate() {
                let pix = base + j;
                let p = pack_patch(&cols[pix * k..(pix + 1) * k], words, pl);
                pop[j] = p;
                sums[j] = (0..8).map(|b| (p[b] as i64) << b).sum();
            }
        };
        let tiles = pixels.div_ceil(PACK_TILE);
        if par.should_parallelize_tiles(tiles, pixels) {
            self.planes
                .par_chunks_mut(PACK_TILE * pstride)
                .zip(self.pop.par_chunks_mut(PACK_TILE))
                .zip(self.sums.par_chunks_mut(PACK_TILE))
                .enumerate()
                .for_each(|(t, ((planes, pop), sums))| pack_tile(t, planes, pop, sums));
        } else {
            for t in 0..tiles {
                let lo = t * PACK_TILE;
                let hi = (lo + PACK_TILE).min(pixels);
                pack_tile(
                    t,
                    &mut self.planes[lo * pstride..hi * pstride],
                    &mut self.pop[lo..hi],
                    &mut self.sums[lo..hi],
                );
            }
        }
    }

    /// Number of packed pixels (patch rows).
    pub fn pixels(&self) -> usize {
        self.pixels
    }

    /// Elements per patch (DP length) of the last pack.
    pub fn k(&self) -> usize {
        self.k
    }

    /// `u64` words per plane.
    pub fn words(&self) -> usize {
        self.words
    }

    /// The raw plane slab, `[pixel][p][word]`; pixel `pix`'s plane `p`
    /// occupies `pix * 8 * words + p * words ..` for `words` words.
    pub fn planes(&self) -> &[u64] {
        &self.planes
    }

    /// Mutable raw plane slab — fault-injection hook (`pacim::fault`)
    /// flips transmitted plane bits in place. Layout as
    /// [`Self::planes`]. The sparsity counters are intentionally *not*
    /// recomputed: the encoded edge carries planes and counters as
    /// separate payloads, so a corrupted plane word must not repair
    /// the counters it shipped with.
    pub(crate) fn planes_mut(&mut self) -> &mut [u64] {
        &mut self.planes
    }

    /// Plane `p` of pixel `pix`.
    pub fn plane(&self, pix: usize, p: usize) -> &[u64] {
        let base = (pix * 8 + p) * self.words;
        &self.planes[base..base + self.words]
    }

    /// Sparsity counts `S_x[0..8]` of pixel `pix`.
    pub fn pop(&self, pix: usize) -> &[u32; 8] {
        &self.pop[pix]
    }

    /// Raw element sum of pixel `pix`'s patch (reconstructed from the
    /// sparsity counts — LSB bits are never re-read).
    pub fn element_sum(&self, pix: usize) -> i64 {
        self.sums[pix]
    }

    /// Gather element `i` of pixel `pix` back out of the plane domain —
    /// the encoded skip slot's point read. The residual add consumes
    /// its saved operand one element at a time from the packed planes
    /// (no dense u8 copy ever exists), so this reassembles the byte
    /// from bit `i % 64` of word `i / 64` across all 8 planes. Reads
    /// the slab as transmitted: fault-injected plane flips are visible
    /// here, exactly like on a consumer-side unpack.
    pub fn value(&self, pix: usize, i: usize) -> u8 {
        debug_assert!(pix < self.pixels && i < self.k);
        let (w, b) = (i / 64, i % 64);
        let base = pix * 8 * self.words + w;
        let mut v = 0u8;
        for p in 0..8 {
            v |= (((self.planes[base + p * self.words] >> b) & 1) as u8) << p;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_cols(rng: &mut Rng, pixels: usize, k: usize) -> Vec<u8> {
        (0..pixels * k).map(|_| rng.below(256) as u8).collect()
    }

    #[test]
    fn matches_per_patch_bitplanes() {
        use crate::pac::sparsity::BitPlanes;
        let mut rng = Rng::new(42);
        for (pixels, k) in [(1usize, 64usize), (7, 27), (40, 130), (3, 1)] {
            let cols = random_cols(&mut rng, pixels, k);
            let mut packed = PackedPatches::default();
            packed.pack(&cols, k, pixels, &Parallelism::off());
            assert_eq!(packed.pixels(), pixels);
            assert_eq!(packed.k(), k);
            assert_eq!(packed.words(), crate::util::words_for(k));
            for pix in 0..pixels {
                let bp = BitPlanes::from_u8(&cols[pix * k..(pix + 1) * k]);
                assert_eq!(packed.pop(pix), &bp.pop, "pix {pix}");
                assert_eq!(packed.element_sum(pix), bp.element_sum() as i64);
                for p in 0..8 {
                    assert_eq!(packed.plane(pix, p), &bp.planes[p][..], "pix {pix} p {p}");
                }
            }
        }
    }

    #[test]
    fn parallel_pack_bit_identical() {
        let mut rng = Rng::new(43);
        let (pixels, k) = (101, 90);
        let cols = random_cols(&mut rng, pixels, k);
        let mut scalar = PackedPatches::default();
        scalar.pack(&cols, k, pixels, &Parallelism::off());
        let mut par = PackedPatches::default();
        par.pack(
            &cols,
            k,
            pixels,
            &Parallelism {
                enabled: true,
                min_items: 1,
            },
        );
        assert_eq!(scalar.planes(), par.planes());
        for pix in 0..pixels {
            assert_eq!(scalar.pop(pix), par.pop(pix));
            assert_eq!(scalar.element_sum(pix), par.element_sum(pix));
        }
    }

    #[test]
    fn reuse_shrinks_and_overwrites() {
        // Pack a big layer, then a smaller one into the same scratch: no
        // stale state may leak.
        let mut rng = Rng::new(44);
        let big = random_cols(&mut rng, 50, 200);
        let small = random_cols(&mut rng, 4, 9);
        let mut reused = PackedPatches::default();
        reused.pack(&big, 200, 50, &Parallelism::off());
        reused.pack(&small, 9, 4, &Parallelism::off());
        let mut fresh = PackedPatches::default();
        fresh.pack(&small, 9, 4, &Parallelism::off());
        assert_eq!(reused.planes(), fresh.planes());
        assert_eq!(reused.pixels(), 4);
        for pix in 0..4 {
            assert_eq!(reused.pop(pix), fresh.pop(pix));
            assert_eq!(reused.element_sum(pix), fresh.element_sum(pix));
        }
    }

    #[test]
    fn value_gathers_every_element_back() {
        // The plane-domain point read must reproduce the packed bytes
        // exactly, including across word boundaries and ragged tails.
        let mut rng = Rng::new(45);
        for (pixels, k) in [(1usize, 1usize), (5, 64), (9, 65), (16, 130)] {
            let cols = random_cols(&mut rng, pixels, k);
            let mut packed = PackedPatches::default();
            packed.pack(&cols, k, pixels, &Parallelism::off());
            for pix in 0..pixels {
                for i in 0..k {
                    assert_eq!(packed.value(pix, i), cols[pix * k + i], "pix {pix} i {i}");
                }
            }
        }
    }

    #[test]
    fn empty_dp_and_empty_layer() {
        let mut packed = PackedPatches::default();
        packed.pack(&[], 0, 3, &Parallelism::off());
        assert_eq!(packed.pixels(), 3);
        assert_eq!(packed.words(), 0);
        assert_eq!(packed.pop(2), &[0; 8]);
        assert_eq!(packed.element_sum(0), 0);
        packed.pack(&[], 5, 0, &Parallelism::off());
        assert_eq!(packed.pixels(), 0);
        assert!(packed.planes().is_empty());
    }
}
