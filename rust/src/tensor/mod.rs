//! Dense tensors and the im2col lowering used by the bit-true NN engine.
//!
//! The simulator works almost exclusively on `u8` (quantized activations /
//! weights) and `i32` (accumulators), in NCHW layout, so `Tensor<T>` is a
//! deliberately simple owned, contiguous, row-major container — no views,
//! no broadcasting. Anything fancier belongs to the JAX layer.

pub mod bitplane;
pub mod im2col;

pub use bitplane::PackedPatches;
pub use im2col::{col2im_shape, im2col, im2col_into, im2col_scatter_into, Conv2dGeom};

/// Owned, contiguous, row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-initialized (T::default) tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let numel = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![T::default(); numel],
        }
    }

    /// Build from existing data; `data.len()` must equal the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with data length {}",
            shape,
            data.len()
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Reshape in place (same number of elements).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?} changes element count",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Row-major linear offset of a multi-index.
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bounds for dim {i} ({dim})");
            off = off * dim + ix;
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: T) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    /// Map elementwise into a new tensor (possibly different type).
    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }
}

impl Tensor<f32> {
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

/// Quantization parameters for an affine uint8 tensor:
/// `real = scale * (q - zero_point)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QuantParams {
    pub fn new(scale: f32, zero_point: i32) -> Self {
        assert!(scale > 0.0, "scale must be positive");
        assert!((0..=255).contains(&zero_point), "uint8 zero point");
        Self { scale, zero_point }
    }

    #[inline]
    pub fn quantize(&self, x: f32) -> u8 {
        // Clamp in float space first: huge |x| would overflow the i32 cast.
        let q = (x / self.scale).round() + self.zero_point as f32;
        q.clamp(0.0, 255.0) as u8
    }

    #[inline]
    pub fn dequantize(&self, q: u8) -> f32 {
        self.scale * (q as i32 - self.zero_point) as f32
    }
}

/// A quantized uint8 tensor with its affine parameters — the currency of
/// the whole simulator (both the exact engine and the PAC engine consume
/// `QTensor`s).
#[derive(Debug, Clone)]
pub struct QTensor {
    pub tensor: Tensor<u8>,
    pub params: QuantParams,
}

impl QTensor {
    pub fn new(tensor: Tensor<u8>, params: QuantParams) -> Self {
        Self { tensor, params }
    }

    pub fn shape(&self) -> &[usize] {
        self.tensor.shape()
    }

    pub fn data(&self) -> &[u8] {
        self.tensor.data()
    }

    /// Dequantize the whole tensor to f32.
    pub fn dequantize(&self) -> Tensor<f32> {
        let p = self.params;
        self.tensor.map(|q| p.dequantize(q))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t: Tensor<i32> = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert!(t.data().iter().all(|&x| x == 0));
    }

    #[test]
    fn offset_row_major() {
        let t: Tensor<u8> = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
        assert_eq!(t.offset(&[0, 0, 3]), 3);
        assert_eq!(t.offset(&[0, 1, 0]), 4);
        assert_eq!(t.offset(&[1, 0, 0]), 12);
        assert_eq!(t.offset(&[1, 2, 3]), 23);
    }

    #[test]
    fn set_get() {
        let mut t: Tensor<i32> = Tensor::zeros(&[3, 3]);
        t.set(&[1, 2], 42);
        assert_eq!(t.at(&[1, 2]), 42);
        assert_eq!(t.at(&[2, 1]), 0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1u8, 2, 3]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], vec![1u8, 2, 3, 4, 5, 6]);
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.at(&[2, 1]), 6);
    }

    #[test]
    fn quant_roundtrip_within_half_ulp() {
        let p = QuantParams::new(0.1, 128);
        for x in [-12.0f32, -0.05, 0.0, 0.049, 3.3, 12.69] {
            let q = p.quantize(x);
            let back = p.dequantize(q);
            assert!((back - x).abs() <= 0.05 + 1e-6, "x={x} back={back}");
        }
    }

    #[test]
    fn quant_saturates() {
        let p = QuantParams::new(0.1, 128);
        assert_eq!(p.quantize(1e9), 255);
        assert_eq!(p.quantize(-1e9), 0);
    }

    #[test]
    fn map_changes_type() {
        let t = Tensor::from_vec(&[2, 2], vec![1u8, 2, 3, 4]);
        let f = t.map(|x| x as f32 * 0.5);
        assert_eq!(f.at(&[1, 1]), 2.0);
    }
}
