//! im2col lowering: convolution → matrix multiply.
//!
//! Both the exact uint8 engine and the PAC engine consume convolutions as
//! GEMMs whose K dimension *is* the CiM dot-product (DP) length
//! (`K = kh·kw·C_in`), matching how PACiM maps CONV kernels onto
//! multi-bit weight columns (§4.3 of the paper). Padding inserts the
//! activation **zero point** (not numeric 0) so the affine quantization
//! algebra stays exact.

/// Static geometry of a 2-D convolution (NCHW, OIHW).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Conv2dGeom {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dGeom {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// The dot-product length seen by a CiM column for this layer.
    pub fn dp_len(&self) -> usize {
        self.kh * self.kw * self.in_c
    }

    /// Number of output pixels per image.
    pub fn out_pixels(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// Total MACs per image.
    pub fn macs(&self) -> u64 {
        (self.out_c * self.out_pixels()) as u64 * self.dp_len() as u64
    }
}

/// Lower one image (CHW, `u8`) into a `[out_pixels, dp_len]` patch matrix.
///
/// `pad_value` must be the activation zero point.
/// Row layout: patch for output pixel (oh, ow); column layout: (c, kh, kw)
/// — the same ordering `weights.reshape(out_c, dp_len)` produces from OIHW.
pub fn im2col(input: &[u8], g: &Conv2dGeom, pad_value: u8) -> Vec<u8> {
    let mut out = Vec::new();
    im2col_into(input, g, pad_value, &mut out);
    out
}

/// [`im2col`] into a caller-owned buffer (cleared and refilled) — the
/// engines thread one buffer through every layer of a run so the
/// steady-state lowering allocates nothing.
pub fn im2col_into(input: &[u8], g: &Conv2dGeom, pad_value: u8, out: &mut Vec<u8>) {
    assert_eq!(input.len(), g.in_c * g.in_h * g.in_w);
    let (oh, ow, k) = (g.out_h(), g.out_w(), g.dp_len());
    // clear + resize pad-fills every element while keeping capacity.
    out.clear();
    out.resize(oh * ow * k, pad_value);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * k;
            for c in 0..g.in_c {
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    if iy < 0 || iy >= g.in_h as isize {
                        continue; // row stays pad_value
                    }
                    let in_row = (c * g.in_h + iy as usize) * g.in_w;
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        if ix < 0 || ix >= g.in_w as isize {
                            continue;
                        }
                        out[row + (c * g.kh + ky) * g.kw + kx] = input[in_row + ix as usize];
                    }
                }
            }
        }
    }
}

/// Shape of the im2col output for `g`: (rows = out pixels, cols = DP len).
pub fn col2im_shape(g: &Conv2dGeom) -> (usize, usize) {
    (g.out_pixels(), g.dp_len())
}

/// Inverse im2col ("col scatter"): build `g`'s `[out_pixels, dp_len]`
/// patch matrix directly from a value *producer* instead of a dense CHW
/// tensor. `value(c, pix)` is called exactly once per input position
/// (`pix = y·in_w + x`) and the returned byte is written into every
/// patch slot that references that position; padding slots are filled
/// with `pad_value` (the activation zero point).
///
/// This is the sparsity-encoded dataplane's producer-side lowering: the
/// previous layer requantizes each output element once, hands it here,
/// and no dense u8 activation tensor ever materializes between layers.
/// For any dense `input`, `im2col_scatter_into(g, zp, out, |c, pix|
/// input[c * hw + pix])` produces byte-for-byte the same matrix as
/// [`im2col_into`] (property-tested below).
pub fn im2col_scatter_into(
    g: &Conv2dGeom,
    pad_value: u8,
    out: &mut Vec<u8>,
    mut value: impl FnMut(usize, usize) -> u8,
) {
    let (oh, ow, k) = (g.out_h(), g.out_w(), g.dp_len());
    // clear + resize pad-fills every element while keeping capacity.
    out.clear();
    out.resize(oh * ow * k, pad_value);
    for c in 0..g.in_c {
        for y in 0..g.in_h {
            for x in 0..g.in_w {
                let v = value(c, y * g.in_w + x);
                // Output pixels (oy, ox) whose patch reads (c, y, x):
                // oy·stride + ky − pad = y, per kernel row/col in range.
                for ky in 0..g.kh {
                    let ty = y + g.pad;
                    if ty < ky || (ty - ky) % g.stride != 0 {
                        continue;
                    }
                    let oy = (ty - ky) / g.stride;
                    if oy >= oh {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let tx = x + g.pad;
                        if tx < kx || (tx - kx) % g.stride != 0 {
                            continue;
                        }
                        let ox = (tx - kx) / g.stride;
                        if ox >= ow {
                            continue;
                        }
                        out[(oy * ow + ox) * k + (c * g.kh + ky) * g.kw + kx] = v;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_conv(
        input: &[u8],
        weight: &[i32],
        g: &Conv2dGeom,
        x_zp: i32,
    ) -> Vec<i64> {
        // Direct NCHW convolution in i64 over (x - zp is NOT applied here;
        // we convolve raw with zp padding to compare against im2col+GEMM).
        let (oh, ow) = (g.out_h(), g.out_w());
        let mut out = vec![0i64; g.out_c * oh * ow];
        for oc in 0..g.out_c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for c in 0..g.in_c {
                        for ky in 0..g.kh {
                            for kx in 0..g.kw {
                                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                                let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                                let x = if iy < 0
                                    || ix < 0
                                    || iy >= g.in_h as isize
                                    || ix >= g.in_w as isize
                                {
                                    x_zp
                                } else {
                                    input[(c * g.in_h + iy as usize) * g.in_w + ix as usize]
                                        as i32
                                };
                                let w = weight
                                    [((oc * g.in_c + c) * g.kh + ky) * g.kw + kx];
                                acc += (x as i64) * (w as i64);
                            }
                        }
                    }
                    out[(oc * oh + oy) * ow + ox] = acc;
                }
            }
        }
        out
    }

    #[test]
    fn geometry() {
        let g = Conv2dGeom {
            in_c: 3,
            in_h: 32,
            in_w: 32,
            out_c: 16,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
        };
        assert_eq!(g.out_h(), 32);
        assert_eq!(g.out_w(), 32);
        assert_eq!(g.dp_len(), 27);
        assert_eq!(g.macs(), (16 * 32 * 32 * 27) as u64);
    }

    #[test]
    fn strided_geometry() {
        let g = Conv2dGeom {
            in_c: 16,
            in_h: 32,
            in_w: 32,
            out_c: 32,
            kh: 3,
            kw: 3,
            stride: 2,
            pad: 1,
        };
        assert_eq!(g.out_h(), 16);
        assert_eq!(g.out_w(), 16);
    }

    #[test]
    fn im2col_gemm_matches_naive_conv() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(2024);
        for &(stride, pad) in &[(1usize, 0usize), (1, 1), (2, 1)] {
            let g = Conv2dGeom {
                in_c: 3,
                in_h: 8,
                in_w: 8,
                out_c: 4,
                kh: 3,
                kw: 3,
                stride,
                pad,
            };
            let input: Vec<u8> = (0..g.in_c * g.in_h * g.in_w)
                .map(|_| rng.below(256) as u8)
                .collect();
            let weight: Vec<i32> = (0..g.out_c * g.dp_len())
                .map(|_| rng.range_i64(-128, 127) as i32)
                .collect();
            let zp = 7u8;
            let cols = im2col(&input, &g, zp);
            let (rows, k) = col2im_shape(&g);
            // GEMM: out[oc][pix] = Σ_k w[oc][k] * cols[pix][k]
            let mut gemm = vec![0i64; g.out_c * rows];
            for oc in 0..g.out_c {
                for r in 0..rows {
                    let mut acc = 0i64;
                    for kk in 0..k {
                        acc += weight[oc * k + kk] as i64 * cols[r * k + kk] as i64;
                    }
                    gemm[oc * rows + r] = acc;
                }
            }
            let naive = naive_conv(&input, &weight, &g, zp as i32);
            assert_eq!(gemm, naive, "stride={stride} pad={pad}");
        }
    }

    #[test]
    fn into_reuses_buffer_without_stale_pads() {
        // A buffer warm from a layer with a *different* pad value must be
        // fully re-padded, not left with stale bytes.
        let g = Conv2dGeom {
            in_c: 1,
            in_h: 2,
            in_w: 2,
            out_c: 1,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
        };
        let input = [10u8, 20, 30, 40];
        let mut buf = Vec::new();
        im2col_into(&input, &g, 99, &mut buf);
        let fresh = im2col(&input, &g, 7);
        im2col_into(&input, &g, 7, &mut buf);
        assert_eq!(buf, fresh);
    }

    #[test]
    fn scatter_matches_gather_on_random_geometries() {
        // The producer-side scatter must reproduce the consumer-side
        // gather byte for byte, for every geometry the engines run.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(4096);
        for _ in 0..40 {
            let g = Conv2dGeom {
                in_c: 1 + rng.below(4) as usize,
                in_h: 3 + rng.below(8) as usize,
                in_w: 3 + rng.below(8) as usize,
                out_c: 1,
                kh: 1 + rng.below(3) as usize,
                kw: 1 + rng.below(3) as usize,
                stride: 1 + rng.below(2) as usize,
                pad: rng.below(2) as usize,
            };
            let hw = g.in_h * g.in_w;
            let input: Vec<u8> = (0..g.in_c * hw).map(|_| rng.below(256) as u8).collect();
            let zp = rng.below(256) as u8;
            let gathered = im2col(&input, &g, zp);
            // Warm buffer with different contents: must be fully rewritten.
            let mut scattered = vec![0xAAu8; 7];
            let mut calls = 0usize;
            im2col_scatter_into(&g, zp, &mut scattered, |c, pix| {
                calls += 1;
                input[c * hw + pix]
            });
            assert_eq!(scattered, gathered, "geom {g:?}");
            // The producer requantizes each element exactly once.
            assert_eq!(calls, g.in_c * hw);
        }
    }

    #[test]
    fn padding_uses_zero_point() {
        let g = Conv2dGeom {
            in_c: 1,
            in_h: 2,
            in_w: 2,
            out_c: 1,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
        };
        let input = [10u8, 20, 30, 40];
        let cols = im2col(&input, &g, 99);
        // Output pixel (0,0): top-left patch has 5 padded positions.
        let first_patch = &cols[0..9];
        assert_eq!(first_patch.iter().filter(|&&v| v == 99).count(), 5);
        assert!(first_patch.contains(&10));
    }
}
