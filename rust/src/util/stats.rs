//! Streaming statistics used by the error-analysis experiments.
//!
//! The Fig. 3 Monte-Carlo sweeps run up to 100K iterations per
//! configuration; `Accumulator` keeps O(1) state via Welford's algorithm so
//! we never materialize the sample vectors. `Histogram` backs the
//! Fig. 3(b) MAC-distribution plot.

/// Welford online mean/variance accumulator with extras for RMSE.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    sum_sq: f64, // Σ x² — for RMSE of an error stream
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.sum_sq += x * x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Root-mean-square of the pushed values — when the stream is an error
    /// stream `(approx - exact)`, this is the RMSE.
    pub fn rms(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.sum_sq / self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Accumulator) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bin integer histogram, used for the Fig. 3(b) MAC distribution.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: i64,
    hi: i64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    /// One bin per integer in `[lo, hi]`.
    pub fn new(lo: i64, hi: i64) -> Self {
        assert!(hi >= lo);
        Self {
            lo,
            hi,
            bins: vec![0; (hi - lo + 1) as usize],
            underflow: 0,
            overflow: 0,
        }
    }

    #[inline]
    pub fn push(&mut self, x: i64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x > self.hi {
            self.overflow += 1;
        } else {
            self.bins[(x - self.lo) as usize] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bin(&self, x: i64) -> u64 {
        if x < self.lo || x > self.hi {
            0
        } else {
            self.bins[(x - self.lo) as usize]
        }
    }

    /// (value, count) pairs for non-empty bins.
    pub fn nonzero(&self) -> Vec<(i64, u64)> {
        self.bins
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (self.lo + i as i64, c))
            .collect()
    }

    /// Fraction of samples within `±w` of `center`.
    pub fn mass_within(&self, center: i64, w: i64) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let mut acc = 0u64;
        for x in (center - w)..=(center + w) {
            acc += self.bin(x);
        }
        acc as f64 / total as f64
    }

    /// Render a compact ASCII sparkline of the distribution (for bench
    /// output). Bins are grouped into `width` columns.
    pub fn sparkline(&self, width: usize) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let n = self.bins.len();
        if n == 0 || width == 0 {
            return String::new();
        }
        let per = (n + width - 1) / width;
        let grouped: Vec<u64> = self
            .bins
            .chunks(per)
            .map(|c| c.iter().sum::<u64>())
            .collect();
        let max = *grouped.iter().max().unwrap_or(&1);
        if max == 0 {
            return GLYPHS[0].to_string().repeat(grouped.len());
        }
        grouped
            .iter()
            .map(|&c| GLYPHS[((c * 7) / max) as usize])
            .collect()
    }
}

/// Percentile over a mutable sample buffer (nearest-rank): sorts, then
/// delegates to [`percentile_sorted`].
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(samples, p)
}

/// Percentile over an **ascending-sorted** sample buffer (nearest-rank);
/// `0.0` on an empty buffer. The serving metrics sort their latency
/// reservoir once at `stop()` and answer every percentile query through
/// this read-only path.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input not sorted");
    if sorted.is_empty() {
        return 0.0;
    }
    // Nearest-rank: the ⌈p/100·N⌉-th smallest sample (1-indexed).
    let rank = ((p / 100.0) * sorted.len() as f64).ceil().max(1.0) as usize;
    sorted[rank - 1]
}

/// RMSE between two equal-length slices.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum();
    (s / a.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((acc.mean() - mean).abs() < 1e-12);
        assert!((acc.variance() - var).abs() < 1e-12);
        assert_eq!(acc.min(), 1.0);
        assert_eq!(acc.max(), 10.0);
        assert_eq!(acc.count(), 5);
    }

    #[test]
    fn accumulator_rms_error_stream() {
        let mut acc = Accumulator::new();
        acc.push(3.0);
        acc.push(-4.0);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        assert!((acc.rms() - 12.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn accumulator_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Accumulator::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Accumulator::new();
        let mut b = Accumulator::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
        assert!((a.rms() - whole.rms()).abs() < 1e-10);
    }

    #[test]
    fn histogram_basic() {
        let mut h = Histogram::new(-5, 5);
        for x in [-6, -5, 0, 0, 0, 5, 6, 7] {
            h.push(x);
        }
        assert_eq!(h.total(), 8);
        assert_eq!(h.bin(0), 3);
        assert_eq!(h.bin(-5), 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
        assert!((h.mass_within(0, 0) - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut s: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile(&mut s, 50.0), 50.0);
        assert_eq!(percentile(&mut s, 0.0), 1.0);
        assert_eq!(percentile(&mut s, 100.0), 100.0);
    }

    #[test]
    fn percentile_sorted_matches_sorting_path() {
        let mut unsorted = vec![9.0, 1.0, 5.0, 3.0, 7.0];
        let mut sorted = unsorted.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for p in [0.0, 10.0, 50.0, 90.0, 100.0] {
            assert_eq!(percentile(&mut unsorted, p), percentile_sorted(&sorted, p));
        }
        // Empty reservoir: a defined zero, not an abort.
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }

    #[test]
    fn rmse_direct() {
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - 12.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn sparkline_renders() {
        let mut h = Histogram::new(0, 15);
        for i in 0..16 {
            for _ in 0..i {
                h.push(i);
            }
        }
        let s = h.sparkline(8);
        assert_eq!(s.chars().count(), 8);
    }
}
