//! Parallel-execution policy shared by the NN engines and the PAC batch
//! kernels.
//!
//! The per-output-activation work of a PACiM layer (one `hybrid_mac` per
//! DP column) is embarrassingly parallel, so the engines fan it out over
//! rayon's work-stealing pool. Every parallel path in this crate is
//! **bit-deterministic**: items are mapped independently and collected in
//! index order, and all merged statistics are integer counters, so the
//! result never depends on thread count or scheduling.
//!
//! `Parallelism` is the knob threaded through the engines: it gates
//! whether a loop fans out at all and below which size it stays scalar
//! (small layers lose more to fork/join overhead than they gain).

use rayon::prelude::*;

/// Parallel-execution configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Master switch; `false` forces every engine loop scalar.
    pub enabled: bool,
    /// Minimum number of independent work items (output pixels, DP
    /// columns, layer schedules) before a loop fans out.
    pub min_items: usize,
}

impl Parallelism {
    /// Parallel with a fan-out threshold tuned for the NN engines: below
    /// ~32 items the rayon fork/join overhead exceeds the per-item work of
    /// even the deepest ResNet DP columns.
    pub fn auto() -> Self {
        Self {
            enabled: true,
            min_items: 32,
        }
    }

    /// Parallel with a coarse-grained threshold: fan out from 2 items.
    /// For loops whose items are whole forward passes (serving lanes in
    /// `nn::run_model_batch` / `runtime::PacExecutor`), where per-item
    /// work dwarfs fork/join overhead even at tiny batch sizes.
    pub fn coarse() -> Self {
        Self {
            enabled: true,
            min_items: 2,
        }
    }

    /// Fully scalar execution (the pre-parallel behavior).
    pub fn off() -> Self {
        Self {
            enabled: false,
            min_items: usize::MAX,
        }
    }

    /// Should a loop over `items` independent units fan out?
    #[inline]
    pub fn should_parallelize(&self, items: usize) -> bool {
        self.enabled && items >= self.min_items
    }

    /// Map `f` over `0..n` and collect in index order, fanning out over
    /// rayon when the policy allows. This is the single dispatch point the
    /// engines share, so tuning (thresholds, future chunking) lands in one
    /// place. Deterministic for pure `f`: both paths collect by index.
    pub fn map_collect<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync + Send,
    {
        if self.should_parallelize(n) {
            (0..n).into_par_iter().map(f).collect()
        } else {
            (0..n).map(f).collect()
        }
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_gates_on_size() {
        let p = Parallelism::auto();
        assert!(p.should_parallelize(1000));
        assert!(!p.should_parallelize(1));
    }

    #[test]
    fn off_never_parallelizes() {
        let p = Parallelism::off();
        assert!(!p.should_parallelize(usize::MAX - 1));
        assert!(!p.should_parallelize(0));
    }

    #[test]
    fn default_is_auto() {
        assert_eq!(Parallelism::default(), Parallelism::auto());
    }

    #[test]
    fn coarse_fans_out_tiny_batches() {
        let p = Parallelism::coarse();
        assert!(p.should_parallelize(2));
        assert!(!p.should_parallelize(1));
    }

    #[test]
    fn map_collect_order_and_identity() {
        let f = |i: usize| i * i;
        let seq: Vec<usize> = (0..100).map(f).collect();
        assert_eq!(Parallelism::off().map_collect(100, f), seq);
        assert_eq!(Parallelism::auto().map_collect(100, f), seq);
        let forced = Parallelism {
            enabled: true,
            min_items: 1,
        };
        assert_eq!(forced.map_collect(100, f), seq);
        assert!(forced.map_collect(0, f).is_empty());
    }
}
