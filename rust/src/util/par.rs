//! Parallel-execution policy shared by the NN engines and the PAC batch
//! kernels.
//!
//! The per-output-activation work of a PACiM layer (one `hybrid_mac` per
//! DP column) is embarrassingly parallel, so the engines fan it out over
//! rayon's work-stealing pool. Every parallel path in this crate is
//! **bit-deterministic**: items are mapped independently and collected in
//! index order, and all merged statistics are integer counters, so the
//! result never depends on thread count or scheduling.
//!
//! `Parallelism` is the knob threaded through the engines: it gates
//! whether a loop fans out at all and below which size it stays scalar
//! (small layers lose more to fork/join overhead than they gain).

use rayon::prelude::*;

/// Parallel-execution configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Master switch; `false` forces every engine loop scalar.
    pub enabled: bool,
    /// Minimum number of independent work items (output pixels, DP
    /// columns, layer schedules) before a loop fans out.
    pub min_items: usize,
}

impl Parallelism {
    /// Parallel with a fan-out threshold tuned for the NN engines: below
    /// ~32 items the rayon fork/join overhead exceeds the per-item work of
    /// even the deepest ResNet DP columns.
    pub fn auto() -> Self {
        Self {
            enabled: true,
            min_items: 32,
        }
    }

    /// Parallel with a coarse-grained threshold: fan out from 2 items.
    /// For loops whose items are whole forward passes (serving lanes in
    /// `nn::run_model_batch_with` / `runtime::PacExecutor`), where per-item
    /// work dwarfs fork/join overhead even at tiny batch sizes.
    pub fn coarse() -> Self {
        Self {
            enabled: true,
            min_items: 2,
        }
    }

    /// Fully scalar execution (the pre-parallel behavior).
    pub fn off() -> Self {
        Self {
            enabled: false,
            min_items: usize::MAX,
        }
    }

    /// Should a loop over `items` independent units fan out?
    #[inline]
    pub fn should_parallelize(&self, items: usize) -> bool {
        self.enabled && items >= self.min_items
    }

    /// Combine two policies: `self` when it is enabled, else `fallback`.
    /// Backends use this to merge the driver's policy (authoritative when
    /// it asks for parallelism) with their own configured default (the
    /// fallback when the driver runs scalar, e.g. `nn::run_model_with` driving
    /// a backend whose `PacConfig::par` is enabled).
    #[inline]
    pub fn or(&self, fallback: &Parallelism) -> Parallelism {
        if self.enabled {
            *self
        } else {
            *fallback
        }
    }

    /// Map `f` over `0..n` and collect in index order, fanning out over
    /// rayon when the policy allows. This is the single dispatch point the
    /// engines share, so tuning (thresholds, future chunking) lands in one
    /// place. Deterministic for pure `f`: both paths collect by index.
    pub fn map_collect<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync + Send,
    {
        if self.should_parallelize(n) {
            (0..n).into_par_iter().map(f).collect()
        } else {
            (0..n).map(f).collect()
        }
    }

    /// Gate for *tiled* loops: fan out only when there are at least two
    /// tiles to steal **and** the underlying element count meets
    /// `min_items`. Tiles are coarse bundles (often ~32 work items
    /// each), so comparing the tile count against `min_items` — which is
    /// tuned in per-item units — would silently disable fan-out for
    /// most layers; `min_items` keeps its per-item meaning here.
    #[inline]
    pub fn should_parallelize_tiles(&self, tiles: usize, items: usize) -> bool {
        self.enabled && tiles >= 2 && items >= self.min_items
    }

    /// Split `data` into `chunk`-sized tiles and map `f(tile_index, tile)`
    /// over them, fanning the tiles out over rayon when the policy allows
    /// (see [`Parallelism::should_parallelize_tiles`]); per-tile results
    /// are collected in tile order. This is the engines' blocked-GEMM
    /// dispatch point: tiles own disjoint slices of the output slab, so
    /// the fan-out is bit-deterministic for pure `f` (same tiling, same
    /// per-tile arithmetic, index-ordered collect — identical to the
    /// sequential path by construction).
    pub fn map_chunks_mut<T, R, F>(&self, data: &mut [T], chunk: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut [T]) -> R + Sync + Send,
    {
        assert!(chunk > 0, "tile size must be positive");
        let tiles = data.len().div_ceil(chunk);
        if self.should_parallelize_tiles(tiles, data.len()) {
            data.par_chunks_mut(chunk)
                .enumerate()
                .map(|(t, c)| f(t, c))
                .collect()
        } else {
            data.chunks_mut(chunk).enumerate().map(|(t, c)| f(t, c)).collect()
        }
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_gates_on_size() {
        let p = Parallelism::auto();
        assert!(p.should_parallelize(1000));
        assert!(!p.should_parallelize(1));
    }

    #[test]
    fn off_never_parallelizes() {
        let p = Parallelism::off();
        assert!(!p.should_parallelize(usize::MAX - 1));
        assert!(!p.should_parallelize(0));
    }

    #[test]
    fn default_is_auto() {
        assert_eq!(Parallelism::default(), Parallelism::auto());
    }

    #[test]
    fn coarse_fans_out_tiny_batches() {
        let p = Parallelism::coarse();
        assert!(p.should_parallelize(2));
        assert!(!p.should_parallelize(1));
    }

    #[test]
    fn or_prefers_enabled_self() {
        let auto = Parallelism::auto();
        let coarse = Parallelism::coarse();
        assert_eq!(Parallelism::off().or(&auto), auto);
        assert_eq!(coarse.or(&auto), coarse);
        assert_eq!(Parallelism::off().or(&Parallelism::off()), Parallelism::off());
    }

    #[test]
    fn tile_gate_compares_items_not_tiles() {
        // 256 pixels in 32-pixel tiles = 8 tiles: far under a per-item
        // min_items of 32, but the *items* clear it — must fan out.
        let p = Parallelism::auto();
        assert!(p.should_parallelize_tiles(8, 256));
        // A single tile has nothing to steal.
        assert!(!p.should_parallelize_tiles(1, 4096));
        // Too little total work stays scalar.
        assert!(!p.should_parallelize_tiles(2, 8));
        assert!(!Parallelism::off().should_parallelize_tiles(100, 10_000));
    }

    #[test]
    fn map_chunks_mut_tiles_disjoint_and_ordered() {
        // Every element written exactly once, tile results in tile order,
        // identical across policies (including a forced fan-out).
        for par in [
            Parallelism::off(),
            Parallelism::auto(),
            Parallelism {
                enabled: true,
                min_items: 1,
            },
        ] {
            let mut data = vec![0usize; 103]; // non-multiple of the tile
            let sums = par.map_chunks_mut(&mut data, 10, |t, tile| {
                for (i, v) in tile.iter_mut().enumerate() {
                    *v = t * 10 + i;
                }
                tile.len()
            });
            assert_eq!(sums.len(), 11);
            assert_eq!(sums.iter().sum::<usize>(), 103);
            assert_eq!(*sums.last().unwrap(), 3);
            let expect: Vec<usize> = (0..103).collect();
            assert_eq!(data, expect);
        }
        // Empty input: no tiles, no calls.
        let mut empty: Vec<usize> = Vec::new();
        let r = Parallelism::auto().map_chunks_mut(&mut empty, 4, |_, _| 1);
        assert!(r.is_empty());
    }

    #[test]
    fn map_collect_order_and_identity() {
        let f = |i: usize| i * i;
        let seq: Vec<usize> = (0..100).map(f).collect();
        assert_eq!(Parallelism::off().map_collect(100, f), seq);
        assert_eq!(Parallelism::auto().map_collect(100, f), seq);
        let forced = Parallelism {
            enabled: true,
            min_items: 1,
        };
        assert_eq!(forced.map_collect(100, f), seq);
        assert!(forced.map_collect(0, f).is_empty());
    }
}
