//! Deterministic pseudo-random number generation.
//!
//! All Monte-Carlo experiments in the PACiM reproduction (Fig. 3 error
//! analysis, Table 1 RMSE sweeps, synthetic workload generation) must be
//! reproducible from a seed, so we use a small, well-understood generator
//! rather than an OS entropy source. `SplitMix64` seeds an `Xoshiro256**`
//! state; both are public-domain algorithms (Blackman & Vigna).

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse RNG for simulation.
///
/// Statistically strong, 2^256-1 period, and fast enough to generate the
/// hundreds of millions of Bernoulli bits the Fig. 3 experiments need.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed. Two `Rng`s with the same seed produce the
    /// same stream on every platform.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`, 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)`, f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift method
    /// (bias is negligible for our bounds; experiments use bounds << 2^32).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Uniform in the inclusive integer range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (we only need one of the pair; this
    /// is not on a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gaussian with the given mean / std.
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with uniform random bytes.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Random binary vector of length `n` with exactly ⌊p·n⌉ ones in random
    /// positions (sparsity-exact generation, used when an experiment pins
    /// `S` rather than the Bernoulli rate).
    pub fn binary_with_popcount(&mut self, n: usize, ones: usize) -> Vec<u8> {
        assert!(ones <= n);
        let mut v = vec![0u8; n];
        for slot in v.iter_mut().take(ones) {
            *slot = 1;
        }
        // Fisher–Yates shuffle.
        for i in (1..n).rev() {
            let j = self.below(i as u32 + 1) as usize;
            v.swap(i, j);
        }
        v
    }

    /// Random binary vector where each element is Bernoulli(p).
    pub fn binary_bernoulli(&mut self, n: usize, p: f64) -> Vec<u8> {
        (0..n).map(|_| self.bernoulli(p) as u8).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bound() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn bernoulli_rate_converges() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let ones = (0..n).filter(|_| r.bernoulli(0.3)).count();
        let rate = ones as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn popcount_exact() {
        let mut r = Rng::new(5);
        let v = r.binary_with_popcount(1024, 300);
        assert_eq!(v.iter().map(|&b| b as usize).sum::<usize>(), 300);
        assert_eq!(v.len(), 1024);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(9);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        // Overwhelmingly unlikely to be all zeros.
        assert!(buf.iter().any(|&b| b != 0));
    }
}
