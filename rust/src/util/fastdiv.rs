//! Exact division by a runtime-constant divisor via reciprocal multiply.
//!
//! The PCU evaluates `round(Sx·Sw / n)` for every sparsity-domain cycle
//! (§3.1, Eq. 3) with `n` fixed per layer — 48 divides per output MAC.
//! Hardware implements "divide by the configured DP length" as a
//! reciprocal multiplier; we do the same (§Perf: the `div` instruction
//! was ~40% of the PAC backend's time).
//!
//! Correctness domain: dividends up to `2^26` (the largest `Sx·Sw + n/2`
//! for DP lengths ≤ 8192), divisors 1..=8192. For divisors < 64 the
//! reciprocal's magic constant would overflow the u64 product, and a
//! native divide is cheap there anyway, so we fall back.

/// Precomputed exact divider for a fixed divisor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FastDiv {
    k: u64,
    magic: u64,
}

const SHIFT: u32 = 42;
/// Below this divisor the magic multiply could overflow; use native div.
const MIN_MAGIC_K: u64 = 64;
/// Largest dividend the magic path is proven exact for (see analysis in
/// the module docs: x·e ≤ x·k ≤ 2^39 < 2^42 for x ≤ 2^26, k ≤ 2^13).
pub const MAX_DIVIDEND: u64 = 1 << 26;

impl FastDiv {
    pub fn new(k: u64) -> Self {
        assert!(k > 0, "divisor must be positive");
        assert!(k <= 8192, "PCU divider supports DP lengths up to 8192");
        let magic = if k >= MIN_MAGIC_K {
            (1u64 << SHIFT) / k + 1
        } else {
            0
        };
        Self { k, magic }
    }

    /// Guarded constructor for DP-length divisors: a degenerate empty DP
    /// (`k = 0` — an empty layer or zero-length patch) divides by 1.
    ///
    /// This is the *same* convention `pac::mac::pcu_cycle` applies to its
    /// native divide (`n.max(1)`), so the reciprocal path and the native
    /// path cannot diverge on degenerate shapes — previously the guard
    /// lived at scattered call sites while `FastDiv::new(0)` panicked.
    pub fn for_dp_len(k: u64) -> Self {
        Self::new(k.max(1))
    }

    pub fn divisor(&self) -> u64 {
        self.k
    }

    /// Exact `x / k` (floor) for `x ≤ MAX_DIVIDEND`.
    #[inline]
    pub fn div(&self, x: u64) -> u64 {
        debug_assert!(x <= MAX_DIVIDEND, "dividend {x} out of proven range");
        if self.magic != 0 {
            (x * self.magic) >> SHIFT
        } else {
            x / self.k
        }
    }

    /// Round-nearest `x / k` (the PCU's +n/2 pre-add).
    #[inline]
    pub fn div_round(&self, x: u64) -> u64 {
        self.div(x + self.k / 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exact_for_all_layer_dp_lengths() {
        // Every DP length that appears in the model zoo + stress values.
        let ks = [
            27u64, 64, 72, 144, 147, 288, 576, 1152, 2304, 4096, 4608, 8192, 1, 2, 3, 63, 65,
        ];
        let mut rng = Rng::new(9);
        for &k in &ks {
            let f = FastDiv::new(k);
            // Edges + random sample.
            for x in [0u64, 1, k - 1, k, k + 1, MAX_DIVIDEND - 1, MAX_DIVIDEND] {
                assert_eq!(f.div(x), x / k, "k={k} x={x}");
            }
            for _ in 0..20_000 {
                let x = rng.next_u64() % (MAX_DIVIDEND + 1);
                assert_eq!(f.div(x), x / k, "k={k} x={x}");
            }
        }
    }

    #[test]
    fn round_nearest_matches_formula() {
        let mut rng = Rng::new(10);
        for &k in &[64u64, 576, 1024, 4096] {
            let f = FastDiv::new(k);
            for _ in 0..10_000 {
                let x = rng.next_u64() % (MAX_DIVIDEND - k);
                assert_eq!(f.div_round(x), (x + k / 2) / k, "k={k} x={x}");
            }
        }
    }

    #[test]
    fn exhaustive_small_dividends() {
        for k in 1..=512u64 {
            let f = FastDiv::new(k);
            for x in 0..4096u64 {
                assert_eq!(f.div(x), x / k, "k={k} x={x}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_divisor_rejected() {
        let _ = FastDiv::new(0);
    }

    #[test]
    fn dp_len_constructor_guards_empty_layers() {
        // k = 0 (empty DP) behaves as divide-by-1, matching the `n.max(1)`
        // guard in `pcu_cycle` — the two divide paths share one rule.
        let f = FastDiv::for_dp_len(0);
        assert_eq!(f.divisor(), 1);
        for x in [0u64, 1, 7, MAX_DIVIDEND] {
            assert_eq!(f.div(x), x);
        }
        // Non-degenerate lengths are unchanged.
        assert_eq!(FastDiv::for_dp_len(576).divisor(), 576);
        assert_eq!(FastDiv::for_dp_len(576).div_round(575), 1);
    }
}
