//! Machine-readable bench-artifact schemas.
//!
//! CI uploads four JSON artifacts per run — `BENCH_hotpath.json`
//! (`benches/perf_hotpath.rs`), `BENCH_serve.json`
//! (`examples/loadgen.rs`), `BENCH_traffic.json`
//! (`benches/fig7_system.rs`, the measured sparsity-encoded dataplane
//! ledger), and `BENCH_tune.json` (`pacim tune`, the design-space
//! Pareto front) — to track the perf trajectory across PRs. Regression
//! gating only works if the files stay machine-readable, so the writers
//! serialize *these* structs and `tests/bench_schema.rs` re-parses the
//! emitted files with `deny_unknown_fields`: any schema drift (renamed,
//! added, or removed field) fails the build instead of silently
//! breaking the trend tooling.

use serde::{Deserialize, Serialize};

/// One scalar-vs-parallel PAC MAC measurement (a `BENCH_hotpath.json`
/// row).
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct LayerBench {
    pub layer: String,
    pub dp_len: usize,
    pub pairs: usize,
    pub scalar_macs_per_s: f64,
    pub parallel_macs_per_s: f64,
    pub speedup: f64,
    pub bit_identical: bool,
}

/// One blocked-vs-per-patch layer GEMM measurement (a
/// `BENCH_hotpath.json` row): the layer-level blocked bit-plane kernel
/// (`PacBackend::gemm_layer`, single-thread) against the frozen
/// per-patch engine it replaced (`gemm_per_patch_reference`).
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct BlockedBench {
    /// Layer name from the ResNet-18 shape table.
    pub shape: String,
    pub dp_len: usize,
    pub out_c: usize,
    /// Output pixels fed to one layer-level GEMM call.
    pub pixels: usize,
    pub per_patch_macs_per_s: f64,
    pub blocked_macs_per_s: f64,
    /// `blocked / per_patch` throughput ratio; CI gates this ≥ 1.0 on
    /// every shape ([`enforce_blocked_floor`]).
    pub speedup_blocked: f64,
    pub bit_identical: bool,
}

/// One SIMD-vs-scalar kernel-tier measurement (a `BENCH_hotpath.json`
/// row): the blocked GEMM (`PacBackend::gemm_layer`, single-thread)
/// with the auto-detected kernel tier against the same GEMM forced to
/// the scalar tier — same shape, same inputs, bit-identity asserted.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct SimdBench {
    /// Layer name from the ResNet-18 shape table, suffixed with the
    /// weight fill (`-dense` / `-msbsparse`).
    pub shape: String,
    pub dp_len: usize,
    pub out_c: usize,
    /// Output pixels fed to one layer-level GEMM call.
    pub pixels: usize,
    /// Kernel tier the SIMD side resolved (`KernelTier::name()`:
    /// `"scalar"`, `"avx2"`, or `"avx512"`). `"scalar"` on hosts with
    /// no vector tier — [`enforce_simd_floor`] then refuses to gate.
    pub tier: String,
    /// Whether the weight fill zeroes MSB planes in word-aligned
    /// stripes (exercising the zero-word skipping) or is dense
    /// (exercising the density auto-off).
    pub msb_sparse_weights: bool,
    /// Live MSB-word fraction of the prepared layer (the skip-bitmap
    /// density; 1.0 for dense fills).
    pub live_word_fraction: f64,
    /// Columns whose sweep actually skips (post auto-off).
    pub skip_columns: usize,
    pub scalar_macs_per_s: f64,
    pub simd_macs_per_s: f64,
    /// `simd / scalar` throughput ratio; CI gates this ≥ 1.0 on every
    /// row when the host has a vector tier ([`enforce_simd_floor`]).
    pub speedup_simd: f64,
    pub bit_identical: bool,
}

/// One fused-vs-roundtrip end-to-end measurement (a
/// `BENCH_hotpath.json` row): multi-layer PAC inference with the
/// sparsity-encoded dataplane (producer-side requantize→scatter→pack)
/// against the dense-u8 round-trip it replaced, same model, same
/// images, single-thread.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct FusedBench {
    /// Model the forward passes ran (synthetic tiny-resnet label).
    pub model: String,
    /// Images per timed repetition.
    pub images: usize,
    /// Inter-layer edges that moved in MSB+counter form per image.
    pub encoded_layers: usize,
    pub roundtrip_images_per_s: f64,
    pub fused_images_per_s: f64,
    /// `fused / roundtrip` throughput ratio (reported, not gated — the
    /// logits bit-identity below is the hard claim).
    pub speedup_fused: f64,
    pub bit_identical: bool,
}

/// `BENCH_hotpath.json` — hot-path throughput report.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct HotpathReport {
    /// Always `"perf_hotpath"`.
    pub bench: String,
    pub threads: usize,
    pub quick: bool,
    pub layers: Vec<LayerBench>,
    /// Blocked-vs-per-patch layer GEMM rows (single-thread).
    pub blocked: Vec<BlockedBench>,
    /// SIMD-tier vs forced-scalar blocked GEMM rows (single-thread).
    pub simd: Vec<SimdBench>,
    /// Fused-dataplane vs dense-roundtrip end-to-end rows.
    pub fused: Vec<FusedBench>,
}

/// One serving scenario (a `BENCH_serve.json` row): an executor driven
/// by one traffic pattern.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct ServeScenario {
    /// `"<executor>-<mode>"`, e.g. `"pac-open"`, or `"mix-<model>-open"`
    /// for per-model rows of a multi-model run.
    pub name: String,
    /// `"mock"`, `"pac"`, or `"exact"`.
    pub executor: String,
    /// Tenant model the scenario served (registry id, e.g. `"resnet18"`;
    /// single-model scenarios use the workload's model label).
    pub model: String,
    /// `"open"` (Poisson arrivals) or `"closed"` (fixed client loop).
    pub mode: String,
    pub workers: usize,
    /// Ingress shards behind the scenario (1 = the pre-sharded pool).
    pub shards: u64,
    /// Requests executed by a worker other than the one whose shard
    /// admitted them (`ServerMetrics::steals`); 0 on a single shard.
    pub steals: u64,
    pub batch_size: usize,
    pub queue_cap: usize,
    /// Offered open-loop rate (req/s); 0 for closed-loop scenarios.
    pub offered_rps: f64,
    /// Requests attempted (admitted + load-shed).
    pub requests: u64,
    pub completed: u64,
    /// Submissions load-shed by admission control.
    pub rejected: u64,
    /// Batches whose execution failed.
    pub failed_batches: u64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_batch_occupancy: f64,
    /// `batch_fill[i]` = batches that carried exactly `i + 1` requests.
    pub batch_fill: Vec<u64>,
    /// Modeled PACiM bit-serial cycles per image (0 = no cost model).
    pub modeled_cycles_per_image: u64,
    /// Modeled PACiM energy per image, µJ (0 = no cost model).
    pub modeled_energy_uj_per_image: f64,
    /// Inter-layer bits the executors actually moved (the `TrafficLedger`
    /// totals aggregated through `ServerMetrics`; 0 for executors with no
    /// ledger, e.g. mock).
    pub measured_traffic_bits: u64,
    /// 8-bit dense-equivalent bits for the same edges (0 = no ledger).
    pub traffic_baseline_bits: u64,
    /// `measured_traffic_bits / completed` — measured bits moved per
    /// completed request (0 when nothing completed); `validate_serve`
    /// recomputes it from the fields, a writer cannot cook it.
    pub bits_per_request: f64,
    /// Requests re-run through the exact backend by the confidence
    /// monitor (0 unless the executor serves `Fidelity::Auto` lanes).
    pub escalated: u64,
}

/// `BENCH_serve.json` — serving-pipeline report.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct ServeReport {
    /// Always `"serve"`.
    pub bench: String,
    pub quick: bool,
    pub scenarios: Vec<ServeScenario>,
}

/// Parse + sanity-check a `BENCH_hotpath.json` payload.
pub fn validate_hotpath(json: &str) -> Result<HotpathReport, String> {
    let r: HotpathReport = serde_json::from_str(json).map_err(|e| e.to_string())?;
    if r.bench != "perf_hotpath" {
        return Err(format!("bench field is '{}', expected 'perf_hotpath'", r.bench));
    }
    if r.layers.is_empty() {
        return Err("no layer rows".into());
    }
    for l in &r.layers {
        if !(l.scalar_macs_per_s.is_finite() && l.scalar_macs_per_s > 0.0) {
            return Err(format!("layer '{}' has invalid scalar rate", l.layer));
        }
        if !(l.parallel_macs_per_s.is_finite() && l.parallel_macs_per_s > 0.0) {
            return Err(format!("layer '{}' has invalid parallel rate", l.layer));
        }
    }
    for b in &r.blocked {
        if !(b.per_patch_macs_per_s.is_finite() && b.per_patch_macs_per_s > 0.0) {
            return Err(format!("shape '{}' has invalid per-patch rate", b.shape));
        }
        if !(b.blocked_macs_per_s.is_finite() && b.blocked_macs_per_s > 0.0) {
            return Err(format!("shape '{}' has invalid blocked rate", b.shape));
        }
    }
    for s in &r.simd {
        if !(s.scalar_macs_per_s.is_finite() && s.scalar_macs_per_s > 0.0) {
            return Err(format!("simd row '{}' has invalid scalar rate", s.shape));
        }
        if !(s.simd_macs_per_s.is_finite() && s.simd_macs_per_s > 0.0) {
            return Err(format!("simd row '{}' has invalid simd rate", s.shape));
        }
        if crate::util::KernelTier::parse(&s.tier).is_none() {
            return Err(format!("simd row '{}' has unknown tier '{}'", s.shape, s.tier));
        }
        if !(0.0..=1.0).contains(&s.live_word_fraction) {
            return Err(format!("simd row '{}': live_word_fraction out of [0,1]", s.shape));
        }
        if !s.bit_identical {
            return Err(format!("simd row '{}': SIMD kernel diverged from scalar", s.shape));
        }
    }
    for f in &r.fused {
        if !(f.roundtrip_images_per_s.is_finite() && f.roundtrip_images_per_s > 0.0) {
            return Err(format!("fused row '{}' has invalid roundtrip rate", f.model));
        }
        if !(f.fused_images_per_s.is_finite() && f.fused_images_per_s > 0.0) {
            return Err(format!("fused row '{}' has invalid fused rate", f.model));
        }
        if !f.bit_identical {
            return Err(format!("fused row '{}': dataplane diverged from round-trip", f.model));
        }
        if f.encoded_layers == 0 {
            return Err(format!("fused row '{}' encoded no edges (nothing measured)", f.model));
        }
    }
    Ok(r)
}

/// One measured inter-layer traffic row (a `BENCH_traffic.json` row):
/// what the executor's `TrafficLedger` recorded for one edge, next to
/// the closed-form prediction for the same geometry + encode decision.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct TrafficLayerBench {
    pub layer: String,
    /// Edge kind (`memory::EdgeKind::as_str()`): `conv`, `linear`,
    /// `pool`, `residual_save`, `residual_in`, or `residual_add`. One
    /// layer can emit several rows of different kinds (a residual tail
    /// conv writes both the add operand and the post-add activation).
    pub kind: String,
    /// Channels per encoding group.
    pub channels: usize,
    /// Encoding groups moved (output pixels × images).
    pub groups: u64,
    /// 8-bit dense-equivalent bits (one direction).
    pub baseline_bits: u64,
    /// Bits the executor actually moved (one direction).
    pub measured_bits: u64,
    /// The analytic `memory::traffic` prediction for the same edge,
    /// computed from layer geometry — must equal `measured_bits`.
    pub analytic_bits: u64,
    /// `1 − measured/baseline`.
    pub reduction: f64,
    /// Moved in MSB+counter form (vs dense u8) — or, on a
    /// `residual_in` row, eliminated outright (zero measured bits).
    pub encoded: bool,
    /// Deep layer (≥ 128 channels): the band Fig. 7(b) quotes 40–50%
    /// for; CI's floor gate applies to deep encoded *payload* rows
    /// (every kind except `residual_save` — which honestly pays an
    /// 8-plane premium — and the eliminated `residual_in`).
    pub deep: bool,
}

/// `BENCH_traffic.json` — measured sparsity-encoded dataplane report.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct TrafficReport {
    /// Always `"traffic"`.
    pub bench: String,
    pub quick: bool,
    /// Model the ledger was measured on.
    pub model: String,
    /// Forward passes aggregated into the rows.
    pub images: usize,
    pub layers: Vec<TrafficLayerBench>,
    /// Rows moved in encoded form.
    pub encoded_layers: usize,
    /// Minimum reduction over `deep && encoded` rows (the gated floor).
    pub deep_encoded_min_reduction: f64,
    /// Whole-network measured reduction (every edge, encoded or not).
    pub network_reduction: f64,
}

/// Channel count at and above which a traffic row counts as a *deep*
/// layer (the band Fig. 7(b) quotes 40–50% for). Part of the
/// `BENCH_traffic.json` schema: `validate_traffic` recomputes every
/// row's `deep` flag from this threshold, so the floor gate never
/// trusts a writer-supplied label.
pub const TRAFFIC_DEEP_CHANNELS: usize = 128;

/// Edge-kind strings `validate_traffic` accepts — exactly
/// `memory::EdgeKind::as_str()`'s range.
pub const TRAFFIC_EDGE_KINDS: [&str; 6] =
    ["conv", "linear", "pool", "residual_save", "residual_in", "residual_add"];

/// Whether a traffic row is a *payload* edge for the deep-reduction
/// claim: `residual_save` rows honestly pay an 8-plane premium to keep
/// the skip operand encoded, and eliminated `residual_in` rows reduce
/// by 1.0 — both would distort a floor defined for the Fig. 7(b)
/// MSB+counter band, so the floor gate and the `deep_encoded_min`
/// summary cover every other kind.
pub fn traffic_payload_row(l: &TrafficLayerBench) -> bool {
    l.kind != "residual_save" && l.kind != "residual_in"
}

/// Parse + sanity-check a `BENCH_traffic.json` payload, including the
/// measured-vs-analytic cross-check: every row's measured bits must
/// equal the closed-form `memory::traffic` prediction for its geometry
/// and encode decision (dense rows: the 8-bit baseline), every `deep`
/// flag must match [`TRAFFIC_DEEP_CHANNELS`], and the summary fields
/// must agree with the rows they summarize.
pub fn validate_traffic(json: &str) -> Result<TrafficReport, String> {
    let r: TrafficReport = serde_json::from_str(json).map_err(|e| e.to_string())?;
    if r.bench != "traffic" {
        return Err(format!("bench field is '{}', expected 'traffic'", r.bench));
    }
    if r.layers.is_empty() {
        return Err("no traffic rows".into());
    }
    for l in &r.layers {
        if !TRAFFIC_EDGE_KINDS.contains(&l.kind.as_str()) {
            return Err(format!("layer '{}' has unknown edge kind '{}'", l.layer, l.kind));
        }
        if l.kind == "residual_in" && l.encoded && l.measured_bits != 0 {
            return Err(format!(
                "layer '{}': an encoded residual_in edge is eliminated by definition \
                 but reports {} measured bits",
                l.layer, l.measured_bits
            ));
        }
        if l.baseline_bits == 0 {
            return Err(format!("layer '{}' moved no baseline bits", l.layer));
        }
        if l.measured_bits != l.analytic_bits {
            return Err(format!(
                "layer '{}': measured {} bits but the analytic model predicts {} — \
                 the ledger's bookkeeping drifted from `memory::traffic`",
                l.layer, l.measured_bits, l.analytic_bits
            ));
        }
        if !l.encoded && l.measured_bits != l.baseline_bits {
            return Err(format!(
                "layer '{}': a dense edge must move exactly the 8-bit baseline",
                l.layer
            ));
        }
        let want = 1.0 - l.measured_bits as f64 / l.baseline_bits as f64;
        if !(l.reduction.is_finite() && (l.reduction - want).abs() < 1e-9) {
            return Err(format!("layer '{}': reduction field inconsistent", l.layer));
        }
        if l.deep != (l.channels >= TRAFFIC_DEEP_CHANNELS) {
            return Err(format!(
                "layer '{}': deep flag disagrees with its {} channels (threshold {})",
                l.layer, l.channels, TRAFFIC_DEEP_CHANNELS
            ));
        }
    }
    let encoded = r.layers.iter().filter(|l| l.encoded).count();
    if encoded != r.encoded_layers {
        return Err(format!(
            "encoded_layers says {} but {} rows are encoded",
            r.encoded_layers, encoded
        ));
    }
    let deep_min = r
        .layers
        .iter()
        .filter(|l| l.deep && l.encoded && traffic_payload_row(l))
        .map(|l| l.reduction)
        .fold(f64::INFINITY, f64::min);
    if deep_min.is_finite() && (r.deep_encoded_min_reduction - deep_min).abs() >= 1e-9 {
        return Err(format!(
            "deep_encoded_min_reduction says {} but the rows give {deep_min}",
            r.deep_encoded_min_reduction
        ));
    }
    let (bits, base) = r
        .layers
        .iter()
        .fold((0u64, 0u64), |(b, d), l| (b + l.measured_bits, d + l.baseline_bits));
    let net = 1.0 - bits as f64 / base as f64;
    if (r.network_reduction - net).abs() >= 1e-9 {
        return Err(format!(
            "network_reduction says {} but the rows give {net}",
            r.network_reduction
        ));
    }
    Ok(r)
}

/// The traffic regression gate (CI bench-smoke, behind
/// `PACIM_ENFORCE_TRAFFIC_REDUCTION`): every deep (≥128-channel)
/// sparsity-encoded *payload* edge must hit at least `floor` reduction
/// — the measured version of the paper's 40–50% deep-layer claim.
/// `residual_save` rows (8-plane slot writes, honestly above baseline)
/// and eliminated `residual_in` rows (reduction 1.0 by construction)
/// are accounted in the network total but not floor-gated.
pub fn enforce_traffic_floor(r: &TrafficReport, floor: f64) -> Result<(), String> {
    let deep: Vec<&TrafficLayerBench> = r
        .layers
        .iter()
        .filter(|l| l.deep && l.encoded && traffic_payload_row(l))
        .collect();
    if deep.is_empty() {
        return Err("no deep encoded payload rows to gate".into());
    }
    for l in &deep {
        if !(l.reduction.is_finite() && l.reduction >= floor) {
            return Err(format!(
                "layer '{}' ({} ch): measured reduction {:.3} below the {:.2} floor",
                l.layer, l.channels, l.reduction, floor
            ));
        }
    }
    Ok(())
}

/// The blocked-GEMM regression gate (CI bench-smoke): the blocked kernel
/// must stay bit-identical to the per-patch baseline and at least as
/// fast (`speedup_blocked >= 1.0`) on **every** measured shape.
pub fn enforce_blocked_floor(r: &HotpathReport) -> Result<(), String> {
    if r.blocked.is_empty() {
        return Err("no blocked GEMM rows to gate".into());
    }
    for b in &r.blocked {
        if !b.bit_identical {
            return Err(format!("shape '{}': blocked kernel diverged from baseline", b.shape));
        }
        if !(b.speedup_blocked.is_finite() && b.speedup_blocked >= 1.0) {
            return Err(format!(
                "shape '{}': blocked GEMM regressed vs per-patch baseline (speedup {:.3} < 1.0)",
                b.shape, b.speedup_blocked
            ));
        }
    }
    Ok(())
}

/// The SIMD kernel-tier regression gate (CI bench-smoke, behind
/// `PACIM_ENFORCE_SIMD_SPEEDUP`): every `simd[]` row must be
/// bit-identical to the forced-scalar run and at least as fast
/// (`speedup_simd >= 1.0`). Rows whose resolved tier is `"scalar"`
/// mean the host has no vector unit to measure — the gate then fails
/// loudly rather than vacuously passing, because CI only sets the
/// enforcement variable on AVX2-capable runners.
pub fn enforce_simd_floor(r: &HotpathReport) -> Result<(), String> {
    if r.simd.is_empty() {
        return Err("no simd rows to gate".into());
    }
    for s in &r.simd {
        if !s.bit_identical {
            return Err(format!("simd row '{}': SIMD kernel diverged from scalar", s.shape));
        }
        if s.tier == "scalar" {
            return Err(format!(
                "simd row '{}' resolved tier 'scalar' — nothing vectorized on this host, \
                 refusing to gate a scalar-vs-scalar measurement",
                s.shape
            ));
        }
        if !(s.speedup_simd.is_finite() && s.speedup_simd >= 1.0) {
            return Err(format!(
                "simd row '{}' ({}): SIMD sweep regressed vs forced scalar (speedup {:.3} < 1.0)",
                s.shape, s.tier, s.speedup_simd
            ));
        }
    }
    Ok(())
}

/// One evaluated design point (a `BENCH_tune.json` row): a
/// (threshold map × bank count × tile size × λ) configuration with its
/// measured accuracy and modeled schedule cost.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct TunePointBench {
    pub banks: usize,
    /// Rows per bank (DP tile size).
    pub rows: usize,
    /// `[th0, th1, th2]` dynamic map; `null` = the static 16-cycle map.
    pub thresholds: Option<[f64; 3]>,
    /// Traffic price in cycles per bit (0 = cycles-only schedule).
    pub lambda: f64,
    /// Top-1 accuracy on the validation split.
    pub accuracy: f64,
    /// Measured average digital cycles per output group.
    pub avg_digital_cycles: f64,
    /// Modeled cycles of the priced schedule over the workload.
    pub cycles: u64,
    /// Modeled bits moved (activation + spill) by the priced schedule.
    pub bits: u64,
    /// On the non-dominated (accuracy ↑, cycles ↓, bits ↓) front.
    /// `validate_tune` recomputes this from the rows — a writer cannot
    /// promote a dominated point onto the front.
    pub on_front: bool,
}

/// One λ-priced schedule next to its cycles-only baseline (a
/// `BENCH_tune.json` row): the comparison [`enforce_tune_front`] gates —
/// strictly fewer bits within [`TUNE_CYCLE_BOUND`]× the baseline cycles
/// on at least one deep workload.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct TuneScheduleBench {
    /// Workload the schedules were priced over (e.g. `resnet18-cifar`).
    pub workload: String,
    pub banks: usize,
    pub rows: usize,
    /// The non-zero λ the priced side used.
    pub lambda: f64,
    pub cycles_cycles_only: u64,
    pub bits_cycles_only: u64,
    pub cycles_priced: u64,
    pub bits_priced: u64,
    /// Layers the pricing flipped from buffer spill to digital replay.
    pub replayed_layers: usize,
}

/// `BENCH_tune.json` — design-space autotuner report (`pacim tune`).
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct TuneReport {
    /// Always `"tune"`.
    pub bench: String,
    pub quick: bool,
    /// Model the accuracy split evaluated (label + weight source).
    pub model: String,
    /// Workload whose shapes the priced schedules modeled.
    pub workload: String,
    /// Validation images per engine evaluation.
    pub images: usize,
    pub points: Vec<TunePointBench>,
    /// λ-vs-cycles-only schedule comparisons on the modeled workload.
    pub schedules: Vec<TuneScheduleBench>,
    /// One-direction bits the `TrafficLedger` measured on the probe run.
    pub measured_bits: u64,
    /// Closed-form recomputation of the same edges from layer geometry;
    /// `validate_tune` requires it equal to `measured_bits`.
    pub analytic_bits: u64,
    /// Measured bits of the probe run's residual edges (skip-slot save +
    /// add-in + post-add) under the fused dataplane.
    pub residual_bits_encoded: u64,
    /// Dense-baseline bits of the same residual edges — what the
    /// round-trip representation would have moved. `enforce_tune_front`
    /// requires the encoded side strictly below this (λ-independent: the
    /// eliminated add-in edge outweighs the 8-plane save premium).
    pub residual_bits_dense: u64,
}

/// Maximum cycle premium the traffic-priced schedule may pay for its
/// bit savings and still satisfy [`enforce_tune_front`]:
/// `cycles_priced ≤ TUNE_CYCLE_BOUND × cycles_cycles_only`.
pub const TUNE_CYCLE_BOUND: f64 = 1.10;

fn tune_dominates(a: &TunePointBench, b: &TunePointBench) -> bool {
    let no_worse = a.accuracy >= b.accuracy && a.cycles <= b.cycles && a.bits <= b.bits;
    no_worse && (a.accuracy > b.accuracy || a.cycles < b.cycles || a.bits < b.bits)
}

/// Parse + sanity-check a `BENCH_tune.json` payload.
///
/// Beyond field validity, this recomputes the Pareto front from the
/// rows (every `on_front` flag must match non-domination over the
/// actual (accuracy, cycles, bits) values) and enforces the
/// measured-vs-analytic traffic cross-check — the same
/// never-trust-the-writer posture as [`validate_traffic`].
pub fn validate_tune(json: &str) -> Result<TuneReport, String> {
    let r: TuneReport = serde_json::from_str(json).map_err(|e| e.to_string())?;
    if r.bench != "tune" {
        return Err(format!("bench field is '{}', expected 'tune'", r.bench));
    }
    if r.points.is_empty() {
        return Err("no design points".into());
    }
    for (i, p) in r.points.iter().enumerate() {
        if !(p.accuracy.is_finite() && (0.0..=1.0).contains(&p.accuracy)) {
            return Err(format!("point {i}: accuracy out of [0,1]"));
        }
        if !(p.avg_digital_cycles.is_finite() && p.avg_digital_cycles > 0.0) {
            return Err(format!("point {i}: invalid avg_digital_cycles"));
        }
        if !(p.lambda.is_finite() && p.lambda >= 0.0) {
            return Err(format!("point {i}: invalid lambda"));
        }
        if p.cycles == 0 || p.bits == 0 {
            return Err(format!("point {i}: empty schedule (zero cycles or bits)"));
        }
        if p.banks == 0 || p.rows == 0 {
            return Err(format!("point {i}: degenerate bank geometry"));
        }
    }
    for (i, p) in r.points.iter().enumerate() {
        let dominated = r
            .points
            .iter()
            .enumerate()
            .any(|(j, q)| j != i && tune_dominates(q, p));
        if p.on_front == dominated {
            return Err(format!(
                "point {i}: on_front flag disagrees with the rows (recomputed {})",
                !dominated
            ));
        }
    }
    for s in &r.schedules {
        if !(s.lambda.is_finite() && s.lambda > 0.0) {
            return Err(format!("schedule '{}': priced row needs λ > 0", s.workload));
        }
        if s.cycles_cycles_only == 0 || s.cycles_priced == 0 {
            return Err(format!("schedule '{}': zero-cycle schedule", s.workload));
        }
        if s.bits_cycles_only == 0 || s.bits_priced == 0 {
            return Err(format!("schedule '{}': zero-bit schedule", s.workload));
        }
    }
    if r.measured_bits != r.analytic_bits {
        return Err(format!(
            "measured {} bits but the analytic model predicts {} — the probe run's \
             ledger drifted from the closed-form traffic model",
            r.measured_bits, r.analytic_bits
        ));
    }
    if r.residual_bits_encoded > r.measured_bits {
        return Err(format!(
            "residual_bits_encoded {} exceeds the probe's total measured bits {}",
            r.residual_bits_encoded, r.measured_bits
        ));
    }
    Ok(r)
}

/// The autotuner gate (CI bench-smoke, behind `PACIM_ENFORCE_TUNE_FRONT`):
/// the Pareto front must hold at least 3 mutually non-dominated points,
/// and on at least one deep workload the traffic-priced schedule must
/// move *strictly fewer* bits than the λ=0 cycles-only baseline while
/// staying within [`TUNE_CYCLE_BOUND`]× its cycles — the claim that the
/// λ knob buys real traffic, not a relabeling.
pub fn enforce_tune_front(r: &TuneReport) -> Result<(), String> {
    let front: Vec<&TunePointBench> = r.points.iter().filter(|p| p.on_front).collect();
    if front.len() < 3 {
        return Err(format!(
            "Pareto front holds {} point(s), need ≥ 3 — the sweep axes are not trading",
            front.len()
        ));
    }
    if r.schedules.is_empty() {
        return Err("no λ-comparison rows to gate".into());
    }
    let ok = r.schedules.iter().any(|s| {
        s.bits_priced < s.bits_cycles_only
            && (s.cycles_priced as f64) <= s.cycles_cycles_only as f64 * TUNE_CYCLE_BOUND
    });
    if !ok {
        return Err(format!(
            "no workload where the traffic-priced schedule moves strictly fewer bits \
             within the {TUNE_CYCLE_BOUND}× cycle bound"
        ));
    }
    if r.residual_bits_dense == 0 {
        return Err(
            "the probe run measured no residual edges — the fused residual dataplane \
             never ran, nothing to gate"
                .into(),
        );
    }
    if r.residual_bits_encoded >= r.residual_bits_dense {
        return Err(format!(
            "fused residual edges moved {} bits, not strictly below their {}-bit dense \
             round-trip — the encoded skip slots are not paying for themselves",
            r.residual_bits_encoded, r.residual_bits_dense
        ));
    }
    Ok(())
}

/// Parse + sanity-check a `BENCH_serve.json` payload.
pub fn validate_serve(json: &str) -> Result<ServeReport, String> {
    let r: ServeReport = serde_json::from_str(json).map_err(|e| e.to_string())?;
    if r.bench != "serve" {
        return Err(format!("bench field is '{}', expected 'serve'", r.bench));
    }
    if r.scenarios.is_empty() {
        return Err("no scenarios".into());
    }
    for s in &r.scenarios {
        if s.model.is_empty() {
            return Err(format!("scenario '{}': empty model id", s.name));
        }
        if s.shards == 0 {
            return Err(format!("scenario '{}': zero ingress shards", s.name));
        }
        if s.shards == 1 && s.steals > 0 {
            return Err(format!(
                "scenario '{}': {} steals reported on a single shard — nothing to steal from",
                s.name, s.steals
            ));
        }
        if s.completed + s.rejected > s.requests {
            return Err(format!(
                "scenario '{}': completed {} + rejected {} exceed requests {}",
                s.name, s.completed, s.rejected, s.requests
            ));
        }
        if !(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us) {
            return Err(format!("scenario '{}': percentiles out of order", s.name));
        }
        if s.completed > 0 && !(s.throughput_rps.is_finite() && s.throughput_rps > 0.0) {
            return Err(format!("scenario '{}': invalid throughput", s.name));
        }
        let filled: u64 = s
            .batch_fill
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as u64 + 1) * c)
            .sum();
        if filled != s.completed {
            return Err(format!(
                "scenario '{}': batch_fill accounts for {} requests, completed {}",
                s.name, filled, s.completed
            ));
        }
        let want_bpr = if s.completed > 0 {
            s.measured_traffic_bits as f64 / s.completed as f64
        } else {
            0.0
        };
        if !(s.bits_per_request.is_finite() && (s.bits_per_request - want_bpr).abs() < 1e-6) {
            return Err(format!(
                "scenario '{}': bits_per_request says {} but measured_traffic_bits / \
                 completed gives {want_bpr}",
                s.name, s.bits_per_request
            ));
        }
        if s.measured_traffic_bits > s.traffic_baseline_bits {
            return Err(format!(
                "scenario '{}': measured traffic {} exceeds its 8-bit dense baseline {}",
                s.name, s.measured_traffic_bits, s.traffic_baseline_bits
            ));
        }
    }
    Ok(r)
}

/// Highest p99 latency a gated multi-model open-loop row may report and
/// still satisfy [`enforce_serve_slo`] (microseconds). Generous enough
/// for a loaded CI runner; the gate's job is catching collapse (a
/// stalled shard, a stranded queue), not micro-benchmark variance.
pub const SERVE_SLO_P99_FLOOR_US: f64 = 250_000.0;

/// Minimum fraction of the summed offered rate the gated rows must
/// sustain as completed throughput under [`enforce_serve_slo`].
pub const SERVE_SLO_MIN_RATE_FRACTION: f64 = 0.5;

/// The multi-model serving SLO gate (CI serve-smoke, behind
/// `PACIM_ENFORCE_SERVE_SLO`).
///
/// Gated rows are the sharded (`shards ≥ 2`) open-loop scenarios — the
/// multi-model ingress measurement this PR's acceptance names. The gate
/// refuses vacuous passes: no gated rows, fewer than two distinct
/// models, or a row that completed nothing all fail. On the gated set
/// it requires every p99 under [`SERVE_SLO_P99_FLOOR_US`], aggregate
/// completed throughput at least [`SERVE_SLO_MIN_RATE_FRACTION`] of the
/// aggregate offered rate, a nonzero steal count somewhere (proof the
/// work-stealing path actually ran), and — on `pac` rows — a positive
/// measured bits-per-request (proof the per-model traffic attribution
/// is wired through).
pub fn enforce_serve_slo(r: &ServeReport) -> Result<(), String> {
    let gated: Vec<&ServeScenario> = r
        .scenarios
        .iter()
        .filter(|s| s.shards >= 2 && s.mode == "open")
        .collect();
    if gated.is_empty() {
        return Err("no sharded open-loop rows to gate".into());
    }
    let mut models: Vec<&str> = gated.iter().map(|s| s.model.as_str()).collect();
    models.sort_unstable();
    models.dedup();
    if models.len() < 2 {
        return Err(format!(
            "gated rows cover {} model(s), need ≥ 2 — not a multi-model measurement",
            models.len()
        ));
    }
    let (mut offered, mut achieved, mut steals) = (0.0f64, 0.0f64, 0u64);
    for s in &gated {
        if s.completed == 0 {
            return Err(format!("scenario '{}': completed nothing", s.name));
        }
        if !(s.p99_us.is_finite() && s.p99_us <= SERVE_SLO_P99_FLOOR_US) {
            return Err(format!(
                "scenario '{}': p99 {:.0}µs over the {SERVE_SLO_P99_FLOOR_US:.0}µs SLO floor",
                s.name, s.p99_us
            ));
        }
        if s.executor == "pac" && s.bits_per_request <= 0.0 {
            return Err(format!(
                "scenario '{}': a pac row with no measured bits per request — the \
                 per-model traffic attribution is not wired through",
                s.name
            ));
        }
        offered += s.offered_rps;
        achieved += s.throughput_rps;
        steals += s.steals;
    }
    if steals == 0 {
        return Err("no gated row recorded a steal — the work-stealing path never ran".into());
    }
    if achieved < offered * SERVE_SLO_MIN_RATE_FRACTION {
        return Err(format!(
            "aggregate throughput {achieved:.1} req/s under {:.1} ({} of the {offered:.1} \
             req/s offered)",
            offered * SERVE_SLO_MIN_RATE_FRACTION,
            SERVE_SLO_MIN_RATE_FRACTION
        ));
    }
    Ok(())
}

/// One fault-injection operating point (a `BENCH_resilience.json` row):
/// the same image set scored through the exact baseline, the faulted
/// PAC engine, and the faulted PAC engine with confidence-gated
/// escalation, all at one bit-error rate.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct ResilienceRow {
    /// Bit-error rate driving all three fault channels
    /// (`FaultConfig::at_ber`); 0 = the fault-free reference row.
    pub ber: f64,
    /// Exact 8b/8b accuracy on the (self-labeled) split — 1.0 by
    /// construction when labels are the exact engine's own argmax.
    pub acc_exact: f64,
    /// Faulted PAC accuracy without escalation.
    pub acc_plain: f64,
    /// Faulted PAC accuracy with `Fidelity::Auto` escalation.
    pub acc_escalated: f64,
    /// Fraction of images the monitor re-ran through the exact backend.
    pub escalation_rate: f64,
    /// Weight MSB-plane bits flipped over the non-escalating sweep.
    pub weight_bits_flipped: u64,
    /// Encoded-edge transmission bits flipped over the same sweep.
    pub edge_bits_flipped: u64,
    /// Outputs that received PCU sampling noise over the same sweep.
    pub pcu_noise_events: u64,
    /// Fraction of the fault-induced accuracy loss the escalating engine
    /// recovered ([`resilience_recovered`]); `validate_resilience`
    /// recomputes it — a writer cannot cook the gated number.
    pub recovered: f64,
}

/// `BENCH_resilience.json` — fault-injection resilience report
/// (`pacim faultsweep`).
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct ResilienceReport {
    /// Always `"resilience"`.
    pub bench: String,
    pub quick: bool,
    /// Model the sweep evaluated (label + weight source).
    pub model: String,
    /// Images per engine evaluation.
    pub images: usize,
    /// Calibrated escalation margin floor (logit units; the clean-run
    /// margin percentile `pacim faultsweep` chose).
    pub min_margin: f64,
    /// Whether an engine built with `FaultConfig::off()` reproduced the
    /// fault-free engine's logits bit-for-bit on this split.
    pub fault_off_bit_identical: bool,
    pub rows: Vec<ResilienceRow>,
}

/// The operating point [`enforce_resilience`] gates on: the paper-scale
/// "survivable" error rate where escalation must earn its keep.
pub const RESILIENCE_GATE_BER: f64 = 1e-3;

/// Minimum fraction of the fault-induced accuracy loss the escalating
/// engine must recover at [`RESILIENCE_GATE_BER`].
pub const RESILIENCE_RECOVERY_FLOOR: f64 = 0.5;

/// Fraction of the fault-induced loss escalation won back:
/// `(acc_escalated − acc_plain) / (acc_exact − acc_plain)`, 0 when the
/// faulted engine lost nothing. The single definition both the
/// `faultsweep` writer and [`validate_resilience`] use.
pub fn resilience_recovered(acc_exact: f64, acc_plain: f64, acc_escalated: f64) -> f64 {
    let loss = acc_exact - acc_plain;
    if loss <= 0.0 {
        0.0
    } else {
        (acc_escalated - acc_plain) / loss
    }
}

/// Parse + sanity-check a `BENCH_resilience.json` payload.
///
/// Every row's `recovered` is recomputed from its accuracies, and the
/// `ber = 0` reference row must report zero injections — the
/// never-trust-the-writer posture of [`validate_traffic`].
pub fn validate_resilience(json: &str) -> Result<ResilienceReport, String> {
    let r: ResilienceReport = serde_json::from_str(json).map_err(|e| e.to_string())?;
    if r.bench != "resilience" {
        return Err(format!("bench field is '{}', expected 'resilience'", r.bench));
    }
    if r.rows.is_empty() {
        return Err("no sweep rows".into());
    }
    if r.images == 0 {
        return Err("zero images evaluated".into());
    }
    if !(r.min_margin.is_finite() && r.min_margin >= 0.0) {
        return Err(format!("invalid min_margin {}", r.min_margin));
    }
    for row in &r.rows {
        if !(row.ber.is_finite() && (0.0..1.0).contains(&row.ber)) {
            return Err(format!("row ber {} out of [0, 1)", row.ber));
        }
        for (name, acc) in [
            ("acc_exact", row.acc_exact),
            ("acc_plain", row.acc_plain),
            ("acc_escalated", row.acc_escalated),
        ] {
            if !(acc.is_finite() && (0.0..=1.0).contains(&acc)) {
                return Err(format!("row ber {}: {name} out of [0, 1]", row.ber));
            }
        }
        if !(row.escalation_rate.is_finite() && (0.0..=1.0).contains(&row.escalation_rate)) {
            return Err(format!("row ber {}: escalation_rate out of [0, 1]", row.ber));
        }
        let want = resilience_recovered(row.acc_exact, row.acc_plain, row.acc_escalated);
        if !(row.recovered.is_finite() && (row.recovered - want).abs() < 1e-9) {
            return Err(format!(
                "row ber {}: recovered says {} but the accuracies give {want}",
                row.ber, row.recovered
            ));
        }
        if row.ber == 0.0
            && row.weight_bits_flipped + row.edge_bits_flipped + row.pcu_noise_events > 0
        {
            return Err(
                "the ber = 0 reference row reports injections — the fault channels leak \
                 when disabled"
                    .into(),
            );
        }
    }
    for w in r.rows.windows(2) {
        if w[1].ber <= w[0].ber {
            return Err(format!(
                "rows out of order: ber {} follows {}",
                w[1].ber, w[0].ber
            ));
        }
    }
    Ok(r)
}

/// The resilience gate (CI bench-smoke, behind
/// `PACIM_ENFORCE_RESILIENCE`): fault-off runs must be bit-identical to
/// the fault-free engine, the sweep must include the fault-free
/// reference row and the [`RESILIENCE_GATE_BER`] row, the gate row must
/// show the channels actually injected and the monitor actually fired,
/// and — when the faults cost any accuracy — escalation must recover at
/// least [`RESILIENCE_RECOVERY_FLOOR`] of the loss.
pub fn enforce_resilience(r: &ResilienceReport) -> Result<(), String> {
    if !r.fault_off_bit_identical {
        return Err("fault-off run diverged from the fault-free engine".into());
    }
    if !r.rows.iter().any(|row| row.ber == 0.0) {
        return Err("no ber = 0 reference row".into());
    }
    let Some(gate) = r.rows.iter().find(|row| row.ber == RESILIENCE_GATE_BER) else {
        return Err(format!("no row at the gate BER {RESILIENCE_GATE_BER:e}"));
    };
    if gate.weight_bits_flipped + gate.edge_bits_flipped + gate.pcu_noise_events == 0 {
        return Err(format!(
            "gate row (ber {RESILIENCE_GATE_BER:e}) injected nothing — the sweep \
             measured a fault-free engine"
        ));
    }
    if gate.escalation_rate <= 0.0 {
        return Err(format!(
            "gate row (ber {RESILIENCE_GATE_BER:e}): the confidence monitor never fired"
        ));
    }
    let loss = gate.acc_exact - gate.acc_plain;
    if loss > 0.0 && gate.recovered < RESILIENCE_RECOVERY_FLOOR {
        return Err(format!(
            "gate row (ber {RESILIENCE_GATE_BER:e}): escalation recovered {:.3} of the \
             {loss:.4} accuracy loss, below the {RESILIENCE_RECOVERY_FLOOR} floor",
            gate.recovered
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_hotpath() -> HotpathReport {
        HotpathReport {
            bench: "perf_hotpath".into(),
            threads: 4,
            quick: true,
            layers: vec![LayerBench {
                layer: "layer1.0.conv1".into(),
                dp_len: 576,
                pairs: 96,
                scalar_macs_per_s: 1e8,
                parallel_macs_per_s: 3e8,
                speedup: 3.0,
                bit_identical: true,
            }],
            blocked: vec![BlockedBench {
                shape: "layer1.0.conv1".into(),
                dp_len: 576,
                out_c: 64,
                pixels: 256,
                per_patch_macs_per_s: 1e8,
                blocked_macs_per_s: 2e8,
                speedup_blocked: 2.0,
                bit_identical: true,
            }],
            simd: vec![SimdBench {
                shape: "layer1.0.conv1-msbsparse".into(),
                dp_len: 576,
                out_c: 64,
                pixels: 256,
                tier: "avx2".into(),
                msb_sparse_weights: true,
                live_word_fraction: 0.4,
                skip_columns: 64,
                scalar_macs_per_s: 1e8,
                simd_macs_per_s: 2.5e8,
                speedup_simd: 2.5,
                bit_identical: true,
            }],
            fused: vec![FusedBench {
                model: "tiny_resnet_c16".into(),
                images: 4,
                encoded_layers: 14,
                roundtrip_images_per_s: 50.0,
                fused_images_per_s: 55.0,
                speedup_fused: 1.1,
                bit_identical: true,
            }],
        }
    }

    fn sample_traffic() -> TrafficReport {
        TrafficReport {
            bench: "traffic".into(),
            quick: true,
            model: "tiny_resnet_c64".into(),
            images: 1,
            layers: vec![
                TrafficLayerBench {
                    layer: "block3.conv1".into(),
                    kind: "conv".into(),
                    channels: 256,
                    groups: 16,
                    baseline_bits: 16 * 2048,
                    measured_bits: 16 * 1088,
                    analytic_bits: 16 * 1088,
                    reduction: 1.0 - 1088.0 / 2048.0,
                    encoded: true,
                    deep: true,
                },
                TrafficLayerBench {
                    layer: "down2".into(),
                    kind: "conv".into(),
                    channels: 256,
                    groups: 16,
                    baseline_bits: 16 * 2048,
                    measured_bits: 16 * 2048,
                    analytic_bits: 16 * 2048,
                    reduction: 0.0,
                    encoded: false,
                    deep: true,
                },
            ],
            encoded_layers: 1,
            deep_encoded_min_reduction: 1.0 - 1088.0 / 2048.0,
            network_reduction: 1.0 - (1088.0 + 2048.0) / 4096.0,
        }
    }

    #[test]
    fn hotpath_roundtrip() {
        let r = sample_hotpath();
        let json = serde_json::to_string_pretty(&r).unwrap();
        let back = validate_hotpath(&json).unwrap();
        assert_eq!(back.layers.len(), 1);
        assert_eq!(back.blocked.len(), 1);
        assert_eq!(back.simd.len(), 1);
        assert_eq!(back.fused.len(), 1);
    }

    #[test]
    fn simd_rows_validated() {
        // Divergence is a schema error, not just a gate error.
        let mut r = sample_hotpath();
        r.simd[0].bit_identical = false;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_hotpath(&json).unwrap_err().contains("diverged"));
        // Unknown tier strings are rejected.
        let mut r = sample_hotpath();
        r.simd[0].tier = "neon".into();
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_hotpath(&json).unwrap_err().contains("unknown tier"));
        // Density out of range is rejected.
        let mut r = sample_hotpath();
        r.simd[0].live_word_fraction = 1.5;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_hotpath(&json).unwrap_err().contains("live_word_fraction"));
    }

    #[test]
    fn simd_floor_gate() {
        let mut r = sample_hotpath();
        enforce_simd_floor(&r).unwrap();
        // Regression: SIMD slower than the forced-scalar run.
        r.simd[0].speedup_simd = 0.97;
        let err = enforce_simd_floor(&r).unwrap_err();
        assert!(err.contains("regressed"), "{err}");
        // A scalar-resolved tier cannot satisfy the gate.
        r.simd[0].speedup_simd = 1.2;
        r.simd[0].tier = "scalar".into();
        assert!(enforce_simd_floor(&r).unwrap_err().contains("refusing"));
        // No rows cannot pass.
        r.simd.clear();
        assert!(enforce_simd_floor(&r).is_err());
    }

    #[test]
    fn fused_rows_must_be_bit_identical_and_encode() {
        let mut r = sample_hotpath();
        r.fused[0].bit_identical = false;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_hotpath(&json).unwrap_err().contains("diverged"));
        let mut r = sample_hotpath();
        r.fused[0].bit_identical = true;
        r.fused[0].encoded_layers = 0;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_hotpath(&json).unwrap_err().contains("encoded no edges"));
    }

    #[test]
    fn traffic_roundtrip_and_cross_check() {
        let r = sample_traffic();
        let json = serde_json::to_string_pretty(&r).unwrap();
        let back = validate_traffic(&json).unwrap();
        assert_eq!(back.layers.len(), 2);
        enforce_traffic_floor(&back, 0.44).unwrap();

        // Measured bits drifting from the analytic model is a hard error.
        let mut drift = sample_traffic();
        drift.layers[0].measured_bits += 1;
        drift.layers[0].reduction = 1.0 - drift.layers[0].measured_bits as f64
            / drift.layers[0].baseline_bits as f64;
        let json = serde_json::to_string(&drift).unwrap();
        assert!(validate_traffic(&json).unwrap_err().contains("analytic"));

        // A dense edge claiming savings is a hard error too.
        let mut dense = sample_traffic();
        dense.layers[1].measured_bits -= 8;
        dense.layers[1].analytic_bits -= 8;
        dense.layers[1].reduction = 1.0 - dense.layers[1].measured_bits as f64
            / dense.layers[1].baseline_bits as f64;
        let json = serde_json::to_string(&dense).unwrap();
        assert!(validate_traffic(&json).unwrap_err().contains("dense edge"));
    }

    #[test]
    fn traffic_floor_gate() {
        // Below-floor deep encoded row fails the gate.
        let mut r = sample_traffic();
        r.layers[0].measured_bits = 22938; // 30.0% reduction
        r.layers[0].analytic_bits = 22938;
        r.layers[0].reduction = 1.0 - 22938.0 / 32768.0;
        r.deep_encoded_min_reduction = r.layers[0].reduction;
        r.network_reduction = 1.0 - (22938.0 + 32768.0) / 65536.0;
        let json = serde_json::to_string(&r).unwrap();
        let r = validate_traffic(&json).unwrap();
        assert!(enforce_traffic_floor(&r, 0.44).unwrap_err().contains("floor"));
        // A report whose only encoded rows are shallow cannot pass.
        let mut r = sample_traffic();
        r.layers[0].channels = 64;
        r.layers[0].deep = false;
        let json = serde_json::to_string(&r).unwrap();
        let r = validate_traffic(&json).unwrap();
        assert!(enforce_traffic_floor(&r, 0.44).is_err());
    }

    #[test]
    fn traffic_residual_rows_validated() {
        // An edge kind the ledger never emits is a schema error.
        let mut r = sample_traffic();
        r.layers[0].kind = "skipnet".into();
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_traffic(&json).unwrap_err().contains("unknown edge kind"));
        // An encoded residual_in edge is eliminated by definition —
        // reporting moved bits on one means the fused epilogue leaked a
        // dense gather.
        let mut r = sample_traffic();
        r.layers[1].kind = "residual_in".into();
        r.layers[1].encoded = true;
        r.encoded_layers = 2;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_traffic(&json).unwrap_err().contains("eliminated by definition"));
    }

    #[test]
    fn traffic_floor_gate_skips_residual_save_rows() {
        // A deep residual_save row sits *above* its 8-bit baseline (the
        // slot stores all 8 planes plus counters); the floor gate must
        // skip it rather than fail the whole report, while the network
        // summary still counts its bits honestly.
        let mut r = sample_traffic();
        let save_bits = 16 * (2048 + 64); // 8·256 planes + 8·cb(256) counters per group
        r.layers.push(TrafficLayerBench {
            layer: "block3.add(save)".into(),
            kind: "residual_save".into(),
            channels: 256,
            groups: 16,
            baseline_bits: 16 * 2048,
            measured_bits: save_bits,
            analytic_bits: save_bits,
            reduction: 1.0 - save_bits as f64 / (16.0 * 2048.0),
            encoded: true,
            deep: true,
        });
        r.encoded_layers = 2;
        r.network_reduction =
            1.0 - (16.0 * 1088.0 + 16.0 * 2048.0 + save_bits as f64) / (3.0 * 16.0 * 2048.0);
        let json = serde_json::to_string(&r).unwrap();
        let back = validate_traffic(&json).unwrap();
        assert!(back.layers[2].reduction < 0.0, "save rows cost bits by design");
        enforce_traffic_floor(&back, 0.44).unwrap();
    }

    #[test]
    fn traffic_deep_flag_is_recomputed_not_trusted() {
        // A 256-channel encoded row labeled shallow (which would dodge
        // the floor gate) is schema-invalid, not silently exempt.
        let mut r = sample_traffic();
        r.layers[0].deep = false;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_traffic(&json).unwrap_err().contains("deep flag"));
        // So are summary fields that disagree with the rows.
        let mut r = sample_traffic();
        r.deep_encoded_min_reduction = 0.5;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_traffic(&json).unwrap_err().contains("deep_encoded_min_reduction"));
        let mut r = sample_traffic();
        r.network_reduction = 0.5;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_traffic(&json).unwrap_err().contains("network_reduction"));
    }

    #[test]
    fn blocked_floor_gate() {
        let mut r = sample_hotpath();
        enforce_blocked_floor(&r).unwrap();
        // Regression: blocked slower than the per-patch baseline.
        r.blocked[0].speedup_blocked = 0.93;
        let err = enforce_blocked_floor(&r).unwrap_err();
        assert!(err.contains("regressed"), "{err}");
        // Divergence outranks speed.
        r.blocked[0].speedup_blocked = 2.0;
        r.blocked[0].bit_identical = false;
        assert!(enforce_blocked_floor(&r).unwrap_err().contains("diverged"));
        // A report with no blocked rows cannot pass the gate.
        r.blocked.clear();
        assert!(enforce_blocked_floor(&r).is_err());
    }

    fn tune_point(
        accuracy: f64,
        cycles: u64,
        bits: u64,
        lambda: f64,
        on_front: bool,
    ) -> TunePointBench {
        TunePointBench {
            banks: 4,
            rows: 256,
            thresholds: None,
            lambda,
            accuracy,
            avg_digital_cycles: 16.0,
            cycles,
            bits,
            on_front,
        }
    }

    fn sample_tune() -> TuneReport {
        TuneReport {
            bench: "tune".into(),
            quick: true,
            model: "tiny_resnet-synthetic".into(),
            workload: "resnet18-cifar".into(),
            images: 48,
            points: vec![
                tune_point(0.91, 1_000_000, 5_000_000, 0.0, true),
                tune_point(0.91, 1_010_000, 4_800_000, 0.005, true),
                tune_point(0.905, 800_000, 4_600_000, 0.02, true),
                tune_point(0.90, 1_020_000, 5_100_000, 0.0, false),
            ],
            schedules: vec![TuneScheduleBench {
                workload: "resnet18-cifar".into(),
                banks: 4,
                rows: 256,
                lambda: 0.02,
                cycles_cycles_only: 1_000_000,
                bits_cycles_only: 5_000_000,
                cycles_priced: 1_030_000,
                bits_priced: 4_600_000,
                replayed_layers: 3,
            }],
            measured_bits: 1_417_216,
            analytic_bits: 1_417_216,
            residual_bits_encoded: 101_376,
            residual_bits_dense: 180_224,
        }
    }

    #[test]
    fn tune_roundtrip_and_gate() {
        let r = sample_tune();
        let json = serde_json::to_string_pretty(&r).unwrap();
        let back = validate_tune(&json).unwrap();
        assert_eq!(back.points.len(), 4);
        enforce_tune_front(&back).unwrap();
    }

    #[test]
    fn tune_front_flag_is_recomputed_not_trusted() {
        // Promoting the dominated point onto the front is schema-invalid.
        let mut r = sample_tune();
        r.points[3].on_front = true;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_tune(&json).unwrap_err().contains("on_front"));
        // So is hiding a genuine front point.
        let mut r = sample_tune();
        r.points[0].on_front = false;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_tune(&json).unwrap_err().contains("on_front"));
    }

    #[test]
    fn tune_measured_must_match_analytic() {
        let mut r = sample_tune();
        r.measured_bits += 8;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_tune(&json).unwrap_err().contains("analytic"));
    }

    #[test]
    fn tune_front_gate() {
        // Fewer than 3 front points fails.
        let mut r = sample_tune();
        r.points.truncate(2);
        let json = serde_json::to_string(&r).unwrap();
        let r = validate_tune(&json).unwrap();
        assert!(enforce_tune_front(&r).unwrap_err().contains("≥ 3"));
        // A priced schedule with no bit savings fails.
        let mut r = sample_tune();
        r.schedules[0].bits_priced = r.schedules[0].bits_cycles_only;
        let json = serde_json::to_string(&r).unwrap();
        let r = validate_tune(&json).unwrap();
        assert!(enforce_tune_front(&r).unwrap_err().contains("fewer bits"));
        // Savings bought with an unbounded cycle premium fail too.
        let mut r = sample_tune();
        r.schedules[0].cycles_priced = 2_000_000;
        let json = serde_json::to_string(&r).unwrap();
        let r = validate_tune(&json).unwrap();
        assert!(enforce_tune_front(&r).is_err());
        // No comparison rows cannot pass.
        let mut r = sample_tune();
        r.schedules.clear();
        let json = serde_json::to_string(&r).unwrap();
        let r = validate_tune(&json).unwrap();
        assert!(enforce_tune_front(&r).unwrap_err().contains("comparison"));
    }

    #[test]
    fn tune_residual_gate() {
        // The probe must have exercised the fused residual dataplane.
        let mut r = sample_tune();
        r.residual_bits_encoded = 0;
        r.residual_bits_dense = 0;
        let json = serde_json::to_string(&r).unwrap();
        let r = validate_tune(&json).unwrap();
        assert!(enforce_tune_front(&r).unwrap_err().contains("no residual edges"));
        // …and the encoded skip slots must move strictly fewer bits than
        // their dense round-trip.
        let mut r = sample_tune();
        r.residual_bits_encoded = r.residual_bits_dense;
        let json = serde_json::to_string(&r).unwrap();
        let r = validate_tune(&json).unwrap();
        let err = enforce_tune_front(&r).unwrap_err();
        assert!(err.contains("not strictly below"), "{err}");
        // Residual bits exceeding the probe total are schema-invalid.
        let mut r = sample_tune();
        r.residual_bits_encoded = r.measured_bits + 1;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_tune(&json).unwrap_err().contains("exceeds"));
    }

    fn serve_scenario() -> ServeScenario {
        ServeScenario {
            name: "mock-closed".into(),
            executor: "mock".into(),
            model: "tiny_resnet_c8".into(),
            mode: "closed".into(),
            workers: 2,
            batch_size: 4,
            queue_cap: 64,
            shards: 2,
            steals: 3,
            offered_rps: 0.0,
            requests: 10,
            completed: 10,
            rejected: 0,
            failed_batches: 0,
            wall_s: 0.5,
            throughput_rps: 20.0,
            p50_us: 100.0,
            p95_us: 200.0,
            p99_us: 300.0,
            mean_batch_occupancy: 2.5,
            batch_fill: vec![2, 1, 2, 0],
            modeled_cycles_per_image: 0,
            modeled_energy_uj_per_image: 0.0,
            measured_traffic_bits: 4000,
            traffic_baseline_bits: 8000,
            bits_per_request: 400.0,
            escalated: 0,
        }
    }

    #[test]
    fn serve_roundtrip_and_conservation() {
        let r = ServeReport {
            bench: "serve".into(),
            quick: true,
            scenarios: vec![serve_scenario()],
        };
        let json = serde_json::to_string(&r).unwrap();
        validate_serve(&json).unwrap();

        // A cooked bits_per_request is a schema error: the validator
        // recomputes it from measured_traffic_bits / completed.
        let mut cooked = r.clone();
        cooked.scenarios[0].bits_per_request = 100.0;
        let json = serde_json::to_string(&cooked).unwrap();
        assert!(validate_serve(&json).unwrap_err().contains("bits_per_request"));
        // Measured traffic above its dense baseline is rejected too.
        let mut inflated = r.clone();
        inflated.scenarios[0].measured_traffic_bits = 9000;
        inflated.scenarios[0].bits_per_request = 900.0;
        let json = serde_json::to_string(&inflated).unwrap();
        assert!(validate_serve(&json).unwrap_err().contains("baseline"));
        // Steals on a single shard are impossible — schema error.
        let mut lone = r.clone();
        lone.scenarios[0].shards = 1;
        let json = serde_json::to_string(&lone).unwrap();
        assert!(validate_serve(&json).unwrap_err().contains("steal"));
        // So is an anonymous scenario.
        let mut anon = r;
        anon.scenarios[0].model = String::new();
        let json = serde_json::to_string(&anon).unwrap();
        assert!(validate_serve(&json).unwrap_err().contains("model"));
    }

    #[test]
    fn serve_slo_gate() {
        fn mix_row(model: &str, steals: u64) -> ServeScenario {
            ServeScenario {
                name: format!("mix-{model}-open"),
                executor: "pac".into(),
                model: model.into(),
                mode: "open".into(),
                shards: 2,
                steals,
                offered_rps: 40.0,
                throughput_rps: 38.0,
                ..serve_scenario()
            }
        }
        let report = |scenarios: Vec<ServeScenario>| ServeReport {
            bench: "serve".into(),
            quick: true,
            scenarios,
        };
        let good = report(vec![mix_row("resnet18", 4), mix_row("tinyvgg", 0)]);
        enforce_serve_slo(&good).unwrap();

        // Closed-loop-only / single-shard-only reports have nothing to
        // gate — that is a failure, not a pass.
        let err = enforce_serve_slo(&report(vec![serve_scenario()])).unwrap_err();
        assert!(err.contains("no sharded open-loop"), "{err}");
        // One model is not a multi-model measurement.
        let err = enforce_serve_slo(&report(vec![mix_row("resnet18", 4)])).unwrap_err();
        assert!(err.contains("≥ 2"), "{err}");
        // A p99 over the floor fails.
        let mut slow = good.clone();
        slow.scenarios[0].p99_us = SERVE_SLO_P99_FLOOR_US * 2.0;
        assert!(enforce_serve_slo(&slow).unwrap_err().contains("SLO floor"));
        // Zero steals everywhere means the stealing path never ran.
        let mut idle = good.clone();
        idle.scenarios[0].steals = 0;
        assert!(enforce_serve_slo(&idle).unwrap_err().contains("steal"));
        // Collapsed throughput fails.
        let mut starved = good.clone();
        for s in &mut starved.scenarios {
            s.throughput_rps = 5.0;
        }
        assert!(enforce_serve_slo(&starved).unwrap_err().contains("throughput"));
        // A pac row with no measured traffic attribution fails.
        let mut unwired = good.clone();
        unwired.scenarios[1].bits_per_request = 0.0;
        unwired.scenarios[1].measured_traffic_bits = 0;
        assert!(enforce_serve_slo(&unwired).unwrap_err().contains("attribution"));
        // An empty row fails before any aggregate check.
        let mut empty = good;
        empty.scenarios[0].completed = 0;
        assert!(enforce_serve_slo(&empty).unwrap_err().contains("completed nothing"));
    }

    #[test]
    fn unknown_field_rejected() {
        let json = r#"{"bench":"serve","quick":true,"scenarios":[],"extra":1}"#;
        assert!(validate_serve(json).is_err());
    }

    fn resilience_row(ber: f64, acc_plain: f64, acc_escalated: f64) -> ResilienceRow {
        let injected = if ber > 0.0 { (ber * 1e6) as u64 } else { 0 };
        ResilienceRow {
            ber,
            acc_exact: 1.0,
            acc_plain,
            acc_escalated,
            escalation_rate: if acc_escalated > acc_plain { 0.4 } else { 0.1 },
            weight_bits_flipped: injected,
            edge_bits_flipped: injected / 2,
            pcu_noise_events: injected * 3,
            recovered: resilience_recovered(1.0, acc_plain, acc_escalated),
        }
    }

    fn sample_resilience() -> ResilienceReport {
        ResilienceReport {
            bench: "resilience".into(),
            quick: true,
            model: "tiny_resnet-synthetic".into(),
            images: 64,
            min_margin: 1.25,
            fault_off_bit_identical: true,
            rows: vec![
                resilience_row(0.0, 0.92, 0.98),
                resilience_row(1e-3, 0.72, 0.95),
            ],
        }
    }

    #[test]
    fn resilience_roundtrip_and_gate() {
        let r = sample_resilience();
        let json = serde_json::to_string_pretty(&r).unwrap();
        let back = validate_resilience(&json).unwrap();
        assert_eq!(back.rows.len(), 2);
        enforce_resilience(&back).unwrap();
    }

    #[test]
    fn resilience_recovered_is_recomputed_not_trusted() {
        // Cooking the gated number is schema-invalid.
        let mut r = sample_resilience();
        r.rows[1].recovered = 0.99;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_resilience(&json).unwrap_err().contains("recovered"));
        // A ber = 0 row reporting injections means the channels leak
        // when disabled.
        let mut r = sample_resilience();
        r.rows[0].pcu_noise_events = 5;
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_resilience(&json).unwrap_err().contains("leak"));
        // Out-of-order rows are rejected.
        let mut r = sample_resilience();
        r.rows.swap(0, 1);
        let json = serde_json::to_string(&r).unwrap();
        assert!(validate_resilience(&json).unwrap_err().contains("out of order"));
    }

    #[test]
    fn resilience_gate() {
        // Weak recovery at the gate BER fails.
        let mut r = sample_resilience();
        r.rows[1] = resilience_row(1e-3, 0.72, 0.80); // recovers 8 of 28 points
        assert!(enforce_resilience(&r).unwrap_err().contains("floor"));
        // A fault-off divergence is fatal regardless of accuracy.
        let mut r = sample_resilience();
        r.fault_off_bit_identical = false;
        assert!(enforce_resilience(&r).unwrap_err().contains("diverged"));
        // The gate refuses a sweep that never injected at the gate BER.
        let mut r = sample_resilience();
        r.rows[1].weight_bits_flipped = 0;
        r.rows[1].edge_bits_flipped = 0;
        r.rows[1].pcu_noise_events = 0;
        assert!(enforce_resilience(&r).unwrap_err().contains("injected nothing"));
        // …or whose monitor never fired there.
        let mut r = sample_resilience();
        r.rows[1].escalation_rate = 0.0;
        assert!(enforce_resilience(&r).unwrap_err().contains("never fired"));
        // …or that skipped the gate BER / the reference row entirely.
        let mut r = sample_resilience();
        r.rows.remove(1);
        assert!(enforce_resilience(&r).unwrap_err().contains("gate BER"));
        let mut r = sample_resilience();
        r.rows.remove(0);
        assert!(enforce_resilience(&r).unwrap_err().contains("reference"));
        // Lossless gate rows pass without a recovery requirement.
        let mut r = sample_resilience();
        r.rows[1] = resilience_row(1e-3, 1.0, 1.0);
        enforce_resilience(&r).unwrap();
    }

    #[test]
    fn missing_field_rejected() {
        let json = r#"{"bench":"perf_hotpath","threads":4,"layers":[]}"#;
        assert!(validate_hotpath(json).is_err(), "quick field is required");
    }
}
