//! Machine-readable bench-artifact schemas.
//!
//! CI uploads two JSON artifacts per run — `BENCH_hotpath.json`
//! (`benches/perf_hotpath.rs`) and `BENCH_serve.json`
//! (`examples/loadgen.rs`) — to track the perf trajectory across PRs.
//! Regression gating only works if the files stay machine-readable, so
//! the writers serialize *these* structs and `tests/bench_schema.rs`
//! re-parses the emitted files with `deny_unknown_fields`: any schema
//! drift (renamed, added, or removed field) fails the build instead of
//! silently breaking the trend tooling.

use serde::{Deserialize, Serialize};

/// One scalar-vs-parallel PAC MAC measurement (a `BENCH_hotpath.json`
/// row).
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct LayerBench {
    pub layer: String,
    pub dp_len: usize,
    pub pairs: usize,
    pub scalar_macs_per_s: f64,
    pub parallel_macs_per_s: f64,
    pub speedup: f64,
    pub bit_identical: bool,
}

/// One blocked-vs-per-patch layer GEMM measurement (a
/// `BENCH_hotpath.json` row): the layer-level blocked bit-plane kernel
/// (`PacBackend::gemm_layer`, single-thread) against the frozen
/// per-patch engine it replaced (`gemm_per_patch_reference`).
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct BlockedBench {
    /// Layer name from the ResNet-18 shape table.
    pub shape: String,
    pub dp_len: usize,
    pub out_c: usize,
    /// Output pixels fed to one layer-level GEMM call.
    pub pixels: usize,
    pub per_patch_macs_per_s: f64,
    pub blocked_macs_per_s: f64,
    /// `blocked / per_patch` throughput ratio; CI gates this ≥ 1.0 on
    /// every shape ([`enforce_blocked_floor`]).
    pub speedup_blocked: f64,
    pub bit_identical: bool,
}

/// `BENCH_hotpath.json` — hot-path throughput report.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct HotpathReport {
    /// Always `"perf_hotpath"`.
    pub bench: String,
    pub threads: usize,
    pub quick: bool,
    pub layers: Vec<LayerBench>,
    /// Blocked-vs-per-patch layer GEMM rows (single-thread).
    pub blocked: Vec<BlockedBench>,
}

/// One serving scenario (a `BENCH_serve.json` row): an executor driven
/// by one traffic pattern.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct ServeScenario {
    /// `"<executor>-<mode>"`, e.g. `"pac-open"`.
    pub name: String,
    /// `"mock"`, `"pac"`, or `"exact"`.
    pub executor: String,
    /// `"open"` (Poisson arrivals) or `"closed"` (fixed client loop).
    pub mode: String,
    pub workers: usize,
    pub batch_size: usize,
    pub queue_cap: usize,
    /// Offered open-loop rate (req/s); 0 for closed-loop scenarios.
    pub offered_rps: f64,
    /// Requests attempted (admitted + load-shed).
    pub requests: u64,
    pub completed: u64,
    /// Submissions load-shed by admission control.
    pub rejected: u64,
    /// Batches whose execution failed.
    pub failed_batches: u64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_batch_occupancy: f64,
    /// `batch_fill[i]` = batches that carried exactly `i + 1` requests.
    pub batch_fill: Vec<u64>,
    /// Modeled PACiM bit-serial cycles per image (0 = no cost model).
    pub modeled_cycles_per_image: u64,
    /// Modeled PACiM energy per image, µJ (0 = no cost model).
    pub modeled_energy_uj_per_image: f64,
}

/// `BENCH_serve.json` — serving-pipeline report.
#[derive(Debug, Clone, Serialize, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct ServeReport {
    /// Always `"serve"`.
    pub bench: String,
    pub quick: bool,
    pub scenarios: Vec<ServeScenario>,
}

/// Parse + sanity-check a `BENCH_hotpath.json` payload.
pub fn validate_hotpath(json: &str) -> Result<HotpathReport, String> {
    let r: HotpathReport = serde_json::from_str(json).map_err(|e| e.to_string())?;
    if r.bench != "perf_hotpath" {
        return Err(format!("bench field is '{}', expected 'perf_hotpath'", r.bench));
    }
    if r.layers.is_empty() {
        return Err("no layer rows".into());
    }
    for l in &r.layers {
        if !(l.scalar_macs_per_s.is_finite() && l.scalar_macs_per_s > 0.0) {
            return Err(format!("layer '{}' has invalid scalar rate", l.layer));
        }
        if !(l.parallel_macs_per_s.is_finite() && l.parallel_macs_per_s > 0.0) {
            return Err(format!("layer '{}' has invalid parallel rate", l.layer));
        }
    }
    for b in &r.blocked {
        if !(b.per_patch_macs_per_s.is_finite() && b.per_patch_macs_per_s > 0.0) {
            return Err(format!("shape '{}' has invalid per-patch rate", b.shape));
        }
        if !(b.blocked_macs_per_s.is_finite() && b.blocked_macs_per_s > 0.0) {
            return Err(format!("shape '{}' has invalid blocked rate", b.shape));
        }
    }
    Ok(r)
}

/// The blocked-GEMM regression gate (CI bench-smoke): the blocked kernel
/// must stay bit-identical to the per-patch baseline and at least as
/// fast (`speedup_blocked >= 1.0`) on **every** measured shape.
pub fn enforce_blocked_floor(r: &HotpathReport) -> Result<(), String> {
    if r.blocked.is_empty() {
        return Err("no blocked GEMM rows to gate".into());
    }
    for b in &r.blocked {
        if !b.bit_identical {
            return Err(format!("shape '{}': blocked kernel diverged from baseline", b.shape));
        }
        if !(b.speedup_blocked.is_finite() && b.speedup_blocked >= 1.0) {
            return Err(format!(
                "shape '{}': blocked GEMM regressed vs per-patch baseline (speedup {:.3} < 1.0)",
                b.shape, b.speedup_blocked
            ));
        }
    }
    Ok(())
}

/// Parse + sanity-check a `BENCH_serve.json` payload.
pub fn validate_serve(json: &str) -> Result<ServeReport, String> {
    let r: ServeReport = serde_json::from_str(json).map_err(|e| e.to_string())?;
    if r.bench != "serve" {
        return Err(format!("bench field is '{}', expected 'serve'", r.bench));
    }
    if r.scenarios.is_empty() {
        return Err("no scenarios".into());
    }
    for s in &r.scenarios {
        if s.completed + s.rejected > s.requests {
            return Err(format!(
                "scenario '{}': completed {} + rejected {} exceed requests {}",
                s.name, s.completed, s.rejected, s.requests
            ));
        }
        if !(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us) {
            return Err(format!("scenario '{}': percentiles out of order", s.name));
        }
        if s.completed > 0 && !(s.throughput_rps.is_finite() && s.throughput_rps > 0.0) {
            return Err(format!("scenario '{}': invalid throughput", s.name));
        }
        let filled: u64 = s
            .batch_fill
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as u64 + 1) * c)
            .sum();
        if filled != s.completed {
            return Err(format!(
                "scenario '{}': batch_fill accounts for {} requests, completed {}",
                s.name, filled, s.completed
            ));
        }
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_hotpath() -> HotpathReport {
        HotpathReport {
            bench: "perf_hotpath".into(),
            threads: 4,
            quick: true,
            layers: vec![LayerBench {
                layer: "layer1.0.conv1".into(),
                dp_len: 576,
                pairs: 96,
                scalar_macs_per_s: 1e8,
                parallel_macs_per_s: 3e8,
                speedup: 3.0,
                bit_identical: true,
            }],
            blocked: vec![BlockedBench {
                shape: "layer1.0.conv1".into(),
                dp_len: 576,
                out_c: 64,
                pixels: 256,
                per_patch_macs_per_s: 1e8,
                blocked_macs_per_s: 2e8,
                speedup_blocked: 2.0,
                bit_identical: true,
            }],
        }
    }

    #[test]
    fn hotpath_roundtrip() {
        let r = sample_hotpath();
        let json = serde_json::to_string_pretty(&r).unwrap();
        let back = validate_hotpath(&json).unwrap();
        assert_eq!(back.layers.len(), 1);
        assert_eq!(back.blocked.len(), 1);
    }

    #[test]
    fn blocked_floor_gate() {
        let mut r = sample_hotpath();
        enforce_blocked_floor(&r).unwrap();
        // Regression: blocked slower than the per-patch baseline.
        r.blocked[0].speedup_blocked = 0.93;
        let err = enforce_blocked_floor(&r).unwrap_err();
        assert!(err.contains("regressed"), "{err}");
        // Divergence outranks speed.
        r.blocked[0].speedup_blocked = 2.0;
        r.blocked[0].bit_identical = false;
        assert!(enforce_blocked_floor(&r).unwrap_err().contains("diverged"));
        // A report with no blocked rows cannot pass the gate.
        r.blocked.clear();
        assert!(enforce_blocked_floor(&r).is_err());
    }

    #[test]
    fn serve_roundtrip_and_conservation() {
        let r = ServeReport {
            bench: "serve".into(),
            quick: true,
            scenarios: vec![ServeScenario {
                name: "mock-closed".into(),
                executor: "mock".into(),
                mode: "closed".into(),
                workers: 2,
                batch_size: 4,
                queue_cap: 64,
                offered_rps: 0.0,
                requests: 10,
                completed: 10,
                rejected: 0,
                failed_batches: 0,
                wall_s: 0.5,
                throughput_rps: 20.0,
                p50_us: 100.0,
                p95_us: 200.0,
                p99_us: 300.0,
                mean_batch_occupancy: 2.5,
                batch_fill: vec![2, 1, 2, 0],
                modeled_cycles_per_image: 0,
                modeled_energy_uj_per_image: 0.0,
            }],
        };
        let json = serde_json::to_string(&r).unwrap();
        validate_serve(&json).unwrap();
    }

    #[test]
    fn unknown_field_rejected() {
        let json = r#"{"bench":"serve","quick":true,"scenarios":[],"extra":1}"#;
        assert!(validate_serve(json).is_err());
    }

    #[test]
    fn missing_field_rejected() {
        let json = r#"{"bench":"perf_hotpath","threads":4,"layers":[]}"#;
        assert!(validate_hotpath(json).is_err(), "quick field is required");
    }
}
