//! CPU kernel-tier capability probe for the popcount sweeps.
//!
//! The blocked bit-plane GEMM (`nn::pac_exec`) has three interchangeable
//! inner loops — a portable scalar word sweep, an AVX2 lookup-popcount
//! sweep, and (behind the nightly-only `avx512` cargo feature) an
//! AVX-512 `VPOPCNTQ` sweep; see `nn::simd`. All three compute identical
//! integers; the tier only changes host speed. This module decides which
//! tier runs, `Parallelism`-style: a [`KernelCaps`] value is resolved
//! once per backend and threaded into the tile kernels.
//!
//! Resolution precedence (first hit wins):
//! 1. an explicit request from the caller (`PacConfig::kernel`),
//! 2. the `PACIM_FORCE_KERNEL` environment variable
//!    (`scalar`/`avx2`/`avx512`, case-insensitive; anything else is
//!    ignored and resolution falls through to the probe),
//! 3. the runtime CPUID probe (`is_x86_feature_detected!`).
//!
//! Whatever is requested, the resolved tier is **clamped to what the
//! host supports**: [`KernelCaps`] keeps its fields private, so the only
//! way to obtain one is through the clamping constructors, and the
//! `unsafe` `#[target_feature]` kernels in `nn::simd` are therefore
//! unreachable on hardware that lacks the feature. Forcing `scalar` on
//! any machine is always honored (that is the bit-identity escape hatch
//! CI uses); forcing a tier *up* beyond the host silently degrades to
//! the best supported tier.

/// Environment variable overriding kernel-tier selection
/// (`scalar` | `avx2` | `avx512`, case-insensitive).
pub const FORCE_KERNEL_ENV: &str = "PACIM_FORCE_KERNEL";

/// One inner-loop implementation tier, ordered by capability:
/// `Scalar < Avx2 < Avx512`. The ordering is what makes clamping a
/// `min`: a request never resolves above the host's supported tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelTier {
    /// Portable `u64::count_ones` word sweep — runs everywhere and is
    /// the bit-identity reference for the vector tiers.
    Scalar,
    /// 256-bit sweep: `_mm256_*` AND + nibble-lookup popcount
    /// (`_mm256_shuffle_epi8` + `_mm256_sad_epu8`).
    Avx2,
    /// 512-bit sweep using the `VPOPCNTQ` instruction
    /// (`_mm512_popcnt_epi64`). Requires the nightly-only `avx512`
    /// cargo feature; without it the probe never reports this tier.
    Avx512,
}

impl KernelTier {
    /// Canonical lower-case name, matching what [`KernelTier::parse`]
    /// accepts and what bench artifacts record in their `tier` field.
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Avx512 => "avx512",
        }
    }

    /// Parse a tier name (case-insensitive). Unknown names yield `None`
    /// — the env-override path treats that as "no override" rather than
    /// failing, so a typo degrades to auto-detection, never to a panic
    /// deep inside backend construction.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelTier::Scalar),
            "avx2" => Some(KernelTier::Avx2),
            "avx512" => Some(KernelTier::Avx512),
            _ => None,
        }
    }
}

/// Resolved kernel capabilities: which tier the sweeps dispatch to and
/// what the host could support. Fields are private on purpose — the
/// soundness argument for the `unsafe` SIMD kernels (DESIGN.md §13)
/// rests on every `KernelCaps` having been clamped to the probed
/// hardware, so no public constructor may accept an arbitrary tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCaps {
    tier: KernelTier,
    supported: KernelTier,
    forced: bool,
}

impl KernelCaps {
    /// Probe the host and apply the [`FORCE_KERNEL_ENV`] override, if
    /// any. Equivalent to `KernelCaps::select(None)`.
    pub fn detect() -> Self {
        Self::select(None)
    }

    /// Resolve a tier request: an explicit `request` wins over the env
    /// override, which wins over plain auto-detection; the result is
    /// clamped to [`KernelCaps::supported_tier`] either way.
    pub fn select(request: Option<KernelTier>) -> Self {
        let request = request.or_else(env_request);
        let supported = Self::supported_tier();
        Self {
            tier: resolve(request, supported),
            supported,
            forced: request.is_some(),
        }
    }

    /// The tier the sweeps dispatch to. Never exceeds
    /// [`KernelCaps::supported_tier`].
    #[inline]
    pub fn tier(self) -> KernelTier {
        self.tier
    }

    /// The best tier the host CPU (and build configuration) supports.
    pub fn supported(self) -> KernelTier {
        self.supported
    }

    /// Whether the resolved tier came from an explicit request (config
    /// field or env override) rather than plain auto-detection. Purely
    /// informational — bench artifacts record it.
    pub fn forced(self) -> bool {
        self.forced
    }

    /// Runtime probe: the best tier this host supports. AVX-512 is
    /// only ever reported when the nightly-only `avx512` cargo feature
    /// compiled the `VPOPCNTQ` path in; AVX2 is detected on stable via
    /// `is_x86_feature_detected!`; everything else (including non-x86
    /// targets) is `Scalar`.
    pub fn supported_tier() -> KernelTier {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        {
            if std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx512vpopcntdq")
            {
                return KernelTier::Avx512;
            }
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return KernelTier::Avx2;
            }
        }
        KernelTier::Scalar
    }
}

impl Default for KernelCaps {
    fn default() -> Self {
        Self::detect()
    }
}

/// Clamp a request to the supported tier; no request means "best
/// supported". Pure so the clamping rule is unit-testable without
/// depending on the build machine's CPU.
fn resolve(request: Option<KernelTier>, supported: KernelTier) -> KernelTier {
    request.unwrap_or(supported).min(supported)
}

/// Read and parse [`FORCE_KERNEL_ENV`]; unset or unparsable → `None`.
fn env_request() -> Option<KernelTier> {
    std::env::var(FORCE_KERNEL_ENV).ok().and_then(|v| KernelTier::parse(&v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_ignores_unknown() {
        for t in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512] {
            assert_eq!(KernelTier::parse(t.name()), Some(t));
        }
        assert_eq!(KernelTier::parse("AVX2"), Some(KernelTier::Avx2));
        assert_eq!(KernelTier::parse(" Scalar\n"), Some(KernelTier::Scalar));
        assert_eq!(KernelTier::parse("avx-512"), None);
        assert_eq!(KernelTier::parse(""), None);
        assert_eq!(KernelTier::parse("mmx"), None);
    }

    #[test]
    fn resolve_clamps_to_supported() {
        use KernelTier::*;
        // No request → best supported.
        assert_eq!(resolve(None, Scalar), Scalar);
        assert_eq!(resolve(None, Avx512), Avx512);
        // Downward requests always honored.
        assert_eq!(resolve(Some(Scalar), Avx512), Scalar);
        assert_eq!(resolve(Some(Avx2), Avx512), Avx2);
        // Upward requests clamp to the host.
        assert_eq!(resolve(Some(Avx512), Scalar), Scalar);
        assert_eq!(resolve(Some(Avx512), Avx2), Avx2);
        assert_eq!(resolve(Some(Avx2), Avx2), Avx2);
    }

    #[test]
    fn detect_never_selects_unsupported() {
        let caps = KernelCaps::detect();
        assert!(caps.tier() <= caps.supported());
        assert_eq!(caps.supported(), KernelCaps::supported_tier());
        // Explicit requests stay clamped, whatever the host is.
        for req in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512] {
            let c = KernelCaps::select(Some(req));
            assert!(c.tier() <= c.supported(), "request {req:?}");
            assert_eq!(c.tier(), req.min(c.supported()));
            assert!(c.forced());
        }
    }

    #[test]
    fn scalar_request_always_honored() {
        let c = KernelCaps::select(Some(KernelTier::Scalar));
        assert_eq!(c.tier(), KernelTier::Scalar);
    }

    #[test]
    fn env_override_roundtrips() {
        // Tier selection is numerically inert (every tier computes the
        // same integers), so mutating the env var here cannot perturb
        // concurrently running tests — at worst they pick a different
        // speed. Restore the prior state regardless.
        let prior = std::env::var(FORCE_KERNEL_ENV).ok();
        for t in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512] {
            std::env::set_var(FORCE_KERNEL_ENV, t.name());
            let c = KernelCaps::detect();
            assert_eq!(c.tier(), t.min(c.supported()), "env {}", t.name());
            assert!(c.forced());
        }
        // Unparsable values fall through to auto-detection.
        std::env::set_var(FORCE_KERNEL_ENV, "warp-drive");
        let c = KernelCaps::detect();
        assert_eq!(c.tier(), c.supported());
        assert!(!c.forced());
        // An explicit request beats the env override.
        std::env::set_var(FORCE_KERNEL_ENV, "avx2");
        let c = KernelCaps::select(Some(KernelTier::Scalar));
        assert_eq!(c.tier(), KernelTier::Scalar);
        match prior {
            Some(v) => std::env::set_var(FORCE_KERNEL_ENV, v),
            None => std::env::remove_var(FORCE_KERNEL_ENV),
        }
    }

    #[test]
    fn default_is_detect() {
        // Compare only the env-independent parts: `env_override_roundtrips`
        // mutates PACIM_FORCE_KERNEL in a parallel test thread, so two
        // back-to-back detect() calls may legitimately disagree on the
        // resolved tier mid-run; the probed support level cannot change.
        let d = KernelCaps::default();
        assert_eq!(d.supported(), KernelCaps::supported_tier());
        assert!(d.tier() <= d.supported());
    }
}
