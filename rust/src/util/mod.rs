//! Shared utilities: deterministic RNG, streaming statistics, a minimal
//! property-testing harness, bench-artifact schemas, the CPU kernel-tier
//! probe ([`kernel`]), and bit-plane packing helpers used by the hot
//! simulation paths.

pub mod benchfmt;
pub mod check;
pub mod fastdiv;
pub mod kernel;
pub mod par;
pub mod rng;
pub mod stats;

pub use kernel::{KernelCaps, KernelTier};
pub use par::Parallelism;

/// Pack a `{0,1}`-valued byte slice into `u64` words, LSB-first, for
/// popcount-based dot products (the software analogue of the D-CiM adder
/// tree; see `pac::mac`). The tail word is zero-padded.
pub fn pack_bits_u64(bits: &[u8]) -> Vec<u64> {
    let words = (bits.len() + 63) / 64;
    let mut out = vec![0u64; words];
    for (i, &b) in bits.iter().enumerate() {
        debug_assert!(b <= 1, "pack_bits_u64 expects binary input");
        if b != 0 {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
    out
}

/// AND-popcount between two packed bit vectors: `Σ_n a[n] & b[n]` — one
/// binary MAC cycle of a CiM column.
#[inline]
pub fn and_popcount(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for (x, y) in a.iter().zip(b) {
        acc += (x & y).count_ones();
    }
    acc
}

/// Number of `u64` words needed to hold `n` bits.
#[inline]
pub fn words_for(n: usize) -> usize {
    (n + 63) / 64
}

#[cfg(test)]
mod tests {
    use super::*;
    use rng::Rng;

    #[test]
    fn pack_roundtrip() {
        let bits = [1u8, 0, 1, 1, 0, 0, 0, 1];
        let packed = pack_bits_u64(&bits);
        assert_eq!(packed.len(), 1);
        assert_eq!(packed[0], 0b1000_1101);
    }

    #[test]
    fn pack_multi_word() {
        let mut bits = vec![0u8; 130];
        bits[0] = 1;
        bits[64] = 1;
        bits[129] = 1;
        let packed = pack_bits_u64(&bits);
        assert_eq!(packed.len(), 3);
        assert_eq!(packed[0], 1);
        assert_eq!(packed[1], 1);
        assert_eq!(packed[2], 2);
    }

    #[test]
    fn and_popcount_matches_naive() {
        let mut rng = Rng::new(99);
        for n in [1usize, 63, 64, 65, 1000, 1024] {
            let a = rng.binary_bernoulli(n, 0.4);
            let b = rng.binary_bernoulli(n, 0.6);
            let naive: u32 = a.iter().zip(&b).map(|(&x, &y)| (x & y) as u32).sum();
            let fast = and_popcount(&pack_bits_u64(&a), &pack_bits_u64(&b));
            assert_eq!(naive, fast, "n={n}");
        }
    }
}
