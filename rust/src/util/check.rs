//! Minimal property-based testing harness.
//!
//! `proptest` is not available in the offline vendor set, so we grew the
//! 10% of it we need: run a property over many seeded random cases, and on
//! failure report the seed + case index so the exact case replays with
//! `Checker::replay`.
//!
//! Usage:
//! ```
//! use pacim::util::check::Checker;
//! Checker::new("popcount_roundtrip", 256).run(|rng| {
//!     let n = 1 + rng.below(64) as usize;
//!     let v = rng.binary_bernoulli(n, 0.5);
//!     let pop: usize = v.iter().map(|&b| b as usize).sum();
//!     assert!(pop <= n);
//! });
//! ```

use super::rng::Rng;

/// Property-test driver. Each case gets an `Rng` derived from
/// `(base_seed, case_index)` so any failing case can be replayed in
/// isolation.
pub struct Checker {
    name: &'static str,
    cases: u64,
    base_seed: u64,
}

impl Checker {
    pub fn new(name: &'static str, cases: u64) -> Self {
        // A fixed default base seed keeps CI deterministic; override with
        // PACIM_CHECK_SEED for exploratory fuzzing.
        let base_seed = std::env::var("PACIM_CHECK_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_CAFE);
        Self {
            name,
            cases,
            base_seed,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }

    fn case_rng(&self, idx: u64) -> Rng {
        // Mix name into the stream so distinct properties see distinct data
        // even with the same base seed.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Rng::new(self.base_seed ^ h ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Run the property over all cases. Panics (with replay info) on the
    /// first failing case.
    pub fn run<F: FnMut(&mut Rng)>(&self, mut prop: F) {
        for idx in 0..self.cases {
            let mut rng = self.case_rng(idx);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut rng)
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property '{}' failed at case {}/{} (replay: Checker::new(..).with_seed({:#x}).replay({})): {}",
                    self.name, idx, self.cases, self.base_seed, idx, msg
                );
            }
        }
    }

    /// Re-run a single case by index (for debugging a reported failure).
    pub fn replay<F: FnMut(&mut Rng)>(&self, idx: u64, mut prop: F) {
        let mut rng = self.case_rng(idx);
        prop(&mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Checker::new("trivial", 64).run(|rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    fn reports_failure_with_replay_info() {
        let res = std::panic::catch_unwind(|| {
            Checker::new("always_fails", 8).run(|_| {
                panic!("intentional");
            });
        });
        let err = res.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always_fails"), "{msg}");
        assert!(msg.contains("case 0/8"), "{msg}");
    }

    #[test]
    fn replay_matches_run_case() {
        // The value observed in case 3 of `run` must equal what `replay(3)`
        // produces.
        let c = Checker::new("replay_match", 8).with_seed(123);
        let mut seen = Vec::new();
        c.run(|rng| seen.push(rng.next_u64()));
        let mut replayed = 0;
        c.replay(3, |rng| replayed = rng.next_u64());
        assert_eq!(seen[3], replayed);
    }
}
