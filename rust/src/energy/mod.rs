//! Calibrated 65 nm energy / area model (§6.2, Table 3, Table 4, Fig. 7).
//!
//! The paper evaluates PACiM by composing per-block numbers: the D-CiM
//! bank spec is taken from ISSCC'21 [6] normalized to 65 nm, and the CnM
//! processing unit was synthesized with Design Compiler + IC Compiler.
//! We cannot re-run a 65 nm flow, so the published per-block constants are
//! encoded here as the calibration points and every system-level figure is
//! recomputed *structurally* from them (DESIGN.md §3, §7). Anything that
//! scales with cycle counts, DP lengths, or traffic is computed — only the
//! leaf constants are quoted.

pub mod area;
pub mod timing;

/// Supply voltage operating point. Energy scales with V² (the paper's
/// 0.6 V / 1.2 V pairs follow this: 235.01/58.72 ≈ 4.00).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Supply {
    V06,
    V12,
}

impl Supply {
    /// Energy multiplier relative to the 0.6 V calibration point.
    pub fn energy_scale(self) -> f64 {
        match self {
            Supply::V06 => 1.0,
            Supply::V12 => 4.0, // (1.2/0.6)²
        }
    }
}

/// 1 TOPS/W ⇔ 1 op/pJ. Helper to convert.
#[inline]
pub fn tops_per_watt_to_pj_per_op(tops_w: f64) -> f64 {
    1.0 / tops_w
}

/// The calibrated energy model. All energies in pJ at 0.6 V, 65 nm.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// D-CiM energy per binary op (1b/1b MAC = 2 ops). Table 3: 235.01
    /// TOPS/W → 1/235.01 pJ/op.
    pub dcim_pj_per_op: f64,
    /// PCU + accumulator energy per *equivalent* binary op (Table 3:
    /// 2945.92 TOPS/W). One physical PCU multiply-divide covers an entire
    /// (p,q) cycle over the DP vector, so its energy is amortized n ways;
    /// the equivalent-op form is what composes across the map.
    pub pcu_pj_per_op: f64,
    /// CnM buffer + encoder overhead as a fraction of CnM compute energy
    /// (Fig. 7(c): the buffer is ~70% of CnM power ⇒ compute is ~30%).
    pub cnm_buffer_overhead: f64,
    /// Memory access energies (§2.1).
    pub sram_pj_per_16b: f64,
    pub dram_pj_per_access: f64,
    pub mac16_pj: f64,
    /// Equivalent 1b cycles per 8b/8b MAC used by the paper's
    /// normalization (1170.28 / 14.63 = 80 = 64 MAC + 16 shift-acc).
    pub cycles_per_8b_mac: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            dcim_pj_per_op: 1.0 / 235.01,
            pcu_pj_per_op: 1.0 / 2945.92,
            cnm_buffer_overhead: 0.7 / 0.3, // buffer ≈ 70% of CnM power
            sram_pj_per_16b: 30.375,
            dram_pj_per_access: 200.0,
            mac16_pj: 0.075,
            cycles_per_8b_mac: 80.0,
        }
    }
}

/// Efficiency summary for a computation split across the two domains.
#[derive(Debug, Clone, Copy)]
pub struct Efficiency {
    /// 1b/1b-normalized TOPS/W.
    pub tops_w_1b: f64,
    /// 8b/8b TOPS/W (1b value / cycles_per_8b_mac).
    pub tops_w_8b: f64,
    /// Total energy per 8b/8b MAC in pJ.
    pub pj_per_8b_mac: f64,
}

impl EnergyModel {
    pub fn at_supply(&self, s: Supply) -> EnergyModel {
        let k = s.energy_scale();
        EnergyModel {
            dcim_pj_per_op: self.dcim_pj_per_op * k,
            pcu_pj_per_op: self.pcu_pj_per_op * k,
            ..self.clone()
        }
    }

    /// Pure D-CiM 1b/1b efficiency (Table 3 column 1).
    pub fn dcim_tops_w(&self) -> f64 {
        1.0 / self.dcim_pj_per_op
    }

    /// PCU + accumulator 1b/1b efficiency (Table 3 column 2).
    pub fn pcu_tops_w(&self) -> f64 {
        1.0 / self.pcu_pj_per_op
    }

    /// Hybrid efficiency for a computation that executes `digital` cycles
    /// in the D-CiM domain and `sparsity` cycles in the sparsity domain,
    /// out of the 64 binary cycles of an 8b/8b MAC. All 64 cycles' worth
    /// of arithmetic is delivered either way, so the op count is 64 (+16
    /// shift-acc overhead under the paper's normalization).
    pub fn hybrid_efficiency(&self, digital_cycles: f64, sparsity_cycles: f64) -> Efficiency {
        let total_ops = digital_cycles + sparsity_cycles;
        debug_assert!((total_ops - 64.0).abs() < 1e-9);
        let energy =
            digital_cycles * self.dcim_pj_per_op + sparsity_cycles * self.pcu_pj_per_op;
        let tops_w_1b = total_ops / energy;
        Efficiency {
            tops_w_1b,
            tops_w_8b: tops_w_1b / self.cycles_per_8b_mac,
            pj_per_8b_mac: energy * self.cycles_per_8b_mac / 64.0,
        }
    }

    /// The paper's headline composition: 4-bit operand approximation
    /// (16 digital / 48 sparsity).
    pub fn pacim_static(&self) -> Efficiency {
        self.hybrid_efficiency(16.0, 48.0)
    }

    /// Peak operating point: dynamic workload configuration at its
    /// minimum digital budget (10 cycles, §5). This is the configuration
    /// under which the paper quotes peak TOPS/W.
    pub fn pacim_peak(&self) -> Efficiency {
        self.hybrid_efficiency(10.0, 54.0)
    }

    /// Fully digital 8b/8b baseline (64 digital cycles).
    pub fn digital_8b(&self) -> Efficiency {
        self.hybrid_efficiency(64.0, 0.0)
    }

    /// Energy (pJ) of running a layer given cycle/traffic tallies from the
    /// architecture simulator. `dcim_ops`/`pcu_ops` are equivalent binary
    /// op counts; traffic in bits.
    pub fn layer_energy_pj(
        &self,
        dcim_ops: f64,
        pcu_ops: f64,
        sram_bits: f64,
        dram_bits: f64,
    ) -> f64 {
        let compute = dcim_ops * self.dcim_pj_per_op
            + pcu_ops * self.pcu_pj_per_op * (1.0 + self.cnm_buffer_overhead);
        let mem = sram_bits / 16.0 * self.sram_pj_per_16b
            + dram_bits / 64.0 * self.dram_pj_per_access;
        compute + mem
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_dcim_and_pcu_match_paper() {
        let m = EnergyModel::default();
        assert!((m.dcim_tops_w() - 235.01).abs() < 0.01);
        assert!((m.pcu_tops_w() - 2945.92).abs() < 0.01);
        // 12× improvement claim (§4.4).
        let ratio = m.pcu_tops_w() / m.dcim_tops_w();
        assert!((ratio - 12.5).abs() < 0.1, "ratio={ratio}");
    }

    #[test]
    fn supply_scaling_matches_table3() {
        let m = EnergyModel::default().at_supply(Supply::V12);
        assert!((m.dcim_tops_w() - 58.75).abs() < 0.1); // paper: 58.72
        assert!((m.pcu_tops_w() - 736.48).abs() < 1.0);
    }

    #[test]
    fn hybrid_is_about_5x_digital() {
        // §6.2: the 8b/8b hybrid system is ≈5× a fully digital system.
        let m = EnergyModel::default();
        let hybrid = m.pacim_peak().tops_w_1b;
        let digital = m.digital_8b().tops_w_1b;
        let ratio = hybrid / digital;
        assert!(
            (4.0..5.5).contains(&ratio),
            "hybrid/digital = {ratio}, paper claims ≈5×"
        );
    }

    #[test]
    fn peak_8b_efficiency_ballpark() {
        // Paper: 14.63 TOPS/W at 8b/8b (peak). Our structural composition
        // gives the same order: between the static (9.5) and the paper's
        // peak — we assert the reproduction band rather than the exact
        // value (see DESIGN.md §7).
        let m = EnergyModel::default();
        let peak = m.pacim_peak().tops_w_8b;
        let stat = m.pacim_static().tops_w_8b;
        assert!(stat > 8.0, "static {stat}");
        assert!(peak > 12.0, "peak {peak}");
        assert!(peak < 20.0, "peak {peak}");
    }

    #[test]
    fn digital_8b_matches_1b_over_80() {
        let m = EnergyModel::default();
        let d = m.digital_8b();
        assert!((d.tops_w_1b - 235.01).abs() < 1e-9);
        assert!((d.tops_w_8b - 235.01 / 80.0).abs() < 1e-9);
    }

    #[test]
    fn memory_dominates_without_reuse() {
        // §2.1: a 16b MAC is 0.075 pJ vs 30.375 pJ per SRAM access — the
        // 400× disparity that motivates the sparsity encoding.
        let m = EnergyModel::default();
        assert!((m.sram_pj_per_16b / m.mac16_pj - 405.0).abs() < 1.0);
    }

    #[test]
    fn layer_energy_monotone_in_traffic() {
        let m = EnergyModel::default();
        let base = m.layer_energy_pj(1e6, 1e6, 1e6, 0.0);
        let more = m.layer_energy_pj(1e6, 1e6, 2e6, 0.0);
        assert!(more > base);
    }
}
