//! Clock/timing model: turns scheduler cycle counts into latency and
//! TOPS so the coordinator can report both efficiency (TOPS/W) *and*
//! throughput — the axis Table 4's "Peak TOPS/W" implies but the paper
//! only reports indirectly.
//!
//! Calibration: digital SRAM-CiM macros of the ISSCC'21 [6] generation
//! clock their bit-serial arrays at 100–200 MHz at low supply; we expose
//! the frequency as a parameter (default 100 MHz @ 0.6 V, scaling
//! linearly with supply per the usual near-threshold approximation).

use super::Supply;
use crate::coordinator::scheduler::ModelReport;

/// Timing parameters of one PACiM bank.
#[derive(Debug, Clone, Copy)]
pub struct TimingModel {
    /// Bit-serial array clock (Hz) at 0.6 V.
    pub clock_hz: f64,
    /// Cycles to update one weight row (write driver latency).
    pub weight_write_cycles: f64,
    /// PCU multiply-divide latency in array cycles (pipelined: the PCE
    /// keeps up with the array when `pcus * throughput >= demand`, §4.4).
    pub pcu_cycles_per_op: f64,
    /// Parallel banks.
    pub banks: usize,
}

impl Default for TimingModel {
    fn default() -> Self {
        Self {
            clock_hz: 100e6,
            weight_write_cycles: 1.0,
            pcu_cycles_per_op: 1.0,
            banks: 1,
        }
    }
}

/// Latency/throughput summary for one model run.
#[derive(Debug, Clone, Copy)]
pub struct TimingReport {
    pub compute_s: f64,
    pub weight_update_s: f64,
    pub total_s: f64,
    /// Deliverable ops/s counting all 64 binary cycles per 8b/8b MAC ×2.
    pub effective_tops: f64,
}

impl TimingModel {
    pub fn at_supply(&self, s: Supply) -> TimingModel {
        let f = match s {
            Supply::V06 => 1.0,
            Supply::V12 => 2.0, // ~linear f-V in the near-threshold regime
        };
        TimingModel {
            clock_hz: self.clock_hz * f,
            ..*self
        }
    }

    /// Timing for a scheduled model (per image).
    pub fn model_timing(&self, rep: &ModelReport, total_macs: f64) -> TimingReport {
        let cycles: u64 = rep.total_macs_cycles();
        let weight_writes: f64 = rep
            .layers
            .iter()
            .map(|l| l.weight_loads as f64 * 256.0 * self.weight_write_cycles)
            .sum();
        // PCE runs concurrently with the array (weight-stationary); it
        // adds latency only if it outpaces the array — modeled as the max.
        let pcu_cycles: f64 = rep.total_pcu_ops() / 256.0 * self.pcu_cycles_per_op;
        let compute_cycles = (cycles as f64).max(pcu_cycles / 6.0);
        let compute_s = compute_cycles / self.clock_hz / self.banks as f64;
        let weight_update_s = weight_writes / self.clock_hz / self.banks as f64;
        let total_s = compute_s + weight_update_s;
        TimingReport {
            compute_s,
            weight_update_s,
            total_s,
            effective_tops: total_macs * 2.0 / total_s / 1e12,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{schedule_model, ScheduleConfig};
    use crate::workload::{resnet18, Resolution};

    #[test]
    fn pacim_faster_than_digital() {
        let shapes = resnet18(Resolution::Cifar, 10);
        let tm = TimingModel::default();
        let total_macs: f64 = shapes.iter().map(|s| s.macs() as f64).sum();
        let dig = tm.model_timing(
            &schedule_model(&shapes, &ScheduleConfig::digital_baseline()),
            total_macs,
        );
        let pac = tm.model_timing(
            &schedule_model(&shapes, &ScheduleConfig::pacim_default()),
            total_macs,
        );
        // 75% fewer bit-serial cycles → ~4x faster compute.
        assert!(pac.compute_s < dig.compute_s * 0.3,
            "pac {} vs dig {}", pac.compute_s, dig.compute_s);
        assert!(pac.effective_tops > dig.effective_tops * 2.0);
    }

    #[test]
    fn supply_scales_clock() {
        let tm = TimingModel::default();
        assert_eq!(tm.at_supply(Supply::V12).clock_hz, 2.0 * tm.clock_hz);
    }

    #[test]
    fn multibank_scales_throughput() {
        let shapes = resnet18(Resolution::Cifar, 10);
        let total_macs: f64 = shapes.iter().map(|s| s.macs() as f64).sum();
        let rep = schedule_model(&shapes, &ScheduleConfig::pacim_default());
        let t1 = TimingModel::default().model_timing(&rep, total_macs);
        let t4 = TimingModel { banks: 4, ..Default::default() }.model_timing(&rep, total_macs);
        assert!((t4.total_s - t1.total_s / 4.0).abs() / t1.total_s < 1e-9);
    }

    #[test]
    fn weight_updates_accounted() {
        let shapes = resnet18(Resolution::Cifar, 10);
        let total_macs: f64 = shapes.iter().map(|s| s.macs() as f64).sum();
        let rep = schedule_model(&shapes, &ScheduleConfig::pacim_default());
        let t = TimingModel::default().model_timing(&rep, total_macs);
        assert!(t.weight_update_s > 0.0);
        assert!(t.total_s > t.compute_s);
    }
}
