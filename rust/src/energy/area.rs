//! Area model and the Fig. 7(c) area/power breakdown.
//!
//! Calibration points (65 nm): one PCU + accumulator (register files +
//! arithmetic) = 8640 µm² (§4.4); the CnM processing unit is ~10% of the
//! single-bank system area and ~30% of its power, with the CnM buffer
//! accounting for >50% of CnM area and ~70% of CnM power (Fig. 7(c)).

/// Area/power shares of one PACiM bank (single-bank system).
#[derive(Debug, Clone)]
pub struct BankBreakdown {
    /// µm² per named block.
    pub area_um2: Vec<(&'static str, f64)>,
    /// Relative power per named block (sums to 1).
    pub power_frac: Vec<(&'static str, f64)>,
}

/// Configuration for the area model.
#[derive(Debug, Clone)]
pub struct AreaModel {
    /// µm² of one PCU + accumulator (§4.4 calibration point).
    pub pcu_um2: f64,
    /// PCUs per PCE (6 match a 64-accumulator bank's throughput, §6.2).
    pub pcus_per_pce: usize,
    /// CnM fraction of total bank area (Fig. 7(c): ≈10%).
    pub cnm_area_frac: f64,
    /// Buffer fraction of CnM area (Fig. 7(c): >50%).
    pub buffer_of_cnm_area: f64,
    /// CnM fraction of total bank power (Fig. 7(c): ≈30%).
    pub cnm_power_frac: f64,
    /// Buffer fraction of CnM power (Fig. 7(c): ≈70%).
    pub buffer_of_cnm_power: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self {
            pcu_um2: 8640.0,
            pcus_per_pce: 6,
            cnm_area_frac: 0.10,
            buffer_of_cnm_area: 0.55,
            cnm_power_frac: 0.30,
            buffer_of_cnm_power: 0.70,
        }
    }
}

impl AreaModel {
    /// Area of the PCE compute portion (PCUs + accumulators).
    pub fn pce_compute_um2(&self) -> f64 {
        self.pcu_um2 * self.pcus_per_pce as f64
    }

    /// Total CnM area implied by the compute/buffer/encoder shares:
    /// compute+encoder = (1 − buffer_share) of CnM.
    pub fn cnm_total_um2(&self) -> f64 {
        // PCE compute ≈ 80% of the non-buffer CnM area (the rest is the
        // sparsity encoder + control), per the Fig. 7(c) proportions.
        let non_buffer = self.pce_compute_um2() / 0.8;
        non_buffer / (1.0 - self.buffer_of_cnm_area)
    }

    /// Total single-bank system area implied by the CnM share.
    pub fn bank_total_um2(&self) -> f64 {
        self.cnm_total_um2() / self.cnm_area_frac
    }

    /// Fig. 7(c)-style breakdown.
    pub fn breakdown(&self) -> BankBreakdown {
        let cnm = self.cnm_total_um2();
        let bank = self.bank_total_um2();
        let dcim = bank - cnm;
        let buffer = cnm * self.buffer_of_cnm_area;
        let encoder = (cnm - buffer) * 0.2;
        let pce = cnm - buffer - encoder;
        let cnm_p = self.cnm_power_frac;
        let buf_p = cnm_p * self.buffer_of_cnm_power;
        let enc_p = (cnm_p - buf_p) * 0.25;
        let pce_p = cnm_p - buf_p - enc_p;
        BankBreakdown {
            area_um2: vec![
                ("D-CiM banks", dcim),
                ("CnM buffer", buffer),
                ("CnM PCE", pce),
                ("CnM encoder", encoder),
            ],
            power_frac: vec![
                ("D-CiM banks", 1.0 - cnm_p),
                ("CnM buffer", buf_p),
                ("CnM PCE", pce_p),
                ("CnM encoder", enc_p),
            ],
        }
    }

    /// Bit-cell area saving from LSB-column elimination (§6.1): removing
    /// the 4 LSB weight columns halves the weight storage of each MWC.
    pub fn bitcell_saving(&self, kept_weight_bits: u32) -> f64 {
        1.0 - kept_weight_bits as f64 / 8.0
    }

    /// Multi-bank system: the intermediate encoding buffer can be removed
    /// (§4.5 "Tiling Multiple Banks"), shrinking CnM area by the buffer
    /// share.
    pub fn multibank_cnm_um2(&self) -> f64 {
        self.cnm_total_um2() * (1.0 - self.buffer_of_cnm_area)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcu_area_calibration() {
        let m = AreaModel::default();
        assert_eq!(m.pce_compute_um2(), 8640.0 * 6.0);
    }

    #[test]
    fn cnm_is_10pct_of_bank() {
        let m = AreaModel::default();
        let frac = m.cnm_total_um2() / m.bank_total_um2();
        assert!((frac - 0.10).abs() < 1e-9);
    }

    #[test]
    fn breakdown_sums() {
        let m = AreaModel::default();
        let b = m.breakdown();
        let area_sum: f64 = b.area_um2.iter().map(|(_, a)| a).sum();
        assert!((area_sum - m.bank_total_um2()).abs() / area_sum < 1e-9);
        let p_sum: f64 = b.power_frac.iter().map(|(_, p)| p).sum();
        assert!((p_sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn buffer_dominates_cnm() {
        // Fig. 7(c): buffer >50% of CnM area, ≈70% of CnM power.
        let m = AreaModel::default();
        let b = m.breakdown();
        let buf_area = b.area_um2.iter().find(|(n, _)| *n == "CnM buffer").unwrap().1;
        assert!(buf_area / m.cnm_total_um2() > 0.5);
        let buf_p = b.power_frac.iter().find(|(n, _)| *n == "CnM buffer").unwrap().1;
        assert!((buf_p / 0.30 - 0.70).abs() < 1e-9);
    }

    #[test]
    fn lsb_elimination_halves_bitcells() {
        let m = AreaModel::default();
        assert!((m.bitcell_saving(4) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn multibank_removes_buffer() {
        let m = AreaModel::default();
        assert!(m.multibank_cnm_um2() < m.cnm_total_um2() * 0.5);
    }
}
