//! uint8 affine quantization — the numerical contract shared with the
//! python build path (`python/compile/quant_utils.py` mirrors this file
//! bit-for-bit; `python/tests/test_quant.py` + `rust/tests/proptests.rs`
//! enforce the equivalence on random tensors).
//!
//! Scheme (per-tensor, asymmetric, uint8 — the paper quantizes both
//! weights and activations to UINT8 before bit-serial decomposition,
//! Eq. 1):
//!
//! ```text
//! q = clamp(round(x / scale) + zero_point, 0, 255)
//! x ≈ scale · (q − zero_point)
//! ```
//!
//! Integer GEMM + requantization follows the gemmlowp recipe: the i32
//! accumulator is scaled by a fixed-point multiplier `(m0, shift)` with
//! `m_real = m0 · 2^shift`, `m0 ∈ [0.5, 1)` as Q31.

use crate::tensor::{QuantParams, Tensor};

/// Choose quantization parameters covering `[lo, hi]` (min-max
/// calibration). The range is widened to include 0 so that the zero point
/// is exactly representable — required for zero-point padding in im2col.
pub fn calibrate_minmax(lo: f32, hi: f32) -> QuantParams {
    let lo = lo.min(0.0);
    let hi = hi.max(0.0);
    let span = (hi - lo).max(1e-8);
    let scale = span / 255.0;
    let zp = (-lo / scale).round() as i32;
    QuantParams::new(scale, zp.clamp(0, 255))
}

/// Calibrate over a tensor's values.
pub fn calibrate_tensor(t: &Tensor<f32>) -> QuantParams {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in t.data() {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() || !hi.is_finite() {
        lo = 0.0;
        hi = 1.0;
    }
    calibrate_minmax(lo, hi)
}

/// Quantize an f32 tensor with the given params.
pub fn quantize(t: &Tensor<f32>, p: QuantParams) -> Tensor<u8> {
    t.map(|x| p.quantize(x))
}

/// Symmetric "shifted-uint8" weight quantization used by the CiM mapping:
/// zero point pinned to 128 so every weight bit-plane is well-defined and
/// the MSB column of the D-CiM array carries the sign information
/// (`w_real = scale · (q − 128)`).
pub fn calibrate_weights_symmetric(t: &Tensor<f32>) -> QuantParams {
    let max_abs = t.data().iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-8);
    QuantParams::new(max_abs / 127.0, 128)
}

/// Fixed-point requantization multiplier: represents `m_real ∈ (0, 1)` as
/// `m0 · 2^-n` with `m0` a Q31 integer in `[2^30, 2^31)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Requant {
    pub m0: i32,
    /// Right-shift amount (≥ 0 for m_real < 1).
    pub shift: i32,
}

impl Requant {
    /// Decompose a positive real multiplier.
    pub fn from_real(m_real: f64) -> Self {
        assert!(m_real > 0.0, "requant multiplier must be positive");
        let mut shift = 0i32;
        let mut m = m_real;
        while m < 0.5 {
            m *= 2.0;
            shift += 1;
        }
        while m >= 1.0 {
            m /= 2.0;
            shift -= 1;
        }
        // m ∈ [0.5, 1) → Q31 in [2^30, 2^31)
        let mut m0 = (m * (1u64 << 31) as f64).round() as i64;
        if m0 == (1i64 << 31) {
            m0 /= 2;
            shift -= 1;
        }
        Self {
            m0: m0 as i32,
            shift,
        }
    }

    pub fn to_real(self) -> f64 {
        self.m0 as f64 / (1u64 << 31) as f64 * 2f64.powi(-self.shift)
    }

    /// Apply to an i32 accumulator: rounding doubled high-mul then rounding
    /// right shift (gemmlowp `SaturatingRoundingDoublingHighMul` +
    /// `RoundingDivideByPOT`).
    #[inline]
    pub fn apply(self, acc: i32) -> i32 {
        let prod = (acc as i64) * (self.m0 as i64);
        // Rounding doubling high mul: (2·prod + 2^30) >> 31, saturating.
        let nudged = prod.saturating_add(1 << 30);
        let high = (nudged >> 31) as i32;
        if self.shift <= 0 {
            // Left shift (multiplier ≥ 1): saturating.
            return high.saturating_mul(1i32 << (-self.shift).min(30));
        }
        // Rounding right shift.
        let mask = (1i32 << self.shift) - 1;
        let remainder = high & mask;
        let threshold = (mask >> 1) + ((high < 0) as i32);
        (high >> self.shift) + ((remainder > threshold) as i32)
    }
}

/// Requantize the accumulator of a quantized GEMM back to uint8:
/// `out_q = clamp(zp_out + requant(acc), 0, 255)`.
#[inline]
pub fn requantize_acc(acc: i32, r: Requant, zp_out: i32) -> u8 {
    (zp_out + r.apply(acc)).clamp(0, 255) as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn minmax_includes_zero() {
        let p = calibrate_minmax(0.5, 4.0); // lo must widen to 0
        assert_eq!(p.zero_point, 0);
        assert!((p.dequantize(p.quantize(0.0))).abs() < 1e-6);
    }

    #[test]
    fn minmax_negative_range() {
        let p = calibrate_minmax(-2.0, 2.0);
        let q0 = p.quantize(0.0);
        assert!((p.dequantize(q0)).abs() < p.scale / 2.0 + 1e-7);
        // Full range representable without saturation beyond half ulp.
        assert!((p.dequantize(p.quantize(-2.0)) + 2.0).abs() <= p.scale);
        assert!((p.dequantize(p.quantize(2.0)) - 2.0).abs() <= p.scale);
    }

    #[test]
    fn symmetric_weights_zp128() {
        let t = Tensor::from_vec(&[4], vec![-1.0f32, 0.5, 0.25, 1.0]);
        let p = calibrate_weights_symmetric(&t);
        assert_eq!(p.zero_point, 128);
        let q = p.quantize(-1.0);
        assert_eq!(q, 128 - 127);
    }

    #[test]
    fn requant_roundtrip_precision() {
        for &m in &[0.25f64, 0.017, 0.5, 0.9999, 1.5, 0.0001] {
            let r = Requant::from_real(m);
            assert!(
                (r.to_real() - m).abs() / m < 1e-8,
                "m={m} got {}",
                r.to_real()
            );
        }
    }

    #[test]
    fn requant_apply_matches_float() {
        let mut rng = Rng::new(77);
        for _ in 0..2000 {
            let m = 0.001 + rng.next_f64() * 0.8;
            let r = Requant::from_real(m);
            let acc = rng.range_i64(-1_000_000, 1_000_000) as i32;
            let got = r.apply(acc);
            let want = (acc as f64 * m).round();
            assert!(
                (got as f64 - want).abs() <= 1.0,
                "acc={acc} m={m} got={got} want={want}"
            );
        }
    }

    #[test]
    fn requantize_saturates_to_u8() {
        let r = Requant::from_real(1.0);
        assert_eq!(requantize_acc(10_000, r, 0), 255);
        assert_eq!(requantize_acc(-10_000, r, 0), 0);
        assert_eq!(requantize_acc(100, r, 10), 110);
    }

    #[test]
    fn calibrate_tensor_covers_data() {
        let t = Tensor::from_vec(&[5], vec![-3.0f32, -1.0, 0.0, 2.0, 7.0]);
        let p = calibrate_tensor(&t);
        for &x in t.data() {
            let err = (p.dequantize(p.quantize(x)) - x).abs();
            assert!(err <= p.scale * 0.5 + 1e-6, "x={x} err={err}");
        }
    }
}
