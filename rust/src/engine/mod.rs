//! One front door: the typed `Session` API over the bit-true PACiM
//! pipeline.
//!
//! Every consumer surface of this crate — the `pacim` CLI, the bench
//! harness, the examples, and the serving executor
//! ([`crate::runtime::PacExecutor`]) — constructs inference through this
//! module instead of wiring quantize → im2col → backend by hand:
//!
//! ```text
//! EngineBuilder ── build() ──▶ Engine ── session() ──▶ Session
//!   model               validated, Arc-shared:        per-caller scratch:
//!   backend mode        model + packed backend        infer / infer_f32 /
//!   policies            + cost model                  infer_batch / evaluate
//! ```
//!
//! - [`EngineBuilder`] validates the model program and configuration and
//!   prepares the backend exactly once (typed errors, no aborts);
//! - [`Engine`] is the immutable, cheaply-clonable result: share one per
//!   process, clone per worker;
//! - [`Session`] owns the mutable scratch arenas: one per thread, every
//!   call steady-state allocation-free per pixel;
//! - [`PacimError`] is the crate-wide error taxonomy (shape /
//!   configuration / model / serving), with lossless conversions from
//!   [`crate::Error`] and [`crate::coordinator::ServeError`] so
//!   queue-full load-shed signals pass through typed.
//!
//! The engine is a pure facade: results are bit-identical to the
//! low-level reference path (`nn::run_model_with` over an explicitly
//! constructed backend) — property-tested in `tests/engine_api.rs` for
//! both backends, with parallelism on and off, over logits *and*
//! statistics. See DESIGN.md §10 for the builder states, the error
//! taxonomy, and the old→new migration table.

mod builder;
mod error;
mod session;

pub use builder::EngineBuilder;
pub use error::{EngineResult, PacimError};
pub use session::{Engine, Evaluation, Fidelity, Inference, Session};
