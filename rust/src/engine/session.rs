//! The [`Engine`] (prepared model + backend) and its per-caller
//! [`Session`] (scratch-owning inference handle).
//!
//! An `Engine` is immutable and cheap to clone (`Arc`-shared): the model
//! program, the prepared MAC backend (packed weight bit-planes, sparsity
//! counts), the parallelism policies, and the modeled per-image silicon
//! cost are all built exactly once by [`super::EngineBuilder`]. A
//! `Session` adds the mutable per-caller state — the im2col / packed
//! activation-plane / accumulator arenas — so steady-state inference
//! allocates nothing per pixel while concurrent callers never contend:
//! one session per thread, all sharing one engine.
//!
//! Every entry point validates its inputs and returns
//! [`PacimError`](super::PacimError) instead of aborting; the inner
//! tiled kernels stay branch-free because the validation happens once,
//! at the boundary.

use crate::coordinator::scheduler::CostEstimate;
use crate::memory::{LayerTraffic, TrafficLedger};
use crate::nn::exec::{run_model_batch_with, run_model_with, ExactBackend, ModelScratch, RunStats};
use crate::nn::layers::Model;
use crate::nn::pac_exec::{EscalationConfig, PacBackend};
use crate::util::Parallelism;
use std::sync::Arc;

use super::error::{EngineResult, PacimError};

/// Per-request fidelity class (DESIGN.md §15): which compute path a
/// sample takes through a built engine.
///
/// On an exact engine every class runs the (only) exact backend. On a
/// PAC engine, `Fast` is the plain hybrid path, `Accurate` routes
/// through the exact digital fallback (available once
/// [`crate::nn::PacConfig::escalation`] is armed), and `Auto` runs the
/// hybrid path under the confidence monitor, re-running low-margin
/// samples exactly ([`RunStats::escalations`] records the rerun).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Fidelity {
    /// Exact digital result, unconditionally (per-sample ground truth).
    Accurate,
    /// The engine's configured backend, no monitor (the default — what
    /// [`Session::infer`] runs).
    #[default]
    Fast,
    /// Configured backend plus the confidence-gated escalation monitor.
    Auto,
}

/// One inference result: float logits plus the engine statistics of the
/// forward pass that produced them.
#[derive(Debug, Clone)]
pub struct Inference {
    pub logits: Vec<f32>,
    pub stats: RunStats,
}

impl Inference {
    /// Index of the largest logit (ties resolve to the last maximum,
    /// matching the legacy evaluation loop bit-for-bit). `0` when the
    /// logit vector is empty.
    pub fn argmax(&self) -> usize {
        argmax(&self.logits)
    }
}

/// Aggregate result of [`Engine::evaluate`] over a labeled image set.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// Top-1 accuracy in `[0, 1]`.
    pub accuracy: f64,
    /// Engine statistics summed over every image.
    pub stats: RunStats,
    /// Number of images evaluated.
    pub images: usize,
}

/// Largest-logit index with last-wins tie-breaking (the semantics of
/// `Iterator::max_by` over `partial_cmp`, which the legacy evaluate loop
/// used — preserved so accuracy counts stay bit-identical).
fn argmax(v: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in v.iter().enumerate() {
        if x >= best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

/// The prepared compute backend behind an engine.
pub(crate) enum EngineBackend {
    /// Exact 8b/8b integer baseline (fully digital D-CiM).
    Exact(ExactBackend),
    /// Hybrid digital/sparsity PAC computation (the paper's architecture).
    Pac(PacBackend),
}

impl EngineBackend {
    fn run(
        &self,
        model: &Model,
        image: &[u8],
        par: &Parallelism,
        scratch: &mut ModelScratch,
    ) -> EngineResult<(Vec<f32>, RunStats)> {
        match self {
            EngineBackend::Exact(b) => run_model_with(model, b, image, par, scratch),
            EngineBackend::Pac(b) => run_model_with(model, b, image, par, scratch),
        }
    }

    fn run_batch(
        &self,
        model: &Model,
        images: &[&[u8]],
        par: &Parallelism,
        scratches: &mut [ModelScratch],
    ) -> EngineResult<Vec<(Vec<f32>, RunStats)>> {
        match self {
            EngineBackend::Exact(b) => run_model_batch_with(model, b, images, par, scratches),
            EngineBackend::Pac(b) => run_model_batch_with(model, b, images, par, scratches),
        }
    }
}

/// Everything immutable about a built engine, shared by every clone and
/// session via one `Arc`.
pub(crate) struct EngineInner {
    pub(crate) model: Model,
    pub(crate) backend: EngineBackend,
    /// Tile fan-out policy for single-image inference.
    pub(crate) par: Parallelism,
    /// Lane fan-out policy for batched inference (each lane is a whole
    /// forward pass, so the default threshold is coarse).
    pub(crate) lane_par: Parallelism,
    /// Modeled per-image silicon cost under the schedule matching the
    /// backend mode (digital baseline / PACiM static / PACiM dynamic).
    pub(crate) cost: CostEstimate,
    /// `"exact"` or `"pac"`, for reports.
    pub(crate) mode: &'static str,
    /// Exact digital fallback next to a PAC backend — the escalation /
    /// [`Fidelity::Accurate`] target. Built only when
    /// [`crate::nn::PacConfig::escalation`] is armed (a second packed
    /// copy of the weights); always `None` on exact engines.
    pub(crate) fallback: Option<ExactBackend>,
    /// The armed escalation thresholds (copied out of the PAC config so
    /// the monitor never reaches into the backend).
    pub(crate) escalation: Option<EscalationConfig>,
    /// Logit units per terminal-accumulator LSB (`sx·sw` of the
    /// classifier head): converts `RunStats::estimator_var` (LSB²) into
    /// the scale the margin monitor compares against. `0.0` unless
    /// escalation is armed.
    pub(crate) logit_lsb: f32,
}

/// A prepared inference engine: the single typed front door to the
/// bit-true PACiM pipeline (validated model + packed backend + cost
/// model). Build one with [`super::EngineBuilder`]; clone it freely
/// (clones share all preparation); open a [`Session`] per thread to run.
///
/// ```
/// use pacim::engine::EngineBuilder;
/// use pacim::nn::layers::synthetic::random_store;
/// use pacim::nn::tiny_resnet;
/// use pacim::util::rng::Rng;
///
/// # fn main() -> Result<(), Box<dyn std::error::Error>> {
/// let mut rng = Rng::new(7);
/// let model = tiny_resnet(&random_store(&mut rng, 8, 10), 16, 10)?;
/// let engine = EngineBuilder::new(model).exact().build()?;
/// let out = engine.session().infer(&vec![0u8; engine.input_elems()])?;
/// assert_eq!(out.logits.len(), engine.output_elems());
/// # Ok(()) }
/// ```
#[derive(Clone)]
pub struct Engine {
    pub(crate) inner: Arc<EngineInner>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("model", &self.inner.model.name)
            .field("mode", &self.inner.mode)
            .field("input_elems", &self.input_elems())
            .field("output_elems", &self.output_elems())
            .field("modeled_cycles", &self.inner.cost.cycles)
            .finish_non_exhaustive()
    }
}

impl Engine {
    /// Start building an engine for `model` (PAC backend with the
    /// paper-default configuration unless overridden).
    pub fn builder(model: Model) -> super::EngineBuilder {
        super::EngineBuilder::new(model)
    }

    /// The validated model program this engine runs.
    pub fn model(&self) -> &Model {
        &self.inner.model
    }

    /// `"exact"` or `"pac"`.
    pub fn mode(&self) -> &'static str {
        self.inner.mode
    }

    /// Elements per input image (C·H·W).
    pub fn input_elems(&self) -> usize {
        let m = &self.inner.model;
        m.in_c * m.in_hw * m.in_hw
    }

    /// Elements per output (number of classes).
    pub fn output_elems(&self) -> usize {
        self.inner.model.num_classes
    }

    /// Modeled per-image PACiM cycles/energy under the schedule matching
    /// this engine's backend mode.
    pub fn cost_estimate(&self) -> CostEstimate {
        self.inner.cost
    }

    /// Layer shapes of this engine's model, in program order — the input
    /// the multibank / traffic-priced schedulers and the `arch::dse`
    /// sweep consume (same extraction as
    /// [`model_shapes`](crate::coordinator::model_shapes)).
    pub fn layer_shapes(&self) -> Vec<crate::workload::LayerShape> {
        crate::coordinator::model_shapes(&self.inner.model)
    }

    /// Join a measured [`TrafficLedger`] (from
    /// [`RunStats::traffic`](crate::nn::RunStats)) with this engine's
    /// compute-layer names: one `(name, entry)` row per inter-layer
    /// activation edge, in program order. The measured counterpart of
    /// the analytic traffic columns in [`Engine::cost_estimate`].
    pub fn traffic_rows<'a>(
        &'a self,
        ledger: &'a TrafficLedger,
    ) -> Vec<(&'a str, &'a LayerTraffic)> {
        let names = self.inner.model.compute_layers();
        ledger
            .layers()
            .iter()
            .filter_map(|e| names.get(e.layer_id).map(|&(n, _)| (n, e)))
            .collect()
    }

    /// Open a session: a mutable inference handle owning its scratch
    /// arenas. Sessions are independent; open one per thread.
    pub fn session(&self) -> Session {
        Session {
            engine: self.clone(),
            scratches: vec![ModelScratch::default()],
            lane_par: self.inner.lane_par,
        }
    }

    fn check_image(&self, image: &[u8], context: &str) -> EngineResult<()> {
        let want = self.input_elems();
        if image.len() != want {
            return Err(PacimError::ShapeMismatch {
                context: context.into(),
                got: image.len(),
                want,
            });
        }
        Ok(())
    }

    /// Run one validated image (internal: callers have already checked
    /// the input length; interpreter errors — a malformed skip program,
    /// an in-model shape clash — surface as typed [`PacimError`]s).
    pub(crate) fn run_validated(
        &self,
        image: &[u8],
        par: &Parallelism,
        scratch: &mut ModelScratch,
    ) -> EngineResult<(Vec<f32>, RunStats)> {
        self.inner.backend.run(&self.inner.model, image, par, scratch)
    }

    /// The escalation thresholds this engine was built with (`None` on
    /// exact engines and on PAC engines without the monitor armed).
    pub fn escalation(&self) -> Option<EscalationConfig> {
        self.inner.escalation
    }

    /// Typed pre-check that `fidelity` can run on this engine:
    /// [`Fidelity::Accurate`] on a PAC engine needs the exact fallback,
    /// which only exists once escalation is armed.
    pub(crate) fn check_fidelity(&self, fidelity: Fidelity) -> EngineResult<()> {
        if fidelity == Fidelity::Accurate
            && matches!(self.inner.backend, EngineBackend::Pac(_))
            && self.inner.fallback.is_none()
        {
            return Err(PacimError::InvalidConfig(
                "Fidelity::Accurate on a PAC engine requires the exact fallback; \
                 arm it with EngineBuilder::escalation (or PacConfig::escalation)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Whether `fidelity` can run on this engine ([`Fidelity::Accurate`]
    /// on a PAC engine needs the exact fallback, which only exists once
    /// escalation is armed). The registry validation hook of
    /// [`crate::coordinator::ModelRegistry`].
    pub fn supports_fidelity(&self, fidelity: Fidelity) -> bool {
        self.check_fidelity(fidelity).is_ok()
    }

    /// The escalation decision (DESIGN.md §15): re-run a sample exactly
    /// when its top-two logit margin is smaller than
    /// `min_margin + sigma · σ_margin`, where `σ_margin` is the standard
    /// deviation of a logit *difference* under the terminal layer's
    /// estimator variance — `sqrt(2 · estimator_var / n_outputs)`
    /// accumulator LSBs, converted to logit units through `logit_lsb`.
    /// When the terminal layer ran digitally the variance is zero and
    /// the gate degenerates to the pure margin floor.
    pub(crate) fn should_escalate(&self, logits: &[f32], stats: &RunStats) -> bool {
        let Some(esc) = self.inner.escalation else {
            return false;
        };
        if self.inner.fallback.is_none() || logits.len() < 2 {
            return false;
        }
        let mut top = f32::NEG_INFINITY;
        let mut second = f32::NEG_INFINITY;
        for &x in logits {
            if x >= top {
                second = top;
                top = x;
            } else if x > second {
                second = x;
            }
        }
        let margin = (top - second) as f64;
        let per_output_var = stats.estimator_var / logits.len() as f64;
        let sigma_margin = (2.0 * per_output_var).sqrt() * self.inner.logit_lsb as f64;
        margin < esc.min_margin as f64 + esc.sigma * sigma_margin
    }

    /// Run one validated image under a fidelity class (internal: callers
    /// have already run [`Engine::check_image`] and
    /// [`Engine::check_fidelity`]). On escalation the returned stats are
    /// the *sum* of both passes with [`RunStats::escalations`] `= 1`, and
    /// the logits are the exact pass's.
    pub(crate) fn run_fidelity_validated(
        &self,
        image: &[u8],
        fidelity: Fidelity,
        par: &Parallelism,
        scratch: &mut ModelScratch,
    ) -> EngineResult<(Vec<f32>, RunStats)> {
        match fidelity {
            Fidelity::Fast => self.run_validated(image, par, scratch),
            Fidelity::Accurate => match &self.inner.fallback {
                Some(fb) => run_model_with(&self.inner.model, fb, image, par, scratch),
                // Exact engines: the backend already is the exact path
                // (check_fidelity rejected the fallback-less PAC case).
                None => self.run_validated(image, par, scratch),
            },
            Fidelity::Auto => {
                let (logits, mut stats) = self.run_validated(image, par, scratch)?;
                if self.should_escalate(&logits, &stats) {
                    if let Some(fb) = &self.inner.fallback {
                        let (exact_logits, exact_stats) =
                            run_model_with(&self.inner.model, fb, image, par, scratch)?;
                        stats.merge(&exact_stats);
                        stats.escalations = 1;
                        return Ok((exact_logits, stats));
                    }
                }
                Ok((logits, stats))
            }
        }
    }

    /// Top-1 accuracy of this engine over a labeled image set, fanned out
    /// over `threads` workers (each with its own warm scratch arena).
    /// Bit-identical to evaluating the images one by one in a session:
    /// per-image work is independent and all merged statistics are
    /// integer counters.
    pub fn evaluate(
        &self,
        images: &[&[u8]],
        labels: &[usize],
        threads: usize,
    ) -> EngineResult<Evaluation> {
        self.evaluate_with(images, labels, threads, Fidelity::Fast)
    }

    /// [`Engine::evaluate`] under an explicit fidelity class: `Accurate`
    /// scores the exact fallback, `Auto` runs the escalation monitor
    /// (reruns land in `stats.escalations`). `Fast` is exactly
    /// [`Engine::evaluate`].
    pub fn evaluate_with(
        &self,
        images: &[&[u8]],
        labels: &[usize],
        threads: usize,
        fidelity: Fidelity,
    ) -> EngineResult<Evaluation> {
        self.check_fidelity(fidelity)?;
        if images.len() != labels.len() {
            return Err(PacimError::ShapeMismatch {
                context: "evaluate labels".into(),
                got: labels.len(),
                want: images.len(),
            });
        }
        let want = self.input_elems();
        for (i, img) in images.iter().enumerate() {
            // Context built only on the error path (no per-image allocation).
            if img.len() != want {
                return Err(PacimError::ShapeMismatch {
                    context: format!("evaluate image {i}"),
                    got: img.len(),
                    want,
                });
            }
        }
        let n = images.len();
        let next = std::sync::atomic::AtomicUsize::new(0);
        let mut correct = 0usize;
        let mut stats = RunStats::default();
        let mut worker_died = false;
        let mut failure: Option<PacimError> = None;
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..threads.max(1) {
                let next = &next;
                handles.push(s.spawn(move || -> EngineResult<(usize, RunStats)> {
                    let mut local_correct = 0usize;
                    let mut local = RunStats::default();
                    // Per-worker scratch arena, reused across every image
                    // this worker claims (zero allocation per pixel).
                    let mut scratch = ModelScratch::default();
                    let par = Parallelism::off();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let (logits, st) =
                            self.run_fidelity_validated(images[i], fidelity, &par, &mut scratch)?;
                        local.merge(&st);
                        if argmax(&logits) == labels[i] {
                            local_correct += 1;
                        }
                    }
                    Ok((local_correct, local))
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(Ok((c, st))) => {
                        correct += c;
                        stats.merge(&st);
                    }
                    Ok(Err(e)) => failure = Some(e),
                    Err(_) => worker_died = true,
                }
            }
        });
        if worker_died {
            return Err(PacimError::Internal("an evaluation worker died".into()));
        }
        if let Some(e) = failure {
            return Err(e);
        }
        Ok(Evaluation {
            accuracy: correct as f64 / n.max(1) as f64,
            stats,
            images: n,
        })
    }
}

/// A mutable inference handle over a shared [`Engine`]: owns the scratch
/// arenas (im2col buffer, packed activation planes, accumulator slab, one
/// set per batch lane) so repeated calls run out of warm buffers.
///
/// ```
/// use pacim::engine::{EngineBuilder, PacimError};
/// use pacim::nn::layers::synthetic::random_store;
/// use pacim::nn::{tiny_resnet, PacConfig};
/// use pacim::util::rng::Rng;
///
/// # fn main() -> Result<(), Box<dyn std::error::Error>> {
/// let mut rng = Rng::new(11);
/// let model = tiny_resnet(&random_store(&mut rng, 8, 10), 16, 10)?;
/// let engine = EngineBuilder::new(model).pac(PacConfig::default()).build()?;
/// let mut session = engine.session();
///
/// // Typed errors instead of aborts on every boundary:
/// match session.infer(&[0u8; 3]) {
///     Err(PacimError::ShapeMismatch { got: 3, .. }) => {}
///     other => return Err(format!("wanted ShapeMismatch, got {other:?}").into()),
/// }
///
/// let img = vec![128u8; engine.input_elems()];
/// let out = session.infer(&img)?;
/// assert_eq!(out.logits.len(), 10);
/// assert!(out.stats.macs > 0);
/// # Ok(()) }
/// ```
#[derive(Clone, Debug)]
pub struct Session {
    engine: Engine,
    /// Lane-indexed scratch arenas; grown on demand, never shrunk, always
    /// at least one (the single-image lane).
    scratches: Vec<ModelScratch>,
    lane_par: Parallelism,
}

impl Session {
    /// The shared engine behind this session.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Modeled per-image silicon cost (see [`Engine::cost_estimate`]).
    pub fn cost_estimate(&self) -> CostEstimate {
        self.engine.cost_estimate()
    }

    /// Override the lane fan-out policy for [`Session::infer_batch`]
    /// (bit-deterministic either way; this only changes speed).
    pub fn set_lane_parallelism(&mut self, par: Parallelism) {
        self.lane_par = par;
    }

    /// Pre-grow the per-lane scratch arenas to `lanes` (optional: batched
    /// inference grows them on demand; serving executors pre-grow to the
    /// compiled batch size so the first request pays no setup).
    pub fn reserve_lanes(&mut self, lanes: usize) {
        if self.scratches.len() < lanes {
            self.scratches.resize_with(lanes, ModelScratch::default);
        }
    }

    /// Classify one quantized CHW u8 image (the [`Fidelity::Fast`] path).
    pub fn infer(&mut self, image: &[u8]) -> EngineResult<Inference> {
        self.engine.check_image(image, "Session::infer input")?;
        let par = self.engine.inner.par;
        let (logits, stats) = self.engine.run_validated(image, &par, &mut self.scratches[0])?;
        Ok(Inference { logits, stats })
    }

    /// Classify one quantized CHW u8 image under an explicit fidelity
    /// class. `Fast` is exactly [`Session::infer`]; `Accurate` routes
    /// through the exact fallback; `Auto` runs the PAC path and re-runs
    /// the sample exactly when the confidence monitor trips (the result
    /// then carries the exact logits, the summed statistics of both
    /// passes, and `stats.escalations == 1`).
    pub fn infer_with(&mut self, image: &[u8], fidelity: Fidelity) -> EngineResult<Inference> {
        self.engine.check_image(image, "Session::infer input")?;
        self.engine.check_fidelity(fidelity)?;
        let par = self.engine.inner.par;
        let (logits, stats) =
            self.engine
                .run_fidelity_validated(image, fidelity, &par, &mut self.scratches[0])?;
        Ok(Inference { logits, stats })
    }

    /// Classify one float CHW image, quantizing through the model's input
    /// parameters first (the serving submission path).
    pub fn infer_f32(&mut self, image: &[f32]) -> EngineResult<Inference> {
        let want = self.engine.input_elems();
        if image.len() != want {
            return Err(PacimError::ShapeMismatch {
                context: "Session::infer_f32 input".into(),
                got: image.len(),
                want,
            });
        }
        let p = self.engine.inner.model.input_params;
        let q: Vec<u8> = image.iter().map(|&x| p.quantize(x)).collect();
        self.infer(&q)
    }

    /// Classify a batch of quantized images, fanning the lanes out per
    /// the session's lane policy (each lane is one whole forward pass in
    /// its own warm arena). Bit-identical to calling [`Session::infer`]
    /// per image, in order.
    pub fn infer_batch(&mut self, images: &[&[u8]]) -> EngineResult<Vec<Inference>> {
        let want = self.engine.input_elems();
        for (i, img) in images.iter().enumerate() {
            // Inline length check: the context string is built only on the
            // error path, so a valid serving batch allocates nothing here.
            if img.len() != want {
                return Err(PacimError::ShapeMismatch {
                    context: format!("Session::infer_batch lane {i} input"),
                    got: img.len(),
                    want,
                });
            }
        }
        if images.is_empty() {
            return Ok(Vec::new());
        }
        self.reserve_lanes(images.len());
        let lanes = self.engine.inner.backend.run_batch(
            &self.engine.inner.model,
            images,
            &self.lane_par,
            &mut self.scratches[..images.len()],
        )?;
        Ok(lanes
            .into_iter()
            .map(|(logits, stats)| Inference { logits, stats })
            .collect())
    }

    /// Classify a batch with a per-lane fidelity class. An all-`Fast`
    /// batch takes the fanned-out [`Session::infer_batch`] path
    /// unchanged; any `Accurate`/`Auto` lane switches the whole batch to
    /// lane-serial execution (each lane still bit-identical to
    /// [`Session::infer_with`] on the same image), since an escalated
    /// lane re-enters the model mid-batch.
    pub fn infer_batch_with(
        &mut self,
        images: &[&[u8]],
        fidelities: &[Fidelity],
    ) -> EngineResult<Vec<Inference>> {
        if fidelities.len() != images.len() {
            return Err(PacimError::ShapeMismatch {
                context: "Session::infer_batch_with fidelities".into(),
                got: fidelities.len(),
                want: images.len(),
            });
        }
        if fidelities.iter().all(|&f| f == Fidelity::Fast) {
            return self.infer_batch(images);
        }
        for &f in fidelities {
            self.engine.check_fidelity(f)?;
        }
        let want = self.engine.input_elems();
        for (i, img) in images.iter().enumerate() {
            if img.len() != want {
                return Err(PacimError::ShapeMismatch {
                    context: format!("Session::infer_batch_with lane {i} input"),
                    got: img.len(),
                    want,
                });
            }
        }
        self.reserve_lanes(images.len());
        let par = self.engine.inner.par;
        let mut out = Vec::with_capacity(images.len());
        for (i, (&img, &f)) in images.iter().zip(fidelities).enumerate() {
            let (logits, stats) =
                self.engine
                    .run_fidelity_validated(img, f, &par, &mut self.scratches[i])?;
            out.push(Inference { logits, stats });
        }
        Ok(out)
    }

    /// Labeled-set accuracy (delegates to [`Engine::evaluate`]; the
    /// multi-threaded sweep uses per-worker arenas, not this session's).
    pub fn evaluate(
        &self,
        images: &[&[u8]],
        labels: &[usize],
        threads: usize,
    ) -> EngineResult<Evaluation> {
        self.engine.evaluate(images, labels, threads)
    }
}
