//! The crate-wide typed error of the engine front door.
//!
//! Every fallible operation reachable from [`crate::engine`] returns
//! [`PacimError`] — one enum a caller can match on instead of fishing
//! through stringly-typed `anyhow` chains or catching aborts. The
//! variants cover the four failure families of the system:
//!
//! - **shapes** — an input buffer whose element count disagrees with the
//!   model ([`PacimError::ShapeMismatch`]);
//! - **configuration** — an invalid [`crate::nn::PacConfig`] or builder
//!   state, e.g. a dynamic-threshold request on a base map whose digital
//!   block is not the 16-cycle 4×4 split ([`PacimError::InvalidConfig`]);
//! - **model/artifact** — malformed programs, weight stores, or
//!   manifests ([`PacimError::Model`], converted from [`crate::Error`]);
//! - **serving** — the admission-control and lifecycle states of the
//!   coordinator pool, converted losslessly from
//!   [`crate::coordinator::ServeError`] so load-shed signals
//!   ([`PacimError::QueueFull`]) pass through typed.

use crate::coordinator::ServeError;

/// Typed error for every engine-facing operation.
#[derive(Debug, thiserror::Error)]
pub enum PacimError {
    /// An input/output buffer has the wrong number of elements.
    #[error("{context}: got {got} elements, expected {want}")]
    ShapeMismatch {
        /// Which boundary was violated (e.g. `"Session::infer input"`).
        context: String,
        got: usize,
        want: usize,
    },

    /// The requested engine configuration is invalid (bad cycle split,
    /// zero-lane executor, thresholds on a non-4×4 base map, …).
    #[error("invalid configuration: {0}")]
    InvalidConfig(String),

    /// The model program, weight store, or artifact manifest is broken
    /// (missing tensors, shape disagreements, unreachable ops, no logits
    /// layer).
    #[error("model error: {0}")]
    Model(String),

    /// Serving admission control fired: the bounded queue already holds
    /// `capacity` pending requests. Clients should back off and retry.
    #[error("admission queue full ({capacity} pending requests); load shed")]
    QueueFull { capacity: usize },

    /// The serving pool has stopped accepting submissions.
    #[error("server stopped")]
    ServerStopped,

    /// The request was admitted but its batch failed to execute.
    #[error("request dropped (batch execution failed)")]
    RequestDropped,

    /// The executor serving this request's batch panicked; the pool
    /// rebuilt the worker and kept serving, but this batch is lost.
    #[error("worker lost (executor panicked mid-batch); retry")]
    WorkerLost,

    /// The request's serving deadline expired while it was still queued
    /// (reaped by the batcher; it never occupied an executor lane).
    #[error("request deadline exceeded while queued")]
    DeadlineExceeded,

    /// The request's traffic-budget SLO is below the executor's modeled
    /// per-image floor; it cannot possibly be served within budget and
    /// was reaped before occupying a lane.
    #[error("traffic budget {budget_bits} bits below the modeled floor of {floor_bits} bits")]
    TrafficBudgetExceeded { budget_bits: u64, floor_bits: u64 },

    /// The multi-model router has no tenant registered under this id.
    #[error("unknown model '{model}'")]
    UnknownModel { model: String },

    /// An internal invariant failed (e.g. an evaluation worker died).
    #[error("internal error: {0}")]
    Internal(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<crate::Error> for PacimError {
    fn from(e: crate::Error) -> Self {
        match e {
            crate::Error::Artifact(m) => PacimError::Model(format!("artifact: {m}")),
            crate::Error::Shape(m) => PacimError::Model(format!("shape: {m}")),
            crate::Error::Config(m) => PacimError::InvalidConfig(m),
            crate::Error::Runtime(m) => PacimError::Internal(m),
            crate::Error::Io(e) => PacimError::Io(e),
        }
    }
}

impl From<ServeError> for PacimError {
    fn from(e: ServeError) -> Self {
        match e {
            ServeError::BadInput { got, want } => PacimError::ShapeMismatch {
                context: "serve request input".into(),
                got,
                want,
            },
            ServeError::QueueFull { capacity } => PacimError::QueueFull { capacity },
            ServeError::Stopped => PacimError::ServerStopped,
            ServeError::Dropped => PacimError::RequestDropped,
            ServeError::WorkerLost => PacimError::WorkerLost,
            ServeError::DeadlineExceeded => PacimError::DeadlineExceeded,
            ServeError::TrafficBudgetExceeded {
                budget_bits,
                floor_bits,
            } => PacimError::TrafficBudgetExceeded {
                budget_bits,
                floor_bits,
            },
            ServeError::UnknownModel { model } => PacimError::UnknownModel { model },
        }
    }
}

/// Crate-wide shorthand for engine results.
pub type EngineResult<T> = std::result::Result<T, PacimError>;
