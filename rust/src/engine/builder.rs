//! [`EngineBuilder`] — the validated construction path of an
//! [`Engine`](super::Engine).
//!
//! The builder is the one place where configuration mistakes surface as
//! typed errors instead of aborts deep inside a kernel: the model
//! program is shape-walked end to end (every conv/linear/skip checked
//! against the activation shape it will actually receive), the PAC
//! configuration is validated (operand split within the 8-bit planes,
//! dynamic thresholds only on the 16-cycle 4×4 base map), and only a
//! fully-consistent engine is ever handed back. After `build()`, the
//! interpreter's internal invariants are guaranteed, so the hot loops
//! stay branch-free.

use crate::coordinator::scheduler::{estimate_image_cost, model_shapes, ScheduleConfig};
use crate::energy::EnergyModel;
use crate::fault::FaultConfig;
use crate::nn::exec::exact_backend;
use crate::nn::layers::{Model, Op};
use crate::nn::pac_exec::{pac_backend, EscalationConfig, PacConfig};
use crate::pac::ComputeMap;
use crate::util::Parallelism;
use std::sync::Arc;

use super::error::{EngineResult, PacimError};
use super::session::{Engine, EngineBackend, EngineInner};

/// Which compute backend the engine will prepare.
enum Mode {
    /// Fully digital 8b/8b integer reference.
    Exact,
    /// Hybrid digital/sparsity PAC computation.
    Pac(PacConfig),
}

/// Builder for [`Engine`]: pick a backend, tune policies, `build()`.
///
/// Defaults: PAC backend with the paper-default [`PacConfig`] (static
/// 4×4 operand map, first layer exact), [`Parallelism::auto`] tile
/// fan-out for single-image inference, [`Parallelism::coarse`] lane
/// fan-out for batches, and the cost schedule matching the backend mode.
///
/// ```
/// use pacim::engine::EngineBuilder;
/// use pacim::nn::layers::synthetic::random_store;
/// use pacim::nn::tiny_resnet;
/// use pacim::util::rng::Rng;
///
/// # fn main() -> Result<(), Box<dyn std::error::Error>> {
/// let mut rng = Rng::new(3);
/// let model = tiny_resnet(&random_store(&mut rng, 8, 10), 16, 10)?;
///
/// // An invalid cycle split is a typed error, not an abort:
/// assert!(EngineBuilder::new(model.clone()).approx_bits(9, 4).build().is_err());
///
/// let engine = EngineBuilder::new(model).approx_bits(4, 4).build()?;
/// assert_eq!(engine.mode(), "pac");
/// # Ok(()) }
/// ```
pub struct EngineBuilder {
    model: Model,
    mode: Mode,
    approx_bits: Option<(u32, u32)>,
    thresholds: Option<crate::arch::ThresholdSet>,
    fault: Option<FaultConfig>,
    escalation: Option<EscalationConfig>,
    par: Parallelism,
    lane_par: Parallelism,
    schedule: Option<ScheduleConfig>,
}

impl EngineBuilder {
    /// Start building an engine for `model`.
    pub fn new(model: Model) -> Self {
        Self {
            model,
            mode: Mode::Pac(PacConfig::default()),
            approx_bits: None,
            thresholds: None,
            fault: None,
            escalation: None,
            par: Parallelism::auto(),
            lane_par: Parallelism::coarse(),
            schedule: None,
        }
    }

    /// Use the exact 8b/8b integer backend (fully digital D-CiM).
    pub fn exact(mut self) -> Self {
        self.mode = Mode::Exact;
        self
    }

    /// Use the PAC hybrid backend with an explicit configuration.
    pub fn pac(mut self, config: PacConfig) -> Self {
        self.mode = Mode::Pac(config);
        self
    }

    /// Shorthand for the operand-based split: keep the `bx` activation
    /// MSBs × `bw` weight MSBs digital (`bx·bw` of the 64 cycles) and
    /// approximate the rest. Validated at `build()`: each operand width
    /// must fit the 8 bit-planes.
    pub fn approx_bits(mut self, bx: u32, bw: u32) -> Self {
        self.approx_bits = Some((bx, bw));
        self
    }

    /// Enable the dynamic workload configuration (§5) with the given
    /// speculation thresholds. Requires the PAC backend on the 4×4 base
    /// map (validated at `build()`).
    pub fn dynamic(mut self, thresholds: crate::arch::ThresholdSet) -> Self {
        self.thresholds = Some(thresholds);
        self
    }

    /// Inject the seeded CiM error model (`pacim::fault`): PCU sampling
    /// noise, weight-MSB bit-cell flips, and encoded-edge transmission
    /// flips, all position-keyed off `fault.seed` so injections are
    /// bit-identical across tile schedules and parallelism settings.
    /// Requires the PAC backend (validated at `build()`); a
    /// [`FaultConfig::off`] value is free — no RNG is ever constructed.
    pub fn fault(mut self, fault: FaultConfig) -> Self {
        self.fault = Some(fault);
        self
    }

    /// Arm the confidence-gated PAC→exact escalation monitor: builds an
    /// exact digital fallback next to the PAC backend, and
    /// [`super::Session::infer_with`] under [`super::Fidelity::Auto`]
    /// re-runs samples whose top-two logit margin falls below
    /// `min_margin + sigma · σ_logit` through it. Requires the PAC
    /// backend (validated at `build()`).
    pub fn escalation(mut self, escalation: EscalationConfig) -> Self {
        self.escalation = Some(escalation);
        self
    }

    /// Tile fan-out policy for single-image inference (default
    /// [`Parallelism::auto`]). Bit-deterministic at any setting.
    pub fn parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// Lane fan-out policy for batched inference (default
    /// [`Parallelism::coarse`]). Bit-deterministic at any setting.
    pub fn lane_parallelism(mut self, par: Parallelism) -> Self {
        self.lane_par = par;
        self
    }

    /// Override the bank schedule used for the modeled per-image cost
    /// (default: the schedule matching the backend mode).
    pub fn schedule(mut self, schedule: ScheduleConfig) -> Self {
        self.schedule = Some(schedule);
        self
    }

    /// Validate everything and prepare the engine (packs weight
    /// bit-planes once, computes the per-image cost model).
    pub fn build(self) -> EngineResult<Engine> {
        validate_model(&self.model)?;
        let (backend, mode, default_sched, fallback, escalation, logit_lsb) = match self.mode {
            Mode::Exact => {
                if self.thresholds.is_some() {
                    return Err(PacimError::InvalidConfig(
                        "dynamic thresholds require the PAC backend; \
                         the exact backend is fully digital"
                            .into(),
                    ));
                }
                if self.approx_bits.is_some() {
                    return Err(PacimError::InvalidConfig(
                        "approx_bits requires the PAC backend; \
                         the exact backend runs all 64 cycles digitally"
                            .into(),
                    ));
                }
                if self.fault.is_some() {
                    return Err(PacimError::InvalidConfig(
                        "fault injection models PAC-boundary errors (PCU noise, weight-MSB \
                         cells, encoded edges) and requires the PAC backend"
                            .into(),
                    ));
                }
                if self.escalation.is_some() {
                    return Err(PacimError::InvalidConfig(
                        "escalation re-runs low-confidence PAC samples exactly; \
                         the exact backend is already the escalation target"
                            .into(),
                    ));
                }
                (
                    EngineBackend::Exact(exact_backend(&self.model)),
                    "exact",
                    ScheduleConfig::digital_baseline(),
                    None,
                    None,
                    0.0f32,
                )
            }
            Mode::Pac(mut cfg) => {
                if let Some((bx, bw)) = self.approx_bits {
                    if bx > 8 || bw > 8 {
                        return Err(PacimError::InvalidConfig(format!(
                            "invalid cycle split: operand widths {bx}×{bw} exceed the 8 \
                             bit-planes (the digital block covers bx·bw of the 64 cycles, \
                             so bx ≤ 8 and bw ≤ 8)"
                        )));
                    }
                    cfg.map = ComputeMap::operand_based(bx, bw);
                }
                if let Some(th) = self.thresholds {
                    cfg.thresholds = Some(th);
                }
                if let Some(f) = self.fault {
                    cfg.fault = f;
                }
                if let Some(e) = self.escalation {
                    cfg.escalation = Some(e);
                }
                validate_pac_config(&cfg)?;
                let sched = if cfg.thresholds.is_some() {
                    ScheduleConfig::pacim_dynamic()
                } else {
                    ScheduleConfig::pacim_default()
                };
                // Arming escalation builds the exact digital fallback next
                // to the PAC backend (a second packed copy of the weights)
                // and resolves the accumulator-LSB → logit-unit conversion
                // the margin monitor divides through.
                let escalation = cfg.escalation;
                let (fallback, logit_lsb) = if escalation.is_some() {
                    (Some(exact_backend(&self.model)), terminal_logit_lsb(&self.model))
                } else {
                    (None, 0.0)
                };
                (
                    EngineBackend::Pac(pac_backend(&self.model, cfg)),
                    "pac",
                    sched,
                    fallback,
                    escalation,
                    logit_lsb,
                )
            }
        };
        let sched = self.schedule.unwrap_or(default_sched);
        let cost = estimate_image_cost(
            &model_shapes(&self.model),
            &sched,
            &EnergyModel::default(),
        );
        Ok(Engine {
            inner: Arc::new(EngineInner {
                model: self.model,
                backend,
                par: self.par,
                lane_par: self.lane_par,
                cost,
                mode,
                fallback,
                escalation,
                logit_lsb,
            }),
        })
    }
}

/// One integer accumulator LSB of the terminal logits layer, expressed in
/// logit units: the classifier's weight scale times the activation scale
/// it receives (logits are `sx·sw · (acc − corrections) + bias`, so every
/// accumulator count is worth `sx·sw` logits). Converts the PCU estimator
/// variance — accumulated in LSB² — into the units the escalation
/// monitor's margin comparison runs in. `0.0` for a program without a
/// terminal logits layer (unreachable past `validate_model`).
fn terminal_logit_lsb(model: &Model) -> f32 {
    let mut cur = model.input_params;
    for op in &model.ops {
        match op {
            Op::Conv2d(c) => cur = c.out_params,
            Op::Linear(l) => match &l.out_params {
                None => return cur.scale * l.wparams.scale,
                Some(p) => cur = *p,
            },
            Op::AddSkip { out_params, .. } => cur = *out_params,
            Op::MaxPool2 | Op::GlobalAvgPool | Op::SaveSkip => {}
        }
    }
    0.0
}

/// Validate a PAC configuration independent of any model (also used for
/// executor construction): the dynamic-threshold ladder is defined on
/// the 16-cycle 4×4 operand base map only, and the fault / escalation
/// knobs must hold sane rates and thresholds.
pub(crate) fn validate_pac_config(cfg: &PacConfig) -> EngineResult<()> {
    if cfg.thresholds.is_some() {
        let base = ComputeMap::operand_based(4, 4);
        if cfg.map.digital_set() != base.digital_set() {
            return Err(PacimError::InvalidConfig(format!(
                "dynamic workload configuration requires the operand 4×4 base map \
                 (16 digital + 48 sparsity cycles); map '{}' has {} digital cycles",
                cfg.map.name,
                cfg.map.digital_cycles()
            )));
        }
    }
    cfg.fault.validate().map_err(PacimError::InvalidConfig)?;
    if let Some(esc) = &cfg.escalation {
        esc.validate().map_err(PacimError::InvalidConfig)?;
    }
    Ok(())
}

/// Shape-walk the model program end to end, so every invariant the
/// interpreter relies on is established before the first inference:
/// conv/linear geometry vs the incoming activation shape, weight/bias
/// arities, balanced skip stack, a terminal logits layer, and no
/// unreachable ops behind it.
fn validate_model(model: &Model) -> EngineResult<()> {
    if model.in_c == 0 || model.in_hw == 0 {
        return Err(PacimError::Model(format!(
            "model '{}' declares an empty input ({}×{}×{})",
            model.name, model.in_c, model.in_hw, model.in_hw
        )));
    }
    let mut shape = (model.in_c, model.in_hw, model.in_hw);
    let mut skips: Vec<(usize, usize, usize)> = Vec::new();
    let mut compute_layers = 0usize;
    let mut finished = false;
    for (i, op) in model.ops.iter().enumerate() {
        if finished {
            return Err(PacimError::Model(format!(
                "model '{}': op {i} is unreachable (the logits layer already ended \
                 the program)",
                model.name
            )));
        }
        match op {
            Op::Conv2d(c) => {
                let g = &c.geom;
                if g.stride == 0 {
                    return Err(PacimError::Model(format!(
                        "conv '{}' declares stride 0",
                        c.name
                    )));
                }
                if g.in_h + 2 * g.pad < g.kh || g.in_w + 2 * g.pad < g.kw {
                    return Err(PacimError::Model(format!(
                        "conv '{}' kernel {}×{} exceeds its padded input \
                         ({}+2·{})×({}+2·{})",
                        c.name, g.kh, g.kw, g.in_h, g.pad, g.in_w, g.pad
                    )));
                }
                if (g.in_c, g.in_h, g.in_w) != shape {
                    return Err(PacimError::Model(format!(
                        "conv '{}' declares input {}×{}×{} but receives {}×{}×{}",
                        c.name, g.in_c, g.in_h, g.in_w, shape.0, shape.1, shape.2
                    )));
                }
                if c.weight.shape() != [g.out_c, g.dp_len()] {
                    return Err(PacimError::Model(format!(
                        "conv '{}' weight shape {:?} != [{}, {}]",
                        c.name,
                        c.weight.shape(),
                        g.out_c,
                        g.dp_len()
                    )));
                }
                if c.bias.len() != g.out_c {
                    return Err(PacimError::Model(format!(
                        "conv '{}' bias length {} != {} output channels",
                        c.name,
                        c.bias.len(),
                        g.out_c
                    )));
                }
                shape = (g.out_c, g.out_h(), g.out_w());
                if shape.0 == 0 || shape.1 == 0 || shape.2 == 0 {
                    return Err(PacimError::Model(format!(
                        "conv '{}' produces an empty output ({}×{}×{})",
                        c.name, shape.0, shape.1, shape.2
                    )));
                }
                compute_layers += 1;
            }
            Op::Linear(l) => {
                let elems = shape.0 * shape.1 * shape.2;
                if elems != l.in_f {
                    return Err(PacimError::Model(format!(
                        "linear '{}' declares {} input features but receives {} \
                         ({}×{}×{})",
                        l.name, l.in_f, elems, shape.0, shape.1, shape.2
                    )));
                }
                if l.weight.shape() != [l.out_f, l.in_f] {
                    return Err(PacimError::Model(format!(
                        "linear '{}' weight shape {:?} != [{}, {}]",
                        l.name,
                        l.weight.shape(),
                        l.out_f,
                        l.in_f
                    )));
                }
                if l.bias.len() != l.out_f {
                    return Err(PacimError::Model(format!(
                        "linear '{}' bias length {} != {} output features",
                        l.name,
                        l.bias.len(),
                        l.out_f
                    )));
                }
                compute_layers += 1;
                match &l.out_params {
                    None => finished = true,
                    Some(_) => shape = (l.out_f, 1, 1),
                }
            }
            Op::MaxPool2 => {
                if shape.1 < 2 || shape.2 < 2 {
                    return Err(PacimError::Model(format!(
                        "MaxPool2 over a {}×{}×{} activation would produce an empty \
                         output",
                        shape.0, shape.1, shape.2
                    )));
                }
                shape = (shape.0, shape.1 / 2, shape.2 / 2);
            }
            Op::GlobalAvgPool => {
                shape = (shape.0, 1, 1);
            }
            Op::SaveSkip => {
                skips.push(shape);
            }
            Op::AddSkip { .. } => match skips.pop() {
                Some(saved) if saved == shape => {}
                Some(saved) => {
                    return Err(PacimError::Model(format!(
                        "AddSkip shape mismatch: saved {}×{}×{}, current {}×{}×{}",
                        saved.0, saved.1, saved.2, shape.0, shape.1, shape.2
                    )));
                }
                None => {
                    return Err(PacimError::Model(
                        "AddSkip without a matching SaveSkip".into(),
                    ));
                }
            },
        }
    }
    if !skips.is_empty() {
        return Err(PacimError::Model(format!(
            "model '{}' leaves {} SaveSkip activation(s) unconsumed \
             (every SaveSkip needs a matching AddSkip)",
            model.name,
            skips.len()
        )));
    }
    if compute_layers == 0 {
        return Err(PacimError::Model(format!(
            "model '{}' has no compute layers",
            model.name
        )));
    }
    if !finished {
        return Err(PacimError::Model(format!(
            "model '{}' does not end in a logits layer (a Linear with out_params = None)",
            model.name
        )));
    }
    Ok(())
}
