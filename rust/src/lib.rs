// The AVX-512 popcount tier uses `_mm512_popcnt_epi64` & friends, which
// are unstable on the pinned toolchain; the nightly-only `avx512` cargo
// feature opts into them (see nn::simd and Cargo.toml).
#![cfg_attr(feature = "avx512", feature(stdarch_x86_avx512))]

//! # PACiM — sparsity-centric hybrid compute-in-memory, reproduced
//!
//! Production-quality reproduction of **"PACiM: A Sparsity-Centric Hybrid
//! Compute-in-Memory Architecture via Probabilistic Approximation"**
//! (Zhang et al., ICCAD 2024) as a three-layer rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the architecture simulator and serving
//!   coordinator: bit-true D-CiM bank model, PAC computation engine,
//!   on-die sparsity encoder, memory-hierarchy energy model, integer NN
//!   engine, scheduler, and a multi-worker batch-serving pool that runs
//!   the PAC engine natively (and, behind the `pjrt` feature,
//!   AOT-compiled JAX artifacts through PJRT).
//! - **L2 (python/compile/model.py)** — the quantized CNN compute graph,
//!   lowered once to HLO text at build time.
//! - **L1 (python/compile/kernels/)** — Pallas kernels implementing the
//!   hybrid PAC matmul, validated against a pure-jnp oracle.
//!
//! See `DESIGN.md` at the repository root for the full system inventory
//! and the per-experiment index mapping every table/figure of the paper
//! to a bench target; `README.md` covers build/test/bench usage.
//!
//! Popcount inner loops are tiered (scalar / AVX2 / nightly-only
//! AVX-512 via the `avx512` feature) and runtime-dispatched through
//! [`util::KernelCaps`]; see [`nn::simd`] and DESIGN.md §13.
//!
//! The front door for running inference is [`engine`]: an
//! [`engine::EngineBuilder`] → [`engine::Engine`] → [`engine::Session`]
//! facade returning typed [`engine::PacimError`]s, used by the CLI, the
//! benches, the examples, and the serving executor alike.
//!
//! ## Quick tour
//!
//! ```
//! use pacim::pac::{BitPlanes, ComputeMap, hybrid_mac, PcuRounding};
//!
//! // One CiM column: a DP vector pair of UINT8 operands.
//! let x = vec![200u8, 13, 255, 9, 77, 121, 64, 42];
//! let w = vec![17u8, 250, 3, 88, 120, 199, 31, 5];
//! let (xp, wp) = (BitPlanes::from_u8(&x), BitPlanes::from_u8(&w));
//!
//! // The paper's 4-bit approximation: 16 digital + 48 sparsity cycles.
//! let map = ComputeMap::operand_based(4, 4);
//! let out = hybrid_mac(&xp, &wp, &map, PcuRounding::RoundNearest);
//! let exact: i64 = x.iter().zip(&w).map(|(&a, &b)| a as i64 * b as i64).sum();
//! assert!(((out.value - exact).abs() as f64) / (exact as f64) < 0.25);
//! ```

pub mod arch;
pub mod baselines;
pub mod coordinator;
pub mod energy;
pub mod engine;
pub mod fault;
pub mod memory;
pub mod nn;
pub mod pac;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod workload;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("shape error: {0}")]
    Shape(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;
