//! Bit-plane decomposition and bit-level sparsity extraction (§3.1 "Data
//! Encoding").
//!
//! An N-element UINT8 vector decomposes into 8 binary planes; plane `p`
//! holds bit `p` of every element. The *bit-level sparsity* of plane `p`
//! is its popcount `S[p] = Σ_n v_n[p]` — the quantity the PAC method and
//! the on-die sparsity encoder operate on. Planes are packed into `u64`
//! words so a binary MAC cycle is a word-wise AND + popcount (the software
//! analogue of the D-CiM NOR array + adder tree).

use crate::util::words_for;

/// Packed bit-planes of a UINT8 vector, plus per-plane popcounts.
#[derive(Debug, Clone)]
pub struct BitPlanes {
    /// Element count (DP length n).
    pub n: usize,
    /// `planes[p]` = packed plane of bit `p`, `words_for(n)` words each.
    pub planes: [Vec<u64>; 8],
    /// `pop[p]` = S[p], the bit-level sparsity count of plane `p`.
    pub pop: [u32; 8],
}

impl BitPlanes {
    /// Decompose a UINT8 vector. O(8·n/64) words of output.
    ///
    /// Hot path (§Perf): the whole PAC engine decomposes every im2col
    /// patch through here. Bits are accumulated into eight u64 registers
    /// per 64-element block and stored once per word — ~2.5× faster than
    /// scattering into the plane vectors element by element (the indexed
    /// stores defeated vectorization).
    pub fn from_u8(v: &[u8]) -> Self {
        let n = v.len();
        let words = words_for(n);
        let mut planes: [Vec<u64>; 8] = Default::default();
        for p in planes.iter_mut() {
            *p = vec![0u64; words];
        }
        let mut pop = [0u32; 8];
        for (w, chunk) in v.chunks(64).enumerate() {
            let mut acc = [0u64; 8];
            for (b, &x) in chunk.iter().enumerate() {
                // Spread bit p of x to position b of register p. The
                // compiler unrolls this fixed-trip loop over registers.
                let x = x as u64;
                acc[0] |= (x & 1) << b;
                acc[1] |= ((x >> 1) & 1) << b;
                acc[2] |= ((x >> 2) & 1) << b;
                acc[3] |= ((x >> 3) & 1) << b;
                acc[4] |= ((x >> 4) & 1) << b;
                acc[5] |= ((x >> 5) & 1) << b;
                acc[6] |= ((x >> 6) & 1) << b;
                acc[7] |= ((x >> 7) & 1) << b;
            }
            for p in 0..8 {
                planes[p][w] = acc[p];
                pop[p] += acc[p].count_ones();
            }
        }
        Self { n, planes, pop }
    }

    /// Popcount vector S[0..8] (bit-level sparsity counts).
    pub fn sparsity_counts(&self) -> [u32; 8] {
        self.pop
    }

    /// Sparsity *rates* S[p]/n ∈ [0,1].
    pub fn sparsity_rates(&self) -> [f64; 8] {
        let n = self.n.max(1) as f64;
        let mut r = [0f64; 8];
        for p in 0..8 {
            r[p] = self.pop[p] as f64 / n;
        }
        r
    }

    /// Reconstruct `Σ_n v_n` from the sparsity counts alone:
    /// `Σ v = Σ_p 2^p · S[p]`. The PACiM zero-point correction uses this
    /// identity — the raw activation sum is recoverable from the encoded
    /// sparsity without ever transmitting LSB bits.
    pub fn element_sum(&self) -> u64 {
        (0..8).map(|p| (self.pop[p] as u64) << p).sum()
    }
}

/// Sparsity counts of each bit plane without materializing planes
/// (used by the on-die encoder model and traffic analytics).
pub fn bit_sparsity_counts(v: &[u8]) -> [u32; 8] {
    let mut s = [0u32; 8];
    for &x in v {
        let mut bits = x;
        while bits != 0 {
            let p = bits.trailing_zeros();
            s[p as usize] += 1;
            bits &= bits - 1;
        }
    }
    s
}

/// Per-bit sparsity rates of a tensor slice (Fig. 3(a) profile).
pub fn bit_sparsity_rates(v: &[u8]) -> [f64; 8] {
    let counts = bit_sparsity_counts(v);
    let n = v.len().max(1) as f64;
    let mut r = [0f64; 8];
    for p in 0..8 {
        r[p] = counts[p] as f64 / n;
    }
    r
}

/// Compression ratio of sparsity encoding (§3.1): an n-element B-bit
/// tensor (n·B bits) encodes to B counters of `counter_bits(n)` bits.
pub fn compression_ratio(n: usize, bits: u32) -> f64 {
    let raw = n as f64 * bits as f64;
    let enc = bits as f64 * counter_bits(n) as f64;
    1.0 - enc / raw
}

/// Width of one sparsity counter for DP length n. The paper uses
/// ⌈log2(n)⌉ (8×128b → 8×7b in §3.1): the all-ones count n is encoded by
/// saturating at 2^w − 1, an error of at most 1 LSB in the densest case.
pub fn counter_bits(n: usize) -> u32 {
    debug_assert!(n > 0);
    (64 - (n as u64 - 1).leading_zeros()).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::and_popcount;
    use crate::util::rng::Rng;

    #[test]
    fn planes_reconstruct_values() {
        let v = [0u8, 1, 2, 3, 128, 255, 170, 85];
        let bp = BitPlanes::from_u8(&v);
        for (i, &x) in v.iter().enumerate() {
            let mut rebuilt = 0u8;
            for p in 0..8 {
                let bit = (bp.planes[p][i / 64] >> (i % 64)) & 1;
                rebuilt |= (bit as u8) << p;
            }
            assert_eq!(rebuilt, x);
        }
    }

    #[test]
    fn popcounts_match_naive() {
        let mut rng = Rng::new(1);
        let v: Vec<u8> = (0..777).map(|_| rng.below(256) as u8).collect();
        let bp = BitPlanes::from_u8(&v);
        let naive = bit_sparsity_counts(&v);
        assert_eq!(bp.sparsity_counts(), naive);
    }

    #[test]
    fn element_sum_identity() {
        let mut rng = Rng::new(2);
        let v: Vec<u8> = (0..513).map(|_| rng.below(256) as u8).collect();
        let bp = BitPlanes::from_u8(&v);
        let direct: u64 = v.iter().map(|&x| x as u64).sum();
        assert_eq!(bp.element_sum(), direct);
    }

    #[test]
    fn bitserial_identity_eq1() {
        // Eq. 1: x·w = Σ_{p,q} 2^{p+q} Σ_n x_n[p] w_n[q] — the AND-popcount
        // over planes must reproduce the direct uint product-sum.
        let mut rng = Rng::new(3);
        let n = 300;
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        let mut bitserial = 0u64;
        for p in 0..8 {
            for q in 0..8 {
                let dp = and_popcount(&xp.planes[p], &wp.planes[q]) as u64;
                bitserial += dp << (p + q);
            }
        }
        let direct: u64 = x.iter().zip(&w).map(|(&a, &b)| a as u64 * b as u64).sum();
        assert_eq!(bitserial, direct);
    }

    #[test]
    fn compression_ratio_paper_example() {
        // Paper §3.1: 8×128-bit tensor → 8×7 bits = 95% compression
        // (1024 → 56 bits).
        let r = compression_ratio(128, 8);
        assert!((r - (1.0 - 56.0 / 1024.0)).abs() < 1e-12);
        assert!(r > 0.94);
    }

    #[test]
    fn counter_bits_widths() {
        assert_eq!(counter_bits(127), 7);
        assert_eq!(counter_bits(128), 7);
        assert_eq!(counter_bits(129), 8);
        assert_eq!(counter_bits(1024), 10);
        assert_eq!(counter_bits(1), 1);
    }

    #[test]
    fn empty_vector() {
        let bp = BitPlanes::from_u8(&[]);
        assert_eq!(bp.n, 0);
        assert_eq!(bp.element_sum(), 0);
        assert_eq!(bp.sparsity_rates(), [0.0; 8]);
    }
}
