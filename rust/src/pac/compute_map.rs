//! The digital–sparsity computing map (Fig. 4 and Eq. 4).
//!
//! An 8b/8b MAC decomposes into 64 binary MAC cycles, one per bit-index
//! pair `(p, q)` (activation bit p × weight bit q). The map assigns each
//! cycle to either the **digital** domain 𝔻 (exact D-CiM computation) or
//! the **sparsity** domain 𝔸 (PAC approximation in the CnM unit).
//!
//! PACiM uses an *operand-based* split: the `Bx` MSBs of the activation and
//! `Bw` MSBs of the weight form the digital block 𝔻 = {(p,q) : p ≥ 8−Bx,
//! q ≥ 8−Bw}; everything else is approximated. With the default 4×4 split,
//! 16 of 64 cycles stay digital (75% cycle reduction) and the four LSB
//! weight memory columns are removed entirely.
//!
//! The *dynamic workload configuration* (§5) further drops the
//! lowest-significance digital cycles for low-saliency outputs:
//! 16 → 14 → 12 → 10 cycles, transferring them to the sparsity domain.

/// Domain of one binary MAC cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    /// Exact bit-serial computation in the D-CiM array.
    Digital,
    /// PAC approximation in the CnM unit.
    Sparsity,
}

/// A full 8×8 cycle map. `domain(p, q)` tells where cycle (p,q) runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ComputeMap {
    /// Row-major [p][q]; true = digital.
    digital: [[bool; 8]; 8],
    /// Label for reports.
    pub name: String,
}

impl ComputeMap {
    /// Operand-based PACiM map: digital iff `p ≥ 8−bx && q ≥ 8−bw`.
    /// `operand_based(4, 4)` is the paper's default 4-bit approximation.
    pub fn operand_based(bx: u32, bw: u32) -> Self {
        assert!(bx <= 8 && bw <= 8);
        let mut digital = [[false; 8]; 8];
        for (p, row) in digital.iter_mut().enumerate() {
            for (q, cell) in row.iter_mut().enumerate() {
                *cell = p as u32 >= 8 - bx && q as u32 >= 8 - bw;
            }
        }
        Self {
            digital,
            name: format!("operand-{bx}x{bw}"),
        }
    }

    /// Traditional H-CiM shift-order map (for comparison): digital iff
    /// `p + q ≥ threshold`. This is how prior hybrid designs split cycles.
    pub fn shift_based(threshold: u32) -> Self {
        let mut digital = [[false; 8]; 8];
        for (p, row) in digital.iter_mut().enumerate() {
            for (q, cell) in row.iter_mut().enumerate() {
                *cell = (p + q) as u32 >= threshold;
            }
        }
        Self {
            digital,
            name: format!("shift-ge{threshold}"),
        }
    }

    /// Fully digital map (pure D-CiM baseline).
    pub fn all_digital() -> Self {
        Self {
            digital: [[true; 8]; 8],
            name: "all-digital".into(),
        }
    }

    /// Fully approximate map (pure PAC — used by error analyses).
    pub fn all_sparsity() -> Self {
        Self {
            digital: [[false; 8]; 8],
            name: "all-sparsity".into(),
        }
    }

    #[inline]
    pub fn domain(&self, p: usize, q: usize) -> Domain {
        if self.digital[p][q] {
            Domain::Digital
        } else {
            Domain::Sparsity
        }
    }

    #[inline]
    pub fn is_digital(&self, p: usize, q: usize) -> bool {
        self.digital[p][q]
    }

    /// Number of digital cycles.
    pub fn digital_cycles(&self) -> u32 {
        self.digital
            .iter()
            .flatten()
            .map(|&d| d as u32)
            .sum()
    }

    /// Number of sparsity-domain cycles.
    pub fn sparsity_cycles(&self) -> u32 {
        64 - self.digital_cycles()
    }

    /// All digital (p, q) pairs.
    pub fn digital_set(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for p in 0..8 {
            for q in 0..8 {
                if self.digital[p][q] {
                    v.push((p, q));
                }
            }
        }
        v
    }

    /// All sparsity (p, q) pairs.
    pub fn sparsity_set(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for p in 0..8 {
            for q in 0..8 {
                if !self.digital[p][q] {
                    v.push((p, q));
                }
            }
        }
        v
    }

    /// Weight bit indices that must exist as physical memory columns
    /// (a column is removable only if *no* cycle uses it digitally —
    /// the LSB-column elimination of §4.1/§4.3).
    pub fn required_weight_bits(&self) -> Vec<usize> {
        (0..8)
            .filter(|&q| (0..8).any(|p| self.digital[p][q]))
            .collect()
    }

    /// Activation bits that must be transmitted in binary form (the rest
    /// travel only as sparsity counts).
    pub fn required_activation_bits(&self) -> Vec<usize> {
        (0..8)
            .filter(|&p| (0..8).any(|q| self.digital[p][q]))
            .collect()
    }

    /// Derive a reduced map by moving the `drop` lowest-significance
    /// digital cycles (smallest p+q, tie-break smaller p) to the sparsity
    /// domain — the §5 dynamic workload mechanism (Fig. 4 gray squares).
    pub fn with_dropped_cycles(&self, drop: u32) -> Self {
        let mut cells = self.digital_set();
        cells.sort_by_key(|&(p, q)| (p + q, p));
        let mut out = self.clone();
        for &(p, q) in cells.iter().take(drop as usize) {
            out.digital[p][q] = false;
        }
        out.name = format!("{}-drop{}", self.name, drop);
        out
    }

    /// ASCII rendering of the map (Fig. 4 style): rows = activation bit p
    /// (MSB at top), cols = weight bit q (MSB at left). `D` digital,
    /// `s` sparsity.
    pub fn render(&self) -> String {
        let mut s = String::from("      q=7 6 5 4 3 2 1 0\n");
        for p in (0..8).rev() {
            s.push_str(&format!("  p={p}  "));
            for q in (0..8).rev() {
                s.push(if self.digital[p][q] { 'D' } else { 's' });
                s.push(' ');
            }
            s.push('\n');
        }
        s
    }
}

/// The four dynamic workload levels of §5 / Fig. 6(b): number of digital
/// cycles retained for a 4×4 operand split, selected by the SPEC
/// speculation thresholds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DynamicLevel {
    /// SPEC ≤ TH0 — minimal digital work.
    Cycles10,
    /// TH0 < SPEC ≤ TH1.
    Cycles12,
    /// TH1 < SPEC ≤ TH2.
    Cycles14,
    /// SPEC > TH2 — full 4×4 digital block.
    Cycles16,
}

impl DynamicLevel {
    pub fn digital_cycles(self) -> u32 {
        match self {
            DynamicLevel::Cycles10 => 10,
            DynamicLevel::Cycles12 => 12,
            DynamicLevel::Cycles14 => 14,
            DynamicLevel::Cycles16 => 16,
        }
    }

    /// The compute map for this level (derived from the 4×4 base).
    pub fn map(self) -> ComputeMap {
        let base = ComputeMap::operand_based(4, 4);
        base.with_dropped_cycles(16 - self.digital_cycles())
    }

    pub fn all() -> [DynamicLevel; 4] {
        [
            DynamicLevel::Cycles10,
            DynamicLevel::Cycles12,
            DynamicLevel::Cycles14,
            DynamicLevel::Cycles16,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operand_4x4_counts() {
        let m = ComputeMap::operand_based(4, 4);
        assert_eq!(m.digital_cycles(), 16);
        assert_eq!(m.sparsity_cycles(), 48);
        assert!(m.is_digital(7, 7));
        assert!(m.is_digital(4, 4));
        assert!(!m.is_digital(3, 7));
        assert!(!m.is_digital(7, 3));
        assert!(!m.is_digital(0, 0));
    }

    #[test]
    fn operand_split_reduction_claim() {
        // §4.1: D-CiM cycles reduced from 64 to 16 = 75% reduction.
        let m = ComputeMap::operand_based(4, 4);
        let reduction = 1.0 - m.digital_cycles() as f64 / 64.0;
        assert!((reduction - 0.75).abs() < 1e-12);
    }

    #[test]
    fn lsb_columns_removable() {
        // §4.1: 4-bit approximation eliminates the four LSB weight columns.
        let m = ComputeMap::operand_based(4, 4);
        assert_eq!(m.required_weight_bits(), vec![4, 5, 6, 7]);
        assert_eq!(m.required_activation_bits(), vec![4, 5, 6, 7]);
    }

    #[test]
    fn all_digital_all_sparsity() {
        assert_eq!(ComputeMap::all_digital().digital_cycles(), 64);
        assert_eq!(ComputeMap::all_sparsity().digital_cycles(), 0);
    }

    #[test]
    fn shift_map_differs_from_operand() {
        // A shift-based split with the same digital budget keeps LSB weight
        // columns alive — the reason PACiM's operand split saves area.
        let shift = ComputeMap::shift_based(10); // p+q ∈ {10..14}: 15 cells
        assert!(shift.required_weight_bits().len() > 4);
    }

    #[test]
    fn dynamic_levels_monotone() {
        let mut prev = 0;
        for lvl in DynamicLevel::all() {
            let m = lvl.map();
            assert_eq!(m.digital_cycles(), lvl.digital_cycles());
            assert!(m.digital_cycles() > prev);
            prev = m.digital_cycles();
        }
    }

    #[test]
    fn dropped_cycles_are_lowest_significance() {
        let base = ComputeMap::operand_based(4, 4);
        let lvl14 = base.with_dropped_cycles(2);
        // (4,4) has the smallest p+q=8 and must be dropped first.
        assert!(!lvl14.is_digital(4, 4));
        // MSB cycle always retained.
        assert!(lvl14.is_digital(7, 7));
        // Exactly two dropped.
        assert_eq!(lvl14.digital_cycles(), 14);
        // Dropped set ⊂ base digital set, all with p+q ≤ 9.
        for p in 0..8 {
            for q in 0..8 {
                if base.is_digital(p, q) && !lvl14.is_digital(p, q) {
                    assert!(p + q <= 9, "dropped high-significance ({p},{q})");
                }
            }
        }
    }

    #[test]
    fn render_shape() {
        let m = ComputeMap::operand_based(4, 4);
        let r = m.render();
        assert_eq!(r.lines().count(), 9);
        assert!(r.contains('D') && r.contains('s'));
    }

    #[test]
    fn digital_sparsity_sets_partition() {
        let m = ComputeMap::operand_based(3, 5);
        assert_eq!(m.digital_cycles(), 15);
        let d = m.digital_set();
        let a = m.sparsity_set();
        assert_eq!(d.len() + a.len(), 64);
        for (p, q) in d {
            assert!(p >= 5 && q >= 3);
        }
    }
}
