//! Monte-Carlo error analysis of the PAC method (§3.2, Fig. 3, Table 1).
//!
//! The paper's protocol: simulate a CiM column of DP length `n`, generate
//! binary weight/activation vectors at given sparsity levels, record the
//! actual AND-popcount DP against the PAC point estimate `Sx·Sw/n`
//! (computed from the *actual* popcounts, exactly as the on-die encoder
//! would), over 100K iterations. RMSE is reported in LSB and as a
//! percentage of the DP length.

use super::mac::pac_cycle_f64;
use crate::util::rng::Rng;
use crate::util::stats::{Accumulator, Histogram};
use crate::util::{and_popcount, pack_bits_u64};

/// How the random binary vectors are drawn.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BitModel {
    /// i.i.d. Bernoulli(p) per element — the paper's assumption (Eq. 2).
    Iid,
    /// Correlated bits: runs of identical values with the given mean run
    /// length (> 1). Stresses the independence assumption (DESIGN.md §11
    /// ablation) — real activation bit-planes are spatially correlated.
    Correlated { mean_run: f64 },
}

fn gen_bits(rng: &mut Rng, n: usize, p: f64, model: BitModel) -> Vec<u8> {
    match model {
        BitModel::Iid => rng.binary_bernoulli(n, p),
        BitModel::Correlated { mean_run } => {
            // Markov chain with stationary probability p and mean run
            // length `mean_run` for the '1' state.
            let stay1 = 1.0 - 1.0 / mean_run;
            // Solve stationarity: p·(1−stay1) = (1−p)·p01 → p01.
            let p01 = if p < 1.0 {
                (p * (1.0 - stay1) / (1.0 - p)).min(1.0)
            } else {
                1.0
            };
            let mut v = vec![0u8; n];
            let mut state = rng.bernoulli(p);
            for slot in v.iter_mut() {
                *slot = state as u8;
                state = if state {
                    rng.bernoulli(stay1)
                } else {
                    rng.bernoulli(p01)
                };
            }
            v
        }
    }
}

/// Result of one RMSE experiment.
#[derive(Debug, Clone, Copy)]
pub struct RmseResult {
    pub dp_len: usize,
    pub sparsity_w: f64,
    pub sparsity_x: f64,
    pub iterations: u64,
    /// RMSE of (actual − estimate) in LSB.
    pub rmse_lsb: f64,
    /// RMSE as % of DP length (the paper's RMSE (%) metric).
    pub rmse_pct: f64,
    /// Mean signed error (bias) in LSB.
    pub bias_lsb: f64,
}

/// Core experiment: RMSE of the PAC estimate for one (n, sw, sx) point.
pub fn pac_rmse(
    n: usize,
    sparsity_w: f64,
    sparsity_x: f64,
    iterations: u64,
    seed: u64,
    model: BitModel,
) -> RmseResult {
    let mut rng = Rng::new(seed);
    let mut err = Accumulator::new();
    for _ in 0..iterations {
        let x = gen_bits(&mut rng, n, sparsity_x, model);
        let w = gen_bits(&mut rng, n, sparsity_w, model);
        let sx: u32 = x.iter().map(|&b| b as u32).sum();
        let sw: u32 = w.iter().map(|&b| b as u32).sum();
        let actual = and_popcount(&pack_bits_u64(&x), &pack_bits_u64(&w)) as f64;
        let est = pac_cycle_f64(sx, sw, n as u32);
        err.push(actual - est);
    }
    RmseResult {
        dp_len: n,
        sparsity_w,
        sparsity_x,
        iterations,
        rmse_lsb: err.rms(),
        rmse_pct: err.rms() / n as f64 * 100.0,
        bias_lsb: err.mean(),
    }
}

/// Fig. 3(b): distribution of actual MAC outputs for a typical sparsity
/// combination, against the PAC expectation.
pub struct MacDistribution {
    pub histogram: Histogram,
    pub expected: f64,
    pub rmse_lsb: f64,
    /// Fraction of trials within ±1 RMSE of the estimate (≈68% if
    /// Gaussian, as the paper argues).
    pub within_1_rmse: f64,
}

pub fn mac_distribution(
    n: usize,
    sparsity_w: f64,
    sparsity_x: f64,
    iterations: u64,
    seed: u64,
) -> MacDistribution {
    let mut rng = Rng::new(seed);
    let expected = sparsity_x * sparsity_w * n as f64;
    let span = (expected.sqrt() * 8.0).max(16.0) as i64;
    let center = expected.round() as i64;
    let mut hist = Histogram::new((center - span).max(0), center + span);
    let mut err = Accumulator::new();
    let mut errors = Vec::with_capacity(iterations as usize);
    for _ in 0..iterations {
        let x = rng.binary_bernoulli(n, sparsity_x);
        let w = rng.binary_bernoulli(n, sparsity_w);
        let sx: u32 = x.iter().map(|&b| b as u32).sum();
        let sw: u32 = w.iter().map(|&b| b as u32).sum();
        let actual = and_popcount(&pack_bits_u64(&x), &pack_bits_u64(&w));
        let est = pac_cycle_f64(sx, sw, n as u32);
        hist.push(actual as i64);
        let e = actual as f64 - est;
        err.push(e);
        errors.push(e);
    }
    let rmse = err.rms();
    let within = errors.iter().filter(|e| e.abs() <= rmse).count() as f64
        / errors.len().max(1) as f64;
    MacDistribution {
        histogram: hist,
        expected,
        rmse_lsb: rmse,
        within_1_rmse: within,
    }
}

/// Fig. 3(c): RMSE (%) across DP lengths. Sparsities follow the paper's
/// "typical" operating point unless overridden.
pub fn rmse_vs_dp_length(
    dp_lengths: &[usize],
    sparsity_w: f64,
    sparsity_x: f64,
    iterations: u64,
    seed: u64,
) -> Vec<RmseResult> {
    dp_lengths
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            pac_rmse(
                n,
                sparsity_w,
                sparsity_x,
                iterations,
                seed ^ (i as u64).wrapping_mul(0x9E37_79B9),
                BitModel::Iid,
            )
        })
        .collect()
}

/// Check the n^{-1/2} law: fit log(rmse%) vs log(n) and return the slope.
/// The paper (via the law of large numbers / CLT) predicts ≈ −0.5.
pub fn rmse_scaling_exponent(results: &[RmseResult]) -> f64 {
    assert!(results.len() >= 2);
    let pts: Vec<(f64, f64)> = results
        .iter()
        .filter(|r| r.rmse_pct > 0.0)
        .map(|r| ((r.dp_len as f64).ln(), r.rmse_pct.ln()))
        .collect();
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

/// Theoretical RMSE of the PAC estimate for i.i.d. bits, conditioned on
/// observed popcounts — hypergeometric overlap variance:
/// `Var = Sx·Sw·(n−Sx)·(n−Sw) / (n²·(n−1))`.
/// Used as an analytic cross-check of the Monte-Carlo results.
pub fn theoretical_rmse_lsb(n: usize, sx: f64, sw: f64) -> f64 {
    let nf = n as f64;
    let (sx, sw) = (sx * nf, sw * nf);
    if n < 2 {
        return 0.0;
    }
    (sx * sw * (nf - sx) * (nf - sw) / (nf * nf * (nf - 1.0))).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_paper_operating_point() {
        // §3.2: DP length 1024, typical sparsity → RMSE ≈ 6 LSB (≈ 0.6%).
        let r = pac_rmse(1024, 0.5, 0.3, 4000, 42, BitModel::Iid);
        assert!(
            (4.0..9.0).contains(&r.rmse_lsb),
            "rmse_lsb={} out of paper ballpark",
            r.rmse_lsb
        );
        assert!(r.rmse_pct < 1.0, "rmse_pct={}", r.rmse_pct);
        assert!(r.bias_lsb.abs() < 0.5, "bias={}", r.bias_lsb);
    }

    #[test]
    fn rmse_matches_theory() {
        let r = pac_rmse(512, 0.4, 0.25, 6000, 7, BitModel::Iid);
        let theory = theoretical_rmse_lsb(512, 0.25, 0.4);
        let rel = (r.rmse_lsb - theory).abs() / theory;
        assert!(rel < 0.15, "mc={} theory={theory}", r.rmse_lsb);
    }

    #[test]
    fn rmse_scaling_is_inverse_sqrt() {
        let res = rmse_vs_dp_length(&[64, 256, 1024, 4096], 0.5, 0.3, 2000, 9);
        let slope = rmse_scaling_exponent(&res);
        assert!(
            (-0.62..=-0.38).contains(&slope),
            "scaling exponent {slope} not ≈ -0.5"
        );
    }

    #[test]
    fn rmse_below_1pct_at_conv_lengths() {
        // Paper claim: CONV DP lengths 576..4608 → RMSE < 1%.
        for n in [576, 1152, 2304, 4608] {
            let r = pac_rmse(n, 0.5, 0.3, 1500, 11, BitModel::Iid);
            assert!(r.rmse_pct < 1.0, "n={n} rmse={}", r.rmse_pct);
        }
    }

    #[test]
    fn distribution_centered_on_estimate() {
        let d = mac_distribution(1024, 0.5, 0.3, 4000, 21);
        // ~68% of trials within ±1 RMSE (Gaussian-ish, paper §3.2).
        assert!(
            (0.60..0.78).contains(&d.within_1_rmse),
            "within_1_rmse={}",
            d.within_1_rmse
        );
        assert!(d.histogram.total() == 4000);
        assert!((d.rmse_lsb - 6.0).abs() < 3.0);
    }

    #[test]
    fn correlated_bits_degrade_gracefully() {
        // Correlation does not bias the estimator (popcounts still exact),
        // but the overlap variance grows — PAC degrades, doesn't break.
        let iid = pac_rmse(1024, 0.5, 0.3, 2500, 31, BitModel::Iid);
        let corr = pac_rmse(
            1024,
            0.5,
            0.3,
            2500,
            31,
            BitModel::Correlated { mean_run: 8.0 },
        );
        assert!(corr.rmse_lsb > iid.rmse_lsb, "correlation should increase RMSE");
        assert!(corr.bias_lsb.abs() < 1.0, "bias={}", corr.bias_lsb);
        assert!(corr.rmse_lsb < 10.0 * iid.rmse_lsb);
    }

    #[test]
    fn zero_sparsity_is_exact() {
        let r = pac_rmse(256, 0.0, 0.5, 200, 41, BitModel::Iid);
        assert_eq!(r.rmse_lsb, 0.0);
    }

    #[test]
    fn full_density_is_exact() {
        // All-ones vectors: overlap is deterministic (= n), estimate = n.
        let r = pac_rmse(256, 1.0, 1.0, 200, 43, BitModel::Iid);
        assert_eq!(r.rmse_lsb, 0.0);
    }
}
