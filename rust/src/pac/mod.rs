//! PAC — Probabilistic Approximate Computation (§3 of the paper).
//!
//! The paper's primary contribution: approximate the dot-product of one
//! binary MAC cycle by point estimation on bit-level sparsity,
//! `E(DP) = Sx·Sw/n` (Eq. 3), and split the 64 binary cycles of an 8b/8b
//! MAC between an exact digital domain and this sparsity domain (Eq. 4).
//!
//! - [`sparsity`] — bit-plane decomposition, popcounts, encoding math
//! - [`compute_map`] — the digital/sparsity cycle map (Fig. 4) + dynamic levels
//! - [`mac`] — exact bit-serial, PCU fixed-point, and hybrid MAC kernels
//! - [`error_analysis`] — Monte-Carlo RMSE experiments (Fig. 3, Table 1)

pub mod compute_map;
pub mod error_analysis;
pub mod mac;
pub mod sparsity;

pub use compute_map::{ComputeMap, Domain, DynamicLevel};
pub use mac::{
    exact_mac, exact_mac_bitserial, hybrid_mac, hybrid_mac_batch, par_hybrid_mac_batch,
    pcu_cycle, sparsity_domain_sum, zero_point_correct, HybridMac, PcuRounding,
};
pub use sparsity::{bit_sparsity_counts, bit_sparsity_rates, BitPlanes};
