//! MAC computation: exact bit-serial, PAC-approximate, and the hybrid of
//! Eq. 4 — the numerical heart of the PACiM reproduction.
//!
//! Everything here operates on one DP (dot-product) vector pair
//! `(x, w) ∈ UINT8^n`, i.e. one output activation's worth of MACs as seen
//! by a CiM column. The NN engines (`nn::exec`, `nn::pac_exec`) call these
//! per output element; the error analyses (`pac::error_analysis`) call
//! them per Monte-Carlo trial.

use super::compute_map::ComputeMap;
use super::sparsity::BitPlanes;
use crate::util::and_popcount;
use rayon::prelude::*;

/// Rounding mode of the PCU's fixed-point divide (ablation: §11 of
/// DESIGN.md). Hardware divides by the DP length `n`; `RoundNearest`
/// models a divider with a +n/2 pre-add, `Floor` a bare shifter chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PcuRounding {
    #[default]
    RoundNearest,
    Floor,
}

/// One PAC sparsity-domain cycle (Eq. 3) in PCU fixed-point arithmetic:
/// `DP ≈ Sx·Sw / n`.
///
/// A degenerate empty DP (`n = 0`) divides by 1 — the same guarded rule
/// as `util::fastdiv::FastDiv::for_dp_len`, so the native and
/// reciprocal-multiply divide paths agree on every input (unit-tested in
/// both modules; the guard used to be duplicated at call sites).
#[inline]
pub fn pcu_cycle(sx: u32, sw: u32, n: u32, rounding: PcuRounding) -> u32 {
    let n = n.max(1);
    let prod = sx as u64 * sw as u64;
    match rounding {
        PcuRounding::RoundNearest => ((prod + n as u64 / 2) / n as u64) as u32,
        PcuRounding::Floor => (prod / n as u64) as u32,
    }
}

/// The same cycle in exact real arithmetic (for error analysis).
#[inline]
pub fn pac_cycle_f64(sx: u32, sw: u32, n: u32) -> f64 {
    sx as f64 * sw as f64 / n as f64
}

/// Exact raw MAC `Σ_n x_n·w_n` over UINT8 vectors (direct form).
pub fn exact_mac(x: &[u8], w: &[u8]) -> u64 {
    debug_assert_eq!(x.len(), w.len());
    x.iter().zip(w).map(|(&a, &b)| a as u64 * b as u64).sum()
}

/// Exact raw MAC computed the bit-serial way (Eq. 1) from pre-decomposed
/// planes — must equal `exact_mac` (tested); this is the D-CiM model.
pub fn exact_mac_bitserial(xp: &BitPlanes, wp: &BitPlanes) -> u64 {
    debug_assert_eq!(xp.n, wp.n);
    let mut acc = 0u64;
    for p in 0..8 {
        for q in 0..8 {
            let dp = and_popcount(&xp.planes[p], &wp.planes[q]) as u64;
            acc += dp << (p + q);
        }
    }
    acc
}

/// Outcome of a hybrid MAC, split by domain for the energy/cycle
/// accounting done by the architecture model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HybridMac {
    /// Total approximated raw MAC value (digital + sparsity terms).
    pub value: i64,
    /// Contribution of the digital cycles alone.
    pub digital_part: i64,
    /// Contribution of the PAC-approximated cycles.
    pub sparsity_part: i64,
    /// Number of digital cycles executed.
    pub digital_cycles: u32,
    /// Number of PCU cycles executed.
    pub pcu_cycles: u32,
}

/// Hybrid MAC per Eq. 4: digital cycles run exact AND-popcounts on the
/// planes; sparsity cycles run PCU point estimation on the popcounts.
pub fn hybrid_mac(
    xp: &BitPlanes,
    wp: &BitPlanes,
    map: &ComputeMap,
    rounding: PcuRounding,
) -> HybridMac {
    debug_assert_eq!(xp.n, wp.n);
    let n = xp.n as u32;
    let mut digital = 0i64;
    let mut approx = 0i64;
    let mut dc = 0u32;
    let mut pc = 0u32;
    for p in 0..8 {
        for q in 0..8 {
            if map.is_digital(p, q) {
                let dp = and_popcount(&xp.planes[p], &wp.planes[q]) as i64;
                digital += dp << (p + q);
                dc += 1;
            } else {
                let dp = pcu_cycle(xp.pop[p], wp.pop[q], n, rounding) as i64;
                approx += dp << (p + q);
                pc += 1;
            }
        }
    }
    HybridMac {
        value: digital + approx,
        digital_part: digital,
        sparsity_part: approx,
        digital_cycles: dc,
        pcu_cycles: pc,
    }
}

/// Sequential batched hybrid MAC: one [`hybrid_mac`] per `(x, w)` DP
/// vector pair, in order. The scalar reference for
/// [`par_hybrid_mac_batch`] (and the scalar side of the
/// `perf_hotpath` bench).
pub fn hybrid_mac_batch(
    pairs: &[(BitPlanes, BitPlanes)],
    map: &ComputeMap,
    rounding: PcuRounding,
) -> Vec<HybridMac> {
    pairs
        .iter()
        .map(|(xp, wp)| hybrid_mac(xp, wp, map, rounding))
        .collect()
}

/// Rayon-parallel batched hybrid MAC over independent DP vector pairs —
/// one output activation per pair, work-stolen across the pool.
///
/// **Bit-identical to [`hybrid_mac_batch`]** by construction: each pair
/// is computed independently in pure integer arithmetic and results are
/// collected in input order, so neither thread count nor scheduling can
/// change a single bit of the output (property-tested in
/// `tests/proptests.rs`).
pub fn par_hybrid_mac_batch(
    pairs: &[(BitPlanes, BitPlanes)],
    map: &ComputeMap,
    rounding: PcuRounding,
) -> Vec<HybridMac> {
    pairs
        .par_iter()
        .map(|(xp, wp)| hybrid_mac(xp, wp, map, rounding))
        .collect()
}

/// `sparsity_domain_sum` with a precomputed reciprocal divider — the
/// §Perf fast path used by `nn::pac_exec` (identical results, tested).
pub fn sparsity_domain_sum_fast(
    sx: &[u32; 8],
    sw: &[u32; 8],
    div: &crate::util::fastdiv::FastDiv,
    map: &ComputeMap,
    rounding: PcuRounding,
) -> i64 {
    let mut acc = 0i64;
    for p in 0..8 {
        for q in 0..8 {
            if !map.is_digital(p, q) {
                let prod = sx[p] as u64 * sw[q] as u64;
                let dp = match rounding {
                    PcuRounding::RoundNearest => div.div_round(prod),
                    PcuRounding::Floor => div.div(prod),
                } as i64;
                acc += dp << (p + q);
            }
        }
    }
    acc
}

/// Hybrid MAC where the sparsity terms are pre-aggregated: because the
/// approximation for cycle (p,q) is `Sx[p]·Sw[q]/n`, the full sparsity-
/// domain sum factors per weight column as
/// `Σ_{(p,q)∈𝔸} 2^{p+q}·Sx[p]·Sw[q]/n`. This is what the PCU actually
/// evaluates (one multiply-divide per (p,q), accumulated with shifts);
/// we expose it for the fast NN engine which reuses `Sw` across pixels
/// (weight-stationary, §4.4).
pub fn sparsity_domain_sum(
    sx: &[u32; 8],
    sw: &[u32; 8],
    n: u32,
    map: &ComputeMap,
    rounding: PcuRounding,
) -> i64 {
    let mut acc = 0i64;
    for p in 0..8 {
        for q in 0..8 {
            if !map.is_digital(p, q) {
                let dp = pcu_cycle(sx[p], sw[q], n, rounding) as i64;
                acc += dp << (p + q);
            }
        }
    }
    acc
}

/// Zero-point-corrected integer dot product from a raw (possibly
/// approximated) uint MAC:
/// `Σ (x−zx)(w−zw) = raw − zw·Σx − zx·Σw + n·zx·zw`.
///
/// `sum_x`/`sum_w` are the raw element sums; in PACiM `sum_x` is
/// reconstructed from the encoded sparsity (`BitPlanes::element_sum`) —
/// no LSB transmission needed.
#[inline]
pub fn zero_point_correct(raw: i64, sum_x: i64, sum_w: i64, n: i64, zx: i32, zw: i32) -> i64 {
    raw - zw as i64 * sum_x - zx as i64 * sum_w + n * zx as i64 * zw as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_pair(rng: &mut Rng, n: usize) -> (Vec<u8>, Vec<u8>) {
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        (x, w)
    }

    #[test]
    fn bitserial_equals_direct() {
        let mut rng = Rng::new(10);
        for n in [1usize, 9, 64, 257, 1024] {
            let (x, w) = random_pair(&mut rng, n);
            let xp = BitPlanes::from_u8(&x);
            let wp = BitPlanes::from_u8(&w);
            assert_eq!(exact_mac(&x, &w), exact_mac_bitserial(&xp, &wp), "n={n}");
        }
    }

    #[test]
    fn hybrid_all_digital_is_exact() {
        let mut rng = Rng::new(11);
        let (x, w) = random_pair(&mut rng, 300);
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        let h = hybrid_mac(&xp, &wp, &ComputeMap::all_digital(), PcuRounding::default());
        assert_eq!(h.value as u64, exact_mac(&x, &w));
        assert_eq!(h.sparsity_part, 0);
        assert_eq!(h.digital_cycles, 64);
        assert_eq!(h.pcu_cycles, 0);
    }

    #[test]
    fn hybrid_4x4_close_to_exact() {
        // With DP length 1024 the 4-bit approximation must land within a
        // small relative error of the exact MAC (paper: RMSE < 1%).
        let mut rng = Rng::new(12);
        let n = 1024;
        let map = ComputeMap::operand_based(4, 4);
        let mut worst = 0f64;
        for _ in 0..50 {
            let (x, w) = random_pair(&mut rng, n);
            let xp = BitPlanes::from_u8(&x);
            let wp = BitPlanes::from_u8(&w);
            let h = hybrid_mac(&xp, &wp, &map, PcuRounding::default());
            let exact = exact_mac(&x, &w) as f64;
            let rel = (h.value as f64 - exact).abs() / exact;
            worst = worst.max(rel);
        }
        assert!(worst < 0.01, "worst relative error {worst}");
    }

    #[test]
    fn hybrid_cycle_counts_match_map() {
        let mut rng = Rng::new(13);
        let (x, w) = random_pair(&mut rng, 64);
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        let map = ComputeMap::operand_based(4, 4);
        let h = hybrid_mac(&xp, &wp, &map, PcuRounding::default());
        assert_eq!(h.digital_cycles, 16);
        assert_eq!(h.pcu_cycles, 48);
        assert_eq!(h.value, h.digital_part + h.sparsity_part);
    }

    #[test]
    fn sparsity_domain_sum_matches_hybrid() {
        let mut rng = Rng::new(14);
        let (x, w) = random_pair(&mut rng, 500);
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        let map = ComputeMap::operand_based(4, 4);
        let h = hybrid_mac(&xp, &wp, &map, PcuRounding::RoundNearest);
        let s = sparsity_domain_sum(&xp.pop, &wp.pop, 500, &map, PcuRounding::RoundNearest);
        assert_eq!(h.sparsity_part, s);
    }

    #[test]
    fn pcu_rounding_modes() {
        // 7*3/4 = 5.25 → nearest 5, floor 5; 7*5/4 = 8.75 → nearest 9, floor 8.
        assert_eq!(pcu_cycle(7, 3, 4, PcuRounding::RoundNearest), 5);
        assert_eq!(pcu_cycle(7, 3, 4, PcuRounding::Floor), 5);
        assert_eq!(pcu_cycle(7, 5, 4, PcuRounding::RoundNearest), 9);
        assert_eq!(pcu_cycle(7, 5, 4, PcuRounding::Floor), 8);
    }

    #[test]
    fn pcu_cycle_never_exceeds_n_bound() {
        // DP of length n can be at most n; the estimate Sx·Sw/n ≤ n because
        // Sx, Sw ≤ n.
        let mut rng = Rng::new(15);
        for _ in 0..1000 {
            let n = 1 + rng.below(2048);
            let sx = rng.below(n + 1);
            let sw = rng.below(n + 1);
            let e = pcu_cycle(sx, sw, n, PcuRounding::RoundNearest);
            assert!(e <= n, "sx={sx} sw={sw} n={n} e={e}");
        }
    }

    #[test]
    fn empty_dp_divide_guard_consistent() {
        // k = 0: both divide paths follow the divide-by-1 rule, so an
        // empty layer cannot make them diverge.
        use crate::util::fastdiv::FastDiv;
        let f = FastDiv::for_dp_len(0);
        for (sx, sw) in [(0u32, 0u32), (5, 7), (255, 1)] {
            let prod = sx as u64 * sw as u64;
            assert_eq!(pcu_cycle(sx, sw, 0, PcuRounding::Floor) as u64, f.div(prod));
            assert_eq!(
                pcu_cycle(sx, sw, 0, PcuRounding::RoundNearest) as u64,
                f.div_round(prod)
            );
            assert_eq!(
                pcu_cycle(sx, sw, 0, PcuRounding::Floor),
                pcu_cycle(sx, sw, 1, PcuRounding::Floor)
            );
        }
        // And the aggregated sparsity-domain sum inherits the guard.
        let map = ComputeMap::operand_based(4, 4);
        let s0 = sparsity_domain_sum(&[3; 8], &[2; 8], 0, &map, PcuRounding::RoundNearest);
        let s1 = sparsity_domain_sum(&[3; 8], &[2; 8], 1, &map, PcuRounding::RoundNearest);
        assert_eq!(s0, s1);
    }

    #[test]
    fn zero_point_correction_identity() {
        // Correcting the raw uint MAC must equal the signed dot product.
        let mut rng = Rng::new(16);
        let n = 200;
        let (x, w) = random_pair(&mut rng, n);
        let (zx, zw) = (17i32, 128i32);
        let raw = exact_mac(&x, &w) as i64;
        let sum_x: i64 = x.iter().map(|&v| v as i64).sum();
        let sum_w: i64 = w.iter().map(|&v| v as i64).sum();
        let corrected = zero_point_correct(raw, sum_x, sum_w, n as i64, zx, zw);
        let direct: i64 = x
            .iter()
            .zip(&w)
            .map(|(&a, &b)| (a as i64 - zx as i64) * (b as i64 - zw as i64))
            .sum();
        assert_eq!(corrected, direct);
    }

    #[test]
    fn par_batch_matches_sequential_batch() {
        let mut rng = Rng::new(18);
        let map = ComputeMap::operand_based(4, 4);
        let pairs: Vec<(BitPlanes, BitPlanes)> = (0..64)
            .map(|_| {
                let (x, w) = random_pair(&mut rng, 576);
                (BitPlanes::from_u8(&x), BitPlanes::from_u8(&w))
            })
            .collect();
        let seq = hybrid_mac_batch(&pairs, &map, PcuRounding::RoundNearest);
        let par = par_hybrid_mac_batch(&pairs, &map, PcuRounding::RoundNearest);
        assert_eq!(seq, par);
        for (i, (xp, wp)) in pairs.iter().enumerate() {
            assert_eq!(seq[i], hybrid_mac(xp, wp, &map, PcuRounding::RoundNearest), "pair {i}");
        }
    }

    #[test]
    fn batch_empty_input() {
        let map = ComputeMap::operand_based(4, 4);
        assert!(hybrid_mac_batch(&[], &map, PcuRounding::Floor).is_empty());
        assert!(par_hybrid_mac_batch(&[], &map, PcuRounding::Floor).is_empty());
    }

    #[test]
    fn unbiasedness_of_pac_estimate() {
        // E[actual − estimate] ≈ 0 over random vectors with fixed
        // popcounts: PAC is an unbiased point estimator (binomial mean).
        let mut rng = Rng::new(17);
        let n = 512;
        let (sx, sw) = (150usize, 300usize);
        let mut err_sum = 0f64;
        let iters = 3000;
        for _ in 0..iters {
            let x = rng.binary_with_popcount(n, sx);
            let w = rng.binary_with_popcount(n, sw);
            let actual: u32 = x.iter().zip(&w).map(|(&a, &b)| (a & b) as u32).sum();
            let est = pac_cycle_f64(sx as u32, sw as u32, n as u32);
            err_sum += actual as f64 - est;
        }
        let bias = err_sum / iters as f64;
        assert!(bias.abs() < 0.5, "bias={bias}");
    }
}
