//! The PAC-native batch executor: serving without PJRT.
//!
//! [`PacExecutor`] is a thin [`BatchExecutor`] adapter over the typed
//! engine front door ([`crate::engine::Engine`]): each request lane is
//! quantized to u8 and run through one [`Session`] whose per-lane
//! scratch arenas persist across `execute` calls, so a warm worker's
//! whole forward pass allocates nothing per pixel. Intra-batch
//! parallelism fans the lanes out over rayon via [`Parallelism::coarse`]
//! (one lane = one whole forward pass).
//!
//! The executor is `Clone` (the engine — packed weight bit-planes,
//! sparsity counts, cost model — is `Arc`-shared; each clone gets its
//! own session arenas), so a worker pool shares one weight preparation:
//! `InferenceServer::start_pool(move |_| Ok(exec.clone()), policy)`.
//!
//! Every executor carries the modeled PACiM cost of one image
//! ([`CostEstimate`], computed by the engine builder), which the server
//! attaches to each reply — a load test against this executor reports
//! software latency *and* modeled silicon cycles/energy side by side.

use crate::coordinator::scheduler::CostEstimate;
use crate::coordinator::server::{BatchExecutor, ExecTelemetry};
use crate::engine::{Engine, EngineBuilder, Fidelity, PacimError, Session};
use crate::nn::exec::RunStats;
use crate::nn::layers::Model;
use crate::nn::pac_exec::PacConfig;
use crate::util::Parallelism;

/// A pure-rust [`BatchExecutor`] adapter over [`Engine`].
#[derive(Clone, Debug)]
pub struct PacExecutor {
    engine: Engine,
    /// Per-executor session: lane-indexed scratch arenas kept across
    /// `execute` calls (each pool worker clones the executor, so arenas
    /// are per-worker).
    session: Session,
    batch: usize,
    stats: RunStats,
}

impl PacExecutor {
    /// Adapt a built engine to the serving trait at batch size `batch`
    /// (≥ 1; a zero-lane executor tile can serve no requests).
    pub fn from_engine(engine: Engine, batch: usize) -> Result<Self, PacimError> {
        if batch == 0 {
            return Err(PacimError::InvalidConfig(
                "executor batch size must be ≥ 1 (got 0)".into(),
            ));
        }
        // The session inherits the engine's lane policy (default
        // `Parallelism::coarse`); `with_parallelism` overrides per clone.
        let mut session = engine.session();
        session.reserve_lanes(batch);
        Ok(Self {
            engine,
            session,
            batch,
            stats: RunStats::default(),
        })
    }

    /// Build a PAC executor for `model` at batch size `batch`. Weight
    /// bit-planes are packed once, by the engine builder; the cost
    /// annotation follows the config (dynamic thresholds report the
    /// dynamic schedule, static the 4-bit default).
    pub fn new(model: Model, config: PacConfig, batch: usize) -> Result<Self, PacimError> {
        let engine = EngineBuilder::new(model)
            .pac(config)
            .parallelism(Parallelism::off())
            .build()?;
        Self::from_engine(engine, batch)
    }

    /// Exact 8b/8b baseline executor (for A/B serving comparisons); its
    /// cost annotation uses the fully digital schedule.
    pub fn exact(model: Model, batch: usize) -> Result<Self, PacimError> {
        let engine = EngineBuilder::new(model)
            .exact()
            .parallelism(Parallelism::off())
            .build()?;
        Self::from_engine(engine, batch)
    }

    /// Override the intra-batch (lane) parallelism policy.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.session.set_lane_parallelism(par);
        self
    }

    /// Cumulative engine statistics for everything this executor (clone)
    /// has served.
    pub fn stats(&self) -> &RunStats {
        &self.stats
    }

    pub fn model(&self) -> &Model {
        self.engine.model()
    }

    /// The shared engine behind this executor.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Start one sharded serving pool per registered model and put them
    /// behind a single routing front door (the `pacim serve --models`
    /// path). Each tenant's pool clones one [`PacExecutor`] per worker —
    /// the engine's packed weight planes are `Arc`-shared, so replicas
    /// cost only their session arenas — and keeps the spec's
    /// [`BatchPolicy`](crate::coordinator::BatchPolicy), default
    /// [`Fidelity`], and default
    /// [`SloClass`](crate::coordinator::SloClass).
    pub fn serve_registry(
        registry: crate::coordinator::ModelRegistry,
    ) -> anyhow::Result<crate::coordinator::MultiModelServer> {
        use crate::coordinator::{InferenceServer, MultiModelServer, Tenant};
        if registry.is_empty() {
            anyhow::bail!("model registry is empty; register at least one ModelSpec");
        }
        let mut tenants = Vec::with_capacity(registry.len());
        for spec in registry.into_specs() {
            let exec = PacExecutor::from_engine(spec.engine.clone(), spec.batch)?;
            let server =
                InferenceServer::start_pool(move |_| Ok(exec.clone()), spec.policy)?;
            tenants.push(Tenant {
                id: spec.id,
                server,
                default_fidelity: spec.default_fidelity,
                default_slo: spec.default_slo,
            });
        }
        Ok(MultiModelServer::from_tenants(tenants)?)
    }
}

impl BatchExecutor for PacExecutor {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn input_elems(&self) -> usize {
        self.engine.input_elems()
    }

    fn output_elems(&self) -> usize {
        self.engine.output_elems()
    }

    fn execute(&mut self, batch: &[f32], occupancy: usize) -> anyhow::Result<Vec<f32>> {
        self.run(batch, occupancy, None)
    }

    fn execute_with(
        &mut self,
        batch: &[f32],
        occupancy: usize,
        fidelities: &[Fidelity],
    ) -> anyhow::Result<Vec<f32>> {
        self.run(batch, occupancy, Some(fidelities))
    }

    fn cost_estimate(&self) -> Option<CostEstimate> {
        Some(self.engine.cost_estimate())
    }

    fn telemetry(&self) -> ExecTelemetry {
        ExecTelemetry {
            traffic_bits: self.stats.traffic.total_bits(),
            traffic_baseline_bits: self.stats.traffic.total_baseline_bits(),
            escalated: self.stats.escalations,
        }
    }
}

impl PacExecutor {
    /// The shared execute path: quantize the occupied lanes and run them
    /// through the session — fanned out when every lane is `Fast` (or no
    /// fidelities were given), fidelity-routed otherwise.
    fn run(
        &mut self,
        batch: &[f32],
        occupancy: usize,
        fidelities: Option<&[Fidelity]>,
    ) -> anyhow::Result<Vec<f32>> {
        let in_elems = self.input_elems();
        let out_elems = self.output_elems();
        if batch.len() != self.batch * in_elems {
            return Err(PacimError::ShapeMismatch {
                context: "PacExecutor::execute batch buffer".into(),
                got: batch.len(),
                want: self.batch * in_elems,
            }
            .into());
        }
        // No fixed compiled batch here: padded lanes would burn a whole
        // forward pass each and pollute the stats, so only the occupied
        // lanes run; the rest of the output is zero-filled (the server
        // never reads it).
        let occupancy = occupancy.clamp(1, self.batch);
        let p = self.engine.model().input_params;
        let quantized: Vec<u8> = batch[..occupancy * in_elems]
            .iter()
            .map(|&x| p.quantize(x))
            .collect();
        let images: Vec<&[u8]> = quantized.chunks_exact(in_elems).collect();
        let lanes = match fidelities {
            Some(f) => {
                if f.len() != occupancy {
                    return Err(PacimError::ShapeMismatch {
                        context: "PacExecutor::execute_with fidelities".into(),
                        got: f.len(),
                        want: occupancy,
                    }
                    .into());
                }
                self.session.infer_batch_with(&images, f)?
            }
            None => self.session.infer_batch(&images)?,
        };
        let mut out = vec![0f32; self.batch * out_elems];
        for (lane, inf) in lanes.iter().enumerate() {
            self.stats.merge(&inf.stats);
            out[lane * out_elems..(lane + 1) * out_elems].copy_from_slice(&inf.logits);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::synthetic_serving_workload;

    fn workload() -> (Model, crate::workload::Dataset) {
        synthetic_serving_workload(900, 8, 16, 10, 8).unwrap()
    }

    #[test]
    fn executor_matches_offline_inference_bit_exactly() {
        let (model, ds) = workload();
        let offline_engine = EngineBuilder::new(model.clone())
            .pac(PacConfig::serving())
            .build()
            .unwrap();
        let mut offline_session = offline_engine.session();
        let offline: Vec<Vec<f32>> = (0..4)
            .map(|i| offline_session.infer(ds.image(i)).unwrap().logits)
            .collect();
        let mut exec = PacExecutor::new(model, PacConfig::serving(), 4).unwrap();
        let in_elems = exec.input_elems();
        let mut flat = vec![0f32; 4 * in_elems];
        for i in 0..4 {
            for (j, &q) in ds.image(i).iter().enumerate() {
                flat[i * in_elems + j] = ds.params.dequantize(q);
            }
        }
        let out = exec.execute(&flat, 4).unwrap();
        for (i, logits) in offline.iter().enumerate() {
            assert_eq!(&out[i * 10..(i + 1) * 10], logits.as_slice(), "lane {i}");
        }
        assert!(exec.stats().macs > 0);
    }

    #[test]
    fn padded_lanes_are_not_computed() {
        let (model, ds) = workload();
        let mut exec = PacExecutor::new(model, PacConfig::serving(), 4).unwrap();
        let in_elems = exec.input_elems();
        let mut flat = vec![0f32; 4 * in_elems];
        for (j, &q) in ds.image(0).iter().enumerate() {
            flat[j] = ds.params.dequantize(q);
        }
        let out = exec.execute(&flat, 1).unwrap();
        let one_lane_macs = exec.stats().macs;
        // Stats count exactly one forward pass, not four.
        assert_eq!(one_lane_macs, exec.model().macs());
        // Output stays full-size; padded lanes are zero-filled.
        assert_eq!(out.len(), 4 * 10);
        assert!(out[10..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lane_parallelism_is_bit_deterministic() {
        let (model, ds) = workload();
        let mk = |par: Parallelism| {
            PacExecutor::new(model.clone(), PacConfig::serving(), 4)
                .unwrap()
                .with_parallelism(par)
        };
        let mut scalar = mk(Parallelism::off());
        let mut coarse = mk(Parallelism::coarse());
        let in_elems = scalar.input_elems();
        let mut flat = vec![0f32; 4 * in_elems];
        for i in 0..4 {
            for (j, &q) in ds.image(i).iter().enumerate() {
                flat[i * in_elems + j] = ds.params.dequantize(q);
            }
        }
        assert_eq!(
            scalar.execute(&flat, 4).unwrap(),
            coarse.execute(&flat, 4).unwrap()
        );
    }

    #[test]
    fn cost_annotation_present_and_cheaper_than_exact() {
        let (model, _) = workload();
        let pac = PacExecutor::new(model.clone(), PacConfig::serving(), 2).unwrap();
        let exact = PacExecutor::exact(model, 2).unwrap();
        let cp = pac.cost_estimate().unwrap();
        let ce = exact.cost_estimate().unwrap();
        assert!(cp.cycles < ce.cycles);
        assert!(cp.total_uj() < ce.total_uj());
    }

    #[test]
    fn wrong_batch_buffer_rejected() {
        let (model, _) = workload();
        let mut exec = PacExecutor::new(model, PacConfig::serving(), 2).unwrap();
        assert!(exec.execute(&[0.0; 7], 1).is_err());
    }

    #[test]
    fn zero_batch_is_a_typed_config_error() {
        let (model, _) = workload();
        let err = PacExecutor::new(model, PacConfig::serving(), 0).unwrap_err();
        assert!(matches!(err, PacimError::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn fidelity_classes_route_through_the_executor() {
        use crate::nn::pac_exec::EscalationConfig;
        let (model, ds) = workload();
        // An unreachable margin floor: every Auto lane escalates.
        let config = PacConfig {
            escalation: Some(EscalationConfig {
                min_margin: 1e30,
                sigma: 0.0,
            }),
            ..PacConfig::serving()
        };
        let mut exec = PacExecutor::new(model.clone(), config, 2).unwrap();
        let in_elems = exec.input_elems();
        let mut flat = vec![0f32; 2 * in_elems];
        for i in 0..2 {
            for (j, &q) in ds.image(i).iter().enumerate() {
                flat[i * in_elems + j] = ds.params.dequantize(q);
            }
        }
        let auto = exec.execute_with(&flat, 2, &[Fidelity::Auto, Fidelity::Auto]).unwrap();
        assert_eq!(exec.stats().escalations, 2);
        let t = exec.telemetry();
        assert_eq!(t.escalated, 2);
        assert!(t.traffic_bits > 0);
        assert!(t.traffic_baseline_bits >= t.traffic_bits);
        // Escalated lanes carry the exact engine's logits.
        let mut exact = PacExecutor::exact(model, 2).unwrap();
        let want = exact.execute(&flat, 2).unwrap();
        assert_eq!(auto, want);
        // A mismatched fidelity slice is a typed error.
        assert!(exec.execute_with(&flat, 2, &[Fidelity::Fast]).is_err());
    }
}
