//! The PAC-native batch executor: serving without PJRT.
//!
//! [`PacExecutor`] implements [`BatchExecutor`] directly on top of the
//! bit-true engine (`nn::exec` + `nn::pac_exec`): each request lane is
//! quantized to u8, run through im2col → bit-plane encoding → hybrid
//! digital/sparsity MAC, and the float logits are returned. Intra-batch
//! parallelism fans the lanes out over rayon via [`Parallelism::coarse`]
//! (one lane = one whole forward pass).
//!
//! The executor is `Clone` (the prepared backend — packed weight
//! bit-planes, sparsity counts — is behind an `Arc`), so a worker pool
//! shares one weight preparation: `InferenceServer::start_pool(move |_|
//! Ok(exec.clone()), policy)`.
//!
//! Every executor carries the modeled PACiM cost of one image
//! ([`CostEstimate`], from `coordinator::scheduler`), which the server
//! attaches to each reply — a load test against this executor reports
//! software latency *and* modeled silicon cycles/energy side by side.

use crate::coordinator::scheduler::{
    estimate_image_cost, model_shapes, CostEstimate, ScheduleConfig,
};
use crate::coordinator::server::BatchExecutor;
use crate::energy::EnergyModel;
use crate::nn::exec::{
    exact_backend, run_model_batch_with, ExactBackend, ModelScratch, RunStats,
};
use crate::nn::layers::Model;
use crate::nn::pac_exec::{pac_backend, PacBackend, PacConfig};
use crate::util::Parallelism;
use std::sync::Arc;

/// The prepared compute engine behind an executor.
enum Engine {
    /// Hybrid digital/sparsity PAC computation (the paper's architecture).
    Pac(PacBackend),
    /// Exact 8b/8b integer baseline (fully digital D-CiM).
    Exact(ExactBackend),
}

impl Engine {
    fn run_batch(
        &self,
        model: &Model,
        images: &[&[u8]],
        par: &Parallelism,
        scratches: &mut [ModelScratch],
    ) -> Vec<(Vec<f32>, RunStats)> {
        match self {
            Engine::Pac(b) => run_model_batch_with(model, b, images, par, scratches),
            Engine::Exact(b) => run_model_batch_with(model, b, images, par, scratches),
        }
    }
}

/// A pure-rust [`BatchExecutor`] over the PAC engine.
#[derive(Clone)]
pub struct PacExecutor {
    model: Arc<Model>,
    engine: Arc<Engine>,
    batch: usize,
    par: Parallelism,
    cost: CostEstimate,
    stats: RunStats,
    /// Per-lane scratch arenas, kept across `execute` calls: a warm
    /// worker's forward passes reuse the im2col / packed-plane /
    /// accumulator buffers — zero steady-state allocation per pixel.
    /// (Each worker clones the executor, so arenas are per-worker.)
    scratch: Vec<ModelScratch>,
}

impl PacExecutor {
    /// Build a PAC executor for `model` at compiled batch size `batch`.
    /// Weight bit-planes are packed once, here. The cost annotation
    /// follows the config: dynamic thresholds report the dynamic
    /// schedule (avg 12 digital cycles), static the 4-bit default.
    pub fn new(model: Model, config: PacConfig, batch: usize) -> Self {
        let sched = if config.thresholds.is_some() {
            ScheduleConfig::pacim_dynamic()
        } else {
            ScheduleConfig::pacim_default()
        };
        let engine = Engine::Pac(pac_backend(&model, config));
        Self::build(model, engine, batch, sched)
    }

    /// Exact 8b/8b baseline executor (for A/B serving comparisons); its
    /// cost annotation uses the fully digital schedule.
    pub fn exact(model: Model, batch: usize) -> Self {
        let engine = Engine::Exact(exact_backend(&model));
        Self::build(model, engine, batch, ScheduleConfig::digital_baseline())
    }

    fn build(model: Model, engine: Engine, batch: usize, sched: ScheduleConfig) -> Self {
        let shapes = model_shapes(&model);
        let cost = estimate_image_cost(&shapes, &sched, &EnergyModel::default());
        let batch = batch.max(1);
        Self {
            model: Arc::new(model),
            engine: Arc::new(engine),
            batch,
            par: Parallelism::coarse(),
            cost,
            stats: RunStats::default(),
            scratch: vec![ModelScratch::default(); batch],
        }
    }

    /// Override the intra-batch (lane) parallelism policy.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// Cumulative engine statistics for everything this executor (clone)
    /// has served.
    pub fn stats(&self) -> &RunStats {
        &self.stats
    }

    pub fn model(&self) -> &Model {
        &self.model
    }
}

impl BatchExecutor for PacExecutor {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn input_elems(&self) -> usize {
        self.model.in_c * self.model.in_hw * self.model.in_hw
    }

    fn output_elems(&self) -> usize {
        self.model.num_classes
    }

    fn execute(&mut self, batch: &[f32], occupancy: usize) -> anyhow::Result<Vec<f32>> {
        let in_elems = self.input_elems();
        anyhow::ensure!(
            batch.len() == self.batch * in_elems,
            "batch buffer has {} elems, expected {}",
            batch.len(),
            self.batch * in_elems
        );
        // No fixed compiled batch here: padded lanes would burn a whole
        // forward pass each and pollute the stats, so only the occupied
        // lanes run; the rest of the output is zero-filled (the server
        // never reads it).
        let occupancy = occupancy.clamp(1, self.batch);
        let p = self.model.input_params;
        let quantized: Vec<u8> = batch[..occupancy * in_elems]
            .iter()
            .map(|&x| p.quantize(x))
            .collect();
        let images: Vec<&[u8]> = quantized.chunks_exact(in_elems).collect();
        let lanes =
            self.engine
                .run_batch(&self.model, &images, &self.par, &mut self.scratch);
        let mut out = vec![0f32; self.batch * self.model.num_classes];
        for (lane, (logits, st)) in lanes.iter().enumerate() {
            self.stats.merge(st);
            out[lane * self.model.num_classes..(lane + 1) * self.model.num_classes]
                .copy_from_slice(logits);
        }
        Ok(out)
    }

    fn cost_estimate(&self) -> Option<CostEstimate> {
        Some(self.cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::run_model;
    use crate::workload::synthetic_serving_workload;

    fn workload() -> (Model, crate::workload::Dataset) {
        synthetic_serving_workload(900, 8, 16, 10, 8).unwrap()
    }

    #[test]
    fn executor_matches_offline_inference_bit_exactly() {
        let (model, ds) = workload();
        let offline: Vec<Vec<f32>> = (0..4)
            .map(|i| {
                let backend = pac_backend(&model, PacConfig::serving());
                run_model(&model, &backend, ds.image(i)).0
            })
            .collect();
        let mut exec = PacExecutor::new(model, PacConfig::serving(), 4);
        let in_elems = exec.input_elems();
        let mut flat = vec![0f32; 4 * in_elems];
        for i in 0..4 {
            for (j, &q) in ds.image(i).iter().enumerate() {
                flat[i * in_elems + j] = ds.params.dequantize(q);
            }
        }
        let out = exec.execute(&flat, 4).unwrap();
        for (i, logits) in offline.iter().enumerate() {
            assert_eq!(&out[i * 10..(i + 1) * 10], logits.as_slice(), "lane {i}");
        }
        assert!(exec.stats().macs > 0);
    }

    #[test]
    fn padded_lanes_are_not_computed() {
        let (model, ds) = workload();
        let mut exec = PacExecutor::new(model, PacConfig::serving(), 4);
        let in_elems = exec.input_elems();
        let mut flat = vec![0f32; 4 * in_elems];
        for (j, &q) in ds.image(0).iter().enumerate() {
            flat[j] = ds.params.dequantize(q);
        }
        let out = exec.execute(&flat, 1).unwrap();
        let one_lane_macs = exec.stats().macs;
        // Stats count exactly one forward pass, not four.
        assert_eq!(one_lane_macs, exec.model().macs());
        // Output stays full-size; padded lanes are zero-filled.
        assert_eq!(out.len(), 4 * 10);
        assert!(out[10..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lane_parallelism_is_bit_deterministic() {
        let (model, ds) = workload();
        let mk = |par: Parallelism| {
            PacExecutor::new(model.clone(), PacConfig::serving(), 4).with_parallelism(par)
        };
        let mut scalar = mk(Parallelism::off());
        let mut coarse = mk(Parallelism::coarse());
        let in_elems = scalar.input_elems();
        let mut flat = vec![0f32; 4 * in_elems];
        for i in 0..4 {
            for (j, &q) in ds.image(i).iter().enumerate() {
                flat[i * in_elems + j] = ds.params.dequantize(q);
            }
        }
        assert_eq!(
            scalar.execute(&flat, 4).unwrap(),
            coarse.execute(&flat, 4).unwrap()
        );
    }

    #[test]
    fn cost_annotation_present_and_cheaper_than_exact() {
        let (model, _) = workload();
        let pac = PacExecutor::new(model.clone(), PacConfig::serving(), 2);
        let exact = PacExecutor::exact(model, 2);
        let cp = pac.cost_estimate().unwrap();
        let ce = exact.cost_estimate().unwrap();
        assert!(cp.cycles < ce.cycles);
        assert!(cp.total_uj() < ce.total_uj());
    }

    #[test]
    fn wrong_batch_buffer_rejected() {
        let (model, _) = workload();
        let mut exec = PacExecutor::new(model, PacConfig::serving(), 2);
        assert!(exec.execute(&[0.0; 7], 1).is_err());
    }
}
