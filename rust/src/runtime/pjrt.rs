//! The PJRT executor proper — only compiled with the `pjrt` feature,
//! which requires the vendored `xla` bindings (xla-rs) and a local XLA
//! toolchain. Everything else in the crate (including the serving
//! coordinator, which is generic over [`BatchExecutor`]) builds and tests
//! without it.

use crate::coordinator::server::BatchExecutor;
use anyhow::{Context, Result};
use std::path::Path;

/// A compiled model executable on the PJRT CPU client.
pub struct PjrtExecutor {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    input_elems: usize,
    output_elems: usize,
}

impl PjrtExecutor {
    /// Load HLO text, compile on the CPU client.
    ///
    /// The artifact's entry computation must take one f32 parameter of
    /// shape `[batch, input_elems…]` and return a 1-tuple of f32
    /// `[batch, output_elems]` (the aot.py convention).
    pub fn load(
        hlo_path: impl AsRef<Path>,
        batch: usize,
        input_elems: usize,
        output_elems: usize,
    ) -> Result<Self> {
        let path = hlo_path.as_ref();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Self {
            exe,
            batch,
            input_elems,
            output_elems,
        })
    }

    /// Run one batch (flattened `[batch × input_elems]` f32).
    pub fn run(&self, flat: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            flat.len() == self.batch * self.input_elems,
            "batch buffer has {} elems, expected {}",
            flat.len(),
            self.batch * self.input_elems
        );
        let lit = xla::Literal::vec1(flat)
            .reshape(&[self.batch as i64, self.input_elems as i64])
            .context("reshape input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let out = result.to_tuple1().context("unwrap result tuple")?;
        let values = out.to_vec::<f32>().context("read result values")?;
        anyhow::ensure!(
            values.len() == self.batch * self.output_elems,
            "result has {} elems, expected {}",
            values.len(),
            self.batch * self.output_elems
        );
        Ok(values)
    }
}

impl BatchExecutor for PjrtExecutor {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn input_elems(&self) -> usize {
        self.input_elems
    }

    fn output_elems(&self) -> usize {
        self.output_elems
    }

    fn execute(&mut self, batch: &[f32], _occupancy: usize) -> Result<Vec<f32>> {
        // The compiled executable has a fixed batch; padded lanes run
        // anyway and are discarded by the server.
        self.run(batch)
    }
}

// No unit tests here: PJRT execution requires artifacts, covered by
// rust/tests/integration_runtime.rs (skips gracefully when artifacts are
// missing) and examples/.
