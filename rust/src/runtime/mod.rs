//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them
//! on the CPU PJRT client — the only place the `xla` crate is touched.
//!
//! Interchange format is HLO **text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! `python/compile/aot.py` and /opt/xla-example/README.md).
//!
//! The executor itself lives behind the `pjrt` cargo feature so the crate
//! builds, tests, and benches with **no JAX/XLA toolchain installed**
//! (DESIGN.md §8): artifact manifests, weight stores, and datasets load
//! unconditionally; only `PjrtExecutor` needs the feature. Without it,
//! the serving coordinator still runs against any other
//! [`crate::coordinator::server::BatchExecutor`] implementation.

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::Manifest;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtExecutor;
