//! Execution runtimes for the serving coordinator.
//!
//! Two [`crate::coordinator::server::BatchExecutor`] implementations
//! live here:
//!
//! - [`PacExecutor`] — the PAC-native path: quantize → im2col →
//!   bit-plane encode → hybrid MAC, pure rust, always available. This is
//!   what `pacim serve` and `examples/loadgen.rs` run.
//! - `PjrtExecutor` — loads AOT-compiled HLO **text** artifacts and
//!   executes them on the CPU PJRT client; the only place the `xla`
//!   crate is touched. Interchange is HLO text, not serialized
//!   `HloModuleProto`: jax ≥ 0.5 emits protos with 64-bit instruction
//!   ids that xla_extension 0.5.1 rejects; the text parser reassigns ids
//!   (see `python/compile/aot.py`).
//!
//! The PJRT executor lives behind the `pjrt` cargo feature so the crate
//! builds, tests, and benches with **no JAX/XLA toolchain installed**
//! (DESIGN.md §8): artifact manifests, weight stores, datasets, and the
//! PAC-native serving path all load unconditionally.

pub mod manifest;
pub mod pac_executor;

#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::Manifest;
pub use pac_executor::PacExecutor;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtExecutor;
