//! On-die sparsity encoder (§4.5, Fig. 5 ③).
//!
//! Converts 8-bit activations coming off the BN/AF/quant pipeline into
//! sparsity format: eight counters track the number of '1's at each bit
//! index over an *encoding group*. For CONV layers the group is one output
//! pixel across all channels (pixel-wise); for LINEAR layers it is the
//! whole layer (layer-wise). When a group's MACs span multiple weight
//! tiles in a single-bank system, the intermediate encoding buffer
//! checkpoints the counters across weight updates; a multi-bank schedule
//! eliminates the buffer entirely.

/// Encoding granularity (§4.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EncodingMode {
    /// CONV: one group per output pixel, across channels.
    PixelWise,
    /// LINEAR: one group for the whole layer's activations.
    LayerWise,
}

/// Counter state — what the intermediate encoding buffer stores.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EncoderState {
    pub counters: [u32; 8],
    pub count: u32,
}

/// Statistics for energy accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EncoderStats {
    /// Activations pushed through the counters.
    pub encoded_values: u64,
    /// Counter checkpoints to the intermediate buffer.
    pub buffer_saves: u64,
    /// Counter restores from the intermediate buffer.
    pub buffer_restores: u64,
    /// Finalized groups emitted to cache.
    pub groups_emitted: u64,
}

/// The on-die sparsity encoder.
#[derive(Debug, Clone)]
pub struct SparsityEncoder {
    pub mode: EncodingMode,
    state: EncoderState,
    /// Intermediate encoding buffer (single-bank systems only).
    buffer: Option<EncoderState>,
    pub stats: EncoderStats,
}

impl SparsityEncoder {
    pub fn new(mode: EncodingMode) -> Self {
        Self {
            mode,
            state: EncoderState::default(),
            buffer: None,
            stats: EncoderStats::default(),
        }
    }

    /// Feed one 8-bit activation into the counters.
    #[inline]
    pub fn push(&mut self, value: u8) {
        let mut bits = value;
        while bits != 0 {
            let p = bits.trailing_zeros() as usize;
            self.state.counters[p] += 1;
            bits &= bits - 1;
        }
        self.state.count += 1;
        self.stats.encoded_values += 1;
    }

    pub fn push_slice(&mut self, values: &[u8]) {
        for &v in values {
            self.push(v);
        }
    }

    /// Current counter snapshot without finalizing.
    pub fn peek(&self) -> EncoderState {
        self.state
    }

    /// Checkpoint the counters to the intermediate encoding buffer — used
    /// when a weight update interrupts a group (§4.5 "Intermediate
    /// Encoding Buffer").
    pub fn save_to_buffer(&mut self) {
        self.buffer = Some(self.state);
        self.state = EncoderState::default();
        self.stats.buffer_saves += 1;
    }

    /// Resume encoding from the buffered state.
    pub fn restore_from_buffer(&mut self) {
        let buffered = self
            .buffer
            .take()
            .expect("restore_from_buffer without a prior save");
        // Merge the (normally empty) current state into the restored one,
        // mirroring the configurable counter-load path of the RTL.
        for p in 0..8 {
            self.state.counters[p] += buffered.counters[p];
        }
        self.state.count += buffered.count;
        self.stats.buffer_restores += 1;
    }

    /// Finalize the current group: emit its sparsity vector and reset.
    pub fn finalize_group(&mut self) -> EncoderState {
        let out = self.state;
        self.state = EncoderState::default();
        self.stats.groups_emitted += 1;
        out
    }

    pub fn reset_stats(&mut self) {
        self.stats = EncoderStats::default();
    }
}

/// Encode a CONV layer output tensor (CHW, already quantized to u8)
/// pixel-wise: returns one sparsity vector per pixel (count over C).
pub fn encode_conv_output(
    chw: &[u8],
    channels: usize,
    pixels: usize,
    enc: &mut SparsityEncoder,
) -> Vec<EncoderState> {
    assert_eq!(chw.len(), channels * pixels);
    assert_eq!(enc.mode, EncodingMode::PixelWise);
    let mut out = Vec::with_capacity(pixels);
    for pix in 0..pixels {
        for c in 0..channels {
            enc.push(chw[c * pixels + pix]);
        }
        out.push(enc.finalize_group());
    }
    out
}

/// Encode a LINEAR layer output layer-wise: one sparsity vector total.
pub fn encode_linear_output(values: &[u8], enc: &mut SparsityEncoder) -> EncoderState {
    assert_eq!(enc.mode, EncodingMode::LayerWise);
    enc.push_slice(values);
    enc.finalize_group()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pac::sparsity::bit_sparsity_counts;
    use crate::util::rng::Rng;

    #[test]
    fn counters_match_popcounts() {
        let mut rng = Rng::new(70);
        let vals: Vec<u8> = (0..500).map(|_| rng.below(256) as u8).collect();
        let mut enc = SparsityEncoder::new(EncodingMode::LayerWise);
        let st = encode_linear_output(&vals, &mut enc);
        assert_eq!(st.counters, bit_sparsity_counts(&vals));
        assert_eq!(st.count, 500);
        assert_eq!(enc.stats.groups_emitted, 1);
    }

    #[test]
    fn pixel_wise_groups_across_channels() {
        // 3 channels × 4 pixels, CHW layout.
        let chw = [
            0b0001u8, 0b0010, 0b0100, 0b1000, // c0
            0b0001, 0b0000, 0b0100, 0b0000, // c1
            0b0001, 0b0010, 0b0000, 0b0000, // c2
        ];
        let mut enc = SparsityEncoder::new(EncodingMode::PixelWise);
        let groups = encode_conv_output(&chw, 3, 4, &mut enc);
        assert_eq!(groups.len(), 4);
        // Pixel 0: values {1,1,1} → counters[0] = 3.
        assert_eq!(groups[0].counters[0], 3);
        assert_eq!(groups[0].count, 3);
        // Pixel 1: {2,0,2} → counters[1] = 2.
        assert_eq!(groups[1].counters[1], 2);
        // Pixel 3: {8,0,0} → counters[3] = 1.
        assert_eq!(groups[3].counters[3], 1);
    }

    #[test]
    fn buffer_checkpoint_resumes_exactly() {
        // Encoding interrupted by a weight update must produce the same
        // group as uninterrupted encoding.
        let mut rng = Rng::new(71);
        let vals: Vec<u8> = (0..300).map(|_| rng.below(256) as u8).collect();

        let mut uninterrupted = SparsityEncoder::new(EncodingMode::LayerWise);
        uninterrupted.push_slice(&vals);
        let want = uninterrupted.finalize_group();

        let mut interrupted = SparsityEncoder::new(EncodingMode::LayerWise);
        interrupted.push_slice(&vals[..137]);
        interrupted.save_to_buffer(); // weight update happens here
        interrupted.restore_from_buffer();
        interrupted.push_slice(&vals[137..]);
        let got = interrupted.finalize_group();

        assert_eq!(got, want);
        assert_eq!(interrupted.stats.buffer_saves, 1);
        assert_eq!(interrupted.stats.buffer_restores, 1);
    }

    #[test]
    #[should_panic(expected = "without a prior save")]
    fn restore_without_save_panics() {
        let mut enc = SparsityEncoder::new(EncodingMode::LayerWise);
        enc.restore_from_buffer();
    }

    #[test]
    fn finalize_resets_state() {
        let mut enc = SparsityEncoder::new(EncodingMode::LayerWise);
        enc.push(0xFF);
        let g1 = enc.finalize_group();
        assert_eq!(g1.counters, [1; 8]);
        let g2 = enc.finalize_group();
        assert_eq!(g2.counters, [0; 8]);
        assert_eq!(g2.count, 0);
    }

    #[test]
    fn zero_values_count_toward_group_size() {
        let mut enc = SparsityEncoder::new(EncodingMode::LayerWise);
        enc.push_slice(&[0, 0, 0]);
        let g = enc.finalize_group();
        assert_eq!(g.count, 3);
        assert_eq!(g.counters, [0; 8]);
    }
}
