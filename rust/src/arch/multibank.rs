//! Multi-bank system model (§4.5 "Tiling Multiple Banks").
//!
//! A single-bank PACiM must checkpoint the sparsity encoder across weight
//! updates (the intermediate encoding buffer — >50% of CnM area, ~70% of
//! its power, Fig. 7(c)). Tiling multiple banks lets the scheduler stage
//! weight updates so that, at any time, the banks covering one output
//! group are resident together: encoding never interrupts, the buffer
//! disappears, and weight-update latency hides behind compute on the
//! other banks.
//!
//! This module models that schedule: given a layer's tile grid
//! (`row_tiles × oc_tiles`) and a bank count, it produces the steady-state
//! schedule, counts buffer checkpoints (zero when the DP tiles of a group
//! fit the bank set), and quantifies the §4.5 claim that multi-bank tiling
//! eliminates the intermediate encoding buffer.

use crate::util::Parallelism;
use crate::workload::shapes::LayerShape;

/// Multi-bank configuration.
#[derive(Debug, Clone, Copy)]
pub struct MultiBankConfig {
    pub banks: usize,
    /// Rows per bank (DP segment per pass).
    pub rows: usize,
    /// MWCs per bank.
    pub mwcs: usize,
}

impl Default for MultiBankConfig {
    fn default() -> Self {
        Self {
            banks: 4,
            rows: 256,
            mwcs: 64,
        }
    }
}

/// Outcome of scheduling one layer onto the bank set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MultiBankSchedule {
    pub layer: String,
    pub row_tiles: usize,
    pub oc_tiles: usize,
    /// Weight-update *rounds*: groups of tile loads that execute while
    /// other banks compute.
    pub update_rounds: usize,
    /// Encoder checkpoints to the intermediate buffer (single-bank would
    /// need one per weight update that interrupts a group).
    pub buffer_checkpoints: u64,
    /// True when the layer's full DP (all row tiles) is bank-resident at
    /// once, so encoding never pauses.
    pub encoding_uninterrupted: bool,
}

/// Schedule one layer onto `cfg.banks` banks.
///
/// Strategy (the §4.5 staging): all `row_tiles` of a DP column group are
/// placed on distinct banks so an output group's partial sums are
/// produced in one pass. Output-channel tiles rotate through the
/// remaining bank capacity; their weight updates are staged during the
/// compute of resident tiles.
pub fn schedule_layer_multibank(shape: &LayerShape, cfg: &MultiBankConfig) -> MultiBankSchedule {
    let k = shape.dp_len();
    let row_tiles = (k + cfg.rows - 1) / cfg.rows;
    let oc_tiles = (shape.geom.out_c + cfg.mwcs - 1) / cfg.mwcs;
    let pixels = shape.out_pixels() as u64;

    if row_tiles <= cfg.banks {
        // The whole DP is resident: each output group completes without a
        // weight update in between; oc tiles rotate between groups, with
        // updates overlapped (double-buffered rows) — no checkpoints.
        let rounds = oc_tiles.div_ceil(cfg.banks / row_tiles.max(1)).max(1);
        MultiBankSchedule {
            layer: shape.name.clone(),
            row_tiles,
            oc_tiles,
            update_rounds: rounds,
            buffer_checkpoints: 0,
            encoding_uninterrupted: true,
        }
    } else {
        // DP longer than the bank set: a group's accumulation must pause
        // while the remaining row tiles are loaded — each pause is one
        // encoder checkpoint per in-flight output group (pixel).
        let passes = row_tiles.div_ceil(cfg.banks);
        MultiBankSchedule {
            layer: shape.name.clone(),
            row_tiles,
            oc_tiles,
            update_rounds: passes * oc_tiles,
            buffer_checkpoints: (passes as u64 - 1) * pixels,
            encoding_uninterrupted: false,
        }
    }
}

/// System-level summary over a whole network.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MultiBankReport {
    pub schedules: Vec<MultiBankSchedule>,
}

impl MultiBankReport {
    pub fn total_checkpoints(&self) -> u64 {
        self.schedules.iter().map(|s| s.buffer_checkpoints).sum()
    }

    /// Fraction of layers whose encoding runs uninterrupted.
    pub fn uninterrupted_fraction(&self) -> f64 {
        if self.schedules.is_empty() {
            return 1.0;
        }
        self.schedules.iter().filter(|s| s.encoding_uninterrupted).count() as f64
            / self.schedules.len() as f64
    }

    /// §4.5 claim: the intermediate encoding buffer can be removed iff no
    /// layer needs checkpoints.
    pub fn buffer_removable(&self) -> bool {
        self.total_checkpoints() == 0
    }
}

/// Schedule every layer of a network onto the bank set with the default
/// parallelism policy (scalar below the fan-out threshold — a ~20-layer
/// network is cheaper to schedule inline than to fork/join).
pub fn schedule_network_multibank(
    shapes: &[LayerShape],
    cfg: &MultiBankConfig,
) -> MultiBankReport {
    schedule_network_multibank_with(shapes, cfg, &Parallelism::auto())
}

/// Schedule with an explicit parallelism policy. Layers are independent
/// and collected in order, so the report is identical to the sequential
/// equivalent for any policy; large design-space sweeps pass a permissive
/// policy to work-steal across the rayon pool.
pub fn schedule_network_multibank_with(
    shapes: &[LayerShape],
    cfg: &MultiBankConfig,
    par: &Parallelism,
) -> MultiBankReport {
    MultiBankReport {
        schedules: par.map_collect(shapes.len(), |i| schedule_layer_multibank(&shapes[i], cfg)),
    }
}

/// Smallest bank count that removes the buffer for a whole network.
pub fn min_banks_for_buffer_removal(shapes: &[LayerShape], rows: usize, mwcs: usize) -> usize {
    let max_row_tiles = shapes
        .iter()
        .map(|s| (s.dp_len() + rows - 1) / rows)
        .max()
        .unwrap_or(1);
    let _ = mwcs;
    max_row_tiles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::shapes::{resnet18, Resolution};

    #[test]
    fn small_layer_never_checkpoints() {
        let l = LayerShape::conv("s", 16, 32, 16, 3, 1); // k=144 < 256
        let s = schedule_layer_multibank(&l, &MultiBankConfig::default());
        assert_eq!(s.row_tiles, 1);
        assert!(s.encoding_uninterrupted);
        assert_eq!(s.buffer_checkpoints, 0);
    }

    #[test]
    fn single_bank_long_dp_checkpoints_per_pixel() {
        // k = 4608 → 18 row tiles; 1 bank → 18 passes → 17 checkpoints
        // per output pixel.
        let l = LayerShape::conv("d", 512, 512, 7, 3, 1);
        let cfg = MultiBankConfig { banks: 1, ..Default::default() };
        let s = schedule_layer_multibank(&l, &cfg);
        assert_eq!(s.row_tiles, 18);
        assert!(!s.encoding_uninterrupted);
        assert_eq!(s.buffer_checkpoints, 17 * l.out_pixels() as u64);
    }

    #[test]
    fn enough_banks_remove_buffer_entirely() {
        // §4.5: multi-bank tiling eliminates intermediate encoding buffers.
        let shapes = resnet18(Resolution::Cifar, 10);
        let need = min_banks_for_buffer_removal(&shapes, 256, 64);
        let cfg = MultiBankConfig { banks: need, ..Default::default() };
        let rep = schedule_network_multibank(&shapes, &cfg);
        assert!(rep.buffer_removable(), "checkpoints: {}", rep.total_checkpoints());
        assert_eq!(rep.uninterrupted_fraction(), 1.0);
    }

    #[test]
    fn single_bank_needs_buffer_on_resnet18() {
        let shapes = resnet18(Resolution::Cifar, 10);
        let cfg = MultiBankConfig { banks: 1, ..Default::default() };
        let rep = schedule_network_multibank(&shapes, &cfg);
        assert!(!rep.buffer_removable());
        assert!(rep.uninterrupted_fraction() < 1.0);
    }

    #[test]
    fn checkpoints_decrease_monotonically_with_banks() {
        let shapes = resnet18(Resolution::ImageNet, 1000);
        let mut last = u64::MAX;
        for banks in [1usize, 2, 4, 8, 18] {
            let cfg = MultiBankConfig { banks, ..Default::default() };
            let rep = schedule_network_multibank(&shapes, &cfg);
            let cp = rep.total_checkpoints();
            assert!(cp <= last, "banks={banks} cp={cp} last={last}");
            last = cp;
        }
        assert_eq!(last, 0, "18 banks hold ResNet-18's deepest DP");
    }

    #[test]
    fn parallel_schedule_identical_to_sequential() {
        let shapes = resnet18(Resolution::ImageNet, 1000);
        let cfg = MultiBankConfig::default();
        let seq = schedule_network_multibank_with(&shapes, &cfg, &Parallelism::off());
        let par = schedule_network_multibank_with(
            &shapes,
            &cfg,
            &Parallelism {
                enabled: true,
                min_items: 1,
            },
        );
        assert_eq!(seq, par);
        assert_eq!(seq, schedule_network_multibank(&shapes, &cfg));
    }

    #[test]
    fn min_banks_matches_deepest_layer() {
        let shapes = resnet18(Resolution::Cifar, 10);
        // Deepest CONV: 3x3x512 = 4608 → 18 tiles of 256.
        assert_eq!(min_banks_for_buffer_removal(&shapes, 256, 64), 18);
    }
}
