//! Multi-bank system model (§4.5 "Tiling Multiple Banks").
//!
//! A single-bank PACiM must checkpoint the sparsity encoder across weight
//! updates (the intermediate encoding buffer — >50% of CnM area, ~70% of
//! its power, Fig. 7(c)). Tiling multiple banks lets the scheduler stage
//! weight updates so that, at any time, the banks covering one output
//! group are resident together: encoding never interrupts, the buffer
//! disappears, and weight-update latency hides behind compute on the
//! other banks.
//!
//! This module models that schedule: given a layer's tile grid
//! (`row_tiles × oc_tiles`) and a bank count, it produces the steady-state
//! schedule, counts buffer checkpoints (zero when the DP tiles of a group
//! fit the bank set), and quantifies the §4.5 claim that multi-bank tiling
//! eliminates the intermediate encoding buffer.
//!
//! On top of the cycles-only staging sits the *traffic-priced* scheduler
//! ([`schedule_network_priced`]): every candidate bank assignment is scored
//! `cycles + λ · bits`, where the bits term covers both the inter-layer
//! activation traffic (the per-layer share of
//! [`CostEstimate::act_bits`](crate::coordinator::CostEstimate)) and the
//! checkpoint bits an interrupted group spills to the intermediate
//! encoding buffer. At `λ = 0` the priced schedule is bit-identical to
//! [`schedule_network_multibank`]; at `λ > 0` the scheduler may replay
//! interrupted groups digitally instead of spilling them, trading a
//! bounded cycle premium for strictly fewer bits moved.

use crate::memory::traffic::activation_traffic;
use crate::util::Parallelism;
use crate::workload::shapes::{LayerShape, LayerShapeKind};

/// Multi-bank configuration.
#[derive(Debug, Clone, Copy)]
pub struct MultiBankConfig {
    pub banks: usize,
    /// Rows per bank (DP segment per pass).
    pub rows: usize,
    /// MWCs per bank.
    pub mwcs: usize,
}

impl Default for MultiBankConfig {
    fn default() -> Self {
        Self {
            banks: 4,
            rows: 256,
            mwcs: 64,
        }
    }
}

/// Outcome of scheduling one layer onto the bank set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MultiBankSchedule {
    pub layer: String,
    pub row_tiles: usize,
    pub oc_tiles: usize,
    /// Weight-update *rounds*: groups of tile loads that execute while
    /// other banks compute.
    pub update_rounds: usize,
    /// Encoder checkpoints to the intermediate buffer (single-bank would
    /// need one per weight update that interrupts a group).
    pub buffer_checkpoints: u64,
    /// True when the layer's full DP (all row tiles) is bank-resident at
    /// once, so encoding never pauses.
    pub encoding_uninterrupted: bool,
}

/// Schedule one layer onto `cfg.banks` banks.
///
/// Strategy (the §4.5 staging): all `row_tiles` of a DP column group are
/// placed on distinct banks so an output group's partial sums are
/// produced in one pass. Output-channel tiles rotate through the
/// remaining bank capacity; their weight updates are staged during the
/// compute of resident tiles.
pub fn schedule_layer_multibank(shape: &LayerShape, cfg: &MultiBankConfig) -> MultiBankSchedule {
    let k = shape.dp_len();
    let row_tiles = (k + cfg.rows - 1) / cfg.rows;
    let oc_tiles = (shape.geom.out_c + cfg.mwcs - 1) / cfg.mwcs;
    let pixels = shape.out_pixels() as u64;

    if row_tiles <= cfg.banks {
        // The whole DP is resident: each output group completes without a
        // weight update in between; oc tiles rotate between groups, with
        // updates overlapped (double-buffered rows) — no checkpoints.
        let rounds = oc_tiles.div_ceil(cfg.banks / row_tiles.max(1)).max(1);
        MultiBankSchedule {
            layer: shape.name.clone(),
            row_tiles,
            oc_tiles,
            update_rounds: rounds,
            buffer_checkpoints: 0,
            encoding_uninterrupted: true,
        }
    } else {
        // DP longer than the bank set: a group's accumulation must pause
        // while the remaining row tiles are loaded — each pause is one
        // encoder checkpoint per in-flight output group (pixel).
        let passes = row_tiles.div_ceil(cfg.banks);
        MultiBankSchedule {
            layer: shape.name.clone(),
            row_tiles,
            oc_tiles,
            update_rounds: passes * oc_tiles,
            buffer_checkpoints: (passes as u64 - 1) * pixels,
            encoding_uninterrupted: false,
        }
    }
}

/// System-level summary over a whole network.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MultiBankReport {
    pub schedules: Vec<MultiBankSchedule>,
}

impl MultiBankReport {
    pub fn total_checkpoints(&self) -> u64 {
        self.schedules.iter().map(|s| s.buffer_checkpoints).sum()
    }

    /// Fraction of layers whose encoding runs uninterrupted.
    pub fn uninterrupted_fraction(&self) -> f64 {
        if self.schedules.is_empty() {
            return 1.0;
        }
        self.schedules.iter().filter(|s| s.encoding_uninterrupted).count() as f64
            / self.schedules.len() as f64
    }

    /// §4.5 claim: the intermediate encoding buffer can be removed iff no
    /// layer needs checkpoints.
    pub fn buffer_removable(&self) -> bool {
        self.total_checkpoints() == 0
    }
}

/// Schedule every layer of a network onto the bank set with the default
/// parallelism policy (scalar below the fan-out threshold — a ~20-layer
/// network is cheaper to schedule inline than to fork/join).
pub fn schedule_network_multibank(
    shapes: &[LayerShape],
    cfg: &MultiBankConfig,
) -> MultiBankReport {
    schedule_network_multibank_with(shapes, cfg, &Parallelism::auto())
}

/// Schedule with an explicit parallelism policy. Layers are independent
/// and collected in order, so the report is identical to the sequential
/// equivalent for any policy; large design-space sweeps pass a permissive
/// policy to work-steal across the rayon pool.
pub fn schedule_network_multibank_with(
    shapes: &[LayerShape],
    cfg: &MultiBankConfig,
    par: &Parallelism,
) -> MultiBankReport {
    MultiBankReport {
        schedules: par.map_collect(shapes.len(), |i| schedule_layer_multibank(&shapes[i], cfg)),
    }
}

/// Smallest bank count that removes the buffer for a whole network.
/// Only the DP depth matters — MWC width shapes rounds, not checkpoints.
pub fn min_banks_for_buffer_removal(shapes: &[LayerShape], rows: usize, _mwcs: usize) -> usize {
    shapes
        .iter()
        .map(|s| (s.dp_len() + rows - 1) / rows)
        .max()
        .unwrap_or(1)
}

// --- Traffic-priced scheduling (the λ knob) --------------------------------

/// Buffer-port cycles per spilled checkpoint (one write + one read
/// transaction against the intermediate encoding buffer).
pub const SPILL_CYCLES: f64 = 2.0;

/// Pricing knobs for the traffic-aware schedule.
///
/// `λ` converts bits moved into schedule cost (cycles per bit), so one
/// scalar trades the two objectives the paper optimizes separately:
/// bit-serial cycles (§5 dynamic configuration) and bits moved (§4.4
/// sparsity encoding, §4.5 bank tiling). `λ = 0` is the documented
/// contract for "cycles only": [`schedule_layer_priced`] then returns the
/// legacy [`schedule_layer_multibank`] staging bit for bit.
#[derive(Debug, Clone, Copy)]
pub struct TrafficPrice {
    /// Cost weight in cycles per bit moved. `0.0` = cycles-only.
    pub lambda: f64,
    /// Binary MSB planes carried per activation (paper default 4).
    pub msb_bits: u32,
    /// Average digital bit-serial cycles per output group (16.0 static,
    /// ≈12 with the dynamic map); scales the compute and replay terms.
    pub avg_digital_cycles: f64,
}

impl Default for TrafficPrice {
    fn default() -> Self {
        Self {
            lambda: 0.0,
            msb_bits: 4,
            avg_digital_cycles: 16.0,
        }
    }
}

/// What an interrupted output group does while its remaining DP row
/// tiles are loaded into the bank set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpillPolicy {
    /// Checkpoint the group's partial encoding state to the intermediate
    /// buffer and restore it next pass: cheap in cycles
    /// ([`SPILL_CYCLES`] each), expensive in bits (the encoded group
    /// state travels to the buffer and back).
    Spill,
    /// Re-broadcast the group digitally when its row tiles return
    /// instead of spilling: zero buffer bits, but
    /// [`TrafficPrice::avg_digital_cycles`] extra cycles per
    /// interruption.
    Replay,
}

/// One layer's traffic-priced schedule: the selected §4.5 staging plus
/// the modeled cycle and bit costs the selection was scored on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PricedSchedule {
    /// The staging this pricing selected, in cycles-only schedule terms.
    /// At `λ = 0` this is bit-identical to [`schedule_layer_multibank`].
    pub schedule: MultiBankSchedule,
    /// Banks held co-resident per output group (`λ = 0` uses
    /// `min(row_tiles, banks)`, the legacy staging).
    pub group_banks: usize,
    /// How interrupted groups are handled (always [`SpillPolicy::Spill`]
    /// at `λ = 0`).
    pub policy: SpillPolicy,
    /// Group interruptions: `(passes − 1)` per in-flight output pixel.
    pub interruptions: u64,
    /// Modeled cycles: bit-serial compute + one row-write stall per bank
    /// row per update round + the spill/replay penalty.
    pub cycles: u64,
    /// Inter-layer activation bits moved (write + read) — this layer's
    /// share of [`CostEstimate::act_bits`](crate::coordinator::CostEstimate).
    pub act_bits: u64,
    /// Checkpoint bits spilled to the intermediate buffer (zero when the
    /// layer never interrupts or replays instead).
    pub spill_bits: u64,
}

impl PricedSchedule {
    /// Total bits this layer's schedule moves (activation + spill).
    pub fn total_bits(&self) -> u64 {
        self.act_bits + self.spill_bits
    }

    /// The λ-weighted score candidates compete on: `cycles + λ · bits`.
    pub fn score(&self, lambda: f64) -> f64 {
        self.cycles as f64 + lambda * self.total_bits() as f64
    }
}

/// Build one candidate staging: `group_banks` banks co-resident per
/// output group, interrupted groups handled per `policy`.
fn priced_candidate(
    shape: &LayerShape,
    encoded: bool,
    cfg: &MultiBankConfig,
    price: &TrafficPrice,
    group_banks: usize,
    policy: SpillPolicy,
) -> PricedSchedule {
    let k = shape.dp_len();
    let row_tiles = (k + cfg.rows - 1) / cfg.rows;
    let oc_tiles = (shape.geom.out_c + cfg.mwcs - 1) / cfg.mwcs;
    let pixels = shape.out_pixels() as u64;
    // Generalized §4.5 staging: `passes` sweeps over the DP with
    // `group_banks` banks per group, `concurrent` groups side by side.
    // `group_banks = min(row_tiles, banks)` reproduces both branches of
    // `schedule_layer_multibank` exactly.
    let passes = row_tiles.div_ceil(group_banks.max(1));
    let concurrent = (cfg.banks / group_banks.max(1)).max(1);
    let rounds = (passes * oc_tiles.div_ceil(concurrent)).max(1);
    let interruptions = (passes as u64 - 1) * pixels;

    // Bits: same write+read closed form as `coordinator::schedule_layer`
    // (one group per output pixel for convs, one per image for linears).
    let groups = match shape.kind {
        LayerShapeKind::Conv => pixels,
        LayerShapeKind::Linear => 1,
    };
    let t = activation_traffic(shape.geom.out_c, price.msb_bits);
    let group_bits = if encoded { t.pacim } else { t.baseline };
    let act_bits = 2 * groups * group_bits;
    let spill_bits = match policy {
        SpillPolicy::Spill => interruptions * 2 * group_bits,
        SpillPolicy::Replay => 0,
    };

    let compute =
        (pixels * row_tiles as u64 * oc_tiles as u64) as f64 * price.avg_digital_cycles;
    let penalty = match policy {
        SpillPolicy::Spill => interruptions as f64 * SPILL_CYCLES,
        SpillPolicy::Replay => interruptions as f64 * price.avg_digital_cycles,
    };
    let cycles = (compute + rounds as f64 * cfg.rows as f64 + penalty) as u64;

    PricedSchedule {
        schedule: MultiBankSchedule {
            layer: shape.name.clone(),
            row_tiles,
            oc_tiles,
            update_rounds: rounds,
            buffer_checkpoints: match policy {
                SpillPolicy::Spill => interruptions,
                SpillPolicy::Replay => 0,
            },
            encoding_uninterrupted: passes == 1,
        },
        group_banks,
        policy,
        interruptions,
        cycles,
        act_bits,
        spill_bits,
    }
}

/// Traffic-priced schedule for one layer.
///
/// Candidates range over group width (`1..=min(row_tiles, banks)` banks
/// co-resident per output group) × spill policy, scored
/// `cycles + λ · (act_bits + spill_bits)`. Selection is deterministic:
/// the search starts from the legacy staging and only a *strictly*
/// better score displaces it, so ties keep the cycles-only choice.
///
/// Contract: `price.lambda == 0.0` returns the legacy
/// [`schedule_layer_multibank`] staging bit for bit (property-tested).
pub fn schedule_layer_priced(
    shape: &LayerShape,
    encoded: bool,
    cfg: &MultiBankConfig,
    price: &TrafficPrice,
) -> PricedSchedule {
    let k = shape.dp_len();
    let row_tiles = (k + cfg.rows - 1) / cfg.rows;
    let legacy_banks = row_tiles.min(cfg.banks).max(1);
    let legacy = priced_candidate(shape, encoded, cfg, price, legacy_banks, SpillPolicy::Spill);
    debug_assert_eq!(legacy.schedule, schedule_layer_multibank(shape, cfg));
    if price.lambda <= 0.0 {
        return legacy;
    }
    let mut best = legacy;
    for group_banks in (1..=legacy_banks).rev() {
        for policy in [SpillPolicy::Spill, SpillPolicy::Replay] {
            let cand = priced_candidate(shape, encoded, cfg, price, group_banks, policy);
            if policy == SpillPolicy::Replay && cand.interruptions == 0 {
                continue; // identical to Spill when nothing interrupts
            }
            if cand.score(price.lambda) < best.score(price.lambda) {
                best = cand;
            }
        }
    }
    best
}

/// Network-level traffic-priced schedule.
#[derive(Debug, Clone)]
pub struct PricedBankReport {
    /// The λ the schedules were selected under (cycles per bit).
    pub lambda: f64,
    /// Per-layer selections, in network order.
    pub schedules: Vec<PricedSchedule>,
}

impl PricedBankReport {
    /// Total modeled cycles across the network.
    pub fn total_cycles(&self) -> u64 {
        self.schedules.iter().map(|s| s.cycles).sum()
    }

    /// Total inter-layer activation bits (write + read). With every edge
    /// encoded this equals
    /// [`CostEstimate::act_bits`](crate::coordinator::CostEstimate).
    pub fn total_act_bits(&self) -> u64 {
        self.schedules.iter().map(|s| s.act_bits).sum()
    }

    /// Total checkpoint bits spilled to the intermediate buffer.
    pub fn total_spill_bits(&self) -> u64 {
        self.schedules.iter().map(|s| s.spill_bits).sum()
    }

    /// Total bits moved — the quantity λ prices against cycles.
    pub fn total_bits(&self) -> u64 {
        self.total_act_bits() + self.total_spill_bits()
    }

    /// Layers that replay interrupted groups instead of spilling them.
    pub fn replayed_layers(&self) -> usize {
        self.schedules
            .iter()
            .filter(|s| s.policy == SpillPolicy::Replay && s.interruptions > 0)
            .count()
    }

    /// Strip the pricing: the §4.5 staging view of this schedule. At
    /// `λ = 0` this is bit-identical to [`schedule_network_multibank`].
    pub fn to_multibank(&self) -> MultiBankReport {
        MultiBankReport {
            schedules: self.schedules.iter().map(|s| s.schedule.clone()).collect(),
        }
    }
}

/// Traffic-priced schedule for a whole network, treating every
/// inter-layer edge as sparsity-encoded — the analytic convention
/// [`CostEstimate::act_bits`](crate::coordinator::CostEstimate) uses, so
/// [`PricedBankReport::total_act_bits`] cross-checks against it exactly.
/// Pass explicit per-edge flags (e.g. from the measured ledger) through
/// [`schedule_network_priced_with`] instead.
pub fn schedule_network_priced(
    shapes: &[LayerShape],
    cfg: &MultiBankConfig,
    price: &TrafficPrice,
) -> PricedBankReport {
    let encoded = vec![true; shapes.len()];
    schedule_network_priced_with(shapes, &encoded, cfg, price, &Parallelism::auto())
}

/// Traffic-priced schedule with explicit per-layer encode flags (the
/// DESIGN.md §12 still-dense edges — pooling heads, digital fallbacks —
/// price at the 8-bit dense baseline) and an explicit parallelism
/// policy. The flags cover the per-layer *payload* edges this scheduler
/// models; residual save/add edges are costed separately by the
/// measured ledger and `arch::dse`'s residual accounting
/// (`memory::residual_traffic` is their closed form), so a fused
/// residual block no longer silently prices as a dense round-trip.
pub fn schedule_network_priced_with(
    shapes: &[LayerShape],
    encoded: &[bool],
    cfg: &MultiBankConfig,
    price: &TrafficPrice,
    par: &Parallelism,
) -> PricedBankReport {
    assert_eq!(shapes.len(), encoded.len(), "one encode flag per layer");
    PricedBankReport {
        lambda: price.lambda,
        schedules: par.map_collect(shapes.len(), |i| {
            schedule_layer_priced(&shapes[i], encoded[i], cfg, price)
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::shapes::{resnet18, Resolution};

    #[test]
    fn small_layer_never_checkpoints() {
        let l = LayerShape::conv("s", 16, 32, 16, 3, 1); // k=144 < 256
        let s = schedule_layer_multibank(&l, &MultiBankConfig::default());
        assert_eq!(s.row_tiles, 1);
        assert!(s.encoding_uninterrupted);
        assert_eq!(s.buffer_checkpoints, 0);
    }

    #[test]
    fn single_bank_long_dp_checkpoints_per_pixel() {
        // k = 4608 → 18 row tiles; 1 bank → 18 passes → 17 checkpoints
        // per output pixel.
        let l = LayerShape::conv("d", 512, 512, 7, 3, 1);
        let cfg = MultiBankConfig { banks: 1, ..Default::default() };
        let s = schedule_layer_multibank(&l, &cfg);
        assert_eq!(s.row_tiles, 18);
        assert!(!s.encoding_uninterrupted);
        assert_eq!(s.buffer_checkpoints, 17 * l.out_pixels() as u64);
    }

    #[test]
    fn enough_banks_remove_buffer_entirely() {
        // §4.5: multi-bank tiling eliminates intermediate encoding buffers.
        let shapes = resnet18(Resolution::Cifar, 10);
        let need = min_banks_for_buffer_removal(&shapes, 256, 64);
        let cfg = MultiBankConfig { banks: need, ..Default::default() };
        let rep = schedule_network_multibank(&shapes, &cfg);
        assert!(rep.buffer_removable(), "checkpoints: {}", rep.total_checkpoints());
        assert_eq!(rep.uninterrupted_fraction(), 1.0);
    }

    #[test]
    fn single_bank_needs_buffer_on_resnet18() {
        let shapes = resnet18(Resolution::Cifar, 10);
        let cfg = MultiBankConfig { banks: 1, ..Default::default() };
        let rep = schedule_network_multibank(&shapes, &cfg);
        assert!(!rep.buffer_removable());
        assert!(rep.uninterrupted_fraction() < 1.0);
    }

    #[test]
    fn checkpoints_decrease_monotonically_with_banks() {
        let shapes = resnet18(Resolution::ImageNet, 1000);
        let mut last = u64::MAX;
        for banks in [1usize, 2, 4, 8, 18] {
            let cfg = MultiBankConfig { banks, ..Default::default() };
            let rep = schedule_network_multibank(&shapes, &cfg);
            let cp = rep.total_checkpoints();
            assert!(cp <= last, "banks={banks} cp={cp} last={last}");
            last = cp;
        }
        assert_eq!(last, 0, "18 banks hold ResNet-18's deepest DP");
    }

    #[test]
    fn parallel_schedule_identical_to_sequential() {
        let shapes = resnet18(Resolution::ImageNet, 1000);
        let cfg = MultiBankConfig::default();
        let seq = schedule_network_multibank_with(&shapes, &cfg, &Parallelism::off());
        let par = schedule_network_multibank_with(
            &shapes,
            &cfg,
            &Parallelism {
                enabled: true,
                min_items: 1,
            },
        );
        assert_eq!(seq, par);
        assert_eq!(seq, schedule_network_multibank(&shapes, &cfg));
    }

    #[test]
    fn min_banks_matches_deepest_layer() {
        let shapes = resnet18(Resolution::Cifar, 10);
        // Deepest CONV: 3x3x512 = 4608 → 18 tiles of 256.
        assert_eq!(min_banks_for_buffer_removal(&shapes, 256, 64), 18);
    }

    #[test]
    fn priced_lambda_zero_matches_cycles_only_schedule() {
        // The λ=0 contract, on both paper resolutions and several bank
        // counts (the proptest covers random shapes).
        for res in [Resolution::Cifar, Resolution::ImageNet] {
            let shapes = resnet18(res, 10);
            for banks in [1usize, 2, 4, 8, 18] {
                let cfg = MultiBankConfig { banks, ..Default::default() };
                let priced =
                    schedule_network_priced(&shapes, &cfg, &TrafficPrice::default());
                assert_eq!(priced.to_multibank(), schedule_network_multibank(&shapes, &cfg));
            }
        }
    }

    #[test]
    fn priced_act_bits_match_cost_estimate() {
        // Cross-check contract: with every edge encoded, the priced
        // schedule's activation bits equal the analytic
        // `CostEstimate::act_bits` for the same msb width.
        use crate::coordinator::{estimate_image_cost, ScheduleConfig};
        use crate::energy::EnergyModel;
        let shapes = resnet18(Resolution::Cifar, 10);
        let rep = schedule_network_priced(
            &shapes,
            &MultiBankConfig::default(),
            &TrafficPrice::default(),
        );
        let est =
            estimate_image_cost(&shapes, &ScheduleConfig::pacim_default(), &EnergyModel::default());
        assert_eq!(rep.total_act_bits(), est.act_bits);
    }

    #[test]
    fn lambda_trades_spill_bits_for_replay_cycles() {
        // ResNet-18/CIFAR on 4 banks interrupts its ≥128-channel stages
        // (up to 18 row tiles); a λ above the per-layer flip point
        // 14 / (2·t.pacim) replays them: strictly fewer bits at a small
        // bounded cycle premium. This is the CI gate's claim.
        let shapes = resnet18(Resolution::Cifar, 10);
        let cfg = MultiBankConfig::default();
        let base = schedule_network_priced(&shapes, &cfg, &TrafficPrice::default());
        let price = TrafficPrice { lambda: 0.02, ..Default::default() };
        let priced = schedule_network_priced(&shapes, &cfg, &price);
        assert!(base.total_spill_bits() > 0, "λ=0 must spill on deep layers");
        assert!(priced.replayed_layers() > 0);
        assert!(priced.total_bits() < base.total_bits());
        assert!(priced.total_cycles() as f64 <= base.total_cycles() as f64 * 1.10);
        // Activation bits are schedule-invariant; only spills moved.
        assert_eq!(priced.total_act_bits(), base.total_act_bits());
    }

    #[test]
    fn dense_edges_price_at_eight_bit_baseline() {
        // DESIGN.md §12: still-dense edges move 8 bits per element.
        let shapes = vec![
            LayerShape::conv("enc", 64, 128, 8, 3, 1),
            LayerShape::linear("hidden", 512, 256),
        ];
        let rep = schedule_network_priced_with(
            &shapes,
            &[true, false],
            &MultiBankConfig::default(),
            &TrafficPrice::default(),
            &Parallelism::off(),
        );
        let t = activation_traffic(128, 4);
        assert_eq!(rep.schedules[0].act_bits, 2 * shapes[0].out_pixels() as u64 * t.pacim);
        assert_eq!(rep.schedules[1].act_bits, 2 * 8 * 256);
    }
}
