//! The PACiM bank architecture model (§4, Fig. 5).
//!
//! Composes the D-CiM array ([`dcim`]), the CnM PAC computation engine
//! ([`pcu`]), the on-die sparsity encoder ([`encoder`]) and the bank
//! logic's dynamic workload configuration ([`bank_logic`]) into a
//! *bit-true, cycle-accounted* model of one PACiM bank: weights resident
//! in the array (4-bit MSB) and in the PCU sparsity registers, input
//! activations arriving as 4-bit MSB planes + 8 sparsity counts, outputs
//! produced per multi-bit weight column (MWC).
//!
//! `nn::pac_exec` uses a flattened fast path for full-network runs; the
//! integration tests cross-check the two against each other, MAC by MAC.

pub mod bank_logic;
pub mod dcim;
pub mod dse;
pub mod encoder;
pub mod multibank;
pub mod pcu;
pub mod tuner;

pub use bank_logic::{classify, spec_normalized, spec_score, LevelHistogram, ThresholdSet};
pub use dcim::{DCimBank, DCimConfig, DCimStats};
pub use dse::{
    compare_lambda, dominates, pareto_front, sweep, DseAxes, DseConfig, DseOutcome, DsePoint,
    LambdaComparison,
};
pub use encoder::{EncodingMode, SparsityEncoder};
pub use multibank::{
    schedule_layer_priced, schedule_network_multibank, schedule_network_multibank_with,
    schedule_network_priced, schedule_network_priced_with, MultiBankConfig, MultiBankReport,
    PricedBankReport, PricedSchedule, SpillPolicy, TrafficPrice,
};
pub use pcu::{pcu_estimate_variance, Pce, PceStats, Pcu};
pub use tuner::{candidate_grid, tune, TunePoint, TuneResult};

use crate::pac::compute_map::DynamicLevel;
use crate::pac::sparsity::BitPlanes;
use crate::pac::{ComputeMap, PcuRounding};

/// Bank-level configuration.
#[derive(Debug, Clone)]
pub struct BankConfig {
    pub dcim: DCimConfig,
    /// PCUs in the PCE (6 matches one 64-accumulator bank, §6.2).
    pub n_pcus: usize,
    /// Base compute map (operand-based 4×4 by default).
    pub map: ComputeMap,
    /// Dynamic workload thresholds (None/disabled ⇒ always the base map).
    pub thresholds: Option<ThresholdSet>,
    pub rounding: PcuRounding,
}

impl Default for BankConfig {
    fn default() -> Self {
        Self {
            dcim: DCimConfig::default(),
            n_pcus: 6,
            map: ComputeMap::operand_based(4, 4),
            thresholds: None,
            rounding: PcuRounding::RoundNearest,
        }
    }
}

/// Combined event counters of one bank.
#[derive(Debug, Clone, Default)]
pub struct BankStats {
    pub dcim: DCimStats,
    pub pce: PceStats,
    pub levels: LevelHistogram,
}

impl BankStats {
    /// Average digital bit-serial cycles per output MAC (Fig. 7(a)).
    pub fn avg_digital_cycles(&self) -> f64 {
        if self.levels.total() > 0 {
            self.levels.average_cycles()
        } else if self.pce.pcu_ops > 0 || self.dcim.bit_serial_cycles > 0 {
            // Static map: derive from the cycle tally.
            self.dcim.bit_serial_cycles as f64
                / (self.pce.pcu_ops as f64 / 48.0).max(1.0)
        } else {
            0.0
        }
    }
}

/// One PACiM bank: D-CiM array + PCE + output accumulators.
pub struct PacimBank {
    pub config: BankConfig,
    dcim: DCimBank,
    pce: Pce,
    /// Weight sparsity per resident MWC (the PCE register contents).
    w_sparsity: Vec<[u32; 8]>,
    /// Raw weight element sums per MWC (for zero-point correction).
    w_sums: Vec<i64>,
    dp_len: usize,
    pub stats: BankStats,
}

impl PacimBank {
    pub fn new(config: BankConfig) -> Self {
        let dcim = DCimBank::new(config.dcim);
        let pce = Pce::new(config.n_pcus, config.rounding);
        Self {
            config,
            dcim,
            pce,
            w_sparsity: Vec::new(),
            w_sums: Vec::new(),
            dp_len: 0,
            stats: BankStats::default(),
        }
    }

    /// Load one weight tile: `weights[mwc]` = UINT8 weight vector
    /// (DP segment) of one output channel. MSBs go to the array, full
    /// sparsity counts to the PCE registers.
    pub fn load_weights(&mut self, weights: &[Vec<u8>]) {
        self.dcim.load_weights(weights);
        self.dp_len = weights.first().map_or(0, |w| w.len());
        self.w_sparsity = weights
            .iter()
            .map(|w| BitPlanes::from_u8(w).pop)
            .collect();
        self.w_sums = weights
            .iter()
            .map(|w| w.iter().map(|&v| v as i64).sum())
            .collect();
        if self.dp_len > 0 {
            self.pce.load_weights(&self.w_sparsity, self.dp_len as u32);
        }
    }

    pub fn dp_len(&self) -> usize {
        self.dp_len
    }

    /// Weight element sums of the resident MWCs (zero-point correction).
    pub fn weight_sums(&self) -> &[i64] {
        &self.w_sums
    }

    /// Process one input DP vector against all resident MWCs, returning
    /// the raw (uint-domain) hybrid MAC per MWC plus the level used.
    ///
    /// The input arrives exactly as the architecture receives it: MSB
    /// bit-planes in binary + the 8 sparsity counts from the upstream
    /// encoder. We take the full vector and decompose internally (the
    /// LSB planes are used only to *emulate nothing* — digital cycles are
    /// restricted to stored MSB columns by the compute map).
    pub fn compute(&mut self, x: &[u8]) -> (Vec<i64>, DynamicLevel) {
        assert_eq!(x.len(), self.dp_len, "input length != loaded DP length");
        let xp = BitPlanes::from_u8(x);
        // --- bank logic: dynamic workload configuration (§5) ---
        let level = match &self.config.thresholds {
            Some(th) => {
                let spec = spec_normalized(&xp.pop, self.dp_len as u32);
                let lvl = classify(spec, th);
                self.stats.levels.record(lvl);
                lvl
            }
            None => DynamicLevel::Cycles16,
        };
        let map = if self.config.thresholds.is_some() {
            level.map()
        } else {
            self.config.map.clone()
        };

        // --- digital domain: bit-serial cycles over the D-CiM array ---
        let mwcs = self.dcim.active_mwcs();
        let mut digital = vec![0i64; mwcs];
        for p in 0..8 {
            for q in 0..8 {
                if map.is_digital(p, q) {
                    let dps = self.dcim.bit_serial_cycle(&xp.planes[p], q);
                    for (m, &dp) in dps.iter().enumerate() {
                        digital[m] += (dp as i64) << (p + q);
                    }
                }
            }
        }

        // --- sparsity domain: PCE over the sparsity registers ---
        let approx =
            self.pce
                .compute_all(&self.w_sparsity, self.dp_len as u32, &xp.pop, &map);

        self.stats.dcim = self.dcim.stats;
        self.stats.pce = self.pce.stats;

        (
            digital
                .iter()
                .zip(&approx)
                .map(|(&d, &a)| d + a)
                .collect(),
            level,
        )
    }

    pub fn reset_stats(&mut self) {
        self.dcim.reset_stats();
        self.pce.reset_stats();
        self.stats = BankStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pac::mac::hybrid_mac;
    use crate::util::rng::Rng;

    fn random_weights(rng: &mut Rng, mwcs: usize, n: usize) -> Vec<Vec<u8>> {
        (0..mwcs)
            .map(|_| (0..n).map(|_| rng.below(256) as u8).collect())
            .collect()
    }

    #[test]
    fn bank_matches_hybrid_mac_reference() {
        // The structural bank model and the flat pac::hybrid_mac kernel
        // must agree exactly — two independent implementations of Eq. 4.
        let mut rng = Rng::new(90);
        let n = 200;
        let ws = random_weights(&mut rng, 16, n);
        let mut bank = PacimBank::new(BankConfig::default());
        bank.load_weights(&ws);
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let (got, level) = bank.compute(&x);
        assert_eq!(level, DynamicLevel::Cycles16);
        let xp = BitPlanes::from_u8(&x);
        let map = ComputeMap::operand_based(4, 4);
        for (m, w) in ws.iter().enumerate() {
            let wp = BitPlanes::from_u8(w);
            let want = hybrid_mac(&xp, &wp, &map, PcuRounding::RoundNearest);
            assert_eq!(got[m], want.value, "mwc {m}");
        }
    }

    #[test]
    fn digital_cycles_counted_per_broadcast() {
        let mut rng = Rng::new(91);
        let ws = random_weights(&mut rng, 8, 64);
        let mut bank = PacimBank::new(BankConfig::default());
        bank.load_weights(&ws);
        let x: Vec<u8> = (0..64).map(|_| rng.below(256) as u8).collect();
        bank.compute(&x);
        // 16 digital (p,q) pairs = 16 broadcasts regardless of MWC count.
        assert_eq!(bank.stats.dcim.bit_serial_cycles, 16);
        // 48 sparsity cycles per MWC.
        assert_eq!(bank.stats.pce.pcu_ops, 48 * 8);
    }

    #[test]
    fn dynamic_level_engages_for_sparse_input() {
        let mut rng = Rng::new(92);
        let ws = random_weights(&mut rng, 4, 128);
        let cfg = BankConfig {
            thresholds: Some(ThresholdSet::new(0.05, 0.15, 0.3)),
            ..BankConfig::default()
        };
        let mut bank = PacimBank::new(cfg);
        bank.load_weights(&ws);
        // Nearly-zero input → SPEC ≈ 0 → minimal level.
        let x = vec![0u8; 128];
        let (_, level) = bank.compute(&x);
        assert_eq!(level, DynamicLevel::Cycles10);
        // Dense input → full level.
        let x = vec![255u8; 128];
        let (_, level) = bank.compute(&x);
        assert_eq!(level, DynamicLevel::Cycles16);
        assert_eq!(bank.stats.levels.total(), 2);
    }

    #[test]
    fn weight_sums_support_zero_point_correction() {
        let mut rng = Rng::new(93);
        let ws = random_weights(&mut rng, 3, 50);
        let mut bank = PacimBank::new(BankConfig::default());
        bank.load_weights(&ws);
        for (m, w) in ws.iter().enumerate() {
            let want: i64 = w.iter().map(|&v| v as i64).sum();
            assert_eq!(bank.weight_sums()[m], want);
        }
    }

    #[test]
    #[should_panic(expected = "input length")]
    fn wrong_input_length_panics() {
        let mut bank = PacimBank::new(BankConfig::default());
        bank.load_weights(&[vec![0u8; 10]]);
        bank.compute(&[0u8; 11]);
    }

    #[test]
    fn reset_stats_clears_everything() {
        let mut rng = Rng::new(94);
        let ws = random_weights(&mut rng, 2, 32);
        let mut bank = PacimBank::new(BankConfig::default());
        bank.load_weights(&ws);
        let x: Vec<u8> = (0..32).map(|_| rng.below(256) as u8).collect();
        bank.compute(&x);
        bank.reset_stats();
        assert_eq!(bank.stats.dcim.bit_serial_cycles, 0);
        assert_eq!(bank.stats.pce.pcu_ops, 0);
    }
}
