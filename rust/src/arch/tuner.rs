//! Threshold auto-tuner for the dynamic workload configuration (§5).
//!
//! The paper sets `[TH0, TH1, TH2]` "referring to Eq. 5" by hand; a
//! deployment needs a procedure. This tuner searches the threshold space
//! against a user-supplied evaluation callback (accuracy on a validation
//! split) under an accuracy-loss budget, and returns the configuration
//! with the fewest average digital cycles — the knob behind Fig. 6(b)'s
//! "average cycle 12 at ≤1% degradation".

use super::bank_logic::ThresholdSet;

/// One evaluated configuration.
#[derive(Debug, Clone, Copy)]
pub struct TunePoint {
    pub thresholds: ThresholdSet,
    pub accuracy: f64,
    pub avg_cycles: f64,
}

/// Tuning result: the chosen point and the full trace for reporting.
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub best: Option<TunePoint>,
    pub baseline_accuracy: f64,
    pub trace: Vec<TunePoint>,
}

/// Grid-search candidate generator: geometric ladders over the
/// `0.02 · 1.6^i` step sequence, capped below 0.9.
///
/// Candidates always satisfy `th0 ≤ th1 ≤ th2` and are unique: the
/// defensive `t2.min(1.0)` clamp can collapse distinct ladder rungs onto
/// the same `ThresholdSet`, so equal candidates are dropped (evaluating
/// a duplicate would waste a full validation-split pass in [`tune`] and
/// in the `arch::dse` sweep, which both iterate this grid).
pub fn candidate_grid(levels: usize) -> Vec<ThresholdSet> {
    let mut out: Vec<ThresholdSet> = Vec::new();
    let steps: Vec<f64> = (0..levels)
        .map(|i| 0.02 * 1.6f64.powi(i as i32))
        .take_while(|&v| v < 0.9)
        .collect();
    for (i, &t0) in steps.iter().enumerate() {
        for (j, &t1) in steps.iter().enumerate().skip(i) {
            for &t2 in steps.iter().skip(j) {
                let cand = ThresholdSet::new(t0, t1, t2.min(1.0));
                if !out.contains(&cand) {
                    out.push(cand);
                }
            }
        }
    }
    out
}

/// Tune thresholds: `eval(th)` must return `(accuracy, avg_cycles)` for
/// the dynamic configuration with thresholds `th`; `baseline_accuracy` is
/// the static-map accuracy; `max_loss` the budget (paper: 0.01).
pub fn tune<F>(
    candidates: &[ThresholdSet],
    baseline_accuracy: f64,
    max_loss: f64,
    mut eval: F,
) -> TuneResult
where
    F: FnMut(&ThresholdSet) -> (f64, f64),
{
    let mut trace = Vec::with_capacity(candidates.len());
    let mut best: Option<TunePoint> = None;
    for th in candidates {
        let (accuracy, avg_cycles) = eval(th);
        let pt = TunePoint {
            thresholds: *th,
            accuracy,
            avg_cycles,
        };
        trace.push(pt);
        if baseline_accuracy - accuracy <= max_loss {
            let better = match best {
                Some(b) => {
                    avg_cycles < b.avg_cycles
                        || (avg_cycles == b.avg_cycles && accuracy > b.accuracy)
                }
                None => true,
            };
            if better {
                best = Some(pt);
            }
        }
    }
    TuneResult {
        best,
        baseline_accuracy,
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic accuracy/cycles landscape: aggressive thresholds cut
    /// cycles but cost accuracy (monotone, like the real system).
    fn fake_eval(th: &ThresholdSet) -> (f64, f64) {
        // "Aggressiveness" = how much probability mass falls below TH2.
        let agg = th.th0 * 0.5 + th.th1 * 0.3 + th.th2 * 0.2;
        let cycles = 16.0 - 6.0 * agg.min(1.0);
        let acc = 0.93 - 0.08 * agg * agg;
        (acc, cycles)
    }

    #[test]
    fn grid_is_ordered_and_nonempty() {
        let grid = candidate_grid(8);
        assert!(grid.len() > 20);
        for th in &grid {
            assert!(th.th0 <= th.th1 && th.th1 <= th.th2);
        }
    }

    #[test]
    fn grid_candidates_are_unique() {
        // The t2.min(1.0) clamp must not leak duplicate candidates —
        // each grid entry costs a full validation pass to evaluate.
        for levels in [4usize, 8, 16, 32] {
            let grid = candidate_grid(levels);
            for (i, a) in grid.iter().enumerate() {
                for b in grid.iter().skip(i + 1) {
                    assert_ne!(
                        (a.th0, a.th1, a.th2),
                        (b.th0, b.th1, b.th2),
                        "duplicate candidate at levels={levels}"
                    );
                }
            }
        }
    }

    #[test]
    fn tuner_respects_loss_budget() {
        let grid = candidate_grid(8);
        let res = tune(&grid, 0.93, 0.01, fake_eval);
        let best = res.best.expect("a feasible point exists");
        assert!(0.93 - best.accuracy <= 0.01 + 1e-12);
        // It should have found something cheaper than the static 16.
        assert!(best.avg_cycles < 16.0);
        // And nothing in the trace with fewer cycles satisfies the budget.
        for p in &res.trace {
            if 0.93 - p.accuracy <= 0.01 {
                assert!(p.avg_cycles >= best.avg_cycles - 1e-12);
            }
        }
    }

    #[test]
    fn infeasible_budget_returns_none() {
        let grid = candidate_grid(6);
        // Baseline far above anything eval can produce → nothing feasible.
        let res = tune(&grid, 2.0, 0.001, fake_eval);
        assert!(res.best.is_none());
        assert_eq!(res.trace.len(), grid.len());
    }

    #[test]
    fn looser_budget_never_worse() {
        let grid = candidate_grid(8);
        let tight = tune(&grid, 0.93, 0.005, fake_eval);
        let loose = tune(&grid, 0.93, 0.02, fake_eval);
        let (t, l) = (tight.best.unwrap(), loose.best.unwrap());
        assert!(l.avg_cycles <= t.avg_cycles);
    }
}
