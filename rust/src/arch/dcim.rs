//! D-CiM bank model (§4.3, Fig. 5 ①).
//!
//! A 256×256 6T-SRAM digital CiM array in the style of ISSCC'21 [6]:
//! 64 multi-bit weight columns (MWCs) of 4 bits each, wordline/input
//! drivers broadcasting one activation bit-plane per cycle, NOR-gate
//! dot-product cells, and a 256-input adder tree per column group.
//!
//! With the PAC operand split, only the `weight_bits` MSB columns exist
//! physically (LSB columns eliminated, §4.1); one **bit-serial cycle**
//! broadcasts activation plane `p` and reduces the AND with weight plane
//! `q` across all rows of every MWC simultaneously.
//!
//! The model is *bit-true* (the adder tree output is exact) and keeps
//! cycle/write statistics for the energy composition.

use crate::util::{and_popcount, pack_bits_u64, words_for};

/// Static configuration of one D-CiM bank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DCimConfig {
    /// SRAM rows = maximum DP length per column pass.
    pub rows: usize,
    /// Multi-bit weight columns (output channels resident at once).
    pub mwcs: usize,
    /// Physical weight bits stored per MWC (MSBs; 4 after LSB elimination).
    pub weight_bits: u32,
}

impl Default for DCimConfig {
    fn default() -> Self {
        Self {
            rows: 256,
            mwcs: 64,
            weight_bits: 4,
        }
    }
}

impl DCimConfig {
    /// Physical SRAM columns = MWCs × stored weight bits.
    pub fn columns(&self) -> usize {
        self.mwcs * self.weight_bits as usize
    }
}

/// Cycle/energy-relevant event counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DCimStats {
    /// Bit-serial compute cycles executed (one = one (p,q) broadcast
    /// across the whole array).
    pub bit_serial_cycles: u64,
    /// Equivalent binary MAC ops delivered (cycles × active rows × MWCs).
    pub binary_ops: u64,
    /// SRAM row-writes performed by weight updates.
    pub weight_row_writes: u64,
}

/// One D-CiM bank holding packed weight bit-planes.
#[derive(Debug, Clone)]
pub struct DCimBank {
    pub config: DCimConfig,
    /// `planes[mwc][q_rel]` = packed plane of stored weight bit
    /// `q = 8 - weight_bits + q_rel` over the rows.
    planes: Vec<Vec<Vec<u64>>>,
    /// Rows occupied by the currently loaded weights (DP length).
    active_rows: usize,
    /// Loaded MWC count (≤ config.mwcs).
    active_mwcs: usize,
    pub stats: DCimStats,
}

impl DCimBank {
    pub fn new(config: DCimConfig) -> Self {
        Self {
            config,
            planes: Vec::new(),
            active_rows: 0,
            active_mwcs: 0,
            stats: DCimStats::default(),
        }
    }

    /// Lowest weight bit index stored physically.
    pub fn min_weight_bit(&self) -> usize {
        8 - self.config.weight_bits as usize
    }

    /// Load weights: `weights[mwc]` is the UINT8 weight vector of one
    /// output channel (length = DP segment ≤ rows). Only the MSB planes
    /// are written — the LSBs have no columns to live in.
    pub fn load_weights(&mut self, weights: &[Vec<u8>]) {
        assert!(
            weights.len() <= self.config.mwcs,
            "{} MWCs exceed bank capacity {}",
            weights.len(),
            self.config.mwcs
        );
        let rows = weights.first().map_or(0, |w| w.len());
        assert!(rows <= self.config.rows, "DP segment {rows} exceeds {} rows", self.config.rows);
        for w in weights {
            assert_eq!(w.len(), rows, "ragged weight load");
        }
        let min_bit = self.min_weight_bit();
        self.planes = weights
            .iter()
            .map(|w| {
                (min_bit..8)
                    .map(|q| {
                        let bits: Vec<u8> = w.iter().map(|&v| (v >> q) & 1).collect();
                        pack_bits_u64(&bits)
                    })
                    .collect()
            })
            .collect();
        self.active_rows = rows;
        self.active_mwcs = weights.len();
        // Each weight bit of each row is one SRAM cell write; the column
        // write drivers update a full row per cycle.
        self.stats.weight_row_writes +=
            (rows * weights.len()) as u64 * self.config.weight_bits as u64;
    }

    pub fn active_rows(&self) -> usize {
        self.active_rows
    }

    pub fn active_mwcs(&self) -> usize {
        self.active_mwcs
    }

    /// Execute one bit-serial cycle: broadcast packed activation plane
    /// `x_plane` (over `active_rows` rows) against stored weight bit `q`,
    /// returning the adder-tree output (DP count) of every active MWC.
    ///
    /// Panics if `q` addresses an eliminated LSB column — by construction
    /// the compute map never routes such cycles to the digital domain.
    pub fn bit_serial_cycle(&mut self, x_plane: &[u64], q: usize) -> Vec<u32> {
        assert!(
            (self.min_weight_bit()..8).contains(&q),
            "weight bit {q} not stored (columns {}..7 only)",
            self.min_weight_bit()
        );
        assert_eq!(x_plane.len(), words_for(self.active_rows));
        let q_rel = q - self.min_weight_bit();
        let out: Vec<u32> = self
            .planes
            .iter()
            .map(|mwc| and_popcount(x_plane, &mwc[q_rel]))
            .collect();
        self.stats.bit_serial_cycles += 1;
        self.stats.binary_ops += (self.active_rows * self.active_mwcs) as u64;
        out
    }

    /// Reset statistics (weights stay loaded).
    pub fn reset_stats(&mut self) {
        self.stats = DCimStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pack_plane(x: &[u8], p: usize) -> Vec<u64> {
        let bits: Vec<u8> = x.iter().map(|&v| (v >> p) & 1).collect();
        pack_bits_u64(&bits)
    }

    #[test]
    fn config_columns() {
        let c = DCimConfig::default();
        assert_eq!(c.columns(), 256);
    }

    #[test]
    fn cycle_matches_naive_dp() {
        let mut rng = Rng::new(50);
        let rows = 200;
        let weights: Vec<Vec<u8>> = (0..8)
            .map(|_| (0..rows).map(|_| rng.below(256) as u8).collect())
            .collect();
        let mut bank = DCimBank::new(DCimConfig::default());
        bank.load_weights(&weights);
        let x: Vec<u8> = (0..rows).map(|_| rng.below(256) as u8).collect();
        for p in 0..8 {
            let xp = pack_plane(&x, p);
            for q in 4..8 {
                let got = bank.bit_serial_cycle(&xp, q);
                for (mwc, w) in weights.iter().enumerate() {
                    let want: u32 = x
                        .iter()
                        .zip(w)
                        .map(|(&a, &b)| (((a >> p) & 1) & ((b >> q) & 1)) as u32)
                        .sum();
                    assert_eq!(got[mwc], want, "p={p} q={q} mwc={mwc}");
                }
            }
        }
    }

    #[test]
    fn stats_count_cycles_and_ops() {
        let mut bank = DCimBank::new(DCimConfig::default());
        bank.load_weights(&[vec![255u8; 100], vec![1u8; 100]]);
        assert_eq!(bank.stats.weight_row_writes, 2 * 100 * 4);
        let xp = pack_plane(&[7u8; 100], 0);
        bank.bit_serial_cycle(&xp, 7);
        bank.bit_serial_cycle(&xp, 6);
        assert_eq!(bank.stats.bit_serial_cycles, 2);
        assert_eq!(bank.stats.binary_ops, 2 * 100 * 2);
    }

    #[test]
    #[should_panic(expected = "not stored")]
    fn lsb_column_access_panics() {
        let mut bank = DCimBank::new(DCimConfig::default());
        bank.load_weights(&[vec![0u8; 10]]);
        let xp = pack_plane(&[0u8; 10], 0);
        bank.bit_serial_cycle(&xp, 3); // LSB column was eliminated
    }

    #[test]
    #[should_panic(expected = "exceed")]
    fn overloading_mwcs_panics() {
        let mut bank = DCimBank::new(DCimConfig {
            rows: 16,
            mwcs: 2,
            weight_bits: 4,
        });
        bank.load_weights(&[vec![0u8; 4], vec![0u8; 4], vec![0u8; 4]]);
    }

    #[test]
    fn full_precision_variant_stores_all_bits() {
        // weight_bits = 8 models the baseline (no LSB elimination).
        let mut bank = DCimBank::new(DCimConfig {
            rows: 64,
            mwcs: 4,
            weight_bits: 8,
        });
        bank.load_weights(&[vec![0xAB; 64]]);
        assert_eq!(bank.min_weight_bit(), 0);
        let xp = pack_plane(&[255u8; 64], 0);
        let got = bank.bit_serial_cycle(&xp, 0);
        assert_eq!(got[0], 64); // 0xAB bit0 = 1 on all rows
    }
}
