//! Design-space exploration: the `pacim tune` sweep driver.
//!
//! The paper tunes its knobs one at a time — Fig. 6(b) picks the dynamic
//! threshold map, §4.5 picks the bank tiling — but deployment has to pick
//! them *jointly*: thresholds move accuracy and average digital cycles,
//! bank/tile geometry moves cycles and bits, and the traffic price λ
//! (see [`TrafficPrice`]) trades the two. This module enumerates that
//! joint space with the `engine::EngineBuilder` front door, evaluates
//! each point's (accuracy, cycles, bits moved) on a validation split, and
//! returns the non-dominated Pareto front plus the λ-vs-cycles-only
//! comparisons the CI gate (`util::benchfmt::enforce_tune_front`) prices.
//!
//! Axis economics: accuracy and measured average digital cycles depend
//! only on the threshold map, so the sweep runs one engine evaluation per
//! distinct map and reuses it across the (banks × rows × λ) cost grid —
//! a full grid costs `thresholds` engine runs, not `points` of them.
//!
//! The first engine run doubles as the measured-vs-analytic cross-check:
//! its [`TrafficLedger`](crate::memory::TrafficLedger) bit counts are
//! recomputed per edge from layer geometry (the same closed form
//! `benches/fig7_system.rs` asserts on) and both sums are carried into
//! the report, where `validate_tune` requires them equal.

use super::bank_logic::ThresholdSet;
use super::multibank::{schedule_network_priced, MultiBankConfig, TrafficPrice};
use super::tuner::candidate_grid;
use crate::coordinator::model_shapes;
use crate::engine::{EngineBuilder, EngineResult};
use crate::memory::{activation_traffic, EdgeKind, LayerTraffic};
use crate::nn::{Model, PacConfig};
use crate::workload::shapes::{LayerShape, LayerShapeKind};

/// Sweep axes of the joint design space.
#[derive(Debug, Clone)]
pub struct DseAxes {
    /// Bank counts (§4.5 tiling).
    pub banks: Vec<usize>,
    /// Rows per bank — the DP tile size a pass covers.
    pub rows: Vec<usize>,
    /// Dynamic-threshold maps; `None` is the static 16-cycle map.
    pub thresholds: Vec<Option<ThresholdSet>>,
    /// Traffic prices λ in cycles per bit; `0.0` is the cycles-only
    /// schedule every other point is compared against.
    pub lambdas: Vec<f64>,
}

impl DseAxes {
    /// CI-sized grid: 3 threshold maps (3 engine evaluations) ×
    /// 2 bank counts × 1 tile size × 3 λ rungs = 18 cost points.
    pub fn quick() -> Self {
        Self {
            banks: vec![2, 4],
            rows: vec![256],
            thresholds: grid_thresholds(2),
            lambdas: vec![0.0, 0.005, 0.02],
        }
    }

    /// Full grid: 5 threshold maps × 5 bank counts × 2 tile sizes ×
    /// 5 λ rungs = 250 cost points (still only 5 engine evaluations).
    pub fn full() -> Self {
        Self {
            banks: vec![1, 2, 4, 8, 18],
            rows: vec![128, 256],
            thresholds: grid_thresholds(4),
            lambdas: vec![0.0, 0.002, 0.005, 0.01, 0.02],
        }
    }

    /// Number of cost points this grid enumerates.
    pub fn points(&self) -> usize {
        self.banks.len() * self.rows.len() * self.thresholds.len() * self.lambdas.len()
    }
}

/// The static map (`None`) plus `n` interior samples of
/// [`candidate_grid`]'s geometric threshold ladder, spread from
/// conservative to aggressive.
fn grid_thresholds(n: usize) -> Vec<Option<ThresholdSet>> {
    let grid = candidate_grid(8);
    let mut out = vec![None];
    for k in 0..n {
        if grid.is_empty() {
            break;
        }
        let idx = (grid.len() * (k + 1)) / (n + 1);
        let cand = grid[idx.min(grid.len() - 1)];
        if !out.contains(&Some(cand)) {
            out.push(Some(cand));
        }
    }
    out
}

/// Sweep configuration: the axes, plus the workload whose shapes the
/// priced schedule models. Accuracy comes from the evaluation split on
/// `model`; cycles and bits come from pricing `workload` — a deep paper
/// workload (default: ResNet-18) exposes the spill-vs-replay trade that
/// shallow validation models cannot.
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub axes: DseAxes,
    /// Layer shapes the priced schedule is computed over.
    pub workload: Vec<LayerShape>,
    /// Human-readable workload label carried into the report.
    pub workload_label: String,
    /// Worker threads for the accuracy evaluations.
    pub threads: usize,
}

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DsePoint {
    pub banks: usize,
    pub rows: usize,
    /// `None` = static 16-cycle map.
    pub thresholds: Option<ThresholdSet>,
    /// Traffic price this point's schedule was selected under.
    pub lambda: f64,
    /// Top-1 accuracy on the validation split (threshold-dependent).
    pub accuracy: f64,
    /// Measured average digital cycles per output group.
    pub avg_digital_cycles: f64,
    /// Modeled cycles of the priced schedule over the workload.
    pub cycles: u64,
    /// Modeled bits moved (activation + spill) of the priced schedule.
    pub bits: u64,
}

/// `a` dominates `b` iff it is at least as good on every objective
/// (accuracy ↑, cycles ↓, bits ↓) and strictly better on at least one.
pub fn dominates(a: &DsePoint, b: &DsePoint) -> bool {
    let no_worse = a.accuracy >= b.accuracy && a.cycles <= b.cycles && a.bits <= b.bits;
    no_worse && (a.accuracy > b.accuracy || a.cycles < b.cycles || a.bits < b.bits)
}

/// Indices (ascending) of the non-dominated points.
///
/// Deterministic — pure comparisons, no tolerance — and invariant to
/// input order: membership depends only on each point's objective values,
/// so permuting the input permutes the front the same way
/// (property-tested in `tests/proptests.rs`).
pub fn pareto_front(points: &[DsePoint]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, p)| j != i && dominates(p, &points[i]))
        })
        .collect()
}

/// A λ-priced schedule next to its cycles-only baseline on one workload
/// — the rows `enforce_tune_front` gates on (strictly fewer bits within
/// a bounded cycle premium on at least one deep shape).
#[derive(Debug, Clone)]
pub struct LambdaComparison {
    /// Workload label (e.g. `resnet18-cifar`).
    pub workload: String,
    pub banks: usize,
    pub rows: usize,
    /// The non-zero λ the priced side used.
    pub lambda: f64,
    /// Cycles of the λ=0 (cycles-only) schedule.
    pub cycles_cycles_only: u64,
    /// Bits moved by the λ=0 schedule.
    pub bits_cycles_only: u64,
    /// Cycles of the λ-priced schedule.
    pub cycles_priced: u64,
    /// Bits moved by the λ-priced schedule.
    pub bits_priced: u64,
    /// Layers the pricing flipped from spill to digital replay.
    pub replayed_layers: usize,
}

/// Price one workload at `lambda` and at the λ=0 baseline.
pub fn compare_lambda(
    shapes: &[LayerShape],
    label: &str,
    cfg: &MultiBankConfig,
    lambda: f64,
    avg_digital_cycles: f64,
) -> LambdaComparison {
    let base_price = TrafficPrice {
        lambda: 0.0,
        avg_digital_cycles,
        ..Default::default()
    };
    let price = TrafficPrice {
        lambda,
        avg_digital_cycles,
        ..Default::default()
    };
    let base = schedule_network_priced(shapes, cfg, &base_price);
    let priced = schedule_network_priced(shapes, cfg, &price);
    LambdaComparison {
        workload: label.to_string(),
        banks: cfg.banks,
        rows: cfg.rows,
        lambda,
        cycles_cycles_only: base.total_cycles(),
        bits_cycles_only: base.total_bits(),
        cycles_priced: priced.total_cycles(),
        bits_priced: priced.total_bits(),
        replayed_layers: priced.replayed_layers(),
    }
}

/// Everything one sweep produces.
#[derive(Debug, Clone)]
pub struct DseOutcome {
    /// Every evaluated point, in canonical axes order
    /// (thresholds → rows → banks → λ).
    pub points: Vec<DsePoint>,
    /// Indices into `points` of the non-dominated front.
    pub front: Vec<usize>,
    /// λ-vs-cycles-only comparisons on the modeled workload, one per
    /// bank count at the grid's largest λ.
    pub comparisons: Vec<LambdaComparison>,
    /// One-direction bits the ledger measured on the probe run.
    pub measured_bits: u64,
    /// Closed-form recomputation of the same edges from layer geometry.
    pub analytic_bits: u64,
    /// Measured bits of the probe run's residual edges (save + add-in +
    /// post-add) under the fused dataplane.
    pub residual_bits_encoded: u64,
    /// Dense-baseline bits of those same residual edges — what the
    /// round-trip representation would have moved.
    pub residual_bits_dense: u64,
}

/// Recompute one measured ledger edge from layer geometry — the
/// `benches/fig7_system.rs` cross-check formula. Covers every edge kind:
/// eliminated edges (the fused residual add-in) are zero by definition,
/// encoded edges follow the MSB+counter closed form at the edge's own
/// plane count (8 on `residual_save` slots, the map's bits elsewhere),
/// dense edges are 8 bits per element.
fn analytic_edge_bits(
    shapes: &[LayerShape],
    name: &str,
    e: &LayerTraffic,
    images: usize,
) -> u64 {
    if e.is_eliminated() {
        return 0;
    }
    let Some(g) = shapes.iter().find(|s| s.name == name) else {
        return e.bits; // edge without a shape row: trust the measurement
    };
    let per_image_groups = match g.kind {
        LayerShapeKind::Conv => g.out_pixels() as u64,
        LayerShapeKind::Linear => 1,
    };
    let groups = per_image_groups * images as u64;
    if e.encoded {
        groups * activation_traffic(g.geom.out_c, e.msb_bits).pacim
    } else {
        groups * g.geom.out_c as u64 * 8
    }
}

/// Run the sweep: one engine evaluation per distinct threshold map, the
/// priced cost model across the full grid, Pareto filtering, and the
/// measured-vs-analytic traffic cross-check on the probe run.
pub fn sweep(
    model: &Model,
    images: &[&[u8]],
    labels: &[usize],
    cfg: &DseConfig,
) -> EngineResult<DseOutcome> {
    let eval_shapes = model_shapes(model);
    let mut evals: Vec<(Option<ThresholdSet>, f64, f64)> = Vec::new();
    let mut measured_bits = 0u64;
    let mut analytic_bits = 0u64;
    let mut residual_bits_encoded = 0u64;
    let mut residual_bits_dense = 0u64;
    for (i, th) in cfg.axes.thresholds.iter().enumerate() {
        let mut builder = EngineBuilder::new(model.clone()).pac(PacConfig::default());
        if let Some(t) = th {
            builder = builder.dynamic(*t);
        }
        let engine = builder.build()?;
        let ev = engine.evaluate(images, labels, cfg.threads.max(1))?;
        let avg = if ev.stats.levels.total() > 0 {
            ev.stats.levels.average_cycles()
        } else {
            16.0 // static map: every group runs the full 16 cycles
        };
        if i == 0 {
            for (name, e) in engine.traffic_rows(&ev.stats.traffic) {
                measured_bits += e.bits;
                analytic_bits += analytic_edge_bits(&eval_shapes, name, e, images.len());
                if matches!(
                    e.kind,
                    EdgeKind::ResidualSave | EdgeKind::ResidualIn | EdgeKind::ResidualAdd
                ) {
                    residual_bits_encoded += e.bits;
                    residual_bits_dense += e.baseline_bits;
                }
            }
        }
        evals.push((*th, ev.accuracy, avg));
    }

    let mut points = Vec::with_capacity(cfg.axes.points());
    for (th, accuracy, avg) in &evals {
        for &rows in &cfg.axes.rows {
            for &banks in &cfg.axes.banks {
                for &lambda in &cfg.axes.lambdas {
                    let mb = MultiBankConfig { banks, rows, ..Default::default() };
                    let price = TrafficPrice {
                        lambda,
                        avg_digital_cycles: *avg,
                        ..Default::default()
                    };
                    let rep = schedule_network_priced(&cfg.workload, &mb, &price);
                    points.push(DsePoint {
                        banks,
                        rows,
                        thresholds: *th,
                        lambda,
                        accuracy: *accuracy,
                        avg_digital_cycles: *avg,
                        cycles: rep.total_cycles(),
                        bits: rep.total_bits(),
                    });
                }
            }
        }
    }
    let front = pareto_front(&points);

    let lambda_max = cfg.axes.lambdas.iter().copied().fold(0.0f64, f64::max);
    let rows_max = cfg.axes.rows.iter().copied().max().unwrap_or(256);
    let comparisons = if lambda_max > 0.0 {
        cfg.axes
            .banks
            .iter()
            .map(|&banks| {
                let mb = MultiBankConfig { banks, rows: rows_max, ..Default::default() };
                compare_lambda(&cfg.workload, &cfg.workload_label, &mb, lambda_max, 16.0)
            })
            .collect()
    } else {
        Vec::new()
    };

    Ok(DseOutcome {
        points,
        front,
        comparisons,
        measured_bits,
        analytic_bits,
        residual_bits_encoded,
        residual_bits_dense,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::shapes::{resnet18, Resolution};
    use crate::workload::synthetic_serving_workload;

    fn point(accuracy: f64, cycles: u64, bits: u64) -> DsePoint {
        DsePoint {
            banks: 4,
            rows: 256,
            thresholds: None,
            lambda: 0.0,
            accuracy,
            avg_digital_cycles: 16.0,
            cycles,
            bits,
        }
    }

    #[test]
    fn front_keeps_only_nondominated_points() {
        let pts = vec![
            point(0.90, 100, 100), // front: best accuracy
            point(0.85, 50, 120),  // front: best cycles
            point(0.85, 80, 60),   // front: best bits
            point(0.84, 100, 130), // dominated by the first
        ];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn duplicate_points_share_the_front() {
        let pts = vec![point(0.9, 10, 10), point(0.9, 10, 10)];
        assert_eq!(pareto_front(&pts), vec![0, 1]);
    }

    #[test]
    fn grid_thresholds_start_static_and_stay_unique() {
        let ths = grid_thresholds(4);
        assert_eq!(ths[0], None);
        assert!(ths.len() >= 3);
        for (i, a) in ths.iter().enumerate() {
            for b in ths.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn comparison_finds_the_lambda_trade_on_resnet18() {
        let shapes = resnet18(Resolution::Cifar, 10);
        let mb = MultiBankConfig::default();
        let c = compare_lambda(&shapes, "resnet18-cifar", &mb, 0.02, 16.0);
        assert!(c.bits_priced < c.bits_cycles_only);
        assert!(c.cycles_priced as f64 <= c.cycles_cycles_only as f64 * 1.10);
        assert!(c.replayed_layers > 0);
    }

    #[test]
    fn quick_sweep_produces_a_front_of_at_least_three() {
        // End-to-end on a tiny synthetic split; the modeled workload is
        // the deep paper shape so the λ rungs genuinely trade.
        let (model, ds) = synthetic_serving_workload(7, 8, 16, 10, 12).expect("workload");
        let images: Vec<&[u8]> = (0..ds.n).map(|i| ds.image(i)).collect();
        let labels: Vec<usize> = (0..ds.n).map(|i| ds.label(i)).collect();
        let cfg = DseConfig {
            axes: DseAxes::quick(),
            workload: resnet18(Resolution::Cifar, 10),
            workload_label: "resnet18-cifar".into(),
            threads: 2,
        };
        let out = sweep(&model, &images, &labels, &cfg).expect("sweep");
        assert_eq!(out.points.len(), cfg.axes.points());
        assert!(out.front.len() >= 3, "front: {:?}", out.front);
        for &i in &out.front {
            for &j in &out.front {
                if i != j {
                    assert!(!dominates(&out.points[i], &out.points[j]));
                }
            }
        }
        assert_eq!(out.measured_bits, out.analytic_bits);
        // The probe's fused residual edges move strictly fewer bits than
        // their dense round-trip baseline (the eliminated add-in edge
        // pays for the 8-plane save slot at every width ≥ 2 channels).
        assert!(out.residual_bits_dense > 0);
        assert!(
            out.residual_bits_encoded < out.residual_bits_dense,
            "encoded {} vs dense {}",
            out.residual_bits_encoded,
            out.residual_bits_dense
        );
        assert!(out.comparisons.iter().any(|c| c.bits_priced < c.bits_cycles_only));
    }
}
