//! Bank logic: saliency speculation and dynamic workload configuration
//! (§5, Eq. 5, Fig. 6(b)).
//!
//! PACiM knows the bit-level sparsity of the *input* activations before
//! broadcasting them, so it can speculate on each output's magnitude:
//! `SPEC = Σ_p 2^p · Sx[p]` — a weighted sum of the input sparsity. Low
//! SPEC ⇒ the output is likely small ⇒ its MAC tolerates more
//! approximation ⇒ digital cycles can be transferred to the sparsity
//! domain. A threshold set `[TH0, TH1, TH2]` over the normalized score
//! selects one of four levels: 10/12/14/16 digital cycles.

use crate::pac::compute_map::DynamicLevel;

/// Normalized speculation thresholds, ascending, each in [0, 1].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThresholdSet {
    pub th0: f64,
    pub th1: f64,
    pub th2: f64,
}

impl ThresholdSet {
    pub fn new(th0: f64, th1: f64, th2: f64) -> Self {
        assert!(
            (0.0..=1.0).contains(&th0) && th0 <= th1 && th1 <= th2 && th2 <= 1.0,
            "thresholds must be ascending in [0,1]: {th0} {th1} {th2}"
        );
        Self { th0, th1, th2 }
    }

    /// A configuration that disables dynamic adaptation (everything runs
    /// the full 16-cycle map).
    pub fn disabled() -> Self {
        Self {
            th0: 0.0,
            th1: 0.0,
            th2: 0.0,
        }
    }

    /// Default operating point used in the Fig. 6(b) reproduction: tuned
    /// on the synthetic validation split for ≈12-cycle average at ≤1%
    /// accuracy loss (see `bench fig6_accuracy`).
    pub fn default_cifar() -> Self {
        Self {
            th0: 0.08,
            th1: 0.16,
            th2: 0.30,
        }
    }
}

/// Raw speculation score (Eq. 5): `Σ_p 2^p · Sx[p]`. Note this equals the
/// element sum of the activation group — the same quantity the zero-point
/// correction uses, so the hardware computes it once.
pub fn spec_score(sx: &[u32; 8]) -> u64 {
    (0..8).map(|p| (sx[p] as u64) << p).sum()
}

/// Normalized SPEC ∈ [0, 1]: raw score / (n · 255) — the maximum possible
/// element sum of an n-element UINT8 group.
pub fn spec_normalized(sx: &[u32; 8], n: u32) -> f64 {
    if n == 0 {
        return 0.0;
    }
    spec_score(sx) as f64 / (n as f64 * 255.0)
}

/// Classify a normalized SPEC against the thresholds (§5):
/// > TH2 → 16 cycles; (TH1, TH2] → 14; (TH0, TH1] → 12; ≤ TH0 → 10.
pub fn classify(spec: f64, th: &ThresholdSet) -> DynamicLevel {
    if spec > th.th2 {
        DynamicLevel::Cycles16
    } else if spec > th.th1 {
        DynamicLevel::Cycles14
    } else if spec > th.th0 {
        DynamicLevel::Cycles12
    } else {
        DynamicLevel::Cycles10
    }
}

/// Tally of dynamic-level decisions across a layer/model run — backs the
/// Fig. 6(b)/Fig. 7(a) average-cycle numbers.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LevelHistogram {
    pub c10: u64,
    pub c12: u64,
    pub c14: u64,
    pub c16: u64,
}

impl LevelHistogram {
    pub fn record(&mut self, level: DynamicLevel) {
        match level {
            DynamicLevel::Cycles10 => self.c10 += 1,
            DynamicLevel::Cycles12 => self.c12 += 1,
            DynamicLevel::Cycles14 => self.c14 += 1,
            DynamicLevel::Cycles16 => self.c16 += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.c10 + self.c12 + self.c14 + self.c16
    }

    /// Average digital cycles per output (paper: 12 at the chosen
    /// thresholds on CIFAR-100).
    pub fn average_cycles(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        (10 * self.c10 + 12 * self.c12 + 14 * self.c14 + 16 * self.c16) as f64 / t as f64
    }

    /// Reduction vs a fully digital 64-cycle 8b/8b MAC (Fig. 7(a): 81%
    /// at the average level of 12).
    pub fn cycle_reduction_vs_digital(&self) -> f64 {
        1.0 - self.average_cycles() / 64.0
    }

    pub fn merge(&mut self, other: &LevelHistogram) {
        self.c10 += other.c10;
        self.c12 += other.c12;
        self.c14 += other.c14;
        self.c16 += other.c16;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_equals_element_sum() {
        use crate::pac::sparsity::BitPlanes;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(80);
        let v: Vec<u8> = (0..300).map(|_| rng.below(256) as u8).collect();
        let bp = BitPlanes::from_u8(&v);
        let direct: u64 = v.iter().map(|&x| x as u64).sum();
        assert_eq!(spec_score(&bp.pop), direct);
    }

    #[test]
    fn normalization_bounds() {
        // All-255 group: normalized SPEC = 1. All-zero: 0.
        let n = 64u32;
        let all_on = [n; 8];
        assert!((spec_normalized(&all_on, n) - 1.0).abs() < 1e-12);
        assert_eq!(spec_normalized(&[0; 8], n), 0.0);
    }

    #[test]
    fn classify_levels() {
        let th = ThresholdSet::new(0.1, 0.2, 0.4);
        assert_eq!(classify(0.05, &th), DynamicLevel::Cycles10);
        assert_eq!(classify(0.15, &th), DynamicLevel::Cycles12);
        assert_eq!(classify(0.3, &th), DynamicLevel::Cycles14);
        assert_eq!(classify(0.9, &th), DynamicLevel::Cycles16);
        // Boundary: exactly TH0 goes down.
        assert_eq!(classify(0.1, &th), DynamicLevel::Cycles10);
    }

    #[test]
    fn disabled_thresholds_always_full() {
        let th = ThresholdSet::disabled();
        for s in [0.0001, 0.5, 1.0] {
            assert_eq!(classify(s, &th), DynamicLevel::Cycles16);
        }
        // Exactly zero is the one ≤TH0 case; inputs with SPEC=0 have
        // all-zero activations and produce zero regardless of level.
        assert_eq!(classify(0.0, &th), DynamicLevel::Cycles10);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn bad_thresholds_rejected() {
        let _ = ThresholdSet::new(0.5, 0.2, 0.8);
    }

    #[test]
    fn histogram_average() {
        let mut h = LevelHistogram::default();
        for _ in 0..2 {
            h.record(DynamicLevel::Cycles10);
        }
        for _ in 0..2 {
            h.record(DynamicLevel::Cycles14);
        }
        assert_eq!(h.average_cycles(), 12.0);
        // Paper Fig. 7(a): avg 12 cycles ⇒ 81% reduction vs 64.
        assert!((h.cycle_reduction_vs_digital() - 0.8125).abs() < 1e-12);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LevelHistogram::default();
        a.record(DynamicLevel::Cycles16);
        let mut b = LevelHistogram::default();
        b.record(DynamicLevel::Cycles10);
        a.merge(&b);
        assert_eq!(a.total(), 2);
        assert_eq!(a.average_cycles(), 13.0);
    }
}
