//! PAC Computation Engine (§4.4, Fig. 5 ②).
//!
//! The PCE is the CnM block that evaluates sparsity-domain cycles. Each
//! PAC Computing Unit (PCU) holds a weight-sparsity register file (the
//! per-MWC `Sw[q]` counts, loaded once — weight-stationary) and the
//! multiply-divide arithmetic of Eq. 3; an accumulator per MWC merges the
//! shifted cycle results. Six PCUs match the throughput of one
//! 64-accumulator D-CiM bank (§6.2).

use crate::pac::mac::{pcu_cycle, PcuRounding};
use crate::pac::ComputeMap;

/// Event counters for the PCE.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PceStats {
    /// PCU multiply-divide operations executed (one per sparsity-domain
    /// (p,q) cycle per output channel).
    pub pcu_ops: u64,
    /// Equivalent binary MAC ops delivered (each PCU op covers a whole DP
    /// vector: n per op).
    pub equivalent_binary_ops: u64,
    /// Accumulator shift-add operations.
    pub acc_ops: u64,
    /// Weight-sparsity register refreshes.
    pub weight_loads: u64,
}

/// One PCU: weight-stationary sparsity registers + arithmetic.
#[derive(Debug, Clone)]
pub struct Pcu {
    /// `Sw[q]` for the weight vector this PCU currently serves.
    w_sparsity: [u32; 8],
    /// DP length of the loaded weight vector.
    n: u32,
    pub rounding: PcuRounding,
}

impl Pcu {
    pub fn new(rounding: PcuRounding) -> Self {
        Self {
            w_sparsity: [0; 8],
            n: 0,
            rounding,
        }
    }

    /// Load the weight sparsity registers (one per weight bit index).
    pub fn load_weight_sparsity(&mut self, sw: [u32; 8], n: u32) {
        assert!(n > 0, "DP length must be positive");
        for (q, &s) in sw.iter().enumerate() {
            assert!(s <= n, "Sw[{q}]={s} exceeds DP length {n}");
        }
        self.w_sparsity = sw;
        self.n = n;
    }

    pub fn weight_sparsity(&self) -> [u32; 8] {
        self.w_sparsity
    }

    pub fn dp_len(&self) -> u32 {
        self.n
    }

    /// One sparsity-domain cycle: estimate the DP of activation bit `p`
    /// against weight bit `q` from the streamed activation sparsity
    /// `sx_p` (Eq. 3).
    #[inline]
    pub fn cycle(&self, sx_p: u32, q: usize) -> u32 {
        debug_assert!(self.n > 0, "PCU used before weight load");
        pcu_cycle(sx_p, self.w_sparsity[q], self.n, self.rounding)
    }

    /// Full sparsity-domain contribution for one output under `map`:
    /// `Σ_{(p,q)∈𝔸} 2^{p+q} · cycle(p, q)`, with stats tallied.
    pub fn sparsity_sum(&self, sx: &[u32; 8], map: &ComputeMap, stats: &mut PceStats) -> i64 {
        let mut acc = 0i64;
        for p in 0..8 {
            for q in 0..8 {
                if !map.is_digital(p, q) {
                    acc += (self.cycle(sx[p], q) as i64) << (p + q);
                    stats.pcu_ops += 1;
                    stats.equivalent_binary_ops += self.n as u64;
                    stats.acc_ops += 1;
                }
            }
        }
        acc
    }
}

/// Closed-form variance of one output's PCU estimate (Eq. 3 summed over
/// the sparsity set), in accumulator LSB² — the confidence signal the
/// DESIGN.md §15 escalation monitor thresholds against. Each
/// approximated `(p, q)` pair's true binary dot product is modeled
/// `Binomial(n, ŝx·ŝw)` around the PCU's mean estimate `n·ŝx·ŝw`
/// (Counting-Cards-style variance awareness), so
///
/// ```text
/// Var ≈ Σ_{(p,q)∉𝔻} 4^{p+q} · n · ŝx[p]·ŝw[q] · (1 − ŝx[p]·ŝw[q])
/// ```
///
/// Degenerate sparsities (all-zero or saturated counts) contribute
/// nothing, matching the estimator being exact there.
pub fn pcu_estimate_variance(sx: &[u32; 8], sw: &[u32; 8], n: u32, map: &ComputeMap) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let nf = n as f64;
    let mut var = 0.0;
    for p in 0..8 {
        for q in 0..8 {
            if !map.is_digital(p, q) {
                let rate = (sx[p] as f64 / nf) * (sw[q] as f64 / nf);
                var += f64::powi(4.0, (p + q) as i32) * nf * rate * (1.0 - rate);
            }
        }
    }
    var
}

/// The PCE: a pool of PCUs, one logical accumulator per served MWC.
#[derive(Debug, Clone)]
pub struct Pce {
    pub pcus: Vec<Pcu>,
    pub stats: PceStats,
}

impl Pce {
    /// `n_pcus = 6` matches a single 64-accumulator bank (§6.2).
    pub fn new(n_pcus: usize, rounding: PcuRounding) -> Self {
        Self {
            pcus: (0..n_pcus).map(|_| Pcu::new(rounding)).collect(),
            stats: PceStats::default(),
        }
    }

    pub fn n_pcus(&self) -> usize {
        self.pcus.len()
    }

    /// Load weight sparsity for a batch of MWCs, round-robin across PCUs
    /// (each PCU time-multiplexes several MWCs; the register file holds
    /// one entry per served MWC — we model the assignment, the arithmetic
    /// is identical).
    pub fn load_weights(&mut self, sw_per_mwc: &[[u32; 8]], n: u32) {
        for (i, &sw) in sw_per_mwc.iter().enumerate() {
            let idx = i % self.pcus.len();
            self.pcus[idx].load_weight_sparsity(sw, n);
            self.stats.weight_loads += 1;
        }
    }

    /// Sparsity-domain sums for every MWC given shared activation
    /// sparsity `sx` (activation broadcast matches the D-CiM array).
    /// `sw_per_mwc` must be passed again because PCUs time-multiplex.
    pub fn compute_all(
        &mut self,
        sw_per_mwc: &[[u32; 8]],
        n: u32,
        sx: &[u32; 8],
        map: &ComputeMap,
    ) -> Vec<i64> {
        let mut out = Vec::with_capacity(sw_per_mwc.len());
        let rounding = self.pcus[0].rounding;
        for (i, &sw) in sw_per_mwc.iter().enumerate() {
            let idx = i % self.pcus.len();
            // Refresh the time-multiplexed register slot if it serves a
            // different MWC than last loaded (weight-stationary within an
            // MWC's tenure).
            if self.pcus[idx].weight_sparsity() != sw || self.pcus[idx].dp_len() != n {
                self.pcus[idx].load_weight_sparsity(sw, n);
            }
            let _ = rounding;
            let v = {
                let mut stats = PceStats::default();
                let v = self.pcus[idx].sparsity_sum(sx, map, &mut stats);
                self.stats.pcu_ops += stats.pcu_ops;
                self.stats.equivalent_binary_ops += stats.equivalent_binary_ops;
                self.stats.acc_ops += stats.acc_ops;
                v
            };
            out.push(v);
        }
        out
    }

    pub fn reset_stats(&mut self) {
        self.stats = PceStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pac::sparsity::BitPlanes;
    use crate::pac::{sparsity_domain_sum, ComputeMap};
    use crate::util::rng::Rng;

    #[test]
    fn pcu_cycle_matches_eq3() {
        let mut pcu = Pcu::new(PcuRounding::RoundNearest);
        let mut sw = [0u32; 8];
        sw[3] = 100;
        pcu.load_weight_sparsity(sw, 256);
        // 80·100/256 = 31.25 → 31
        assert_eq!(pcu.cycle(80, 3), 31);
    }

    #[test]
    fn pcu_sum_matches_reference() {
        let mut rng = Rng::new(60);
        let n = 512usize;
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let w: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let xp = BitPlanes::from_u8(&x);
        let wp = BitPlanes::from_u8(&w);
        let map = ComputeMap::operand_based(4, 4);
        let mut pcu = Pcu::new(PcuRounding::RoundNearest);
        pcu.load_weight_sparsity(wp.pop, n as u32);
        let mut stats = PceStats::default();
        let got = pcu.sparsity_sum(&xp.pop, &map, &mut stats);
        let want = sparsity_domain_sum(&xp.pop, &wp.pop, n as u32, &map, PcuRounding::RoundNearest);
        assert_eq!(got, want);
        assert_eq!(stats.pcu_ops, 48);
        assert_eq!(stats.equivalent_binary_ops, 48 * n as u64);
    }

    #[test]
    fn pce_serves_more_mwcs_than_pcus() {
        let mut rng = Rng::new(61);
        let n = 128usize;
        let map = ComputeMap::operand_based(4, 4);
        let mwcs = 64;
        let ws: Vec<Vec<u8>> = (0..mwcs)
            .map(|_| (0..n).map(|_| rng.below(256) as u8).collect())
            .collect();
        let sw: Vec<[u32; 8]> = ws.iter().map(|w| BitPlanes::from_u8(w).pop).collect();
        let x: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let sx = BitPlanes::from_u8(&x).pop;
        let mut pce = Pce::new(6, PcuRounding::RoundNearest);
        pce.load_weights(&sw, n as u32);
        let got = pce.compute_all(&sw, n as u32, &sx, &map);
        assert_eq!(got.len(), mwcs);
        for (i, w) in ws.iter().enumerate() {
            let wp = BitPlanes::from_u8(w);
            let want =
                sparsity_domain_sum(&sx, &wp.pop, n as u32, &map, PcuRounding::RoundNearest);
            assert_eq!(got[i], want, "mwc {i}");
        }
        assert_eq!(pce.stats.pcu_ops, 48 * mwcs as u64);
    }

    #[test]
    #[should_panic(expected = "exceeds DP length")]
    fn sparsity_beyond_n_rejected() {
        let mut pcu = Pcu::new(PcuRounding::RoundNearest);
        pcu.load_weight_sparsity([300, 0, 0, 0, 0, 0, 0, 0], 256);
    }

    #[test]
    fn estimate_variance_tracks_uncertainty() {
        let map = ComputeMap::operand_based(4, 4);
        // Degenerate sparsity: estimator exact, variance zero.
        assert_eq!(pcu_estimate_variance(&[0; 8], &[128; 8], 256, &map), 0.0);
        assert_eq!(pcu_estimate_variance(&[128; 8], &[256; 8], 256, &map), 0.0);
        // All-digital map: nothing approximated.
        assert_eq!(
            pcu_estimate_variance(&[128; 8], &[128; 8], 256, &ComputeMap::all_digital()),
            0.0
        );
        // Half-dense counts: positive, and growing with DP length.
        let v256 = pcu_estimate_variance(&[128; 8], &[128; 8], 256, &map);
        let v512 = pcu_estimate_variance(&[256; 8], &[256; 8], 512, &map);
        assert!(v256 > 0.0);
        assert!(v512 > v256);
        assert_eq!(pcu_estimate_variance(&[0; 8], &[0; 8], 0, &map), 0.0);
    }

    #[test]
    fn all_digital_map_means_no_pcu_work() {
        let mut pcu = Pcu::new(PcuRounding::RoundNearest);
        pcu.load_weight_sparsity([1; 8], 8);
        let mut stats = PceStats::default();
        let v = pcu.sparsity_sum(&[1; 8], &ComputeMap::all_digital(), &mut stats);
        assert_eq!(v, 0);
        assert_eq!(stats.pcu_ops, 0);
    }
}
