//! `weights.bin` — the quantized-model sidecar artifact.
//!
//! Written by `python/compile/aot.py` after build-time training + PTQ;
//! read here to construct the bit-true model for the architecture
//! simulator. (The PJRT serving path uses the HLO artifact with baked-in
//! weights; this sidecar is what lets the rust simulator replay the same
//! network MAC-by-MAC.) Little-endian binary:
//!
//! ```text
//! magic  b"PACW", version u32 = 1, n_entries u32
//! entry: name_len u16, name utf8,
//!        dtype u8 (0 = u8, 1 = i32, 2 = f32),
//!        ndim u8, dims u32 × ndim,
//!        scale f32, zero_point i32,   // quantization (u8 entries)
//!        data
//! ```

use crate::tensor::QuantParams;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"PACW";
const VERSION: u32 = 1;

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    U8 = 0,
    I32 = 1,
    F32 = 2,
}

impl DType {
    fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(DType::U8),
            1 => Ok(DType::I32),
            2 => Ok(DType::F32),
            _ => Err(Error::Artifact(format!("unknown dtype tag {v}"))),
        }
    }

    fn elem_size(self) -> usize {
        match self {
            DType::U8 => 1,
            DType::I32 | DType::F32 => 4,
        }
    }
}

/// One stored tensor.
#[derive(Debug, Clone)]
pub struct Entry {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub scale: f32,
    pub zero_point: i32,
    pub data: Vec<u8>,
}

impl Entry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        if self.dtype != DType::U8 {
            return Err(Error::Artifact("entry is not u8".into()));
        }
        Ok(&self.data)
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            return Err(Error::Artifact("entry is not f32".into()));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            return Err(Error::Artifact("entry is not i32".into()));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn quant_params(&self) -> QuantParams {
        QuantParams::new(self.scale, self.zero_point)
    }
}

/// The full weight store, keyed by entry name.
#[derive(Debug, Clone, Default)]
pub struct WeightStore {
    pub entries: BTreeMap<String, Entry>,
}

fn read_exact_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

impl WeightStore {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref()).map_err(|e| {
            Error::Artifact(format!(
                "cannot open weights {} (run `make artifacts`): {e}",
                path.as_ref().display()
            ))
        })?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Artifact("bad weights magic".into()));
        }
        if read_exact_u32(&mut f)? != VERSION {
            return Err(Error::Artifact("unsupported weights version".into()));
        }
        let n = read_exact_u32(&mut f)? as usize;
        let mut entries = BTreeMap::new();
        for _ in 0..n {
            let mut b2 = [0u8; 2];
            f.read_exact(&mut b2)?;
            let name_len = u16::from_le_bytes(b2) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name)
                .map_err(|_| Error::Artifact("non-utf8 entry name".into()))?;
            let mut b1 = [0u8; 1];
            f.read_exact(&mut b1)?;
            let dtype = DType::from_u8(b1[0])?;
            f.read_exact(&mut b1)?;
            let ndim = b1[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_exact_u32(&mut f)? as usize);
            }
            let mut b4 = [0u8; 4];
            f.read_exact(&mut b4)?;
            let scale = f32::from_le_bytes(b4);
            f.read_exact(&mut b4)?;
            let zero_point = i32::from_le_bytes(b4);
            let numel: usize = shape.iter().product();
            let mut data = vec![0u8; numel * dtype.elem_size()];
            f.read_exact(&mut data)?;
            entries.insert(
                name,
                Entry {
                    dtype,
                    shape,
                    scale,
                    zero_point,
                    data,
                },
            );
        }
        Ok(Self { entries })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for (name, e) in &self.entries {
            f.write_all(&(name.len() as u16).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&[e.dtype as u8, e.shape.len() as u8])?;
            for &d in &e.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            f.write_all(&e.scale.to_le_bytes())?;
            f.write_all(&e.zero_point.to_le_bytes())?;
            f.write_all(&e.data)?;
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("missing weights entry '{name}'")))
    }

    pub fn insert_u8(&mut self, name: &str, shape: &[usize], data: Vec<u8>, p: QuantParams) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        self.entries.insert(
            name.into(),
            Entry {
                dtype: DType::U8,
                shape: shape.to_vec(),
                scale: p.scale,
                zero_point: p.zero_point,
                data,
            },
        );
    }

    pub fn insert_f32(&mut self, name: &str, shape: &[usize], data: &[f32]) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        self.entries.insert(
            name.into(),
            Entry {
                dtype: DType::F32,
                shape: shape.to_vec(),
                scale: 1.0,
                zero_point: 0,
                data: data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            },
        );
    }

    /// Fetch a `(scale, zero_point)` pair stored as a 2-element f32 tensor
    /// (the `<layer>.oq` convention shared with aot.py).
    pub fn get_qparams(&self, name: &str) -> Result<QuantParams> {
        let e = self.get(name)?;
        let v = e.as_f32()?;
        if v.len() != 2 {
            return Err(Error::Artifact(format!("'{name}' is not a qparams pair")));
        }
        Ok(QuantParams::new(v[0], v[1].round() as i32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_dtypes() {
        let mut s = WeightStore::default();
        s.insert_u8("w", &[2, 3], vec![1, 2, 3, 4, 5, 6], QuantParams::new(0.5, 128));
        s.insert_f32("b", &[3], &[0.5, -1.0, 2.25]);
        s.insert_f32("layer.oq", &[2], &[0.125, 7.0]);
        let path = std::env::temp_dir().join("pacim_test_weights.bin");
        s.save(&path).unwrap();
        let back = WeightStore::load(&path).unwrap();
        assert_eq!(back.get("w").unwrap().as_u8().unwrap(), &[1, 2, 3, 4, 5, 6]);
        assert_eq!(back.get("w").unwrap().quant_params(), QuantParams::new(0.5, 128));
        assert_eq!(back.get("b").unwrap().as_f32().unwrap(), vec![0.5, -1.0, 2.25]);
        let qp = back.get_qparams("layer.oq").unwrap();
        assert_eq!(qp, QuantParams::new(0.125, 7));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_entry_reports_name() {
        let s = WeightStore::default();
        let err = s.get("conv9.w").unwrap_err();
        assert!(err.to_string().contains("conv9.w"));
    }

    #[test]
    fn wrong_dtype_rejected() {
        let mut s = WeightStore::default();
        s.insert_f32("b", &[1], &[1.0]);
        assert!(s.get("b").unwrap().as_u8().is_err());
        assert!(s.get("b").unwrap().as_i32().is_err());
    }

    #[test]
    fn truncated_file_rejected() {
        let mut s = WeightStore::default();
        s.insert_u8("w", &[4], vec![9; 4], QuantParams::new(1.0, 0));
        let path = std::env::temp_dir().join("pacim_test_weights_trunc.bin");
        s.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 1]).unwrap();
        assert!(WeightStore::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
