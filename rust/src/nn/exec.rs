//! Bit-true quantized inference engine (the paper's "PyTorch-based
//! simulation framework that accurately reflects bitwise operations of
//! CiM", §6.1 — re-implemented in rust).
//!
//! The engine interprets the model IR over per-image CHW `u8`
//! activations. Convolutions/linears run through a [`MacBackend`]: the
//! exact backend computes the integer GEMM directly; the PAC backend
//! (`nn::pac_exec`) replays the hybrid digital/sparsity computation of
//! the PACiM bank. Everything around the MACs (im2col, requantization,
//! pooling, residual adds) is shared, so accuracy differences between
//! engines isolate the approximation itself.
//!
//! **Sparsity-encoded dataplane** (§3.1/§4.5): when a conv's output
//! flows directly into another conv whose backend consumes packed
//! planes ([`MacBackend::packed_input_bits`]), the producer requantizes
//! each accumulator once and scatters it straight into the consumer's
//! im2col slab, bit-plane-packs it, and hands the planes over — no
//! dense u8 activation tensor exists on that edge and the consumer
//! never re-packs. Numerically inert (the packed planes are
//! byte-identical to packing the dense matrix), so logits and cycle
//! statistics match the dense round-trip bit for bit; only the measured
//! [`TrafficLedger`] (and speed) differ. Exact mode keeps the dense
//! path end to end and stays the bit-identity reference.
//!
//! **Residual skip edges** ride the same representation: a `SaveSkip`
//! adjacent to its producing conv stores that conv's (post-add) output
//! as packed planes + counters + quant params in a scratch-resident
//! skip slot — no dense CHW copy — and the matching `AddSkip` is folded
//! into the consuming conv's requantize epilogue, so the add operand
//! never moves at all (recorded as an eliminated
//! [`EdgeKind::ResidualIn`] edge). The add *arithmetic*
//! (requantize → dequantize-add → requantize) is identical in both
//! dataplane modes, so `fuse_dataplane = false` reproduces logits,
//! stats, and cycle counters bit for bit; only the representation and
//! the ledger rows differ. [`MacBackend::fuse_residual`] is the switch.

use super::layers::{ConvLayer, Model, Op};
use crate::arch::LevelHistogram;
use crate::engine::{EngineResult, PacimError};
use crate::fault::{self, FaultConfig, FaultLedger};
use crate::memory::{EdgeKind, TrafficLedger};
use crate::tensor::{
    im2col_into, im2col_scatter_into, Conv2dGeom, PackedPatches, QuantParams, Tensor,
};
use crate::util::Parallelism;

/// Output pixels per GEMM tile: the unit of rayon fan-out *and* of cache
/// blocking in the blocked backends (a tile's activation planes stay
/// L1-hot while each weight row streams through exactly once per tile).
/// 32 pixels × 4 MSB planes × ≤128 words × 8 B ≤ 128 KiB worst-case,
/// ≤ 9 KiB on the common CIFAR shapes.
pub(crate) const TILE_PIXELS: usize = 32;

/// Nonce salt for the save-slot transmission channel: one layer can own
/// both a conv→conv inbox edge and an encoded save edge in the same
/// pass, and their position-keyed fault draws must stay independent.
const SAVE_EDGE_NONCE_SALT: u64 = 0x5341_5645; // "SAVE"

/// Per-run statistics (accuracy benches aggregate these across images).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Total MACs executed.
    pub macs: u64,
    /// Digital bit-serial cycles (per output MAC, summed).
    pub digital_cycles: u64,
    /// PCU (sparsity-domain) ops.
    pub pcu_ops: u64,
    /// Dynamic-level decisions (empty when dynamic config is off).
    pub levels: LevelHistogram,
    /// Measured inter-layer activation traffic (bits actually moved, per
    /// edge, tagged encoded vs dense) — the workload-measured
    /// counterpart of the analytic `memory::traffic` model.
    pub traffic: TrafficLedger,
    /// Per-layer injected-fault counters (empty when faults are off).
    pub faults: FaultLedger,
    /// PAC→exact escalations performed (auto fidelity; 0 or 1 per image).
    pub escalations: u64,
    /// Accumulated PCU estimator variance of the **terminal** PAC layer's
    /// outputs, in accumulator LSB² (DESIGN.md §15). Stays 0 unless the
    /// backend's escalation monitor is armed; summed in tile order, so
    /// the f64 total is bit-identical across par on/off.
    pub estimator_var: f64,
}

impl RunStats {
    pub fn merge(&mut self, other: &RunStats) {
        self.macs += other.macs;
        self.digital_cycles += other.digital_cycles;
        self.pcu_ops += other.pcu_ops;
        self.levels.merge(&other.levels);
        self.traffic.merge(&other.traffic);
        self.faults.merge(&other.faults);
        self.escalations += other.escalations;
        self.estimator_var += other.estimator_var;
    }

    /// Average digital cycles per 8b/8b MAC (64 would be fully digital).
    pub fn avg_cycles_per_mac(&self) -> f64 {
        if self.macs == 0 {
            return 0.0;
        }
        self.digital_cycles as f64 / self.macs as f64
    }
}

/// One residual skip operand, parked between its `SaveSkip` and the
/// matching `AddSkip`: either packed MSB planes + sparsity counters (the
/// encoded dataplane form — no dense CHW copy exists) or the dense CHW
/// tensor of the round-trip baseline, plus the quant params needed to
/// dequantize it at add time.
#[derive(Debug, Clone)]
struct SkipSlot {
    /// Packed planes of the pixel-major `[pix][c]` operand (encoded form).
    packed: PackedPatches,
    /// Dense CHW copy (round-trip baseline form).
    dense: Vec<u8>,
    /// Which of the two representations is live.
    encoded: bool,
    /// Quantization of the saved operand.
    params: QuantParams,
    /// `(c, h, w)` of the saved operand.
    shape: (usize, usize, usize),
}

impl Default for SkipSlot {
    fn default() -> Self {
        SkipSlot {
            packed: PackedPatches::default(),
            dense: Vec::new(),
            encoded: false,
            params: QuantParams::new(1.0, 0),
            shape: (0, 0, 0),
        }
    }
}

impl SkipSlot {
    /// The saved u8 operand at channel `c`, pixel `pix` (`pixels` is the
    /// operand's `h·w`). Reads the encoded slab exactly as transmitted,
    /// so injected save-edge plane flips are visible here.
    fn value(&self, pix: usize, c: usize, pixels: usize) -> u8 {
        if self.encoded {
            self.packed.value(pix, c)
        } else {
            self.dense[c * pixels + pix]
        }
    }
}

/// LIFO arena of [`SkipSlot`]s. Slots are never dropped mid-run: `pop`
/// only moves the depth pointer, so a popped operand stays readable
/// while the consuming conv's epilogue streams it — and the storage is
/// reused by the next push (typically the same conv saving its own
/// post-add output), keeping steady state allocation-free.
#[derive(Debug, Clone, Default)]
struct SkipArena {
    slots: Vec<SkipSlot>,
    depth: usize,
}

impl SkipArena {
    fn reset(&mut self) {
        self.depth = 0;
    }

    /// Pop the top slot, returning its (still-valid) index.
    fn pop(&mut self) -> Option<usize> {
        if self.depth == 0 {
            None
        } else {
            self.depth -= 1;
            Some(self.depth)
        }
    }

    /// Push a slot and hand it out for filling (contents are stale from
    /// a previous run/pop; every field must be overwritten).
    fn push_slot(&mut self) -> &mut SkipSlot {
        if self.depth == self.slots.len() {
            self.slots.push(SkipSlot::default());
        }
        let slot = &mut self.slots[self.depth];
        self.depth += 1;
        slot
    }
}

/// Reusable per-run working set of the interpreter: the im2col matrix,
/// the packed activation planes, the accumulator slab of the layer in
/// flight, and the residual skip-slot arena. One scratch serves a whole
/// forward pass (buffers grow to the largest layer once, then every
/// subsequent layer — and, when the caller reuses the scratch, every
/// subsequent image — runs with zero per-pixel heap allocation).
#[derive(Debug, Clone, Default)]
pub struct ModelScratch {
    /// `[pixels][k]` im2col patch matrix of the current conv layer.
    cols: Vec<u8>,
    /// `[pixel][oc]` accumulator slab filled by [`MacBackend::gemm_layer`].
    acc: Vec<i64>,
    /// Packed activation bit-planes (ignored by non-bit-plane backends).
    planes: PackedPatches,
    /// Producer-packed planes for the *next* compute layer: the
    /// sparsity-encoded dataplane inbox. A fusing producer requantizes
    /// its accumulators straight into `cols` (inverse-im2col scatter)
    /// and packs them here; the consumer then runs from this slab and
    /// never re-packs.
    inbox: PackedPatches,
    /// Pixel-major `[pix][c]` staging of a saving conv's epilogue output
    /// — the scatter, the dense transpose, and the skip slot all read it
    /// (and staging first lets a popped operand slot be reused as the
    /// same conv's save slot).
    stage: Vec<u8>,
    /// Residual skip slots (encoded planes or dense CHW + quant params).
    skips: SkipArena,
}

/// One compute layer's input as handed to [`MacBackend::gemm_layer`]:
/// the dense `[pixels][k]` im2col matrix, or the same matrix already
/// bit-plane-packed by the *producing* layer (the sparsity-encoded
/// dataplane handoff). The interpreter only passes `Packed` to layers
/// that advertise it via [`MacBackend::packed_input_bits`].
#[derive(Debug, Clone, Copy)]
pub enum GemmInput<'a> {
    /// Dense im2col matrix, `[pixels][k]` row-major u8.
    Dense(&'a [u8]),
    /// Producer-packed bit-planes + sparsity counters of that matrix.
    Packed(&'a PackedPatches),
}

/// Backend computing signed accumulators `Σ_k (x−zpx)(w−zpw)` for every
/// output channel of every output pixel of one compute layer.
pub trait MacBackend {
    /// Called once per compute layer in program order; `layer_id` indexes
    /// subsequent `gemm_layer` calls.
    fn prepare(&mut self, layer_id: usize, weight: &Tensor<u8>, zpw: i32);

    /// Binary activation bit-planes this backend actually reads for
    /// `layer_id` when its input arrives pre-packed — the MSB width of
    /// the sparsity-encoded dataplane (paper default 4). `None` (the
    /// default) ⇒ the layer consumes a dense u8 im2col matrix and the
    /// interpreter must not fuse into it (exact backends, digital
    /// fallback layers, fusion disabled).
    fn packed_input_bits(&self, _layer_id: usize) -> Option<u32> {
        None
    }

    /// Whether residual skip slots should be kept in the encoded
    /// representation (packed MSB planes + sparsity counters, all 8
    /// planes so the add operand survives exactly) and the add-operand
    /// edge eliminated. `false` (the default) keeps dense CHW slots —
    /// the round-trip baseline. The add *arithmetic* is fused into the
    /// producing conv's epilogue either way; this switches only the
    /// representation and the traffic accounting, so both settings are
    /// bit-identical on logits and cycle statistics.
    fn fuse_residual(&self) -> bool {
        false
    }

    /// The backend's active fault model, if any (`pacim::fault`,
    /// DESIGN.md §15). The interpreter consults it for the encoded-edge
    /// transmission channels (conv→conv inbox and encoded save slots)
    /// and to derive the per-image content nonce it threads through
    /// [`Self::gemm_layer`]; `None` (the default) keeps every fault path
    /// compiled out of the hot loop.
    fn fault(&self) -> Option<&FaultConfig> {
        None
    }

    /// Layer-level blocked GEMM. `input` is the `[pixels][k]` im2col
    /// matrix, dense or producer-packed (`k` = DP length; a linear layer
    /// is `pixels = 1`); `out` is resized to `pixels * out_c` and filled
    /// `[pixel][oc]`.
    ///
    /// `par` is the driver's tile fan-out policy and `planes` the
    /// reusable packing scratch for dense inputs (backends that don't
    /// bit-plane-pack ignore it). `nonce` is the per-image content nonce
    /// for position-keyed runtime fault draws (0 when faults are off;
    /// fault-free backends ignore it). Implementations must be
    /// **bit-deterministic**: the same input produces the same `out` and
    /// `stats` for every `par`, thread count, schedule, and input form
    /// (`Packed` planes are byte-identical to packing the dense matrix).
    #[allow(clippy::too_many_arguments)]
    fn gemm_layer(
        &self,
        layer_id: usize,
        input: GemmInput<'_>,
        pixels: usize,
        zpx: i32,
        nonce: u64,
        par: &Parallelism,
        planes: &mut PackedPatches,
        out: &mut Vec<i64>,
        stats: &mut RunStats,
    );
}

/// Exact integer backend (the 8-bit QAT/PTQ reference).
#[derive(Default)]
pub struct ExactBackend {
    /// Per layer: (weights [n, k] as i32-ready u8, zpw, k).
    layers: Vec<(Tensor<u8>, i32)>,
}

impl MacBackend for ExactBackend {
    fn prepare(&mut self, layer_id: usize, weight: &Tensor<u8>, zpw: i32) {
        assert_eq!(layer_id, self.layers.len(), "layers must prepare in order");
        self.layers.push((weight.clone(), zpw));
    }

    fn gemm_layer(
        &self,
        layer_id: usize,
        input: GemmInput<'_>,
        pixels: usize,
        zpx: i32,
        _nonce: u64,
        par: &Parallelism,
        _planes: &mut PackedPatches,
        out: &mut Vec<i64>,
        stats: &mut RunStats,
    ) {
        let cols = match input {
            GemmInput::Dense(c) => c,
            // Contract: the interpreter fuses only into layers whose
            // `packed_input_bits` is Some; this backend never opts in.
            GemmInput::Packed(_) => panic!("exact backend cannot consume packed input"),
        };
        let (w, zpw) = &self.layers[layer_id];
        let n = w.shape()[0];
        let k = w.shape()[1];
        debug_assert_eq!(cols.len(), pixels * k);
        out.clear();
        out.resize(pixels * n, 0);
        exact_gemm_tiled(w.data(), *zpw, cols, k, n, pixels, zpx, par, out, stats);
    }
}

/// Tiled exact integer GEMM (the 8b/8b fully digital D-CiM kernel),
/// shared by [`ExactBackend`] and the PAC backend's first-layer /
/// short-DP exact fallback. `out` must already be sized `pixels * n`.
/// Pixel tiles own disjoint `[pixel][oc]` rows of the slab and the
/// per-(pixel, oc) arithmetic is identical for any schedule, so the
/// fan-out is bit-deterministic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exact_gemm_tiled(
    wd: &[u8],
    zpw: i32,
    cols: &[u8],
    k: usize,
    n: usize,
    pixels: usize,
    zpx: i32,
    par: &Parallelism,
    out: &mut [i64],
    stats: &mut RunStats,
) {
    debug_assert_eq!(out.len(), pixels * n);
    stats.macs += (pixels * n * k) as u64;
    stats.digital_cycles += (pixels * n) as u64 * 64; // 8b/8b fully digital
    if out.is_empty() {
        return;
    }
    let zpw = zpw as i64;
    let zpx = zpx as i64;
    par.map_chunks_mut(out, TILE_PIXELS * n, |t, chunk| {
        let p0 = t * TILE_PIXELS;
        for (j, row) in chunk.chunks_exact_mut(n).enumerate() {
            let patch = &cols[(p0 + j) * k..(p0 + j + 1) * k];
            for (oc, slot) in row.iter_mut().enumerate() {
                let wrow = &wd[oc * k..(oc + 1) * k];
                let mut acc = 0i64;
                for (&x, &wv) in patch.iter().zip(wrow) {
                    acc += (x as i64 - zpx) * (wv as i64 - zpw);
                }
                *slot = acc;
            }
        }
    });
}

/// The shared interpreter: runs `model` on one quantized CHW image with
/// an explicit parallelism policy (handed to each layer's blocked GEMM
/// as the tile fan-out policy — tiles of [`TILE_PIXELS`] output pixels)
/// and a caller-owned scratch arena. Serving workers and evaluation
/// loops thread one [`ModelScratch`] per worker through every request so
/// steady-state inference allocates nothing per pixel.
///
/// Bit-identical for any `par`: tiles own disjoint output rows, per-tile
/// statistics are integer counters merged in tile order, and backends
/// are required to be bit-deterministic. This is the low-level reference
/// entry point; typed, validated inference goes through `pacim::engine`
/// (`EngineBuilder::new(model).build()?.session().infer(&img)?`).
///
/// # Errors
///
/// Zero-panic contract: a wrong-sized `image` returns
/// [`PacimError::ShapeMismatch`]; malformed programs (an `AddSkip`
/// without a matching `SaveSkip`, a skip operand whose shape disagrees
/// with the activation it is added to, a program that never reaches a
/// logits layer) return [`PacimError::Model`] /
/// [`PacimError::ShapeMismatch`].
pub fn run_model_with<B: MacBackend + Sync>(
    model: &Model,
    backend: &B,
    image: &[u8],
    par: &Parallelism,
    scratch: &mut ModelScratch,
) -> EngineResult<(Vec<f32>, RunStats)> {
    let want = model.in_c * model.in_hw * model.in_hw;
    if image.len() != want {
        return Err(PacimError::ShapeMismatch {
            context: "run_model input".into(),
            got: image.len(),
            want,
        });
    }
    let mut stats = RunStats::default();
    // Per-image content nonce for the runtime fault channels: computed
    // once, independent of lane index and tile schedule, 0 (and no hash
    // pass) when the backend carries no fault model.
    let nonce = match backend.fault() {
        Some(fc) if !fc.is_off() => fault::image_nonce(image),
        _ => 0,
    };
    scratch.skips.reset();
    let mut act = image.to_vec();
    let mut params = model.input_params;
    let mut shape = (model.in_c, model.in_hw, model.in_hw);
    let mut layer_id = 0usize;
    let mut logits: Option<Vec<f32>> = None;
    // When true, the previous conv emitted its output in encoded form
    // straight into `scratch` (cols scattered + inbox packed): the
    // sparsity-encoded dataplane handoff. `act` is stale and the fusion
    // condition guarantees the very next op is the consuming conv.
    let mut packed_ready = false;

    let ops = &model.ops;
    let mut i = 0usize;
    while i < ops.len() {
        match &ops[i] {
            Op::Conv2d(conv) => {
                // Canonical residual grammar around a conv: an optional
                // `AddSkip` folded into this conv's epilogue, then an
                // optional `SaveSkip` of the (post-add) output. Both are
                // consumed here; any other arrangement falls through to
                // the generic standalone arms below.
                let mut j = i + 1;
                let add = match ops.get(j) {
                    Some(Op::AddSkip { out_params, relu }) => {
                        j += 1;
                        Some((*out_params, *relu))
                    }
                    _ => None,
                };
                let save = matches!(ops.get(j), Some(Op::SaveSkip));
                if save {
                    j += 1;
                }
                // Fuse the producer-side emit when the (post-add) output
                // flows into a conv that consumes packed planes.
                let fuse_next = match ops.get(j) {
                    Some(Op::Conv2d(next)) => backend
                        .packed_input_bits(layer_id + 1)
                        .map(|bits| (&next.geom, bits)),
                    _ => None,
                };
                let plan = ConvPlan {
                    add,
                    save,
                    fuse_next,
                    out_kind: consumer_kind(ops, j),
                };
                let (out, op_params, oshape) = run_conv(
                    conv,
                    &act,
                    params,
                    layer_id,
                    backend,
                    &mut stats,
                    par,
                    scratch,
                    packed_ready,
                    plan,
                    nonce,
                )?;
                packed_ready = out.is_none();
                act = out.unwrap_or_default();
                params = op_params;
                shape = oshape;
                layer_id += 1;
                i = j;
            }
            Op::Linear(lin) => {
                debug_assert!(!packed_ready, "fusion never targets a linear layer");
                let (c, h, w) = shape;
                if c * h * w != lin.in_f {
                    return Err(PacimError::Model(format!(
                        "linear input mismatch at {}: {c}×{h}×{w} != {}",
                        lin.name, lin.in_f
                    )));
                }
                backend.gemm_layer(
                    layer_id,
                    GemmInput::Dense(&act[..]),
                    1,
                    params.zero_point,
                    nonce,
                    par,
                    &mut scratch.planes,
                    &mut scratch.acc,
                    &mut stats,
                );
                let sx = params.scale;
                let sw = lin.wparams.scale;
                let reals: Vec<f32> = scratch
                    .acc
                    .iter()
                    .enumerate()
                    .map(|(i, &a)| a as f32 * sx * sw + lin.bias[i])
                    .collect();
                match &lin.out_params {
                    None => {
                        // Terminal logits go to the host, not the
                        // activation cache: no traffic edge.
                        logits = Some(reals);
                        break;
                    }
                    Some(oq) => {
                        act = reals
                            .iter()
                            .map(|&r| oq.quantize(if lin.relu { r.max(0.0) } else { r }))
                            .collect();
                        // Hidden FC output: one layer-wise group, dense,
                        // feeding the next linear.
                        stats
                            .traffic
                            .record_dense(layer_id, EdgeKind::Linear, 1, lin.out_f as u64);
                        params = *oq;
                        shape = (lin.out_f, 1, 1);
                    }
                }
                layer_id += 1;
                i += 1;
            }
            Op::MaxPool2 => {
                let (c, h, w) = shape;
                let (oh, ow) = (h / 2, w / 2);
                let mut out = vec![0u8; c * oh * ow];
                for ch in 0..c {
                    for y in 0..oh {
                        for x in 0..ow {
                            let mut m = 0u8;
                            for dy in 0..2 {
                                for dx in 0..2 {
                                    m = m.max(act[(ch * h + 2 * y + dy) * w + 2 * x + dx]);
                                }
                            }
                            out[(ch * oh + y) * ow + x] = m;
                        }
                    }
                }
                act = out;
                shape = (c, oh, ow);
                i += 1;
            }
            Op::GlobalAvgPool => {
                let (c, h, w) = shape;
                let px = h * w;
                let mut out = vec![0u8; c];
                for ch in 0..c {
                    let sum: u32 = act[ch * px..(ch + 1) * px].iter().map(|&v| v as u32).sum();
                    out[ch] = ((sum + px as u32 / 2) / px as u32) as u8;
                }
                act = out;
                shape = (c, 1, 1);
                i += 1;
            }
            Op::SaveSkip => {
                // Standalone save (producer was a pool, a hidden linear,
                // or the program input): the operand is already dense
                // CHW; park it as-is. Skip edges are only modeled in the
                // ledger when conv-adjacent (the fused grammar above).
                let slot = scratch.skips.push_slot();
                slot.encoded = false;
                slot.params = params;
                slot.shape = shape;
                slot.dense.clear();
                slot.dense.extend_from_slice(&act);
                i += 1;
            }
            Op::AddSkip { out_params, relu } => {
                // Standalone add (not immediately after a conv): dense
                // elementwise dequantize-add-requantize over `act`.
                let idx = scratch.skips.pop().ok_or_else(|| {
                    PacimError::Model("AddSkip without a matching SaveSkip".into())
                })?;
                let slot = &scratch.skips.slots[idx];
                if slot.shape != shape {
                    return Err(shape_mismatch("AddSkip operand", slot.shape, shape));
                }
                let (_, h, w) = shape;
                let px = h * w;
                act = act
                    .iter()
                    .enumerate()
                    .map(|(e, &a)| {
                        let (ch, pix) = (e / px, e % px);
                        let r = params.dequantize(a)
                            + slot.params.dequantize(slot.value(pix, ch, px));
                        out_params.quantize(if *relu { r.max(0.0) } else { r })
                    })
                    .collect();
                params = *out_params;
                i += 1;
            }
        }
    }
    let logits =
        logits.ok_or_else(|| PacimError::Model("model did not end in a logits layer".into()))?;
    Ok((logits, stats))
}

/// Run a batch of images through the interpreter, fanning the *lanes*
/// out over rayon (the intra-batch parallelism of the serving path: each
/// lane is one whole forward pass, so the fan-out threshold is coarse —
/// see [`Parallelism::coarse`]) with caller-owned per-lane scratch
/// arenas (`scratches.len() >= images.len()`): the serving executor
/// keeps its arenas across requests, so a warm worker's whole forward
/// pass runs out of reused buffers. Each lane's driver is scalar (the
/// lanes *are* the parallel grain); a backend's configured parallelism
/// still applies. Bit-identical to looping [`run_model_with`] over
/// `images`: lanes are independent and collected in lane order; the
/// first lane error (in lane order) is returned. Typed batch inference
/// goes through `Session::infer_batch`.
pub fn run_model_batch_with<B: MacBackend + Sync>(
    model: &Model,
    backend: &B,
    images: &[&[u8]],
    par: &Parallelism,
    scratches: &mut [ModelScratch],
) -> EngineResult<Vec<(Vec<f32>, RunStats)>> {
    assert!(
        scratches.len() >= images.len(),
        "need one scratch per lane: {} < {}",
        scratches.len(),
        images.len()
    );
    let lanes = images.len();
    par.map_chunks_mut(&mut scratches[..lanes], 1, |lane, s| {
        run_model_with(model, backend, images[lane], &Parallelism::off(), &mut s[0])
    })
    .into_iter()
    .collect()
}

/// The consumed-op plan of one conv: what the surrounding program asked
/// this layer's epilogue to absorb.
struct ConvPlan<'a> {
    /// `AddSkip` folded into the epilogue: `(out_params, relu)`.
    add: Option<(QuantParams, bool)>,
    /// `SaveSkip` of the (post-add) output into a skip slot.
    save: bool,
    /// Scatter + pack straight into the next conv (`geom`, MSB planes).
    fuse_next: Option<(&'a Conv2dGeom, u32)>,
    /// Consumer class of the (post-add) output edge when it is not a
    /// residual-add edge.
    out_kind: EdgeKind,
}

/// Consumer class of the op at `j` (the first op after everything this
/// conv consumed) — what the conv's output edge feeds.
fn consumer_kind(ops: &[Op], j: usize) -> EdgeKind {
    match ops.get(j) {
        Some(Op::Linear(_)) => EdgeKind::Linear,
        Some(Op::MaxPool2) | Some(Op::GlobalAvgPool) => EdgeKind::Pool,
        _ => EdgeKind::Conv,
    }
}

/// Transpose the pixel-major `[pix][c]` staging buffer into the CHW
/// activation layout (`dst` is fully overwritten).
fn transpose_to_chw(stage: &[u8], out_c: usize, pixels: usize, dst: &mut Vec<u8>) {
    dst.clear();
    dst.resize(out_c * pixels, 0);
    for pix in 0..pixels {
        for c in 0..out_c {
            dst[c * pixels + pix] = stage[pix * out_c + c];
        }
    }
}

fn shape_mismatch(
    context: &str,
    got: (usize, usize, usize),
    want: (usize, usize, usize),
) -> PacimError {
    PacimError::ShapeMismatch {
        context: format!("{context}: {got:?} vs {want:?}"),
        got: got.0 * got.1 * got.2,
        want: want.0 * want.1 * want.2,
    }
}

/// Run one conv layer. `packed_input` means the producer already
/// scattered + packed this layer's im2col matrix into `scratch`
/// (`cols`/`inbox`); `plan.fuse_next` asks this layer to do the same for
/// the next one — requantize each accumulator **once** (folding a
/// consumed `AddSkip` into the same pass), scatter the u8 straight into
/// the next layer's im2col slab (no dense CHW tensor ever exists),
/// bit-plane-pack it, and record the edge as encoded traffic. Returns
/// `None` for the dense output in that case. A consumed `SaveSkip`
/// parks the (post-add) output in a skip slot — packed planes when the
/// backend opts into [`MacBackend::fuse_residual`], dense CHW otherwise.
#[allow(clippy::too_many_arguments)]
fn run_conv<B: MacBackend + Sync>(
    conv: &ConvLayer,
    act: &[u8],
    in_params: QuantParams,
    layer_id: usize,
    backend: &B,
    stats: &mut RunStats,
    par: &Parallelism,
    scratch: &mut ModelScratch,
    packed_input: bool,
    plan: ConvPlan<'_>,
    nonce: u64,
) -> EngineResult<(Option<Vec<u8>>, QuantParams, (usize, usize, usize))> {
    let g = &conv.geom;
    let pixels = g.out_pixels();
    let out_c = g.out_c;
    let ModelScratch { cols, acc, planes, inbox, stage, skips } = scratch;
    if packed_input {
        backend.gemm_layer(
            layer_id,
            GemmInput::Packed(&*inbox),
            pixels,
            in_params.zero_point,
            nonce,
            par,
            planes,
            acc,
            stats,
        );
    } else {
        im2col_into(act, g, in_params.zero_point as u8, cols);
        backend.gemm_layer(
            layer_id,
            GemmInput::Dense(&cols[..]),
            pixels,
            in_params.zero_point,
            nonce,
            par,
            planes,
            acc,
            stats,
        );
    }
    let sx = in_params.scale;
    let sw = conv.wparams.scale;
    let oshape = (out_c, g.out_h(), g.out_w());
    let oq = conv.out_params;
    let fused = backend.fuse_residual();

    // Pop the skip operand a consumed `AddSkip` reads. The slot index
    // stays valid (and its contents untouched) until this conv pushes
    // its own save — the arena never drops storage mid-run.
    let add = match plan.add {
        Some((add_q, add_relu)) => {
            let idx = skips
                .pop()
                .ok_or_else(|| PacimError::Model("AddSkip without a matching SaveSkip".into()))?;
            let slot_shape = skips.slots[idx].shape;
            if slot_shape != oshape {
                return Err(shape_mismatch("AddSkip operand", slot_shape, oshape));
            }
            Some((idx, add_q, add_relu))
        }
        None => None,
    };
    let slot_encoded = add.map_or(false, |(idx, ..)| skips.slots[idx].encoded);
    let final_params = add.map_or(oq, |(_, q, _)| q);

    // The fused epilogue value: requantize the accumulator once, then
    // (when an `AddSkip` rides on this conv) fold the skip operand in
    // through the same dequantize→add→requantize arithmetic the
    // standalone op uses — bit-identical in both dataplane modes by
    // construction (the intermediate `base` quantization is retained).
    let acc_ref: &[i64] = acc;
    let bias = &conv.bias;
    let relu = conv.relu;
    let emit = |skips: &SkipArena, c: usize, pix: usize| -> u8 {
        let real = acc_ref[pix * out_c + c] as f32 * sx * sw + bias[c];
        let base = oq.quantize(if relu { real.max(0.0) } else { real });
        match add {
            Some((idx, add_q, add_relu)) => {
                let slot = &skips.slots[idx];
                let r = oq.dequantize(base) + slot.params.dequantize(slot.value(pix, c, pixels));
                add_q.quantize(if add_relu { r.max(0.0) } else { r })
            }
            None => base,
        }
    };

    let mut out: Option<Vec<u8>> = None;
    if plan.save {
        // Stage the epilogue output once in pixel-major [pix][c] form;
        // everything downstream (scatter, dense transpose, skip slot)
        // reads the staged bytes.
        stage.clear();
        stage.resize(pixels * out_c, 0);
        for pix in 0..pixels {
            for c in 0..out_c {
                stage[pix * out_c + c] = emit(skips, c, pix);
            }
        }
        if let Some((gnext, msb_bits)) = plan.fuse_next {
            debug_assert_eq!((gnext.in_c, gnext.in_h, gnext.in_w), oshape);
            im2col_scatter_into(gnext, final_params.zero_point as u8, cols, |c, pix| {
                stage[pix * out_c + c]
            });
            inbox.pack(&cols[..], gnext.dp_len(), gnext.out_pixels(), par);
            // Transmission faults hit the encoded edge *after* the
            // producer packs and before the consumer sweeps — exactly
            // the wire. Single-threaded interpreter section, so the
            // ledger row is identical for every tile/lane schedule.
            if let Some(fc) = backend.fault() {
                let flipped = fault::flip_encoded_edge(fc, inbox, layer_id, nonce, msb_bits);
                if flipped > 0 {
                    stats.faults.record_edge(layer_id, flipped);
                }
            }
        } else {
            let mut o = Vec::new();
            transpose_to_chw(stage, out_c, pixels, &mut o);
            out = Some(o);
        }
        let slot = skips.push_slot();
        slot.params = final_params;
        slot.shape = oshape;
        slot.encoded = fused;
        if fused {
            slot.dense.clear();
            slot.packed.pack(&stage[..], out_c, pixels, par);
            // The encoded save edge is a real transmission of all 8
            // planes: it draws its own position-keyed flips, salted so
            // it never aliases the same layer's conv→conv inbox channel.
            if let Some(fc) = backend.fault() {
                let flipped = fault::flip_encoded_edge(
                    fc,
                    &mut slot.packed,
                    layer_id,
                    nonce ^ SAVE_EDGE_NONCE_SALT,
                    8,
                );
                if flipped > 0 {
                    stats.faults.record_edge(layer_id, flipped);
                }
            }
        } else {
            transpose_to_chw(stage, out_c, pixels, &mut slot.dense);
        }
    } else if let Some((gnext, msb_bits)) = plan.fuse_next {
        debug_assert_eq!((gnext.in_c, gnext.in_h, gnext.in_w), oshape);
        im2col_scatter_into(gnext, final_params.zero_point as u8, cols, |c, pix| {
            emit(skips, c, pix)
        });
        inbox.pack(&cols[..], gnext.dp_len(), gnext.out_pixels(), par);
        if let Some(fc) = backend.fault() {
            let flipped = fault::flip_encoded_edge(fc, inbox, layer_id, nonce, msb_bits);
            if flipped > 0 {
                stats.faults.record_edge(layer_id, flipped);
            }
        }
    } else {
        // Output is CHW: out[oc][pixel]; accumulators arrive [pixel][oc].
        let mut o = vec![0u8; out_c * pixels];
        for pix in 0..pixels {
            for c in 0..out_c {
                o[c * pixels + pix] = emit(skips, c, pix);
            }
        }
        out = Some(o);
    }

    // Ledger rows, one per edge this conv's write produced. A consumed
    // add replaces the plain output edge with the residual pair: the
    // operand hand-off (eliminated when it stayed in its slot's encoded
    // form) and the post-add output.
    let (groups, ch) = (pixels as u64, out_c as u64);
    if add.is_some() {
        if slot_encoded {
            stats
                .traffic
                .record_eliminated(layer_id, EdgeKind::ResidualIn, groups, ch);
        } else {
            stats
                .traffic
                .record_dense(layer_id, EdgeKind::ResidualIn, groups, ch);
        }
        match plan.fuse_next {
            Some((_, msb_bits)) => {
                stats
                    .traffic
                    .record_encoded(layer_id, EdgeKind::ResidualAdd, groups, ch, msb_bits)
            }
            None => stats
                .traffic
                .record_dense(layer_id, EdgeKind::ResidualAdd, groups, ch),
        }
    } else {
        match plan.fuse_next {
            Some((_, msb_bits)) => stats
                .traffic
                .record_encoded(layer_id, plan.out_kind, groups, ch, msb_bits),
            None => stats
                .traffic
                .record_dense(layer_id, plan.out_kind, groups, ch),
        }
    }
    if plan.save {
        if fused {
            // All 8 planes travel (the add needs the exact operand
            // back) plus counters — honestly above the dense baseline;
            // the eliminated add-in edge more than pays for it.
            stats
                .traffic
                .record_encoded(layer_id, EdgeKind::ResidualSave, groups, ch, 8);
        } else {
            stats
                .traffic
                .record_dense(layer_id, EdgeKind::ResidualSave, groups, ch);
        }
    }
    Ok((out, final_params, oshape))
}

/// Convenience: build an exact backend prepared for `model`.
pub fn exact_backend(model: &Model) -> ExactBackend {
    let mut b = ExactBackend::default();
    let mut id = 0;
    for op in &model.ops {
        match op {
            Op::Conv2d(c) => {
                b.prepare(id, &c.weight, c.wparams.zero_point);
                id += 1;
            }
            Op::Linear(l) => {
                b.prepare(id, &l.weight, l.wparams.zero_point);
                id += 1;
            }
            _ => {}
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::{synthetic, tiny_resnet};
    use crate::util::rng::Rng;

    /// Scalar-driver, fresh-scratch convenience for these tests (dataset
    /// evaluation goes through `Engine::evaluate`).
    fn run_model<B: MacBackend + Sync>(
        model: &Model,
        backend: &B,
        image: &[u8],
    ) -> (Vec<f32>, RunStats) {
        run_model_with(
            model,
            backend,
            image,
            &Parallelism::off(),
            &mut ModelScratch::default(),
        )
        .unwrap()
    }

    #[test]
    fn exact_engine_runs_tiny_resnet() {
        let mut rng = Rng::new(200);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (logits, stats) = run_model(&model, &backend, &img);
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|l| l.is_finite()));
        assert_eq!(stats.macs, model.macs());
    }

    #[test]
    fn exact_mode_records_dense_residual_rows() {
        // The residual grammar emits one row per edge — save, in-block
        // add operand, post-add output — all dense in exact mode, with
        // the same (layer, kind) keys the fused dataplane uses.
        let mut rng = Rng::new(203);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (_, stats) = run_model(&model, &backend, &img);
        let t = &stats.traffic;
        assert_eq!(t.encoded_layer_count(), 0);
        assert_eq!(t.layers().len(), 15);
        // Stem output is both saved and fed forward.
        assert!(t.row(0, EdgeKind::ResidualSave).is_some());
        assert!(t.row(0, EdgeKind::Conv).is_some());
        // Block tail convs write the add operand and the post-add edge.
        for id in [2, 5, 8] {
            assert!(t.row(id, EdgeKind::ResidualIn).is_some(), "layer {id}");
            assert!(t.row(id, EdgeKind::ResidualAdd).is_some(), "layer {id}");
        }
        // Terminal logits layer records nothing.
        assert!(t.layer(9).is_none());
    }

    #[test]
    fn deterministic_across_runs() {
        let mut rng = Rng::new(201);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (a, _) = run_model(&model, &backend, &img);
        let (b, _) = run_model(&model, &backend, &img);
        assert_eq!(a, b);
    }

    #[test]
    fn different_images_different_logits() {
        let mut rng = Rng::new(202);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let img1: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let img2: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (a, _) = run_model(&model, &backend, &img1);
        let (b, _) = run_model(&model, &backend, &img2);
        assert_ne!(a, b);
    }

    #[test]
    fn wrong_input_size_is_a_typed_error() {
        let mut rng = Rng::new(214);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let err = run_model_with(
            &model,
            &backend,
            &[0u8; 7],
            &Parallelism::off(),
            &mut ModelScratch::default(),
        )
        .unwrap_err();
        assert!(
            matches!(err, PacimError::ShapeMismatch { got: 7, .. }),
            "{err:?}"
        );
    }

    #[test]
    fn addskip_without_saveskip_is_a_typed_error() {
        use crate::nn::layers::LinearLayer;
        let ident = QuantParams::new(1.0, 0);
        let lin = LinearLayer {
            name: "fc".into(),
            in_f: 4,
            out_f: 2,
            weight: Tensor::from_vec(&[2, 4], vec![1u8; 8]),
            wparams: ident,
            bias: vec![0.0, 0.0],
            out_params: None,
            relu: false,
        };
        let model = Model {
            name: "mini".into(),
            ops: vec![
                Op::AddSkip { out_params: ident, relu: false },
                Op::Linear(lin),
            ],
            input_params: ident,
            in_c: 1,
            in_hw: 2,
            num_classes: 2,
        };
        let mut backend = ExactBackend::default();
        if let Op::Linear(l) = &model.ops[1] {
            backend.prepare(0, &l.weight, 0);
        }
        let err = run_model_with(
            &model,
            &backend,
            &[0u8; 4],
            &Parallelism::off(),
            &mut ModelScratch::default(),
        )
        .unwrap_err();
        assert!(matches!(err, PacimError::Model(_)), "{err:?}");
    }

    #[test]
    fn parallel_run_bit_identical_to_scalar() {
        // The rayon pixel fan-out must not change a single bit of the
        // logits or the statistics, at any threshold.
        let mut rng = Rng::new(210);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (a, sa) = run_model(&model, &backend, &img);
        for par in [
            Parallelism::auto(),
            Parallelism {
                enabled: true,
                min_items: 1,
            },
        ] {
            let (b, sb) =
                run_model_with(&model, &backend, &img, &par, &mut ModelScratch::default())
                    .unwrap();
            assert_eq!(a, b);
            assert_eq!(sa.macs, sb.macs);
            assert_eq!(sa.digital_cycles, sb.digital_cycles);
            assert_eq!(sa.pcu_ops, sb.pcu_ops);
            assert_eq!(sa.levels, sb.levels);
        }
    }

    #[test]
    fn batch_run_bit_identical_to_sequential() {
        let mut rng = Rng::new(211);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let imgs: Vec<Vec<u8>> = (0..5)
            .map(|_| (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect())
            .collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|v| v.as_slice()).collect();
        let seq: Vec<(Vec<f32>, RunStats)> = refs
            .iter()
            .map(|img| run_model(&model, &backend, img))
            .collect();
        for par in [Parallelism::off(), Parallelism::coarse()] {
            let mut scratches = vec![ModelScratch::default(); refs.len()];
            let lanes =
                run_model_batch_with(&model, &backend, &refs, &par, &mut scratches).unwrap();
            for ((a, sa), (b, sb)) in seq.iter().zip(&lanes) {
                assert_eq!(a, b);
                assert_eq!(sa.macs, sb.macs);
            }
        }
    }

    #[test]
    fn scratch_reuse_across_images_bit_identical() {
        // One warm ModelScratch threaded through several images (the
        // serving worker pattern) must reproduce fresh-scratch runs
        // exactly — no stale cols/planes/skip-slot state may leak.
        let mut rng = Rng::new(212);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let mut scratch = ModelScratch::default();
        for _ in 0..3 {
            let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
            let (fresh, sf) = run_model(&model, &backend, &img);
            let (warm, sw) =
                run_model_with(&model, &backend, &img, &Parallelism::off(), &mut scratch)
                    .unwrap();
            assert_eq!(fresh, warm);
            assert_eq!(sf.macs, sw.macs);
        }
    }

    #[test]
    fn maxpool_and_gap_shapes() {
        // Covered implicitly by tiny_vgg when artifacts exist; here check
        // the pure ops via a crafted mini-program.
        use crate::nn::layers::LinearLayer;
        let ident = QuantParams::new(1.0, 0);
        let lin = LinearLayer {
            name: "fc".into(),
            in_f: 1,
            out_f: 2,
            weight: Tensor::from_vec(&[2, 1], vec![1u8, 3]),
            wparams: QuantParams::new(1.0, 0),
            bias: vec![0.0, 0.0],
            out_params: None,
            relu: false,
        };
        let model = Model {
            name: "mini".into(),
            ops: vec![Op::MaxPool2, Op::GlobalAvgPool, Op::Linear(lin)],
            input_params: ident,
            in_c: 1,
            in_hw: 4,
            num_classes: 2,
        };
        let mut backend = ExactBackend::default();
        if let Op::Linear(l) = &model.ops[2] {
            backend.prepare(0, &l.weight, 0);
        }
        // 4×4 image; maxpool → 2×2 of maxes; GAP → mean.
        let img = vec![
            1u8, 2, 3, 4, //
            5, 6, 7, 8, //
            9, 10, 11, 12, //
            13, 14, 15, 16,
        ];
        let (logits, _) = run_model(&model, &backend, &img);
        // maxes = [6, 8, 14, 16] → mean 11 → logits [11, 33].
        assert_eq!(logits, vec![11.0, 33.0]);
    }
}
